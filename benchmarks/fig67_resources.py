"""Figs. 6–7: robustness to network resources and tier count.

Fig. 6: converged time vs compute/communication scaling coefficients.
Fig. 7: three-tier HSFL vs two-tier client-edge and client-cloud SFL —
the two-tier baselines are the ``two-tier-*`` system presets of
``repro.api.registry``.
"""
from __future__ import annotations

import numpy as np

from repro.api import build, evaluate_schedule, paper_spec, two_tier_spec
from repro.core import solve_bcd

from .common import POLICIES, emit, expected_converged_time, record


def main(quick: bool = False, seed: int = 0) -> list:
    rows = []
    scales = [0.25, 0.5, 1.0] if quick else [0.125, 0.25, 0.5, 1.0, 2.0]
    draws = 5 if quick else 15
    # Fig. 6: HSFL + 2 representative baselines across resource scalings
    for axis in ("compute", "comm"):
        for s in scales:
            kw = {f"{axis}_scale": s}
            prob = build(paper_spec(seed=seed, **kw)).problem
            for name in ("HSFL(ours)", "RMA+MS", "RMA+RMS"):
                t, _ = expected_converged_time(
                    prob, POLICIES[name], draws=draws, seed=seed
                )
                rows.append((f"fig6_{axis}", s, name, t))
    # Fig. 7: tier count under shrinking resources
    for s in scales:
        b3 = build(paper_spec(seed=seed, compute_scale=s))
        r3 = solve_bcd(b3.problem)
        record(evaluate_schedule(b3, r3.cuts, r3.intervals))
        rows.append(("fig7_compute", s, "three-tier", r3.total_latency))
        for kind in ("client-edge", "client-cloud"):
            b2 = build(two_tier_spec(kind, seed=seed, compute_scale=s))
            r2 = solve_bcd(b2.problem)
            rows.append(("fig7_compute", s, kind, r2.total_latency))
    emit(rows, ("figure", "scale", "policy", "converged_time_s"))
    if quick:  # the claims below need the full scale grid + draw count
        return rows
    # robustness claim: HSFL degrades less than RMA+RMS as resources shrink
    for axis in ("compute", "comm"):
        h = [r[3] for r in rows if r[0] == f"fig6_{axis}" and r[2] == "HSFL(ours)"]
        r_ = [r[3] for r in rows if r[0] == f"fig6_{axis}" and r[2] == "RMA+RMS"]
        assert h[0] / h[-1] <= r_[0] / r_[-1] * 1.5
    # Fig. 7's actual claim is robustness under scarcity: the extra tier
    # pays off when compute is constrained (the cloud's FLOPS matter) and
    # costs an extra hop + an extra bound term when it is not. Assert:
    # (a) three-tier is fastest at the scarcest setting, (b) three-tier
    # never loses to client-cloud (the paper's slow-WAN baseline).
    scarcest = min(scales)
    sub0 = {r[2]: r[3] for r in rows if r[0] == "fig7_compute" and r[1] == scarcest}
    assert sub0["three-tier"] <= min(sub0["client-edge"], sub0["client-cloud"]) * 1.05, sub0
    for s in scales:
        sub = {r[2]: r[3] for r in rows if r[0] == "fig7_compute" and r[1] == s}
        assert sub["three-tier"] <= sub["client-cloud"], sub
    return rows


if __name__ == "__main__":
    main()
