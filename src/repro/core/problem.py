"""The joint MA+MS optimization problem P'' (Eq. 21–24) as an object.

Bundles the three ingredients the solvers need:

* ``LayerProfile``  — per-unit compute/communication quantities (Eq. 11–16),
* ``SystemSpec``    — the multi-tier resource topology,
* ``HyperSpec``     — the convergence-bound constants (Theorem 1),

and exposes the exact objective

    Θ'(I, μ) = (2ϑ/γ) · N(I, μ) / D(I, μ)
    N = T_S(μ) + Σ_{m<M} T_{m,A}(μ) / I_m            (latency numerator)
    D = c − κ · Σ_{m<M} 1{I_m>1} I_m² d_m(μ)         (bound denominator)

with c, κ from ``bound_constants`` and d_m(μ) the tier-m sum of G_l².
A schedule is *feasible* iff D > 0 (the bound can reach ε) and the memory
constraint C5 holds.

The latency terms T_S / T_{m,A} default to the nominal point estimates of
Eqs. (17)–(18); an optional ``latency_model`` (any object with
``split_T(cuts)`` / ``agg_T(cuts, m)`` — see ``repro.sim.robust``) swaps in
empirical per-round quantiles from a fleet-simulation trace, so the same
solvers optimize against heterogeneous / straggler / churn regimes.

An optional ``compression`` (``repro.compress.CompressionSpec``) prices a
lossy wire on both sides of the fraction: per-link byte ratios shrink the
latency numerator (Eqs. 12–16), ω shrinks the denominator headroom c
(Theorem 1's σ² → (1+ω)σ²).  When a trace-based ``latency_model`` is
attached it must price the same ratios itself (``robust_problem`` wires
this up); ω always enters through ``constants()`` here.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import (
    TYPE_CHECKING,
    Iterator,
    List,
    Optional,
    Protocol,
    Sequence,
    Tuple,
)

import numpy as np

from ..compress.base import CompressionSpec

if TYPE_CHECKING:  # pragma: no cover - type hints only (no import cycle)
    from ..energy import EnergySpec
    from ..faults import FaultSpec
    from ..privacy import PrivacySpec
from .convergence import (
    HyperSpec,
    ParticipationSpec,
    bound_constants,
    participation_rates,
    tier_G2_sums,
)
from .latency import (
    LayerProfile,
    SystemSpec,
    aggregation_latency,
    memory_ok,
    split_latency,
)

INFEASIBLE = float("inf")


class LatencyModel(Protocol):
    """Pluggable pricing of the latency terms (nominal or trace-based)."""

    def split_T(self, cuts: Sequence[int]) -> float: ...

    def agg_T(self, cuts: Sequence[int], m: int) -> float: ...


@dataclass(frozen=True)
class HsflProblem:
    profile: LayerProfile
    system: SystemSpec
    hyper: HyperSpec
    eps: float
    latency_model: Optional[LatencyModel] = None
    compression: Optional[CompressionSpec] = None
    participation: Optional[ParticipationSpec] = None
    privacy: Optional["PrivacySpec"] = None
    energy: Optional["EnergySpec"] = None
    faults: Optional["FaultSpec"] = None

    @property
    def M(self) -> int:
        return self.system.M

    @property
    def n_units(self) -> int:
        return self.profile.n_units

    @property
    def omega(self) -> float:
        """Compression-error second moment ω (0 for the f32 wire)."""
        return 0.0 if self.compression is None else self.compression.omega

    @property
    def q(self) -> np.ndarray:
        """Per-tier participation rates q_m ``[M]`` (all ones when full)."""
        return participation_rates(self.participation, self.M)

    def with_participation(
        self, participation: Optional[ParticipationSpec]
    ) -> "HsflProblem":
        """The same problem under straggler-aware partial participation
        (DESIGN.md §12): the Theorem-1 terms inflate by 1/q_m, and — when
        the spec carries a ``deadline`` and no trace ``latency_model`` is
        attached — the nominal T_S is capped at the deadline (a round
        never waits past the barrier).

        Like ``with_compression``, this refuses to change the regime under
        an attached ``latency_model``: a trace model's cached latencies
        price one participation policy, so swapping the spec alone would
        leave the latency and bound sides describing different deadlines.
        Compose both at once with ``repro.sim.participation_problem`` (or
        declare a ``participation`` section in an ``ExperimentSpec``).
        """
        if participation is not None:
            participation.validate_for(self.M)
        if self.latency_model is not None and participation != self.participation:
            raise ValueError(
                "cannot change participation under an attached latency_model "
                "(its latencies price the old policy); compose trace pricing "
                "and the participation spec together via "
                "repro.sim.participation_problem, or declare a participation "
                "section in an ExperimentSpec and let repro.api.build "
                "resolve the composition"
            )
        return dataclasses.replace(self, participation=participation)

    def with_compression(self, compression: Optional[CompressionSpec]) -> "HsflProblem":
        """The same problem priced over a compressed wire: byte ratios enter
        the latency terms (Eqs. 12–16), ω enters the bound denominator —
        the solvers then re-optimize (I, μ) under both, unchanged.

        Refuses to change the wire under an attached ``latency_model``: the
        model's cached quantiles price the *old* wire, so ω and the latency
        terms would describe two different codecs.  Attach compression
        first, then re-price (``robust_problem`` threads it to the trace) —
        or declare both in one ``ExperimentSpec`` and let ``repro.api.build``
        resolve the ordering automatically.
        """
        if compression is not None:
            compression.validate_for(self.M)
        if self.latency_model is not None and compression != self.compression:
            raise ValueError(
                "cannot change compression under an attached latency_model "
                "(its quantiles price the old wire); set compression on the "
                "base problem and re-attach via robust_problem, or declare "
                "compression + scenario in an ExperimentSpec and let "
                "repro.api.build resolve the composition order"
            )
        return dataclasses.replace(self, compression=compression)

    @property
    def retry_mult(self) -> Optional[float]:
        """Expected link attempts per traversal under the fault spec
        (DESIGN.md §16) — None when no faults / no link failures, keeping
        the zero-fault latency arithmetic untouched bit-for-bit."""
        return None if self.faults is None else self.faults.retry_mult

    def with_faults(self, faults: Optional["FaultSpec"]) -> "HsflProblem":
        """The same problem priced under a fault regime (DESIGN.md §16):
        link payloads inflate by the expected retry-attempt count in both
        the scalar chain and the batched lattice tables.  Fault-driven
        participation loss enters separately via ``with_participation``
        (``faults.deflate_participation``), keeping q-deflation and retry
        pricing independently composable.

        Refuses to change the regime under an attached ``latency_model``
        (same contract as ``with_compression``): a trace model's cached
        latencies price one fault regime; compose them together via
        ``repro.sim`` (``faults.faulty_trace`` before pricing) or an
        ``ExperimentSpec`` faults section.
        """
        if faults is not None:
            faults.validate_for(self.M, self.system.entities)
        if self.latency_model is not None and faults != self.faults:
            raise ValueError(
                "cannot change faults under an attached latency_model (its "
                "latencies price the old regime); wrap the trace with "
                "faults.faulty_trace before pricing, or declare a faults "
                "section in an ExperimentSpec and let repro.api.build "
                "resolve the composition"
            )
        return dataclasses.replace(self, faults=faults)

    def with_privacy(self, privacy: Optional["PrivacySpec"]) -> "HsflProblem":
        """The same problem under a DP-noised fed uplink (DESIGN.md §15):
        σ²_DP joins the bound's variance term through ``constants()`` and
        the (ε, δ) budget becomes the denominator floor ``d_min()``."""
        return dataclasses.replace(self, privacy=privacy)

    def with_energy(self, energy: Optional["EnergySpec"]) -> "HsflProblem":
        """The same problem under per-tier energy pricing (DESIGN.md §15):
        a ``budget_j_per_round`` masks schedules with E(I, μ) above it —
        energy never enters the Θ' arithmetic."""
        if energy is not None:
            energy.validate_for(self.M)
        return dataclasses.replace(self, energy=energy)

    # ------------------------------------------------------------------ #
    # objective pieces
    # ------------------------------------------------------------------ #
    @property
    def dp_sigma2(self) -> float:
        """Per-round DP uplink noise mass σ²_DP (0 for the noiseless wire)."""
        return 0.0 if self.privacy is None else self.privacy.dp_sigma2

    def constants(self) -> Tuple[float, float]:
        """(c, κ) of the bound denominator (ω-inflated under compression,
        1/q_1-inflated under partial participation, σ²_DP-shrunk under a
        DP-noised uplink).

        Memoized on the instance: every input is a frozen field, and the
        scalar solvers re-read (c, κ) at each coordinate step — which the
        adaptive controller turns into a per-round hot path."""
        cached = self.__dict__.get("_constants_cache")
        if cached is None:
            q1 = 1.0 if self.participation is None else self.q[0]
            cached = bound_constants(
                self.hyper, self.eps, omega=self.omega, q1=q1,
                dp_sigma2=self.dp_sigma2,
            )
            self.__dict__["_constants_cache"] = cached
        return cached

    def d_min(self) -> float:
        """Denominator floor from the privacy budget (DESIGN.md §15).

        Corollary 1 gives R(I, μ) = 2θ₀/(γ·D), so the accountant's round
        cap R ≤ R_max is exactly D ≥ 2θ₀/(γ·R_max) — one uniform
        threshold every feasibility site compares D against.  Without a
        budget this is 0.0, making ``D > d_min`` bit-identical to the
        unconstrained ``D > 0`` check; an unaffordable budget (R_max = 0)
        returns +inf, marking every schedule infeasible.
        """
        cached = self.__dict__.get("_d_min_cache")
        if cached is None:
            cached = 0.0
            if self.privacy is not None and self.privacy.epsilon_budget is not None:
                rmax = self.privacy.max_rounds(sampling_rate=float(self.q[0]))
                if rmax is not None:
                    if rmax <= 0:
                        cached = INFEASIBLE
                    else:
                        cached = 2.0 * self.hyper.theta0 / (
                            self.hyper.gamma * rmax
                        )
            self.__dict__["_d_min_cache"] = cached
        return cached

    def tier_d(self, cuts: Sequence[int]) -> np.ndarray:
        """d_m(μ) = Σ_{l ∈ tier m} G_l² for all tiers — inflated to d_m/q_m
        under partial participation (DESIGN.md §12; the batched lattice
        core applies the identical per-tier division, so scalar and
        batched denominators stay bit-equal).

        Memoized per cut vector (depends only on frozen fields); treat the
        returned array as read-only."""
        cache = self.__dict__.setdefault("_tier_d_cache", {})
        key = tuple(int(c) for c in cuts)
        d = cache.get(key)
        if d is None:
            d = tier_G2_sums(self.hyper.G2, cuts)
            if self.participation is not None:
                d = d / self.q
            cache[key] = d
        return d

    def split_T(self, cuts: Sequence[int]) -> float:
        if self.latency_model is not None:
            return self.latency_model.split_T(cuts)
        t = split_latency(
            self.profile, self.system, cuts, self.compression,
            self.retry_mult,
        )
        if self.participation is not None and self.participation.deadline is not None:
            # nominal view of the deadline barrier: the server never waits
            # past it (trace-based expectation pricing lives in
            # repro.sim.participation.DeadlineLatency)
            t = min(t, self.participation.deadline)
        return t

    def agg_T(self, cuts: Sequence[int]) -> np.ndarray:
        """b_m = T_{m,A} for tiers m < M."""
        if self.latency_model is not None:
            return np.array(
                [self.latency_model.agg_T(cuts, m) for m in range(self.M - 1)]
            )
        return np.array(
            [
                aggregation_latency(
                    self.profile, self.system, cuts, m, self.compression,
                    self.retry_mult,
                )
                for m in range(self.M - 1)
            ]
        )

    def total_T(
        self, intervals: Sequence[int], cuts: Sequence[int], R: float
    ) -> float:
        """T(I, μ) of Eq. (19) under this problem's latency pricing."""
        tot = R * self.split_T(cuts)
        b = self.agg_T(cuts)
        for m in range(self.M - 1):
            tot += np.floor(R / intervals[m]) * b[m]
        return float(tot)

    def numerator(self, intervals: Sequence[int], cuts: Sequence[int]) -> float:
        b = self.agg_T(cuts)
        return self.split_T(cuts) + float(
            np.sum(b / np.asarray(intervals[: self.M - 1], dtype=float))
        )

    def denominator(self, intervals: Sequence[int], cuts: Sequence[int]) -> float:
        c, kappa = self.constants()
        d = self.tier_d(cuts)
        s = sum(
            (I**2) * dm
            for I, dm in zip(intervals[: self.M - 1], d[: self.M - 1])
            if I > 1
        )
        return c - kappa * s

    def theta(self, intervals: Sequence[int], cuts: Sequence[int]) -> float:
        """Exact Θ'(I, μ); +inf when infeasible (D ≤ d_min, C5 violated,
        or the round energy exceeds the budget)."""
        if not self.memory_feasible(cuts):
            return INFEASIBLE
        D = self.denominator(intervals, cuts)
        if D <= self.d_min():
            return INFEASIBLE
        if not self.energy_feasible(intervals, cuts):
            return INFEASIBLE
        return (
            2.0
            * self.hyper.theta0
            / self.hyper.gamma
            * self.numerator(intervals, cuts)
            / D
        )

    def rounds(self, intervals: Sequence[int], cuts: Sequence[int]) -> Optional[float]:
        """R(I, μ) of Corollary 1 (None if unreachable, or if reaching ε
        would overrun the privacy budget's round cap)."""
        D = self.denominator(intervals, cuts)
        if D <= self.d_min():
            return None
        return 2.0 * self.hyper.theta0 / (self.hyper.gamma * D)

    # ------------------------------------------------------------------ #
    # energy pricing (DESIGN.md §15)
    # ------------------------------------------------------------------ #
    def round_energy(
        self, intervals: Sequence[int], cuts: Sequence[int]
    ) -> Optional[float]:
        """E(I, μ) in joules under the attached ``EnergySpec`` (None when
        no spec is attached) — the scalar canonical-chain oracle."""
        if self.energy is None:
            return None
        from ..energy import round_energy

        return round_energy(
            self.profile, self.system, self.energy, cuts, intervals,
            self.compression,
        )

    def energy_feasible(
        self, intervals: Sequence[int], cuts: Sequence[int]
    ) -> bool:
        """E(I, μ) ≤ budget; vacuously True without a spec or budget, so
        the unconstrained path never prices energy at all."""
        if self.energy is None or self.energy.budget_j_per_round is None:
            return True
        e = self.round_energy(intervals, cuts)
        return e <= self.energy.budget_j_per_round

    # ------------------------------------------------------------------ #
    # constraints
    # ------------------------------------------------------------------ #
    def memory_feasible(self, cuts: Sequence[int]) -> bool:
        """C5, memoized per cut vector — a pure function of the frozen
        profile/system, re-asked for the same few cuts thousands of times
        by the scalar walk and the controller's warm re-solves."""
        cache = self.__dict__.setdefault("_memory_cache", {})
        key = tuple(int(c) for c in cuts)
        ok = cache.get(key)
        if ok is None:
            ok = cache[key] = memory_ok(self.profile, self.system, cuts)
        return ok

    def valid_cuts(self, cuts: Sequence[int]) -> bool:
        """C2–C4: M−1 non-decreasing boundaries within [0, U]."""
        if len(cuts) != self.M - 1:
            return False
        prev = 0
        for cval in cuts:
            if cval < prev or cval > self.n_units:
                return False
            prev = cval
        return True

    def cut_lattice(self, min_tier_units: int = 1) -> np.ndarray:
        """The C2–C4-valid cut lattice as one memoized ``[K, M-1]`` int
        array (row order == ``iter_cut_vectors``), shared by every solver
        — the scalar Dinkelbach walk, ``solve_ms_bruteforce``, and the
        batched core all read this one materialization instead of
        re-generating and re-filtering it per call.

        The cache lives on the instance: ``with_compression`` (and any
        ``dataclasses.replace``) returns a NEW problem, so derived
        problems re-materialize against their own wire/caches.
        """
        cache = self.__dict__.setdefault("_lattice_cache", {})
        lat = cache.get(min_tier_units)
        if lat is None:
            from .batched import cut_lattice

            lat = cache[min_tier_units] = cut_lattice(
                self.n_units, self.M, min_tier_units
            )
        return lat

    def evaluator(self, backend: str = "auto"):
        """The memoized whole-lattice ``BatchedEvaluator`` (DESIGN.md §11).

        Built once per (problem instance, resolved backend): BCD's
        repeated MS solves share one latency-table build.  Results are
        bit-identical across backends and to the scalar walk.

        The memo assumes a frozen problem — which holds for the static
        latency models (``TraceLatency``/``DeadlineLatency`` never mutate
        after construction).  A *mutable* model (the controller's
        ``WindowedLatency``, whose tables change every observed round)
        must advertise a monotone ``version`` attribute: the memo stores
        the version the tables were built against and rebuilds when it
        has moved, so a mid-run control step never reads stale split/agg
        tables.  Models without ``version`` keep the frozen fast path.
        """
        from .batched import BatchedEvaluator, resolve_backend

        be = resolve_backend(
            backend,
            work_elems=self.cut_lattice().shape[0] * self.system.num_clients,
        )
        token = getattr(self.latency_model, "version", None)
        cache = self.__dict__.setdefault("_evaluator_cache", {})
        hit = cache.get(be)
        if hit is not None and hit[1] == token:
            return hit[0]
        ev = BatchedEvaluator(self, backend=be)
        cache[be] = (ev, token)
        return ev

    # ------------------------------------------------------------------ #
    # per-class cut assignment (DESIGN.md §14)
    # ------------------------------------------------------------------ #
    def class_theta(self, spec, intervals: Sequence[int]) -> float:
        """Exact Θ'(I, {μ_c}) for a ``classes.CutClassSpec`` — delegates to
        the per-class oracle (``core.classes``), which mirrors this
        problem's single-cut arithmetic term for term."""
        from .classes import class_theta

        return class_theta(self, spec, intervals)

    def class_split_T(self, spec) -> float:
        from .classes import class_split_T

        return class_split_T(self, spec)

    def class_agg_T(self, spec) -> np.ndarray:
        from .classes import class_agg_T

        return class_agg_T(self, spec)

    def class_tier_d(self, spec) -> np.ndarray:
        from .classes import class_tier_d

        return class_tier_d(self, spec)

    def invalidate_caches(self) -> None:
        """Explicitly drop the memoized lattice and evaluator tables.

        For callers that replace or mutate the attached system/latency
        model in place and cannot (or do not want to) rely on the
        ``version`` protocol above — after this, the next ``evaluator()``
        or ``cut_lattice()`` call rebuilds from the live model.
        """
        self.__dict__.pop("_evaluator_cache", None)
        self.__dict__.pop("_lattice_cache", None)
        self.__dict__.pop("_constants_cache", None)
        self.__dict__.pop("_tier_d_cache", None)
        self.__dict__.pop("_memory_cache", None)
        self.__dict__.pop("_d_min_cache", None)

    def iter_cut_vectors(
        self, min_tier_units: int = 1
    ) -> Iterator[Tuple[int, ...]]:
        """All C2–C4-valid cut vectors with every tier holding at least
        ``min_tier_units`` units (the paper requires each tier non-empty so
        the split actually spans the hierarchy).  Yields rows of the
        memoized ``cut_lattice`` in order."""
        for row in self.cut_lattice(min_tier_units):
            yield tuple(int(x) for x in row)
