"""qwen3-32b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-8B]."""
import dataclasses
from ..models.spec import ModelSpec

SPEC = ModelSpec(
    name="qwen3-32b", family="dense", num_layers=64, d_model=5120,
    num_heads=64, num_kv_heads=8, d_ff=25600, vocab_size=151936,
    qk_norm=True, head_dim=128, rope_theta=1e6,
    source="hf:Qwen/Qwen3-8B",
)

REDUCED = dataclasses.replace(
    SPEC, num_layers=2, d_model=256, num_heads=8, num_kv_heads=2,
    d_ff=512, vocab_size=512, head_dim=32,
)
