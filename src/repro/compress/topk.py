"""Top-k magnitude sparsification, plus the error-feedback accumulator.

``TopK(frac)`` keeps the ``k = ceil(frac · d)`` largest-magnitude entries
of a flattened tensor and zeroes the rest.  The wire carries a (f32 value,
int32 index) pair per kept entry, so ``ratio = 2·frac``.  The kept entries
are the largest squares, hence kept energy ≥ (k/d)·‖x‖² and

    ω  =  sup_x ‖C(x) − x‖² / ‖x‖²  ≤  1 − frac.

Plain top-k is biased (it always drops the same small coordinates of a
slowly-moving tensor); ``ErrorFeedback`` wraps any codec with the standard
residual accumulator — compress ``x + e_t``, carry the round-off
``e_{t+1} = x + e_t − C(x + e_t)`` — which restores convergence in
practice and keeps the cumulative emitted signal within one residual of
the cumulative input (asserted in ``tests/test_compress.py``).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .base import Compressor


@dataclass(frozen=True)
class TopK:
    """Keep the ``ceil(frac·d)`` largest-|x| entries of each tensor."""

    frac: float = 0.25
    name: str = "top-k"

    def __post_init__(self):
        if not 0.0 < self.frac <= 1.0:
            raise ValueError(f"frac must be in (0, 1]: {self.frac}")

    @property
    def ratio(self) -> float:
        return min(1.0, 2.0 * self.frac)  # value + index per kept entry

    @property
    def omega(self) -> float:
        return 1.0 - self.frac

    def k_for(self, size: int) -> int:
        return max(1, int(math.ceil(self.frac * size)))

    def transform(self, x: jax.Array, key: Optional[jax.Array] = None) -> jax.Array:
        flat = x.reshape(-1)
        k = self.k_for(flat.shape[0])
        vals, idx = jax.lax.top_k(jnp.abs(flat.astype(jnp.float32)), k)
        del vals
        out = jnp.zeros_like(flat).at[idx].set(flat[idx])
        return out.reshape(x.shape)


@dataclass(frozen=True)
class ErrorFeedback:
    """Residual accumulator around any codec (functional state).

    Note: EF deliberately has *no* ``omega`` — it is not a stateless
    ``Compressor`` and its per-round emitted error relative to the current
    input is NOT bounded by the inner codec's ω (the steady-state residual
    of a slowly-varying signal can be many multiples of ‖x‖, so one
    round's ‖x̂ − x‖ can exceed any per-round bound).  The *byte* ratio of
    the wire is still the inner codec's; Theorem-1 pricing of EF schedules
    is out of scope for the one-shot ω contract of DESIGN.md §9.
    """

    inner: Compressor

    @property
    def name(self) -> str:
        return f"ef({self.inner.name})"

    @property
    def ratio(self) -> float:
        return self.inner.ratio

    def init(self, x: jax.Array) -> jax.Array:
        return jnp.zeros_like(x, dtype=jnp.float32)

    def step(
        self, residual: jax.Array, x: jax.Array, key: Optional[jax.Array] = None
    ) -> Tuple[jax.Array, jax.Array]:
        """(emitted x̂, new residual) for one round."""
        y = x.astype(jnp.float32) + residual
        xh = self.inner.transform(y, key=key)
        return xh.astype(x.dtype), y - xh.astype(jnp.float32)
