"""Pure-jnp oracle for causal sliding-window attention (GQA).

q [B, S, H, hd]; k, v [B, S, K, hd] with H = G·K. A query at position p
attends keys in (p − window, p] (causal, window inclusive of self).
window=0 means full causal attention.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def swa_attention_ref(q, k, v, window: int = 0):
    B, S, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    qf = q.astype(jnp.float32) / math.sqrt(hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qg = qf.reshape(B, S, K, G, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, kf)
    pos = jnp.arange(S)
    ok = pos[None, :] <= pos[:, None]
    if window > 0:
        ok = ok & (pos[None, :] > pos[:, None] - window)
    scores = jnp.where(ok[None, None, None], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, vf)
    return out.reshape(B, S, H, hd).astype(q.dtype)
