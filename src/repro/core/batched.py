"""Batched evaluation core: the whole MS/MA/BCD cut lattice at once.

The scalar objective walk in ``core.problem`` prices one cut vector at a
time — ``split_T`` re-runs the canonical stage chain of
``latency.split_stages`` per candidate, so a Dinkelbach iteration over
the U=64/M=3 lattice is ~2,016 Python chain walks and U=128/M=4 explodes
to ~3·10⁵.  This module prices the *entire* C2–C5 lattice as array
arithmetic, the same way ``sim/fleet.py`` vectorized the discrete-event
oracle:

* the feasible lattice is one ``[K, M-1]`` int array
  (:func:`cut_lattice`, exact row order of
  ``HsflProblem.iter_cut_vectors``);
* every tier quantity is a gather into the leading-zero prefix-sum
  tables the scalar path reads (``LayerProfile.prefix``, the G² cumsum
  of ``convergence.tier_G2_sums``) — identical subtraction, identical
  bits;
* the canonical stage chain becomes a ``[K, S]`` work tensor
  (:func:`split_work_tensor`) accumulated against per-stage ``[N]``
  rates *in chain order*, so per-candidate ``split_T``/``agg_T`` and
  therefore N(I, μ), D(I, μ), Θ'(I, μ) match the scalar oracle
  bit-for-bit — the ``events.py``/``fleet.py`` contract, ported to the
  solvers (enforced in ``tests/test_batched.py``).

Backends: ``numpy`` is the reference implementation; ``jax`` runs the
same chain jitted under ``enable_x64`` (float64 elementwise IEEE ops
match NumPy exactly); ``auto`` picks jax only when the lattice is big
enough to amortize the per-shape jit compile.  The scalar walk stays
available as ``backend="scalar"`` in the solvers and is the test oracle.
See DESIGN.md §11.
"""
from __future__ import annotations

import itertools
from typing import TYPE_CHECKING, List, Optional, Sequence, Tuple

import numpy as np

from ..compress.base import CompressionSpec, act_ratio, model_ratio
from .latency import BITS, LayerProfile, SystemSpec

if TYPE_CHECKING:  # pragma: no cover - type hints only
    from .problem import HsflProblem

try:  # CPU jax is in the image; keep the solver core importable without it
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    _HAS_JAX = True
except Exception:  # pragma: no cover - exercised only on jax-less installs
    _HAS_JAX = False

BACKENDS = ("numpy", "jax")

# auto picks jax only when the [K, N] chain is big enough to amortize the
# per-shape jit compile (~hundreds of ms); below this numpy wins outright.
AUTO_JAX_MIN_ELEMS = 1_000_000


def resolve_backend(backend: str, work_elems: Optional[int] = None) -> str:
    """Map ``auto`` to a concrete backend (``scalar`` is handled upstream
    by the solvers, before the batched core is involved)."""
    if backend == "auto":
        if not _HAS_JAX:
            return "numpy"
        if work_elems is not None and work_elems < AUTO_JAX_MIN_ELEMS:
            return "numpy"
        return "jax"
    if backend == "jax" and not _HAS_JAX:
        raise RuntimeError("jax backend requested but jax is not importable")
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown batched backend {backend!r}; use numpy|jax|auto "
            '(backend="scalar" is the solvers\' non-batched oracle walk and '
            "never reaches the batched core)"
        )
    return backend


# --------------------------------------------------------------------------- #
# lattice materialization (C2–C4)
# --------------------------------------------------------------------------- #


def cut_lattice(n_units: int, M: int, min_tier_units: int = 1) -> np.ndarray:
    """All C2–C4-valid cut vectors as one ``[K, M-1]`` int64 array.

    Row order is exactly ``HsflProblem.iter_cut_vectors`` (lexicographic
    ``itertools.combinations``), so scalar loops and batched argmins
    break ties identically.
    """
    t = min_tier_units
    rng = range(t, n_units - t * (M - 1) + 1)
    rows = [
        c
        for c in itertools.combinations(rng, M - 1)
        if all(c[i + 1] - c[i] >= t for i in range(len(c) - 1))
    ]
    if not rows:
        return np.zeros((0, M - 1), dtype=np.int64)
    return np.asarray(rows, dtype=np.int64)


def lattice_bounds(lattice: np.ndarray, n_units: int) -> np.ndarray:
    """``[K, M+1]`` tier boundaries: 0 | cuts | U for every row."""
    K = lattice.shape[0]
    return np.concatenate(
        [
            np.zeros((K, 1), dtype=np.int64),
            lattice,
            np.full((K, 1), n_units, dtype=np.int64),
        ],
        axis=1,
    )


def stage_meta(M: int) -> Tuple[Tuple[str, int], ...]:
    """(kind, index) of every leg of the canonical chain — cut-independent,
    mirroring ``latency.split_stages`` (fwd up the hierarchy, bwd back)."""
    meta: List[Tuple[str, int]] = []
    for m in range(M):
        meta.append(("compute_fwd", m))
        if m < M - 1:
            meta.append(("uplink", m))
    for m in range(M - 1, -1, -1):
        meta.append(("compute_bwd", m))
        if m > 0:
            meta.append(("downlink", m - 1))
    return tuple(meta)


# --------------------------------------------------------------------------- #
# per-candidate work tensors (Eqs. 11–16 gathered from the prefix tables)
# --------------------------------------------------------------------------- #


def boundary_bits_lattice(
    profile: LayerProfile,
    lattice: np.ndarray,
    m: int,
    compression: Optional[CompressionSpec] = None,
    retry_mult: Optional[float] = None,
) -> np.ndarray:
    """``[K]`` boundary-m activation/gradient bits (Eq. 12/14), matching
    ``split_stages``'s ``boundary_bits`` multiply order — including the
    trailing retry-attempt factor (DESIGN.md §16), applied last so scalar
    and batched stay bit-equal."""
    cut = lattice[:, m]
    act = np.where(cut > 0, profile.act_bytes[np.maximum(cut - 1, 0)], 0.0)
    bits = profile.batch * act * BITS * act_ratio(compression, m)
    return bits if retry_mult is None else bits * retry_mult


def split_work_tensor(
    profile: LayerProfile,
    lattice: np.ndarray,
    compression: Optional[CompressionSpec] = None,
    retry_mult: Optional[float] = None,
) -> np.ndarray:
    """``[K, S]`` stage works in canonical chain order for every row —
    the batched counterpart of ``latency.split_stages`` work values."""
    M = lattice.shape[1] + 1
    bnds = lattice_bounds(lattice, profile.n_units)
    px = profile.prefix
    fwd = px.flops_fwd[bnds[:, 1:]] - px.flops_fwd[bnds[:, :-1]]  # [K, M]
    bwd = px.flops_bwd[bnds[:, 1:]] - px.flops_bwd[bnds[:, :-1]]
    cols: List[np.ndarray] = []
    for kind, idx in stage_meta(M):
        if kind == "compute_fwd":
            cols.append(fwd[:, idx])
        elif kind == "compute_bwd":
            cols.append(bwd[:, idx])
        else:  # uplink / downlink share the boundary payload
            cols.append(
                boundary_bits_lattice(
                    profile, lattice, idx, compression, retry_mult
                )
            )
    return np.stack(cols, axis=1)


def model_bits_lattice(
    profile: LayerProfile,
    lattice: np.ndarray,
    compression: Optional[CompressionSpec] = None,
    retry_mult: Optional[float] = None,
) -> np.ndarray:
    """``[K, M-1]`` fed-server model bits λ_m (Eq. 15/16 payload), matching
    ``aggregation_phases``'s ``tier_param_bytes · 8 · ratio`` order with
    the retry factor applied last (DESIGN.md §16)."""
    M = lattice.shape[1] + 1
    bnds = lattice_bounds(lattice, profile.n_units)
    cs = profile.prefix.param_bytes
    out = np.empty((lattice.shape[0], M - 1))
    for m in range(M - 1):
        lam = cs[bnds[:, m + 1]] - cs[bnds[:, m]]
        if m == 0:
            lam = lam + profile.frontend_param_bytes
        lam = lam * BITS * model_ratio(compression, m)
        if retry_mult is not None:
            lam = lam * retry_mult
        out[:, m] = lam
    return out


def tier_d_lattice(G2: np.ndarray, lattice: np.ndarray) -> np.ndarray:
    """``[K, M]`` per-tier Σ G_l² — same cumsum-diff as ``tier_G2_sums``."""
    cs = np.concatenate(([0.0], np.cumsum(np.asarray(G2, dtype=np.float64))))
    bnds = lattice_bounds(lattice, len(G2))
    return cs[bnds[:, 1:]] - cs[bnds[:, :-1]]


def memory_mask(
    profile: LayerProfile, system: SystemSpec, lattice: np.ndarray
) -> np.ndarray:
    """``[K]`` bool — constraint C5 for every row, same expression shape as
    the scalar ``latency.memory_ok``."""
    N = system.num_clients
    bnds = lattice_bounds(lattice, profile.n_units)
    px = profile.prefix
    ok = np.ones(lattice.shape[0], dtype=bool)
    for m in range(system.M):
        lo, hi = bnds[:, m], bnds[:, m + 1]
        hosted = N // system.entities[m]
        per_model = (
            (px.act_bytes[hi] - px.act_bytes[lo])
            + (px.grad_act_bytes[hi] - px.grad_act_bytes[lo])
        ) * profile.batch + (
            (px.param_bytes[hi] - px.param_bytes[lo])
            + (px.opt_bytes[hi] - px.opt_bytes[lo])
        )
        if m == 0:
            per_model = per_model + profile.frontend_param_bytes
        if m == system.M - 1:
            per_model = per_model + profile.head_param_bytes
        ok &= hosted * per_model < float(np.min(system.memory[m]))
    return ok


# --------------------------------------------------------------------------- #
# nominal latency tables (Eqs. 17/18 for every row)
# --------------------------------------------------------------------------- #


def nominal_stage_rates(system: SystemSpec, M: int) -> List[np.ndarray]:
    """Per-stage nominal ``[N]`` service rates, chain order (``stage_rate``)."""
    rates: List[np.ndarray] = []
    for kind, idx in stage_meta(M):
        if kind in ("compute_fwd", "compute_bwd"):
            rates.append(system.compute[idx])
        elif kind == "uplink":
            rates.append(system.act_up[idx])
        else:
            rates.append(system.act_down[idx])
    return rates


def accumulate_chain(
    works: np.ndarray, rates: Sequence[np.ndarray], backend: str = "numpy"
) -> np.ndarray:
    """``[K]`` max-over-clients of the chain sum Σ_s work/rate, accumulated
    in stage order (the bit-exactness-critical reduction)."""
    if backend == "jax":
        with enable_x64():
            return np.asarray(
                _chain_jit(jnp.asarray(works), jnp.asarray(np.stack(rates, axis=0)))
            )
    t = np.zeros((works.shape[0], rates[0].shape[0]))
    for s, r in enumerate(rates):
        t = t + works[:, s][:, None] / r[None, :]
    return t.max(axis=1)


if _HAS_JAX:

    @jax.jit
    def _chain_jit(works, rates):  # works [K, S], rates [S, N]
        t = jnp.zeros((works.shape[0], rates.shape[1]), dtype=works.dtype)
        for s in range(rates.shape[0]):
            t = t + works[:, s][:, None] / rates[s][None, :]
        return jnp.max(t, axis=1)

    @jax.jit
    def _agg_jit(lam, up, down):  # lam [K], up/down [J]
        return jnp.max(lam[:, None] / up[None, :], axis=1) + jnp.max(
            lam[:, None] / down[None, :], axis=1
        )


def nominal_split_table(
    profile: LayerProfile,
    system: SystemSpec,
    lattice: np.ndarray,
    compression: Optional[CompressionSpec] = None,
    backend: str = "numpy",
    retry_mult: Optional[float] = None,
) -> np.ndarray:
    """``[K]`` T_S(μ) for every lattice row (Eq. 17)."""
    works = split_work_tensor(profile, lattice, compression, retry_mult)
    rates = nominal_stage_rates(system, lattice.shape[1] + 1)
    return accumulate_chain(works, rates, backend)


def nominal_agg_table(
    profile: LayerProfile,
    system: SystemSpec,
    lattice: np.ndarray,
    compression: Optional[CompressionSpec] = None,
    backend: str = "numpy",
    retry_mult: Optional[float] = None,
) -> np.ndarray:
    """``[K, M-1]`` T_{m,A}(μ) for every lattice row (Eq. 18)."""
    M = lattice.shape[1] + 1
    lam = model_bits_lattice(profile, lattice, compression, retry_mult)
    agg = np.zeros((lattice.shape[0], M - 1))
    for m in range(M - 1):
        if system.entities[m] <= 1:
            continue  # Eq. (15)/(16) indicator
        up, down = system.model_up[m], system.model_down[m]
        if backend == "jax":
            with enable_x64():
                agg[:, m] = np.asarray(
                    _agg_jit(
                        jnp.asarray(lam[:, m]), jnp.asarray(up), jnp.asarray(down)
                    )
                )
        else:
            agg[:, m] = (lam[:, m][:, None] / up[None, :]).max(axis=1) + (
                lam[:, m][:, None] / down[None, :]
            ).max(axis=1)
    return agg


# --------------------------------------------------------------------------- #
# the evaluator
# --------------------------------------------------------------------------- #


class BatchedEvaluator:
    """Whole-lattice Θ'/N/D evaluation for one ``HsflProblem``.

    Latency tables (``split`` [K], ``agg`` [K, M-1]) and the convergence
    gathers (``d`` [K, M-1], ``mem_ok`` [K]) are computed ONCE per
    problem; evaluating the objective for any interval vector is then
    O(K·M) elementwise arithmetic — one Dinkelbach step is a single
    argmin over a [K] array.  Obtain via ``problem.evaluator(backend)``
    (memoized per problem instance, so BCD's repeated MS solves share
    one table build; ``with_compression`` returns a new problem and
    therefore re-prices).

    Latency pricing mirrors ``HsflProblem``: nominal Eq. 17/18 tables
    when no ``latency_model`` is attached; a model exposing
    ``split_T_batch``/``agg_T_batch`` (``sim.robust.TraceLatency``)
    prices the lattice through the trace; any other ``LatencyModel``
    falls back to per-row protocol calls (correct, not fast).
    """

    def __init__(self, problem: "HsflProblem", backend: str = "auto"):
        self.problem = problem
        lattice = problem.cut_lattice()
        M = problem.M
        self.backend = resolve_backend(
            backend, work_elems=lattice.shape[0] * problem.system.num_clients
        )
        self.lattice = lattice
        self.mem_ok = memory_mask(problem.profile, problem.system, lattice)
        lm = problem.latency_model
        pp = problem.participation
        rm = problem.retry_mult
        if lm is None:
            self.split = nominal_split_table(
                problem.profile, problem.system, lattice,
                problem.compression, self.backend, rm,
            )
            if pp is not None and pp.deadline is not None:
                # nominal deadline barrier — same min as the scalar split_T
                self.split = np.minimum(self.split, pp.deadline)
            self.agg = nominal_agg_table(
                problem.profile, problem.system, lattice,
                problem.compression, self.backend, rm,
            )
        elif hasattr(lm, "split_T_batch") and hasattr(lm, "agg_T_batch"):
            self.split = np.asarray(lm.split_T_batch(lattice), dtype=np.float64)
            self.agg = np.asarray(lm.agg_T_batch(lattice), dtype=np.float64)
        else:  # generic LatencyModel: scalar protocol per row
            rows = [tuple(int(x) for x in r) for r in lattice]
            self.split = np.array([lm.split_T(r) for r in rows])
            self.agg = np.array(
                [[lm.agg_T(r, m) for m in range(M - 1)] for r in rows]
            )
        self.d = tier_d_lattice(problem.hyper.G2, lattice)[:, : M - 1]
        if pp is not None:
            # per-tier 1/q_m drift inflation — the same elementwise divide
            # the scalar problem.tier_d applies, so D stays bit-equal
            self.d = self.d / problem.q[: M - 1][None, :]
        self.c, self.kappa = problem.constants()
        self.scale = 2.0 * problem.hyper.theta0 / problem.hyper.gamma
        # privacy budget as a denominator floor (0.0 unconstrained, so the
        # feasibility compare below is bit-identical to D > 0) and energy
        # prices over the lattice (DESIGN.md §15; masks only, never Θ')
        self.d_min = problem.d_min()
        en = problem.energy
        self.energy_budget = None if en is None else en.budget_j_per_round
        if en is not None:
            from ..energy import agg_energy_lattice, split_energy_lattice

            self.e_split = split_energy_lattice(
                problem.profile, problem.system, en, lattice,
                problem.compression,
            )
            self.e_agg = agg_energy_lattice(
                problem.profile, problem.system, en, lattice,
                problem.compression,
            )
        else:
            self.e_split = None
            self.e_agg = None

    @property
    def K(self) -> int:
        return self.lattice.shape[0]

    def cuts_at(self, i: int) -> Tuple[int, ...]:
        return tuple(int(x) for x in self.lattice[i])

    def numerator(self, intervals: Sequence[int]) -> np.ndarray:
        """[K] N(I, μ) — ``split + Σ_m agg_m / I_m`` in tier order (the
        ``add.reduce`` order of the scalar ``problem.numerator``)."""
        M = self.problem.M
        acc = self.agg[:, 0] / float(intervals[0])
        for m in range(1, M - 1):
            acc = acc + self.agg[:, m] / float(intervals[m])
        return self.split + acc

    def denominator(self, intervals: Sequence[int]) -> np.ndarray:
        """[K] D(I, μ) = c − κ·Σ_{I_m>1} I_m² d_m (Eq. 22/24)."""
        s = np.zeros(self.K)
        for m in range(self.problem.M - 1):
            I = int(intervals[m])
            if I > 1:
                s = s + (I**2) * self.d[:, m]
        return self.c - self.kappa * s

    def round_energy(self, intervals: Sequence[int]) -> Optional[np.ndarray]:
        """[K] E(I, μ) — ``e_split + Σ_m e_agg_m / I_m`` in tier order (the
        accumulation shape of ``numerator``); None without an EnergySpec."""
        if self.e_split is None:
            return None
        M = self.problem.M
        acc = self.e_agg[:, 0] / float(intervals[0])
        for m in range(1, M - 1):
            acc = acc + self.e_agg[:, m] / float(intervals[m])
        return self.e_split + acc

    def theta(self, intervals: Sequence[int]) -> np.ndarray:
        """[K] exact Θ'(I, μ); +inf where C5 fails, D ≤ d_min, or the
        round energy overruns the budget."""
        from .problem import INFEASIBLE

        D = self.denominator(intervals)
        N_ = self.numerator(intervals)
        th = np.full(self.K, INFEASIBLE)
        ok = self.mem_ok & (D > self.d_min)
        if self.energy_budget is not None:
            ok = ok & (self.round_energy(intervals) <= self.energy_budget)
        th[ok] = self.scale * N_[ok] / D[ok]
        return th
