"""Bound-constant estimator (beta, sigma^2, G^2, theta0) on a probe run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.configs.shapes import concrete_inputs
from repro.core import build_train_step_a, init_state_a
from repro.core.estimator import HyperEstimator, _unit_sq_norms
from repro.core.tiers import default_plan
from repro.models.model import SplittableModel
from repro.optim import sgd


def test_unit_sq_norms_partition():
    """Per-unit squared norms sum to the global squared norm."""
    key = jax.random.PRNGKey(0)
    N, U = 4, 6
    tree = {
        "frontend": {"e": jax.random.normal(key, (N, 5))},
        "units": {"w": jax.random.normal(jax.random.fold_in(key, 1), (N, U, 3, 3))},
        "head": {"h": jax.random.normal(jax.random.fold_in(key, 2), (N, 2))},
    }
    sq = _unit_sq_norms(tree, U)
    assert sq.shape == (N, U)
    total = sum(float(jnp.sum(x**2)) for x in jax.tree.leaves(tree))
    np.testing.assert_allclose(float(jnp.sum(sq)), total, rtol=1e-5)


@pytest.mark.slow
def test_estimator_on_probe_run():
    spec = get_reduced("smollm-135m")
    model = SplittableModel(spec)
    N = 4
    plan = default_plan(spec.n_units, N, entities=(N, 2, 1))
    opt = sgd(1e-2)
    state = init_state_a(model, plan, opt, jax.random.PRNGKey(0))
    step = jax.jit(build_train_step_a(model, plan, opt))
    grad_fn = jax.jit(lambda p, b: jax.vmap(jax.value_and_grad(model.loss_fn))(p, b))
    est = HyperEstimator(plan.n_units, N, gamma=1e-2)
    for t in range(4):
        batch = concrete_inputs(spec, N * 2, 16, jax.random.PRNGKey(t))
        batch = {k: v.reshape(N, 2, *v.shape[1:]) for k, v in batch.items()}
        losses, grads = grad_fn(state.params, batch)
        est.observe(state.params, grads, float(jnp.mean(losses)))
        state, _ = step(state, batch)
    hp = est.hyperspec()
    assert hp.G2.shape == (plan.n_units,)
    assert np.all(hp.G2 > 0)
    assert np.all(hp.sigma2 >= 0)
    # non-IID client batches: variance is strictly positive somewhere
    assert hp.sigma2.sum() > 0
    assert hp.beta > 0 and np.isfinite(hp.beta)
    assert hp.theta0 > 0
    # variance can never exceed the second moment (Assumption 2 structure)
    assert np.all(hp.sigma2 <= hp.G2 + 1e-9)


def test_estimator_requires_observations():
    est = HyperEstimator(4, 2, 1e-3)
    with pytest.raises(ValueError):
        est.hyperspec()


# --------------------------------------------------------------------------- #
# sliding-window mode (the adaptive controller's online estimator)
# --------------------------------------------------------------------------- #


def _round_trees(key, N=4, U=5, d=3):
    """One round's (params, grads) client-stacked trees."""
    kp, kg = jax.random.split(key)
    mk = lambda k: {
        "frontend": {"e": jax.random.normal(jax.random.fold_in(k, 0), (N, d))},
        "units": {"w": jax.random.normal(jax.random.fold_in(k, 1), (N, U, d))},
        "head": {"h": jax.random.normal(jax.random.fold_in(k, 2), (N, d))},
    }
    return mk(kp), mk(kg)


def _feed(est, rounds, seed=0, loss_of=lambda t: 2.0 - 0.1 * t):
    for t in rounds:
        params, grads = _round_trees(jax.random.fold_in(jax.random.PRNGKey(seed), t))
        est.observe(params, grads, loss_of(t))


def test_window_wraps_to_exactly_last_w():
    """A windowed estimator fed T > W rounds reports the same G2/sigma2/
    theta0 as a fresh windowed estimator fed only the last W rounds —
    stale rounds age out of the moment statistics bit-exactly.  beta is
    the one one-sided quantity: the full stream also saw the ratio at the
    window's left edge (against the pre-window round), so it can only be
    >= the fresh estimate."""
    T, W = 10, 4
    full = HyperEstimator(5, 4, 1e-2, window=W)
    _feed(full, range(T))
    fresh = HyperEstimator(5, 4, 1e-2, window=W)
    _feed(fresh, range(T - W, T))
    hp_full, hp_fresh = full.hyperspec(), fresh.hyperspec()
    np.testing.assert_array_equal(hp_full.G2, hp_fresh.G2)
    np.testing.assert_array_equal(hp_full.sigma2, hp_fresh.sigma2)
    assert hp_full.theta0 == hp_fresh.theta0
    assert hp_full.beta >= hp_fresh.beta


def test_window_requires_two_rounds():
    with pytest.raises(ValueError, match="window must be >= 2"):
        HyperEstimator(5, 4, 1e-2, window=1)


def test_windowed_tracks_regime_change_unwindowed_averages():
    """After a regime shift in gradient scale, the windowed G2 matches the
    new regime exactly while the lifetime average sits in between."""
    W = 3
    win = HyperEstimator(5, 4, 1e-2, window=W)
    life = HyperEstimator(5, 4, 1e-2)
    scale_of = lambda t: 1.0 if t < 5 else 10.0
    for t in range(8):
        params, grads = _round_trees(jax.random.fold_in(jax.random.PRNGKey(7), t))
        grads = jax.tree.map(lambda x: scale_of(t) * x, grads)
        win.observe(params, grads, 2.0)
        life.observe(params, grads, 2.0)
    late = HyperEstimator(5, 4, 1e-2, window=W)
    for t in range(5, 8):
        params, grads = _round_trees(jax.random.fold_in(jax.random.PRNGKey(7), t))
        grads = jax.tree.map(lambda x: 10.0 * x, grads)
        late.observe(params, grads, 2.0)
    np.testing.assert_array_equal(win.hyperspec().G2, late.hyperspec().G2)
    assert np.all(life.hyperspec().G2 < win.hyperspec().G2)


def test_constant_stream_converges_to_single_round_stats():
    """A constant (params drifting, grads fixed) stream: windowed moments
    equal the single round's values for any stream length, and beta hits
    its floor (the mean gradient never moves)."""
    params0, grads0 = _round_trees(jax.random.PRNGKey(11))
    for est in (HyperEstimator(5, 4, 1e-2, window=3),
                HyperEstimator(5, 4, 1e-2)):
        for t in range(6):
            params_t = jax.tree.map(lambda x: x + 0.1 * t, params0)
            est.observe(params_t, grads0, 1.0)
        hp = est.hyperspec()
        one = HyperEstimator(5, 4, 1e-2)
        one.observe(params0, grads0, 1.0)
        hp1 = one.hyperspec()
        np.testing.assert_allclose(hp.G2, hp1.G2, rtol=1e-6)
        np.testing.assert_allclose(hp.sigma2, hp1.sigma2, rtol=1e-7, atol=1e-12)
        assert hp.beta == 1e-3  # dg = 0 every step -> floor


def test_client_duplication_invariance():
    """Duplicating every client leaves G2/sigma2 unchanged (both are
    client means; windowed and lifetime modes alike).  beta is out of
    scope: its denominator is the global norm over the client stack, so
    it scales with fleet size by construction."""
    for window in (None, 4):
        a = HyperEstimator(5, 4, 1e-2, window=window)
        b = HyperEstimator(5, 8, 1e-2, window=window)
        for t in range(5):
            params, grads = _round_trees(jax.random.fold_in(jax.random.PRNGKey(3), t))
            dup = lambda tree: jax.tree.map(
                lambda x: jnp.concatenate([x, x], axis=0), tree
            )
            a.observe(params, grads, 1.0)
            b.observe(dup(params), dup(grads), 1.0)
        hp_a, hp_b = a.hyperspec(), b.hyperspec()
        np.testing.assert_allclose(hp_b.G2, hp_a.G2, rtol=1e-6)
        np.testing.assert_allclose(hp_b.sigma2, hp_a.sigma2, rtol=1e-6, atol=1e-12)
        # beta's Δw norm runs over the stacked tree: doubling the fleet
        # scales it by exactly sqrt(2) — a deterministic artifact, not noise
        assert hp_b.beta == pytest.approx(hp_a.beta / np.sqrt(2.0), rel=1e-6)
