"""Scenario library: generators of per-round fleet state (``SystemTrace``).

A scenario prices the *same* ``LayerProfile``/``SystemSpec`` terms the
analytic model uses (Eqs. 11–18), but per round and per client: each round
carries multiplicative perturbations of every compute / link rate plus a
client-availability mask.  Traces are generated lazily and deterministically
— round r's state is drawn from ``default_rng([seed, r, tag])`` — so the
discrete-event oracle (``events.py``) and the vectorized fast path
(``fleet.py``) consume identical numbers without materializing [R, N]
arrays up front, and a 10⁶-client trace costs memory only for the rounds
actually touched.

The five regimes (motivated by AdaptSFL / HASFL's system models):

* ``homogeneous-paper``      — all multipliers 1, everyone available; by
  construction reproduces ``split_latency``/``aggregation_latency`` exactly.
* ``lognormal-heterogeneous``— static per-client lognormal compute + access
  link rates (device heterogeneity).
* ``diurnal-churn``          — sinusoidal participation rate (day/night
  cycle) with per-round Bernoulli availability.
* ``flaky-wan``              — per-round lognormal link jitter plus rare
  deep outages (×0.1) on access, backhaul, and fed-server links.
* ``straggler-tail``         — a Pareto-tailed slowdown hits a random few
  clients' on-device compute each round.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from ..compress.base import CompressionSpec
from ..core.latency import LayerProfile, SystemSpec


@dataclass(frozen=True)
class RoundState:
    """Multiplicative fleet state for one round (all float64, masks bool).

    ``compute_mult[m]``            [N]    scales ``system.compute[m]``
    ``link_up_mult[m]``            [N]    scales ``system.act_up[m]``
    ``link_down_mult[m]``          [N]    scales ``system.act_down[m]``
    ``fed_up_mult[m]``             [J_m]  scales ``system.model_up[m]``
    ``fed_down_mult[m]``           [J_m]  scales ``system.model_down[m]``
    ``available``                  [N]    client participates this round
    """
    available: np.ndarray
    compute_mult: Tuple[np.ndarray, ...]
    link_up_mult: Tuple[np.ndarray, ...]
    link_down_mult: Tuple[np.ndarray, ...]
    fed_up_mult: Tuple[np.ndarray, ...]
    fed_down_mult: Tuple[np.ndarray, ...]


class SystemTrace:
    """Lazily generated, seeded sequence of ``RoundState`` for one scenario.

    ``compression`` (a ``repro.compress.CompressionSpec``) puts the trace's
    links on a compressed wire: both the discrete-event oracle and the
    vectorized fast path price boundary bits × ``act_ratio`` and model
    bits × ``model_ratio`` — per-round multipliers stay untouched, so the
    bit-exactness contract between the two paths is preserved.
    """

    def __init__(
        self,
        name: str,
        profile: LayerProfile,
        system: SystemSpec,
        rounds: int,
        seed: int,
        gen: Callable[[int], RoundState],
        compression: Optional[CompressionSpec] = None,
    ):
        self.name = name
        self.profile = profile
        self.system = system
        self.rounds = rounds
        self.seed = seed
        self.compression = compression
        self._gen = gen
        self._cache: Dict[int, RoundState] = {}

    def round_state(self, r: int) -> RoundState:
        if not 0 <= r < self.rounds:
            raise IndexError(f"round {r} outside trace [0, {self.rounds})")
        st = self._cache.get(r)
        if st is None:
            st = self._cache[r] = self._gen(r)
        return st

    def with_compression(
        self, compression: Optional[CompressionSpec]
    ) -> "SystemTrace":
        """The same seeded trace priced over a compressed wire."""
        if compression is not None:
            compression.validate_for(self.system.M)
        return SystemTrace(
            self.name, self.profile, self.system, self.rounds, self.seed,
            self._gen, compression,
        )


# --------------------------------------------------------------------------- #
# scenario constructors
# --------------------------------------------------------------------------- #

# per-scenario stream tags so scenarios sharing a seed stay decorrelated
_TAGS = {
    "homogeneous-paper": 0,
    "lognormal-heterogeneous": 1,
    "diurnal-churn": 2,
    "flaky-wan": 3,
    "straggler-tail": 4,
}


def _rng(seed: int, r: int, tag: int) -> np.random.Generator:
    return np.random.default_rng([seed, r, tag])


def _ones_state(system: SystemSpec) -> RoundState:
    N, M = system.num_clients, system.M
    one_n = np.ones(N)
    return RoundState(
        available=np.ones(N, dtype=bool),
        compute_mult=tuple(one_n for _ in range(M)),
        link_up_mult=tuple(one_n for _ in range(M - 1)),
        link_down_mult=tuple(one_n for _ in range(M - 1)),
        fed_up_mult=tuple(np.ones(len(system.model_up[m])) for m in range(M - 1)),
        fed_down_mult=tuple(np.ones(len(system.model_down[m])) for m in range(M - 1)),
    )


def _ensure_someone(avail: np.ndarray, r: int) -> np.ndarray:
    if not avail.any():  # a round with zero clients has no defined latency
        avail[r % len(avail)] = True
    return avail


def homogeneous_paper(
    profile: LayerProfile, system: SystemSpec, rounds: int, seed: int = 0
) -> SystemTrace:
    """The paper's static model: every round is the nominal system."""
    base = _ones_state(system)
    return SystemTrace(
        "homogeneous-paper", profile, system, rounds, seed, lambda r: base
    )


def lognormal_heterogeneous(
    profile: LayerProfile,
    system: SystemSpec,
    rounds: int,
    seed: int = 0,
    compute_sigma: float = 0.5,
    link_sigma: float = 0.6,
) -> SystemTrace:
    """Static device heterogeneity: per-client lognormal compute and access
    link multipliers drawn once (median 1), constant across rounds."""
    N = system.num_clients
    tag = _TAGS["lognormal-heterogeneous"]
    rng = _rng(seed, 0, tag)
    dev = np.exp(rng.normal(0.0, compute_sigma, N))
    up = np.exp(rng.normal(0.0, link_sigma, N))
    down = np.exp(rng.normal(0.0, link_sigma, N))
    base = _ones_state(system)
    st = RoundState(
        available=base.available,
        compute_mult=(dev,) + base.compute_mult[1:],
        link_up_mult=(up,) + base.link_up_mult[1:],
        link_down_mult=(down,) + base.link_down_mult[1:],
        fed_up_mult=base.fed_up_mult,
        fed_down_mult=base.fed_down_mult,
    )
    return SystemTrace(
        "lognormal-heterogeneous", profile, system, rounds, seed, lambda r: st
    )


def diurnal_churn(
    profile: LayerProfile,
    system: SystemSpec,
    rounds: int,
    seed: int = 0,
    period: int = 24,
    p_min: float = 0.35,
    p_max: float = 0.95,
) -> SystemTrace:
    """Participation follows a day/night sinusoid; each client flips a
    Bernoulli coin against the hour's rate every round (dropout / rejoin)."""
    N = system.num_clients
    tag = _TAGS["diurnal-churn"]
    base = _ones_state(system)

    def gen(r: int) -> RoundState:
        p = p_min + (p_max - p_min) * 0.5 * (1.0 + np.sin(2.0 * np.pi * r / period))
        avail = _ensure_someone(_rng(seed, r, tag).random(N) < p, r)
        return RoundState(
            available=avail,
            compute_mult=base.compute_mult,
            link_up_mult=base.link_up_mult,
            link_down_mult=base.link_down_mult,
            fed_up_mult=base.fed_up_mult,
            fed_down_mult=base.fed_down_mult,
        )

    return SystemTrace("diurnal-churn", profile, system, rounds, seed, gen)


def flaky_wan(
    profile: LayerProfile,
    system: SystemSpec,
    rounds: int,
    seed: int = 0,
    jitter_sigma: float = 0.25,
    outage_p: float = 0.05,
    outage_mult: float = 0.1,
    outage_len: int = 1,
) -> SystemTrace:
    """Per-round WAN weather: lognormal jitter on every link, plus rare deep
    outages that cut a link to ``outage_mult`` of nominal for the round.

    ``outage_len > 1`` makes outages *persistent weather*: the outage
    indicators are drawn once per block of ``outage_len`` consecutive
    rounds (jitter stays per-round), so a hit link stays degraded long
    enough for a sliding-window estimate to see it — the regime the
    adaptive controller (``repro.control``) exploits.  The default
    ``outage_len=1`` reproduces the original per-round-iid stream
    bit-for-bit.
    """
    N, M = system.num_clients, system.M
    tag = _TAGS["flaky-wan"]
    base = _ones_state(system)

    def link(rng: np.random.Generator, n: int,
             orng: Optional[np.random.Generator] = None) -> np.ndarray:
        mult = np.exp(rng.normal(0.0, jitter_sigma, n))
        hit = (orng if orng is not None else rng).random(n) < outage_p
        return np.where(hit, mult * outage_mult, mult)

    def gen(r: int) -> RoundState:
        rng = _rng(seed, r, tag)
        # block stream for persistent outages; same draw order as the
        # per-round calls below, so every round of a block sees one weather
        orng = None if outage_len <= 1 else _rng(seed, r // outage_len, tag + 16)
        return RoundState(
            available=base.available,
            compute_mult=base.compute_mult,
            link_up_mult=tuple(link(rng, N, orng) for _ in range(M - 1)),
            link_down_mult=tuple(link(rng, N, orng) for _ in range(M - 1)),
            fed_up_mult=tuple(
                link(rng, len(system.model_up[m]), orng) for m in range(M - 1)
            ),
            fed_down_mult=tuple(
                link(rng, len(system.model_down[m]), orng) for m in range(M - 1)
            ),
        )

    return SystemTrace("flaky-wan", profile, system, rounds, seed, gen)


def straggler_tail(
    profile: LayerProfile,
    system: SystemSpec,
    rounds: int,
    seed: int = 0,
    straggler_p: float = 0.1,
    pareto_shape: float = 1.5,
    pareto_scale: float = 6.0,
) -> SystemTrace:
    """Pareto-tailed on-device slowdowns: each round a random ~10% of clients
    run their tier-0 compute 1/(1 + Pareto) slower — the heavy tail that
    makes p95 round latency diverge from the nominal max."""
    N = system.num_clients
    tag = _TAGS["straggler-tail"]
    base = _ones_state(system)

    def gen(r: int) -> RoundState:
        rng = _rng(seed, r, tag)
        slow = 1.0 + pareto_scale * rng.pareto(pareto_shape, N)
        straggler = rng.random(N) < straggler_p
        dev = np.where(straggler, 1.0 / slow, 1.0)
        return RoundState(
            available=base.available,
            compute_mult=(dev,) + base.compute_mult[1:],
            link_up_mult=base.link_up_mult,
            link_down_mult=base.link_down_mult,
            fed_up_mult=base.fed_up_mult,
            fed_down_mult=base.fed_down_mult,
        )

    return SystemTrace("straggler-tail", profile, system, rounds, seed, gen)


SCENARIOS: Dict[str, Callable[..., SystemTrace]] = {
    "homogeneous-paper": homogeneous_paper,
    "lognormal-heterogeneous": lognormal_heterogeneous,
    "diurnal-churn": diurnal_churn,
    "flaky-wan": flaky_wan,
    "straggler-tail": straggler_tail,
}


def scenario_params(name: str) -> Tuple[str, ...]:
    """The extra keyword knobs a scenario accepts (beyond rounds/seed) —
    what a serialized ``ScenarioCfg.params`` mapping may contain."""
    import inspect

    try:
        factory = SCENARIOS[name]
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r}; available: {sorted(SCENARIOS)}"
        ) from None
    sig = inspect.signature(factory)
    skip = {"profile", "system", "rounds", "seed"}
    return tuple(p for p in sig.parameters if p not in skip)


def make_trace(
    name: str,
    profile: LayerProfile,
    system: SystemSpec,
    rounds: int,
    seed: int = 0,
    compression: Optional[CompressionSpec] = None,
    **kwargs,
) -> SystemTrace:
    """Build a named scenario's trace (see ``SCENARIOS`` for the registry)."""
    try:
        factory = SCENARIOS[name]
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r}; available: {sorted(SCENARIOS)}"
        ) from None
    # specs arrive from JSON (repro.api.ScenarioCfg.params): fail with the
    # accepted knob list instead of a bare TypeError deep in the factory
    allowed = set(scenario_params(name))
    unknown = sorted(set(kwargs) - allowed)
    if unknown:
        raise ValueError(
            f"scenario {name!r} got unknown param(s) {unknown}; "
            f"accepted: {sorted(allowed)}"
        )
    trace = factory(profile, system, rounds, seed=seed, **kwargs)
    return trace if compression is None else trace.with_compression(compression)
