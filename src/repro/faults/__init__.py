"""Fault injection & fault-tolerant training (DESIGN.md §16).

Deterministic fault regimes (``FaultSpec``) expanded per round from seeded
streams, composed with ``sim`` scenario traces (``faulty_trace``) so both
latency paths price identical fault-adjusted rounds; data-plane corruption
(``apply_corruption``) for the guard in ``tiers.synchronize`` to catch;
cell-outage rerouting over one-hot cell membership (``reroute``); and the
q-deflation accounting that keeps Theorem 1 honest under detected faults.
"""
from .accounting import (
    deflate_participation,
    fault_survival,
    round_healthy,
)
from .inject import apply_corruption, faulty_round_state, faulty_trace
from .reroute import (
    assignment_members,
    membership_mean,
    outage_assignment,
    reroute_entity_sync,
)
from .spec import (
    CORRUPT_MODES,
    CRASH_STAGES,
    FAULT_TAG,
    FaultSpec,
    RoundFaults,
    expand_faults,
    retry_attempts,
)

__all__ = [
    "CORRUPT_MODES",
    "CRASH_STAGES",
    "FAULT_TAG",
    "FaultSpec",
    "RoundFaults",
    "apply_corruption",
    "assignment_members",
    "deflate_participation",
    "expand_faults",
    "fault_survival",
    "faulty_round_state",
    "faulty_trace",
    "membership_mean",
    "outage_assignment",
    "reroute_entity_sync",
    "retry_attempts",
    "round_healthy",
]
