"""The closed-loop controller: telemetry → window → drift → warm re-solve.

``Controller`` owns the feedback loop around a running HSFL schedule:

1. ``observe`` folds each round's measured telemetry into the windowed
   system estimate (``WindowedLatency`` + windowed participation rates);
2. ``maybe_replan`` compares the windowed estimate against the prices the
   current schedule was solved for (``detect_drift``) and, on drift,
   re-solves MS/MA/BCD **warm-started at the current optimum** against a
   problem carrying the windowed model — the versioned evaluator memo
   plus the Dinkelbach warm seed make a control step milliseconds, not
   the seconds a cold trace re-price costs;
3. a confirmed schedule change is returned as a ``ControlDecision`` for
   the training loop to act on (plan rebuild + state migration).

Cooldown, minimum-window, and check-cadence knobs bound how often the
solver runs; the priced snapshot is refreshed after every re-solve so a
drift that doesn't change the optimum doesn't re-trigger each round.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..core.bcd import BcdResult, solve_bcd
from ..core.convergence import ParticipationSpec
from ..core.problem import HsflProblem
from .drift import DriftReport, detect_drift
from .telemetry import RoundObservation, reconstruct_state
from .window import WindowedLatency


@dataclass(frozen=True)
class ControlDecision:
    """One drift-triggered re-solve (``switched`` marks a schedule change)."""

    round_index: int
    trigger: str
    old_cuts: Tuple[int, ...]
    old_intervals: Tuple[int, ...]
    new_cuts: Tuple[int, ...]
    new_intervals: Tuple[int, ...]
    solve_seconds: float
    drift: DriftReport
    switched: bool

    def describe(self) -> str:
        arrow = "->" if self.switched else "== (no change)"
        return (
            f"round {self.round_index:4d}  [{self.trigger}]  "
            f"cuts {self.old_cuts} x I{self.old_intervals} {arrow} "
            f"cuts {self.new_cuts} x I{self.new_intervals}  "
            f"({1e3 * self.solve_seconds:.1f} ms re-solve)"
        )


class Controller:
    """Adaptive (cut, I, μ) control around a running schedule.

    ``problem`` is the experiment's problem (its profile / system / hyper
    / eps / compression seed the windowed re-pricing; any attached
    latency model or participation spec defines the *initially priced*
    reference the drift detector compares against).  ``deadline`` keeps
    the straggler policy: the windowed model then prices deadline-capped
    expected rounds and the windowed q comes from realized deadline
    masks, exactly like the offline ``DeadlineLatency`` pipeline.
    """

    def __init__(
        self,
        problem: HsflProblem,
        cuts: Sequence[int],
        intervals: Sequence[int],
        *,
        window: int = 8,
        check_every: int = 1,
        rel_tol: float = 0.25,
        cooldown: int = 8,
        min_window: int = 4,
        quantile: float = 0.5,
        deadline: Optional[float] = None,
        warm_start: bool = True,
        backend: str = "auto",
        max_switches: int = 0,
        fault_tol: float = 1.0,
    ):
        self.cuts = tuple(int(c) for c in cuts)
        self.intervals = tuple(int(i) for i in intervals)
        self.check_every = max(1, int(check_every))
        self.rel_tol = float(rel_tol)
        self.cooldown = max(0, int(cooldown))
        self.min_window = max(2, int(min_window))
        self.warm_start = bool(warm_start)
        self.backend = backend
        self.max_switches = int(max_switches)
        # sustained-fault-burst trigger (DESIGN.md §16): windowed mean
        # fraction of clients lost per round; 1.0 disables (rate ≤ 1).
        self.fault_tol = float(fault_tol)
        self._fault_window: List[float] = []
        if deadline is None and problem.participation is not None:
            deadline = problem.participation.deadline
        self.deadline = deadline
        # the windowed re-pricing base: same physics, no offline model
        self.base = dataclasses.replace(
            problem, latency_model=None, participation=None
        )
        lattice_rows = {
            tuple(int(x) for x in row) for row in self.base.cut_lattice()
        }
        if self.cuts not in lattice_rows:
            raise ValueError(
                f"initial cuts {self.cuts} are not on the problem's cut "
                f"lattice ({len(lattice_rows)} rows for n_units="
                f"{self.base.n_units}, M={self.base.M}); the controller can "
                "only price and re-solve lattice schedules — start from a "
                "solver result or a valid iter_cut_vectors row"
            )
        self.window_model = WindowedLatency(
            self.base.profile, self.base.system, self.base.cut_lattice(),
            window=window, quantile=quantile, deadline=deadline,
            compression=self.base.compression,
        )
        self._wproblem: Optional[HsflProblem] = None
        self.decisions: List[ControlDecision] = []
        self.resolve_seconds: List[float] = []
        self._cooldown_until = -1
        self._n_switches = 0
        # what the current schedule was priced against
        self._snapshot_from_problem(problem)

    # ------------------------------------------------------------------ #
    def _snapshot_from_problem(self, problem: HsflProblem) -> None:
        self._priced_split = float(problem.split_T(self.cuts))
        self._priced_agg = np.asarray(problem.agg_T(self.cuts), dtype=float)
        self._priced_q1 = float(problem.q[0])

    def _snapshot_from_window(self) -> None:
        self._priced_split = float(self.window_model.split_T(self.cuts))
        self._priced_agg = np.array(
            [
                self.window_model.agg_T(self.cuts, m)
                for m in range(self.base.M - 1)
            ]
        )
        self._priced_q1 = float(self._windowed_q()[0])

    def _windowed_q(self) -> np.ndarray:
        return np.clip(self.window_model.q_tiers(), 1e-6, 1.0)

    # ------------------------------------------------------------------ #
    def observe(self, obs: RoundObservation) -> None:
        """Fold one round's telemetry into the window (reconstructs the
        round's rate multipliers from the measured durations)."""
        state = reconstruct_state(
            obs, self.base.profile, self.base.system, self.base.compression
        )
        self.window_model.push(state, mask=obs.mask)
        self._fault_window.append(
            float(obs.n_faulty) / float(self.base.system.num_clients)
        )
        if len(self._fault_window) > self.window_model.window:
            self._fault_window.pop(0)

    def fault_rate(self) -> float:
        """Windowed mean fraction of clients lost to faults per round."""
        if not self._fault_window:
            return 0.0
        return float(np.mean(self._fault_window))

    def windowed_problem(self) -> HsflProblem:
        """The problem the re-solve runs against: the base physics with the
        windowed latency model and windowed participation attached.

        Both sides derive from the same observation window, so composing
        them via a direct ``dataclasses.replace`` is the consistent
        online analogue of ``participation_problem``.  The instance is
        reused while the participation view is unchanged — the versioned
        evaluator memo (``HsflProblem.evaluator``) then rebuilds only the
        latency tables that actually moved.
        """
        q = self._windowed_q()
        spec = None
        if self.deadline is not None or bool(np.any(q < 1.0 - 1e-12)):
            spec = ParticipationSpec(
                q=tuple(float(v) for v in q), deadline=self.deadline
            )
        if self._wproblem is None or spec != self._wproblem.participation:
            self._wproblem = dataclasses.replace(
                self.base,
                latency_model=self.window_model,
                participation=spec,
            )
        return self._wproblem

    def resolve(self) -> Tuple[BcdResult, float]:
        """Warm-started BCD against the windowed problem; returns the
        result and the wall-clock seconds the solve took."""
        wp = self.windowed_problem()
        t0 = time.perf_counter()
        res = solve_bcd(
            wp,
            init_cuts=self.cuts if self.warm_start else None,
            init_intervals=self.intervals if self.warm_start else None,
            backend=self.backend,
            warm_start=self.warm_start,
        )
        dt = time.perf_counter() - t0
        self.resolve_seconds.append(dt)
        return res, dt

    def maybe_replan(self, r: int) -> Optional[ControlDecision]:
        """Drift check for round ``r``; re-solves and returns a decision
        when the windowed system has left the priced model."""
        if self.window_model.n_obs < self.min_window:
            return None
        if (r + 1) % self.check_every != 0:
            return None
        if r < self._cooldown_until:
            return None
        if self.max_switches and self._n_switches >= self.max_switches:
            return None
        split_obs = self.window_model.split_T(self.cuts)
        agg_obs = np.array(
            [self.window_model.agg_T(self.cuts, m) for m in range(self.base.M - 1)]
        )
        report = detect_drift(
            split_obs, self._priced_split,
            agg_obs, self._priced_agg,
            float(self._windowed_q()[0]), self._priced_q1,
            self.rel_tol,
            fault_rate_obs=self.fault_rate(),
            fault_tol=self.fault_tol,
        )
        if not report.drifted:
            return None
        res, dt = self.resolve()
        new_cuts = tuple(int(c) for c in res.cuts)
        new_intervals = tuple(int(i) for i in res.intervals)
        switched = (new_cuts, new_intervals) != (self.cuts, self.intervals)
        dec = ControlDecision(
            round_index=int(r),
            trigger=report.trigger,
            old_cuts=self.cuts,
            old_intervals=self.intervals,
            new_cuts=new_cuts,
            new_intervals=new_intervals,
            solve_seconds=dt,
            drift=report,
            switched=switched,
        )
        self.cuts, self.intervals = new_cuts, new_intervals
        # re-anchor the drift reference at what we just solved against
        self._snapshot_from_window()
        self._cooldown_until = r + 1 + self.cooldown
        if switched:
            self._n_switches += 1
        self.decisions.append(dec)
        return dec

    # ------------------------------------------------------------------ #
    @property
    def n_switches(self) -> int:
        return self._n_switches

    def resolve_quantiles(self, qs=(0.5, 0.95)) -> Tuple[float, ...]:
        """Re-solve latency quantiles in seconds (p50/p95 by default)."""
        if not self.resolve_seconds:
            return tuple(float("nan") for _ in qs)
        arr = np.asarray(self.resolve_seconds)
        return tuple(float(np.quantile(arr, q)) for q in qs)
