"""Round telemetry: what the controller can actually measure.

In a deployed HSFL system the orchestrator sees per-stage wall-clock
durations reported by clients and fed servers, the availability /
participation masks, and the training loss — never the underlying rate
multipliers the scenario generators draw.  ``RoundObservation`` is exactly
that sensor payload; ``observe_round`` produces it from a fleet trace
(the "ground truth" in this repro), and ``reconstruct_state`` inverts the
timings back into a ``RoundState`` (rate multipliers) the windowed system
estimate can re-price the whole cut lattice against.

The inversion is exact up to floating-point division error: a stage
duration is ``work / (nominal_rate · mult)``, so ``mult = work /
(duration · nominal_rate)``.  Absent clients report nothing — their
durations are NaN and their reconstructed multipliers default to 1.0,
which is immaterial because every pricing path masks unavailable clients
out of the round reductions.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import numpy as np

from ..core.batched import model_bits_lattice
from ..core.latency import (
    LayerProfile,
    SystemSpec,
    aggregation_phases,
    split_stages,
    stage_rate,
)
from ..sim.events import round_stage_durations
from ..sim.scenarios import RoundState, SystemTrace


@dataclass(frozen=True)
class RoundObservation:
    """One round's measured telemetry (the controller's sensor payload).

    ``stage_durations`` follows the canonical chain order of
    ``core.latency.split_stages`` at ``cuts``; entries are NaN for absent
    clients.  ``fed_up``/``fed_down`` are the per-entity model-exchange
    durations of each client-hosted-or-fed-server tier sync (None for
    single-entity tiers).  ``mask`` is the realized participation mask
    when a deadline policy is active (None = availability is the mask).
    """

    round_index: int
    cuts: Tuple[int, ...]
    stage_durations: Tuple[np.ndarray, ...]       # [S] entries of [N]
    available: np.ndarray                          # [N] bool
    fed_up: Tuple[Optional[np.ndarray], ...]       # [M-1] entries of [J_m]
    fed_down: Tuple[Optional[np.ndarray], ...]     # [M-1] entries of [J_m]
    mask: Optional[np.ndarray] = None              # [N] bool
    loss: Optional[float] = None
    n_faulty: int = 0                              # clients lost to faults
                                                   # this round (crash +
                                                   # quarantine, §16)


def observe_round(
    trace: SystemTrace,
    r: int,
    cuts: Sequence[int],
    mask: Optional[np.ndarray] = None,
    loss: Optional[float] = None,
    n_faulty: int = 0,
) -> RoundObservation:
    """Measure round ``r`` of a fleet trace at the current cut vector.

    This is the sensor of the control loop: it reads the same per-stage
    duration arrays the simulators price (``events.round_stage_durations``)
    and the full per-entity fed-exchange phases, NaN-ing out what absent
    clients would never report.
    """
    system = trace.system
    state = trace.round_state(r)
    avail = state.available
    _, durs = round_stage_durations(trace, r, cuts)
    durs = tuple(np.where(avail, d, np.nan) for d in durs)
    fed_up, fed_down = [], []
    for m in range(system.M - 1):
        if system.entities[m] <= 1:
            fed_up.append(None)
            fed_down.append(None)
            continue
        up_rate = system.model_up[m] * state.fed_up_mult[m]
        down_rate = system.model_down[m] * state.fed_down_mult[m]
        up, down = aggregation_phases(
            trace.profile, system, cuts, m,
            up_rate=up_rate, down_rate=down_rate,
            compression=trace.compression,
        )
        if len(up) == system.num_clients:  # client-hosted: absentees silent
            up = np.where(avail, up, np.nan)
            down = np.where(avail, down, np.nan)
        fed_up.append(up)
        fed_down.append(down)
    return RoundObservation(
        round_index=int(r),
        cuts=tuple(int(c) for c in cuts),
        stage_durations=durs,
        available=avail.copy(),
        fed_up=tuple(fed_up),
        fed_down=tuple(fed_down),
        mask=None if mask is None else np.asarray(mask, dtype=bool).copy(),
        loss=None if loss is None else float(loss),
        n_faulty=int(n_faulty),
    )


def _invert(work: float, durations: np.ndarray, nominal: np.ndarray) -> np.ndarray:
    """mult = work / (duration · nominal_rate), 1.0 where unobserved."""
    with np.errstate(divide="ignore", invalid="ignore"):
        mult = work / (durations * nominal)
    return np.where(np.isfinite(mult) & (mult > 0), mult, 1.0)


def reconstruct_state(
    obs: RoundObservation,
    profile: LayerProfile,
    system: SystemSpec,
    compression=None,
) -> RoundState:
    """Invert measured durations into the round's rate multipliers.

    Compute multipliers come from the forward-compute stages (the
    backward stage yields the identical estimate — every scenario scales
    both by the same device multiplier); link multipliers from the
    uplink/downlink stages; fed multipliers from the model-exchange
    phases against the tier's model bits.  Unobserved entries (absent
    clients, single-entity tiers) reconstruct to 1.0.
    """
    M, N = system.M, system.num_clients
    stages = split_stages(profile, obs.cuts, compression)
    by_key = {}
    for s, st in enumerate(stages):
        by_key[(st.kind, st.index)] = _invert(
            st.work, obs.stage_durations[s], stage_rate(system, st)
        )
    ones = np.ones(N)
    compute = tuple(by_key.get(("compute_fwd", m), ones) for m in range(M))
    link_up = tuple(by_key.get(("uplink", m), ones) for m in range(M - 1))
    link_down = tuple(by_key.get(("downlink", m), ones) for m in range(M - 1))
    lam = model_bits_lattice(
        profile, np.asarray([obs.cuts], dtype=np.int64), compression
    )[0]
    fed_up, fed_down = [], []
    for m in range(M - 1):
        n_ent = len(system.model_up[m])
        if obs.fed_up[m] is None:
            fed_up.append(np.ones(n_ent))
            fed_down.append(np.ones(n_ent))
            continue
        fed_up.append(_invert(lam[m], obs.fed_up[m], system.model_up[m]))
        fed_down.append(_invert(lam[m], obs.fed_down[m], system.model_down[m]))
    return RoundState(
        available=obs.available.copy(),
        compute_mult=compute,
        link_up_mult=link_up,
        link_down_mult=link_down,
        fed_up_mult=tuple(fed_up),
        fed_down_mult=tuple(fed_down),
    )
