"""Quickstart: HSFL through the declarative API.

One serializable ``ExperimentSpec`` names the model, the tier topology,
and the (μ, I) schedule; ``run(spec)`` trains a reduced smollm-135m-family
LM across the 3-tier hierarchy (8 clients -> 4 edge entities -> 1 cloud)
with the paper's multi-timescale aggregation schedule, then we show
Theorem 1's bound for the schedule used.

    PYTHONPATH=src python examples/quickstart.py [--quick]
"""
import argparse

from repro.api import (
    ExperimentSpec, HyperCfg, ModelCfg, RunCfg, SolverCfg, SystemCfg, run,
)


def main(quick: bool = False, seed: int = 0):
    # the whole experiment as one declarative value (JSON-serializable)
    spec = ExperimentSpec(
        model=ModelCfg(arch="smollm-135m", variant="reduced", num_layers=4,
                       batch=4, seq=32),
        system=SystemCfg(preset="paper-three-tier", num_clients=8, num_edges=4),
        solver=SolverCfg(kind="fixed", cuts=(1, 3), intervals=(4, 2, 1)),
        run=RunCfg(mode="train", rounds=5 if quick else 30, lr=0.1,
                   seed=seed, log_every=10),
        hyper=HyperCfg(seed=seed),
    )
    from repro.api import build
    from repro.core import theorem1_bound

    built = build(spec)
    res = run(spec, built=built)
    print(f"plan: cuts={res.cuts} I={res.intervals}")
    print(f"loss: {res.train['first_loss']:.4f} -> {res.train['final_loss']:.4f} "
          f"over {res.train['rounds']} rounds (engine {res.train['engine']})")

    # Theorem 1: the convergence bound different schedules guarantee
    for I in [(1, 1, 1), (4, 2, 1), (64, 16, 1)]:
        b = theorem1_bound(built.hyper, R=500, intervals=I, cuts=res.cuts)
        print(f"Theorem-1 bound @R=500, I={I}: {b:.4f}")
    print("smaller I_m -> tighter bound (paper Insight 1)")
    return res


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="few rounds (CI smoke mode)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    main(args.quick, seed=args.seed)
