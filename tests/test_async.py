"""Bounded-staleness async aggregation (core.async_agg, DESIGN.md §17).

Fast tests drive the queue/apply machinery on toy client-stacked trees;
the slow tests pin the two engine-level contracts on a real reduced
model: staleness 0 collapses *bit-identically* onto the synchronous
fed_round dispatch, and a drain immediately after the due round is
bit-identical to the in-step fed level it deferred.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.async_agg import (
    AsyncTrainer,
    async_round_time,
    fed_level_apply,
    make_async_trainer,
    normalize_staleness,
)
from repro.core.engine import TrainState, build_train_step_a, init_state_a
from repro.core.tiers import default_plan, tier_subtrees

N = 8


def make_plan(intervals=(4, 2, 1)):
    return default_plan(4, N, cuts=(1, 2), intervals=intervals,
                        entities=(N, 4, 1))


def toy_params(seed=0):
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 3)
    return {
        "frontend": {"e": jax.random.normal(ks[0], (N, 3))},
        "units": {"w": jax.random.normal(ks[1], (N, 4, 2))},
        "head": {"h": jax.random.normal(ks[2], (N, 2))},
    }


# --------------------------------------------------------------------------- #
# normalize_staleness
# --------------------------------------------------------------------------- #


def test_normalize_scalar_hits_deferrable_tiers_only():
    plan = make_plan(intervals=(4, 1, 1))
    # tier 1 syncs every round, tier 2 is the top tier: both pinned to 0
    assert normalize_staleness(2, plan) == (2, 0, 0)
    assert normalize_staleness(0, plan) == (0, 0, 0)
    assert normalize_staleness(None, plan) == (0, 0, 0)


def test_normalize_explicit_tuple_validated():
    plan = make_plan()
    assert normalize_staleness((1, 0, 0), plan) == (1, 0, 0)
    with pytest.raises(ValueError, match="per-tier staleness"):
        normalize_staleness((1, 0), plan)
    with pytest.raises(ValueError, match=">= 0"):
        normalize_staleness((-1, 0, 0), plan)
    with pytest.raises(ValueError, match="top tier"):
        normalize_staleness((0, 0, 1), plan)
    with pytest.raises(ValueError, match="syncs every round"):
        normalize_staleness((0, 1, 0), make_plan(intervals=(4, 1, 1)))


# --------------------------------------------------------------------------- #
# fed_level_apply
# --------------------------------------------------------------------------- #


def test_fresh_apply_is_the_fed_mean_of_tier_m_only():
    plan, params = make_plan(), toy_params()
    out = fed_level_apply(params, plan, 0)
    # tier 0 = frontend + unit 0: global mean (the fed level has 1 group)
    np.testing.assert_allclose(
        np.asarray(out["frontend"]["e"]),
        np.broadcast_to(np.asarray(params["frontend"]["e"]).mean(0), (N, 3)),
        rtol=1e-6,
    )
    np.testing.assert_allclose(
        np.asarray(out["units"]["w"][:, :1]),
        np.broadcast_to(
            np.asarray(params["units"]["w"][:, :1]).mean(0), (N, 1, 2)
        ),
        rtol=1e-6,
    )
    # tiers 1 and 2 untouched, bit-for-bit
    np.testing.assert_array_equal(
        np.asarray(out["units"]["w"][:, 1:]),
        np.asarray(params["units"]["w"][:, 1:]),
    )
    np.testing.assert_array_equal(
        np.asarray(out["head"]["h"]), np.asarray(params["head"]["h"])
    )


def test_top_tier_apply_rejected():
    plan, params = make_plan(), toy_params()
    with pytest.raises(ValueError, match="top tier"):
        fed_level_apply(params, plan, plan.M - 1)


def test_masked_apply_averages_participants_only():
    plan, params = make_plan(), toy_params()
    mask = jnp.asarray([1, 1, 0, 1, 0, 0, 1, 1], jnp.float32)
    out = fed_level_apply(params, plan, 0, mask=mask)
    sel = np.asarray(mask) > 0
    np.testing.assert_allclose(
        np.asarray(out["frontend"]["e"]),
        np.broadcast_to(
            np.asarray(params["frontend"]["e"])[sel].mean(0), (N, 3)
        ),
        rtol=1e-6,
    )
    # all-zero mask: the zero-participant group keeps its params
    keep = fed_level_apply(params, plan, 0, mask=jnp.zeros((N,)))
    np.testing.assert_array_equal(
        np.asarray(keep["frontend"]["e"]), np.asarray(params["frontend"]["e"])
    )


def test_compress_fn_applied_on_the_fed_wire():
    plan, params = make_plan(), toy_params()
    out = fed_level_apply(params, plan, 0, compress_fn=jnp.round)
    np.testing.assert_allclose(
        np.asarray(out["frontend"]["e"]),
        np.broadcast_to(
            np.round(np.asarray(params["frontend"]["e"])).mean(0), (N, 3)
        ),
        rtol=1e-6,
    )


def test_stale_apply_retains_local_progress():
    """params_new = fed_mean(snapshot) + (params_now − snapshot)."""
    plan = make_plan()
    snap = toy_params(0)
    delta = toy_params(1)
    now = jax.tree.map(lambda a, d: a + 0.25 * d, snap, delta)
    out = fed_level_apply(now, plan, 1, snapshot=snap)
    w_s = np.asarray(snap["units"]["w"][:, 1:2])
    w_n = np.asarray(now["units"]["w"][:, 1:2])
    want = np.broadcast_to(w_s.mean(0), w_s.shape) + (w_n - w_s)
    np.testing.assert_allclose(
        np.asarray(out["units"]["w"][:, 1:2]), want, rtol=1e-5
    )
    # s = 0 degenerates: snapshot == now -> the delta term vanishes
    fresh = fed_level_apply(now, plan, 1)
    zero = fed_level_apply(now, plan, 1, snapshot=now)
    np.testing.assert_allclose(
        np.asarray(zero["units"]["w"]), np.asarray(fresh["units"]["w"]),
        rtol=1e-6,
    )


# --------------------------------------------------------------------------- #
# AsyncTrainer queue mechanics (fake step — no model, no compile)
# --------------------------------------------------------------------------- #


def _fake_builder(fed):
    def step(state, batch):
        params = jax.tree.map(lambda x: x + batch, state.params)
        return (
            TrainState(params, state.opt_state, state.step + 1),
            jnp.float32(0.0),
            jnp.ones((N,), jnp.float32),
        )

    return step


def test_trainer_defers_and_folds_in_the_snapshot_mean():
    plan = make_plan(intervals=(2, 1, 1))
    tr = AsyncTrainer(plan, _fake_builder, staleness=1, jit_apply=False)
    assert tr.async_tiers == [0]
    state = TrainState(toy_params(), (), jnp.int32(0))
    state, _ = tr.run_round(state, jnp.float32(1.0), 0)
    assert not tr.pending                      # (0+1) % 2 != 0: nothing due
    state, _ = tr.run_round(state, jnp.float32(1.0), 1)
    assert [p.tier for p in tr.pending] == [0]
    snap = tr.pending[0].snapshot
    assert tr.pending[0].apply_round == 2
    state, _ = tr.run_round(state, jnp.float32(1.0), 2)
    assert not tr.pending                      # applied at its due round
    want = fed_level_apply(
        jax.tree.map(lambda x: x + 1.0, snap), plan, 0, snapshot=snap
    )
    np.testing.assert_allclose(
        np.asarray(state.params["frontend"]["e"]),
        np.asarray(want["frontend"]["e"]),
        rtol=1e-6,
    )


def test_trainer_drain_empties_the_queue():
    plan = make_plan(intervals=(2, 2, 1))
    tr = AsyncTrainer(plan, _fake_builder, staleness=3, jit_apply=False)
    state = TrainState(toy_params(), (), jnp.int32(0))
    for r in range(2):
        state, _ = tr.run_round(state, jnp.float32(1.0), r)
    assert {p.tier for p in tr.pending} == {0, 1}
    state = tr.drain(state)
    assert not tr.pending
    assert all(np.isfinite(x).all() for x in jax.tree.leaves(state.params))


def test_fed_tuple_disables_async_tiers_in_step():
    plan = make_plan(intervals=(2, 2, 1))
    tr = AsyncTrainer(plan, _fake_builder, staleness=(1, 0, 0))
    # tier 0 deferred (never syncs in-step); tier 1 keeps its in-step gate
    assert tr._fed_tuple(0) == (False, False, True)
    assert tr._fed_tuple(1) == (False, True, True)
    sync = AsyncTrainer(plan, _fake_builder, staleness=0)
    assert sync._fed_tuple(1) == (True, True, True)  # the production dispatch


# --------------------------------------------------------------------------- #
# async_round_time
# --------------------------------------------------------------------------- #


def test_round_time_staleness_zero_reproduces_sync():
    sync, asyn = async_round_time(2.0, [4.0, 1.0, 0.0], (2, 4, 1), (0, 0, 0))
    assert sync == asyn == 2.0 + 4.0 / 2 + 1.0 / 4


def test_round_time_overlap_hides_the_wire():
    sync, asyn = async_round_time(2.0, [4.0, 1.0, 0.0], (2, 4, 1), (1, 1, 0))
    # tier 0: max(0, 4-2)/2 = 1; tier 1: max(0, 1-2)/4 = 0
    assert asyn == 2.0 + 1.0
    assert asyn < sync
    # s large enough hides everything: only the split compute remains
    _, full = async_round_time(2.0, [4.0, 1.0, 0.0], (2, 4, 1), (2, 1, 0))
    assert full == 2.0


# --------------------------------------------------------------------------- #
# real engine (slow): bit-exact collapse + deferred == in-step
# --------------------------------------------------------------------------- #


def _setup(rounds):
    from repro.configs import get_reduced
    from repro.configs.shapes import concrete_inputs
    from repro.models.model import SplittableModel
    from repro.optim import sgd

    spec = get_reduced("smollm-135m")
    model = SplittableModel(spec)
    plan = default_plan(spec.n_units, N, cuts=(1, 2), intervals=(3, 2, 1),
                        entities=(N, 4, 1))
    opt = sgd(1e-2)
    batches = []
    for r in range(rounds):
        b = concrete_inputs(spec, N * 2, 16, jax.random.PRNGKey(r))
        batches.append(jax.tree.map(
            lambda x: x.reshape((N, 2) + x.shape[1:]), b
        ))
    return model, plan, opt, batches


def _run_sync(model, plan, opt, batches):
    state = init_state_a(model, plan, opt, jax.random.PRNGKey(0))
    cache, losses = {}, []
    for r, batch in enumerate(batches):
        fed = tuple((r + 1) % I == 0 if I > 1 else True
                    for I in plan.intervals)
        if fed not in cache:
            cache[fed] = jax.jit(
                build_train_step_a(model, plan, opt, fed_round=fed)
            )
        state, loss = cache[fed](state, batch)
        losses.append(float(loss))
    return state, losses


@pytest.mark.slow
def test_async_staleness0_bitexact_vs_sync_dispatch():
    """All-zero staleness IS the synchronous production dispatch."""
    model, plan, opt, batches = _setup(6)
    ref_state, ref_losses = _run_sync(model, plan, opt, batches)

    tr = make_async_trainer(model, plan, opt, staleness=0)
    state = init_state_a(model, plan, opt, jax.random.PRNGKey(0))
    losses = []
    for r, batch in enumerate(batches):
        state, loss = tr.run_round(state, batch, r)
        losses.append(float(loss))
    assert not tr.pending
    state = tr.drain(state)

    assert losses == ref_losses
    for a, b in zip(jax.tree.leaves(state.params),
                    jax.tree.leaves(ref_state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_async_drain_at_due_round_matches_in_step_sync():
    """With no local steps between snapshot and apply, the deferred
    fed_level_apply is bit-identical to the in-step fed level: run 2
    rounds at s=1 (tiers snapshot on round 1, due round 2) and drain."""
    model, plan, opt, batches = _setup(2)
    plan2 = default_plan(plan.n_units, N, cuts=plan.cuts,
                         intervals=(2, 2, 1), entities=plan.entities)
    ref_state, _ = _run_sync(model, plan2, opt, batches)

    tr = make_async_trainer(model, plan2, opt, staleness=1)
    state = init_state_a(model, plan2, opt, jax.random.PRNGKey(0))
    for r, batch in enumerate(batches):
        state, _ = tr.run_round(state, batch, r)
    assert {p.tier for p in tr.pending} == {0, 1}
    state = tr.drain(state)

    for a, b in zip(jax.tree.leaves(state.params),
                    jax.tree.leaves(ref_state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
