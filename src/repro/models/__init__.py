from .spec import ModelSpec, MoeSpec, SsmSpec
from .model import SplittableModel
from .vgg import VggModel, VggSpec, build_model

__all__ = [
    "ModelSpec", "MoeSpec", "SsmSpec", "SplittableModel",
    "VggModel", "VggSpec", "build_model",
]
