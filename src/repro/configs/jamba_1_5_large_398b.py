"""jamba-1.5-large-398b [hybrid] — Mamba+attn 1:7 interleave, MoE 16e top-2 [arXiv:2403.19887]."""
import dataclasses
from ..models.spec import ModelSpec, MoeSpec, SsmSpec

SPEC = ModelSpec(
    name="jamba-1.5-large-398b", family="hybrid", num_layers=72, d_model=8192,
    num_heads=64, num_kv_heads=8, d_ff=24576, vocab_size=65536,
    moe=MoeSpec(num_experts=16, top_k=2),
    ssm=SsmSpec(state_dim=128, head_dim=128, expand=2, conv_width=4, chunk=256),
    attn_period=8, moe_period=2,
    source="arXiv:2403.19887",
)

REDUCED = dataclasses.replace(
    SPEC, num_layers=4, d_model=128, num_heads=4, num_kv_heads=2,
    d_ff=256, vocab_size=512, head_dim=32, attn_period=2, moe_period=2,
    moe=MoeSpec(num_experts=4, top_k=2),
    ssm=SsmSpec(state_dim=16, head_dim=32, expand=2, conv_width=4, chunk=16),
)
