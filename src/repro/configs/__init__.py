"""Architecture registry: ``--arch <id>`` resolution for every launcher."""
from __future__ import annotations

from importlib import import_module
from typing import Dict, List

_MODULES: Dict[str, str] = {
    "qwen2.5-14b": "qwen2_5_14b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "qwen3-32b": "qwen3_32b",
    "qwen2-1.5b": "qwen2_1_5b",
    "paligemma-3b": "paligemma_3b",
    "smollm-135m": "smollm_135m",
    "whisper-large-v3": "whisper_large_v3",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b_a6_6b",
    "mamba2-1.3b": "mamba2_1_3b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "vgg16-cifar10": "vgg16_cifar10",
}

ARCH_IDS: List[str] = [k for k in _MODULES if k != "vgg16-cifar10"]


def _mod(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return import_module(f".{_MODULES[name]}", __package__)


def get_spec(name: str):
    return _mod(name).SPEC


def get_reduced(name: str):
    return _mod(name).REDUCED
