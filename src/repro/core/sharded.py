"""Sharded Engine A: the multi-host ``shard_map`` lowering (DESIGN.md §17).

The single-host engine stacks every parameter leaf per client on axis 0
and realizes the HSFL hierarchy as ``tiers.synchronize`` group means.
This module shards that client axis over the mesh's client axes
(``data``, or ``pod × data`` multi-pod — ``launch.sharding``'s layout
contract) and lowers each aggregation level to whichever of two
strategies preserves the single-host semantics:

* **device-local** — when every aggregation group lives wholly on one
  device (``groups % num_shards == 0``), the level IS the single-host
  arithmetic on the local shard: ``tiers._group_mean`` /
  ``_group_mean_masked`` run unchanged, so the result is bit-identical
  to the unsharded engine.
* **matmul-shaped collective** — when a group spans devices (the
  fed-server level, groups=1, always does), the level becomes one
  matmul per leaf: a local weight matrix ``W[G, N_local]`` (group
  one-hot × participation weights) contracts against the local client
  stack in f32, partial products are summed with ``lax.psum`` over the
  client axes, and the participant counts are psum'd alongside so the
  zero-participant keep-last fallback survives sharding.  This is
  bit-identical *up to f32 reduction order*: the single-host mean sums
  N replicas in one reduction, the sharded mean sums N/D per device
  then D partials — the one documented deviation
  (``tests/test_sharded_exec.py`` pins it at allclose, and pins the
  device-local levels exactly).

The §16 guard survives sharding exactly: per-client finite checks and
norm² are device-local arithmetic, and the fleet median is taken over an
``all_gather`` of the per-client norm vector — the same multiset of
values the single-host median sorts.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..optim import Optimizer
from .engine import TrainState, init_state_a, _masked_select
from .tiers import (
    GuardSpec,
    TierPlan,
    _group_mean,
    _group_mean_masked,
    combine_tiers,
    tier_subtrees,
)

Params = Dict[str, Any]


def _axis_tuple(client_axes) -> Tuple[str, ...]:
    if isinstance(client_axes, str):
        return (client_axes,)
    return tuple(client_axes)


def num_client_shards(mesh: Mesh, client_axes) -> int:
    return math.prod(mesh.shape[a] for a in _axis_tuple(client_axes))


def _client_base(axis_names: Tuple[str, ...], n_local: int) -> jax.Array:
    """Global client id of this shard's slot 0.

    Clients lay out row-major over the client axes (the order
    ``jax.device_put`` shards axis 0), so the shard index is the mixed-
    radix expansion of the axis indices in the given order.
    """
    idx = jnp.zeros((), jnp.int32)
    for ax in axis_names:
        idx = idx * lax.psum(1, ax) + lax.axis_index(ax)
    return idx * n_local


def _matmul_group_mean(
    tree: Params,
    groups: int,
    n_global: int,
    axis_names: Tuple[str, ...],
    w: Optional[jax.Array],
    keep: Optional[Params] = None,
) -> Params:
    """Cross-device group mean as one matmul-shaped pass per leaf.

    ``tree`` leaves are local shards [N_local, ...]; every group of the
    ``n_global``-client fleet spans shards.  The fed-server batch
    (groups=1) is the degenerate case: one [1, N_local] × [N_local, D]
    contraction per leaf, psum'd over the client axes.
    """
    leaves = jax.tree.leaves(tree)
    n_local = leaves[0].shape[0]
    base = _client_base(axis_names, n_local)
    gs = n_global // groups
    gid = (base + jnp.arange(n_local, dtype=jnp.int32)) // gs  # [N_local]
    onehot = (
        gid[:, None] == jnp.arange(groups, dtype=jnp.int32)[None, :]
    ).astype(jnp.float32)                                      # [N_local, G]
    wl = jnp.ones((n_local,), jnp.float32) if w is None else w.astype(jnp.float32)
    ww = onehot * wl[:, None]                                  # [N_local, G]
    cnt = lax.psum(jnp.sum(ww, axis=0), axis_names)            # [G]
    if keep is None:
        keep = tree

    def f(x, k):
        flat = x.reshape(n_local, -1).astype(jnp.float32)
        partial_sums = jnp.einsum("ng,nd->gd", ww, flat)       # [G, D] matmul
        tot = lax.psum(partial_sums, axis_names)
        mean = tot / jnp.maximum(cnt, 1.0)[:, None]
        mine = mean[gid].astype(x.dtype).reshape(x.shape)      # gather my group
        alive = (cnt[gid] > 0.0).reshape((n_local,) + (1,) * (x.ndim - 1))
        return jnp.where(alive, mine, k)

    return jax.tree.map(f, tree, keep)


def sharded_guard_health(
    tree: Params,
    n_local: int,
    guard: GuardSpec,
    axis_names: Tuple[str, ...],
) -> Tuple[jax.Array, Params]:
    """``tiers.guard_health`` on a client shard: local finite/norm²
    arithmetic, fleet-median blow-up reference over an all_gather of the
    per-client norm vector (identical multiset → identical median)."""
    stacked = [
        x for x in jax.tree.leaves(tree)
        if hasattr(x, "ndim") and x.ndim > 0 and x.shape[0] == n_local
    ]
    finite = jnp.ones((n_local,), dtype=bool)
    for x in stacked:
        finite &= jnp.all(jnp.isfinite(x.reshape(n_local, -1)), axis=1)

    def sanitize(x):
        if not hasattr(x, "ndim") or x.ndim == 0 or x.shape[0] != n_local:
            return x
        ok = finite.reshape((n_local,) + (1,) * (x.ndim - 1))
        return jnp.where(ok, x, jnp.zeros((), x.dtype))

    clean = jax.tree.map(sanitize, tree)
    norm2 = jnp.zeros((n_local,), dtype=jnp.float32)
    for x in jax.tree.leaves(clean):
        if hasattr(x, "ndim") and x.ndim > 0 and x.shape[0] == n_local:
            f = x.reshape(n_local, -1).astype(jnp.float32)
            norm2 = norm2 + jnp.sum(f * f, axis=1)
    norm2_all = lax.all_gather(norm2, axis_names, axis=0, tiled=True)  # [N]
    med = jnp.median(norm2_all)
    blowup = norm2 > guard.norm_factor * jnp.maximum(med, jnp.float32(1e-30))
    health = (finite & ~blowup).astype(jnp.float32)
    return health, clean


def sharded_synchronize(
    params: Params,
    plan: TierPlan,
    step: jax.Array,
    *,
    num_shards: int,
    axis_names: Tuple[str, ...],
    fed_round=None,
    compress_fn=None,
    mask=None,
    guard: Optional[GuardSpec] = None,
) -> Params:
    """``tiers.synchronize`` on client shards, inside ``shard_map``.

    Semantics (fed-wire compression placement, mask weighting,
    zero-participant keep-last, guard quarantine, ``fed_round``
    specialization / ``lax.cond`` gating) mirror ``synchronize`` level
    for level; only the per-level *strategy* changes (module
    docstring).  Device-local levels are bit-identical; cross-device
    levels deviate by f32 reduction order only.
    """
    D = num_shards
    N = plan.num_clients
    n_local = N // D
    if guard is not None:
        health, params = sharded_guard_health(params, n_local, guard, axis_names)
        mask = health if mask is None else mask.astype(jnp.float32) * health
    parts = tier_subtrees(params, plan)
    if fed_round is not None and not isinstance(fed_round, (tuple, list)):
        fed_round = (bool(fed_round),) * plan.M
    out_parts = []
    for m, part in enumerate(parts):
        levels = plan.levels(m)
        for li, (groups, interval) in enumerate(levels):
            fed = (
                compress_fn is not None
                and m < plan.M - 1
                and li == len(levels) - 1
                and plan.entities[m] > 1
            )

            def level_mean(p, groups=groups, fed=fed):
                original = p
                if fed:
                    p = jax.tree.map(compress_fn, p)
                if groups % D == 0:
                    # every group lives wholly on one device: the level
                    # IS the single-host arithmetic on the local shard
                    if mask is not None:
                        return _group_mean_masked(
                            p, groups // D, mask, keep=original
                        )
                    return _group_mean(p, groups // D)
                return _matmul_group_mean(
                    p, groups, N, axis_names, mask, keep=original
                )

            if interval <= 1:
                part = level_mean(part)
            elif fed_round is None:
                do = (step + 1) % interval == 0
                part = lax.cond(do, level_mean, lambda p: p, part)
            elif fed_round[m]:
                part = level_mean(part)
        out_parts.append(part)
    return combine_tiers(out_parts, params)


# --------------------------------------------------------------------------- #
# the sharded Engine-A step
# --------------------------------------------------------------------------- #


def _client_pspec(ca: Tuple[str, ...]):
    return ca if len(ca) > 1 else ca[0]


def sharded_state_specs(state: TrainState, num_clients: int, client_axes):
    """PartitionSpec tree for a ``TrainState``: client axis 0 over the
    client axes, scalar bookkeeping replicated (``launch.sharding``'s
    training-step layout — TP over ``model`` is the serving path)."""
    from ..launch.sharding import train_pspecs

    return train_pspecs(state, _axis_tuple(client_axes), num_clients)


def init_sharded_state_a(
    model, plan: TierPlan, opt: Optimizer, key, mesh: Mesh, client_axes=("data",)
) -> TrainState:
    """``init_state_a`` placed on the mesh: same host-side init (same key →
    bit-identical initial replicas), then device_put under the client-axis
    shardings."""
    D = num_client_shards(mesh, client_axes)
    if plan.num_clients % D != 0:
        raise ValueError(
            f"num_clients={plan.num_clients} must divide over the "
            f"{D} client shards of mesh axes {_axis_tuple(client_axes)!r}"
        )
    state = init_state_a(model, plan, opt, key)
    specs = sharded_state_specs(state, plan.num_clients, client_axes)
    shardings = jax.tree.map(
        lambda ps: NamedSharding(mesh, ps), specs,
        is_leaf=lambda x: isinstance(x, P),
    )
    return jax.tree.map(jax.device_put, state, shardings)


def build_sharded_train_step_a(
    model,
    plan: TierPlan,
    opt: Optimizer,
    mesh: Mesh,
    *,
    client_axes=("data",),
    sync_opt_state: bool = False,
    fed_round=None,
    compressor=None,
    with_mask: bool = False,
    guard: Optional[GuardSpec] = None,
    with_sync_weights: bool = False,
) -> Callable[..., Tuple[TrainState, jax.Array]]:
    """``engine.build_train_step_a`` lowered to a ``shard_map`` program.

    Same signature contract as the single-host builder for the features
    that survive sharding (fed_round / compressor / with_mask / guard /
    sync_opt_state / with_sync_weights); ``privacy`` and
    ``class_members`` are *not* accepted — ``api.build`` rejects those
    spec combinations at build time (DESIGN.md §17 capability matrix).

    The returned step takes and returns client-sharded ``TrainState``s
    (see ``init_sharded_state_a``); batches shard their client axis the
    same way.  Loss is psum-reduced and replicated.
    """
    ca = _axis_tuple(client_axes)
    D = num_client_shards(mesh, ca)
    N = plan.num_clients
    if N % D != 0:
        raise ValueError(
            f"num_clients={N} must divide over the {D} client shards of "
            f"mesh axes {ca!r}"
        )
    n_local = N // D
    compress_fn = (
        None if compressor is None
        else lambda x: jax.vmap(lambda v: compressor.transform(v))(x)
    )

    def _sync(tree, step, *, compress=None, mask=None, guarded=False):
        return sharded_synchronize(
            tree, plan, step,
            num_shards=D, axis_names=ca, fed_round=fed_round,
            compress_fn=compress, mask=mask,
            guard=(guard if guarded else None),
        )

    # the wrapper always feeds a mask array (shard_map arity is static);
    # whether the *caller* masks is the static with_mask flag, which keeps
    # the unmasked paths (plain mean loss, unmasked _group_mean sync)
    # structurally identical to the single-host engine's mask=None graph.
    has_mask = with_mask

    def _shard_step(state: TrainState, batch: Params, mask):
        losses, grads = jax.vmap(jax.value_and_grad(model.loss_fn))(
            state.params, batch
        )
        new_params, new_opt = opt.update(state.params, grads, state.opt_state)
        if guard is not None:
            health, _ = sharded_guard_health(new_params, n_local, guard, ca)
            lfin = jnp.isfinite(losses)
            health = health * lfin.astype(jnp.float32)
            w = mask.astype(jnp.float32) * health if has_mask else health
            new_params = _masked_select(new_params, state.params, w)
            new_opt = _masked_select(new_opt, state.opt_state, w)
            lsafe = jnp.where(lfin, losses, 0.0)
            tot = lax.psum(jnp.sum(lsafe * w), ca)
            s = lax.psum(jnp.sum(w), ca)
            loss = jnp.where(s > 0.0, tot / jnp.maximum(s, 1.0), 0.0)
            if not has_mask:
                # all-healthy unmasked rounds report the exact plain mean
                # (the single-host engine's zero-fault collapse contract)
                all_healthy = lax.psum(jnp.sum(w >= 1.0), ca) >= N
                loss = jnp.where(
                    all_healthy, lax.psum(jnp.sum(lsafe), ca) / N, loss
                )
            sync_mask = w
        elif not has_mask:
            loss = lax.psum(jnp.sum(losses), ca) / N
            sync_mask = None
        else:
            w = mask.astype(jnp.float32)
            new_params = _masked_select(new_params, state.params, w)
            new_opt = _masked_select(new_opt, state.opt_state, w)
            tot = lax.psum(jnp.sum(losses * w), ca)
            s = lax.psum(jnp.sum(w), ca)
            loss = jnp.where(s > 0.0, tot / jnp.maximum(s, 1.0), 0.0)
            sync_mask = mask
        new_params = _sync(
            new_params, state.step, compress=compress_fn, mask=sync_mask,
            guarded=True,
        )
        if sync_opt_state and jax.tree.leaves(new_opt):
            if opt.name == "momentum":
                new_opt = _sync(new_opt, state.step, mask=sync_mask, guarded=True)
            elif opt.name == "adam":
                new_opt = dict(new_opt)
                new_opt["m"] = _sync(
                    new_opt["m"], state.step, mask=sync_mask, guarded=True
                )
                new_opt["v"] = _sync(
                    new_opt["v"], state.step, mask=sync_mask, guarded=True
                )
        out_state = TrainState(new_params, new_opt, state.step + 1)
        if with_sync_weights:
            ww = (
                jnp.ones((n_local,), jnp.float32)
                if sync_mask is None else sync_mask.astype(jnp.float32)
            )
            return out_state, loss, ww
        return out_state, loss, jnp.zeros((n_local,), jnp.float32)

    from ..launch.sharding import batch_pspecs, train_pspecs

    ca_spec = _client_pspec(ca)

    _cache: Dict[Any, Callable] = {}

    def _get(state, batch):
        key = (
            jax.tree.structure(batch),
            tuple(x.ndim for x in jax.tree.leaves(batch)),
            jax.tree.structure(state),
        )
        fn = _cache.get(key)
        if fn is not None:
            return fn
        state_specs = train_pspecs(state, ca, N)
        batch_specs = batch_pspecs(batch, ca)
        mapped = shard_map(
            _shard_step,
            mesh=mesh,
            in_specs=(state_specs, batch_specs, P(ca_spec)),
            out_specs=(state_specs, P(), P(ca_spec)),
            check_rep=False,
        )
        fn = _cache[key] = jax.jit(mapped)
        return fn

    if with_mask or with_sync_weights:
        def step(state, batch, mask=None):
            if mask is None:
                mask_arr = jnp.ones((N,), jnp.float32) if with_mask else None
            else:
                mask_arr = jnp.asarray(mask, jnp.float32)
            if mask_arr is None:
                mask_arr = jnp.ones((N,), jnp.float32)
            out_state, loss, w = _get(state, batch)(state, batch, mask_arr)
            if with_sync_weights:
                return out_state, loss, w
            return out_state, loss
    else:
        def step(state, batch):
            mask_arr = jnp.ones((N,), jnp.float32)
            out_state, loss, _ = _get(state, batch)(state, batch, mask_arr)
            return out_state, loss

    return step
