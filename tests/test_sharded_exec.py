"""Real multi-device execution tests for the perf-variant shardings.

Runs in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
(conftest must NOT set it globally) and checks the seq-sharded KV-cache
decode (EXPERIMENTS.md sect. Perf / qwen3-decode) is bit-compatible with
the replicated-cache layout AND with unsharded single-device decode.
"""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_reduced
    from repro.launch import sharding as sh
    from repro.models.model import SplittableModel

    assert len(jax.devices()) == 8
    spec = get_reduced("qwen2-1.5b")
    model = SplittableModel(spec)
    key = jax.random.PRNGKey(0)
    params = model.init_params(key)
    B, C = 16, 64
    tok = jax.random.randint(jax.random.fold_in(key, 1), (B, 1), 0,
                             spec.vocab_size)

    # reference: plain single-logical-device decode
    caches0 = model.init_caches(B, C)
    ref_logits, ref_caches = jax.jit(model.decode_step)(
        params, tok, caches0, jnp.int32(0)
    )

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    pps = sh.param_pspecs(params, tp=4, client_axes=None)
    params_sh = jax.device_put(params, sh.to_shardings(mesh, pps))
    outs = {}
    for seq_shard in (False, True):
        cps = sh.cache_pspecs(
            jax.eval_shape(lambda: model.init_caches(B, C)),
            batch=B, client_axes=("data",), tp=4, seq_shard=seq_shard,
        )
        caches = jax.device_put(model.init_caches(B, C),
                                sh.to_shardings(mesh, cps))
        f = jax.jit(model.decode_step)
        logits, ncaches = f(params_sh, jax.device_put(tok), caches,
                            jnp.int32(0))
        outs[seq_shard] = np.asarray(logits)
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(ref_logits), rtol=2e-5, atol=2e-5,
            err_msg=f"seq_shard={seq_shard} diverges from reference",
        )
    np.testing.assert_allclose(outs[False], outs[True], rtol=2e-5, atol=2e-5)
    print("SHARDED-DECODE-OK")
""")


@pytest.mark.slow
def test_seq_sharded_cache_decode_equivalence():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SHARDED-DECODE-OK" in out.stdout


MOE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import numpy as np
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_reduced
    from repro.models import layers as L

    spec = get_reduced("granite-moe-1b-a400m")
    ms = dataclasses.replace(spec.moe, capacity_factor=8.0)  # no drops
    spec = dataclasses.replace(spec, moe=ms)
    p = L.init_moe(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, spec.d_model))
    ref, _ = L.moe(p, x, spec, groups=1)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    def constraint(b):
        g, e = b.shape[0], b.shape[1]
        pg = "data" if g % 2 == 0 else None
        pe = "model" if e % 4 == 0 else None
        return jax.lax.with_sharding_constraint(
            b, NamedSharding(mesh, P(pg, pe, None, None)))
    xs = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
    out, _ = jax.jit(
        lambda p_, x_: L.moe(p_, x_, spec, constraint=constraint, groups=2)
    )(p, xs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    print("SHARDED-MOE-OK")
""")


@pytest.mark.slow
def test_grouped_moe_sharded_equivalence():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", MOE_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SHARDED-MOE-OK" in out.stdout


ENGINE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.configs import get_reduced
    from repro.configs.shapes import concrete_inputs
    from repro.core.async_agg import make_async_trainer
    from repro.core.engine import build_train_step_a, init_state_a
    from repro.core.sharded import (
        build_sharded_train_step_a, init_sharded_state_a,
    )
    from repro.core.tiers import GuardSpec, default_plan
    from repro.launch.mesh import make_debug_mesh
    from repro.models.model import SplittableModel
    from repro.optim import sgd
    from repro.compress import Int8Stochastic

    assert len(jax.devices()) == 4
    N, R = 8, 4
    spec = get_reduced("smollm-135m")
    model = SplittableModel(spec)
    opt = sgd(1e-2)
    # entities (8, 2, 1): tier 0's 8 groups land device-local on D=4,
    # tier 1's 2 groups force the matmul-shaped cross-device path
    plan = default_plan(spec.n_units, N, cuts=(1, 2), intervals=(2, 2, 1),
                        entities=(N, 2, 1))
    mesh = make_debug_mesh(data=4, model=1)

    batches, masks = [], []
    for r in range(R):
        b = concrete_inputs(spec, N * 2, 16, jax.random.PRNGKey(r))
        batches.append(jax.tree.map(
            lambda x: x.reshape((N, 2) + x.shape[1:]), b
        ))
        masks.append((jnp.arange(N) % 3 != r % 3).astype(jnp.float32))

    def fed(r):
        return tuple((r + 1) % I == 0 if I > 1 else True
                     for I in plan.intervals)

    def run(sharded, **kw):
        with_mask = kw.get("with_mask", False)
        if sharded:
            state = init_sharded_state_a(model, plan, opt,
                                         jax.random.PRNGKey(0), mesh)
            mk = lambda f: build_sharded_train_step_a(
                model, plan, opt, mesh, fed_round=f, **kw)
        else:
            state = init_state_a(model, plan, opt, jax.random.PRNGKey(0))
            mk = lambda f: jax.jit(build_train_step_a(
                model, plan, opt, fed_round=f, **kw))
        steps, losses = {}, []
        for r in range(R):
            f = fed(r)
            if f not in steps:
                steps[f] = mk(f)
            args = (state, batches[r]) + ((masks[r],) if with_mask else ())
            state, loss = steps[f](*args)
            losses.append(float(loss))
        return losses, state.params

    configs = {
        "plain": {},
        "mask": dict(with_mask=True),
        "compress": dict(compressor=Int8Stochastic(tile=128)),
        "guard+mask": dict(with_mask=True, guard=GuardSpec()),
    }
    for name, kw in configs.items():
        ref_losses, ref_params = run(False, **kw)
        sh_losses, sh_params = run(True, **kw)
        np.testing.assert_allclose(
            sh_losses, ref_losses, rtol=2e-5,
            err_msg=f"{name}: sharded losses diverge",
        )
        # the quantized wire amplifies reduction-order noise: a value that
        # lands on the other side of an int8 rounding boundary jumps a
        # full quant step, so the compressed config gets a step-sized atol
        atol = 2e-3 if name == "compress" else 2e-6
        for a, b in zip(jax.tree.leaves(sh_params),
                        jax.tree.leaves(ref_params)):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=2e-5, atol=atol,
                err_msg=f"{name}: sharded params diverge",
            )
        print(f"config {name}: sharded == single-host")

    # async over the sharded step: s=0 is bit-identical to the sharded
    # sync dispatch (the same shard_map programs run in the same order)
    _, sync_params = run(True)
    tr = make_async_trainer(model, plan, opt, staleness=0, mesh=mesh)
    state = init_sharded_state_a(model, plan, opt, jax.random.PRNGKey(0),
                                 mesh)
    for r in range(R):
        state, _ = tr.run_round(state, batches[r], r)
    assert not tr.pending
    for a, b in zip(jax.tree.leaves(state.params),
                    jax.tree.leaves(sync_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # s=1: defer, then drain right at the due round — equivalent to the
    # in-step fed levels up to cross-device reduction order
    tr1 = make_async_trainer(model, plan, opt, staleness=1, mesh=mesh)
    state = init_sharded_state_a(model, plan, opt, jax.random.PRNGKey(0),
                                 mesh)
    for r in range(2):
        state, loss = tr1.run_round(state, batches[r], r)
        assert np.isfinite(float(loss))
    assert {p.tier for p in tr1.pending} == {0, 1}
    state = tr1.drain(state)
    # reference: the sharded sync engine over the same 2 rounds
    st = init_sharded_state_a(model, plan, opt, jax.random.PRNGKey(0), mesh)
    steps = {}
    for r in range(2):
        f = fed(r)
        if f not in steps:
            steps[f] = build_sharded_train_step_a(
                model, plan, opt, mesh, fed_round=f)
        st, _ = steps[f](st, batches[r])
    for a, b in zip(jax.tree.leaves(state.params),
                    jax.tree.leaves(st.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-6)
    print("SHARDED-ENGINE-OK")
""")


@pytest.mark.slow
def test_sharded_engine_a_equivalence():
    """core.sharded == core.engine across mask x compression x guard, plus
    the async trainer's staleness-0 bit-exact collapse on the mesh."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", ENGINE_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SHARDED-ENGINE-OK" in out.stdout
