"""Batched decode driver (serve_step) — CPU-runnable on reduced configs.

After HSFL training converges, the fed server owns the aggregated model;
this driver runs batched autoregressive decoding against a KV/state cache,
the same ``decode_step`` the decode_32k / long_500k dry-runs lower.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b \
        --batch 4 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp


def load_serving_params(path: str, template):
    """Restore serving params from either checkpoint layout.

    ``launch.train`` saves the Engine-A *client-stacked* state (every
    leaf carries a leading client axis N) — including sharded/async runs,
    which drain in-flight syncs before saving.  After the top-tier cloud
    sync every client row holds the aggregated model, so the serving copy
    is row 0.  A plain single-model checkpoint restores as-is.
    """
    import numpy as np

    from ..checkpoint import load_checkpoint
    from ..checkpoint.npz import _seg
    from ..core.engine import replicate_for_clients, unreplicate

    try:
        params, _, _ = load_checkpoint(path, template)
        return params
    except ValueError:
        pass  # shapes mismatched — try the client-stacked layout
    leaves = jax.tree_util.tree_flatten_with_path(template)[0]
    key0 = "/".join(_seg(p) for p in leaves[0][0])
    with np.load(path) as z:
        if key0 not in z:
            raise KeyError(f"checkpoint missing leaf {key0!r}")
        saved = z[key0].shape
    want = np.asarray(leaves[0][1]).shape
    if len(saved) != len(want) + 1:
        raise ValueError(
            f"checkpoint leaf {key0!r} has shape {saved}, which is neither "
            f"the serving shape {want} nor client-stacked (N,)+{want}"
        )
    n = int(saved[0])
    stacked, _, _ = load_checkpoint(path, replicate_for_clients(template, n))
    return unreplicate(stacked)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from ..configs import get_reduced
    from ..models.model import SplittableModel

    spec = get_reduced(args.arch)
    if spec.family in ("vlm", "audio"):
        raise SystemExit(f"{args.arch}: decode driver supports text-only archs")
    model = SplittableModel(spec)
    key = jax.random.PRNGKey(args.seed)
    params = model.init_params(key)
    if args.checkpoint:
        params = load_serving_params(args.checkpoint, params)
        print(f"restored {args.checkpoint}")

    B = args.batch
    caches = model.init_caches(B, args.cache_len)
    decode = jax.jit(model.decode_step)

    key, k1 = jax.random.split(key)
    prompt = jax.random.randint(k1, (B, args.prompt_len), 0, spec.vocab_size)

    # prefill via repeated decode (tiny models; exercises the cache path)
    t0 = time.time()
    tok = prompt[:, :1]
    for i in range(args.prompt_len):
        logits, caches = decode(params, prompt[:, i : i + 1], caches, jnp.int32(i))
    out_tokens = []
    tok = jnp.argmax(logits[:, : spec.vocab_size], axis=-1)[:, None]
    for i in range(args.gen):
        logits, caches = decode(
            params, tok, caches, jnp.int32(args.prompt_len + i)
        )
        if args.temperature > 0:
            key, ks = jax.random.split(key)
            tok = jax.random.categorical(
                ks, logits[:, : spec.vocab_size] / args.temperature
            )[:, None]
        else:
            tok = jnp.argmax(logits[:, : spec.vocab_size], axis=-1)[:, None]
        out_tokens.append(tok)
    dt = time.time() - t0
    gen = jnp.concatenate(out_tokens, axis=1)
    total = B * (args.prompt_len + args.gen)
    print(f"[serve] arch={spec.name} batch={B} prompt={args.prompt_len} "
          f"gen={args.gen}: {total/dt:.1f} tok/s ({dt:.2f}s)")
    print("sample tokens:", gen[0, :16].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
