"""(ε, δ) accounting for the DP-noised fed-server uplink (DESIGN.md §15).

Rényi-DP composition of the subsampled Gaussian mechanism at integer
orders: one HSFL round is one mechanism invocation whose sampling rate is
the client participation rate q₁ (the deadline-surviving fraction of the
fleet, DESIGN.md §12), and rounds compose additively in RDP.  For order
α ≥ 2 and noise multiplier z the per-round RDP is bounded by the
binomial-expansion moment bound (Mironov et al., "Rényi DP of the Sampled
Gaussian Mechanism", Thm. 4 restricted to integer α):

    A(α) = Σ_{k=0}^{α} C(α,k) (1−q)^{α−k} q^k · exp((k² − k) / (2 z²))
    RDP(α) = ln A(α) / (α − 1)

evaluated in log space (log-sum-exp) so large α / small z stay finite.
q = 1 collapses the sum to the plain Gaussian mechanism's α/(2z²)
exactly, and ε(δ) after R rounds is the standard RDP→DP conversion
minimized over the order grid:

    ε = min_α [ R·RDP(α) + ln(1/δ) / (α − 1) ].

``epsilon_oracle`` is the scalar reference: pure-``math`` per-term,
per-round accumulation loops.  ``Accountant`` is the vectorized numpy
path the solvers use; ``tests/test_privacy.py`` pins the two to 1e-9.
Because composition is linear in R, the budget inverts in closed form:
``rounds_for_budget`` returns the largest R whose ε stays ≤ the budget —
the round cap the BCD problem turns into a denominator floor.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import numpy as np

# α = 2 … 64: the standard moments-accountant grid; past ~64 the
# conversion term ln(1/δ)/(α−1) has flattened for every practical δ.
DEFAULT_ORDERS: Tuple[int, ...] = tuple(range(2, 65))


def _log_a_terms(alpha: int, z: float, q: float) -> list:
    """ln of every k-term of A(α) for the subsampled Gaussian bound."""
    terms = []
    for k in range(alpha + 1):
        lw = math.lgamma(alpha + 1) - math.lgamma(k + 1) - math.lgamma(
            alpha - k + 1
        )
        if k > 0:
            if q <= 0.0:
                continue
            lw += k * math.log(q)
        if alpha - k > 0:
            if q >= 1.0:
                continue
            lw += (alpha - k) * math.log1p(-q)
        terms.append(lw + (k * k - k) / (2.0 * z * z))
    return terms


def _logsumexp(terms: Sequence[float]) -> float:
    m = max(terms)
    return m + math.log(sum(math.exp(t - m) for t in terms))


def rdp_epsilon(alpha: int, z: float, q: float) -> float:
    """Per-round RDP at integer order α of the subsampled Gaussian."""
    if alpha < 2 or int(alpha) != alpha:
        raise ValueError(f"integer order alpha >= 2 required: {alpha}")
    if z <= 0.0:
        return math.inf
    if q <= 0.0:
        return 0.0
    if q >= 1.0:
        return alpha / (2.0 * z * z)
    return _logsumexp(_log_a_terms(int(alpha), z, q)) / (alpha - 1)


def rdp_vector(
    z: float, q: float, orders: Sequence[int] = DEFAULT_ORDERS
) -> np.ndarray:
    """Per-round RDP over the order grid — the vectorized accountant path."""
    return np.array([rdp_epsilon(a, z, q) for a in orders], dtype=np.float64)


def epsilon_oracle(
    z: float,
    q: float,
    rounds: int,
    delta: float,
    orders: Sequence[int] = DEFAULT_ORDERS,
) -> float:
    """Scalar reference: literal per-round composition, plain ``math``.

    Accumulates R·RDP(α) as R explicit additions per order, then takes
    the minimum conversion by a plain loop — the oracle the vectorized
    ``Accountant.epsilon`` must match to 1e-9.
    """
    if rounds <= 0:
        return 0.0
    if z <= 0.0:
        return math.inf
    best = math.inf
    for a in orders:
        r = rdp_epsilon(int(a), z, q)
        total = 0.0
        for _ in range(int(rounds)):
            total += r
        eps = total + math.log(1.0 / delta) / (a - 1)
        if eps < best:
            best = eps
    return best


def rounds_for_budget(
    z: float,
    q: float,
    delta: float,
    eps_budget: float,
    orders: Sequence[int] = DEFAULT_ORDERS,
) -> Optional[float]:
    """Largest round count whose composed ε stays ≤ the budget.

    None means unlimited (no budget, or a noiseless-irrelevant ∞ budget);
    0.0 means even a single round overruns (e.g. z = 0 under a finite ε).
    Linearity of RDP composition in R makes this exact:
    R_max = max_α ⌊(ε_b − ln(1/δ)/(α−1)) / RDP(α)⌋.
    """
    if eps_budget is None or math.isinf(eps_budget):
        return None
    if eps_budget <= 0.0:
        return 0.0
    if z <= 0.0:
        return 0.0  # no noise: any round spends infinite ε
    if q <= 0.0:
        return None  # nothing sampled: zero spend at any R
    best = 0.0
    for a in orders:
        r = rdp_epsilon(int(a), z, q)
        head = eps_budget - math.log(1.0 / delta) / (a - 1)
        if head <= 0.0:
            continue
        if r <= 0.0:
            return None
        best = max(best, math.floor(head / r))
    return best


@dataclass(frozen=True)
class Accountant:
    """Vectorized (ε, δ) accountant for one DP training configuration.

    ``noise_multiplier`` is z (noise std / clip norm), ``sampling_rate``
    the per-round client participation q₁, ``delta`` the target δ.
    """

    noise_multiplier: float
    sampling_rate: float = 1.0
    delta: float = 1e-5
    orders: Tuple[int, ...] = DEFAULT_ORDERS

    def __post_init__(self):
        if self.noise_multiplier < 0:
            raise ValueError(f"noise_multiplier < 0: {self.noise_multiplier}")
        if not (0.0 <= self.sampling_rate <= 1.0):
            raise ValueError(f"sampling_rate outside [0, 1]: {self.sampling_rate}")
        if not (0.0 < self.delta < 1.0):
            raise ValueError(f"delta outside (0, 1): {self.delta}")

    def _rdp(self) -> np.ndarray:
        rdp = self.__dict__.get("_rdp_cache")
        if rdp is None:
            rdp = rdp_vector(self.noise_multiplier, self.sampling_rate, self.orders)
            self.__dict__["_rdp_cache"] = rdp
        return rdp

    def epsilon(self, rounds: int) -> float:
        """ε after composing ``rounds`` rounds at the accountant's δ."""
        if rounds <= 0:
            return 0.0
        if self.noise_multiplier <= 0.0:
            return math.inf
        orders = np.asarray(self.orders, dtype=np.float64)
        eps = rounds * self._rdp() + math.log(1.0 / self.delta) / (orders - 1.0)
        return float(np.min(eps))

    def max_rounds(self, eps_budget: float) -> Optional[float]:
        """Largest R with ε(R) ≤ budget; None = unlimited, 0.0 = none."""
        return rounds_for_budget(
            self.noise_multiplier, self.sampling_rate, self.delta,
            eps_budget, self.orders,
        )
