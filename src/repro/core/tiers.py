"""Tier plans: HSFL's model-splitting + multi-timescale aggregation schedule.

A ``TierPlan`` captures the paper's (μ, I) decisions plus the entity topology:

* ``cuts``       — M-1 unit boundaries; tier m owns units [cuts[m-1], cuts[m])
                   (frontend ∈ tier 1, head ∈ tier M).
* ``intervals``  — I_m per tier; I_M is forced to 1 (single cloud server).
* ``levels``     — generalized aggregation schedule: per tier, a list of
                   (num_groups, interval) levels applied round-robin. The
                   paper's scheme is [(J_m, 1), (1, I_m)] (entity sync every
                   round — Eq. 3; fed-server aggregation every I_m — Eq. 4).
                   Multi-pod adds a pod level, e.g. tier M: [(P, 1), (1, I_pod)].

Synchronization operates on client-stacked parameter pytrees (axis 0 = client).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

Params = Dict[str, Any]


@dataclass(frozen=True)
class GuardSpec:
    """Aggregation guard: quarantine corrupt uploads (DESIGN.md §16).

    A client is *unhealthy* this round when any client-stacked leaf row
    carries a non-finite value, or when its sanitized squared parameter
    norm exceeds ``norm_factor`` × the fleet median (the blow-up check
    that catches finite corruption — scaled uploads, exponent bitflips).
    The guard converts an unhealthy client into a zero-participant via
    the §12 mask machinery: it contributes nothing to any level's mean
    but still *receives* the participating group's broadcast, which is
    what heals it.  Limitation: the median reference assumes fewer than
    half the fleet blows up the same way at once.
    """

    norm_factor: float = 1e4

    def __post_init__(self):
        import math

        if self.norm_factor <= 1.0 or not math.isfinite(self.norm_factor):
            raise ValueError(
                f"norm_factor must be finite and > 1: {self.norm_factor}"
            )


def guard_health(
    tree: Params, num_clients: int, guard: GuardSpec
) -> Tuple[jax.Array, Params]:
    """(health mask [N] float32, sanitized tree) for a client-stacked pytree.

    Sanitization zeroes non-finite rows *before* any arithmetic touches
    them, so the guard itself never produces a NaN/Inf — on an all-healthy
    round every ``where`` selects the original values and the returned
    tree is bit-identical to the input (the ``JAX_DEBUG_NANS`` contract
    pinned in ``tests/test_faults.py``).  Leaves without a leading client
    axis (scalar bookkeeping) pass through unchecked.
    """
    N = num_clients
    stacked = [
        x for x in jax.tree.leaves(tree)
        if hasattr(x, "ndim") and x.ndim > 0 and x.shape[0] == N
    ]
    finite = jnp.ones((N,), dtype=bool)
    for x in stacked:
        finite &= jnp.all(
            jnp.isfinite(x.reshape(N, -1)), axis=1
        )

    def sanitize(x):
        if not hasattr(x, "ndim") or x.ndim == 0 or x.shape[0] != N:
            return x
        ok = finite.reshape((N,) + (1,) * (x.ndim - 1))
        return jnp.where(ok, x, jnp.zeros((), x.dtype))

    clean = jax.tree.map(sanitize, tree)
    norm2 = jnp.zeros((N,), dtype=jnp.float32)
    for x in jax.tree.leaves(clean):
        if hasattr(x, "ndim") and x.ndim > 0 and x.shape[0] == N:
            f = x.reshape(N, -1).astype(jnp.float32)
            norm2 = norm2 + jnp.sum(f * f, axis=1)
    med = jnp.median(norm2)
    blowup = norm2 > guard.norm_factor * jnp.maximum(med, jnp.float32(1e-30))
    health = (finite & ~blowup).astype(jnp.float32)
    return health, clean


@dataclass(frozen=True)
class TierPlan:
    n_units: int
    num_clients: int
    cuts: Tuple[int, ...]          # len M-1, non-decreasing, in [0, n_units]
    intervals: Tuple[int, ...]     # len M (last forced 1)
    entities: Tuple[int, ...]      # J_m per tier; J_1 = num_clients, J_M = 1
    pod_interval: int = 0          # >0: extra cross-pod level on the top tier
    num_pods: int = 1

    def __post_init__(self):
        # User-facing invariants raise ValueError (not ``assert``): plans are
        # built from config files / API specs, and asserts vanish under
        # ``python -O``, silently admitting invalid plans.
        M = len(self.intervals)
        if len(self.cuts) != M - 1:
            raise ValueError(
                f"TierPlan needs exactly M-1 = {M - 1} cuts for "
                f"{M} intervals, got {len(self.cuts)}: "
                f"cuts={self.cuts!r}, intervals={self.intervals!r}"
            )
        if any(
            self.cuts[i] > self.cuts[i + 1] for i in range(len(self.cuts) - 1)
        ):
            raise ValueError(
                f"cuts must be non-decreasing (C4): {self.cuts!r}"
            )
        if any(not 0 <= c <= self.n_units for c in self.cuts):
            raise ValueError(
                f"every cut must lie in [0, n_units={self.n_units}]: "
                f"{self.cuts!r}"
            )
        if self.intervals[-1] != 1:
            raise ValueError(
                "top tier is always synchronized: intervals[-1] must be 1, "
                f"got {self.intervals!r}"
            )
        if len(self.entities) != M:
            raise ValueError(
                f"entities must list J_m for each of the {M} tiers, got "
                f"{len(self.entities)}: {self.entities!r}"
            )
        for j in self.entities:
            if j <= 0 or self.num_clients % j != 0:
                raise ValueError(
                    f"each tier's entity count must evenly divide "
                    f"num_clients={self.num_clients}: entities="
                    f"{self.entities!r} (offending J_m={j})"
                )

    @property
    def M(self) -> int:
        return len(self.intervals)

    def tier_bounds(self, m: int) -> Tuple[int, int]:
        """Unit range [lo, hi) of tier m (0-indexed)."""
        lo = 0 if m == 0 else self.cuts[m - 1]
        hi = self.n_units if m == self.M - 1 else self.cuts[m]
        return lo, hi

    def tier_of_unit(self, u: int) -> int:
        for m in range(self.M):
            lo, hi = self.tier_bounds(m)
            if lo <= u < hi:
                return m
        return self.M - 1

    def levels(self, m: int) -> List[Tuple[int, int]]:
        """Aggregation levels (num_groups, interval) for tier m."""
        lv: List[Tuple[int, int]] = []
        if self.entities[m] < self.num_clients:
            lv.append((self.entities[m], 1))  # Eq. (3): entity-local, per-round
        if m == self.M - 1:
            if self.pod_interval > 0 and self.num_pods > 1:
                # per-pod logical cloud every round; cross-pod at I_pod
                lv = [(self.num_pods, 1), (1, self.pod_interval)]
            else:
                lv.append((1, 1))
        else:
            lv.append((1, int(self.intervals[m])))  # Eq. (4): fed server
        return lv


# --------------------------------------------------------------------------- #
# pytree partition by tier
# --------------------------------------------------------------------------- #


def _slice_units(units: Any, lo: int, hi: int) -> Any:
    """Slice a unit container (stacked arrays: axis *after* the client axis,
    or python list) to the range [lo, hi)."""
    if isinstance(units, (list, tuple)):
        return list(units)[lo:hi]
    if isinstance(units, dict) and set(units) == {"enc", "dec"}:
        # audio: two stacks laid out enc ++ dec
        out = {}
        ne = jax.tree.leaves(units["enc"])[0].shape[1]
        e_lo, e_hi = min(lo, ne), min(hi, ne)
        d_lo, d_hi = max(lo, ne) - ne, max(hi, ne) - ne
        out["enc"] = jax.tree.map(lambda x: x[:, e_lo:e_hi], units["enc"])
        out["dec"] = jax.tree.map(lambda x: x[:, d_lo:d_hi], units["dec"])
        return out
    return jax.tree.map(lambda x: x[:, lo:hi], units)


def tier_subtrees(params: Params, plan: TierPlan) -> List[Params]:
    """Split a client-stacked model pytree into per-tier pytrees (views)."""
    parts: List[Params] = []
    for m in range(plan.M):
        lo, hi = plan.tier_bounds(m)
        part: Params = {"units": _slice_units(params["units"], lo, hi)}
        if m == 0:
            part["frontend"] = params["frontend"]
        if m == plan.M - 1:
            part["head"] = params["head"]
        parts.append(part)
    return parts


def combine_tiers(parts: List[Params], template: Params) -> Params:
    """Inverse of tier_subtrees (same cut structure)."""
    units_parts = [p["units"] for p in parts]
    tu = template["units"]
    if isinstance(tu, (list, tuple)):
        units = [u for part in units_parts for u in part]
    elif isinstance(tu, dict) and set(tu) == {"enc", "dec"}:
        units = {
            "enc": _concat_stacks([p["enc"] for p in units_parts]),
            "dec": _concat_stacks([p["dec"] for p in units_parts]),
        }
    else:
        units = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=1), *units_parts)
    out = {"units": units, "frontend": parts[0]["frontend"], "head": parts[-1]["head"]}
    return out


def _concat_stacks(stacks: List[Any]) -> Any:
    stacks = [s for s in stacks if jax.tree.leaves(s)]
    if len(stacks) == 1:
        return stacks[0]
    return jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=1), *stacks)


# --------------------------------------------------------------------------- #
# synchronization (the HSFL aggregation schedule, Eqs. 3–4)
# --------------------------------------------------------------------------- #


def _group_mean(tree: Params, groups: int) -> Params:
    """Mean over client groups, broadcast back. Leaves: [N, ...].

    ``core.sharded`` lowers this same level semantics onto a device mesh
    (DESIGN.md §17): when the group boundaries align with the shard
    boundaries the per-shard computation IS this function (bit-identical);
    otherwise the mean becomes a matmul-shaped one-hot einsum + ``psum``,
    equal up to f32 cross-device reduction order.
    """

    def f(x):
        n = x.shape[0]
        g = x.reshape(groups, n // groups, *x.shape[1:])
        m = jnp.mean(g, axis=1, keepdims=True, dtype=jnp.float32).astype(x.dtype)
        return jnp.broadcast_to(m, g.shape).reshape(x.shape)

    return jax.tree.map(f, tree)


def _group_mean_masked(
    tree: Params, groups: int, w: jax.Array, keep: Params = None
) -> Params:
    """Participation-weighted group mean, broadcast back (DESIGN.md §12).

    ``w`` is the per-client participation mask [N] (0/1 float32).  Each
    group averages only its participants — effective weights w_i / Σ_g w
    sum to 1 per participating group — and the aggregate is broadcast to
    *every* member (state lives at the group's server, so an absentee
    resumes from the group aggregate when it rejoins).  A zero-participant
    group keeps its members' current params — the entity's last synced
    value — matching the fleet simulator's zero-participant convention
    (nothing is uploaded, so nothing moves).

    Because a completed level leaves every member of a subgroup carrying
    the subgroup's weighted mean, re-averaging the next (coarser) level
    with the same per-client weights reproduces exact hierarchical
    participant-count weighting: Σ_i w_i x_i / Σ_i w_i = Σ_g s_g m_g / Σ_g
    s_g.  With w ≡ 1 the arithmetic (f32 multiply-by-one, same sum
    reduction, divide by the group size) is bit-identical to
    ``_group_mean``.

    ``keep`` (optional pytree matching ``tree``) supplies the fallback
    values a zero-participant group retains.  It defaults to ``tree``
    itself, which is right whenever the input *is* the clients' current
    state — but a compressed fed-server upload must pass the
    pre-compression params here, otherwise a silent group "keeps" a
    lossy-coded copy it never uploaded (DESIGN.md §9/§12).

    The sharded engine (``core.sharded``) reproduces these weights with
    per-shard partial sums + ``lax.psum``; a zero-participant group's
    keep-fallback becomes a ``where`` against the gathered mask.  Note the
    group mean is NOT idempotent on already-averaged rows when weights
    differ, which is why the deferred fed-server replay in
    ``core.async_agg.fed_level_apply`` re-derives the level from a
    snapshot delta instead of calling ``synchronize`` twice (§17).
    """
    w = w.astype(jnp.float32)
    if keep is None:
        keep = tree

    def f(x, k):
        n = x.shape[0]
        g = x.reshape(groups, n // groups, *x.shape[1:])
        gk = k.reshape(groups, n // groups, *x.shape[1:])
        wg = w.reshape(groups, n // groups)
        ww = wg.reshape(wg.shape + (1,) * (g.ndim - 2))
        s = jnp.sum(wg, axis=1).reshape((groups,) + (1,) * (g.ndim - 1))
        tot = jnp.sum(
            g * ww.astype(g.dtype), axis=1, keepdims=True, dtype=jnp.float32
        )
        m = (tot / jnp.maximum(s, 1.0)).astype(x.dtype)
        out = jnp.where(s > 0.0, jnp.broadcast_to(m, g.shape), gk)
        return out.reshape(x.shape)

    return jax.tree.map(f, tree, keep)


def synchronize(
    params: Params,
    plan: TierPlan,
    step: jax.Array,
    *,
    fed_round=None,
    compress_fn=None,
    mask=None,
    guard: Optional[GuardSpec] = None,
) -> Params:
    """Apply the per-tier aggregation schedule at round ``step`` (post-update).

    Rounds are 1-indexed in the paper; we sync when (step+1) % I == 0 so that
    interval I=k aggregates after every k-th update.

    ``fed_round`` specializes the interval-gated (I_m > 1) fed-server levels:
      * None — dynamic ``lax.cond`` on the step counter (single compiled
        step; both branches live in the HLO, so the hot path carries the
        fed-server collectives even though they amortize 1/I_m at runtime);
      * bool or per-tier sequence of bools — compile the round variant where
        tier m's fed-server level is applied iff ``fed_round[m]``. The
        production dispatch picks the variant ``tuple((t+1) % I_m == 0)``
        per round — at most 2^(M-1) compiled steps, typically 2-3 since
        optimal intervals nest (paper's Insight after Eq. 37).
    Specializing step functions instead of branching in-graph is the
    production path (see EXPERIMENTS.md sect. Perf).

    ``compress_fn`` (leaf → leaf, e.g. a vmapped ``Compressor.transform``)
    models the lossy fed-server wire of DESIGN.md §9: it is applied to the
    uploaded replicas immediately before the *fed-server* mean of tiers
    m < M−1 with more than one entity — exactly the exchanges the latency
    model prices with ``model_ratio`` — and never to the unpriced local
    entity syncs (Eq. 3) or the single-entity top tier.

    ``mask`` ([N] bool/float, 1 = the client participated this round)
    switches every level to the participation-weighted mean of
    ``_group_mean_masked`` (DESIGN.md §12): participants are averaged
    with weight 1/|group participants|, the aggregate is broadcast to all
    members, and a zero-participant group keeps its last synced params.
    ``mask=None`` is the exact full-participation path (and an all-ones
    mask is bit-identical to it, pinned in ``tests/test_participation.py``).

    ``guard`` (a ``GuardSpec``) turns on the corrupt-upload quarantine of
    DESIGN.md §16: client health (finite check + norm blow-up) is computed
    once on the incoming tree, non-finite rows are sanitized to zero, and
    the health mask multiplies into ``mask`` — an unhealthy client becomes
    a zero-participant (§12 semantics: excluded from every mean, healed by
    the participating group's broadcast).  On an all-healthy round the
    sanitized tree is bit-identical to the input and the health mask is
    all-ones, so the result collapses bit-for-bit onto the unguarded path.

    Two other call sites reuse these exact level semantics (DESIGN.md §17):
    ``core.sharded.build_sharded_train_step_a`` lowers every level onto a
    device mesh under ``shard_map`` (same schedule, same mask/compression/
    guard gating, cross-device means via ``lax`` collectives), and
    ``core.async_agg.fed_level_apply`` replays a single tier's deferred
    fed-server level from a snapshot — deliberately NOT by re-invoking
    ``synchronize``, because the group mean is not bit-idempotent.
    """
    if guard is not None:
        health, params = guard_health(params, plan.num_clients, guard)
        mask = health if mask is None else mask.astype(jnp.float32) * health
    parts = tier_subtrees(params, plan)
    if fed_round is not None and not isinstance(fed_round, (tuple, list)):
        fed_round = (bool(fed_round),) * plan.M
    out_parts: List[Params] = []
    for m, part in enumerate(parts):
        levels = plan.levels(m)
        for li, (groups, interval) in enumerate(levels):
            # the fed-server level is the last one of a non-top tier; it is
            # a priced wire only when several entities actually exchange.
            fed = (
                compress_fn is not None
                and m < plan.M - 1
                and li == len(levels) - 1
                and plan.entities[m] > 1
            )

            def level_mean(p, groups=groups, fed=fed):
                # keep the *pre-compression* tree as the zero-participant
                # fallback: a silent group uploads nothing, so it must
                # retain its last synced params, not a lossy-coded copy.
                original = p
                if fed:
                    p = jax.tree.map(compress_fn, p)
                if mask is not None:
                    return _group_mean_masked(p, groups, mask, keep=original)
                return _group_mean(p, groups)

            if interval <= 1:
                part = level_mean(part)
            elif fed_round is None:
                do = (step + 1) % interval == 0
                part = lax.cond(do, level_mean, lambda p: p, part)
            elif fed_round[m]:
                part = level_mean(part)
            # fed_round[m] is False -> skip tier m's fed-server level
        out_parts.append(part)
    return combine_tiers(out_parts, params)


# --------------------------------------------------------------------------- #
# ragged synchronization: per-class cut assignments (DESIGN.md §14)
# --------------------------------------------------------------------------- #


def class_tier_members(
    n_units: int,
    class_cuts: Sequence[Sequence[int]],
    class_of: Sequence[int],
) -> List[jnp.ndarray]:
    """Per-tier membership matrices ``[M][N, U]`` (float32 0/1).

    ``members[m][i, u] == 1`` iff unit u lies in tier m *for client i's
    class* — clients in different classes disagree on which units are
    client-side, which is exactly the raggedness ``ragged_synchronize``
    aggregates over.  Every (client, unit) pair belongs to exactly one
    tier, so the per-tier member matrices partition the unit axis per
    client.
    """
    class_of = [int(c) for c in class_of]
    M = len(class_cuts[0]) + 1
    C = len(class_cuts)
    bounds = [[0, *[int(x) for x in cc], n_units] for cc in class_cuts]
    u = jnp.arange(n_units)
    out: List[jnp.ndarray] = []
    for m in range(M):
        rows = []
        for c in range(C):
            lo, hi = bounds[c][m], bounds[c][m + 1]
            rows.append(((u >= lo) & (u < hi)).astype(jnp.float32))
        table = jnp.stack(rows)  # [C, U]
        out.append(table[jnp.asarray(class_of)])  # [N, U]
    return out


def _ragged_units_mean(units, keep, mem, groups, mask):
    """Per-unit member-weighted group mean over a units container.

    ``mem`` [N, U] gates both the average (a unit's tier-m mean only
    reads replicas from clients whose class holds it in tier m) and the
    receive side (non-members keep their value — that unit is synced by
    its own tier's levels).  With ``mem`` all-ones the arithmetic
    (f32 multiply-by-weight, same sum reduction, divide by
    ``max(count, 1)``) is bit-identical to ``_group_mean_masked`` — and
    through it to ``_group_mean`` when ``mask`` is None — which is what
    collapses identical-class ragged sync onto ``synchronize`` exactly.
    """
    cw = mem if mask is None else mem * mask.astype(jnp.float32)[:, None]

    def one_unit(x, k, m_col, w_col):
        # x, k: [N, ...]; m_col/w_col: [N]
        n = x.shape[0]
        g = x.reshape(groups, n // groups, *x.shape[1:])
        gk = k.reshape(g.shape)
        wg = w_col.reshape(groups, n // groups)
        mg = m_col.reshape(groups, n // groups)
        ww = wg.reshape(wg.shape + (1,) * (g.ndim - 2))
        mm = mg.reshape(ww.shape)
        s = jnp.sum(wg, axis=1).reshape((groups,) + (1,) * (g.ndim - 1))
        tot = jnp.sum(
            g * ww.astype(g.dtype), axis=1, keepdims=True, dtype=jnp.float32
        )
        mean = (tot / jnp.maximum(s, 1.0)).astype(x.dtype)
        out = jnp.where(
            (mm > 0.0) & (s > 0.0), jnp.broadcast_to(mean, g.shape), gk
        )
        return out.reshape(x.shape)

    if isinstance(units, (list, tuple)):
        return [
            jax.tree.map(
                lambda x, k, u=u: one_unit(x, k, mem[:, u], cw[:, u]),
                unit,
                keep[u],
            )
            for u, unit in enumerate(units)
        ]
    if isinstance(units, dict) and set(units) == {"enc", "dec"}:
        raise NotImplementedError(
            "ragged per-class sync over enc/dec unit stacks is not "
            "implemented — use a flat unit stack or per-unit list"
        )

    # stacked leaves [N, U, ...]: broadcast the member/weight columns
    def f(x, k):
        n, U = x.shape[0], x.shape[1]
        g = x.reshape(groups, n // groups, U, *x.shape[2:])
        gk = k.reshape(g.shape)
        wg = cw.reshape(groups, n // groups, U)
        mg = mem.reshape(groups, n // groups, U)
        ww = wg.reshape(wg.shape + (1,) * (g.ndim - 3))
        mm = mg.reshape(ww.shape)
        s = jnp.sum(ww, axis=1, keepdims=True)  # [G, 1, U, 1...]
        tot = jnp.sum(
            g * ww.astype(g.dtype), axis=1, keepdims=True, dtype=jnp.float32
        )
        mean = (tot / jnp.maximum(s, 1.0)).astype(x.dtype)
        out = jnp.where(
            (mm > 0.0) & (s > 0.0), jnp.broadcast_to(mean, g.shape), gk
        )
        return out.reshape(x.shape)

    return jax.tree.map(f, units, keep)


def ragged_synchronize(
    params: Params,
    plan: TierPlan,
    members: Sequence[jax.Array],
    step: jax.Array,
    *,
    fed_round=None,
    compress_fn=None,
    mask=None,
    guard: Optional[GuardSpec] = None,
) -> Params:
    """``synchronize`` for per-class cut assignments (DESIGN.md §14).

    ``members`` is the ``class_tier_members`` output: tier m's levels
    average unit u only over the clients whose class holds u in tier m,
    and only those clients receive the broadcast — the rest keep their
    replica untouched for their own tier's schedule.  The entity topology,
    interval gating, ``fed_round`` specialization, fed-wire compression
    and participation ``mask`` semantics are exactly those of
    ``synchronize`` (including the zero-participant keep-last fallback
    and the pre-compression ``keep`` tree).  The frontend always joins
    tier 0 and the head tier M−1, for every class.

    Unlike ``synchronize`` this operates on the *unsliced* params: the
    unit → tier map varies per client, so there is no common
    ``tier_subtrees`` partition to slice.  When every class holds the
    same cuts the member matrices are exactly the plan's tier slices and
    the result is bit-identical to ``synchronize``.

    ``guard`` applies the same quarantine as ``synchronize``: health is
    computed once on the unsliced tree and folded into ``mask``.
    """
    if guard is not None:
        health, params = guard_health(params, plan.num_clients, guard)
        mask = health if mask is None else mask.astype(jnp.float32) * health
    if isinstance(params["units"], dict) and set(params["units"]) == {
        "enc",
        "dec",
    }:
        raise NotImplementedError(
            "ragged per-class sync over enc/dec unit stacks is not "
            "implemented"
        )
    if len(members) != plan.M:
        raise ValueError(
            f"need one member matrix per tier: got {len(members)} for "
            f"M={plan.M}"
        )
    if fed_round is not None and not isinstance(fed_round, (tuple, list)):
        fed_round = (bool(fed_round),) * plan.M

    out = params
    for m in range(plan.M):
        mem = members[m]
        levels = plan.levels(m)
        for li, (groups, interval) in enumerate(levels):
            fed = (
                compress_fn is not None
                and m < plan.M - 1
                and li == len(levels) - 1
                and plan.entities[m] > 1
            )

            def level_fn(
                p,
                groups=groups,
                fed=fed,
                mem=mem,
                front=(m == 0),
                head=(m == plan.M - 1),
            ):
                original = p
                if fed:
                    p = jax.tree.map(compress_fn, p)
                new = dict(original)
                new["units"] = _ragged_units_mean(
                    p["units"], original["units"], mem, groups, mask
                )
                for name, join in (("frontend", front), ("head", head)):
                    if not join:
                        continue
                    if mask is not None:
                        new[name] = _group_mean_masked(
                            p[name], groups, mask, keep=original[name]
                        )
                    else:
                        new[name] = _group_mean(p[name], groups)
                return new

            if interval <= 1:
                out = level_fn(out)
            elif fed_round is None:
                do = (step + 1) % interval == 0
                out = lax.cond(do, level_fn, lambda p: p, out)
            elif fed_round[m]:
                out = level_fn(out)
    return out


def default_plan(
    n_units: int,
    num_clients: int = 16,
    cuts: Tuple[int, ...] = None,
    intervals: Tuple[int, ...] = None,
    entities: Tuple[int, ...] = None,
    num_pods: int = 1,
    pod_interval: int = 0,
) -> TierPlan:
    """Paper-style 3-tier client-edge-cloud plan with sensible defaults."""
    if cuts is None:
        c1 = max(1, n_units // 5)
        c2 = max(c1, n_units // 2)
        cuts = (c1, c2)
    if intervals is None:
        intervals = (8, 4, 1)
    if entities is None:
        entities = (num_clients, max(1, num_clients // 4), 1)
    return TierPlan(
        n_units=n_units,
        num_clients=num_clients,
        cuts=tuple(cuts),
        intervals=tuple(intervals),
        entities=tuple(entities),
        num_pods=num_pods,
        pod_interval=pod_interval,
    )
