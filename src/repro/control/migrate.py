"""Engine state migration when the schedule moves mid-run.

A control switch changes the cut vector, which changes which tier — and
therefore which aggregation entity — owns each unit.  Training state must
be re-partitioned without losing optimizer moments:

* **Engine A** (client-stacked full models): leaf shapes are
  cut-independent, so migration is a *consistency* operation — apply the
  new plan's entity-level group means once (the Eq. 3 sync of the new
  plan), so every entity's replicas agree before training resumes.  A
  unit moving to a finer tier (entity → per-client) keeps each client's
  replica untouched; a unit moving to a coarser tier adopts its new
  entity's client-mean.  Momentum / Adam moments are client-stacked like
  params and migrate through the same means, mirroring the engine's
  ``sync_opt_state`` schedule.

* **Engine B** (per-tier entity stacks): leaf shapes *are* cut-dependent.
  Migration materializes the client-stacked view (``engine_b_to_full``'s
  entity repeat), re-slices the unit ranges under the new plan, and
  reduces each new tier back to its entity stack by the client-weighted
  mean — coarsening averages the old entity copies, refining replicates.

Both directions preserve the global client-mean iterate (means of means
over uniform groups), which is what lets the piecewise Theorem-1 bound
telescope f across switch points (``control.bound``).  The arithmetic is
float32 group-mean (``tiers._group_mean``), so values that merely stay
put are preserved up to mean-roundtrip rounding, not bitwise.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.engine import TrainState, engine_b_to_full
from ..core.tiers import TierPlan, _group_mean, combine_tiers, tier_subtrees
from ..optim import Optimizer

Params = Any


def migrate_params_a(params: Params, new_plan: TierPlan) -> Params:
    """Make a client-stacked tree consistent with ``new_plan``'s entities."""
    parts = tier_subtrees(params, new_plan)
    out = []
    for m, part in enumerate(parts):
        J = new_plan.entities[m]
        if J < new_plan.num_clients:
            part = _group_mean(part, J)
        out.append(part)
    return combine_tiers(out, params)


def _migrate_opt(opt_tree, opt: Optimizer, migrate_fn) -> Any:
    """Apply a params-migration to the optimizer moments (sgd: no state;
    momentum: the whole state is params-shaped; adam: m and v are)."""
    if not jax.tree.leaves(opt_tree):
        return opt_tree
    if opt.name == "momentum":
        return migrate_fn(opt_tree)
    if opt.name == "adam":
        new = dict(opt_tree)
        new["m"] = migrate_fn(new["m"])
        new["v"] = migrate_fn(new["v"])
        return new
    return opt_tree


def migrate_state_a(
    state: TrainState, new_plan: TierPlan, opt: Optimizer
) -> TrainState:
    """Engine-A state under a new tier plan (same leaf shapes, re-grouped)."""
    return TrainState(
        params=migrate_params_a(state.params, new_plan),
        opt_state=_migrate_opt(
            state.opt_state, opt, lambda t: migrate_params_a(t, new_plan)
        ),
        step=state.step,
    )


def _entity_stack(part: Params, J: int, N: int) -> Params:
    """Reduce a client-stacked tier subtree to its [J, ...] entity stack by
    the client-mean (float32, mirroring ``tiers._group_mean``)."""
    per = N // J

    def f(x):
        g = x.reshape(J, per, *x.shape[1:])
        return jnp.mean(g, axis=1, dtype=jnp.float32).astype(x.dtype)

    return jax.tree.map(f, part)


def migrate_params_b(
    model, tier_params, old_plan: TierPlan, new_plan: TierPlan
):
    """Re-partition Engine-B tier stacks from ``old_plan`` to ``new_plan``."""
    full = engine_b_to_full(model, old_plan, tier_params)
    parts = tier_subtrees(full, new_plan)
    return [
        _entity_stack(part, new_plan.entities[m], new_plan.num_clients)
        for m, part in enumerate(parts)
    ]


def migrate_state_b(
    state: TrainState, model, old_plan: TierPlan, new_plan: TierPlan,
    opt: Optimizer,
) -> TrainState:
    """Engine-B state under a new tier plan (re-sliced entity stacks)."""
    fn = lambda t: migrate_params_b(model, t, old_plan, new_plan)
    return TrainState(
        params=fn(state.params),
        opt_state=_migrate_opt(state.opt_state, opt, fn),
        step=state.step,
    )


def migrate_state(
    state: TrainState,
    new_plan: TierPlan,
    opt: Optimizer,
    engine: str = "a",
    model=None,
    old_plan: Optional[TierPlan] = None,
) -> TrainState:
    """Engine-dispatching migration (the controller's switch hook)."""
    if engine == "a":
        return migrate_state_a(state, new_plan, opt)
    if old_plan is None or model is None:
        raise ValueError("engine-b migration needs model and old_plan")
    return migrate_state_b(state, model, old_plan, new_plan, opt)


def resume_with_migration(
    path: str, template: Params, plan: TierPlan
) -> Tuple[Params, int, dict]:
    """Load an Engine-A checkpoint saved under a possibly different cut
    vector and migrate the tier assignment to ``plan`` (the loud-failure
    alternative is ``load_checkpoint(..., expect_cuts=plan.cuts)``)."""
    from ..checkpoint import load_checkpoint

    tree, step, meta = load_checkpoint(path, template)
    saved = meta.get("cuts")
    if saved is not None and tuple(int(c) for c in saved) != tuple(plan.cuts):
        tree = migrate_params_a(tree, plan)
    return tree, step, meta
