"""npz checkpoint roundtrip + failure modes."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import check_schedule_meta, load_checkpoint, save_checkpoint


def tree(key=0):
    k = jax.random.PRNGKey(key)
    return {
        "frontend": {"embed": jax.random.normal(k, (4, 8))},
        "units": {"w": jnp.arange(24.0).reshape(2, 3, 4)},
        "list": [jnp.ones((2,)), jnp.zeros((3,), jnp.int32)],
    }


def test_roundtrip(tmp_path):
    t = tree()
    p = str(tmp_path / "ck.npz")
    save_checkpoint(p, t, step=42, meta={"cuts": [3, 8], "intervals": [140, 20, 1]})
    t2, step, meta = load_checkpoint(p, tree(key=1))
    assert step == 42
    assert meta == {"cuts": [3, 8], "intervals": [140, 20, 1]}
    for a, b in zip(jax.tree.leaves(t2), jax.tree.leaves(t)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_missing_leaf_fails(tmp_path):
    p = str(tmp_path / "ck.npz")
    save_checkpoint(p, {"a": jnp.ones(3)}, step=1)
    with pytest.raises(KeyError):
        load_checkpoint(p, {"a": jnp.ones(3), "b": jnp.ones(2)})


def test_shape_mismatch_fails(tmp_path):
    p = str(tmp_path / "ck.npz")
    save_checkpoint(p, {"a": jnp.ones(3)}, step=1)
    with pytest.raises(ValueError):
        load_checkpoint(p, {"a": jnp.ones(4)})


# --------------------------------------------------------------------------- #
# schedule-metadata verification (resume under a moved cut vector)
# --------------------------------------------------------------------------- #


def test_resume_with_matching_schedule_loads(tmp_path):
    t = tree()
    p = str(tmp_path / "ck.npz")
    save_checkpoint(p, t, step=7, meta={"cuts": [3, 8], "intervals": [4, 2, 1]})
    t2, step, meta = load_checkpoint(
        p, tree(key=1), expect_cuts=(3, 8), expect_intervals=(4, 2, 1)
    )
    assert step == 7 and meta["cuts"] == [3, 8]
    for a, b in zip(jax.tree.leaves(t2), jax.tree.leaves(t)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_resume_with_changed_cuts_fails_loudly(tmp_path):
    """A cut vector that moved between save and resume must not load —
    Engine A leaves are shape-compatible across cuts, so only the
    metadata check can catch the silent tier mis-assignment."""
    p = str(tmp_path / "ck.npz")
    save_checkpoint(p, tree(), step=3, meta={"cuts": [3, 8], "intervals": [4, 2, 1]})
    with pytest.raises(ValueError, match="migrate the tier assignment"):
        load_checkpoint(p, tree(), expect_cuts=(2, 7))
    with pytest.raises(ValueError, match="migrate the tier assignment"):
        load_checkpoint(
            p, tree(), expect_cuts=(3, 8), expect_intervals=(8, 2, 1)
        )


def test_expectation_against_missing_meta_fails(tmp_path):
    p = str(tmp_path / "ck.npz")
    save_checkpoint(p, tree(), step=1)  # no schedule metadata at all
    with pytest.raises(ValueError, match="no 'cuts' metadata"):
        load_checkpoint(p, tree(), expect_cuts=(3, 8))
    # without the expectation the same checkpoint loads fine
    _, step, _ = load_checkpoint(p, tree())
    assert step == 1


def test_check_schedule_meta_direct():
    check_schedule_meta({"cuts": [3, 8]}, expect_cuts=(3, 8))
    check_schedule_meta({"cuts": [3, 8]})  # no expectation -> no-op
    with pytest.raises(ValueError, match="cuts"):
        check_schedule_meta({"cuts": [3, 8]}, expect_cuts=(1, 2))


def test_shape_mismatch_hint_mentions_expect_cuts(tmp_path):
    """When schedule metadata is present, a shape mismatch points the user
    at the expect_cuts= guard."""
    p = str(tmp_path / "ck.npz")
    save_checkpoint(p, {"a": jnp.ones(3)}, step=1, meta={"cuts": [2, 5]})
    with pytest.raises(ValueError, match="pass expect_cuts="):
        load_checkpoint(p, {"a": jnp.ones(4)})


def test_atomic_overwrite(tmp_path):
    p = str(tmp_path / "ck.npz")
    save_checkpoint(p, {"a": jnp.zeros(2)}, step=1)
    save_checkpoint(p, {"a": jnp.ones(2)}, step=2)
    t, step, _ = load_checkpoint(p, {"a": jnp.zeros(2)})
    assert step == 2 and np.all(np.asarray(t["a"]) == 1)
    assert not [f for f in os.listdir(tmp_path) if f.endswith(".tmp.npz")]


def test_bare_filename_save_and_overwrite(tmp_path, monkeypatch):
    """A path with no directory component must save (and atomically
    overwrite) — the durability fsync opens the *containing directory*,
    and ``os.path.dirname("ck.npz")`` is '' (not an openable path)."""
    monkeypatch.chdir(tmp_path)
    save_checkpoint("ck.npz", {"a": jnp.zeros(2)}, step=1)
    save_checkpoint("ck.npz", {"a": jnp.ones(2)}, step=2)
    t, step, _ = load_checkpoint("ck.npz", {"a": jnp.zeros(2)})
    assert step == 2 and np.all(np.asarray(t["a"]) == 1)
    assert not [f for f in os.listdir(".") if f.endswith(".tmp.npz")]


def test_interrupted_save_leaves_previous_checkpoint_intact(
    tmp_path, monkeypatch
):
    """A crash mid-save (DESIGN.md §16) must never tear the installed file:
    the payload is written and fsynced to a same-directory temp file first,
    so an interrupt before ``os.replace`` leaves the previous checkpoint
    byte-for-byte intact and loadable."""
    import repro.checkpoint.npz as npz

    p = str(tmp_path / "ck.npz")
    save_checkpoint(p, {"a": jnp.zeros(2)}, step=1)
    before = open(p, "rb").read()

    real_savez = np.savez

    def torn_savez(f, **payload):
        real_savez(f, **payload)   # bytes hit the TEMP file...
        raise OSError("simulated crash mid-save")  # ...then the power dies

    monkeypatch.setattr(npz.np, "savez", torn_savez)
    with pytest.raises(OSError, match="simulated crash"):
        save_checkpoint(p, {"a": jnp.ones(2)}, step=2)
    monkeypatch.undo()

    # previous checkpoint untouched, loadable, and no temp litter remains
    assert open(p, "rb").read() == before
    t, step, _ = load_checkpoint(p, {"a": jnp.zeros(2)})
    assert step == 1 and np.all(np.asarray(t["a"]) == 0)
    assert not [f for f in os.listdir(tmp_path) if f.endswith(".tmp.npz")]


def test_interrupted_fsync_keeps_previous_checkpoint(tmp_path, monkeypatch):
    """Same contract one step later: dying inside the durability fsync
    (after the payload write, before/at the rename barrier) still leaves
    the previously-installed checkpoint intact."""
    import repro.checkpoint.npz as npz

    p = str(tmp_path / "ck.npz")
    save_checkpoint(p, {"a": jnp.zeros(2)}, step=1)
    before = open(p, "rb").read()

    def dead_fsync(fd):
        raise OSError("simulated crash in fsync")

    monkeypatch.setattr(npz.os, "fsync", dead_fsync)
    with pytest.raises(OSError, match="simulated crash"):
        save_checkpoint(p, {"a": jnp.ones(2)}, step=2)
    monkeypatch.undo()

    assert open(p, "rb").read() == before
    t, step, _ = load_checkpoint(p, {"a": jnp.zeros(2)})
    assert step == 1 and np.all(np.asarray(t["a"]) == 0)
    assert not [f for f in os.listdir(tmp_path) if f.endswith(".tmp.npz")]
