"""Robust system optimization: trace quantiles in place of point estimates.

``TraceLatency`` implements the ``LatencyModel`` protocol of
``repro.core.problem``: T_S(μ) and T_{m,A}(μ) become the q-quantile (p50 =
typical, p95 = straggler-robust) of the per-round latencies a scenario
trace produces for that cut vector.  Attaching it to an ``HsflProblem``
(``robust_problem``) leaves the convergence side of Θ' untouched, so the
existing Proposition-1 MA solver, Dinkelbach MS solver, and BCD loop
optimize (I, μ) against the empirical regime with no changes — on the
homogeneous-paper scenario the quantiles collapse to exactly Eq. (17)/(18)
and the robust problem *is* the nominal one.

This is the trace half of the composition order ``repro.api.build`` owns:
compression lands on the base problem first, and ``robust_problem``
re-prices the trace over that same wire — declare both in one
``ExperimentSpec`` and the ordering is resolved for you.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from ..core.problem import HsflProblem
from .fleet import simulate_lattice_rounds, simulate_rounds
from .scenarios import SystemTrace


class TraceLatency:
    """q-quantile pricing of the latency terms over a ``SystemTrace``.

    Per-round latencies are simulated once per cut vector through the
    vectorized fleet path and cached — the BCD/Dinkelbach solvers revisit
    the same lattice points many times.

    The batched solver core (``core.batched.BatchedEvaluator``) consumes
    the ``split_T_batch``/``agg_T_batch`` lattice methods instead: one
    ``[K, N]``-per-round sweep prices every cut vector at once, and
    ``np.quantile`` along the rounds axis is bit-identical to the scalar
    per-cut quantile — so robust solves return the same optima on every
    backend (DESIGN.md §11).
    """

    def __init__(
        self,
        trace: SystemTrace,
        quantile: float = 0.95,
        rounds: int = None,
        backend: str = "numpy",
    ):
        self.trace = trace
        self.quantile = float(quantile)
        self.rounds = trace.rounds if rounds is None else min(rounds, trace.rounds)
        self.backend = backend
        self._cache: Dict[Tuple[int, ...], Tuple[np.ndarray, np.ndarray]] = {}
        self._lattice_cache: Optional[
            Tuple[bytes, Tuple[np.ndarray, np.ndarray]]
        ] = None

    def per_round(self, cuts: Sequence[int]) -> Tuple[np.ndarray, np.ndarray]:
        """(split [R], agg [M-1, R]) for this cut vector, cached."""
        key = tuple(int(c) for c in cuts)
        hit = self._cache.get(key)
        if hit is None:
            res = simulate_rounds(
                self.trace, key, rounds=self.rounds, backend=self.backend
            )
            hit = self._cache[key] = (res.split, res.agg)
        return hit

    def per_round_lattice(
        self, lattice: np.ndarray
    ) -> Tuple[np.ndarray, np.ndarray]:
        """(split [K, R], agg [K, M-1, R]) for a whole cut lattice, cached
        (BCD builds one evaluator per problem but may rebuild after
        ``with_compression``; the trace sweep is the expensive part)."""
        key = lattice.tobytes()
        if self._lattice_cache is not None and self._lattice_cache[0] == key:
            return self._lattice_cache[1]
        res = simulate_lattice_rounds(
            self.trace, lattice, rounds=self.rounds, backend=self.backend
        )
        self._lattice_cache = (key, res)
        return res

    # ------------------------------------------------------------------ #
    # LatencyModel protocol
    # ------------------------------------------------------------------ #
    def split_T(self, cuts: Sequence[int]) -> float:
        split, _ = self.per_round(cuts)
        return float(np.quantile(split, self.quantile))

    def agg_T(self, cuts: Sequence[int], m: int) -> float:
        _, agg = self.per_round(cuts)
        return float(np.quantile(agg[m], self.quantile))

    # ------------------------------------------------------------------ #
    # batched lattice protocol (consumed by core.batched.BatchedEvaluator)
    # ------------------------------------------------------------------ #
    def split_T_batch(self, lattice: np.ndarray) -> np.ndarray:
        """[K] q-quantile T_S per lattice row (== ``split_T`` per row)."""
        split, _ = self.per_round_lattice(lattice)
        return np.quantile(split, self.quantile, axis=1)

    def agg_T_batch(self, lattice: np.ndarray) -> np.ndarray:
        """[K, M-1] q-quantile T_{m,A} per row (== ``agg_T`` per row)."""
        _, agg = self.per_round_lattice(lattice)
        return np.quantile(agg, self.quantile, axis=2)


def robust_problem(
    problem: HsflProblem,
    trace: SystemTrace,
    quantile: float = 0.95,
    rounds: int = None,
    backend: str = "numpy",
) -> HsflProblem:
    """The same MA+MS problem, priced at the trace's q-quantile latencies.

    A compressed problem stays compressed: when the problem carries a
    ``CompressionSpec`` and the trace does not, the trace is re-priced over
    the same wire, so the quantiles the solvers consume reflect the ratio
    (ω keeps entering through ``problem.constants()`` as always).  A trace
    already priced over a *different* wire is a configuration error —
    quantiles and ω would describe two different codecs — and raises.
    """
    if problem.compression is not None and trace.compression is None:
        trace = trace.with_compression(problem.compression)
    elif trace.compression != problem.compression:
        raise ValueError(
            "trace and problem carry different CompressionSpecs "
            f"({trace.compression} vs {problem.compression}); price both "
            "over one wire (build the trace uncompressed, or attach the "
            "same spec to both)"
        )
    model = TraceLatency(trace, quantile=quantile, rounds=rounds, backend=backend)
    return dataclasses.replace(problem, latency_model=model)
