"""Vectorized fleet fast path: whole-round advancement for N clients at once.

Where ``events.py`` schedules one event per client per stage, this path
treats the round as pure array arithmetic: per-client compute rates, link
bandwidths, and availability live in ``[N]`` float64 arrays, a round is a
fixed chain of elementwise divide/accumulate ops, and the round latency is
one masked reduction.  A 10⁶-client round is ~10 array ops, which is what
lets ``benchmarks/run.py sim_scale`` sweep to a million clients.

Bit-exactness contract: the fast path consumes the *same* per-stage
duration arrays as the event core (``events.round_stage_durations``) and
accumulates them in the same canonical order, so for any trace and cut
vector ``simulate_rounds`` and ``events.simulate`` agree to the last bit —
``tests/test_sim.py`` enforces this on every scenario.  The JAX backend
runs under ``jax.experimental.enable_x64`` (float64 elementwise IEEE ops
match NumPy exactly); straggler quantiles are ``jnp`` reductions.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import numpy as np

from .events import fires, round_agg_phases, round_stage_durations
from .scenarios import SystemTrace

try:  # CPU jax is in the image; keep the subsystem importable without it
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    _HAS_JAX = True
except Exception:  # pragma: no cover - exercised only on jax-less installs
    _HAS_JAX = False


@dataclass(frozen=True)
class FleetRound:
    split: float                 # max over participants
    per_client: np.ndarray       # [N] finish times (NaN when absent)
    agg: np.ndarray              # [M-1] priced tier-sync latency
    n_participants: int


@dataclass(frozen=True)
class FleetResult:
    split: np.ndarray            # [R]
    agg: np.ndarray              # [M-1, R] priced every round
    fired: np.ndarray            # [M-1, R] sync schedule
    total: np.ndarray            # [R]
    participants: np.ndarray     # [R]

    def straggler_quantiles(self, qs=(0.5, 0.95, 0.99)) -> np.ndarray:
        """Quantiles of per-round *round* latency (the straggler-shaped tail)."""
        return quantiles(self.total, qs)


def _resolve_backend(backend: str) -> str:
    if backend == "auto":
        return "jax" if _HAS_JAX else "numpy"
    if backend == "jax" and not _HAS_JAX:
        raise RuntimeError("jax backend requested but jax is not importable")
    return backend


def quantiles(x: np.ndarray, qs: Sequence[float], backend: str = "auto") -> np.ndarray:
    """Quantile reduction (jnp when available — the sim_scale hot path)."""
    if _resolve_backend(backend) == "jax":
        with enable_x64():
            return np.asarray(jnp.quantile(jnp.asarray(x), jnp.asarray(list(qs))))
    return np.quantile(np.asarray(x), list(qs))


def round_latency(
    trace: SystemTrace, r: int, cuts: Sequence[int], backend: str = "auto"
) -> FleetRound:
    """Advance one whole round for all N clients at once."""
    be = _resolve_backend(backend)
    state = trace.round_state(r)
    avail = state.available
    n_part = int(np.count_nonzero(avail))
    _, durs = round_stage_durations(trace, r, cuts)
    M = trace.system.M

    if be == "jax":
        with enable_x64():
            t = jnp.zeros(trace.system.num_clients)
            for d in durs:
                t = t + jnp.asarray(d)
            masked = jnp.where(jnp.asarray(avail), t, -jnp.inf)
            split = float(jnp.max(masked)) if n_part else 0.0
            per_client = np.asarray(
                jnp.where(jnp.asarray(avail), t, jnp.nan)
            )
    else:
        t = np.zeros(trace.system.num_clients)
        for d in durs:
            t = t + d
        split = float(np.max(t[avail])) if n_part else 0.0
        per_client = np.where(avail, t, np.nan)

    agg = np.zeros(M - 1)
    for m in range(M - 1):
        phases = round_agg_phases(trace, r, cuts, m)
        if phases is None:
            continue
        up, down = phases
        if be == "jax":
            with enable_x64():
                agg[m] = float(jnp.max(jnp.asarray(up))) + float(
                    jnp.max(jnp.asarray(down))
                )
        else:
            agg[m] = float(np.max(up)) + float(np.max(down))
    return FleetRound(split, per_client, agg, n_part)


def simulate_lattice_rounds(
    trace: SystemTrace,
    lattice: np.ndarray,
    rounds: Optional[int] = None,
    backend: str = "auto",
    deadline: Optional[float] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Whole-lattice counterpart of ``simulate_rounds`` for the batched
    solver core: per-round split ``[K, R]`` and per-tier agg ``[K, M-1, R]``
    for every cut row at once (no interval gating — quantile pricing
    consumes raw per-round latencies, exactly like ``TraceLatency``).

    Bit-exactness: consumes the same ``[K, S]`` stage-work tensor the
    nominal batched path uses (``core.batched.split_work_tensor``), prices
    it against the same ``base_rate × round_mult`` products as
    ``events.round_stage_durations``, and accumulates in canonical chain
    order — so row k equals ``simulate_rounds(trace, lattice[k])`` to the
    last bit (pinned in ``tests/test_batched.py``).

    ``deadline`` switches on the partial-participation view (DESIGN.md
    §12): a round's split is capped at the *effective* barrier
    ``d_eff = max(deadline, fastest available finish)`` — the server never
    waits past it, but cannot close a round before at least one upload
    lands — and client-hosted tier syncs run over that round's
    participants: available clients whose chain finished by d_eff, a
    per-lattice-row set since finish times depend on the cut.
    """
    from ..core.batched import model_bits_lattice, split_work_tensor, stage_meta

    be = _resolve_backend(backend)
    R = trace.rounds if rounds is None else min(rounds, trace.rounds)
    system, profile = trace.system, trace.profile
    M = system.M
    K = lattice.shape[0]
    works = split_work_tensor(profile, lattice, trace.compression)   # [K, S]
    lam = model_bits_lattice(profile, lattice, trace.compression)    # [K, M-1]
    meta = stage_meta(M)

    split = np.zeros((K, R))
    agg = np.zeros((K, M - 1, R))
    for r in range(R):
        state = trace.round_state(r)
        split[:, r], agg[:, :, r] = price_lattice_round(
            system, works, lam, meta, state, deadline=deadline, backend=be
        )
    return split, agg


def price_lattice_round(
    system,
    works: np.ndarray,
    lam: np.ndarray,
    meta,
    state,
    deadline: Optional[float] = None,
    backend: str = "auto",
) -> Tuple[np.ndarray, np.ndarray]:
    """Price one round's ``RoundState`` against a whole cut lattice:
    returns (split ``[K]``, agg ``[K, M-1]``).

    The single per-round pricing kernel behind ``simulate_lattice_rounds``
    — also consumed incrementally by the adaptive controller's windowed
    system estimate (``repro.control.window.WindowedLatency``), which is
    what makes the windowed tables bit-identical to ``TraceLatency`` over
    the same states.  ``works``/``lam``/``meta`` are the precomputed
    ``core.batched`` tensors for the lattice.
    """
    be = _resolve_backend(backend)
    M, N, K = system.M, system.num_clients, works.shape[0]
    split_col = np.zeros(K)
    agg_col = np.zeros((K, M - 1))
    rates = []
    for kind, idx in meta:
        if kind in ("compute_fwd", "compute_bwd"):
            rates.append(system.compute[idx] * state.compute_mult[idx])
        elif kind == "uplink":
            rates.append(system.act_up[idx] * state.link_up_mult[idx])
        else:
            rates.append(system.act_down[idx] * state.link_down_mult[idx])
    avail = state.available
    part = None  # [K, N] per-row participants (deadline pricing only)
    if not avail.any():
        pass  # a round with zero participants has split 0 (events.py)
    elif be == "jax":
        with enable_x64():
            t = jnp.zeros((K, N))
            for s, rt in enumerate(rates):
                t = t + jnp.asarray(works[:, s])[:, None] / jnp.asarray(rt)[None, :]
            av = jnp.asarray(avail)
            masked = jnp.where(av, t, -jnp.inf)
            top = jnp.max(masked, axis=1)
            if deadline is not None:
                d_eff = jnp.maximum(
                    deadline, jnp.min(jnp.where(av, t, jnp.inf), axis=1)
                )
                part = np.asarray(av[None, :] & (t <= d_eff[:, None]))
                top = jnp.minimum(d_eff, top)
            split_col[:] = np.asarray(top)
    else:
        t = np.zeros((K, N))
        for s, rt in enumerate(rates):
            t = t + works[:, s][:, None] / rt[None, :]
        top = t[:, avail].max(axis=1)
        if deadline is not None:
            d_eff = np.maximum(deadline, t[:, avail].min(axis=1))
            part = avail[None, :] & (t <= d_eff[:, None])
            top = np.minimum(d_eff, top)
        split_col[:] = top
    for m in range(M - 1):
        if system.entities[m] <= 1:
            continue
        up_rate = system.model_up[m] * state.fed_up_mult[m]
        down_rate = system.model_down[m] * state.fed_down_mult[m]
        up = lam[:, m][:, None] / up_rate[None, :]
        down = lam[:, m][:, None] / down_rate[None, :]
        if up.shape[1] == N:  # clients host tier m: absent ones don't sync
            if part is not None:
                any_part = part.any(axis=1)
                up_m = np.where(part, up, -np.inf).max(axis=1)
                down_m = np.where(part, down, -np.inf).max(axis=1)
                agg_col[:, m] = np.where(any_part, up_m + down_m, 0.0)
                continue
            up, down = up[:, avail], down[:, avail]
            if up.shape[1] == 0:
                continue
        agg_col[:, m] = up.max(axis=1) + down.max(axis=1)
    return split_col, agg_col


def simulate_rounds(
    trace: SystemTrace,
    cuts: Sequence[int],
    intervals: Optional[Sequence[int]] = None,
    rounds: Optional[int] = None,
    backend: str = "auto",
) -> FleetResult:
    """Vectorized counterpart of ``events.simulate`` (same result layout)."""
    R = trace.rounds if rounds is None else min(rounds, trace.rounds)
    M = trace.system.M
    iv = [1] * (M - 1) if intervals is None else list(intervals[: M - 1])

    split = np.zeros(R)
    agg = np.zeros((M - 1, R))
    fired = np.zeros((M - 1, R), dtype=bool)
    total = np.zeros(R)
    participants = np.zeros(R, dtype=int)
    for r in range(R):
        res = round_latency(trace, r, cuts, backend=backend)
        split[r] = res.split
        agg[:, r] = res.agg
        participants[r] = res.n_participants
        tot = res.split
        for m in range(M - 1):
            if fires(r, iv[m]):
                fired[m, r] = True
                tot = tot + res.agg[m]
        total[r] = tot
    return FleetResult(split, agg, fired, total, participants)
