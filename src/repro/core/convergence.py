"""Theorem 1 / Corollary 1 of the paper: the HSFL convergence bound.

All quantities are per-*unit* (our cut granularity) rather than per-layer;
this is exact when cut layers are restricted to unit boundaries, since only
tier-sums of G_l² enter the bound.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Tuple, Union

import numpy as np


@dataclass(frozen=True)
class ParticipationSpec:
    """Analytic view of straggler-aware partial participation (DESIGN.md §12).

    ``q`` holds the per-tier participation rates q_m ∈ (0, 1]: the expected
    fraction of tier-m entities whose round contribution survives the
    deadline (tier 1's entities are the clients themselves, so q_1 is the
    plain client participation rate).  ``deadline`` is the round barrier in
    seconds that produced those rates (None for a rate-only spec).

    Estimated from a fleet trace by ``repro.sim.participation`` and
    attached to an ``HsflProblem``; the Theorem-1 terms inflate by 1/q —
    uniform participant sampling keeps the aggregate unbiased but averages
    over N·q_1 instead of N gradients (σ² term), and a tier whose syncs
    only reach a q_m fraction of its entities accumulates 1/q_m more
    drift between effective aggregations (G² term).  q ≡ 1 recovers the
    paper's full-participation bound exactly.
    """

    q: Tuple[float, ...]               # per-tier rates, len M
    deadline: Optional[float] = None   # seconds (the policy that produced q)

    def __post_init__(self):
        object.__setattr__(self, "q", tuple(float(v) for v in self.q))
        if self.deadline is not None:
            object.__setattr__(self, "deadline", float(self.deadline))

    def validate_for(self, M: int) -> "ParticipationSpec":
        if len(self.q) != M:
            raise ValueError(
                f"ParticipationSpec has {len(self.q)} tier rates for an "
                f"M={M} system"
            )
        for m, v in enumerate(self.q):
            if not (0.0 < v <= 1.0):
                raise ValueError(
                    f"participation rate q_{m+1}={v} outside (0, 1] — a "
                    "tier that never participates has an unbounded variance "
                    "inflation (loosen the deadline)"
                )
        if self.deadline is not None and self.deadline <= 0:
            raise ValueError(f"deadline must be positive: {self.deadline}")
        return self


def participation_rates(
    participation: Union[None, float, Sequence[float], ParticipationSpec],
    M: int,
) -> np.ndarray:
    """Normalize a participation argument to per-tier rates ``[M]``.

    Accepts None (full participation), one scalar rate (uniform across
    tiers), a per-tier sequence, or a ``ParticipationSpec``.
    """
    if participation is None:
        return np.ones(M)
    if isinstance(participation, ParticipationSpec):
        participation.validate_for(M)
        return np.asarray(participation.q, dtype=np.float64)
    if isinstance(participation, (int, float)):
        q = np.full(M, float(participation))
    else:
        q = np.asarray([float(v) for v in participation], dtype=np.float64)
        if len(q) != M:
            raise ValueError(f"need {M} per-tier rates, got {len(q)}")
    if np.any(q <= 0) or np.any(q > 1):
        raise ValueError(f"participation rates must lie in (0, 1]: {q}")
    return q


@dataclass(frozen=True)
class HyperSpec:
    """Optimization constants of the bound (estimated or configured)."""
    gamma: float          # learning rate (paper: 5e-4)
    beta: float           # smoothness constant
    theta0: float         # f(w0) - f*
    num_clients: int      # N
    sigma2: np.ndarray    # per-unit gradient variance bounds   [U]
    G2: np.ndarray        # per-unit second-moment bounds       [U]

    @property
    def sigma2_sum(self) -> float:
        return float(np.sum(self.sigma2))


def tier_G2_sums(G2: np.ndarray, cuts: Sequence[int]) -> np.ndarray:
    """Σ_{l in tier m} G_l² for every tier (M = len(cuts)+1).

    Computed as leading-zero cumsum differences — the canonical tier-sum
    arithmetic shared with the batched lattice core
    (``core.batched.tier_d_lattice``), so scalar and batched d_m agree
    bit-for-bit.
    """
    bounds = [0, *cuts, len(G2)]
    cs = np.concatenate(([0.0], np.cumsum(np.asarray(G2, dtype=np.float64))))
    return np.array(
        [float(cs[bounds[m + 1]] - cs[bounds[m]]) for m in range(len(bounds) - 1)]
    )


def class_weighted_G2_sums(
    G2: np.ndarray,
    class_cuts: Sequence[Sequence[int]],
    weights: Sequence[float],
) -> np.ndarray:
    """Class-weighted tier drift mass d̄_m = Σ_c (n_c/N) · d_m(μ_c).

    Under per-class split points (DESIGN.md §14) the Theorem-1 drift term
    averages each class's tier-m G² mass by its client share: tier m's
    divergence accumulates per client over *that client's* tier-m units,
    and the round averages clients uniformly.  Accumulated in class order
    with one multiply-add per class, so a single class (w = [1.0]) is
    bit-identical to ``tier_G2_sums`` and power-of-two equal shares
    collapse exactly when all classes hold the same cuts.
    """
    d = weights[0] * tier_G2_sums(G2, class_cuts[0])
    for w, cc in zip(weights[1:], class_cuts[1:]):
        d = d + w * tier_G2_sums(G2, cc)
    return d


def staleness_rounds(
    staleness: Union[None, int, Sequence[int]],
    M: int,
) -> np.ndarray:
    """Normalize a staleness argument to per-tier round counts ``[M]``.

    Accepts None (synchronous — every sync applies the round it is
    computed), one scalar bound (uniform across the async tiers), or a
    per-tier sequence s_m ≥ 0.  The top tier's entry is accepted but
    inert: the drift sum excludes tier M exactly as it excludes its
    interval (the cloud sync defines the round boundary).
    """
    if staleness is None:
        return np.zeros(M, dtype=np.int64)
    if isinstance(staleness, (int, np.integer)):
        s = np.full(M, int(staleness), dtype=np.int64)
    else:
        s = np.asarray([int(v) for v in staleness], dtype=np.int64)
        if len(s) != M:
            raise ValueError(f"need {M} per-tier staleness bounds, got {len(s)}")
    if np.any(s < 0):
        raise ValueError(f"staleness bounds must be >= 0: {s}")
    return s


def bound_round_terms(
    hp: HyperSpec,
    intervals: Sequence[int],
    cuts: Sequence[int],
    omega: float = 0.0,
    participation: Union[None, float, Sequence[float], ParticipationSpec] = None,
    dp_sigma2: float = 0.0,
    staleness: Union[None, int, Sequence[int]] = None,
) -> Tuple[float, float]:
    """The two R-independent (per-round) terms of Eq. (8): (variance, drift).

    Factored out of ``theorem1_bound`` so the piecewise composition of the
    bound across mid-run control switches (``repro.control.bound``) prices
    each segment's schedule with the *identical* arithmetic — that is what
    makes the single-segment composition collapse bit-exactly to the
    static bound.

    ``dp_sigma2`` (DESIGN.md §15) is the per-round DP noise mass injected
    at the client→fed-server uploads: per-coordinate Gaussian noise of
    variance (z·C)² summed over the clipped update's coordinates.  It
    joins the variance term as a *separate* additive contribution, gated
    on being nonzero, so the noiseless path evaluates the exact same
    float expression as before DP existed (bit-exact collapse).

    ``staleness`` (DESIGN.md §17) is the bounded-staleness budget of the
    async aggregation mode: a tier-m sync computed at round r lands at
    most s_m rounds later, so client drift accumulates for up to
    I_m + s_m rounds between *effective* aggregations and the drift term
    reads (I_m + s_m)² in place of I_m².  The inflation is a separate
    additive correction gated per tier on s_m > 0 — the s ≡ 0 path
    evaluates the exact pre-async float expression (bit-exact collapse,
    the same contract omega / participation / dp_sigma2 honor).  A tier
    with I_m = 1 but s_m > 0 drifts too (its every-round sync lands
    late), contributing the full (1 + s_m)².
    """
    g, b = hp.gamma, hp.beta
    M = len(intervals)
    q = participation_rates(participation, M)
    d = tier_G2_sums(hp.G2, cuts)
    term2 = b * g * (1.0 + omega) * hp.sigma2_sum / (hp.num_clients * q[0])
    if dp_sigma2:
        term2 += b * g * dp_sigma2 / (hp.num_clients * q[0])
    term3 = 4.0 * b**2 * g**2 * sum(
        (I**2) * (dm / qm)
        for I, dm, qm in zip(intervals[:-1], d[:-1], q[:-1])
        if I > 1
    )
    s = staleness_rounds(staleness, M)
    if np.any(s[:-1] > 0):
        term3 += 4.0 * b**2 * g**2 * sum(
            ((I + sm) ** 2 - (I**2 if I > 1 else 0.0)) * (dm / qm)
            for I, sm, dm, qm in zip(intervals[:-1], s[:-1], d[:-1], q[:-1])
            if sm > 0
        )
    return term2, term3


def theorem1_bound(
    hp: HyperSpec,
    R: int,
    intervals: Sequence[int],
    cuts: Sequence[int],
    omega: float = 0.0,
    participation: Union[None, float, Sequence[float], ParticipationSpec] = None,
    dp_sigma2: float = 0.0,
    staleness: Union[None, int, Sequence[int]] = None,
) -> float:
    """RHS of Eq. (8): bound on (1/R) Σ_t E||∇f||².

    ``omega`` is the compression-error second moment ω of a lossy
    aggregation wire (DESIGN.md §9): an unbiased codec with
    E‖C(g) − g‖² ≤ ω‖g‖² inflates the stochastic-gradient variance term
    to (1 + ω)σ², leaving the drift term untouched.  ω = 0 recovers the
    paper's full-precision bound exactly.

    ``participation`` (per-tier rates q_m, a scalar rate, or a
    ``ParticipationSpec`` — DESIGN.md §12) inflates the variance term by
    1/q_1 (the round averages over N·q_1 client gradients) and every
    tier's drift term by 1/q_m (syncs only land on the participating
    fraction of entities).  None recovers full participation exactly.

    ``dp_sigma2`` adds the DP uplink noise mass to the variance term
    (see ``bound_round_terms``); 0 recovers the noiseless bound exactly.

    ``staleness`` inflates the drift term to (I_m + s_m)² per tier under
    the bounded-staleness async mode (see ``bound_round_terms``); None or
    all-zero recovers the synchronous bound bit-exactly.
    """
    term1 = 2.0 * hp.theta0 / (hp.gamma * R)
    term2, term3 = bound_round_terms(
        hp, intervals, cuts, omega, participation, dp_sigma2, staleness
    )
    return term1 + term2 + term3


def corollary1_rounds(
    hp: HyperSpec,
    eps: float,
    intervals: Sequence[int],
    cuts: Sequence[int],
    omega: float = 0.0,
    participation: Union[None, float, Sequence[float], ParticipationSpec] = None,
    dp_sigma2: float = 0.0,
    staleness: Union[None, int, Sequence[int]] = None,
) -> Optional[float]:
    """Eq. (10): rounds to reach target ε; None if the schedule cannot reach ε."""
    g, b = hp.gamma, hp.beta
    M = len(intervals)
    q = participation_rates(participation, M)
    d = tier_G2_sums(hp.G2, cuts)
    denom = eps - b * g * (1.0 + omega) * hp.sigma2_sum / (hp.num_clients * q[0])
    if dp_sigma2:
        denom -= b * g * dp_sigma2 / (hp.num_clients * q[0])
    denom -= 4.0 * b**2 * g**2 * sum(
        (I**2) * (dm / qm)
        for I, dm, qm in zip(intervals[:-1], d[:-1], q[:-1])
        if I > 1
    )
    s = staleness_rounds(staleness, M)
    if np.any(s[:-1] > 0):
        denom -= 4.0 * b**2 * g**2 * sum(
            ((I + sm) ** 2 - (I**2 if I > 1 else 0.0)) * (dm / qm)
            for I, sm, dm, qm in zip(intervals[:-1], s[:-1], d[:-1], q[:-1])
            if sm > 0
        )
    if denom <= 0:
        return None
    return 2.0 * hp.theta0 / (g * denom)


def stale_interval_weights(
    intervals: Sequence[int],
    staleness: Union[None, int, Sequence[int]] = None,
) -> np.ndarray:
    """Per-tier drift weights w_m for the denominator D = c − κ·Σ w_m·d_m.

    Synchronously w_m = 1{I_m > 1}·I_m² — exactly the sum
    ``bound_constants`` documents.  Under a bounded-staleness budget the
    same gated additive correction as ``bound_round_terms`` lifts a
    stale tier to (I_m + s_m)², so a solver pricing an async schedule
    through (c, κ) uses arithmetic identical to the bound itself.  The
    top tier's weight is always 0 (its sync defines the round boundary).
    ``staleness`` None / all-zero reproduces the synchronous weights
    bit-exactly.
    """
    M = len(intervals)
    s = staleness_rounds(staleness, M)
    w = np.zeros(M, dtype=np.float64)
    for m, I in enumerate(intervals[:-1]):
        base = float(I) ** 2 if I > 1 else 0.0
        w[m] = base
        if s[m] > 0:
            w[m] = base + ((float(I) + float(s[m])) ** 2 - base)
    return w


def bound_constants(
    hp: HyperSpec,
    eps: float,
    omega: float = 0.0,
    q1: float = 1.0,
    dp_sigma2: float = 0.0,
) -> Tuple[float, float]:
    """(c, kappa) with denominator = c - kappa * Σ 1{I>1} I² d_m  (Eq. 22/24).

    ω shrinks c (the ε headroom left after the (1+ω)-inflated variance
    term), which is how compression noise reaches the MA/MS solvers;
    ``q1`` < 1 (the client participation rate, DESIGN.md §12) shrinks it
    further — a round only averages N·q_1 stochastic gradients.  The
    per-tier drift inflation 1/q_m enters through ``HsflProblem.tier_d``
    instead (it scales d_m, not the shared κ).  ``dp_sigma2`` (DESIGN.md
    §15) shrinks c by the DP uplink noise mass as a *separate* gated
    subtraction, never restructuring the existing float expression, so
    dp_sigma2 = 0 is bit-identical to the noiseless constants.

    Bounded-staleness async aggregation (DESIGN.md §17) leaves (c, κ)
    untouched: staleness inflates the *schedule-side* drift sum — swap
    the 1{I>1}·I² weights for ``stale_interval_weights(intervals,
    staleness)`` — exactly as per-tier participation enters through
    ``HsflProblem.tier_d`` rather than through κ.
    """
    c = eps - hp.beta * hp.gamma * (1.0 + omega) * hp.sigma2_sum / (
        hp.num_clients * q1
    )
    if dp_sigma2:
        c -= hp.beta * hp.gamma * dp_sigma2 / (hp.num_clients * q1)
    kappa = 4.0 * hp.beta**2 * hp.gamma**2
    return c, kappa


def synthetic_hyperspec(
    n_units: int,
    num_clients: int,
    gamma: float = 5e-4,
    beta: float = 50.0,
    theta0: float = 5.0,
    g2_scale: float = 20.0,
    sigma2_scale: float = 4.0,
    decay: float = 0.9,
    seed: int = 0,
) -> HyperSpec:
    """Plausible per-unit G²/σ² profile (earlier layers larger, as in CNN/LLM
    practice); used where no estimation run is available."""
    rng = np.random.default_rng(seed)
    prof = decay ** np.arange(n_units)
    jitter = rng.uniform(0.8, 1.2, n_units)
    return HyperSpec(
        gamma=gamma,
        beta=beta,
        theta0=theta0,
        num_clients=num_clients,
        sigma2=sigma2_scale * prof * jitter,
        G2=g2_scale * prof * jitter,
    )
