"""System optimization demo: the paper's Sec. V-VI pipeline end to end.

Builds the exact Sec. VII client(20)-edge(5)-cloud(1) system with VGG-16
through the declarative API, solves the joint MA+MS problem with the BCD
algorithm (Algorithm 2: Proposition-1 Newton-Jacobi MA solver + Dinkelbach
MILFP MS solver), and compares the optimized schedule against the paper's
random baselines.

Also prices the same model on the TPU-pod mapping (DESIGN.md sect. 2) to
show the optimizer adapts (I, mu) to a completely different link hierarchy.

    PYTHONPATH=src python examples/optimize_system.py [--quick]
"""
import argparse

import numpy as np

from repro.api import (
    ExperimentSpec, HyperCfg, ModelCfg, RunCfg, SolverCfg, SystemCfg,
    build, run, tpu_pod_spec,
)
from repro.core import solve_ma


def describe(tag, res):
    print(f"{tag:>14s}: cuts={res.cuts} I={res.intervals} "
          f"Theta'={res.theta:.4g}  R_to_eps={res.rounds_to_eps:.0f}  "
          f"T={res.total_latency:.1f}s")


def random_schedule_theta(prob, rng, n=200):
    """RMA+RMS baseline: expected Theta' over random (I, mu) draws."""
    thetas = []
    for _ in range(n):
        cuts = tuple(sorted(rng.integers(3, 15, size=2)))
        I = (int(rng.integers(1, 26)), int(rng.integers(1, 26)), 1)
        th = prob.theta(I, cuts)
        if np.isfinite(th):
            thetas.append(th)
    return float(np.median(thetas))


def paper_wan_spec(seed: int = 0) -> ExperimentSpec:
    """Sec. VII WAN system, default Theorem-1 constants, eps pinned to 2.0."""
    return ExperimentSpec(
        model=ModelCfg(arch="vgg16-cifar10", batch=16),
        system=SystemCfg(preset="paper-three-tier", num_clients=20,
                         num_edges=5, seed=seed),
        hyper=HyperCfg(seed=seed, eps=2.0),
        solver=SolverCfg(kind="bcd"),
        run=RunCfg(mode="solve", seed=seed),
    )


def main(quick: bool = False, seed: int = 0):
    # --- the paper's WAN system (Sec. VII numbers) ----------------------
    spec = paper_wan_spec(seed)
    built = build(spec)
    res = run(spec, built=built)
    describe("BCD (paper)", res)
    rng = np.random.default_rng(seed)
    rand = random_schedule_theta(built.problem, rng, n=40 if quick else 200)
    print(f"{'RMA+RMS':>14s}: median Theta' {rand:.4g}  "
          f"-> BCD speedup {rand / res.theta:.1f}x")

    # --- the TPU-pod mapping: same model, ICI/DCN link prices -----------
    res_tpu = run(tpu_pod_spec(seed=seed, eps=2.0))
    describe("BCD (TPU pod)", res_tpu)
    print("note: faster links -> the optimizer picks smaller I_m "
          "(aggregate more often) and moves the cut shallower")

    # --- Proposition 1 (MA sub-problem) on a fixed deep cut -------------
    # deeper cuts put big fc layers in low tiers -> expensive aggregation
    # -> the optimal I_m grows exactly as the paper's Insight predicts
    print("\nProposition-1 MA solver, fixed cuts (Insight after Eq. 37):")
    for cuts in [(2, 4), (5, 10), (8, 13)]:
        sol = solve_ma(built.problem, cuts)
        print(f"  cuts={cuts}: agg T_m,A={built.problem.agg_T(cuts).round(2)}s "
              f"-> I*={tuple(sol.intervals)}")

    # --- resource-scaling robustness (paper Fig. 6 trend) ---------------
    print("\ncomm-scaling sweep (paper Fig. 6):")
    scales = (1.0, 0.25) if quick else (1.0, 0.5, 0.25)
    for scale in scales:
        s = spec.replace(
            system=SystemCfg(preset="paper-three-tier", num_clients=20,
                             num_edges=5, seed=seed, comm_scale=scale)
        )
        r = run(s)
        print(f"  comm x{scale:>4}: Theta'={r.theta:.4g} I={r.intervals} "
              f"cuts={r.cuts}")
    return res


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="smaller baseline draw count / scale grid")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    main(args.quick, seed=args.seed)
