"""Drift detection: windowed estimate vs. the currently-priced model.

The controller snapshots the latency/participation values its current
schedule was solved against; each check compares the windowed estimate of
those same quantities *at the current schedule* and trips when any
relative deviation exceeds ``rel_tol``.  Checking at the current operating
point (rather than, say, table norms over the whole lattice) keeps the
trigger cheap, scale-free, and aligned with what actually invalidates the
schedule: the prices the solver believed when it chose it.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np


@dataclass(frozen=True)
class DriftReport:
    drifted: bool
    trigger: str       # "" or "+"-joined subset of latency/participation/faults
    split_rel: float   # relative deviation of windowed T_S at current cuts
    agg_rel: float     # max relative deviation of windowed T_{m,A}
    q_rel: float       # relative deviation of windowed q_1
    fault_rate: float = 0.0  # windowed fraction of faulty clients per round


def _rel(observed: float, priced: float, floor: float = 1e-12) -> float:
    return abs(float(observed) - float(priced)) / max(abs(float(priced)), floor)


def detect_drift(
    split_obs: float,
    split_priced: float,
    agg_obs: np.ndarray,
    agg_priced: np.ndarray,
    q1_obs: float,
    q1_priced: float,
    rel_tol: float,
    fault_rate_obs: float = 0.0,
    fault_tol: float = 1.0,
) -> DriftReport:
    """Compare windowed vs. priced system values at the current schedule.

    ``fault_rate_obs`` is the windowed mean fraction of clients lost to
    faults per round (crash + quarantine, DESIGN.md §16); a sustained
    burst above ``fault_tol`` is a drift trigger of its own (``"faults"``)
    — the schedule was priced for a healthier fleet.  The default
    ``fault_tol=1.0`` can never trip (the rate is a fraction ≤ 1), so
    fault-blind callers see bit-identical reports.
    """
    split_rel = _rel(split_obs, split_priced)
    agg_rel = 0.0
    for o, p in zip(np.atleast_1d(agg_obs), np.atleast_1d(agg_priced)):
        if float(o) == 0.0 and float(p) == 0.0:
            continue  # single-entity tier: no fed traffic on either side
        agg_rel = max(agg_rel, _rel(o, p))
    q_rel = _rel(q1_obs, q1_priced)
    triggers = []
    if split_rel > rel_tol or agg_rel > rel_tol:
        triggers.append("latency")
    if q_rel > rel_tol:
        triggers.append("participation")
    if float(fault_rate_obs) > float(fault_tol):
        triggers.append("faults")
    return DriftReport(
        drifted=bool(triggers),
        trigger="+".join(triggers),
        split_rel=float(split_rel),
        agg_rel=float(agg_rel),
        q_rel=float(q_rel),
        fault_rate=float(fault_rate_obs),
    )
