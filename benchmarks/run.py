"""Benchmark runner: one harness per paper table/figure, the roofline
extraction over the dry-run artifacts, and the fleet-simulator scale sweep.

    PYTHONPATH=src python -m benchmarks.run [names...] [--quick] [--seed S]
                                            [--skip-training] [--list]

Every harness is registered in ``HARNESSES`` with a group tag; ``--list``
prints the registry, positional names (or ``--only``) select a subset, and
``--seed`` is threaded through every harness that derives randomness
(system draws, policy draws, synthetic data, model init).

Harness -> paper artifact map (details in DESIGN.md §7):
    fig2_latency_vs_cut   Fig. 2(c)  per-round latency vs cut layer
    fig45_benchmarks      Figs. 4-5  HSFL vs the 5 baseline policies
    fig67_resources       Figs. 6-7  resource scaling + tier count
    sim_scale             (ours)     fleet simulator: oracle check + 10^6-client sweep
    compress_sweep        (ours)     compression ratio/omega priced through BCD,
                                     Thm 1 + the fused q8 kernel oracle
    ablations             Figs. 8-9  MA / MS ablations (+ real training)
    bound_check           Thm 1      empirical gradient norms vs the bound
    roofline              §g         three-term roofline per (arch x shape)
"""
from __future__ import annotations

import argparse
import sys
import time


def _registry(args):
    from . import (
        ablations, bound_check, compress_sweep, fig2_latency_vs_cut,
        fig45_benchmarks, fig67_resources, roofline, sim_scale,
    )

    return [
        # (name, group, thunk)
        ("fig2_latency_vs_cut", "analytic",
         lambda: fig2_latency_vs_cut.main(args.quick, seed=args.seed)),
        ("fig45_benchmarks", "analytic",
         lambda: fig45_benchmarks.main(args.quick, seed=args.seed)),
        ("fig67_resources", "analytic",
         lambda: fig67_resources.main(args.quick, seed=args.seed)),
        ("sim_scale", "analytic",
         lambda: sim_scale.main(args.quick, seed=args.seed)),
        ("ablations", "training",
         lambda: ablations.main(args.quick, seed=args.seed)),
        ("bound_check", "training",
         lambda: bound_check.main(args.quick, seed=args.seed)),
        # runs a (tiny) real compressed training round for the omega bound
        ("compress_sweep", "training",
         lambda: compress_sweep.main(args.quick, seed=args.seed)),
        ("roofline", "extracted", lambda: _roofline(roofline)),
    ]


def _roofline(roofline):
    import os

    if not os.path.isdir("experiments/dryrun"):
        print("roofline skipped: no dry-run artifacts under experiments/ "
              "(produce them with `python -m repro.launch.dryrun` first)")
        return []
    return roofline.main(["--csv", "experiments/roofline_16x16.csv"])


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("names", nargs="*",
                    help="harness names to run (default: all)")
    ap.add_argument("--quick", action="store_true",
                    help="smaller grids / fewer training rounds")
    ap.add_argument("--seed", type=int, default=0,
                    help="base PRNG seed threaded through every harness")
    ap.add_argument("--skip-training", action="store_true",
                    help="skip the real-training ablation/bound harnesses")
    ap.add_argument("--only", default=None,
                    help="run a single harness (same as one positional name)")
    ap.add_argument("--list", action="store_true", dest="list_harnesses",
                    help="print the registered harnesses and exit")
    args = ap.parse_args(argv)

    registry = _registry(args)
    if args.list_harnesses:
        for name, group, _ in registry:
            print(f"{name:22s} [{group}]")
        return 0

    selected = list(args.names) + ([args.only] if args.only else [])
    if selected:
        known = {n for n, _, _ in registry}
        unknown = [n for n in selected if n not in known]
        if unknown:
            print(f"unknown harness(es) {unknown!r}; --list shows the "
                  "registry", file=sys.stderr)
            return 2
        # an explicitly named harness always runs, even under --skip-training
        jobs = [(n, f) for n, _, f in registry if n in selected]
    else:
        jobs = [(n, f) for n, group, f in registry
                if not (args.skip_training and group == "training")]

    failures = []
    for name, fn in jobs:
        print(f"\n{'='*70}\n== {name}\n{'='*70}")
        t0 = time.time()
        try:
            fn()
            print(f"-- {name} ok ({time.time()-t0:.1f}s)")
        except Exception as e:  # keep going; report at the end
            failures.append((name, repr(e)))
            print(f"-- {name} FAILED: {e!r}", file=sys.stderr)
    if failures:
        print(f"\n{len(failures)} harness(es) failed: {failures}", file=sys.stderr)
        return 1
    print(f"\nall {len(jobs)} harnesses passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
