"""phi3.5-moe-42b-a6.6b [moe] — 16 experts top-2 [hf:microsoft/Phi-3.5-MoE-instruct]."""
import dataclasses
from ..models.spec import ModelSpec, MoeSpec

SPEC = ModelSpec(
    name="phi3.5-moe-42b-a6.6b", family="moe", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=8, d_ff=6400, vocab_size=32064,
    moe=MoeSpec(num_experts=16, top_k=2),
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)

REDUCED = dataclasses.replace(
    SPEC, num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=512, moe=MoeSpec(num_experts=4, top_k=2),
)
