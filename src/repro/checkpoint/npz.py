"""Flat-npz checkpointing of arbitrary pytrees + HSFL schedule metadata.

Layout: one ``.npz`` holding every leaf under its '/'-joined key path plus a
JSON sidecar entry ``__meta__`` (step, tier plan, arbitrary user dict).
Restores exactly (structure is rebuilt from the key paths against a
template tree, so dtype/shape mismatches fail loudly).
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_seg(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _seg(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save_checkpoint(
    path: str,
    tree: Any,
    step: int = 0,
    meta: Optional[Dict[str, Any]] = None,
) -> None:
    flat = _flatten(tree)
    payload = dict(flat)
    payload["__meta__"] = np.frombuffer(
        json.dumps({"step": int(step), **(meta or {})}).encode(), dtype=np.uint8
    )
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    # atomic write: tmp + rename
    d = os.path.dirname(os.path.abspath(path))
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp.npz")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **payload)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)


def load_checkpoint(path: str, template: Any) -> Tuple[Any, int, Dict[str, Any]]:
    """Restore into the structure of ``template``; returns (tree, step, meta)."""
    with np.load(path) as z:
        meta = json.loads(bytes(z["__meta__"]).decode())
        leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(template)
        new_leaves = []
        for path_keys, leaf in leaves_paths:
            key = "/".join(_seg(p) for p in path_keys)
            if key not in z:
                raise KeyError(f"checkpoint missing leaf {key!r}")
            arr = z[key]
            want = np.asarray(leaf)
            if arr.shape != want.shape:
                raise ValueError(f"{key}: shape {arr.shape} != template {want.shape}")
            new_leaves.append(arr.astype(want.dtype))
    tree = jax.tree_util.tree_unflatten(treedef, new_leaves)
    step = int(meta.pop("step", 0))
    return tree, step, meta
