"""Shared benchmark scaffolding: the paper's experimental system (Sec. VII),
the six policies of Sec. VIII, and the converged-time metric.

Problems are built through ``repro.api`` (``paper_spec`` + ``build``) —
harnesses hold no hand-wired constructor chains.  ``record`` collects the
``ExperimentResult`` artifacts a harness produces so ``benchmarks/run.py
--json`` can emit one machine-readable file per run.

All Fig. 4–9 comparisons are *analytic* reproductions: policies choose
(I, μ), the metric is total time-to-ε  T(I, μ) = R(I, μ)·T_S + Σ ⌊R/I_m⌋·T_{m,A}
with R from Corollary 1 — the same objective the paper optimizes. The
ablation benchmark additionally runs REAL training on the synthetic CIFAR
stand-in (see ablations.py) to show the trends hold off-paper.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Tuple

import numpy as np

from repro.api import ExperimentResult
from repro.core import HsflProblem, solve_bcd, solve_ma, solve_ms
from repro.core.latency import split_latency, total_latency

# ExperimentResults recorded by API-driven harnesses this process; the
# --json artifact of benchmarks/run.py serializes them via to_dict().
RESULTS: List[ExperimentResult] = []


def record(res: ExperimentResult) -> ExperimentResult:
    RESULTS.append(res)
    return res


def converged_time(prob: HsflProblem, intervals, cuts) -> float:
    """T(I, μ) to reach ε (Eq. 19 with R from Corollary 1); inf if unreachable."""
    R = prob.rounds(intervals, cuts)
    if R is None or not prob.memory_feasible(cuts):
        return float("inf")
    return total_latency(prob.profile, prob.system, cuts, intervals, R)


# ---------------------------------------------------------------------- #
# the six policies (Sec. VII benchmarks)
# ---------------------------------------------------------------------- #


def policy_hsfl(prob: HsflProblem, rng) -> Tuple[Tuple[int, ...], Tuple[int, ...]]:
    res = solve_bcd(prob)
    return res.intervals, res.cuts


def _random_intervals(rng) -> Tuple[int, ...]:
    return (int(rng.integers(1, 26)), int(rng.integers(1, 26)), 1)


def _random_cuts(rng, lo=3, hi=14) -> Tuple[int, ...]:
    return tuple(sorted(int(c) for c in rng.integers(lo, hi + 1, 2)))


def policy_rma_ms(prob, rng):
    I = _random_intervals(rng)
    try:
        ms = solve_ms(prob, I)
    except ValueError:
        return I, None  # random I makes the bound unreachable: infeasible draw
    return I, ms.cuts


def policy_ma_rms(prob, rng):
    cuts = _random_cuts(rng)
    ma = solve_ma(prob, cuts)
    return ma.intervals, cuts


def policy_rma_rms(prob, rng):
    return _random_intervals(rng), _random_cuts(rng)


def policy_dama_rms(prob, rng):
    """DAMA [55]: depth-aware intervals — tiers hosting more layers
    aggregate less often (interval ∝ hosted layer count)."""
    cuts = _random_cuts(rng)
    L1 = cuts[0]
    L2 = cuts[1] - cuts[0]
    return (max(1, 2 * L1), max(1, 2 * L2), 1), cuts


def policy_rma_ams(prob, rng):
    """AMS [56]: resource-heterogeneity-aware MS — minimizes per-round
    split latency only (ignores convergence impact)."""
    best, best_t = None, float("inf")
    for cuts in prob.iter_cut_vectors():
        if not prob.memory_feasible(cuts):
            continue
        t = split_latency(prob.profile, prob.system, cuts)
        if t < best_t:
            best, best_t = cuts, t
    return _random_intervals(rng), best


POLICIES: Dict[str, Callable] = {
    "HSFL(ours)": policy_hsfl,
    "RMA+MS": policy_rma_ms,
    "MA+RMS": policy_ma_rms,
    "RMA+RMS": policy_rma_rms,
    "DAMA+RMS": policy_dama_rms,
    "RMA+AMS": policy_rma_ams,
}


def expected_converged_time(
    prob: HsflProblem, policy: Callable, draws: int = 20, seed: int = 0
) -> Tuple[float, float]:
    """Mean ± std of converged time over the policy's randomness (feasible
    draws only; infeasible draws are counted via the feasibility rate)."""
    rng = np.random.default_rng(seed)
    ts: List[float] = []
    for _ in range(draws):
        I, cuts = policy(prob, rng)
        t = converged_time(prob, I, cuts) if cuts is not None else float("inf")
        if np.isfinite(t):
            ts.append(t)
        if policy is policy_hsfl:
            break  # deterministic
    if not ts:
        return float("inf"), 0.0
    return float(np.mean(ts)), float(np.std(ts))


def emit(rows: List[Tuple], header: Tuple[str, ...]) -> None:
    print(",".join(header))
    for r in rows:
        print(",".join(f"{x:.6g}" if isinstance(x, float) else str(x) for x in r))
