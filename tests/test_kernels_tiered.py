"""tiered_aggregate Pallas kernel vs pure-jnp oracle (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.tiered_aggregate import tiered_aggregate, tiered_aggregate_ref
from repro.kernels.tiered_aggregate.ops import aggregate_tree


@pytest.mark.parametrize("N,J", [(16, 4), (8, 2), (20, 5), (16, 16), (4, 1)])
@pytest.mark.parametrize("P", [257, 2048, 5000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_matches_ref(N, J, P, dtype):
    key = jax.random.PRNGKey(N * P)
    x = jax.random.normal(key, (N, P)).astype(dtype)
    w = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 1), (N,)))
    for de in (0, 1):
        for dg in (0, 1):
            out = tiered_aggregate(
                x, w, jnp.array(de), jnp.array(dg), J, use_pallas=True, interpret=True
            )
            ref = tiered_aggregate_ref(x, w, jnp.array(bool(de)), jnp.array(bool(dg)), J)
            tol = 1e-5 if dtype == jnp.float32 else 2e-2
            np.testing.assert_allclose(
                np.asarray(out, np.float32), np.asarray(ref, np.float32),
                rtol=tol, atol=tol,
            )


def test_flags_semantics():
    x = jnp.arange(8.0).reshape(4, 2)
    w = jnp.full((4,), 0.25)
    noop = tiered_aggregate(x, w, jnp.array(0), jnp.array(0), 2)
    np.testing.assert_allclose(noop, x)
    glob = tiered_aggregate(x, w, jnp.array(0), jnp.array(1), 2)
    np.testing.assert_allclose(glob, jnp.broadcast_to(x.mean(0), x.shape), rtol=1e-6)
    ent = tiered_aggregate(x, w, jnp.array(1), jnp.array(0), 2)
    np.testing.assert_allclose(ent[0], ent[1])
    np.testing.assert_allclose(ent[2], ent[3])
    assert not np.allclose(ent[0], ent[2])


def test_weighted_global_mean():
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (8, 100))
    w = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 1), (8,)))
    out = tiered_aggregate(x, w, jnp.array(0), jnp.array(1), 4)
    expect = jnp.sum(x * w[:, None], axis=0)
    np.testing.assert_allclose(out[3], expect, rtol=1e-5, atol=1e-6)


def test_aggregate_tree_matches_synchronize_level():
    """Kernel applied tree-wise == the engine's _group_mean at a full sync."""
    from repro.core.tiers import _group_mean

    key = jax.random.PRNGKey(5)
    tree = {
        "a": jax.random.normal(key, (8, 3, 5)),
        "b": {"c": jax.random.normal(jax.random.fold_in(key, 1), (8, 7))},
    }
    w = jnp.full((8,), 1 / 8)
    out = aggregate_tree(tree, w, jnp.array(1), jnp.array(0), 4)
    ref = _group_mean(tree, 4)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)
