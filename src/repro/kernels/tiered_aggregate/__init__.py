from .ops import tiered_aggregate
from .ref import tiered_aggregate_ref
