"""Figs. 6–7: robustness to network resources and tier count.

Fig. 6: converged time vs compute/communication scaling coefficients.
Fig. 7: three-tier HSFL vs two-tier client-edge and client-cloud SFL.
"""
from __future__ import annotations

import numpy as np

from repro.configs.vgg16_cifar10 import SPEC as VGG
from repro.core import HsflProblem, SystemSpec, build_profile, solve_bcd, synthetic_hyperspec
from repro.core.convergence import theorem1_bound

from .common import POLICIES, converged_time, emit, expected_converged_time, paper_problem


def two_tier_system(kind: str, seed: int = 0, compute_scale=1.0, comm_scale=1.0):
    """Client-edge (5 edge servers) or client-cloud (one far server)."""
    rng = np.random.default_rng(seed)
    N = 20
    dev = rng.uniform(0.4e12, 0.6e12, N) * compute_scale
    if kind == "client-edge":
        J2, f2 = 5, 5e12
        up = rng.uniform(75e6, 80e6, N) * comm_scale
        down = np.full(N, 370e6) * comm_scale
    else:  # client-cloud: more compute, slower WAN link (15 Mbps, Fig. 2)
        J2, f2 = 1, 50e12
        up = np.full(N, 15e6) * comm_scale
        down = np.full(N, 15e6) * comm_scale
    per = N // J2
    return SystemSpec(
        M=2, num_clients=N, entities=(N, J2),
        compute=(dev, np.full(N, f2 / per) * compute_scale),
        act_up=(up,), act_down=(down,),
        model_up=(rng.uniform(75e6, 80e6, N) * comm_scale,),
        model_down=(np.full(N, 370e6) * comm_scale,),
        memory=(np.full(N, 8e9), np.full(J2, 64e9)),
    )


def two_tier_problem(kind, seed=0, eps_scale=6.0, **scales):
    prof = build_profile(VGG, batch=16)
    system = two_tier_system(kind, seed, **scales)
    hp = synthetic_hyperspec(VGG.n_units, 20, beta=3.0, seed=seed)
    floor = theorem1_bound(hp, 10**9, [1, 1], (8,))
    return HsflProblem(prof, system, hp, eps=eps_scale * floor)


def main(quick: bool = False, seed: int = 0) -> list:
    rows = []
    scales = [0.25, 0.5, 1.0] if quick else [0.125, 0.25, 0.5, 1.0, 2.0]
    draws = 5 if quick else 15
    # Fig. 6: HSFL + 2 representative baselines across resource scalings
    for axis in ("compute", "comm"):
        for s in scales:
            kw = {f"{axis}_scale": s}
            prob = paper_problem(seed=seed, **kw)
            for name in ("HSFL(ours)", "RMA+MS", "RMA+RMS"):
                t, _ = expected_converged_time(
                    prob, POLICIES[name], draws=draws, seed=seed
                )
                rows.append((f"fig6_{axis}", s, name, t))
    # Fig. 7: tier count under shrinking resources
    for s in scales:
        p3 = paper_problem(seed=seed, compute_scale=s)
        r3 = solve_bcd(p3)
        rows.append(("fig7_compute", s, "three-tier", r3.total_latency))
        for kind in ("client-edge", "client-cloud"):
            p2 = two_tier_problem(kind, seed=seed, compute_scale=s)
            r2 = solve_bcd(p2)
            rows.append(("fig7_compute", s, kind, r2.total_latency))
    emit(rows, ("figure", "scale", "policy", "converged_time_s"))
    if quick:  # the claims below need the full scale grid + draw count
        return rows
    # robustness claim: HSFL degrades less than RMA+RMS as resources shrink
    for axis in ("compute", "comm"):
        h = [r[3] for r in rows if r[0] == f"fig6_{axis}" and r[2] == "HSFL(ours)"]
        r_ = [r[3] for r in rows if r[0] == f"fig6_{axis}" and r[2] == "RMA+RMS"]
        assert h[0] / h[-1] <= r_[0] / r_[-1] * 1.5
    # Fig. 7's actual claim is robustness under scarcity: the extra tier
    # pays off when compute is constrained (the cloud's FLOPS matter) and
    # costs an extra hop + an extra bound term when it is not. Assert:
    # (a) three-tier is fastest at the scarcest setting, (b) three-tier
    # never loses to client-cloud (the paper's slow-WAN baseline).
    scarcest = min(scales)
    sub0 = {r[2]: r[3] for r in rows if r[0] == "fig7_compute" and r[1] == scarcest}
    assert sub0["three-tier"] <= min(sub0["client-edge"], sub0["client-cloud"]) * 1.05, sub0
    for s in scales:
        sub = {r[2]: r[3] for r in rows if r[0] == "fig7_compute" and r[1] == s}
        assert sub["three-tier"] <= sub["client-cloud"], sub
    return rows


if __name__ == "__main__":
    main()
