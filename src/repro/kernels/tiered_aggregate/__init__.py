from .ops import aggregate_tree, tiered_aggregate, tiered_aggregate_q8
from .ref import quantized_tiered_aggregate_ref, tiered_aggregate_ref
