"""End-to-end HSFL training driver (deliverable b).

Thin wrapper over ``repro.launch.train``: synthetic non-IID data ->
probe-based estimation of the Theorem-1 constants (beta, sigma_l^2, G_l^2)
-> BCD re-optimization of (I, mu) -> multi-timescale split training ->
checkpoint. Defaults run the paper's VGG-16/CIFAR-10-like setting for a
few hundred rounds on CPU; pass any assigned arch id for its reduced
variant on an LM stream.

    PYTHONPATH=src python examples/train_hsfl_e2e.py                 # paper setting
    PYTHONPATH=src python examples/train_hsfl_e2e.py --arch qwen2-1.5b --rounds 100

``--control`` instead trains under the online adaptive controller
(DESIGN.md §13): telemetry from a drifting fleet scenario feeds a
sliding-window system estimate, and drift triggers warm-started BCD
re-solves that move (cut, I) mid-run with state migration.  The switch
log and the piecewise Theorem-1 bound are printed at the end.

    PYTHONPATH=src python examples/train_hsfl_e2e.py --control [--quick]
"""
import sys


def run_control(quick: bool = False, seed: int = 0) -> int:
    from repro.api import (
        ControlCfg, ExperimentSpec, HyperCfg, ModelCfg, RunCfg, ScenarioCfg,
        SolverCfg, SystemCfg, run,
    )

    rounds = 12 if quick else 48
    spec = ExperimentSpec(
        model=ModelCfg(arch="smollm-135m", variant="reduced", num_layers=6,
                       batch=4, seq=32),
        system=SystemCfg(preset="paper-three-tier", num_clients=8, num_edges=4),
        scenario=ScenarioCfg(name="flaky-wan", rounds=2 * rounds, seed=seed,
                             quantile=0.5),
        solver=SolverCfg(kind="fixed", cuts=(2, 4), intervals=(4, 2, 1)),
        run=RunCfg(mode="control", rounds=rounds, lr=0.1, seed=seed,
                   log_every=max(1, rounds // 4)),
        control=ControlCfg(window=4, min_window=4, cooldown=4, rel_tol=0.1,
                           backend="numpy"),
        hyper=HyperCfg(seed=seed),
    )
    res = run(spec)
    ctl = res.control
    print(f"\nadaptive control: {ctl['rounds']} rounds, "
          f"{ctl['n_resolves']} re-solves, {ctl['n_switches']} switches "
          f"(re-solve p50 {1e3 * ctl['resolve_p50_s']:.2f} ms)")
    print(f"schedule: cuts {tuple(ctl['initial_cuts'])} x "
          f"I{tuple(ctl['initial_intervals'])} -> "
          f"cuts {tuple(ctl['final_cuts'])} x I{tuple(ctl['final_intervals'])}")
    if ctl["switch_log"]:
        print("switch log:")
        for line in ctl["switch_log"]:
            print(f"  {line}")
    else:
        print("switch log: (no schedule changes — window stayed within "
              "tolerance of the priced model)")
    print(f"piecewise Theorem-1 bound: {ctl['piecewise_bound']:.4f}  "
          f"(static schedule would give {ctl['static_bound']:.4f})")
    print(f"loss: {ctl['first_loss']:.4f} -> {ctl['final_loss']:.4f}")
    return 0


if __name__ == "__main__":
    argv = sys.argv[1:]
    if "--control" in argv:
        argv.remove("--control")
        quick = "--quick" in argv
        raise SystemExit(run_control(quick=quick))

    from repro.launch.train import main

    argv = argv or [
        "--arch", "vgg16-cifar10",
        "--rounds", "200",
        "--clients", "8",
        "--edges", "4",
        "--batch", "8",
        "--lr", "0.05",
        "--non-iid",
        "--auto-optimize",
        "--probe-rounds", "4",
        "--log-every", "20",
        "--checkpoint", "/tmp/hsfl_vgg16.npz",
    ]
    raise SystemExit(main(argv))
