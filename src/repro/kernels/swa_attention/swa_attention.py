"""Pallas TPU kernels: blocked causal sliding-window flash attention.

The operator behind every dense-arch ``long_500k`` run (DESIGN.md §6). TPU
adaptation of flash attention with a *static* kv-span: with window W and
tile T (128, MXU-aligned), each q tile only ever touches span = W/T + 1 kv
tiles, so the grid is (B, H, nq, span) and HBM traffic per q tile is
O(W + T) instead of O(S) — the structural win that makes 512k-token decode
feasible. Online softmax in f32 VMEM scratch; -1e30 masking (not -inf) so
fully-masked tiles stay NaN-free.

Forward emits the per-row logsumexp; the backward pass (dq via a q-parallel
grid, dk/dv via a kv-parallel grid with an extra GQA group axis) recomputes
tile scores from it, the standard flash-bwd trade of FLOPs for HBM.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30

# renamed upstream: jax >= 0.5 exposes ``CompilerParams``, 0.4.x the
# ``TPUCompilerParams`` spelling of the same dataclass
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _pos(i, T):
    return i * T + jax.lax.broadcasted_iota(jnp.int32, (T, 1), 0)


def _allowed(qp, kp, W, S_true, valid):
    """[T, T] mask: causal ∧ window ∧ in-bounds ∧ tile-valid."""
    ok = (kp.T <= qp) & (kp.T < S_true) & (qp < S_true)
    if W > 0:
        ok = ok & (kp.T > qp - W)
    return ok & valid


# --------------------------------------------------------------------------- #
# forward
# --------------------------------------------------------------------------- #


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr,
                *, span, T, W, S_true, scale, out_dtype):
    i = pl.program_id(2)
    s = pl.program_id(3)

    @pl.when(s == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    j_int = i - (span - 1) + s
    valid = j_int >= 0
    j = jnp.maximum(j_int, 0)

    q = q_ref[0, 0].astype(jnp.float32) * scale        # [T, hd]
    k = k_ref[0, 0].astype(jnp.float32)                # [T, hd]
    v = v_ref[0, 0].astype(jnp.float32)                # [T, hd]
    sc = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                   # [T, T]
    qp = _pos(i, T)
    kp = _pos(j, T)
    ok = _allowed(qp, kp, W, S_true, valid)
    sc = jnp.where(ok, sc, NEG)

    m_prev = m_scr[:, :1]
    l_prev = l_scr[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(sc, axis=-1, keepdims=True))
    p = jnp.exp(sc - m_new)
    p = jnp.where(ok, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(s == span - 1)
    def _done():
        l = l_scr[:, :1]
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(out_dtype)
        lse = m_scr[:, 0] + jnp.log(jnp.maximum(l[:, 0], 1e-30))
        lse_ref[0, 0] = lse


def _fwd(q, k, v, *, window, T, S_true, interpret):
    """q [B,H,S,hd]; k,v [B,K,S,hd]; S multiple of T. Returns (o, lse)."""
    B, H, S, hd = q.shape
    K = k.shape[1]
    G = H // K
    nq = S // T
    span = (window // T) + 1 if window > 0 else nq
    # NOTE: the 1/sqrt(hd) scale is folded into q by ops.py before padding.

    def q_map(b, h, i, s):
        return (b, h, i, 0)

    def kv_map(b, h, i, s):
        j = jnp.maximum(i - (span - 1) + s, 0) if window > 0 else s
        return (b, h // G, j, 0)

    grid = (B, H, nq, span)
    kernel = functools.partial(
        _fwd_kernel, span=span, T=T, W=window, S_true=S_true,
        scale=1.0, out_dtype=q.dtype,
    )
    if window == 0:
        # full causal: span = nq, j = s, with causal masking skipping j > i
        kernel = functools.partial(
            _full_fwd_wrapper, span=span, T=T, S_true=S_true, out_dtype=q.dtype
        )
    o, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, T, hd), q_map),
            pl.BlockSpec((1, 1, T, hd), kv_map),
            pl.BlockSpec((1, 1, T, hd), kv_map),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, T, hd), q_map),
            pl.BlockSpec((1, 1, T), lambda b, h, i, s: (b, h, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(q.shape, q.dtype),
            jax.ShapeDtypeStruct((B, H, S), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((T, 128), jnp.float32),
            pltpu.VMEM((T, 128), jnp.float32),
            pltpu.VMEM((T, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
    return o, lse


def _full_fwd_wrapper(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr,
                      acc_scr, *, span, T, S_true, out_dtype):
    """Full-causal variant: kv tile index j == s, mask j > i tiles."""
    i = pl.program_id(2)
    s = pl.program_id(3)

    @pl.when(s == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    valid = s <= i
    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    sc = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    qp = _pos(i, T)
    kp = _pos(s, T)
    ok = _allowed(qp, kp, 0, S_true, valid)
    sc = jnp.where(ok, sc, NEG)
    m_prev = m_scr[:, :1]
    l_prev = l_scr[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(sc, axis=-1, keepdims=True))
    p = jnp.where(ok, jnp.exp(sc - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(s == span - 1)
    def _done():
        l = l_scr[:, :1]
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(out_dtype)
        lse_ref[0, 0] = m_scr[:, 0] + jnp.log(jnp.maximum(l[:, 0], 1e-30))


# --------------------------------------------------------------------------- #
# backward
# --------------------------------------------------------------------------- #


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               dq_scr, *, span, T, W, S_true, full):
    i = pl.program_id(2)
    s = pl.program_id(3)

    @pl.when(s == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    if full:
        j_int = s
        valid = s <= i
    else:
        j_int = i - (span - 1) + s
        valid = j_int >= 0
    j = jnp.maximum(j_int, 0)
    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0][:, None]          # [T, 1]
    delta = delta_ref[0, 0][:, None]      # [T, 1]
    sc = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    qp = _pos(i, T)
    kp = _pos(j, T)
    ok = _allowed(qp, kp, W, S_true, valid)
    p = jnp.where(ok, jnp.exp(sc - lse), 0.0)
    dp = jax.lax.dot_general(
        do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    ds = p * (dp - delta)
    dq_scr[...] = dq_scr[...] + jax.lax.dot_general(
        ds, k, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(s == span - 1)
    def _done():
        dq_ref[0, 0] = dq_scr[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_scr, dv_scr, *, span, T, W, S_true,
                G, nq, full):
    jb = pl.program_id(2)   # kv tile
    g = pl.program_id(3)    # GQA group member
    s = pl.program_id(4)    # q tile offset

    @pl.when((g == 0) & (s == 0))
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    if full:
        i_int = jb + s
        valid = i_int < nq
    else:
        i_int = jb + s
        valid = i_int < nq
    i = jnp.minimum(i_int, nq - 1)
    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0][:, None]
    delta = delta_ref[0, 0][:, None]
    sc = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                      # [Tq, Tk]
    qp = _pos(i, T)
    kp = _pos(jb, T)
    ok = _allowed(qp, kp, W, S_true, valid)
    p = jnp.where(ok, jnp.exp(sc - lse), 0.0)
    dv_scr[...] = dv_scr[...] + jax.lax.dot_general(
        p, do, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    dp = jax.lax.dot_general(
        do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    ds = p * (dp - delta)
    dk_scr[...] = dk_scr[...] + jax.lax.dot_general(
        ds, q, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when((g == G - 1) & (s == span - 1))
    def _done():
        dk_ref[0, 0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_scr[...].astype(dv_ref.dtype)


def _bwd(q, k, v, o, lse, do, *, window, T, S_true, interpret):
    B, H, S, hd = q.shape
    K = k.shape[1]
    G = H // K
    nq = S // T
    full = window == 0
    span = nq if full else (window // T) + 1

    delta = jnp.sum(
        o.astype(jnp.float32) * do.astype(jnp.float32), axis=-1
    )  # [B, H, S]

    def q_map(b, h, i, s):
        return (b, h, i, 0)

    def kv_map(b, h, i, s):
        if full:
            return (b, h // G, s, 0)
        return (b, h // G, jnp.maximum(i - (span - 1) + s, 0), 0)

    def lse_map(b, h, i, s):
        return (b, h, i)

    dq = pl.pallas_call(
        functools.partial(
            _dq_kernel, span=span, T=T, W=window, S_true=S_true, full=full
        ),
        grid=(B, H, nq, span),
        in_specs=[
            pl.BlockSpec((1, 1, T, hd), q_map),
            pl.BlockSpec((1, 1, T, hd), kv_map),
            pl.BlockSpec((1, 1, T, hd), kv_map),
            pl.BlockSpec((1, 1, T, hd), q_map),
            pl.BlockSpec((1, 1, T), lse_map),
            pl.BlockSpec((1, 1, T), lse_map),
        ],
        out_specs=pl.BlockSpec((1, 1, T, hd), q_map),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[pltpu.VMEM((T, hd), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    # kv-parallel pass
    def kv_self_map(b, kh, jb, g, s):
        return (b, kh, jb, 0)

    def q_of_kv_map(b, kh, jb, g, s):
        i = jnp.minimum(jb + s, nq - 1)
        return (b, kh * G + g, i, 0)

    def lse_of_kv_map(b, kh, jb, g, s):
        i = jnp.minimum(jb + s, nq - 1)
        return (b, kh * G + g, i)

    dk, dv = pl.pallas_call(
        functools.partial(
            _dkv_kernel, span=span, T=T, W=window, S_true=S_true,
            G=G, nq=nq, full=full,
        ),
        grid=(B, K, nq, G, span),
        in_specs=[
            pl.BlockSpec((1, 1, T, hd), q_of_kv_map),
            pl.BlockSpec((1, 1, T, hd), kv_self_map),
            pl.BlockSpec((1, 1, T, hd), kv_self_map),
            pl.BlockSpec((1, 1, T, hd), q_of_kv_map),
            pl.BlockSpec((1, 1, T), lse_of_kv_map),
            pl.BlockSpec((1, 1, T), lse_of_kv_map),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, T, hd), kv_self_map),
            pl.BlockSpec((1, 1, T, hd), kv_self_map),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(k.shape, k.dtype),
            jax.ShapeDtypeStruct(v.shape, v.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((T, hd), jnp.float32),
            pltpu.VMEM((T, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=(
                "parallel", "parallel", "parallel", "arbitrary", "arbitrary",
            ),
        ),
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    return dq, dk, dv
