"""Fleet simulation demo: robust (I, mu) against heterogeneous regimes.

Builds the paper's client(20)-edge(5)-cloud(1) system with VGG-16 through
the declarative API, replays every scenario in the fleet-simulator library
against it, and then re-solves the joint MA+MS problem with the per-round
p95 trace latencies in place of the paper's static point estimates:

  1. nominal BCD solution on the static system (the paper's Sec. VII run);
  2. per-scenario round-latency profile of that nominal schedule
     (p50 / p95 / worst over 64 simulated rounds);
  3. robust BCD per scenario (p95 pricing) -- on the homogeneous-paper
     scenario this provably recovers the nominal solution, while the
     straggler-tail regime moves the cut shallower: a heavy on-device
     compute tail makes client-side units expensive at p95, which the
     static model cannot see;
  4. the other way to beat the tail: keep the nominal-ish cut but stop
     waiting for stragglers -- a participation deadline at the p75 client
     finish time drops the slow tail, halves the expected round time, and
     the 1/q-inflated Theorem-1 bound still certifies convergence
     (DESIGN.md section 12).

    PYTHONPATH=src python examples/simulate_fleet.py
"""
import argparse

import numpy as np

from repro.api import build, paper_spec, robust_spec, run
from repro.sim import SCENARIOS, simulate_rounds

ROUNDS = 64


def main(quick: bool = False, seed: int = 0):
    rounds = 16 if quick else ROUNDS
    nominal = run(paper_spec(seed=seed))
    print(f"nominal (static Eq. 17/18): cuts={nominal.cuts} "
          f"I={nominal.intervals} Theta'={nominal.theta:.4g}")

    # --- what the nominal schedule actually costs per scenario ------------
    print(f"\nper-round latency of the nominal schedule over {rounds} rounds:")
    print(f"{'scenario':>26s}  {'p50':>9s}  {'p95':>9s}  {'worst':>9s}  "
          f"{'vs static':>9s}")
    built = {}
    static = nominal.latency["split_T"]
    for name in sorted(SCENARIOS):
        built[name] = build(robust_spec(name, seed=seed, rounds=rounds))
        res = simulate_rounds(built[name].trace, nominal.cuts)
        p50, p95 = np.quantile(res.split, [0.5, 0.95])
        print(f"{name:>26s}  {p50:9.3f}  {p95:9.3f}  {res.split.max():9.3f}  "
              f"{p95 / static:8.2f}x")

    # --- robust BCD: optimize against the p95 trace latencies -------------
    print("\nrobust BCD (p95 trace pricing) per scenario:")
    solutions = {}
    for name in sorted(SCENARIOS):
        from repro.core import solve_bcd

        res = solve_bcd(built[name].problem)
        solutions[name] = res
        moved = "" if (res.cuts == nominal.cuts
                       and tuple(res.intervals) == nominal.intervals) \
            else "   <- schedule moved"
        print(f"{name:>26s}: cuts={res.cuts} I={tuple(res.intervals)} "
              f"Theta'={res.theta:.4g}{moved}")

    # the two claims the sim subsystem is built around
    hom = solutions["homogeneous-paper"]
    assert hom.cuts == nominal.cuts and tuple(hom.intervals) == tuple(
        nominal.intervals
    ), "homogeneous trace must recover the static optimum"
    if not quick:  # the tail claim needs the full 64-round tail sample
        tail = solutions["straggler-tail"]
        assert tail.cuts != nominal.cuts, (
            "straggler-tail p95 should move the cut away from the static optimum"
        )
        print("\nhomogeneous trace recovers the static optimum; straggler "
              f"tail moves the cut {nominal.cuts} -> {tail.cuts} (fewer "
              "client-side units: on-device compute is what the tail inflates)")

    # --- straggler deadline: drop the tail instead of pricing it ----------
    from repro.api import ParticipationCfg

    part_spec = robust_spec("straggler-tail", seed=seed, rounds=rounds).replace(
        participation=ParticipationCfg(target_rate=0.75)
    )
    pb = build(part_spec)
    pres = run(part_spec, built=pb)
    full_T = solutions["straggler-tail"].total_latency
    print(f"\nstraggler deadline (target rate 0.75): deadline="
          f"{pb.participation.deadline:.3f}s q1={pb.participation.q[0]:.2f}")
    print(f"  cuts={pres.cuts} I={tuple(pres.intervals)} "
          f"expected round T={pres.latency['split_T']:.3f}s "
          f"rounds-to-eps={pres.rounds_to_eps:.3g} "
          f"converged T={pres.total_latency:.3g}s (p95-robust: {full_T:.3g}s)")
    assert pres.rounds_to_eps is not None  # the inflated bound still certifies
    return solutions


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true", help="fewer trace rounds")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    main(args.quick, seed=args.seed)
