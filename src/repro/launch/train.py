"""End-to-end HSFL training driver (CPU-runnable).

Wires every substrate together: synthetic data → non-IID partitioner →
federated loader → Engine A split training with the multi-timescale
aggregation schedule → bound-constant estimation → BCD (Algorithm 2)
re-optimization of (I, μ) → checkpointing.

    PYTHONPATH=src python -m repro.launch.train --arch vgg16-cifar10 \
        --rounds 300 --non-iid --auto-optimize

``--arch vgg16-cifar10`` reproduces the paper's own setting; any of the 10
assigned architecture ids runs its REDUCED variant on an LM stream.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="vgg16-cifar10")
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--clients", type=int, default=20)
    ap.add_argument("--edges", type=int, default=5)
    ap.add_argument("--lr", type=float, default=5e-4)
    ap.add_argument("--optimizer", choices=["sgd", "momentum", "adam"], default="sgd")
    ap.add_argument("--non-iid", action="store_true")
    ap.add_argument("--cuts", type=int, nargs="*", default=None)
    ap.add_argument("--intervals", type=int, nargs="*", default=None)
    ap.add_argument("--auto-optimize", action="store_true",
                    help="estimate bound constants from a probe run and let "
                         "BCD (Algorithm 2) pick (I, mu)")
    ap.add_argument("--probe-rounds", type=int, default=8)
    ap.add_argument("--eps-scale", type=float, default=4.0,
                    help="target eps as a multiple of the I=1 bound floor")
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--shard-data", type=int, default=0, metavar="D",
                    help="shard the client-stacked axis over D devices "
                         "(core.sharded shard_map engine; needs XLA_FLAGS="
                         "'--xla_force_host_platform_device_count=D' on CPU)")
    ap.add_argument("--shard-pods", type=int, default=0, metavar="P",
                    help="additionally shard clients over P pods "
                         "(client axes become (pod, data))")
    ap.add_argument("--staleness", type=int, nargs="*", default=None,
                    metavar="S",
                    help="bounded-staleness async aggregation: one value "
                         "(applies to every deferrable tier) or one per "
                         "tier; 0 is the synchronous schedule "
                         "(core.async_agg)")
    args = ap.parse_args(argv)

    from ..configs import get_reduced
    from ..core import (
        HsflProblem, SystemSpec, TierPlan, build_profile, build_train_step_a,
        init_state_a, solve_bcd,
    )
    from ..core.estimator import HyperEstimator
    from ..core.tiers import default_plan
    from ..data import (
        lm_loader, image_loader, make_cifar10_like, make_lm_stream,
        partition_iid, partition_sort_and_shard,
    )
    from ..models.vgg import build_model
    from ..optim import adam, momentum, sgd

    opt = {"sgd": sgd, "momentum": momentum, "adam": adam}[args.optimizer](args.lr)

    if args.arch == "vgg16-cifar10":
        from ..configs.vgg16_cifar10 import SPEC as spec
        ds = make_cifar10_like(4096, seed=args.seed)
        labels = ds.labels
        mk_loader = lambda parts: image_loader(ds, parts, args.batch, args.seed)
    else:
        spec = get_reduced(args.arch)
        ds = make_lm_stream(2048, 64, spec.vocab_size, seed=args.seed)
        labels = ds.tokens[:, 0] % 10
        mk_loader = lambda parts: lm_loader(ds, parts, args.batch, args.seed)
        if spec.family in ("vlm", "audio"):
            raise SystemExit(
                f"{args.arch}: frontend is a stub; use examples/train_hsfl_e2e.py "
                "with dense/moe/ssm/hybrid archs or vgg16-cifar10"
            )

    parts = (
        partition_sort_and_shard(labels, args.clients, 2, args.seed)
        if args.non_iid
        else partition_iid(len(labels), args.clients, args.seed)
    )
    loader = mk_loader(parts)
    model = build_model(spec)
    plan = default_plan(
        spec.n_units, args.clients,
        cuts=tuple(args.cuts) if args.cuts else None,
        intervals=tuple(args.intervals) + (1,) if args.intervals else None,
        entities=(args.clients, args.edges, 1),
    )

    # sharded / async execution (DESIGN.md §17)
    mesh, client_axes = None, ("data",)
    if args.shard_data:
        from .mesh import make_debug_mesh

        mesh = make_debug_mesh(
            data=args.shard_data, model=1, pods=args.shard_pods
        )
        client_axes = ("pod", "data") if args.shard_pods else ("data",)
    staleness = 0
    if args.staleness:
        staleness = (
            args.staleness[0] if len(args.staleness) == 1
            else tuple(args.staleness)
        )

    def make_dispatch(plan_):
        """Specialized per-round-type steps (see tiers.synchronize): the
        fed-server collectives only exist in the (rare) sync-round programs,
        so the hot path never pays for them.

        The async trainer generalizes exactly this dispatch — with all-zero
        staleness it picks the same specialized variants; with s_m > 0 the
        due tier's fed level is snapshotted and folded back s_m rounds
        later (core.async_agg).  It also hosts the sharded step builder.
        """
        if mesh is not None or staleness:
            from ..core.async_agg import make_async_trainer

            trainer = make_async_trainer(
                model, plan_, opt, staleness=staleness,
                mesh=mesh, client_axes=client_axes,
            )
            return trainer.run_round, trainer

        cache = {}

        def dispatch(state_, batch_, r):
            fed = tuple((r + 1) % I == 0 if I > 1 else True
                        for I in plan_.intervals)
            if fed not in cache:
                cache[fed] = jax.jit(
                    build_train_step_a(model, plan_, opt, fed_round=fed)
                )
            return cache[fed](state_, batch_)

        return dispatch, None

    def make_probe_step(plan_):
        if mesh is not None:
            from ..core.sharded import build_sharded_train_step_a

            return build_sharded_train_step_a(
                model, plan_, opt, mesh, client_axes=client_axes
            )
        return jax.jit(build_train_step_a(model, plan_, opt))

    key = jax.random.PRNGKey(args.seed)
    if mesh is not None:
        from ..core.sharded import init_sharded_state_a

        state = init_sharded_state_a(
            model, plan, opt, key, mesh, client_axes=client_axes
        )
    else:
        state = init_state_a(model, plan, opt, key)
    step = make_probe_step(plan)

    if args.auto_optimize:
        print(f"[probe] estimating bound constants over {args.probe_rounds} rounds")
        est = HyperEstimator(plan.n_units, args.clients, args.lr)
        grad_fn = jax.jit(lambda p, b: jax.vmap(jax.value_and_grad(model.loss_fn))(p, b))
        pstate = state
        for _ in range(args.probe_rounds):
            batch = {k: jnp.asarray(v) for k, v in loader.next_round().items()}
            losses, grads = grad_fn(pstate.params, batch)
            est.observe(pstate.params, grads, float(jnp.mean(losses)))
            pstate, _ = step(pstate, batch)
        hp = est.hyperspec()
        prof = build_profile(spec, args.batch, seq=64 if args.arch != "vgg16-cifar10" else 1)
        system = SystemSpec.paper_three_tier(args.clients, args.edges, seed=args.seed)
        from ..core.convergence import theorem1_bound
        floor = theorem1_bound(hp, 10**9, [1] * plan.M, plan.cuts)
        prob = HsflProblem(prof, system, hp, eps=args.eps_scale * floor)
        res = solve_bcd(prob)
        print(f"[bcd] cuts={res.cuts} intervals={res.intervals} "
              f"theta={res.theta:.4g} R={res.rounds:.0f} T={res.total_latency:.1f}s")
        plan = default_plan(
            spec.n_units, args.clients, cuts=res.cuts,
            intervals=res.intervals, entities=(args.clients, args.edges, 1),
        )
        step = make_probe_step(plan)

    mode = []
    if mesh is not None:
        mode.append(f"sharded over {client_axes} ({jax.device_count()} dev)")
    if staleness:
        mode.append(f"async staleness={staleness}")
    print(f"[train] arch={spec.name} units={spec.n_units} plan cuts={plan.cuts} "
          f"I={plan.intervals} N={args.clients} J2={args.edges}"
          + (f"  [{', '.join(mode)}]" if mode else ""))
    dispatch, trainer = make_dispatch(plan)
    t0 = time.time()
    for r in range(args.rounds):
        batch = {k: jnp.asarray(v) for k, v in loader.next_round().items()}
        state, loss = dispatch(state, batch, r)
        if (r + 1) % args.log_every == 0 or r == 0:
            print(f"round {r+1:5d}  loss {float(loss):.4f}  "
                  f"({(time.time()-t0)/(r+1):.2f}s/round)")
    if trainer is not None:
        state = trainer.drain(state)  # fold in-flight async syncs in

    if args.checkpoint:
        from ..checkpoint import save_checkpoint

        save_checkpoint(
            args.checkpoint, state.params, step=int(state.step),
            meta={"cuts": list(plan.cuts), "intervals": list(plan.intervals)},
        )
        print(f"saved checkpoint -> {args.checkpoint}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
