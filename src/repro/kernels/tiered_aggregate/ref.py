"""Pure-jnp oracle for the fused two-level HSFL aggregation (Eqs. 3–4).

Semantics (one tier's parameter shard, client-stacked):

    x        [N, P]   per-client parameter values
    weights  [N]      fed-server aggregation weights (N_m^j/N expanded to
                      clients; uniform = 1/N), must sum to 1
    do_entity scalar  bool — apply Eq. (3) entity-local mean (every round)
    do_global scalar  bool — apply Eq. (4) fed-server weighted mean (at I_m)

    y1 = do_entity ? mean within each of the J contiguous client groups : x
    y2 = do_global ? Σ_n w_n · y1_n  (broadcast back)                  : y1
"""
from __future__ import annotations

import jax.numpy as jnp


def tiered_aggregate_ref(x, weights, do_entity, do_global, num_entities: int):
    N, P = x.shape
    J = num_entities
    per = N // J
    xf = x.astype(jnp.float32)
    grouped = xf.reshape(J, per, P)
    emean = jnp.broadcast_to(
        jnp.mean(grouped, axis=1, keepdims=True), grouped.shape
    ).reshape(N, P)
    y1 = jnp.where(do_entity, emean, xf)
    w = weights.astype(jnp.float32)[:, None]
    gmean = jnp.sum(y1 * w, axis=0, keepdims=True)
    y2 = jnp.where(do_global, jnp.broadcast_to(gmean, y1.shape), y1)
    return y2.astype(x.dtype)


def quantized_tiered_aggregate_ref(
    q, scales, weights, do_entity, do_global, num_entities: int, tile_p: int
):
    """Oracle for the fused q8 path: dequantize each ``tile_p`` chunk
    against its scale, then the Eq. 3/4 reduction — per tile, in exactly
    the op order of ``_q8_kernel``, so interpret mode is bit-identical.

    q       [N, Pp] int8 wire payload (Pp a multiple of ``tile_p``)
    scales  [N, Pp // tile_p] f32 per-tile scales
    """
    N, Pp = q.shape
    assert Pp % tile_p == 0, (Pp, tile_p)
    J = num_entities
    per = N // J
    w = weights.astype(jnp.float32)[:, None]
    outs = []
    for t in range(Pp // tile_p):
        s = scales[:, t].astype(jnp.float32)[:, None]
        x = q[:, t * tile_p : (t + 1) * tile_p].astype(jnp.float32) * s
        grouped = x.reshape(J, per, tile_p)
        emean = jnp.mean(grouped, axis=1, keepdims=True)
        emean = jnp.broadcast_to(emean, grouped.shape).reshape(x.shape)
        y1 = jnp.where(do_entity, emean, x)
        gmean = jnp.sum(y1 * w, axis=0, keepdims=True)
        outs.append(jnp.where(do_global, jnp.broadcast_to(gmean, y1.shape), y1))
    return jnp.concatenate(outs, axis=1)


def ragged_quantized_tiered_aggregate_ref(
    q, scales, weights, member, do_entity, do_global,
    num_entities: int, tile_p: int,
):
    """Oracle for the ragged q8 path — per tile, in exactly the op order of
    ``_ragged_q8_kernel`` (dequant, member-masked entity mean, member-
    renormalized fed mean, member-gated receives), so interpret mode is
    bit-identical.  ``member`` [N] marks clients whose class holds this
    shard's units in the aggregating tier (DESIGN.md §14).
    """
    N, Pp = q.shape
    assert Pp % tile_p == 0, (Pp, tile_p)
    J = num_entities
    per = N // J
    m = member.astype(jnp.float32)[:, None]            # [N, 1]
    wm = weights.astype(jnp.float32)[:, None] * m      # [N, 1]
    sw = jnp.sum(wm, axis=0, keepdims=True)            # [1, 1]
    outs = []
    for t in range(Pp // tile_p):
        s = scales[:, t].astype(jnp.float32)[:, None]
        x = q[:, t * tile_p : (t + 1) * tile_p].astype(jnp.float32) * s
        grouped = x.reshape(J, per, tile_p)
        mg = m.reshape(J, per, 1)
        sg = jnp.sum(mg, axis=1, keepdims=True)
        emean = jnp.sum(grouped * mg, axis=1, keepdims=True) / jnp.maximum(
            sg, 1.0
        )
        emean = jnp.broadcast_to(emean, grouped.shape).reshape(x.shape)
        sg_rows = jnp.broadcast_to(sg, grouped.shape).reshape(x.shape)
        y1 = jnp.where(do_entity & (m > 0.0) & (sg_rows > 0.0), emean, x)
        gmean = jnp.sum(y1 * wm, axis=0, keepdims=True) / jnp.where(
            sw > 0.0, sw, 1.0
        )
        outs.append(
            jnp.where(
                do_global & (m > 0.0) & (sw > 0.0),
                jnp.broadcast_to(gmean, y1.shape),
                y1,
            )
        )
    return jnp.concatenate(outs, axis=1)
