"""Quickstart: HSFL in ~60 lines.

Trains a reduced smollm-135m-family LM across a 3-tier hierarchy
(8 clients -> 4 edge entities -> 1 cloud) with the paper's multi-timescale
aggregation schedule, then shows Theorem 1's bound for the schedule used.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.core import (
    build_train_step_a, init_state_a, synthetic_hyperspec, theorem1_bound,
)
from repro.core.tiers import default_plan
from repro.data import lm_loader, make_lm_stream, partition_iid
from repro.models.model import SplittableModel
from repro.optim import sgd


def main():
    # 1. model: any of the 10 assigned archs; reduced variant runs on CPU
    #    (bumped to 4 layers so all three tiers hold at least one unit)
    spec = dataclasses.replace(get_reduced("smollm-135m"), num_layers=4)
    model = SplittableModel(spec)

    # 2. federated data: synthetic LM stream, IID split over 8 clients
    ds = make_lm_stream(512, 32, spec.vocab_size, seed=0)
    parts = partition_iid(len(ds), 8)
    loader = lm_loader(ds, parts, batch=4, seed=0)

    # 3. tier plan: cuts (model splitting mu) + intervals (aggregation I_m)
    #    tier 3 (cloud, J=1) always syncs every round -> interval 1
    plan = default_plan(spec.n_units, num_clients=8, cuts=(1, 3),
                        intervals=(4, 2, 1), entities=(8, 4, 1))
    print(f"plan: units={spec.n_units} cuts={plan.cuts} I={plan.intervals}")

    # 4. train with engine A (sync-groups): Eq. 3 entity sync every round,
    #    Eq. 4 fed-server aggregation every I_m rounds
    opt = sgd(0.1)
    state = init_state_a(model, plan, opt, jax.random.PRNGKey(0))
    step = jax.jit(build_train_step_a(model, plan, opt))
    for r in range(30):
        batch = {k: jnp.asarray(v) for k, v in loader.next_round().items()}
        state, loss = step(state, batch)
        if (r + 1) % 10 == 0:
            print(f"round {r+1:3d}  loss {float(loss):.4f}")

    # 5. Theorem 1: the convergence bound this schedule guarantees
    hp = synthetic_hyperspec(spec.n_units, num_clients=8)
    for I in [(1, 1, 1), (4, 2, 1), (64, 16, 1)]:
        b = theorem1_bound(hp, R=500, intervals=I, cuts=plan.cuts)
        print(f"Theorem-1 bound @R=500, I={I}: {b:.4f}")
    print("smaller I_m -> tighter bound (paper Insight 1)")


if __name__ == "__main__":
    main()
