"""SplittableModel: uniform frontend/units/head protocol over the model zoo.

The HSFL engine only relies on:
  * ``init_params(key)``  -> {"frontend": .., "units": <stacked [U, ...]>, "head": ..}
  * ``loss_fn(params, batch)`` / ``forward(params, batch)``
  * unit stacks being stacked on axis 0 so cut ranges are slices.

Families: dense | moe | ssm | hybrid | vlm | audio (enc-dec).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.ad_checkpoint
import jax.numpy as jnp
from jax import lax

from . import layers as L
from .spec import ModelSpec

Params = Dict[str, Any]


class SplittableModel:
    def __init__(self, spec: ModelSpec):
        self.spec = spec
        # optional hook: sharding constraint applied to the residual stream
        # after every unit (sequence-parallelism; set by launch/dryrun_lib).
        self.carry_constraint = None
        # optional hooks: sharding constraint applied to the MoE dispatch
        # buffer / expert outputs, and the dispatch group count (expert
        # parallelism; set by launch code — see layers.moe).
        self.moe_constraint = None
        self.moe_groups = 1
        # full scan unroll: XLA's cost_analysis counts a while-loop body
        # ONCE (not x trip count), and collectives inside the body likewise
        # appear once in the HLO text. The dry-run sets this so the roofline
        # terms are exact; the training path keeps the rolled scan.
        self.scan_unroll = False

    @property
    def _unroll(self):
        return True if self.scan_unroll else 1


    @property
    def _remat(self):
        """jax.checkpoint partial with the spec's remat policy."""
        if self.spec.remat_policy == "dots":
            return partial(
                jax.checkpoint,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            )
        if self.spec.remat_policy == "outs":
            # save the post-collective sublayer outputs (attn_out / ffn_out,
            # named below): the backward pass then skips both the re-forward
            # matmuls AND their TP all-reduces, at +2 activations/unit of
            # memory (MaxText-style minimal policy).
            return partial(
                jax.checkpoint,
                policy=jax.checkpoint_policies.save_only_these_names(
                    "attn_out", "ffn_out"
                ),
            )
        return jax.checkpoint

    # ------------------------------------------------------------------ #
    # init
    # ------------------------------------------------------------------ #
    def _init_unit(self, key, kind: str) -> Params:
        spec = self.spec
        ks = jax.random.split(key, 16)
        if kind == "dense":
            return {"attn": L.init_attention(ks[0], spec), "mlp": L.init_mlp(ks[1], spec)}
        if kind == "moe":
            return {"attn": L.init_attention(ks[0], spec), "moe": L.init_moe(ks[1], spec)}
        if kind == "ssm":
            return {"mamba": L.init_mamba(ks[0], spec)}
        if kind == "hybrid":
            per = spec.attn_period
            n_m = per - 1
            n_moe = per // spec.moe_period
            n_mlp = per - n_moe
            return {
                "attn": L.init_attention(ks[0], spec),
                "mamba": jax.vmap(lambda k: L.init_mamba(k, spec))(
                    jax.random.split(ks[1], n_m)
                ),
                "moe": jax.vmap(lambda k: L.init_moe(k, spec))(
                    jax.random.split(ks[2], n_moe)
                ),
                "mlp": jax.vmap(lambda k: L.init_mlp(k, spec))(
                    jax.random.split(ks[3], n_mlp)
                ),
            }
        if kind == "enc":
            return {
                "attn": L.init_attention(ks[0], spec),
                "mlp": L.init_mlp(ks[1], spec, gelu=True),
            }
        if kind == "dec":
            return {
                "attn": L.init_attention(ks[0], spec),
                "xattn": L.init_attention(ks[1], spec, cross=True),
                "mlp": L.init_mlp(ks[2], spec, gelu=True),
            }
        raise ValueError(kind)

    def init_params(self, key) -> Params:
        spec = self.spec
        kf, ku, kh = jax.random.split(key, 3)
        V, d = spec.padded_vocab, spec.d_model
        frontend: Params = {
            "embed": (jax.random.normal(kf, (V, d)) * 0.02).astype(spec.pdtype)
        }
        if spec.family == "vlm":
            frontend["proj"] = L._dense_init(
                jax.random.fold_in(kf, 1), (d, d), spec.pdtype
            )
        if spec.family == "audio":
            frontend["proj"] = L._dense_init(
                jax.random.fold_in(kf, 1), (d, d), spec.pdtype
            )
            frontend["enc_pos"] = (
                jax.random.normal(jax.random.fold_in(kf, 2), (spec.encoder_len, d))
                * 0.02
            ).astype(spec.pdtype)

        if spec.family == "audio":
            ne, nd = spec.encoder_layers, spec.num_layers
            units = {
                "enc": jax.vmap(lambda k: self._init_unit(k, "enc"))(
                    jax.random.split(ku, ne)
                ),
                "dec": jax.vmap(lambda k: self._init_unit(k, "dec"))(
                    jax.random.split(jax.random.fold_in(ku, 1), nd)
                ),
            }
        else:
            kind = {"dense": "dense", "vlm": "dense", "moe": "moe",
                    "ssm": "ssm", "hybrid": "hybrid"}[spec.family]
            units = jax.vmap(lambda k: self._init_unit(k, kind))(
                jax.random.split(ku, spec.n_units)
            )

        head: Params = {"norm": jnp.zeros((d,), spec.pdtype)}
        if not spec.tie_embeddings:
            head["unembed"] = L._dense_init(kh, (d, V), spec.pdtype, scale=0.02)
        return {"frontend": frontend, "units": units, "head": head}

    # ------------------------------------------------------------------ #
    # unit application (training / prefill)
    # ------------------------------------------------------------------ #
    def _apply_one_unit(self, up: Params, carry: Params, positions, prefix_len: int) -> Params:
        spec = self.spec
        fam = spec.family
        h = carry["h"]
        aux = carry.get("aux", jnp.zeros((), jnp.float32))
        eps = spec.norm_eps
        if fam in ("dense", "vlm", "moe"):
            a, _ = L.attention(
                up["attn"], L.rms_norm(h, up["attn"]["norm"], eps), spec,
                positions=positions, prefix_len=prefix_len,
            )
            a = jax.ad_checkpoint.checkpoint_name(a, "attn_out")
            h = h + a
            if fam == "moe":
                o, al = L.moe(up["moe"], L.rms_norm(h, up["moe"]["norm"], eps), spec,
                    constraint=self.moe_constraint, groups=self.moe_groups)
                aux = aux + al
            else:
                o = L.mlp(up["mlp"], L.rms_norm(h, up["mlp"]["norm"], eps))
            o = jax.ad_checkpoint.checkpoint_name(o, "ffn_out")
            h = h + o
        elif fam == "ssm":
            o, _ = L.mamba_block(
                up["mamba"], L.rms_norm(h, up["mamba"]["norm"], eps), spec
            )
            h = h + o
        elif fam == "hybrid":
            per = spec.attn_period
            i_m = i_moe = i_mlp = 0
            for j in range(per):
                if j == 0:
                    a, _ = L.attention(
                        up["attn"], L.rms_norm(h, up["attn"]["norm"], eps), spec,
                        positions=positions, prefix_len=prefix_len,
                    )
                    h = h + a
                else:
                    mp = jax.tree.map(lambda x: x[i_m], up["mamba"])
                    o, _ = L.mamba_block(mp, L.rms_norm(h, mp["norm"], eps), spec)
                    h = h + o
                    i_m += 1
                if j % spec.moe_period == 1:  # every 2nd sublayer gets MoE
                    ep = jax.tree.map(lambda x: x[i_moe], up["moe"])
                    o, al = L.moe(ep, L.rms_norm(h, ep["norm"], eps), spec,
                        constraint=self.moe_constraint, groups=self.moe_groups)
                    aux = aux + al
                    i_moe += 1
                else:
                    fp = jax.tree.map(lambda x: x[i_mlp], up["mlp"])
                    o = L.mlp(fp, L.rms_norm(h, fp["norm"], eps))
                    i_mlp += 1
                h = h + o
        else:
            raise ValueError(fam)
        if self.carry_constraint is not None:
            h = self.carry_constraint(h)
        out = dict(carry)
        out["h"] = h
        out["aux"] = aux
        return out

    def _apply_enc_unit(self, up: Params, henc: jax.Array) -> jax.Array:
        spec = self.spec
        eps = spec.norm_eps
        pos = jnp.arange(henc.shape[1])
        a, _ = L.attention(
            up["attn"], L.rms_norm(henc, up["attn"]["norm"], eps), spec,
            positions=pos, causal=False, use_rope=False,
        )
        henc = henc + a
        o = L.mlp(up["mlp"], L.rms_norm(henc, up["mlp"]["norm"], eps))
        return henc + o

    def _apply_dec_unit(self, up: Params, carry: Params, positions) -> Params:
        spec = self.spec
        eps = spec.norm_eps
        h = carry["h"]
        a, _ = L.attention(
            up["attn"], L.rms_norm(h, up["attn"]["norm"], eps), spec,
            positions=positions,
        )
        h = h + a
        enc = carry["enc"]
        kx = (enc @ up["xattn"]["wk"]).reshape(
            enc.shape[0], enc.shape[1], spec.num_kv_heads, spec.hd
        )
        vx = (enc @ up["xattn"]["wv"]).reshape(
            enc.shape[0], enc.shape[1], spec.num_kv_heads, spec.hd
        )
        x, _ = L.attention(
            up["xattn"], L.rms_norm(h, up["xattn"]["norm"], eps), spec,
            positions=positions, kv_override=(kx, vx), use_rope=False,
        )
        h = h + x
        o = L.mlp(up["mlp"], L.rms_norm(h, up["mlp"]["norm"], eps))
        out = dict(carry)
        out["h"] = h + o
        return out

    def apply_units(self, units: Params, carry: Params, lo: int, hi: int,
                    positions=None, prefix_len: int = 0) -> Params:
        """Run units [lo, hi) on the carry. Unit params are stacked on axis 0
        (sliced statically here); the loop is a lax.scan over the slice."""
        spec = self.spec
        if lo >= hi:
            return carry
        if positions is None:
            positions = jnp.arange(carry["h"].shape[1])
        if spec.family == "audio":
            ne = spec.encoder_layers
            e_lo, e_hi = min(lo, ne), min(hi, ne)
            d_lo, d_hi = max(lo, ne) - ne, max(hi, ne) - ne
            if e_hi > e_lo:
                esl = jax.tree.map(lambda x: x[e_lo:e_hi], units["enc"])

                def enc_body(henc, up):
                    return self._apply_enc_unit(up, henc), None

                if spec.remat:
                    enc_body = self._remat(enc_body)
                henc, _ = lax.scan(enc_body, carry["enc"], esl, unroll=self._unroll)
                carry = dict(carry)
                carry["enc"] = henc
            if d_hi > d_lo:
                dsl = jax.tree.map(lambda x: x[d_lo:d_hi], units["dec"])

                def dec_body(c, up):
                    return self._apply_dec_unit(up, c, positions), None

                if spec.remat:
                    dec_body = self._remat(dec_body)
                carry, _ = lax.scan(dec_body, carry, dsl, unroll=self._unroll)
            return carry

        usl = jax.tree.map(lambda x: x[lo:hi], units)

        def body(c, up):
            return self._apply_one_unit(up, c, positions, prefix_len), None

        if spec.remat:
            body = self._remat(body)
        carry, _ = lax.scan(body, carry, usl, unroll=self._unroll)
        return carry

    # ------------------------------------------------------------------ #
    # frontend / head
    # ------------------------------------------------------------------ #
    def frontend_apply(self, frontend: Params, batch: Params) -> Params:
        spec = self.spec
        emb = frontend["embed"]
        if spec.family == "vlm":
            te = emb[batch["tokens"]].astype(spec.cdtype)
            pe = (batch["patch_embeds"].astype(spec.cdtype) @ frontend["proj"])
            h = jnp.concatenate([pe, te], axis=1) * math.sqrt(spec.d_model)
            return {"h": h.astype(spec.cdtype), "aux": jnp.zeros((), jnp.float32)}
        if spec.family == "audio":
            henc = (
                batch["frames"].astype(spec.cdtype) @ frontend["proj"]
                + frontend["enc_pos"][None].astype(spec.cdtype)
            )
            h = emb[batch["tokens"]].astype(spec.cdtype)
            return {"h": h, "enc": henc, "aux": jnp.zeros((), jnp.float32)}
        h = emb[batch["tokens"]].astype(spec.cdtype)
        return {"h": h, "aux": jnp.zeros((), jnp.float32)}

    def head_apply(self, params: Params, carry: Params) -> jax.Array:
        spec = self.spec
        h = L.rms_norm(carry["h"], params["head"]["norm"], spec.norm_eps)
        if spec.tie_embeddings:
            logits = h @ params["frontend"]["embed"].T.astype(h.dtype)
        else:
            logits = h @ params["head"]["unembed"]
        if spec.padded_vocab != spec.vocab_size:
            pad = spec.padded_vocab - spec.vocab_size
            neg = jnp.full(logits.shape[:-1] + (pad,), -1e30, logits.dtype)
            logits = jnp.concatenate([logits[..., : spec.vocab_size], neg], axis=-1)
        return logits

    # ------------------------------------------------------------------ #
    # end-to-end loss / forward
    # ------------------------------------------------------------------ #
    def forward(self, params: Params, batch: Params) -> Tuple[jax.Array, jax.Array]:
        spec = self.spec
        carry = self.frontend_apply(params["frontend"], batch)
        prefix = spec.prefix_len if spec.family == "vlm" else 0
        carry = self.apply_units(
            params["units"], carry, 0, spec.n_units, prefix_len=prefix
        )
        return self.head_apply(params, carry), carry["aux"]

    def loss_fn(self, params: Params, batch: Params) -> jax.Array:
        spec = self.spec
        logits, aux = self.forward(params, batch)
        labels = batch["labels"]
        if spec.family == "vlm":
            # loss on text positions only
            logits = logits[:, spec.prefix_len :]
        mask = (labels >= 0).astype(jnp.float32)
        loss = L.cross_entropy(logits, jnp.maximum(labels, 0), mask)
        if spec.moe is not None:
            loss = loss + 0.01 * aux
        return loss

    # ------------------------------------------------------------------ #
    # decode (serve_step)
    # ------------------------------------------------------------------ #
    def init_caches(self, batch: int, cache_len: int) -> Params:
        spec = self.spec

        def one(kind: str) -> Params:
            if kind == "dense":
                return {"attn": L.init_attn_cache(spec, batch, cache_len)}
            if kind == "moe":
                return {"attn": L.init_attn_cache(spec, batch, cache_len)}
            if kind == "ssm":
                return {"mamba": L.init_mamba_cache(spec, batch)}
            if kind == "hybrid":
                per = spec.attn_period
                return {
                    "attn": L.init_attn_cache(spec, batch, cache_len),
                    "mamba": jax.tree.map(
                        lambda *xs: jnp.stack(xs),
                        *[L.init_mamba_cache(spec, batch) for _ in range(per - 1)],
                    ),
                }
            if kind == "dec":
                return {
                    "attn": L.init_attn_cache(spec, batch, cache_len),
                    "xk": jnp.zeros(
                        (batch, spec.encoder_len, spec.num_kv_heads, spec.hd),
                        spec.cdtype,
                    ),
                    "xv": jnp.zeros(
                        (batch, spec.encoder_len, spec.num_kv_heads, spec.hd),
                        spec.cdtype,
                    ),
                }
            raise ValueError(kind)

        if spec.family == "audio":
            caches = jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[one("dec") for _ in range(spec.num_layers)],
            )
            return caches
        kind = {"dense": "dense", "vlm": "dense", "moe": "moe",
                "ssm": "ssm", "hybrid": "hybrid"}[spec.family]
        return jax.tree.map(
            lambda *xs: jnp.stack(xs), *[one(kind) for _ in range(spec.n_units)]
        )

    def _decode_unit(self, up: Params, cache: Params, carry: Params, pos) -> Tuple[Params, Params]:
        spec = self.spec
        eps = spec.norm_eps
        fam = spec.family
        h = carry["h"]
        if fam in ("dense", "vlm", "moe"):
            a, nc = L.attention(
                up["attn"], L.rms_norm(h, up["attn"]["norm"], eps), spec,
                positions=pos, cache=cache["attn"],
            )
            h = h + a
            if fam == "moe":
                o, _ = L.moe(up["moe"], L.rms_norm(h, up["moe"]["norm"], eps), spec,
                    constraint=self.moe_constraint, groups=self.moe_groups)
            else:
                o = L.mlp(up["mlp"], L.rms_norm(h, up["mlp"]["norm"], eps))
            carry = dict(carry); carry["h"] = h + o
            return carry, {"attn": nc}
        if fam == "ssm":
            o, nc = L.mamba_block(
                up["mamba"], L.rms_norm(h, up["mamba"]["norm"], eps), spec,
                cache=cache["mamba"],
            )
            carry = dict(carry); carry["h"] = h + o
            return carry, {"mamba": nc}
        if fam == "hybrid":
            per = spec.attn_period
            new_m = []
            i_m = i_moe = i_mlp = 0
            for j in range(per):
                if j == 0:
                    a, nca = L.attention(
                        up["attn"], L.rms_norm(h, up["attn"]["norm"], eps), spec,
                        positions=pos, cache=cache["attn"],
                    )
                    h = h + a
                else:
                    mp = jax.tree.map(lambda x: x[i_m], up["mamba"])
                    mc = jax.tree.map(lambda x: x[i_m], cache["mamba"])
                    o, ncm = L.mamba_block(
                        mp, L.rms_norm(h, mp["norm"], eps), spec, cache=mc
                    )
                    h = h + o
                    new_m.append(ncm)
                    i_m += 1
                if j % spec.moe_period == 1:
                    ep = jax.tree.map(lambda x: x[i_moe], up["moe"])
                    o, _ = L.moe(ep, L.rms_norm(h, ep["norm"], eps), spec,
                        constraint=self.moe_constraint, groups=self.moe_groups)
                    i_moe += 1
                else:
                    fp = jax.tree.map(lambda x: x[i_mlp], up["mlp"])
                    o = L.mlp(fp, L.rms_norm(h, fp["norm"], eps))
                    i_mlp += 1
                h = h + o
            carry = dict(carry); carry["h"] = h
            nm = jax.tree.map(lambda *xs: jnp.stack(xs), *new_m)
            return carry, {"attn": nca, "mamba": nm}
        if fam == "audio":
            a, nc = L.attention(
                up["attn"], L.rms_norm(h, up["attn"]["norm"], eps), spec,
                positions=pos, cache=cache["attn"],
            )
            h = h + a
            x, _ = L.attention(
                up["xattn"], L.rms_norm(h, up["xattn"]["norm"], eps), spec,
                positions=pos, kv_override=(cache["xk"], cache["xv"]),
                use_rope=False,
            )
            h = h + x
            o = L.mlp(up["mlp"], L.rms_norm(h, up["mlp"]["norm"], eps))
            carry = dict(carry); carry["h"] = h + o
            return carry, {"attn": nc, "xk": cache["xk"], "xv": cache["xv"]}
        raise ValueError(fam)

    def decode_step(self, params: Params, tokens: jax.Array, caches: Params,
                    pos_index: jax.Array) -> Tuple[jax.Array, Params]:
        """One decode step. tokens [B, 1] int32; pos_index scalar int32."""
        spec = self.spec
        emb = params["frontend"]["embed"]
        h = emb[tokens].astype(spec.cdtype)  # [B, 1, d]
        carry = {"h": h, "aux": jnp.zeros((), jnp.float32)}
        pos = pos_index[None]  # [1]
        units = params["units"]["dec"] if spec.family == "audio" else params["units"]

        def body(c, xs):
            up, uc = xs
            c2, nc = self._decode_unit(up, uc, c, pos)
            return c2, nc

        carry, new_caches = lax.scan(body, carry, (units, caches), unroll=self._unroll)
        logits = self.head_apply(params, carry)
        return logits[:, 0], new_caches
