"""PartitionSpec rules (pure functions — no devices required)."""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import get_reduced, get_spec
from repro.configs.shapes import sds
from repro.launch import sharding as sh
from repro.models.model import SplittableModel


def abstract_params(arch, client=None):
    spec = get_reduced(arch)
    model = SplittableModel(spec)
    p = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    if client:
        p = jax.tree.map(lambda s: sds((client,) + s.shape, s.dtype), p)
    return p


def test_dense_tp_rules():
    p = abstract_params("smollm-135m", client=16)
    pps = sh.param_pspecs(p, tp=16, client_axes=("data",))
    # units stacked [N, U, ...]: wq shards its output dim when divisible
    wq = pps["units"]["attn"]["wq"]
    assert wq[0] == "data"
    emb = pps["frontend"]["embed"]
    assert emb[0] == "data" and emb[1] == "model"  # vocab sharded
    norm = pps["units"]["attn"]["norm"]
    assert norm[0] == "data" and all(e is None for e in norm[1:])


def test_wq_shards_when_divisible():
    # reduced smollm has tiny dims; check the full spec instead
    spec = get_spec("qwen2.5-14b")
    model = SplittableModel(spec)
    p = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    pps = sh.param_pspecs(p, tp=16, client_axes=None)
    assert pps["units"]["attn"]["wq"][-1] == "model"   # 5120 % 16 == 0
    assert pps["units"]["attn"]["wo"][-2] == "model"
    assert pps["units"]["mlp"]["w2"][-2] == "model"
    assert pps["frontend"]["embed"][0] == "model"


def test_moe_expert_parallelism():
    spec = get_spec("phi3.5-moe-42b-a6.6b")  # 16 experts == tp
    model = SplittableModel(spec)
    p = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    pps = sh.param_pspecs(p, tp=16, client_axes=None)
    w1 = pps["units"]["moe"]["w1"]
    assert w1[-3] == "model"  # expert axis
    spec32 = get_spec("granite-moe-1b-a400m")  # 32 experts
    p32 = jax.eval_shape(SplittableModel(spec32).init_params, jax.random.PRNGKey(0))
    assert sh.param_pspecs(p32, tp=16, client_axes=None)["units"]["moe"]["w1"][-3] == "model"


def test_indivisible_dims_stay_replicated():
    p = abstract_params("smollm-135m")  # reduced: d_model 576? reduced <=512
    pps = sh.param_pspecs(p, tp=16, client_axes=None)
    # reduced dims often don't divide 16; whatever doesn't divide is None
    def ok(path_pps, shapes):
        for ps, leaf in zip(jax.tree.leaves(path_pps, is_leaf=lambda x: isinstance(x, P)),
                            jax.tree.leaves(shapes)):
            for ax, name in zip(leaf.shape, ps):
                if name == "model":
                    assert ax % 16 == 0
    ok(pps, p)


def test_multipod_client_axes():
    p = abstract_params("qwen2-1.5b", client=32)
    pps = sh.param_pspecs(p, tp=16, client_axes=("pod", "data"))
    wq = pps["units"]["attn"]["wq"]
    assert wq[0] == ("pod", "data")


def test_batch_and_token_pspecs():
    batch = {"tokens": sds((16, 16, 128), jnp.int32)}
    bps = sh.batch_pspecs(batch, ("data",))
    assert bps["tokens"] == P("data", None, None)
    assert sh.token_pspec(128, ("data",)) == P("data", None)
    assert sh.token_pspec(1, ("data",)) == P(None, None)


def test_cache_pspecs_decode_vs_long():
    spec = get_spec("qwen3-32b")
    model = SplittableModel(spec)
    caches = jax.eval_shape(lambda: model.init_caches(128, 1024))
    cps = sh.cache_pspecs(caches, batch=128, client_axes=("data",), long_context=False)
    k = jax.tree_util.tree_map_with_path(lambda p, l: l, cps)
    # batch axis sharded in decode mode
    flat = jax.tree.leaves(cps, is_leaf=lambda x: isinstance(x, P))
    assert any("data" in [e for e in ps if e] for ps in flat if ps)
    long = sh.cache_pspecs(
        jax.eval_shape(lambda: model.init_caches(1, 1024)),
        batch=1, client_axes=("data",), long_context=True,
    )
    flatl = jax.tree.leaves(long, is_leaf=lambda x: isinstance(x, P))
    assert any("data" in [e for e in ps if e] for ps in flatl if ps)  # seq dim


def test_train_pspecs_client_axis_only():
    """The sharded training step (core.sharded) shards ONLY the client
    axis: no leaf may pick up a ``model`` entry (the param_pspecs(tp=1)
    pitfall its docstring documents)."""
    p = abstract_params("smollm-135m", client=8)
    pps = sh.train_pspecs(p, ("data",), num_clients=8)
    flat = jax.tree.leaves(pps, is_leaf=lambda x: isinstance(x, P))
    assert all(ps[0] == "data" for ps in flat)
    assert all(all(e is None for e in ps[1:]) for ps in flat)
    # multi-pod: the client axis spans both mesh axes
    pps2 = sh.train_pspecs(p, ("pod", "data"), num_clients=8)
    assert all(
        ps[0] == ("pod", "data")
        for ps in jax.tree.leaves(pps2, is_leaf=lambda x: isinstance(x, P))
    )


def test_train_pspecs_replicates_non_client_leaves():
    tree = {
        "stacked": sds((8, 3, 4), jnp.float32),
        "scalar": sds((), jnp.float32),
        "counter": sds((3,), jnp.int32),  # leading dim != num_clients
    }
    pps = sh.train_pspecs(tree, ("data",), num_clients=8)
    assert pps["stacked"] == P("data", None, None)
    assert pps["scalar"] == P()
    assert pps["counter"] == P()
    # num_clients=None: every non-scalar leaf is treated as stacked
    loose = sh.train_pspecs(tree, ("data",))
    assert loose["counter"] == P("data")


def test_make_debug_mesh_too_few_devices_fails_loudly():
    """The device count is fixed at backend init — asking for more must
    raise an actionable error, not silently build a smaller mesh (this
    test process initialized jax without XLA_FLAGS)."""
    import pytest

    from repro.launch.mesh import make_debug_mesh

    with pytest.raises(
        RuntimeError, match="xla_force_host_platform_device_count"
    ):
        make_debug_mesh(data=1024, model=2)


def test_opt_pspecs_follow_params():
    p = abstract_params("qwen2-1.5b", client=4)
    pps = sh.param_pspecs(p, tp=16, client_axes=("data",))
    assert sh.opt_pspecs(None, pps, "sgd") == ()
    assert sh.opt_pspecs(None, pps, "momentum") is pps
    a = sh.opt_pspecs(None, pps, "adam")
    assert a["m"] is pps and a["t"] == P()
