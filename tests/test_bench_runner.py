"""benchmarks.run harness scaffolding: --timeout must limit a harness
from ANY calling thread.

``signal.alarm`` only works on the main thread; driving ``main()``
programmatically from a worker (the --json CI wrappers, notebooks)
previously ran with no limit at all.  The watchdog fallback injects
``HarnessTimeout`` into the calling thread instead.
"""
import threading
import time

import pytest

from benchmarks.run import HarnessTimeout, _alarm


def test_alarm_disabled_at_zero():
    with _alarm(0):
        pass  # no signal handler touched, no watchdog spawned
    assert not [
        t for t in threading.enumerate() if t.name == "bench-watchdog"
    ]


def test_alarm_interrupts_main_thread():
    with pytest.raises(HarnessTimeout, match="exceeded --timeout 1s"):
        with _alarm(1):
            deadline = time.time() + 30
            while time.time() < deadline:
                time.sleep(0.01)
    # the alarm is cancelled on exit: nothing fires later
    with _alarm(1):
        pass


def test_alarm_interrupts_worker_thread():
    """Regression: a worker thread must get the watchdog fallback, not a
    silent no-limit run (signal.alarm would raise or be ignored there)."""
    out = {}

    def work():
        try:
            with _alarm(1):
                deadline = time.time() + 30
                while time.time() < deadline:
                    time.sleep(0.01)
                out["result"] = "ran to completion"
        except HarnessTimeout as e:
            out["result"] = "timeout"
            out["msg"] = str(e)
        except ValueError as e:  # what signal.signal raises off-main-thread
            out["result"] = f"signal error: {e}"

    t = threading.Thread(target=work)
    t.start()
    t.join(25)
    assert not t.is_alive()
    assert out.get("result") == "timeout", out
    assert "exceeded --timeout 1s" in out["msg"]
    # the watchdog cleaned up after itself
    assert not [
        w for w in threading.enumerate() if w.name == "bench-watchdog"
    ]


def test_worker_thread_within_budget_is_untouched():
    out = {}

    def work():
        with _alarm(30):
            out["result"] = sum(range(100))

    t = threading.Thread(target=work)
    t.start()
    t.join(10)
    assert out["result"] == 4950
    assert not [
        w for w in threading.enumerate() if w.name == "bench-watchdog"
    ]
