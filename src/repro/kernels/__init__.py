# Pallas TPU kernels for the compute/memory hot-spots HSFL owns:
#   tiered_aggregate -- fused two-level (Eq. 3 + Eq. 4) parameter aggregation
#   swa_attention    -- blocked sliding-window flash attention (long_500k path)
# Each package ships <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
# wrapper with custom_vjp where needed), and ref.py (pure-jnp oracle).
from .tiered_aggregate import tiered_aggregate, tiered_aggregate_ref
from .swa_attention import swa_attention, swa_attention_ref
