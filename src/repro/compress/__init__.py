# Communication compression, priced end-to-end (DESIGN.md §9):
#   base       -- Compressor protocol + the analytic CompressionSpec
#   identity   -- full-precision no-op codec (differential anchor)
#   quantize   -- stochastic int8 with per-tile scales (+ the shared wire
#                 format the fused Pallas aggregation kernel consumes)
#   topk       -- top-k sparsification + error-feedback accumulator
from .base import Compressor, CompressionSpec, act_ratio, measure_omega, model_ratio
from .identity import Identity
from .quantize import Int8Stochastic, q8_dequantize, q8_quantize
from .topk import ErrorFeedback, TopK

SCHEMES = {
    "identity": Identity,
    "int8": Int8Stochastic,
    "top-k": TopK,
}
