"""Compression subsystem: codec invariants, error feedback, CompressionSpec."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compress import (
    CompressionSpec, ErrorFeedback, Identity, Int8Stochastic, TopK,
    measure_omega, q8_dequantize, q8_quantize,
)


# --------------------------------------------------------------------------- #
# codecs
# --------------------------------------------------------------------------- #


def test_identity_is_exact():
    x = jax.random.normal(jax.random.PRNGKey(0), (257,))
    assert np.array_equal(np.asarray(Identity().transform(x)), np.asarray(x))


def test_q8_wire_roundtrip_shapes():
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 300))
    q, s = q8_quantize(x, tile=128)
    assert q.shape == (4, 384) and q.dtype == jnp.int8
    assert s.shape == (4, 3)
    deq = q8_dequantize(q, s, 128)
    # padding dequantizes to exactly zero, payload to within half an LSB
    assert np.all(np.asarray(deq[:, 300:]) == 0.0)
    lsb = np.asarray(s).max() / 1.0
    np.testing.assert_allclose(
        np.asarray(deq[:, :300]), np.asarray(x), atol=0.5 * lsb + 1e-7
    )


def test_q8_zero_tile_is_stable():
    x = jnp.zeros((2, 256))
    out = Int8Stochastic(tile=128).transform(x)
    assert np.all(np.isfinite(np.asarray(out)))
    assert np.array_equal(np.asarray(out), np.zeros((2, 256)))


def test_int8_deterministic_vs_stochastic():
    c = Int8Stochastic(tile=128)
    x = jax.random.normal(jax.random.PRNGKey(2), (1000,))
    det = c.transform(x)
    assert np.array_equal(np.asarray(det), np.asarray(c.transform(x)))
    sto = c.transform(x, key=jax.random.PRNGKey(3))
    assert not np.array_equal(np.asarray(det), np.asarray(sto))


def test_int8_stochastic_is_unbiased():
    """E[Q(x)] = x: the empirical mean over keys approaches x at ~1/sqrt(K)."""
    c = Int8Stochastic(tile=256)
    x = jax.random.normal(jax.random.PRNGKey(0), (512,))
    K = 400
    acc = np.zeros(512)
    for i in range(K):
        acc += np.asarray(c.transform(x, key=jax.random.PRNGKey(i + 1)))
    lsb = float(jnp.max(jnp.abs(x))) / 127.0
    # stochastic-rounding std per draw is <= lsb/2 -> mean err ~ lsb/(2*sqrt(K))
    assert np.abs(acc / K - np.asarray(x)).max() < 5.0 * lsb / np.sqrt(K)


def test_declared_omega_bounds_measured():
    for codec in (Int8Stochastic(tile=256), TopK(0.25), TopK(0.05)):
        measured = measure_omega(codec, shape=(4096,), samples=4)
        assert measured <= codec.omega, (codec.name, measured, codec.omega)
    assert measure_omega(Identity(), shape=(256,), samples=2) == 0.0


def test_topk_keeps_the_k_largest():
    t = TopK(0.25)
    x = jax.random.normal(jax.random.PRNGKey(5), (512,))
    xh = np.asarray(t.transform(x))
    kept = np.nonzero(xh)[0]
    assert len(kept) == t.k_for(512) == 128
    xs = np.abs(np.asarray(x))
    assert xs[kept].min() >= np.sort(xs)[-128]  # kept are the largest |x|
    np.testing.assert_array_equal(xh[kept], np.asarray(x)[kept])


def test_ratios_are_sane():
    assert Identity().ratio == 1.0
    assert 0.25 < Int8Stochastic(tile=256).ratio < 0.27
    assert TopK(0.25).ratio == 0.5
    assert TopK(0.9).ratio == 1.0  # value+index never beats raw past 1/2


# --------------------------------------------------------------------------- #
# error feedback
# --------------------------------------------------------------------------- #


def test_error_feedback_accounts_for_every_bit():
    """Residual == cumulative input − cumulative emitted, exactly the EF
    invariant; and it stays bounded instead of growing with the horizon."""
    ef = ErrorFeedback(TopK(0.1))
    d = 256
    r = ef.init(jnp.zeros(d))
    tot_in = np.zeros(d)
    tot_out = np.zeros(d)
    norms = []
    for i in range(60):
        x = jax.random.normal(jax.random.PRNGKey(100 + i), (d,))
        xh, r = ef.step(r, x)
        tot_in += np.asarray(x)
        tot_out += np.asarray(xh)
        norms.append(float(np.linalg.norm(np.asarray(r))))
    np.testing.assert_allclose(tot_in - tot_out, np.asarray(r), atol=1e-4)
    # bounded residual: the second half never exceeds 3x the first-half max
    assert max(norms[30:]) <= 3.0 * max(norms[:30])


def test_error_feedback_recovers_constant_signal():
    """With a constant input, plain top-k forever drops the small coords;
    EF's cumulative output still converges to the cumulative input."""
    t = TopK(0.1)
    x = jax.random.normal(jax.random.PRNGKey(7), (200,))
    ef = ErrorFeedback(t)
    r = ef.init(x)
    out = np.zeros(200)
    T = 50
    for _ in range(T):
        xh, r = ef.step(r, x)
        out += np.asarray(xh)
    plain = T * np.asarray(t.transform(x))
    ef_err = np.linalg.norm(out - T * np.asarray(x))
    plain_err = np.linalg.norm(plain - T * np.asarray(x))
    # plain top-k error grows like T; EF's equals ||residual|| = O(1),
    # within a small constant of a single round's error
    assert ef_err < 10.0 * plain_err / T
    assert ef_err < 0.2 * plain_err


# --------------------------------------------------------------------------- #
# CompressionSpec
# --------------------------------------------------------------------------- #


def test_compression_spec_validation():
    assert CompressionSpec.identity(3).omega == 0.0
    s = CompressionSpec.uniform(3, model_ratio=0.25, act_ratio=0.5, omega=0.1)
    assert s.model_ratio == (0.25, 0.25) and s.act_ratio == (0.5, 0.5)
    with pytest.raises(ValueError):
        CompressionSpec.uniform(3, model_ratio=0.0)
    with pytest.raises(ValueError):
        CompressionSpec.uniform(3, model_ratio=1.5)
    with pytest.raises(ValueError):
        CompressionSpec((1.0, 1.0), (1.0, 1.0), omega=-0.1)


def test_schemes_registry_covers_codecs():
    from repro.compress import SCHEMES

    assert set(SCHEMES) == {"identity", "int8", "top-k"}
    for name, cls in SCHEMES.items():
        codec = cls()
        assert codec.name == name
        assert callable(codec.transform)
        assert 0.0 < codec.ratio <= 1.0 and codec.omega >= 0.0


def test_compression_spec_arity_checked_at_attachment():
    from repro.core import HsflProblem, SystemSpec, build_profile, synthetic_hyperspec
    from repro.configs.vgg16_cifar10 import SPEC as VGG

    prob = HsflProblem(
        build_profile(VGG, batch=2),
        SystemSpec.paper_three_tier(num_clients=4, num_edges=2),
        synthetic_hyperspec(VGG.n_units, 4),
        eps=1.0,
    )
    spec2 = CompressionSpec.uniform(3, 0.5)
    assert spec2.validate_for(3) is spec2
    assert prob.with_compression(spec2).compression is spec2
    with pytest.raises(ValueError):
        prob.with_compression(CompressionSpec((0.5,), (0.5,)))  # M=2 spec
