"""End-to-end HSFL training driver (CPU-runnable).

Wires every substrate together: synthetic data → non-IID partitioner →
federated loader → Engine A split training with the multi-timescale
aggregation schedule → bound-constant estimation → BCD (Algorithm 2)
re-optimization of (I, μ) → checkpointing.

    PYTHONPATH=src python -m repro.launch.train --arch vgg16-cifar10 \
        --rounds 300 --non-iid --auto-optimize

``--arch vgg16-cifar10`` reproduces the paper's own setting; any of the 10
assigned architecture ids runs its REDUCED variant on an LM stream.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="vgg16-cifar10")
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--clients", type=int, default=20)
    ap.add_argument("--edges", type=int, default=5)
    ap.add_argument("--lr", type=float, default=5e-4)
    ap.add_argument("--optimizer", choices=["sgd", "momentum", "adam"], default="sgd")
    ap.add_argument("--non-iid", action="store_true")
    ap.add_argument("--cuts", type=int, nargs="*", default=None)
    ap.add_argument("--intervals", type=int, nargs="*", default=None)
    ap.add_argument("--auto-optimize", action="store_true",
                    help="estimate bound constants from a probe run and let "
                         "BCD (Algorithm 2) pick (I, mu)")
    ap.add_argument("--probe-rounds", type=int, default=8)
    ap.add_argument("--eps-scale", type=float, default=4.0,
                    help="target eps as a multiple of the I=1 bound floor")
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from ..configs import get_reduced
    from ..core import (
        HsflProblem, SystemSpec, TierPlan, build_profile, build_train_step_a,
        init_state_a, solve_bcd,
    )
    from ..core.estimator import HyperEstimator
    from ..core.tiers import default_plan
    from ..data import (
        lm_loader, image_loader, make_cifar10_like, make_lm_stream,
        partition_iid, partition_sort_and_shard,
    )
    from ..models.vgg import build_model
    from ..optim import adam, momentum, sgd

    opt = {"sgd": sgd, "momentum": momentum, "adam": adam}[args.optimizer](args.lr)

    if args.arch == "vgg16-cifar10":
        from ..configs.vgg16_cifar10 import SPEC as spec
        ds = make_cifar10_like(4096, seed=args.seed)
        labels = ds.labels
        mk_loader = lambda parts: image_loader(ds, parts, args.batch, args.seed)
    else:
        spec = get_reduced(args.arch)
        ds = make_lm_stream(2048, 64, spec.vocab_size, seed=args.seed)
        labels = ds.tokens[:, 0] % 10
        mk_loader = lambda parts: lm_loader(ds, parts, args.batch, args.seed)
        if spec.family in ("vlm", "audio"):
            raise SystemExit(
                f"{args.arch}: frontend is a stub; use examples/train_hsfl_e2e.py "
                "with dense/moe/ssm/hybrid archs or vgg16-cifar10"
            )

    parts = (
        partition_sort_and_shard(labels, args.clients, 2, args.seed)
        if args.non_iid
        else partition_iid(len(labels), args.clients, args.seed)
    )
    loader = mk_loader(parts)
    model = build_model(spec)
    plan = default_plan(
        spec.n_units, args.clients,
        cuts=tuple(args.cuts) if args.cuts else None,
        intervals=tuple(args.intervals) + (1,) if args.intervals else None,
        entities=(args.clients, args.edges, 1),
    )

    def make_dispatch(plan_):
        """Specialized per-round-type steps (see tiers.synchronize): the
        fed-server collectives only exist in the (rare) sync-round programs,
        so the hot path never pays for them."""
        cache = {}

        def dispatch(state_, batch_, r):
            fed = tuple((r + 1) % I == 0 if I > 1 else True
                        for I in plan_.intervals)
            if fed not in cache:
                cache[fed] = jax.jit(
                    build_train_step_a(model, plan_, opt, fed_round=fed)
                )
            return cache[fed](state_, batch_)

        return dispatch

    key = jax.random.PRNGKey(args.seed)
    state = init_state_a(model, plan, opt, key)
    step = jax.jit(build_train_step_a(model, plan, opt))

    if args.auto_optimize:
        print(f"[probe] estimating bound constants over {args.probe_rounds} rounds")
        est = HyperEstimator(plan.n_units, args.clients, args.lr)
        grad_fn = jax.jit(lambda p, b: jax.vmap(jax.value_and_grad(model.loss_fn))(p, b))
        pstate = state
        for _ in range(args.probe_rounds):
            batch = {k: jnp.asarray(v) for k, v in loader.next_round().items()}
            losses, grads = grad_fn(pstate.params, batch)
            est.observe(pstate.params, grads, float(jnp.mean(losses)))
            pstate, _ = step(pstate, batch)
        hp = est.hyperspec()
        prof = build_profile(spec, args.batch, seq=64 if args.arch != "vgg16-cifar10" else 1)
        system = SystemSpec.paper_three_tier(args.clients, args.edges, seed=args.seed)
        from ..core.convergence import theorem1_bound
        floor = theorem1_bound(hp, 10**9, [1] * plan.M, plan.cuts)
        prob = HsflProblem(prof, system, hp, eps=args.eps_scale * floor)
        res = solve_bcd(prob)
        print(f"[bcd] cuts={res.cuts} intervals={res.intervals} "
              f"theta={res.theta:.4g} R={res.rounds:.0f} T={res.total_latency:.1f}s")
        plan = default_plan(
            spec.n_units, args.clients, cuts=res.cuts,
            intervals=res.intervals, entities=(args.clients, args.edges, 1),
        )
        step = jax.jit(build_train_step_a(model, plan, opt))

    print(f"[train] arch={spec.name} units={spec.n_units} plan cuts={plan.cuts} "
          f"I={plan.intervals} N={args.clients} J2={args.edges}")
    dispatch = make_dispatch(plan)
    t0 = time.time()
    for r in range(args.rounds):
        batch = {k: jnp.asarray(v) for k, v in loader.next_round().items()}
        state, loss = dispatch(state, batch, r)
        if (r + 1) % args.log_every == 0 or r == 0:
            print(f"round {r+1:5d}  loss {float(loss):.4f}  "
                  f"({(time.time()-t0)/(r+1):.2f}s/round)")

    if args.checkpoint:
        from ..checkpoint import save_checkpoint

        save_checkpoint(
            args.checkpoint, state.params, step=int(state.step),
            meta={"cuts": list(plan.cuts), "intervals": list(plan.intervals)},
        )
        print(f"saved checkpoint -> {args.checkpoint}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
