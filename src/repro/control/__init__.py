"""Online adaptive control: re-solve (cut, I, μ, deadline) mid-run from
observed fleet telemetry (DESIGN.md §13).

The loop: ``telemetry.observe_round`` measures a round →
``Controller.observe`` folds it into the windowed system estimate
(``window.WindowedLatency`` + windowed participation) →
``Controller.maybe_replan`` detects drift against the currently-priced
model (``drift``) and re-solves BCD warm-started from the previous
optimum → the training loop migrates engine state across the switch
(``migrate``) → ``bound.piecewise_bound`` composes Theorem 1 across the
segments.  ``replay`` replays the whole loop analytically over a trace
for time-to-ε comparisons (``benchmarks/control_drift.py``).
"""
from .bound import (
    BoundSegment,
    piecewise_bound,
    progress_per_round,
    progress_target,
)
from .controller import ControlDecision, Controller
from .drift import DriftReport, detect_drift
from .migrate import (
    migrate_params_a,
    migrate_params_b,
    migrate_state,
    migrate_state_a,
    migrate_state_b,
    resume_with_migration,
)
from .replay import ReplayResult, replay
from .telemetry import RoundObservation, observe_round, reconstruct_state
from .window import WindowedLatency

__all__ = [
    "BoundSegment",
    "piecewise_bound",
    "progress_per_round",
    "progress_target",
    "ControlDecision",
    "Controller",
    "DriftReport",
    "detect_drift",
    "migrate_params_a",
    "migrate_params_b",
    "migrate_state",
    "migrate_state_a",
    "migrate_state_b",
    "resume_with_migration",
    "ReplayResult",
    "replay",
    "RoundObservation",
    "observe_round",
    "reconstruct_state",
    "WindowedLatency",
]
