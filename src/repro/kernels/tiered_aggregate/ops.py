"""jit'd public wrapper: apply the fused aggregation to whole pytrees.

``aggregate_tree`` flattens a client-stacked pytree (leaves [N, ...]) into
one [N, P] buffer view per leaf, runs the kernel, and reassembles —
exactly what ``tiers.synchronize`` does per (tier, level), but in one fused
HBM pass per leaf. On CPU (tests / this container) ``interpret=True`` runs
the same kernel body in Python; on TPU set ``interpret=False``.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict

import jax
import jax.numpy as jnp

from .ref import tiered_aggregate_ref
from .tiered_aggregate import tiered_aggregate_pallas


@partial(jax.jit, static_argnames=("num_entities", "use_pallas", "interpret"))
def tiered_aggregate(
    x: jax.Array,
    weights: jax.Array,
    do_entity: jax.Array,
    do_global: jax.Array,
    num_entities: int,
    use_pallas: bool = True,
    interpret: bool = True,
) -> jax.Array:
    """[N, P] fused two-level aggregation (see ref.py for semantics)."""
    do_entity = jnp.asarray(do_entity)
    do_global = jnp.asarray(do_global)
    if use_pallas:
        return tiered_aggregate_pallas(
            x, weights, do_entity, do_global, num_entities, interpret=interpret
        )
    return tiered_aggregate_ref(x, weights, do_entity, do_global, num_entities)


def aggregate_tree(
    tree: Any,
    weights: jax.Array,
    do_entity: jax.Array,
    do_global: jax.Array,
    num_entities: int,
    use_pallas: bool = True,
    interpret: bool = True,
) -> Any:
    """Apply the fused aggregation leaf-wise to a client-stacked pytree."""

    def f(x):
        n = x.shape[0]
        flat = x.reshape(n, -1)
        out = tiered_aggregate(
            flat, weights, do_entity, do_global, num_entities,
            use_pallas=use_pallas, interpret=interpret,
        )
        return out.reshape(x.shape)

    return jax.tree.map(f, tree)
