"""Named ``ExperimentSpec`` presets — the repo's canonical setups as data.

``paper_spec`` is the Sec. VII experimental system (the problem every
Fig. 2/4–9 harness prices); the ``EXPERIMENTS`` registry maps short names
to zero-argument spec factories so drivers can look setups up by string.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

from .spec import (
    ClassesCfg,
    CompressionCfg,
    EnergyCfg,
    ExperimentSpec,
    FaultsCfg,
    HyperCfg,
    ModelCfg,
    ParticipationCfg,
    PrivacyCfg,
    RunCfg,
    ScenarioCfg,
    SolverCfg,
    SystemCfg,
)


def paper_spec(
    seed: int = 0,
    eps_scale: float = 6.0,
    compute_scale: float = 1.0,
    comm_scale: float = 1.0,
    batch: int = 16,
    mode: str = "solve",
) -> ExperimentSpec:
    """The paper's Sec. VII setting: VGG-16 on the 20-client/5-edge WAN
    system, β=3 synthetic Theorem-1 constants, ε = eps_scale × the I=1
    floor — the canonical problem every benchmark harness builds from
    (``build(paper_spec(...)).problem``)."""
    return ExperimentSpec(
        name="paper-sec7",
        model=ModelCfg(arch="vgg16-cifar10", batch=batch),
        system=SystemCfg(
            preset="paper-three-tier",
            num_clients=20,
            num_edges=5,
            seed=seed,
            compute_scale=compute_scale,
            comm_scale=comm_scale,
        ),
        hyper=HyperCfg(beta=3.0, seed=seed, eps_scale=eps_scale),
        solver=SolverCfg(kind="bcd"),
        run=RunCfg(mode=mode, seed=seed),
    )


def two_tier_spec(
    kind: str,
    seed: int = 0,
    eps_scale: float = 6.0,
    compute_scale: float = 1.0,
    comm_scale: float = 1.0,
) -> ExperimentSpec:
    """Fig. 7 baselines: two-tier client-edge / client-cloud SFL."""
    if kind not in ("client-edge", "client-cloud"):
        raise ValueError(f"kind must be client-edge|client-cloud: {kind!r}")
    return ExperimentSpec(
        name=f"two-tier-{kind}",
        model=ModelCfg(arch="vgg16-cifar10", batch=16),
        system=SystemCfg(
            preset=f"two-tier-{kind}",
            num_clients=20,
            num_edges=5 if kind == "client-edge" else 1,
            seed=seed,
            compute_scale=compute_scale,
            comm_scale=comm_scale,
        ),
        hyper=HyperCfg(beta=3.0, seed=seed, eps_scale=eps_scale),
        solver=SolverCfg(kind="bcd"),
        run=RunCfg(mode="solve", seed=seed),
    )


def tpu_pod_spec(seed: int = 0, eps: float = 2.0) -> ExperimentSpec:
    """The same model priced on TPU v5e ICI/DCN links (DESIGN.md §2)."""
    return ExperimentSpec(
        name="tpu-pod",
        model=ModelCfg(arch="vgg16-cifar10", batch=16),
        system=SystemCfg(preset="tpu-pod", num_clients=16, num_edges=4),
        hyper=HyperCfg(seed=seed, eps=eps),
        solver=SolverCfg(kind="bcd"),
        run=RunCfg(mode="solve", seed=seed),
    )


def robust_spec(
    scenario: str,
    seed: int = 0,
    eps_scale: float = 6.0,
    rounds: int = 64,
    quantile: float = 0.95,
) -> ExperimentSpec:
    """Paper problem priced at a fleet-sim regime's q-quantile latencies."""
    base = paper_spec(seed=seed, eps_scale=eps_scale)
    return base.replace(
        name=f"robust-{scenario}",
        scenario=ScenarioCfg(
            name=scenario, rounds=rounds, seed=seed, quantile=quantile
        ),
    )


def participation_spec(
    scenario: str = "straggler-tail",
    target_rate: float = 0.75,
    seed: int = 0,
    eps_scale: float = 6.0,
    rounds: int = 64,
) -> ExperimentSpec:
    """Paper problem under a straggler deadline (DESIGN.md §12): the round
    barrier sits at the scenario's pooled ``target_rate`` finish-time
    quantile, latency terms become deadline-capped trace expectations, and
    the bound inflates by the estimated 1/q_m."""
    base = paper_spec(seed=seed, eps_scale=eps_scale)
    return base.replace(
        name=f"participation-{scenario}",
        scenario=ScenarioCfg(name=scenario, rounds=rounds, seed=seed),
        participation=ParticipationCfg(target_rate=target_rate),
    )


def quickstart_spec(seed: int = 0, rounds: int = 30) -> ExperimentSpec:
    """The README quickstart: reduced smollm trained across 8→4→1 tiers."""
    return ExperimentSpec(
        name="quickstart",
        model=ModelCfg(
            arch="smollm-135m", variant="reduced", num_layers=4, batch=4, seq=32
        ),
        system=SystemCfg(
            preset="paper-three-tier", num_clients=8, num_edges=4, seed=seed
        ),
        hyper=HyperCfg(seed=seed),
        solver=SolverCfg(kind="fixed", cuts=(1, 3), intervals=(4, 2, 1)),
        run=RunCfg(mode="train", seed=seed, rounds=rounds, lr=0.1),
    )


def hetcuts_spec(
    num_classes: int = 2,
    by: str = "uplink",
    seed: int = 0,
    eps_scale: float = 10.0,
    compute_sigma: float = 0.5,
    link_sigma: float = 0.6,
) -> ExperimentSpec:
    """Per-class cut assignment on the statically heterogeneous fleet
    (DESIGN.md §14): clients banded by fed-uplink rate each get their own
    split vector; ``num_classes=1`` collapses bit-exactly to the
    single-cut BCD optimum."""
    return ExperimentSpec(
        name=f"hetcuts-c{num_classes}-{by}",
        model=ModelCfg(arch="vgg16-cifar10", batch=16),
        system=SystemCfg(
            preset="lognormal-fleet",
            num_clients=20,
            num_edges=5,
            seed=seed,
            extras={"compute_sigma": compute_sigma, "link_sigma": link_sigma},
        ),
        hyper=HyperCfg(beta=3.0, seed=seed, eps_scale=eps_scale),
        solver=SolverCfg(kind="bcd"),
        run=RunCfg(mode="solve", seed=seed),
        classes=ClassesCfg(num_classes=num_classes, by=by),
    )


def compressed_spec(
    codec: str = "int8",
    seed: int = 0,
    eps_scale: float = 6.0,
    model_ratio=None,
    omega=None,
    **params,
) -> ExperimentSpec:
    """Paper problem over a compressed fed-server wire."""
    base = paper_spec(seed=seed, eps_scale=eps_scale)
    return base.replace(
        name=f"compressed-{codec}",
        compression=CompressionCfg(
            codec=codec, params=dict(params), model_ratio=model_ratio, omega=omega
        ),
    )


def privacy_energy_spec(
    seed: int = 0,
    eps_scale: float = 6.0,
    noise_multiplier: float = 8.0,
    clip: float = 1e-4,
    epsilon_budget: Optional[float] = None,
    budget_j_per_round: Optional[float] = None,
) -> ExperimentSpec:
    """Paper problem with DP-noised uplinks and per-tier energy pricing
    (DESIGN.md §15): the Gaussian mechanism (z, C) noises the Engine-A
    fed wire, the RDP accountant turns ``epsilon_budget`` into a round
    cap the BCD solvers honour, and the energy tables price every
    (I, μ) with ``budget_j_per_round`` as a feasibility constraint."""
    base = paper_spec(seed=seed, eps_scale=eps_scale)
    return base.replace(
        name="privacy-energy",
        privacy=PrivacyCfg(
            noise_multiplier=noise_multiplier,
            clip=clip,
            epsilon_budget=epsilon_budget,
        ),
        energy=EnergyCfg(budget_j_per_round=budget_j_per_round),
    )


def fault_storm_spec(
    seed: int = 0,
    rounds: int = 40,
    crash_rate: float = 0.08,
    corrupt_rate: float = 0.08,
    corrupt_mode: str = "nan",
    link_fail_rate: float = 0.15,
    checkpoint_every: int = 10,
    engine_crash_round: Optional[int] = None,
) -> ExperimentSpec:
    """The fault-tolerance drill (DESIGN.md §16): the quickstart training
    run under a simultaneous crash + corrupt-update + retried-link +
    cell-outage storm, on the flaky-wan fleet.  Crashed clients drop out
    of the round mask, corrupt replicas are quarantined by the guarded
    sync, retries re-price every link, the dead cell's clients reroute to
    siblings, and the Theorem-1 bound runs on fault-deflated q_m —
    ``benchmarks/fault_tolerance.py`` checks it still envelopes the
    realized loss."""
    return ExperimentSpec(
        name="fault-storm",
        model=ModelCfg(
            arch="smollm-135m", variant="reduced", num_layers=4, batch=4, seq=32
        ),
        system=SystemCfg(
            preset="paper-three-tier", num_clients=8, num_edges=4, seed=seed
        ),
        hyper=HyperCfg(seed=seed),
        solver=SolverCfg(kind="fixed", cuts=(1, 3), intervals=(4, 2, 1)),
        run=RunCfg(mode="train", seed=seed, rounds=rounds, lr=0.1),
        scenario=ScenarioCfg(name="flaky-wan", rounds=rounds, seed=seed),
        faults=FaultsCfg(
            seed=seed,
            crash_rate=crash_rate,
            corrupt_rate=corrupt_rate,
            corrupt_mode=corrupt_mode,
            link_fail_rate=link_fail_rate,
            link_retries=2,
            outage_cells=(0,),
            outage_tier=1,
            outage_start=rounds // 4,
            outage_len=max(1, rounds // 8),
            checkpoint_every=checkpoint_every,
            engine_crash_round=engine_crash_round,
        ),
    )


EXPERIMENTS: Dict[str, Callable[[], ExperimentSpec]] = {
    "paper-sec7": paper_spec,
    "tpu-pod": tpu_pod_spec,
    "quickstart": quickstart_spec,
    "robust-straggler-tail": lambda: robust_spec("straggler-tail"),
    "participation-straggler-tail": lambda: participation_spec("straggler-tail"),
    "compressed-int8": lambda: compressed_spec("int8"),
    "hetcuts-lognormal": hetcuts_spec,
    "privacy-energy": privacy_energy_spec,
    "fault-storm": fault_storm_spec,
}


def register_experiment(name: str, factory: Callable[[], ExperimentSpec]) -> None:
    EXPERIMENTS[name] = factory


def get_experiment(name: str) -> ExperimentSpec:
    try:
        return EXPERIMENTS[name]()
    except KeyError:
        raise KeyError(
            f"unknown experiment {name!r}; available: {sorted(EXPERIMENTS)}"
        ) from None
