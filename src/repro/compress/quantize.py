"""Stochastic int8 quantization with per-tile f32 scales.

Wire format (shared verbatim with the fused Pallas aggregation path in
``kernels/tiered_aggregate``): a tensor is flattened, zero-padded to a
multiple of ``tile``, and every tile carries ``tile`` int8 values plus one
f32 scale ``s = max|x| / 127`` — so the wire is ``(tile + 4)`` bytes per
``4·tile`` raw bytes, ≈ 4× smaller.

Rounding is nearest (deterministic) without a key and stochastic
(``floor(y + u)``, unbiased: E[Q(x)] = x) with one.  Either way the
round-off error is at most half an LSB per element, giving the worst-case
relative second moment

    ω  =  sup_x ‖Q(x) − x‖² / ‖x‖²  ≤  tile / (4 · 127²)

since ‖e‖² ≤ d·s²/4 per tile and ‖x‖² ≥ (127·s)² whenever the tile is
non-zero.  That ω is what the convergence side prices (DESIGN.md §9).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

QMAX = 127.0


def q8_quantize(
    x: jax.Array, tile: int, key: Optional[jax.Array] = None
) -> Tuple[jax.Array, jax.Array]:
    """[N, P] f32 → (int8 values [N, Pp], f32 scales [N, Pp/tile]).

    Pp = P rounded up to a multiple of ``tile`` (zero padding; zeros
    quantize to zero and never move a tile's abs-max).
    """
    N, P = x.shape
    pad = (-P) % tile
    xp = jnp.pad(x, ((0, 0), (0, pad))) if pad else x
    T = xp.shape[1] // tile
    xt = xp.astype(jnp.float32).reshape(N, T, tile)
    absmax = jnp.max(jnp.abs(xt), axis=-1)
    scales = jnp.where(absmax > 0.0, absmax / QMAX, 1.0)
    y = xt / scales[..., None]
    if key is None:
        q = jnp.round(y)
    else:
        u = jax.random.uniform(key, y.shape)
        q = jnp.floor(y + u)
    q = jnp.clip(q, -QMAX, QMAX).astype(jnp.int8)
    return q.reshape(N, T * tile), scales


def q8_dequantize(q: jax.Array, scales: jax.Array, tile: int) -> jax.Array:
    """Inverse wire map: (int8 [N, Pp], scales [N, T]) → f32 [N, Pp]."""
    N, Pp = q.shape
    qt = q.reshape(N, Pp // tile, tile).astype(jnp.float32)
    return (qt * scales[..., None]).reshape(N, Pp)


@dataclass(frozen=True)
class Int8Stochastic:
    """Per-tile-scaled int8 codec (see module docstring for ω derivation)."""

    tile: int = 256
    name: str = "int8"

    @property
    def ratio(self) -> float:
        # int8 payload + one f32 scale per tile, over 4 bytes per element
        return (self.tile + 4.0) / (4.0 * self.tile)

    @property
    def omega(self) -> float:
        return self.tile / (4.0 * QMAX * QMAX)

    def transform(self, x: jax.Array, key: Optional[jax.Array] = None) -> jax.Array:
        flat = x.reshape(1, -1)
        q, scales = q8_quantize(flat, self.tile, key=key)
        deq = q8_dequantize(q, scales, self.tile)
        return deq[:, : flat.shape[1]].reshape(x.shape).astype(x.dtype)
