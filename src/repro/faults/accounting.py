"""Fault accounting: deflate the effective q_m fed to Theorem 1.

A detected fault is a lost contribution: a crashed client uploads nothing,
a quarantined (corrupt) client is zeroed out of its group by the guard,
and a cell outage silences a whole fed entity plus its clients.  All three
are *exactly* partial participation in the Theorem-1 sense — the round
averages over fewer gradients and tier syncs land on fewer entities — so
the honest bound is the PR 5 machinery with q_m multiplied by the fault
survival rate (DESIGN.md §16):

    q_m^eff = q_m^base · s_m,   s_m = E_r[ fraction of tier-m entities
                                           with ≥1 healthy participant ]

``fault_survival`` computes s_m from the spec's own seeded expansion over
the run's rounds (the realized masks, not a closed form — bitflips of the
actual streams are what training will see); ``deflate_participation``
folds it into a ``ParticipationSpec``.  A null spec returns the base spec
object unchanged (bit-exact zero-fault collapse).
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from ..core.convergence import ParticipationSpec, participation_rates
from .spec import FaultSpec, expand_faults


def round_healthy(
    spec: FaultSpec, r: int, num_clients: int, entities: Tuple[int, ...]
) -> np.ndarray:
    """[N] bool — clients whose round-r contribution survives the faults
    (not crashed, not corrupt, not served by a dead cell)."""
    rf = expand_faults(spec, r, num_clients)
    healthy = ~rf.faulty
    if rf.cell_out:
        J = entities[spec.outage_tier]
        per = num_clients // J
        cell_of = np.repeat(np.arange(J), per)
        healthy &= ~np.isin(cell_of, np.asarray(spec.outage_cells))
    return healthy


def fault_survival(
    spec: FaultSpec,
    num_clients: int,
    entities: Tuple[int, ...],
    rounds: int,
) -> np.ndarray:
    """[M] mean per-tier entity survival over the run's realized faults.

    Tier m's per-round rate is the fraction of its entities holding at
    least one healthy client — the same entity-participation convention
    ``sim.participation`` uses for deadline misses, so fault deflation
    and straggler deflation compose multiplicatively.
    """
    if rounds <= 0:
        raise ValueError(f"rounds must be > 0: {rounds}")
    M = len(entities)
    if spec.is_null:
        return np.ones(M)
    acc = np.zeros(M)
    for r in range(rounds):
        healthy = round_healthy(spec, r, num_clients, entities)
        for m, J in enumerate(entities):
            per = num_clients // J
            acc[m] += healthy.reshape(J, per).any(axis=1).mean()
    return acc / rounds


def deflate_participation(
    base: Optional[ParticipationSpec],
    spec: Optional[FaultSpec],
    num_clients: int,
    entities: Tuple[int, ...],
    rounds: int,
) -> Optional[ParticipationSpec]:
    """The participation spec with fault survival multiplied in.

    Returns ``base`` itself for a null/absent fault spec.  Raises when a
    tier's survival hits zero — every round lost a whole tier (the
    all-faulty degenerate input), for which no finite 1/q inflation
    exists.
    """
    if spec is None or spec.is_null:
        return base
    M = len(entities)
    s = fault_survival(spec, num_clients, entities, rounds)
    if np.any(s <= 0.0):
        dead = [m for m in range(M) if s[m] <= 0.0]
        raise ValueError(
            f"all-faulty rounds: tier(s) {dead} have zero surviving "
            "entities across the whole run — the 1/q_m bound inflation "
            "is undefined; lower the fault rates or shorten the outage"
        )
    q = participation_rates(base, M) * s
    deadline = base.deadline if base is not None else None
    return ParticipationSpec(q=tuple(float(v) for v in q), deadline=deadline)
