"""Engine A (sync-groups, production) == Engine B (split-placement, literal
SFL dataflow): identical losses and parameters after every step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.configs.shapes import concrete_inputs
from repro.core import build_train_step_a, build_train_step_b, init_state_a, init_state_b
from repro.core.engine import engine_b_to_full
from repro.core.tiers import default_plan

# multi-arch jit compiles dominate (~2 min total): out of the CI fast subset
pytestmark = pytest.mark.slow
from repro.models.model import SplittableModel
from repro.optim import sgd


@pytest.mark.parametrize(
    "arch,cuts,intervals",
    [
        ("smollm-135m", (1, 2), (3, 2, 1)),
        ("qwen2-1.5b", (1, 1), (2, 4, 1)),
        ("mamba2-1.3b", (1, 2), (2, 2, 1)),
        ("granite-moe-1b-a400m", (1, 2), (2, 3, 1)),  # MoE: dispatch+aux path
        ("jamba-1.5-large-398b", (1, 1), (4, 2, 1)),  # hybrid super-blocks
    ],
)
def test_engines_match(arch, cuts, intervals):
    spec = get_reduced(arch)
    model = SplittableModel(spec)
    N = 8
    plan = default_plan(
        spec.n_units, N, cuts=cuts, intervals=intervals, entities=(N, 4, 1)
    )
    opt = sgd(1e-2)
    key = jax.random.PRNGKey(0)
    sa = init_state_a(model, plan, opt, key)
    sb = init_state_b(model, plan, opt, key)
    step_a = jax.jit(build_train_step_a(model, plan, opt))
    step_b = jax.jit(build_train_step_b(model, plan, opt))
    for t in range(4):
        batch = concrete_inputs(spec, N * 2, 16, jax.random.PRNGKey(t))
        batch = {k: v.reshape(N, 2, *v.shape[1:]) for k, v in batch.items()}
        sa, la = step_a(sa, batch)
        sb, lb = step_b(sb, batch)
        assert np.allclose(float(la), float(lb), rtol=1e-5)
        full_b = engine_b_to_full(model, plan, sb.params)
        for a, b in zip(jax.tree.leaves(sa.params), jax.tree.leaves(full_b)):
            np.testing.assert_allclose(a, b, atol=5e-6, rtol=1e-4)


# --------------------------------------------------------------------------- #
# compressed fed-server wire: both engines apply the shared transform at the
# same point, so they stay equal under every codec (DESIGN.md §9)
# --------------------------------------------------------------------------- #


def _run_engine(engine, model, plan, opt, spec, compressor, steps=3):
    key = jax.random.PRNGKey(0)
    if engine == "a":
        s = init_state_a(model, plan, opt, key)
        step = jax.jit(build_train_step_a(model, plan, opt, compressor=compressor))
    else:
        s = init_state_b(model, plan, opt, key)
        step = jax.jit(build_train_step_b(model, plan, opt, compressor=compressor))
    losses = []
    for t in range(steps):
        batch = concrete_inputs(spec, plan.num_clients * 2, 16, jax.random.PRNGKey(t))
        batch = {
            k: v.reshape(plan.num_clients, 2, *v.shape[1:]) for k, v in batch.items()
        }
        s, loss = step(s, batch)
        losses.append(float(loss))
    return s, losses


def _compressed_setup():
    spec = get_reduced("smollm-135m")
    model = SplittableModel(spec)
    N = 8
    plan = default_plan(
        spec.n_units, N, cuts=(1, 2), intervals=(2, 2, 1), entities=(N, 4, 1)
    )
    return spec, model, plan, sgd(1e-2)


def test_identity_compressor_is_bit_exact():
    """Engine A with the identity codec == Engine A without, to the bit."""
    from repro.compress import Identity

    spec, model, plan, opt = _compressed_setup()
    s0, l0 = _run_engine("a", model, plan, opt, spec, None)
    s1, l1 = _run_engine("a", model, plan, opt, spec, Identity())
    assert l0 == l1
    for a, b in zip(jax.tree.leaves(s0.params), jax.tree.leaves(s1.params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("codec", ["identity", "int8", "top-k"])
def test_engines_match_compressed(codec):
    """A == B under each codec: both apply the shared reference transform to
    the fed-server upload, so they agree up to the engines' own ULP-level
    divergence (amplified to ≤ one LSB by the int8 rounding boundary)."""
    from repro.compress import Identity, Int8Stochastic, TopK

    compressor = {
        "identity": Identity(),
        "int8": Int8Stochastic(tile=256),
        "top-k": TopK(0.25),
    }[codec]
    # int8 rounding can flip one LSB (≈ absmax/127) on inputs that differ
    # at ULP level between the engines; the lossless codecs stay tight.
    atol = 2e-3 if codec == "int8" else 5e-6
    spec, model, plan, opt = _compressed_setup()
    sa, la = _run_engine("a", model, plan, opt, spec, compressor)
    sb, lb = _run_engine("b", model, plan, opt, spec, compressor)
    assert np.allclose(la, lb, rtol=1e-5, atol=1e-6)
    full_b = engine_b_to_full(model, plan, sb.params)
    mismatched = total = 0
    for a, b in zip(jax.tree.leaves(sa.params), jax.tree.leaves(full_b)):
        a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
        bad = np.abs(a - b) > atol + 1e-4 * np.abs(b)
        mismatched += int(bad.sum())
        total += a.size
    if codec == "top-k":
        # a |param| near-tie at the rank-k boundary can flip a kept/dropped
        # coordinate between the ULP-divergent engines, mismatching by the
        # full value; require such flips to stay vanishingly rare instead
        # of betting no tie ever lands within the engines' divergence.
        assert mismatched <= max(1, total // 100_000), (mismatched, total)
    else:
        assert mismatched == 0, (mismatched, total)


# --------------------------------------------------------------------------- #
# partial participation: A == B under deadline-driven client masks
# (DESIGN.md §12) — both engines weight participants identically, so the
# equivalence proof extends to partial rounds.
# --------------------------------------------------------------------------- #


def _round_masks(rng, N, steps, plan):
    """Random ~60% participation masks with the adversarial rounds the mask
    semantics single out: a zero-participant *entity* round (round 1) and a
    zero-participant *global* round (round 2, a whole-round no-op)."""
    masks = rng.random((steps, N)) < 0.6
    per = N // plan.entities[1]
    masks[1, :per] = False          # entity 0 of tier 2 fully absent
    if steps > 2:
        masks[2, :] = False         # empty round: params must freeze
    for t in range(steps):
        if t != 2 and not masks[t].any():
            masks[t, int(rng.integers(N))] = True
    return masks.astype(np.float32)


def _run_masked_pair(arch, cuts, intervals, seed, steps=4):
    spec = get_reduced(arch)
    model = SplittableModel(spec)
    N = 8
    plan = default_plan(
        spec.n_units, N, cuts=cuts, intervals=intervals, entities=(N, 4, 1)
    )
    opt = sgd(1e-2)
    key = jax.random.PRNGKey(0)
    sa = init_state_a(model, plan, opt, key)
    sb = init_state_b(model, plan, opt, key)
    step_a = jax.jit(build_train_step_a(model, plan, opt, with_mask=True))
    step_b = jax.jit(build_train_step_b(model, plan, opt, with_mask=True))
    rng = np.random.default_rng(seed)
    masks = _round_masks(rng, N, steps, plan)
    for t in range(steps):
        batch = concrete_inputs(spec, N * 2, 16, jax.random.PRNGKey(t))
        batch = {k: v.reshape(N, 2, *v.shape[1:]) for k, v in batch.items()}
        mk = jnp.asarray(masks[t])
        sa, la = step_a(sa, batch, mk)
        sb, lb = step_b(sb, batch, mk)
        assert np.allclose(float(la), float(lb), rtol=1e-5, atol=1e-6), (t, la, lb)
        if not masks[t].any():
            assert float(la) == 0.0  # empty round reports loss 0
        full_b = engine_b_to_full(model, plan, sb.params)
        for a, b in zip(jax.tree.leaves(sa.params), jax.tree.leaves(full_b)):
            np.testing.assert_allclose(a, b, atol=5e-6, rtol=1e-4)


@pytest.mark.parametrize(
    "arch,cuts,intervals",
    [
        ("smollm-135m", (1, 2), (3, 2, 1)),
        ("qwen2-1.5b", (1, 1), (2, 4, 1)),
        ("mamba2-1.3b", (1, 2), (2, 2, 1)),
    ],
)
def test_engines_match_masked(arch, cuts, intervals):
    """A == B under random participation masks, including a
    zero-participant entity round and a zero-participant global round."""
    _run_masked_pair(arch, cuts, intervals, seed=7)


@pytest.mark.parametrize("seed", range(5))
def test_engines_match_masked_seed_sweep(seed):
    """Nightly flakiness guard: the masked A/B differential re-rolled over
    5 fixed mask seeds (fresh random participation pattern each)."""
    _run_masked_pair("smollm-135m", (1, 2), (2, 2, 1), seed=100 + seed)


def test_engine_b_masked_rejects_moe():
    spec = get_reduced("granite-moe-1b-a400m")
    model = SplittableModel(spec)
    plan = default_plan(spec.n_units, 8, cuts=(1, 2), intervals=(2, 2, 1),
                        entities=(8, 4, 1))
    with pytest.raises(NotImplementedError, match="MoE"):
        build_train_step_b(model, plan, sgd(1e-2), with_mask=True)
