"""End-to-end behaviour of the HSFL system (paper-level claims, small scale)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.configs.vgg16_cifar10 import SPEC as VGG_SPEC
from repro.core import build_train_step_a, init_state_a
from repro.core.tiers import default_plan
from repro.data import (
    image_loader, lm_loader, make_cifar10_like, make_lm_stream,
    partition_iid, partition_sort_and_shard,
)
from repro.models.model import SplittableModel
from repro.models.vgg import VggModel
from repro.optim import sgd

# real multi-round training end to end (~1.5 min): out of the CI fast subset
pytestmark = pytest.mark.slow


def run_training(model, spec, loader, plan, rounds, lr=0.05, seed=0):
    opt = sgd(lr)
    state = init_state_a(model, plan, opt, jax.random.PRNGKey(seed))
    step = jax.jit(build_train_step_a(model, plan, opt))
    losses = []
    for _ in range(rounds):
        batch = {k: jnp.asarray(v) for k, v in loader.next_round().items()}
        state, loss = step(state, batch)
        losses.append(float(loss))
    return state, losses


@pytest.fixture(scope="module")
def small_vgg():
    # thin VGG (fewer channels) to keep CPU time reasonable
    return dataclasses.replace(
        VGG_SPEC,
        conv_channels=(8, 8, 16, 16, 32, 32, 32),
        pool_after=(0, 1, 3, 5),
        fc_dims=(64, 32, 10),
        name="vgg-thin",
    )


def test_vgg_hsfl_loss_decreases(small_vgg):
    ds = make_cifar10_like(512, noise=0.4, seed=0)
    parts = partition_iid(len(ds), 8)
    loader = image_loader(ds, parts, batch=8, seed=0)
    plan = default_plan(small_vgg.n_units, 8, cuts=(3, 6),
                        intervals=(4, 2, 1), entities=(8, 4, 1))
    model = VggModel(small_vgg)
    _, losses = run_training(model, small_vgg, loader, plan, rounds=40)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.05, losses[::8]


def test_lm_hsfl_loss_decreases():
    spec = get_reduced("smollm-135m")
    lm = make_lm_stream(512, 32, spec.vocab_size, seed=0)
    parts = partition_iid(len(lm), 8)
    loader = lm_loader(lm, parts, batch=4, seed=0)
    plan = default_plan(spec.n_units, 8, entities=(8, 4, 1))
    model = SplittableModel(spec)
    _, losses = run_training(model, spec, loader, plan, rounds=40, lr=0.1)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses[::8]


def test_frequent_aggregation_converges_faster(small_vgg):
    """Paper Fig. 8 trend: I=1 beats PSL (I=inf) on non-IID data.

    The paper's metric is *global test accuracy of the aggregated model* —
    PSL can reach lower *local* training loss by overfitting each client's
    2-class shard, so we evaluate the fed-server aggregate on held-out
    global data, exactly as Fig. 8 does.
    """
    ds = make_cifar10_like(512, noise=0.4, seed=1)
    held = make_cifar10_like(256, noise=0.4, seed=77, template_seed=1)
    parts = partition_sort_and_shard(ds.labels, 8, 2, seed=1)
    model = VggModel(small_vgg)
    eval_batch = {"images": jnp.asarray(held.images),
                  "labels": jnp.asarray(held.labels)}

    def global_acc(intervals):
        loader = image_loader(ds, parts, batch=8, seed=1)
        plan = default_plan(small_vgg.n_units, 8, cuts=(3, 6),
                            intervals=intervals, entities=(8, 4, 1))
        state, _ = run_training(model, small_vgg, loader, plan, rounds=50, seed=1)
        # fed-server view: global mean over the client axis (full aggregation)
        gparams = jax.tree.map(lambda x: jnp.mean(x, axis=0), state.params)
        return float(model.accuracy(gparams, eval_batch))

    sync = global_acc((1, 1, 1))
    psl = global_acc((10_000, 10_000, 1))  # PSL: never aggregate lower tiers
    assert sync > psl, (sync, psl)


def test_train_driver_cli(tmp_path):
    """The launch/train.py driver end-to-end with checkpointing."""
    import os

    from repro.launch.train import main

    ck = str(tmp_path / "ck.npz")
    rc = main([
        "--arch", "vgg16-cifar10", "--rounds", "3", "--clients", "4",
        "--edges", "2", "--batch", "4", "--checkpoint", ck, "--log-every", "1",
    ])
    assert rc == 0
    assert os.path.exists(ck)


def test_serve_driver_cli():
    from repro.launch.serve import main

    rc = main(["--arch", "smollm-135m", "--batch", "2",
               "--prompt-len", "4", "--gen", "4", "--cache-len", "16"])
    assert rc == 0
