from .ops import (
    aggregate_tree,
    ragged_tiered_aggregate_q8,
    tiered_aggregate,
    tiered_aggregate_q8,
)
from .ref import (
    quantized_tiered_aggregate_ref,
    ragged_quantized_tiered_aggregate_ref,
    tiered_aggregate_ref,
)
