"""MA (Prop. 1), MS (Dinkelbach), BCD (Alg. 2) — optimality vs brute force."""
import numpy as np
import pytest

from repro.configs.vgg16_cifar10 import SPEC as VGG
from repro.core import (
    HsflProblem, SystemSpec, build_profile, solve_bcd, solve_ma,
    solve_ma_bruteforce, solve_ms, solve_ms_bruteforce, synthetic_hyperspec,
)
from repro.core.convergence import theorem1_bound


def make_problem(seed=0, eps_scale=5.0, beta=None, g2=None):
    rng = np.random.default_rng(seed)
    prof = build_profile(VGG, batch=16)
    system = SystemSpec.paper_three_tier(seed=seed)
    hp = synthetic_hyperspec(
        VGG.n_units, 20,
        beta=beta if beta is not None else rng.uniform(1, 10),
        g2_scale=g2 if g2 is not None else rng.uniform(1, 30),
        seed=seed,
    )
    floor = theorem1_bound(hp, 10**9, [1, 1, 1], (3, 8))
    return HsflProblem(prof, system, hp, eps=eps_scale * floor)


@pytest.mark.parametrize("seed", range(10))
def test_ma_matches_bruteforce(seed):
    rng = np.random.default_rng(100 + seed)
    prob = make_problem(seed, eps_scale=float(rng.uniform(1.5, 40)))
    cuts = tuple(sorted(int(c) for c in rng.choice(range(1, 15), 2)))
    ma = solve_ma(prob, cuts)
    bf = solve_ma_bruteforce(prob, cuts, i_max=250)
    assert ma.theta <= bf.theta * (1 + 1e-9), (ma, bf)


@pytest.mark.parametrize("seed", range(6))
def test_ms_dinkelbach_matches_ratio_enumeration(seed):
    prob = make_problem(seed)
    rng = np.random.default_rng(200 + seed)
    intervals = [int(rng.integers(1, 12)), int(rng.integers(1, 12)), 1]
    try:
        ms = solve_ms(prob, intervals)
    except ValueError:
        # infeasible (D<=0 for every cut): the oracle must agree
        with pytest.raises(ValueError):
            solve_ms_bruteforce(prob, intervals)
        return
    bf = solve_ms_bruteforce(prob, intervals)
    np.testing.assert_allclose(ms.theta, bf.theta, rtol=1e-7)
    assert ms.dinkelbach_iters <= 10


def test_ms_respects_memory_constraint():
    prof = build_profile(VGG, batch=16)
    system = SystemSpec.paper_three_tier(memory_bytes=16e9)
    # devices with tiny memory: shallow tier-1 cuts become infeasible (C5)
    import dataclasses

    small_mem = dataclasses.replace(
        system, memory=(np.full(20, 30e6), system.memory[1], system.memory[2])
    )
    hp = synthetic_hyperspec(VGG.n_units, 20, beta=3.0, seed=0)
    floor = theorem1_bound(hp, 10**9, [1, 1, 1], (3, 8))
    prob = HsflProblem(prof, small_mem, hp, eps=5 * floor)
    ms = solve_ms(prob, [2, 2, 1])
    assert prob.memory_feasible(ms.cuts)


def test_bcd_monotone_and_feasible():
    prob = make_problem(3, eps_scale=10.0)
    res = solve_bcd(prob)
    hist = list(res.history)
    for a, b in zip(hist, hist[1:]):
        assert b <= a * (1 + 1e-9)
    assert np.isfinite(res.theta)
    assert res.rounds > 0 and res.total_latency > 0
    assert prob.valid_cuts(res.cuts)
    # BCD beats the naive all-ones + even-cut starting point
    naive = prob.theta([1] * 3, res.cuts)
    assert res.theta <= naive * (1 + 1e-9)


def test_infeasible_eps_raises():
    prob = make_problem(0, eps_scale=0.0)  # eps below the bound floor
    with pytest.raises(ValueError):
        solve_ms(prob, [1, 1, 1])


def test_cubic_bracket_expansion_capped():
    """Degenerate Ξ coefficients used to hang the bisection bracket loop
    (``while f(hi) < 0: hi *= 2.0`` never terminates when Ξ(I) ≡ −kc);
    the cap must turn that into a clear error instead."""
    from repro.core.ma_solver import _cubic_positive_root

    # ka = kb = 0, kc > 0: Ξ(I) = −kc < 0 for every I — no positive root,
    # and np.roots on the degenerate polynomial finds nothing either
    with pytest.raises(ValueError, match="bracket expansion"):
        _cubic_positive_root(0.0, 0.0, 1.0)
    # tiny-but-valid coefficients still resolve through the fallback
    assert _cubic_positive_root(2.0, 3.0, 5.0) == pytest.approx(1.0)


def test_cubic_root_degenerate_leading_coefficient():
    """ka ≈ 0 collapses Proposition 1's cubic to kb·I² − kc = 0; np.roots
    on the near-degenerate polynomial divides its companion matrix by the
    subnormal leading coefficient and returns garbage.  The explicit
    deflation, the scalar Newton path, and the bisection oracle must all
    agree on the quadratic root."""
    import math

    from repro.core.ma_solver import _cubic_positive_root

    def bisect(ka, kb, kc):
        f = lambda x: ka * x**3 + kb * x**2 - kc
        lo, hi = 1e-12, 1.0
        while f(hi) < 0:
            hi *= 2.0
        for _ in range(200):
            mid = 0.5 * (lo + hi)
            if f(mid) < 0:
                lo = mid
            else:
                hi = mid
        return 0.5 * (lo + hi)

    # degenerate / near-degenerate leading coefficients: exact zero, a
    # subnormal, and a tiny normal — all must deflate to sqrt(kc/kb)
    for ka in (0.0, 5e-324, 1e-320, 1e-300, 1e-30):
        for kb, kc in ((3.0, 7.0), (1e-6, 2.5), (50.0, 1e-4)):
            root = _cubic_positive_root(ka, kb, kc)
            assert root == pytest.approx(math.sqrt(kc / kb), rel=1e-9), (
                ka, kb, kc,
            )
            assert root == pytest.approx(bisect(ka, kb, kc), rel=1e-9), (
                ka, kb, kc,
            )

    # non-degenerate coefficients: Newton, np.roots, and bisection agree
    for ka, kb, kc in ((2.0, 3.0, 5.0), (0.5, 1e3, 10.0), (7.0, 1e-3, 0.4)):
        root = _cubic_positive_root(ka, kb, kc)
        pos = [
            r.real
            for r in np.roots([ka, kb, 0.0, -kc])
            if abs(r.imag) < 1e-9 and r.real > 0
        ]
        assert len(pos) == 1
        assert root == pytest.approx(pos[0], rel=1e-9), (ka, kb, kc)
        assert root == pytest.approx(bisect(ka, kb, kc), rel=1e-9), (ka, kb, kc)
