"""End-to-end HSFL training driver (deliverable b).

Thin wrapper over ``repro.launch.train``: synthetic non-IID data ->
probe-based estimation of the Theorem-1 constants (beta, sigma_l^2, G_l^2)
-> BCD re-optimization of (I, mu) -> multi-timescale split training ->
checkpoint. Defaults run the paper's VGG-16/CIFAR-10-like setting for a
few hundred rounds on CPU; pass any assigned arch id for its reduced
variant on an LM stream.

    PYTHONPATH=src python examples/train_hsfl_e2e.py                 # paper setting
    PYTHONPATH=src python examples/train_hsfl_e2e.py --arch qwen2-1.5b --rounds 100
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    argv = sys.argv[1:] or [
        "--arch", "vgg16-cifar10",
        "--rounds", "200",
        "--clients", "8",
        "--edges", "4",
        "--batch", "8",
        "--lr", "0.05",
        "--non-iid",
        "--auto-optimize",
        "--probe-rounds", "4",
        "--log-every", "20",
        "--checkpoint", "/tmp/hsfl_vgg16.npz",
    ]
    raise SystemExit(main(argv))
