# Fleet simulator: heterogeneity-aware discrete-event simulation of
# multi-tier HSFL systems. events.py is the deterministic oracle, fleet.py
# the vectorized (jnp) fast path, scenarios.py the regime library,
# robust.py plugs trace quantiles into the MA+MS solvers, and
# participation.py turns traces + deadlines into client masks, q_m rates,
# and expected-round-time pricing (DESIGN.md §12).
from .scenarios import (
    RoundState,
    SCENARIOS,
    SystemTrace,
    diurnal_churn,
    flaky_wan,
    homogeneous_paper,
    lognormal_heterogeneous,
    make_trace,
    scenario_params,
    straggler_tail,
)
from .events import EventSimResult, RoundResult, simulate, simulate_round
from .fleet import (
    FleetResult,
    FleetRound,
    round_latency,
    simulate_lattice_rounds,
    simulate_rounds,
)
from .robust import TraceLatency, robust_problem
from .participation import (
    DeadlineLatency,
    ParticipationResult,
    deadline_for_rate,
    estimate_participation,
    participation_masks,
    participation_problem,
)
