"""FederatedLoader: per-round client-stacked mini-batches for Engine A/B.

Every round, each client draws a size-b mini-batch from its own partition
(with replacement across epochs, matching the paper's per-round sampling
ξ_n^t); the loader emits batches whose leaves carry a leading client axis
[N, b, ...], the layout both engines and the pjit data sharding consume.
"""
from __future__ import annotations

from typing import Dict, Iterator, List, Optional

import numpy as np


class FederatedLoader:
    def __init__(
        self,
        arrays: Dict[str, np.ndarray],  # sample-major arrays, same length K
        partitions: List[np.ndarray],
        batch: int,
        seed: int = 0,
    ):
        self.arrays = arrays
        self.partitions = partitions
        self.batch = batch
        self.num_clients = len(partitions)
        self._rng = np.random.default_rng(seed)
        k = len(next(iter(arrays.values())))
        for v in arrays.values():
            assert len(v) == k

    def next_round(self) -> Dict[str, np.ndarray]:
        """One client-stacked batch {key: [N, b, ...]}."""
        idx = np.stack(
            [
                self._rng.choice(part, size=self.batch, replace=len(part) < self.batch)
                for part in self.partitions
            ]
        )  # [N, b]
        return {k: v[idx] for k, v in self.arrays.items()}

    def rounds(self, n: int) -> Iterator[Dict[str, np.ndarray]]:
        for _ in range(n):
            yield self.next_round()


def image_loader(dataset, partitions, batch: int, seed: int = 0) -> FederatedLoader:
    return FederatedLoader(
        {"images": dataset.images, "labels": dataset.labels.astype(np.int32)},
        partitions,
        batch,
        seed,
    )


def lm_loader(dataset, partitions, batch: int, seed: int = 0) -> FederatedLoader:
    return FederatedLoader(
        {
            "tokens": dataset.tokens[:, :-1],
            "labels": dataset.tokens[:, 1:].astype(np.int32),
        },
        partitions,
        batch,
        seed,
    )
