"""Discrete-event core of the fleet simulator (small-N oracle).

One HSFL round is a set of independent per-client event chains — the
canonical stage chain of ``repro.core.latency.split_stages`` (fwd compute /
uplink / … / bwd compute / downlink) — followed by per-tier fed-server
syncs (entity model upload → aggregate → broadcast).  Events are processed
through a heap keyed by ``(time, seq)`` with a deterministic insertion
counter, so a given ``SystemTrace`` always replays to the identical event
log.  Dropout / join events are emitted whenever a client's availability
mask flips between rounds.

The event core exists as the *oracle*: the vectorized fast path in
``fleet.py`` advances whole rounds with array ops, and must agree with this
simulation bit-for-bit.  Both therefore consume the same per-stage duration
arrays (``round_stage_durations`` / ``round_agg_phases`` below) and
accumulate them in the same order; the only difference is scalar event
scheduling here vs. ``[N]``-vector arithmetic there.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..core.latency import (
    Stage,
    aggregation_phases,
    split_stages,
    stage_rate,
)
from .scenarios import SystemTrace

# event kinds, in rough lifecycle order
DROPOUT = "dropout"
JOIN = "join"
COMPUTE_DONE = "compute_done"
UPLINK_DONE = "uplink_done"
DOWNLINK_DONE = "downlink_done"
CLIENT_DONE = "client_round_done"
MODEL_UP_DONE = "model_uplink_done"
AGG_DONE = "fed_aggregate_done"
MODEL_DOWN_DONE = "model_downlink_done"
ENTITY_SYNC = "entity_sync"

_STAGE_EVENT = {
    "compute_fwd": COMPUTE_DONE,
    "compute_bwd": COMPUTE_DONE,
    "uplink": UPLINK_DONE,
    "downlink": DOWNLINK_DONE,
}


@dataclass(frozen=True, order=True)
class Event:
    time: float
    seq: int                       # insertion counter: deterministic ties
    kind: str = field(compare=False)
    actor: int = field(compare=False)   # client id, or entity id for syncs
    stage: int = field(compare=False)   # chain index, or tier for syncs


class EventQueue:
    """Deterministic min-heap of events (ties broken by insertion order)."""

    def __init__(self):
        self._heap: List[Event] = []
        self._seq = 0

    def push(self, time: float, kind: str, actor: int, stage: int) -> Event:
        ev = Event(time, self._seq, kind, actor, stage)
        self._seq += 1
        heapq.heappush(self._heap, ev)
        return ev

    def pop(self) -> Event:
        return heapq.heappop(self._heap)

    def __len__(self) -> int:
        return len(self._heap)


# --------------------------------------------------------------------------- #
# shared round pricing (consumed by the event core AND the fleet fast path)
# --------------------------------------------------------------------------- #


def _stage_mult(state, stage: Stage) -> np.ndarray:
    if stage.kind in ("compute_fwd", "compute_bwd"):
        return state.compute_mult[stage.index]
    if stage.kind == "uplink":
        return state.link_up_mult[stage.index]
    return state.link_down_mult[stage.index]


def round_stage_durations(
    trace: SystemTrace, r: int, cuts: Sequence[int]
) -> Tuple[Tuple[Stage, ...], List[np.ndarray]]:
    """Per-stage per-client durations [N] for round r, canonical chain order.

    The trace's ``compression`` spec (if any) already scaled the boundary
    bits inside ``split_stages``, so both consumers (event oracle + fleet
    fast path) price the compressed wire identically.
    """
    state = trace.round_state(r)
    stages = split_stages(trace.profile, cuts, trace.compression)
    durs = [
        s.work / (stage_rate(trace.system, s) * _stage_mult(state, s))
        for s in stages
    ]
    return stages, durs


def round_agg_phases(
    trace: SystemTrace, r: int, cuts: Sequence[int], m: int
) -> Optional[Tuple[np.ndarray, np.ndarray]]:
    """Per-entity (upload, download) times of a tier-m sync in round r;
    None when tier m has a single entity (no fed-server traffic).

    When tier m's entities are the clients themselves (J_m == N, i.e. each
    client hosts its own tier-m sub-model), absent clients have nothing to
    upload: the phase arrays cover only the round's participants.
    """
    system = trace.system
    if system.entities[m] <= 1:
        return None
    state = trace.round_state(r)
    up_rate = system.model_up[m] * state.fed_up_mult[m]
    down_rate = system.model_down[m] * state.fed_down_mult[m]
    up, down = aggregation_phases(
        trace.profile, system, cuts, m, up_rate=up_rate, down_rate=down_rate,
        compression=trace.compression,
    )
    if len(up) == system.num_clients:
        up, down = up[state.available], down[state.available]
        if len(up) == 0:
            return None  # zero-participant round: nothing to upload this tier
    return up, down


def fires(r: int, interval: int) -> bool:
    """Tier sync schedule: aggregate at the end of every ``interval``-th round."""
    return (r + 1) % max(1, int(interval)) == 0


# --------------------------------------------------------------------------- #
# the simulation
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class RoundResult:
    split: float                   # T_S of this round (max over participants)
    per_client: np.ndarray         # [N] finish times; NaN for absent clients
    agg: np.ndarray                # [M-1] priced sync latency of every tier
    events: Tuple[Event, ...]      # full deterministic event log
    n_participants: int


@dataclass(frozen=True)
class EventSimResult:
    split: np.ndarray              # [R]
    agg: np.ndarray                # [M-1, R] priced every round
    fired: np.ndarray              # [M-1, R] bool, sync schedule
    total: np.ndarray              # [R] split + fired syncs
    participants: np.ndarray       # [R]


def simulate_round(
    trace: SystemTrace,
    r: int,
    cuts: Sequence[int],
    prev_available: Optional[np.ndarray] = None,
) -> RoundResult:
    """Run one round through the event queue."""
    system = trace.system
    N, M = system.num_clients, system.M
    state = trace.round_state(r)
    stages, durs = round_stage_durations(trace, r, cuts)

    q = EventQueue()
    log: List[Event] = []
    # availability transitions (bookkeeping events at round start)
    for i in range(N):
        if not state.available[i]:
            if prev_available is None or prev_available[i]:
                q.push(0.0, DROPOUT, i, -1)
        elif prev_available is not None and not prev_available[i]:
            q.push(0.0, JOIN, i, -1)
    # kick off every participant's chain
    for i in range(N):
        if state.available[i]:
            q.push(durs[0][i], _STAGE_EVENT[stages[0].kind], i, 0)

    per_client = np.full(N, np.nan)
    n_part = 0
    while len(q):
        ev = q.pop()
        log.append(ev)
        if ev.kind in (DROPOUT, JOIN):
            continue
        i, s = ev.actor, ev.stage
        if s + 1 < len(stages):
            nxt = s + 1
            q.push(ev.time + durs[nxt][i], _STAGE_EVENT[stages[nxt].kind], i, nxt)
        else:
            per_client[i] = ev.time
            log.append(Event(ev.time, -1, CLIENT_DONE, i, s))
            n_part += 1

    split = float(np.max(per_client[state.available])) if n_part else 0.0

    # per-tier fed-server syncs, priced off the split barrier
    agg = np.zeros(M - 1)
    for m in range(M - 1):
        phases = round_agg_phases(trace, r, cuts, m)
        if phases is None:
            continue
        up, down = phases
        for j in range(len(up)):
            q.push(split + up[j], MODEL_UP_DONE, j, m)
        up_t = float(np.max(up))
        q.push(split + up_t, AGG_DONE, 0, m)
        for j in range(len(down)):
            q.push(split + up_t + down[j], MODEL_DOWN_DONE, j, m)
        down_t = float(np.max(down))
        q.push(split + up_t + down_t, ENTITY_SYNC, 0, m)
        agg[m] = up_t + down_t
        while len(q):
            log.append(q.pop())

    return RoundResult(split, per_client, agg, tuple(log), n_part)


def simulate(
    trace: SystemTrace,
    cuts: Sequence[int],
    intervals: Optional[Sequence[int]] = None,
    rounds: Optional[int] = None,
) -> EventSimResult:
    """Replay ``rounds`` rounds of the trace (default: all of them).

    ``intervals`` gates which rounds actually pay each tier's sync latency
    (Eq. 19 schedule); every round's sync is still *priced* in ``agg`` so
    trace quantiles are well defined.  With no intervals every tier syncs
    every round.
    """
    R = trace.rounds if rounds is None else min(rounds, trace.rounds)
    M = trace.system.M
    iv = [1] * (M - 1) if intervals is None else list(intervals[: M - 1])

    split = np.zeros(R)
    agg = np.zeros((M - 1, R))
    fired = np.zeros((M - 1, R), dtype=bool)
    total = np.zeros(R)
    participants = np.zeros(R, dtype=int)
    prev = None
    for r in range(R):
        res = simulate_round(trace, r, cuts, prev_available=prev)
        split[r] = res.split
        agg[:, r] = res.agg
        participants[r] = res.n_participants
        tot = res.split
        for m in range(M - 1):
            if fires(r, iv[m]):
                fired[m, r] = True
                tot = tot + res.agg[m]
        total[r] = tot
        prev = trace.round_state(r).available
    return EventSimResult(split, agg, fired, total, participants)
