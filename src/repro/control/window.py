"""Windowed system estimate: the online analogue of ``TraceLatency``.

``WindowedLatency`` keeps a ring buffer of the last W observed rounds.
Each ``push`` prices that single round's ``RoundState`` against the whole
cut lattice through ``sim.fleet.price_lattice_round`` — the *same*
per-round pricing kernel ``simulate_lattice_rounds`` runs — and stores
the resulting split/agg columns.  The batched latency tables are then a
quantile (or deadline-mean) over the buffered columns, incrementally:
one observed round costs one [K, N] pass, and a full lattice re-price at
control time is a pure reduction over the buffer.

Fed the same ``RoundState`` sequence, the windowed tables are
bit-identical to a ``TraceLatency``/``DeadlineLatency`` built over a
trace of exactly those rounds (pinned in ``tests/test_control.py``) —
the controller re-solves against the same arithmetic the offline robust
pricing uses, just restricted to the recent window.

``version`` increments on every push: ``HsflProblem.evaluator`` watches
it to rebuild its memoized ``BatchedEvaluator`` instead of serving stale
split/agg tables (the satellite bugfix this PR makes explicit).
"""
from __future__ import annotations

from collections import deque
from typing import Optional, Sequence

import numpy as np

from ..core.batched import model_bits_lattice, split_work_tensor, stage_meta
from ..core.latency import LayerProfile, SystemSpec
from ..sim.fleet import price_lattice_round
from ..sim.participation import _tier_entity_rates
from ..sim.scenarios import RoundState


class WindowedLatency:
    """Sliding-window lattice pricing over observed rounds.

    ``quantile`` is the pricing level when no deadline policy is active
    (the windowed analogue of ``TraceLatency``); with ``deadline`` set,
    rounds are priced deadline-capped and aggregated by mean (the
    windowed analogue of ``DeadlineLatency``).
    """

    def __init__(
        self,
        profile: LayerProfile,
        system: SystemSpec,
        lattice: np.ndarray,
        window: int,
        quantile: float = 0.5,
        deadline: Optional[float] = None,
        compression=None,
    ):
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        if not 0.0 < quantile <= 1.0:
            raise ValueError(f"quantile must lie in (0, 1]: {quantile}")
        self.profile = profile
        self.system = system
        self.lattice = np.asarray(lattice)
        self.window = int(window)
        self.quantile = float(quantile)
        self.deadline = None if deadline is None else float(deadline)
        self.compression = compression
        self.version = 0
        self._works = split_work_tensor(profile, self.lattice, compression)
        self._lam = model_bits_lattice(profile, self.lattice, compression)
        self._meta = stage_meta(system.M)
        self._key = self.lattice.tobytes()
        self._row = {
            tuple(int(x) for x in row): k
            for k, row in enumerate(self.lattice.tolist())
        }
        self._split_cols: deque = deque(maxlen=self.window)  # [K]
        self._agg_cols: deque = deque(maxlen=self.window)    # [K, M-1]
        self._masks: deque = deque(maxlen=self.window)       # [N] bool
        self._states: deque = deque(maxlen=self.window)      # RoundState

    # ------------------------------------------------------------------ #
    @property
    def n_obs(self) -> int:
        return len(self._split_cols)

    def push(self, state: RoundState, mask: Optional[np.ndarray] = None) -> None:
        """Fold one observed round into the window (prices the whole
        lattice against it once); ``mask`` overrides availability as the
        round's participation set (deadline policies)."""
        split_col, agg_col = price_lattice_round(
            self.system, self._works, self._lam, self._meta, state,
            deadline=self.deadline, backend="numpy",
        )
        self._split_cols.append(split_col)
        self._agg_cols.append(agg_col)
        self._masks.append(
            state.available.copy() if mask is None
            else np.asarray(mask, dtype=bool).copy()
        )
        self._states.append(state)
        self.version += 1

    def states(self) -> tuple:
        """The buffered ``RoundState``s, oldest first — e.g. to rebuild an
        offline ``TraceLatency`` over exactly this window (the cold
        comparator in ``benchmarks/control_drift.py``)."""
        return tuple(self._states)

    def _require_obs(self) -> None:
        if not self._split_cols:
            raise ValueError(
                "WindowedLatency has no observed rounds yet — push() at "
                "least one before pricing"
            )

    def _check_lattice(self, lattice: np.ndarray) -> None:
        if np.asarray(lattice).tobytes() != self._key:
            raise ValueError(
                "lattice mismatch: WindowedLatency prices the lattice it "
                "was constructed with"
            )

    # ------------------------------------------------------------------ #
    # LatencyModel protocol (same surface as TraceLatency/DeadlineLatency)
    # ------------------------------------------------------------------ #
    def _tables(self):
        """Whole-lattice scalar tables, memoized per version: one vectorized
        reduction serves every scalar ``split_T``/``agg_T`` call until the
        next push (the solvers' scalar path hits these hundreds of times
        per control step)."""
        cached = getattr(self, "_table_cache", None)
        if cached is not None and cached[0] == self.version:
            return cached[1], cached[2]
        split = self.split_T_batch(self.lattice)
        agg = self.agg_T_batch(self.lattice)
        self._table_cache = (self.version, split, agg)
        return split, agg

    def split_T(self, cuts: Sequence[int]) -> float:
        self._require_obs()
        k = self._row.get(tuple(int(c) for c in cuts))
        if k is None:
            raise KeyError(f"cuts {tuple(cuts)} not on the priced lattice")
        split, _ = self._tables()
        return float(split[k])

    def agg_T(self, cuts: Sequence[int], m: int) -> float:
        self._require_obs()
        k = self._row.get(tuple(int(c) for c in cuts))
        if k is None:
            raise KeyError(f"cuts {tuple(cuts)} not on the priced lattice")
        _, agg = self._tables()
        return float(agg[k, m])

    # ------------------------------------------------------------------ #
    # batched lattice protocol (consumed by core.batched.BatchedEvaluator)
    # ------------------------------------------------------------------ #
    def split_T_batch(self, lattice: np.ndarray) -> np.ndarray:
        self._require_obs()
        self._check_lattice(lattice)
        cols = np.stack(tuple(self._split_cols), axis=1)  # [K, W]
        if self.deadline is None:
            return np.quantile(cols, self.quantile, axis=1)
        return np.mean(cols, axis=1)

    def agg_T_batch(self, lattice: np.ndarray) -> np.ndarray:
        self._require_obs()
        self._check_lattice(lattice)
        cols = np.stack(tuple(self._agg_cols), axis=2)  # [K, M-1, W]
        if self.deadline is None:
            return np.quantile(cols, self.quantile, axis=2)
        return np.mean(cols, axis=2)

    # ------------------------------------------------------------------ #
    def q_tiers(self) -> np.ndarray:
        """[M] windowed per-tier participation rates — the mean over the
        buffered rounds of ``sim.participation._tier_entity_rates`` on
        each round's mask (the online ``ParticipationSpec`` estimate)."""
        self._require_obs()
        rates = np.stack(
            [_tier_entity_rates(m, self.system.entities) for m in self._masks]
        )
        return rates.mean(axis=0)
