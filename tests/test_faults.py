"""Fault injection & fault-tolerant training (DESIGN.md §16).

Pins the four contracts of the faults subsystem:
  * seeded expansion — deterministic, per-class-independent streams;
  * composition — a null spec is structurally invisible (object identity
    on traces, bit-exact pricing/solves), a live spec keeps the
    event-oracle == fleet-path bit-exactness;
  * the guard — every corrupt mode is quarantined, an all-healthy round
    collapses bit-for-bit onto the unguarded path (the JAX_DEBUG_NANS
    contract);
  * accounting — q-deflation matches the realized masks and degenerate
    (all-faulty) regimes fail loudly.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.vgg16_cifar10 import SPEC as VGG
from repro.core import (
    HsflProblem, SystemSpec, build_profile, solve_ms, synthetic_hyperspec,
)
from repro.core.convergence import ParticipationSpec, theorem1_bound
from repro.core.latency import LayerProfile
from repro.core.tiers import GuardSpec, default_plan, guard_health, synchronize
from repro.faults import (
    FaultSpec,
    apply_corruption,
    assignment_members,
    deflate_participation,
    expand_faults,
    fault_survival,
    faulty_trace,
    membership_mean,
    outage_assignment,
    retry_attempts,
    round_healthy,
)
from repro.sim import make_trace, simulate, simulate_rounds

N = 8
ENTITIES = (N, 4, 1)


def small_problem(seed=0, num_clients=N, num_edges=4):
    prof = build_profile(VGG, batch=2)
    system = SystemSpec.paper_three_tier(
        num_clients=num_clients, num_edges=num_edges, seed=seed
    )
    hp = synthetic_hyperspec(prof.n_units, num_clients, beta=3.0, seed=seed)
    floor = theorem1_bound(hp, 10**9, [1] * 3, (5, 11))
    return HsflProblem(prof, system, hp, eps=6.0 * floor)


def storm_spec(seed=0, **kw):
    base = dict(
        seed=seed, crash_rate=0.1, corrupt_rate=0.1, link_fail_rate=0.2,
        link_retries=2, outage_cells=(0,), outage_tier=1, outage_start=2,
        outage_len=3,
    )
    base.update(kw)
    return FaultSpec(**base)


def _params(key, n=N, U=8, d=4):
    ks = jax.random.split(key, 3)
    return {
        "frontend": {"embed": jax.random.normal(ks[0], (n, 8, d))},
        "units": {"w": jax.random.normal(ks[1], (n, U, d, d))},
        "head": {"norm": jax.random.normal(ks[2], (n, d))},
    }


# --------------------------------------------------------------------------- #
# FaultSpec: serialization + validation
# --------------------------------------------------------------------------- #


def test_fault_spec_json_round_trip():
    spec = storm_spec(seed=7, corrupt_mode="bitflip", crash_stage="downlink")
    loaded = FaultSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
    assert loaded == spec


def test_fault_spec_validation():
    with pytest.raises(ValueError, match="crash_rate"):
        FaultSpec(crash_rate=1.5)
    with pytest.raises(ValueError, match="link_fail_rate"):
        FaultSpec(link_fail_rate=1.0)
    with pytest.raises(ValueError, match="crash_stage"):
        FaultSpec(crash_stage="teleport")
    with pytest.raises(ValueError, match="corrupt_mode"):
        FaultSpec(corrupt_mode="gamma-ray")
    with pytest.raises(ValueError, match="corrupt_scale"):
        FaultSpec(corrupt_scale=0.0)
    with pytest.raises(ValueError, match="link_retries"):
        FaultSpec(link_retries=-1)
    with pytest.raises(ValueError, match="outage_cells"):
        FaultSpec(outage_len=3)  # outage span with no dead cells named


def test_validate_for_topology():
    spec = storm_spec()
    spec.validate_for(3, ENTITIES)  # fine
    with pytest.raises(ValueError, match="outage_tier"):
        storm_spec(outage_tier=2).validate_for(3, ENTITIES)
    with pytest.raises(ValueError, match="entity range"):
        storm_spec(outage_cells=(9,)).validate_for(3, ENTITIES)
    with pytest.raises(ValueError, match="no sibling"):
        storm_spec(outage_cells=(0, 1, 2, 3)).validate_for(3, ENTITIES)


def test_retry_attempts_math():
    assert retry_attempts(0.0, 5) == 1.0
    p, k = 0.3, 4
    closed = (1.0 - p ** (k + 1)) / (1.0 - p)
    assert retry_attempts(p, k) == pytest.approx(closed, rel=1e-12)
    with pytest.raises(ValueError):
        retry_attempts(1.0, 2)
    with pytest.raises(ValueError):
        retry_attempts(0.2, -1)


def test_null_gates():
    null = FaultSpec()
    assert null.is_null and null.retry_mult is None and not null.has_outage
    assert not storm_spec().is_null
    assert FaultSpec(link_fail_rate=0.2).retry_mult == retry_attempts(0.2, 2)
    # a spec whose only content is an outage is not null
    assert not FaultSpec(
        outage_cells=(1,), outage_len=1
    ).is_null


# --------------------------------------------------------------------------- #
# per-round expansion: determinism + stream independence
# --------------------------------------------------------------------------- #


def test_expand_deterministic_and_round_varying():
    spec = storm_spec()
    a = expand_faults(spec, 3, N)
    b = expand_faults(spec, 3, N)
    assert np.array_equal(a.crashed, b.crashed)
    assert np.array_equal(a.corrupt, b.corrupt)
    assert np.array_equal(a.attempts, b.attempts)
    assert a.cell_out == b.cell_out
    # some round must draw differently from round 3 (seeded per-round keys)
    assert any(
        not np.array_equal(expand_faults(spec, r, N).attempts, a.attempts)
        for r in range(20)
        if r != 3
    )


def test_fault_class_streams_independent():
    crash_only = FaultSpec(seed=1, crash_rate=0.3)
    corrupt_only = FaultSpec(seed=1, corrupt_rate=0.3)
    both = FaultSpec(seed=1, crash_rate=0.3, corrupt_rate=0.3,
                     link_fail_rate=0.2)
    link_only = FaultSpec(seed=1, link_fail_rate=0.2)
    for r in range(8):
        rc = expand_faults(crash_only, r, N)
        rk = expand_faults(corrupt_only, r, N)
        rb = expand_faults(both, r, N)
        rl = expand_faults(link_only, r, N)
        # enabling other classes never perturbs a class's own draws
        assert np.array_equal(rb.crashed, rc.crashed)
        assert np.array_equal(rb.attempts, rl.attempts)
        # corruption draws match modulo the crashed-uploads-nothing rule
        assert np.array_equal(rb.corrupt, rk.corrupt & ~rc.crashed)
        assert not np.any(rb.crashed & rb.corrupt)
        assert np.array_equal(rb.faulty, rb.crashed | rb.corrupt)


# --------------------------------------------------------------------------- #
# trace composition: null identity + events == fleet under a storm
# --------------------------------------------------------------------------- #


def _small_trace(rounds=6, seed=0, scenario="lognormal-heterogeneous"):
    prof = build_profile(VGG, batch=2)
    system = SystemSpec.paper_three_tier(
        num_clients=N, num_edges=4, seed=seed
    )
    return make_trace(scenario, prof, system, rounds=rounds, seed=seed)


def test_null_spec_trace_identity():
    trace = _small_trace()
    assert faulty_trace(trace, None) is trace
    assert faulty_trace(trace, FaultSpec()) is trace


@pytest.mark.parametrize("scenario", ["homogeneous-paper", "flaky-wan"])
def test_faulty_trace_events_match_fleet_bit_exact(scenario):
    trace = faulty_trace(_small_trace(scenario=scenario), storm_spec())
    ev = simulate(trace, (3, 8), (2, 3, 1))
    fl = simulate_rounds(trace, (3, 8), (2, 3, 1), backend="numpy")
    assert np.array_equal(ev.split, fl.split)
    assert np.array_equal(ev.agg, fl.agg)
    assert np.array_equal(ev.fired, fl.fired)
    assert np.array_equal(ev.total, fl.total)
    assert np.array_equal(ev.participants, fl.participants)


def test_faulty_trace_round_state_adjustments():
    spec = storm_spec(outage_start=2, outage_len=3)
    base = _small_trace(rounds=8)
    trace = faulty_trace(base, spec)
    assert trace.name.endswith("+faults")
    for r in range(8):
        st = trace.round_state(r)
        rf = expand_faults(spec, r, N)
        # crash: the round barrier excludes crashed clients
        assert not np.any(st.available & rf.crashed)
        dead = np.asarray(spec.outage_cells)
        if spec.outage_active(r):
            assert np.all(np.isinf(st.fed_up_mult[spec.outage_tier][dead]))
        else:
            assert np.all(np.isfinite(st.fed_up_mult[spec.outage_tier][dead]))


def test_all_crashed_round_raises():
    trace = faulty_trace(
        _small_trace(scenario="homogeneous-paper"),
        FaultSpec(crash_rate=1.0),
    )
    with pytest.raises(ValueError, match="every client crashed"):
        trace.round_state(0)


# --------------------------------------------------------------------------- #
# retry pricing: scalar == batched, null == clean
# --------------------------------------------------------------------------- #


def test_retry_pricing_scalar_matches_batched_bit_exact():
    fp = small_problem().with_faults(
        FaultSpec(link_fail_rate=0.25, link_retries=3)
    )
    ev = fp.evaluator("numpy")
    lattice = fp.cut_lattice()
    for i in range(0, len(lattice), max(1, len(lattice) // 16)):
        cuts = tuple(int(c) for c in lattice[i])
        assert float(fp.split_T(cuts)) == float(ev.split[i])
        assert [float(v) for v in fp.agg_T(cuts)] == [
            float(v) for v in ev.agg[i]
        ]


def test_retry_pricing_null_and_monotone():
    problem = small_problem()
    cuts = (3, 8)
    # null spec: pricing is bit-identical to the clean problem
    nulled = problem.with_faults(FaultSpec())
    assert nulled.retry_mult is None
    assert nulled.split_T(cuts) == problem.split_T(cuts)
    assert np.array_equal(nulled.agg_T(cuts), problem.agg_T(cuts))
    # live retries only ever lengthen link traversals
    fp = problem.with_faults(FaultSpec(link_fail_rate=0.25, link_retries=3))
    assert fp.split_T(cuts) > problem.split_T(cuts)
    assert np.all(fp.agg_T(cuts) >= problem.agg_T(cuts))


# --------------------------------------------------------------------------- #
# corruption + guard quarantine
# --------------------------------------------------------------------------- #


@pytest.mark.nanfault
@pytest.mark.parametrize("mode", ["nan", "inf", "scale", "bitflip"])
def test_guard_quarantines_every_corrupt_mode(mode):
    spec = FaultSpec(corrupt_rate=0.5, corrupt_mode=mode)
    params = _params(jax.random.PRNGKey(0))
    corrupt = np.zeros(N, dtype=bool)
    corrupt[3] = True
    bad = apply_corruption(params, corrupt, spec)
    health, clean = guard_health(bad, N, GuardSpec())
    health = np.asarray(health)
    assert health[3] == 0.0, mode
    assert np.all(health[np.arange(N) != 3] == 1.0), mode
    for x in jax.tree.leaves(clean):
        assert np.all(np.isfinite(np.asarray(x))), mode


def test_guard_all_healthy_bit_identical():
    params = _params(jax.random.PRNGKey(1))
    plan = default_plan(8, N, cuts=(2, 5), intervals=(1, 1, 1),
                        entities=ENTITIES)
    plain = synchronize(params, plan, jnp.int32(0))
    guarded = synchronize(params, plan, jnp.int32(0), guard=GuardSpec())
    for a, b in zip(jax.tree.leaves(plain), jax.tree.leaves(guarded)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.nanfault
def test_guarded_sync_heals_quarantined_client():
    params = _params(jax.random.PRNGKey(2))
    plan = default_plan(8, N, cuts=(2, 5), intervals=(1, 1, 1),
                        entities=ENTITIES)
    corrupt = np.zeros(N, dtype=bool)
    corrupt[3] = True
    bad = apply_corruption(
        params, corrupt, FaultSpec(corrupt_rate=0.5, corrupt_mode="nan")
    )
    out = synchronize(bad, plan, jnp.int32(0), guard=GuardSpec())
    healthy = ~corrupt
    # a full (I=1 everywhere, top tier global) sync lands every client on
    # the participant-weighted mean of the HEALTHY uploads; the quarantined
    # client receives it too (heals) instead of spreading NaN
    for key, leaf in (
        (("frontend", "embed"), params["frontend"]["embed"]),
        (("units", "w"), params["units"]["w"]),
        (("head", "norm"), params["head"]["norm"]),
    ):
        want = np.mean(np.asarray(leaf)[healthy], axis=0)
        got = np.asarray(out[key[0]][key[1]])
        assert np.all(np.isfinite(got))
        for i in range(N):
            np.testing.assert_allclose(got[i], want, rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------------------- #
# cell-outage rerouting
# --------------------------------------------------------------------------- #


def test_outage_assignment_balanced_and_errors():
    assign = outage_assignment(8, 4, (0,))
    # healthy cells keep their block; cell 0's clients spread round-robin
    assert list(assign[2:]) == [1, 1, 2, 2, 3, 3]
    assert sorted(assign[:2]) == [1, 2]
    assert np.array_equal(
        outage_assignment(8, 4, ()), np.repeat(np.arange(4), 2)
    )
    with pytest.raises(ValueError, match="divisible"):
        outage_assignment(9, 4, (0,))
    with pytest.raises(ValueError, match="outside"):
        outage_assignment(8, 4, (7,))
    with pytest.raises(ValueError, match="no sibling"):
        outage_assignment(8, 4, (0, 1, 2, 3))


def test_membership_mean_identity_matches_reshape():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(8, 5)))
    members = assignment_members(outage_assignment(8, 4, ()), 4)
    out = np.asarray(membership_mean({"w": x}, members)["w"])
    want = np.asarray(x).reshape(4, 2, 5).mean(axis=1).repeat(2, axis=0)
    np.testing.assert_allclose(out, want, rtol=1e-6)


def test_membership_mean_reroutes_dead_cell():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(8, 3)))
    assign = outage_assignment(8, 4, (0,))
    members = assignment_members(assign, 4)
    # outage round: the dead cell's clients carry zero weight (their cell
    # is unreachable) but still receive their adoptive sibling's mean
    mask = jnp.asarray(np.where(np.arange(8) < 2, 0.0, 1.0).astype(np.float32))
    out = np.asarray(membership_mean({"w": x}, members, w=mask)["w"])
    xs = np.asarray(x)
    for i in (0, 1):  # adopted orphans
        j = assign[i]
        own = [k for k in range(2, 8) if assign[k] == j and k != i]
        np.testing.assert_allclose(
            out[i], xs[own].mean(axis=0), rtol=1e-6
        )


# --------------------------------------------------------------------------- #
# accounting: q-deflation
# --------------------------------------------------------------------------- #


def test_deflate_null_returns_base_object():
    base = ParticipationSpec(q=(0.8, 0.9, 1.0), deadline=12.0)
    assert deflate_participation(base, FaultSpec(), N, ENTITIES, 10) is base
    assert deflate_participation(base, None, N, ENTITIES, 10) is base
    assert deflate_participation(None, FaultSpec(), N, ENTITIES, 10) is None


def test_fault_survival_matches_realized_masks():
    spec = storm_spec(seed=3)
    rounds = 12
    s = fault_survival(spec, N, ENTITIES, rounds)
    acc = np.zeros(3)
    for r in range(rounds):
        healthy = round_healthy(spec, r, N, ENTITIES)
        for m, J in enumerate(ENTITIES):
            acc[m] += healthy.reshape(J, N // J).any(axis=1).mean()
    np.testing.assert_allclose(s, acc / rounds, rtol=1e-12)
    assert np.all(s > 0.0) and np.all(s <= 1.0)
    part = deflate_participation(None, spec, N, ENTITIES, rounds)
    np.testing.assert_allclose(np.asarray(part.q), s, rtol=1e-12)
    assert part.deadline is None
    # composes multiplicatively with a straggler-deadline base
    base = ParticipationSpec(q=(0.5, 0.5, 0.5), deadline=7.0)
    both = deflate_participation(base, spec, N, ENTITIES, rounds)
    np.testing.assert_allclose(np.asarray(both.q), 0.5 * s, rtol=1e-12)
    assert both.deadline == 7.0


def test_deflate_all_faulty_raises():
    with pytest.raises(ValueError, match="all-faulty"):
        deflate_participation(
            None, FaultSpec(crash_rate=1.0), N, ENTITIES, 4
        )


def test_deflated_q_inflates_theorem1_bound():
    hp = synthetic_hyperspec(16, N, seed=0)
    spec = storm_spec(seed=5)
    part = deflate_participation(None, spec, N, ENTITIES, 10)
    clean = theorem1_bound(hp, 50, (4, 2, 1), (5, 11))
    deflated = theorem1_bound(hp, 50, (4, 2, 1), (5, 11), participation=part)
    assert deflated > clean


# --------------------------------------------------------------------------- #
# control integration: sustained fault burst is a drift trigger
# --------------------------------------------------------------------------- #


def test_fault_burst_trips_drift_detector():
    from repro.control.drift import detect_drift

    quiet = detect_drift(1.0, 1.0, np.ones(2), np.ones(2), 1.0, 1.0,
                         rel_tol=0.5)
    assert not quiet.drifted
    burst = detect_drift(1.0, 1.0, np.ones(2), np.ones(2), 1.0, 1.0,
                         rel_tol=0.5, fault_rate_obs=0.4, fault_tol=0.1)
    assert burst.drifted and "faults" in burst.trigger
    assert burst.fault_rate == pytest.approx(0.4)
    # the default tolerance can never trip (rates are fractions <= 1)
    blind = detect_drift(1.0, 1.0, np.ones(2), np.ones(2), 1.0, 1.0,
                         rel_tol=0.5, fault_rate_obs=1.0)
    assert not blind.drifted


# --------------------------------------------------------------------------- #
# api layer: FaultsCfg round-trip + zero-fault solve collapse
# --------------------------------------------------------------------------- #


def test_faults_cfg_round_trip_and_validation():
    from repro.api import ExperimentSpec, FaultsCfg, fault_storm_spec

    spec = fault_storm_spec(rounds=8, checkpoint_every=2, engine_crash_round=4)
    loaded = ExperimentSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
    assert loaded == spec
    with pytest.raises(ValueError, match="corrupt_mode"):
        FaultsCfg(corrupt_mode="solar-flare")
    with pytest.raises(ValueError, match="engine_crash_round"):
        FaultsCfg(engine_crash_round=3)  # recovery needs checkpoints
    with pytest.raises(ValueError, match="norm_factor"):
        FaultsCfg(guard_norm_factor=0.5)


def test_control_cfg_fault_tol_validation():
    from repro.api import ControlCfg

    assert ControlCfg(fault_tol=0.25).fault_tol == 0.25
    with pytest.raises(ValueError, match="fault_tol"):
        ControlCfg(fault_tol=0.0)
    with pytest.raises(ValueError, match="fault_tol"):
        ControlCfg(fault_tol=1.5)


def test_api_null_faults_solve_collapses_bit_exact():
    from repro.api import FaultsCfg, paper_spec, run

    clean = run(paper_spec(seed=0))
    nulled = run(paper_spec(seed=0).replace(faults=FaultsCfg()))
    assert nulled.cuts == clean.cuts
    assert nulled.intervals == clean.intervals
    assert nulled.theta == clean.theta
    assert nulled.latency == clean.latency


def test_faults_require_engine_a():
    from repro.api import FaultsCfg, quickstart_spec
    from repro.api import build
    from repro.api.spec import RunCfg

    spec = quickstart_spec(rounds=2).replace(
        faults=FaultsCfg(crash_rate=0.1),
    )
    spec = spec.replace(run=RunCfg(mode="train", seed=0, rounds=2,
                                   lr=0.1, engine="b"))
    with pytest.raises(ValueError, match='engine="a"'):
        build(spec)


# --------------------------------------------------------------------------- #
# degenerate inputs fail loudly (DESIGN.md §16)
# --------------------------------------------------------------------------- #


def test_system_spec_rejects_zero_bandwidth():
    good = SystemSpec.paper_three_tier(num_clients=4, num_edges=2, seed=0)
    bad_up = tuple(np.array(a) for a in good.act_up)
    bad_up[0][1] = 0.0
    with pytest.raises(ValueError, match="act_up"):
        SystemSpec(
            M=good.M, num_clients=good.num_clients, entities=good.entities,
            compute=good.compute, act_up=bad_up, act_down=good.act_down,
            model_up=good.model_up, model_down=good.model_down,
            memory=good.memory,
        )


def test_layer_profile_rejects_zero_flops():
    U = 4
    ones = np.ones(U)
    kw = dict(
        n_units=U, flops_fwd=ones * 1e9, flops_bwd=ones * 2e9,
        act_bytes=ones, grad_act_bytes=ones, param_bytes=ones,
        opt_bytes=ones, frontend_param_bytes=0.0, head_param_bytes=0.0,
        batch=2,
    )
    LayerProfile(**kw)  # fine
    with pytest.raises(ValueError, match="flops_fwd"):
        LayerProfile(**{**kw, "flops_fwd": np.zeros(U)})
    with pytest.raises(ValueError, match="finite"):
        LayerProfile(**{**kw, "act_bytes": ones * np.inf})
    with pytest.raises(ValueError, match="shape"):
        LayerProfile(**{**kw, "param_bytes": np.ones(U + 1)})
    with pytest.raises(ValueError, match="batch"):
        LayerProfile(**{**kw, "batch": 0})


def test_empty_lattice_solver_raises_cleanly():
    # U=2 units across M=3 tiers leaves no valid cut vector at all
    prof = build_profile(VGG, batch=2)
    system = SystemSpec.paper_three_tier(num_clients=4, num_edges=2, seed=0)
    hp = synthetic_hyperspec(2, 4, seed=0)
    sliced = LayerProfile(
        n_units=2,
        flops_fwd=prof.flops_fwd[:2], flops_bwd=prof.flops_bwd[:2],
        act_bytes=prof.act_bytes[:2], grad_act_bytes=prof.grad_act_bytes[:2],
        param_bytes=prof.param_bytes[:2], opt_bytes=prof.opt_bytes[:2],
        frontend_param_bytes=prof.frontend_param_bytes,
        head_param_bytes=prof.head_param_bytes, batch=prof.batch,
    )
    problem = HsflProblem(sliced, system, hp, eps=1e9)
    assert problem.cut_lattice().shape[0] == 0
    with pytest.raises(ValueError, match="infeasible"):
        solve_ms(problem, (1, 1, 1), backend="numpy")
