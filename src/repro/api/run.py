"""``run(spec)`` — one dispatcher over the three things the repo can do.

* ``mode="solve"``    — optimize (I, μ) with the configured solver and
  report the schedule, Θ′, R-to-ε, and the Eq. 17/18 latency breakdown.
* ``mode="simulate"`` — same solve (typically against trace quantiles),
  then replay the schedule through the fleet simulator and report the
  per-round latency profile (p50/p95/worst, participants).
* ``mode="train"``    — real Engine-A/B split training with the schedule
  (solved or fixed), the spec's codec on the fed-server wire, and the
  Theorem-1 bound for the schedule actually trained.

Every mode returns the same ``ExperimentResult``; ``provenance`` is the
resolved spec, so the artifact alone reproduces the run.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import numpy as np

from ..core.bcd import solve_bcd
from ..core.ma_solver import solve_ma
from ..core.ms_solver import solve_ms
from .build import BuiltExperiment, build
from .result import ExperimentResult, jsonify
from .spec import ExperimentSpec


def _schedule(built: BuiltExperiment) -> Tuple[Tuple[int, ...], Tuple[int, ...]]:
    """Resolve the (cuts, intervals) the run uses, per the solver config."""
    s = built.spec.solver
    p = built.problem
    if s.kind == "bcd":
        res = solve_bcd(
            p,
            init_cuts=s.cuts,
            init_intervals=s.intervals,
            tol=s.tol,
            max_iters=s.max_iters,
            backend=s.backend,
        )
        return res.cuts, tuple(res.intervals)
    if s.kind == "ma":
        if s.cuts is None:
            raise ValueError('solver kind="ma" needs solver.cuts (fixed μ)')
        ma = solve_ma(p, s.cuts, backend=s.backend)
        return tuple(s.cuts), tuple(ma.intervals)
    if s.kind == "ms":
        if s.intervals is None:
            raise ValueError('solver kind="ms" needs solver.intervals (fixed I)')
        ms = solve_ms(p, s.intervals, backend=s.backend)
        return tuple(ms.cuts), tuple(s.intervals)
    # "fixed": evaluate the given schedule as-is
    if s.cuts is None or s.intervals is None:
        raise ValueError('solver kind="fixed" needs both solver.cuts and '
                         "solver.intervals")
    return tuple(s.cuts), tuple(s.intervals)


def _latency_breakdown(built: BuiltExperiment, cuts, intervals) -> Dict[str, Any]:
    p = built.problem
    if built.spec.scenario is None:
        pricing = "nominal"
    elif built.participation is not None:
        pricing = (
            f"{built.spec.scenario.name}"
            f"@deadline{built.participation.deadline:.4g}s"
        )
    else:
        pricing = f"{built.spec.scenario.name}@q{built.spec.scenario.quantile}"
    out = {
        "split_T": float(p.split_T(cuts)),
        "agg_T": [float(t) for t in p.agg_T(cuts)],
        "pricing": pricing,
    }
    if built.participation is not None:
        out["participation"] = {
            "deadline": built.participation.deadline,
            "q_tier": [float(v) for v in built.participation.q],
        }
    return out


def _simulate(built: BuiltExperiment, cuts, intervals) -> Dict[str, Any]:
    from ..sim import simulate_rounds

    sc = built.spec.scenario
    res = simulate_rounds(
        built.trace, cuts, intervals=intervals, backend=sc.backend
    )
    p50, p95, worst = np.quantile(res.total, [0.5, 0.95, 1.0])
    out = {
        "scenario": sc.name,
        "rounds": int(res.total.shape[0]),
        "split_p50": float(np.quantile(res.split, 0.5)),
        "split_p95": float(np.quantile(res.split, 0.95)),
        "total_p50": float(p50),
        "total_p95": float(p95),
        "total_worst": float(worst),
        "mean_participants": float(np.mean(res.participants)),
    }
    if built.participation is not None:
        from ..sim import participation_masks

        pr = participation_masks(
            built.trace, cuts, built.participation.deadline
        )
        out["participation"] = {
            "deadline": built.participation.deadline,
            "mean_rate": float(np.mean(pr.rates)),
            "q_tier": [float(v) for v in pr.q_tier],
            "expected_round_time": float(np.mean(pr.round_time)),
            "full_round_time": float(np.mean(res.split)),
        }
    return out


def _train(built: BuiltExperiment, cuts, intervals) -> Dict[str, Any]:
    """Real split training of the spec's model under the schedule."""
    import jax
    import jax.numpy as jnp

    from ..core.convergence import theorem1_bound
    from ..core.engine import (
        build_train_step_a,
        build_train_step_b,
        init_state_a,
        init_state_b,
    )
    from ..core.tiers import TierPlan
    from ..data import (
        image_loader,
        lm_loader,
        make_cifar10_like,
        make_lm_stream,
        partition_iid,
        partition_sort_and_shard,
    )
    from ..models.vgg import VggSpec, build_model
    from ..optim import adam, momentum, sgd

    spec = built.spec
    rc = spec.run
    model_spec = built.model_spec
    N = built.system.num_clients

    if isinstance(model_spec, VggSpec):
        ds = make_cifar10_like(rc.dataset_size, seed=rc.seed)
        labels = ds.labels
        mk_loader = lambda parts: image_loader(ds, parts, spec.model.batch, rc.seed)
    else:
        # train at the spec's literal seq so pricing, Theorem-1 bound, and
        # provenance all describe the run that actually happened
        if spec.model.seq < 2:
            raise ValueError(
                f'run mode="train" on LM arch {spec.model.arch!r} needs '
                f"model.seq >= 2 (next-token loss); got {spec.model.seq}"
            )
        ds = make_lm_stream(
            rc.dataset_size, spec.model.seq, model_spec.vocab_size, seed=rc.seed
        )
        labels = ds.tokens[:, 0] % 10
        mk_loader = lambda parts: lm_loader(ds, parts, spec.model.batch, rc.seed)

    parts = (
        partition_sort_and_shard(labels, N, 2, rc.seed)
        if rc.non_iid
        else partition_iid(len(labels), N, rc.seed)
    )
    loader = mk_loader(parts)
    model = build_model(model_spec)
    plan = TierPlan(
        n_units=model_spec.n_units,
        num_clients=N,
        cuts=tuple(cuts),
        intervals=tuple(intervals),
        entities=built.system.entities,
    )
    opt = {"sgd": sgd, "momentum": momentum, "adam": adam}[spec.model.optimizer](rc.lr)
    key = jax.random.PRNGKey(rc.seed)

    masks = None
    if built.participation is not None:
        # deadline-driven per-round client masks sampled from the fleet
        # trace at the schedule actually trained (DESIGN.md §12); the
        # trace replays cyclically past its horizon.
        from ..sim import participation_masks

        masks = participation_masks(
            built.trace, cuts, built.participation.deadline
        ).masks

    with_mask = masks is not None
    if rc.engine == "a":
        state = init_state_a(model, plan, opt, key)
        step = jax.jit(
            build_train_step_a(
                model, plan, opt, compressor=built.compressor,
                with_mask=with_mask,
            )
        )
    else:
        state = init_state_b(model, plan, opt, key)
        step = jax.jit(
            build_train_step_b(
                model, plan, opt, compressor=built.compressor,
                with_mask=with_mask,
            )
        )

    losses = []
    for r in range(rc.rounds):
        batch = {k: jnp.asarray(v) for k, v in loader.next_round().items()}
        if with_mask:
            mk = jnp.asarray(
                masks[r % masks.shape[0]], dtype=jnp.float32
            )
            state, loss = step(state, batch, mk)
        else:
            state, loss = step(state, batch)
        losses.append(float(loss))
        if rc.log_every and ((r + 1) % rc.log_every == 0 or r == 0):
            print(f"round {r+1:5d}  loss {losses[-1]:.4f}")

    omega = 0.0 if built.compression is None else built.compression.omega
    bound = theorem1_bound(
        built.hyper, max(1, rc.rounds), intervals, cuts, omega=omega,
        participation=built.participation,
    )
    out = {
        "engine": rc.engine,
        "rounds": rc.rounds,
        "first_loss": losses[0] if losses else None,
        "final_loss": losses[-1] if losses else None,
        "losses": losses,
        "thm1_bound": float(bound),
    }
    if with_mask:
        out["mean_participation"] = float(
            np.mean(masks[np.arange(rc.rounds) % masks.shape[0]])
        )
        out["deadline"] = built.participation.deadline
    return out


def evaluate_schedule(
    built: BuiltExperiment,
    cuts,
    intervals,
    mode: str = "solve",
) -> ExperimentResult:
    """Price one (I, μ) schedule under the built problem as a result.

    This is the solve-mode result body; benchmarks that already hold a
    solved schedule use it to emit artifacts without re-solving.
    """
    p = built.problem
    theta = float(p.theta(intervals, cuts))
    R = p.rounds(intervals, cuts)
    total = float(p.total_T(intervals, cuts, R)) if R is not None else None
    return ExperimentResult(
        mode=mode,
        cuts=tuple(int(c) for c in cuts),
        intervals=tuple(int(i) for i in intervals),
        theta=theta,
        rounds_to_eps=float(R) if R is not None else None,
        total_latency=total,
        latency=_latency_breakdown(built, cuts, intervals),
        provenance=jsonify(built.spec.to_dict()),
    )


def run(
    spec: ExperimentSpec, built: Optional[BuiltExperiment] = None
) -> ExperimentResult:
    """Build the spec, resolve its schedule, and produce the mode's result.

    Callers that already hold the ``build(spec)`` output pass it as
    ``built`` to avoid re-resolving registries / re-drawing the system.
    """
    import dataclasses

    if built is None:
        built = build(spec)
    elif built.spec != spec:
        raise ValueError("built was constructed from a different spec")
    if spec.run.mode == "simulate" and built.trace is None:
        # fail before the (expensive) solve, not after
        raise ValueError('run mode="simulate" needs a scenario section')
    cuts, intervals = _schedule(built)
    result = evaluate_schedule(built, cuts, intervals, mode=spec.run.mode)

    if spec.run.mode == "simulate":
        result = dataclasses.replace(
            result, sim=_simulate(built, cuts, intervals)
        )
    elif spec.run.mode == "train":
        result = dataclasses.replace(
            result, train=_train(built, cuts, intervals)
        )
    return result
