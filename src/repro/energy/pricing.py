"""Per-tier energy pricing of the HSFL round (DESIGN.md §15).

An ``EnergySpec`` carries J/FLOP compute prices per tier and J/byte
radio prices per link level; the round energy is the fleet-total

    E(I, μ) = E_S(μ) + Σ_m E_{m,A}(μ) / I_m

with the split energy E_S priced over the *same* canonical stage chain
as the latency model (``latency.split_stages`` / ``batched.stage_meta``)
and the aggregation energy E_{m,A} over the same fed-server model bits
λ_m.  The scalar walk and the lattice tables share one per-stage price
vector (``stage_energy_prices``) and accumulate in the same stage order,
so ``split_energy(cuts) == split_energy_lattice(...)[k]`` bit-for-bit —
the same contract the latency tables hold (``tests/test_energy.py``).

Energy reaches the solvers purely as the feasibility mask
``E(I, μ) ≤ budget_j_per_round``: it never enters the Θ' arithmetic, so
zero prices or an absent budget are exact no-ops on the optimum.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import numpy as np

from ..compress.base import CompressionSpec, model_ratio
from ..core.latency import BITS, LayerProfile, SystemSpec
from ..core.batched import model_bits_lattice, split_work_tensor, stage_meta


@dataclass(frozen=True)
class EnergySpec:
    """Per-tier energy prices + an optional per-round budget.

    ``compute_j_per_flop`` has one J/FLOP entry per tier (len M);
    ``act_j_per_byte`` one J/byte entry per activation boundary
    (len M−1, prices both the uplink and downlink leg of boundary m);
    ``model_j_per_byte`` one J/byte entry per fed-server level
    (len M−1, prices both the upload and download phase).
    """

    compute_j_per_flop: Tuple[float, ...]
    act_j_per_byte: Tuple[float, ...]
    model_j_per_byte: Tuple[float, ...]
    budget_j_per_round: Optional[float] = None

    def __post_init__(self):
        object.__setattr__(
            self, "compute_j_per_flop",
            tuple(float(v) for v in self.compute_j_per_flop),
        )
        object.__setattr__(
            self, "act_j_per_byte", tuple(float(v) for v in self.act_j_per_byte)
        )
        object.__setattr__(
            self, "model_j_per_byte",
            tuple(float(v) for v in self.model_j_per_byte),
        )
        for name in ("compute_j_per_flop", "act_j_per_byte", "model_j_per_byte"):
            if any(v < 0 for v in getattr(self, name)):
                raise ValueError(f"{name} has a negative price")
        if self.budget_j_per_round is not None:
            object.__setattr__(
                self, "budget_j_per_round", float(self.budget_j_per_round)
            )
            if self.budget_j_per_round <= 0:
                raise ValueError(
                    f"budget_j_per_round must be positive: "
                    f"{self.budget_j_per_round}"
                )

    def validate_for(self, M: int) -> "EnergySpec":
        if len(self.compute_j_per_flop) != M:
            raise ValueError(
                f"compute_j_per_flop has {len(self.compute_j_per_flop)} "
                f"tiers for an M={M} system"
            )
        for name in ("act_j_per_byte", "model_j_per_byte"):
            if len(getattr(self, name)) != M - 1:
                raise ValueError(
                    f"{name} has {len(getattr(self, name))} levels for an "
                    f"M={M} system (need M-1)"
                )
        return self

    @property
    def is_free(self) -> bool:
        """True when every price is zero AND no budget binds — the spec
        cannot move any optimum (the bit-exact-collapse witness)."""
        return (
            self.budget_j_per_round is None
            and not any(self.compute_j_per_flop)
            and not any(self.act_j_per_byte)
            and not any(self.model_j_per_byte)
        )


def default_energy_spec(
    M: int,
    compute_j_per_flop: float = 1e-11,
    act_j_per_byte: float = 2e-7,
    model_j_per_byte: float = 2e-7,
    budget_j_per_round: Optional[float] = None,
) -> EnergySpec:
    """Uniform price tables (edge-device ballpark: ~10 pJ/FLOP, ~0.2 µJ/B
    radio) — a convenient starting point for the presets/benchmarks."""
    return EnergySpec(
        compute_j_per_flop=(compute_j_per_flop,) * M,
        act_j_per_byte=(act_j_per_byte,) * (M - 1),
        model_j_per_byte=(model_j_per_byte,) * (M - 1),
        budget_j_per_round=budget_j_per_round,
    )


def stage_energy_prices(
    spec: EnergySpec, system: SystemSpec, M: int
) -> np.ndarray:
    """Fleet-total J-per-work price of every canonical-chain stage ``[S]``.

    Compute stages pay N · J/FLOP (every client's batch flows through the
    tier's hosted replica); link stages pay N · J/byte / 8 (stage works
    are bits).  Both the scalar walk and the lattice tables multiply
    these exact precomputed scalars, which is what makes them bit-equal.
    """
    N = float(system.num_clients)
    prices = []
    for kind, idx in stage_meta(M):
        if kind in ("compute_fwd", "compute_bwd"):
            prices.append(N * spec.compute_j_per_flop[idx])
        else:  # uplink / downlink share the boundary's radio price
            prices.append(N * spec.act_j_per_byte[idx] / BITS)
    return np.asarray(prices, dtype=np.float64)


def split_energy(
    profile: LayerProfile,
    system: SystemSpec,
    spec: EnergySpec,
    cuts: Sequence[int],
    compression: Optional[CompressionSpec] = None,
) -> float:
    """E_S(μ): fleet split-training energy per round — the scalar oracle,
    accumulated in canonical chain order."""
    from ..core.latency import split_stages

    prices = stage_energy_prices(spec, system, system.M)
    e = 0.0
    for s, p in zip(split_stages(profile, cuts, compression), prices):
        e = e + s.work * p
    return float(e)


def _lam_price(spec: EnergySpec, system: SystemSpec, m: int) -> float:
    """J per λ-bit of a level-m sync: J_m entities × (up + down) × J/byte."""
    return 2.0 * float(system.entities[m]) * spec.model_j_per_byte[m] / BITS


def agg_energy(
    profile: LayerProfile,
    system: SystemSpec,
    spec: EnergySpec,
    cuts: Sequence[int],
    m: int,
    compression: Optional[CompressionSpec] = None,
) -> float:
    """E_{m,A}(μ): fed-server sync energy of one level-m aggregation."""
    if system.entities[m] <= 1:
        return 0.0  # Eq. (15)/(16) indicator: no fed exchange at this level
    lam = profile.tier_param_bytes(cuts, m) * BITS * model_ratio(compression, m)
    return float(lam * _lam_price(spec, system, m))


def round_energy(
    profile: LayerProfile,
    system: SystemSpec,
    spec: EnergySpec,
    cuts: Sequence[int],
    intervals: Sequence[int],
    compression: Optional[CompressionSpec] = None,
) -> float:
    """E(I, μ) = E_S + Σ_m E_{m,A}/I_m — amortized round energy, summed
    in tier order (the accumulation shape of ``problem.numerator``)."""
    e = split_energy(profile, system, spec, cuts, compression)
    acc = agg_energy(profile, system, spec, cuts, 0, compression) / float(
        intervals[0]
    )
    for m in range(1, system.M - 1):
        acc = acc + agg_energy(
            profile, system, spec, cuts, m, compression
        ) / float(intervals[m])
    return float(e + acc)


def split_energy_lattice(
    profile: LayerProfile,
    system: SystemSpec,
    spec: EnergySpec,
    lattice: np.ndarray,
    compression: Optional[CompressionSpec] = None,
) -> np.ndarray:
    """``[K]`` E_S(μ) for every lattice row — identical per-stage
    multiply/accumulate order as the scalar ``split_energy``."""
    M = lattice.shape[1] + 1
    works = split_work_tensor(profile, lattice, compression)
    prices = stage_energy_prices(spec, system, M)
    e = np.zeros(lattice.shape[0])
    for s in range(works.shape[1]):
        e = e + works[:, s] * prices[s]
    return e


def agg_energy_lattice(
    profile: LayerProfile,
    system: SystemSpec,
    spec: EnergySpec,
    lattice: np.ndarray,
    compression: Optional[CompressionSpec] = None,
) -> np.ndarray:
    """``[K, M-1]`` E_{m,A}(μ) for every row — same λ·price order as the
    scalar ``agg_energy``."""
    M = lattice.shape[1] + 1
    lam = model_bits_lattice(profile, lattice, compression)
    out = np.zeros((lattice.shape[0], M - 1))
    for m in range(M - 1):
        if system.entities[m] <= 1:
            continue
        out[:, m] = lam[:, m] * _lam_price(spec, system, m)
    return out
