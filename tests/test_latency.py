"""Latency model Eqs. (11)-(19): hand-computed values + property tests."""
import numpy as np
import pytest

from repro.compress import CompressionSpec
from repro.configs.vgg16_cifar10 import SPEC as VGG
from repro.core.latency import (
    SystemSpec, aggregation_latency, build_profile, memory_ok,
    per_client_split_latency, split_latency, split_stages, stage_rate,
    total_latency,
)


def uniform_system(M=3, N=4, J2=2, f=1e12, r=1e8, mem=1e12):
    return SystemSpec(
        M=M, num_clients=N, entities=(N, J2, 1),
        compute=tuple(np.full(N, f) for _ in range(M)),
        act_up=tuple(np.full(N, r) for _ in range(M - 1)),
        act_down=tuple(np.full(N, r) for _ in range(M - 1)),
        model_up=(np.full(N, r), np.full(J2, r)),
        model_down=(np.full(N, r), np.full(J2, r)),
        memory=tuple(np.full(N, mem) for _ in range(M)),
    )


def test_split_latency_hand_computed():
    prof = build_profile(VGG, batch=2)
    sysu = uniform_system()
    cuts = (3, 8)
    ts = split_latency(prof, sysu, cuts)
    expect_compute = (prof.flops_fwd.sum() + prof.flops_bwd.sum()) / 1e12
    a3 = prof.act_bytes[2] * 8.0 * 2 / 1e8
    a8 = prof.act_bytes[7] * 8.0 * 2 / 1e8
    np.testing.assert_allclose(ts, expect_compute + 2 * a3 + 2 * a8, rtol=1e-9)


def test_aggregation_latency_indicator():
    prof = build_profile(VGG, batch=2)
    sysu = uniform_system()
    # top tier has one entity -> no fed-server traffic (Eq. 15/16 indicator)
    assert aggregation_latency(prof, sysu, (3, 8), 2) == 0.0
    t0 = aggregation_latency(prof, sysu, (3, 8), 0)
    lam = prof.param_bytes[:3].sum() + prof.frontend_param_bytes
    np.testing.assert_allclose(t0, 2 * lam * 8.0 / 1e8, rtol=1e-9)


def test_total_latency_floor_division():
    prof = build_profile(VGG, batch=2)
    sysu = uniform_system()
    R = 10
    t = total_latency(prof, sysu, (3, 8), [3, 2, 1], R)
    ts = split_latency(prof, sysu, (3, 8))
    t1 = aggregation_latency(prof, sysu, (3, 8), 0)
    t2 = aggregation_latency(prof, sysu, (3, 8), 1)
    np.testing.assert_allclose(t, R * ts + 3 * t1 + 5 * t2, rtol=1e-9)


def test_memory_constraint_detects_overflow():
    prof = build_profile(VGG, batch=2)
    assert memory_ok(prof, uniform_system(mem=1e12), (3, 8))
    assert not memory_ok(prof, uniform_system(mem=1e3), (3, 8))


def test_deeper_cut_moves_compute_to_lower_tier():
    prof = build_profile(VGG, batch=16)
    slow_devices = SystemSpec.paper_three_tier(compute_scale=0.01)
    shallow = split_latency(prof, slow_devices, (1, 8))
    deep = split_latency(prof, slow_devices, (10, 12))
    assert deep > shallow  # slow clients hurt more with deeper tier-1 cuts


# --------------------------------------------------------------------------- #
# property tests (random cuts, compression ratios)
# --------------------------------------------------------------------------- #


def _random_cut_vectors(n_units, M, count, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    while len(out) < count:
        cuts = tuple(sorted(int(c) for c in rng.integers(1, n_units, M - 1)))
        out.append(cuts)
    return out


@pytest.mark.parametrize("ratio", [None, 1.0, 0.5, 0.1])
def test_stage_durations_sum_to_split_latency(ratio):
    """The canonical stage chain IS the latency decomposition: per-client
    work/rate durations accumulate to T_S for every random cut vector."""
    prof = build_profile(VGG, batch=2)
    system = SystemSpec.paper_three_tier(num_clients=8, num_edges=2, seed=1)
    comp = None if ratio is None else CompressionSpec.uniform(
        3, model_ratio=ratio, act_ratio=ratio
    )
    for cuts in _random_cut_vectors(prof.n_units, 3, 12, seed=3):
        stages = split_stages(prof, cuts, comp)
        t = np.zeros(system.num_clients)
        for s in stages:
            t = t + s.work / stage_rate(system, s)
        np.testing.assert_array_equal(
            t, per_client_split_latency(prof, system, cuts, comp)
        )
        assert float(np.max(t)) == split_latency(prof, system, cuts, comp)


def test_latency_monotone_in_every_compression_ratio():
    """Fewer bits can never cost time: T_S and every T_{m,A} are monotone
    non-increasing in each act/model ratio separately."""
    prof = build_profile(VGG, batch=4)
    system = SystemSpec.paper_three_tier(seed=0)
    cuts = (3, 8)
    ratios = [1.0, 0.7, 0.4, 0.2, 0.05]
    # joint sweep
    joint = [
        (
            split_latency(prof, system, cuts,
                          CompressionSpec.uniform(3, r, act_ratio=r)),
            total_latency(prof, system, cuts, [2, 3, 1], 10,
                          CompressionSpec.uniform(3, r, act_ratio=r)),
        )
        for r in ratios
    ]
    for (s0, t0), (s1, t1) in zip(joint, joint[1:]):
        assert s1 <= s0 and t1 <= t0
    # each boundary's act ratio alone
    for m in range(2):
        prev = np.inf
        for r in ratios:
            ar = [1.0, 1.0]
            ar[m] = r
            comp = CompressionSpec(tuple(ar), (1.0, 1.0))
            cur = split_latency(prof, system, cuts, comp)
            assert cur <= prev
            prev = cur
    # each tier's model ratio alone
    for m in range(2):
        prev = np.inf
        for r in ratios:
            mr = [1.0, 1.0]
            mr[m] = r
            comp = CompressionSpec((1.0, 1.0), tuple(mr))
            cur = aggregation_latency(prof, system, cuts, m, comp)
            assert cur <= prev
            prev = cur


def test_compression_scales_exactly():
    """A uniform ratio r scales each client's communication time exactly
    linearly: t_n(r) == compute_n + r * (t_n(1) - compute_n).  (The max
    over clients is only piecewise linear — the argmax client can switch —
    so the identity is asserted per client.)"""
    prof = build_profile(VGG, batch=2)
    system = SystemSpec.paper_three_tier(seed=2)
    cuts = (3, 8)
    base = per_client_split_latency(prof, system, cuts)
    compute_only = per_client_split_latency(
        prof, system, cuts,
        CompressionSpec((1e-12, 1e-12), (1.0, 1.0)),
    )
    for r in (0.5, 0.25, 0.125):
        comp = CompressionSpec.uniform(3, 1.0, act_ratio=r)
        got = per_client_split_latency(prof, system, cuts, comp)
        np.testing.assert_allclose(
            got, compute_only + r * (base - compute_only), rtol=1e-9
        )
        agg = aggregation_latency(
            prof, system, cuts, 0, CompressionSpec.uniform(3, r)
        )
        np.testing.assert_allclose(
            agg, r * aggregation_latency(prof, system, cuts, 0), rtol=1e-12
        )


@pytest.mark.parametrize("ratio", [1.0, 0.5, 0.25, 0.1])
def test_trace_quantiles_collapse_to_paper_eqs_under_compression(ratio):
    """On the homogeneous-paper trace the TraceLatency quantiles equal
    Eqs. (17)/(18) exactly for every compression ratio."""
    from repro.sim import TraceLatency, make_trace

    prof = build_profile(VGG, batch=2)
    system = SystemSpec.paper_three_tier(num_clients=8, num_edges=2, seed=0)
    comp = CompressionSpec.uniform(3, model_ratio=ratio, act_ratio=ratio)
    trace = make_trace(
        "homogeneous-paper", prof, system, rounds=6, seed=0, compression=comp
    )
    lat = TraceLatency(trace, quantile=0.95)
    for cuts in [(3, 8), (2, 11), (5, 5)]:
        assert lat.split_T(cuts) == split_latency(prof, system, cuts, comp)
        for m in range(2):
            assert lat.agg_T(cuts, m) == aggregation_latency(
                prof, system, cuts, m, comp
            )
