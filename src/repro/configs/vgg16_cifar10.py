"""VGG-16 on CIFAR-10 — the paper's own experimental model (Sec. VII).

13 conv + 3 FC layers = 16 HSFL-cuttable units. The paper's Fig. 2 uses cut
layers L1=3, L2=8 on this network.
"""
from ..models.vgg import VggSpec

SPEC = VggSpec(
    name="vgg16-cifar10",
    conv_channels=(64, 64, 128, 128, 256, 256, 256, 512, 512, 512, 512, 512, 512),
    pool_after=(1, 3, 6, 9, 12),  # conv indices followed by 2x2 maxpool
    fc_dims=(512, 512, 10),
    image_size=32,
    in_channels=3,
    num_classes=10,
)

REDUCED = VggSpec(
    name="vgg16-reduced",
    conv_channels=(16, 16, 32),
    pool_after=(0, 2),
    fc_dims=(64, 10),
    image_size=16,
    in_channels=3,
    num_classes=10,
)
