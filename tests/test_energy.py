"""Per-tier energy pricing (DESIGN.md §15): scalar chain == lattice
tables bit-for-bit, spec validation, the free-spec bit-exact collapse,
and the budget mask moving the MA/BCD optimum identically on both
backends."""
import itertools

import numpy as np
import pytest

from repro.configs.vgg16_cifar10 import SPEC as VGG
from repro.core import (
    HsflProblem, SystemSpec, build_profile, solve_bcd, solve_ma,
    synthetic_hyperspec,
)
from repro.core.convergence import theorem1_bound
from repro.energy import (
    EnergySpec,
    agg_energy,
    agg_energy_lattice,
    default_energy_spec,
    round_energy,
    split_energy,
    split_energy_lattice,
)


def make_problem(seed=0, eps_scale=8.0, energy=None):
    prof = build_profile(VGG, batch=16)
    system = SystemSpec.paper_three_tier(seed=seed)
    hp = synthetic_hyperspec(VGG.n_units, 20, beta=3.0, seed=seed)
    floor = theorem1_bound(hp, 10**9, [1, 1, 1], (3, 8))
    return HsflProblem(
        prof, system, hp, eps=eps_scale * floor, energy=energy
    )


# --------------------------------------------------------------------- #
# scalar oracle == lattice tables
# --------------------------------------------------------------------- #


@pytest.mark.parametrize("seed", range(3))
def test_scalar_equals_lattice_bitexact(seed):
    """split/agg/round energy: the scalar canonical-chain walk and the
    whole-lattice tables are the same floats, not just close."""
    rng = np.random.default_rng(seed)
    prob = make_problem(seed=seed)
    spec = EnergySpec(
        compute_j_per_flop=tuple(rng.uniform(1e-12, 1e-10, prob.M)),
        act_j_per_byte=tuple(rng.uniform(1e-8, 1e-6, prob.M - 1)),
        model_j_per_byte=tuple(rng.uniform(1e-8, 1e-6, prob.M - 1)),
    )
    lattice = prob.cut_lattice()
    es = split_energy_lattice(prob.profile, prob.system, spec, lattice)
    ea = agg_energy_lattice(prob.profile, prob.system, spec, lattice)
    for k in rng.choice(lattice.shape[0], size=12, replace=False):
        cuts = tuple(int(c) for c in lattice[k])
        assert split_energy(prob.profile, prob.system, spec, cuts) == es[k]
        for m in range(prob.M - 1):
            assert (
                agg_energy(prob.profile, prob.system, spec, cuts, m)
                == ea[k, m]
            )
        iv = tuple(int(v) for v in rng.integers(1, 9, prob.M))
        scalar = round_energy(prob.profile, prob.system, spec, cuts, iv)
        batched = es[k] + sum(
            ea[k, m] / float(iv[m]) for m in range(prob.M - 1)
        )
        assert scalar == pytest.approx(batched, rel=0, abs=0)


def test_evaluator_round_energy_matches_problem_oracle(seed=1):
    """BatchedEvaluator.round_energy == HsflProblem.round_energy (the
    scalar oracle path through the attached spec), bit-for-bit."""
    rng = np.random.default_rng(seed)
    prob = make_problem(seed=seed, energy=default_energy_spec(3))
    ev = prob.evaluator("numpy")
    for _ in range(6):
        iv = tuple(int(v) for v in rng.integers(1, 9, prob.M))
        rows = ev.round_energy(iv)
        for k in rng.choice(ev.lattice.shape[0], size=8, replace=False):
            cuts = tuple(int(c) for c in ev.lattice[k])
            assert prob.round_energy(iv, cuts) == rows[k]


def test_class_energy_matches_scalar_oracle():
    """ClassBatchedEvaluator.round_energy_rows == class_round_energy for
    arbitrary per-class assignments, bit-for-bit."""
    from repro.core import ClassBatchedEvaluator, CutClassSpec
    from repro.core.classes import class_round_energy

    prob = make_problem(seed=2, energy=default_energy_spec(3))
    membership = CutClassSpec.uniform(20, 2, (2, 4))
    ev = ClassBatchedEvaluator(prob, membership, backend="numpy")
    rng = np.random.default_rng(0)
    K = ev.lattice.shape[0]
    assign = rng.integers(0, K, size=(10, 2))
    iv = (2, 3, 1)
    rows = ev.round_energy_rows(assign, iv)
    for r in range(assign.shape[0]):
        cuts = tuple(
            tuple(int(c) for c in ev.lattice[assign[r, c]]) for c in range(2)
        )
        spec_r = CutClassSpec(class_of=membership.class_of, cuts=cuts)
        assert class_round_energy(prob, spec_r, iv) == rows[r]


# --------------------------------------------------------------------- #
# spec validation
# --------------------------------------------------------------------- #


def test_energy_spec_validation():
    with pytest.raises(ValueError, match="negative"):
        EnergySpec((1e-11, -1.0, 1e-11), (0.0, 0.0), (0.0, 0.0))
    with pytest.raises(ValueError, match="positive"):
        EnergySpec((0.0,) * 3, (0.0,) * 2, (0.0,) * 2, budget_j_per_round=0.0)
    with pytest.raises(ValueError, match="M=3"):
        EnergySpec((0.0,) * 2, (0.0,) * 2, (0.0,) * 2).validate_for(3)
    with pytest.raises(ValueError, match="need M-1"):
        EnergySpec((0.0,) * 3, (0.0,) * 3, (0.0,) * 2).validate_for(3)
    assert EnergySpec((0.0,) * 3, (0.0,) * 2, (0.0,) * 2).is_free
    assert not default_energy_spec(3).is_free
    assert not EnergySpec(
        (0.0,) * 3, (0.0,) * 2, (0.0,) * 2, budget_j_per_round=1.0
    ).is_free


# --------------------------------------------------------------------- #
# the solvers: free collapse / binding budget
# --------------------------------------------------------------------- #


def test_free_spec_collapses_bitexact():
    """Zero prices + no budget: the attached spec is a no-op on BCD."""
    base = make_problem(seed=0)
    res0 = solve_bcd(base)
    free = EnergySpec((0.0,) * 3, (0.0,) * 2, (0.0,) * 2)
    res1 = solve_bcd(base.with_energy(free))
    assert (res1.cuts, res1.intervals) == (res0.cuts, res0.intervals)
    assert res1.theta == res0.theta


def test_priced_unbudgeted_spec_collapses_bitexact():
    """Nonzero prices but no budget: energy is reporting-only, never a
    mask, so the optimum still cannot move."""
    base = make_problem(seed=0)
    res0 = solve_bcd(base)
    res1 = solve_bcd(base.with_energy(default_energy_spec(3)))
    assert (res1.cuts, res1.intervals) == (res0.cuts, res0.intervals)
    assert res1.theta == res0.theta


def _binding_budget(prob, res0):
    """A budget strictly between the cheapest feasible round and E(opt)."""
    e_opt = prob.round_energy(res0.intervals, res0.cuts)
    ev = prob.evaluator("numpy")
    floor = np.inf
    for I in itertools.product((1, 2, 4, 8, 16, 32, 64), repeat=prob.M - 1):
        iv = I + (1,)
        ok = ev.mem_ok & (ev.denominator(iv) > ev.d_min)
        if ok.any():
            floor = min(floor, float(ev.round_energy(iv)[ok].min()))
    assert floor < e_opt
    return 0.5 * (floor + e_opt), e_opt


def test_binding_budget_moves_bcd_optimum_both_backends():
    """A budget below E(opt) forces a different schedule whose round
    energy fits, with weakly worse Θ' — identically on the scalar and
    numpy backends (shared candidate lists, same accumulation order)."""
    priced = make_problem(seed=0, energy=default_energy_spec(3))
    res0 = solve_bcd(priced)
    budget, e_opt = _binding_budget(priced, res0)
    prob = make_problem(
        seed=0, energy=default_energy_spec(3, budget_j_per_round=budget)
    )
    res_np = solve_bcd(prob, backend="numpy")
    res_sc = solve_bcd(prob, backend="scalar")
    assert (res_np.cuts, res_np.intervals) == (res_sc.cuts, res_sc.intervals)
    assert res_np.theta == res_sc.theta
    assert (res_np.cuts, res_np.intervals) != (res0.cuts, res0.intervals)
    assert prob.round_energy(res_np.intervals, res_np.cuts) <= budget
    assert res_np.theta >= res0.theta


def test_ma_budget_grid_scalar_equals_batched():
    """Under a binding budget the MA candidate set grows by the budget
    grid; both backends still pick the identical winner."""
    priced = make_problem(seed=1, energy=default_energy_spec(3))
    res0 = solve_bcd(priced)
    budget, _ = _binding_budget(priced, res0)
    prob = make_problem(
        seed=1, energy=default_energy_spec(3, budget_j_per_round=budget)
    )
    for cuts in (res0.cuts, (3, 8)):
        ma_np = solve_ma(prob, cuts, backend="numpy")
        ma_sc = solve_ma(prob, cuts, backend="scalar")
        assert ma_np.intervals == ma_sc.intervals
        assert ma_np.theta == ma_sc.theta
        if np.isfinite(ma_np.theta):
            assert prob.round_energy(ma_np.intervals, cuts) <= budget
