# The paper's primary contribution: the HSFL framework (engines), its
# convergence theory (Theorem 1 / Corollary 1), and the MA+MS system
# optimizer (Proposition 1, Dinkelbach, Algorithm 2 BCD).
from .convergence import (
    HyperSpec,
    ParticipationSpec,
    class_weighted_G2_sums,
    corollary1_rounds,
    synthetic_hyperspec,
    theorem1_bound,
)
from .latency import LayerProfile, SystemSpec, build_profile, total_latency
from .problem import HsflProblem
from .batched import BatchedEvaluator, cut_lattice
from .ma_solver import MaSolution, solve_ma, solve_ma_bruteforce
from .ms_solver import MsSolution, solve_ms, solve_ms_bruteforce
from .bcd import BcdResult, solve_bcd
from .classes import (
    ClassBatchedEvaluator,
    ClassBcdResult,
    ClassMsSolution,
    CutClassSpec,
    banded_assignment,
    solve_bcd_classes,
    solve_ma_classes,
    solve_ms_classes,
)
from .estimator import HyperEstimator, estimate_from_probe
from .tiers import (
    TierPlan,
    class_tier_members,
    default_plan,
    ragged_synchronize,
    synchronize,
    tier_subtrees,
)
from .engine import (
    TrainState,
    build_train_step_a,
    build_train_step_b,
    init_state_a,
    init_state_b,
)
