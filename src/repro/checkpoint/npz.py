"""Flat-npz checkpointing of arbitrary pytrees + HSFL schedule metadata.

Layout: one ``.npz`` holding every leaf under its '/'-joined key path plus a
JSON sidecar entry ``__meta__`` (step, tier plan, arbitrary user dict).
Restores exactly (structure is rebuilt from the key paths against a
template tree, so dtype/shape mismatches fail loudly).
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_seg(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _seg(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save_checkpoint(
    path: str,
    tree: Any,
    step: int = 0,
    meta: Optional[Dict[str, Any]] = None,
) -> None:
    flat = _flatten(tree)
    payload = dict(flat)
    payload["__meta__"] = np.frombuffer(
        json.dumps({"step": int(step), **(meta or {})}).encode(), dtype=np.uint8
    )
    # a bare filename has dirname '' — normalize to '.' so makedirs,
    # mkstemp and the directory fsync all address the CWD instead of
    # crashing on the empty string
    d = os.path.dirname(path) or "."
    os.makedirs(d, exist_ok=True)
    # atomic + durable write: tmp in the SAME directory (os.replace must
    # not cross filesystems), fsync the file so the rename never installs
    # a partially-flushed payload, then fsync the directory so the rename
    # itself survives a crash — a reader of ``path`` sees either the old
    # complete checkpoint or the new complete one, never a torn file
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp.npz")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **payload)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        dfd = os.open(d, os.O_RDONLY)
        try:
            os.fsync(dfd)
        finally:
            os.close(dfd)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)


def check_schedule_meta(
    meta: Dict[str, Any],
    expect_cuts: Optional[Any] = None,
    expect_intervals: Optional[Any] = None,
) -> None:
    """Fail loudly when a checkpoint's saved HSFL schedule metadata does not
    match the schedule the caller is resuming under.

    Resuming a tier-partitioned state under a different cut vector
    silently mis-assigns units to tiers even when every leaf shape lines
    up (Engine A states are client-stacked full models, so no shape check
    catches it).  Callers that know their resume schedule pass it here —
    a mismatch either needs an explicit migration
    (``repro.control.migrate.migrate_state``) or a resume at the saved
    schedule.
    """
    for name, expect in (("cuts", expect_cuts), ("intervals", expect_intervals)):
        if expect is None:
            continue
        saved = meta.get(name)
        if saved is None:
            raise ValueError(
                f"checkpoint has no {name!r} metadata to verify against "
                f"expected {tuple(int(v) for v in expect)}; re-save with "
                f"meta={{{name!r}: ...}} or load without the expectation"
            )
        saved_t = tuple(int(v) for v in saved)
        expect_t = tuple(int(v) for v in expect)
        if saved_t != expect_t:
            raise ValueError(
                f"checkpoint was saved under {name}={saved_t} but resume "
                f"requests {name}={expect_t}; migrate the tier assignment "
                f"explicitly (repro.control.migrate.migrate_state) or "
                f"resume at the saved schedule"
            )


def load_checkpoint(
    path: str,
    template: Any,
    expect_cuts: Optional[Any] = None,
    expect_intervals: Optional[Any] = None,
) -> Tuple[Any, int, Dict[str, Any]]:
    """Restore into the structure of ``template``; returns (tree, step, meta).

    ``expect_cuts`` / ``expect_intervals`` assert the saved schedule
    metadata matches the resume schedule (``check_schedule_meta``): a cut
    vector that moved between save and resume must fail loudly here, not
    silently mis-partition tiers downstream.
    """
    with np.load(path) as z:
        meta = json.loads(bytes(z["__meta__"]).decode())
        check_schedule_meta(meta, expect_cuts, expect_intervals)
        saved_cuts = meta.get("cuts")
        leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(template)
        new_leaves = []
        for path_keys, leaf in leaves_paths:
            key = "/".join(_seg(p) for p in path_keys)
            if key not in z:
                raise KeyError(f"checkpoint missing leaf {key!r}")
            arr = z[key]
            want = np.asarray(leaf)
            if arr.shape != want.shape:
                hint = (
                    f" (checkpoint metadata says cuts={tuple(saved_cuts)}; a "
                    f"template built for a different cut vector mis-shapes "
                    f"tier-stacked leaves — pass expect_cuts= to catch this "
                    f"up front)"
                    if saved_cuts is not None
                    else ""
                )
                raise ValueError(
                    f"{key}: shape {arr.shape} != template {want.shape}{hint}"
                )
            new_leaves.append(arr.astype(want.dtype))
    tree = jax.tree_util.tree_unflatten(treedef, new_leaves)
    step = int(meta.pop("step", 0))
    return tree, step, meta
