"""Per-class cut assignment (DESIGN.md §14): spec validation, the batched
product evaluator vs the scalar oracle, solver collapse/improvement, and
the ragged tier synchronization."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.vgg16_cifar10 import SPEC as VGG
from repro.core import (
    ClassBatchedEvaluator,
    CutClassSpec,
    HsflProblem,
    SystemSpec,
    banded_assignment,
    build_profile,
    class_tier_members,
    default_plan,
    ragged_synchronize,
    solve_bcd,
    solve_bcd_classes,
    solve_ma,
    solve_ma_classes,
    solve_ms,
    solve_ms_classes,
    synchronize,
    synthetic_hyperspec,
)
from repro.core.classes import (
    class_agg_T,
    class_split_T,
    class_theta,
    product_assignments,
)
from repro.core.convergence import class_weighted_G2_sums, theorem1_bound

N_CLIENTS = 20


def make_problem(seed=0, eps_scale=10.0, hetero=0.0):
    """Paper three-tier problem; ``hetero`` > 1 slows the odd half of the
    fleet's access links (activation and model wires) by that factor."""
    prof = build_profile(VGG, batch=16)
    system = SystemSpec.paper_three_tier(seed=seed)
    if hetero:
        slow = np.ones(N_CLIENTS)
        slow[1::2] = 1.0 / float(hetero)

        def scaled(tiers):
            return (tiers[0] * slow,) + tuple(tiers[1:])

        system = dataclasses.replace(
            system,
            act_up=scaled(system.act_up),
            act_down=scaled(system.act_down),
            model_up=scaled(system.model_up),
            model_down=scaled(system.model_down),
        )
    hp = synthetic_hyperspec(VGG.n_units, N_CLIENTS, beta=3.0, seed=seed)
    floor = theorem1_bound(hp, 10**9, [1, 1, 1], (3, 8))
    return HsflProblem(prof, system, hp, eps=eps_scale * floor)


# --------------------------------------------------------------------------- #
# spec validation + constructors
# --------------------------------------------------------------------------- #


def test_spec_validation():
    with pytest.raises(ValueError, match="at least one class"):
        CutClassSpec(class_of=(), cuts=())
    with pytest.raises(ValueError, match="contiguous"):
        CutClassSpec(class_of=(0, 2), cuts=((1, 2), (1, 2), (1, 2)))
    with pytest.raises(ValueError, match="contiguous"):  # class 1 empty
        CutClassSpec(class_of=(0, 0), cuts=((1, 2), (1, 2)))
    with pytest.raises(ValueError, match="same number of cuts"):
        CutClassSpec(class_of=(0, 1), cuts=((1, 2), (1,)))
    with pytest.raises(ValueError, match="non-decreasing"):
        CutClassSpec(class_of=(0,), cuts=((4, 2),))
    with pytest.raises(ValueError, match=">= 0"):
        CutClassSpec(class_of=(0,), cuts=((-1, 2),))


def test_spec_helpers():
    spec = CutClassSpec(class_of=(0, 1, 1, 0), cuts=((1, 3), (2, 4)))
    assert spec.num_classes == 2 and spec.num_clients == 4
    assert spec.class_sizes() == (2, 2)
    np.testing.assert_allclose(spec.weights(), [0.5, 0.5])
    assert spec.weights().sum() == 1.0
    np.testing.assert_array_equal(spec.members(1), [1, 2])
    np.testing.assert_array_equal(
        spec.client_cuts(), [[1, 3], [2, 4], [2, 4], [1, 3]]
    )
    assert not spec.is_uniform()
    assert spec.with_cuts(((1, 3), (1, 3))).is_uniform()
    uni = CutClassSpec.uniform(6, 3, (2, 5))
    assert uni.is_uniform() and uni.class_sizes() == (2, 2, 2)
    by_rate = CutClassSpec.from_rates([9.0, 1.0, 5.0, 7.0], 2, (2, 5))
    # slowest band first: clients 1 and 2 (rates 1, 5) are class 0
    np.testing.assert_array_equal(by_rate.class_of, (1, 0, 0, 1))


def test_banded_assignment():
    rates = np.array([5.0, 1.0, 5.0, 3.0, 2.0])
    a = banded_assignment(rates, 2)
    # 5 clients, 2 bands: slow band {1, 4, 3} then {0, 2} (stable ties)
    np.testing.assert_array_equal(a, [1, 0, 1, 0, 0])
    np.testing.assert_array_equal(a, banded_assignment(rates, 2))
    np.testing.assert_array_equal(banded_assignment(rates, 1), np.zeros(5))
    with pytest.raises(ValueError, match="num_classes"):
        banded_assignment(rates, 0)
    with pytest.raises(ValueError, match="num_classes"):
        banded_assignment(rates, 6)


# --------------------------------------------------------------------------- #
# scalar oracle: collapse to the single-cut objective
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("C", [1, 2, 4])
def test_scalar_oracle_collapses_uniform_classes(C):
    """Identical per-class cuts reproduce the single-cut pieces bit-for-bit
    for any class count — the heterogeneity machinery is free when there
    is no heterogeneity."""
    p = make_problem()
    cuts = (3, 8)
    intervals = (4, 2, 1)
    spec = CutClassSpec.uniform(N_CLIENTS, C, cuts)
    assert class_split_T(p, spec) == p.split_T(cuts)
    np.testing.assert_array_equal(class_agg_T(p, spec), p.agg_T(cuts))
    assert class_theta(p, spec, intervals) == p.theta(intervals, cuts)
    assert p.class_theta(spec, intervals) == p.theta(intervals, cuts)


def test_class_weighted_drift_mass():
    """d̄_m = Σ_c w_c d_m(μ_c), and uniform classes give the plain tier
    sums."""
    p = make_problem()
    g2 = p.hyper.G2
    uni = CutClassSpec.uniform(N_CLIENTS, 2, (3, 8))
    np.testing.assert_array_equal(
        class_weighted_G2_sums(g2, uni.cuts, uni.weights()),
        p.tier_d((3, 8)),
    )
    mixed = CutClassSpec(
        class_of=tuple([0] * 15 + [1] * 5), cuts=((2, 6), (4, 9))
    )
    d = class_weighted_G2_sums(g2, mixed.cuts, mixed.weights())
    expect = 0.75 * p.tier_d((2, 6)) + 0.25 * p.tier_d((4, 9))
    np.testing.assert_allclose(d, expect, rtol=1e-12)


def test_latency_model_pricing_rejected():
    """Per-class cuts are nominal-only: trace tables price one cut vector
    per row, so an attached latency_model must raise, not mis-price."""
    p = dataclasses.replace(make_problem(), latency_model=object())
    spec = CutClassSpec.uniform(N_CLIENTS, 2, (3, 8))
    with pytest.raises(ValueError, match="nominally"):
        class_split_T(p, spec)
    with pytest.raises(ValueError, match="nominally"):
        ClassBatchedEvaluator(p, spec)


# --------------------------------------------------------------------------- #
# batched product evaluator vs the scalar oracle
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_evaluator_matches_scalar_oracle(backend):
    """The product evaluator's objective pieces over random assignment
    matrices equal the scalar oracle bit-for-bit; Θ' itself agrees to the
    last association (the evaluator reports the Dinkelbach order
    ``scale·(N/D)``, the scalar the ``problem.theta`` order
    ``(scale·N)/D`` — one ulp apart), and the infeasible set is
    identical."""
    p = make_problem(seed=1)
    rng = np.random.default_rng(7)
    assign_ids = tuple(int(x) for x in rng.integers(0, 2, N_CLIENTS))
    # both ids present with overwhelming probability; pin it anyway
    assign_ids = (0, 1) + assign_ids[2:]
    spec = CutClassSpec(assign_ids, ((3, 8), (3, 8)))
    ev = ClassBatchedEvaluator(p, spec, backend=backend)
    intervals = (3, 2, 1)
    rows = rng.integers(0, ev.K, size=(40, 2))
    th = ev.theta_rows(rows, intervals)
    split = ev.split_T(rows)
    agg = ev.agg_T(rows)
    num = ev.numerator(rows, intervals)
    den = ev.denominator(rows, intervals)
    for r in range(rows.shape[0]):
        s = spec.with_cuts(ev.cuts_at(rows[r]))
        assert split[r] == class_split_T(p, s)
        np.testing.assert_array_equal(agg[r], class_agg_T(p, s))
        scalar = class_theta(p, s, intervals)
        if not np.isfinite(scalar):
            assert th[r] == scalar  # inf == inf
            continue
        assert num[r] == (
            class_split_T(p, s)
            + float(np.sum(class_agg_T(p, s) / np.asarray(intervals[:2], float)))
        )
        np.testing.assert_allclose(th[r], scalar, rtol=1e-14)
    assert np.all(np.isfinite(den[np.isfinite(th)]))


def test_product_assignments_enumerate_lexicographically():
    a = product_assignments(3, 2)
    assert a.shape == (9, 2)
    np.testing.assert_array_equal(a[:4], [[0, 0], [0, 1], [0, 2], [1, 0]])


# --------------------------------------------------------------------------- #
# solvers: collapse, descent, improvement
# --------------------------------------------------------------------------- #


def test_ms_classes_single_class_collapses_to_ms():
    p = make_problem(seed=2)
    intervals = (4, 2, 1)
    ms = solve_ms(p, intervals, backend="numpy")
    spec = CutClassSpec.uniform(N_CLIENTS, 1, (3, 8))
    cls = solve_ms_classes(p, spec, intervals, backend="numpy")
    assert cls.exhaustive
    assert cls.cuts == (ms.cuts,)
    assert cls.theta <= ms.theta * (1 + 1e-12)


def test_ma_classes_uniform_collapses_to_ma():
    p = make_problem(seed=2)
    cuts = (3, 8)
    ma = solve_ma(p, cuts)
    spec = CutClassSpec.uniform(N_CLIENTS, 3, cuts)
    cls = solve_ma_classes(p, spec)
    assert cls.intervals == ma.intervals
    assert cls.theta == ma.theta


def test_coordinate_descent_never_worse_than_single_cut():
    """product_budget=1 forces the CD fallback; seeded at the single-cut
    optimum it can only descend, and the exhaustive product bounds it."""
    p = make_problem(seed=3, hetero=8.0)
    intervals = (2, 2, 1)
    single = solve_ms(p, intervals, backend="numpy")
    spec = CutClassSpec.from_rates(
        p.system.model_up[0], 2, single.cuts
    )
    cd = solve_ms_classes(
        p, spec, intervals, backend="numpy", product_budget=1
    )
    assert not cd.exhaustive
    assert cd.theta <= single.theta * (1 + 1e-12)
    full = solve_ms_classes(p, spec, intervals, backend="numpy")
    assert full.exhaustive
    assert full.theta <= cd.theta * (1 + 1e-12)


def test_bcd_classes_uniform_fleet_collapses():
    """On a homogeneous fleet (tpu-pod mapping: identical clients) the
    per-class BCD lands every class on the single-cut BCD optimum."""
    prof = build_profile(VGG, batch=16)
    system = SystemSpec.tpu_pod_mapping()
    N = system.num_clients
    hp = synthetic_hyperspec(VGG.n_units, N, beta=3.0, seed=0)
    floor = theorem1_bound(hp, 10**9, [1] * system.M, (3, 8))
    p = HsflProblem(prof, system, hp, eps=10 * floor)
    single = solve_bcd(p, backend="numpy")
    spec = CutClassSpec.uniform(N, 2, single.cuts)
    res = solve_bcd_classes(p, spec, backend="numpy")
    assert res.theta == single.theta
    assert tuple(res.intervals) == tuple(single.intervals)
    assert all(c == single.cuts for c in res.class_cuts)


def test_bcd_classes_strictly_improves_on_heterogeneous_fleet():
    """With half the fleet 8x slower (compute + access links), giving the
    slow band its own split vector strictly lowers Θ' — the tentpole's
    acceptance claim at unit-test scale."""
    p = make_problem(seed=0, hetero=8.0)
    single = solve_bcd(p, backend="numpy")
    spec = CutClassSpec.from_rates(p.system.model_up[0], 2, single.cuts)
    res = solve_bcd_classes(p, spec, backend="numpy")
    assert res.theta < single.theta
    assert len(set(res.class_cuts)) > 1  # the classes actually split
    # monotone descent, like the single-cut BCD
    hist = list(res.history)
    for a, b in zip(hist, hist[1:]):
        assert b <= a * (1 + 1e-9)


def test_spec_client_count_must_match_system():
    p = make_problem()
    bad = CutClassSpec.uniform(N_CLIENTS + 2, 2, (3, 8))
    with pytest.raises(ValueError, match="clients"):
        ClassBatchedEvaluator(p, bad)


# --------------------------------------------------------------------------- #
# ragged tier synchronization
# --------------------------------------------------------------------------- #


def _stacked(key, N, U, d=3):
    ks = jax.random.split(key, 3)
    return {
        "frontend": {"embed": jax.random.normal(ks[0], (N, 4, d))},
        "units": {"w": jax.random.normal(ks[1], (N, U, d, d))},
        "head": {"norm": jax.random.normal(ks[2], (N, d))},
    }


def test_class_tier_members_partition_units():
    members = class_tier_members(
        6, [(1, 2), (2, 4)], [0, 0, 1, 1, 0, 1]
    )
    assert len(members) == 3
    total = sum(np.asarray(m) for m in members)
    np.testing.assert_array_equal(total, np.ones((6, 6)))
    # client 0 (class 0): tiers [0,1) [1,2) [2,6)
    np.testing.assert_array_equal(members[0][0], [1, 0, 0, 0, 0, 0])
    np.testing.assert_array_equal(members[1][0], [0, 1, 0, 0, 0, 0])
    # client 2 (class 1): tiers [0,2) [2,4) [4,6)
    np.testing.assert_array_equal(members[0][2], [1, 1, 0, 0, 0, 0])
    np.testing.assert_array_equal(members[1][2], [0, 0, 1, 1, 0, 0])


@pytest.mark.parametrize("step,masked", [(0, False), (1, False), (0, True)])
def test_ragged_sync_identical_classes_matches_synchronize(step, masked):
    """Same cuts in every class ⇒ the member matrices are the plan's tier
    slices and ragged sync is bit-identical to ``synchronize`` — with and
    without participation masks and the lossy fed wire."""
    N, U = 8, 6
    params = _stacked(jax.random.PRNGKey(21), N, U)
    plan = default_plan(U, N, cuts=(2, 4), intervals=(1, 2, 1),
                        entities=(N, 4, 1))
    members = class_tier_members(U, [(2, 4)] * 2, [i % 2 for i in range(N)])
    mask = (
        jnp.ones((N,), jnp.float32).at[3].set(0.0) if masked else None
    )
    lossy = lambda x: jnp.round(x * 4.0) / 4.0
    ref = synchronize(params, plan, jnp.int32(step),
                      compress_fn=lossy, mask=mask)
    out = ragged_synchronize(params, plan, members, jnp.int32(step),
                             compress_fn=lossy, mask=mask)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ragged_sync_matches_numpy_oracle():
    """Mixed-cut classes against an independent numpy re-statement of the
    schedule: per level, unit u averages over group members whose class
    holds u in that tier, and only those clients receive the mean."""
    N, U, d = 4, 4, 2
    class_of = [0, 1, 0, 1]
    class_cuts = [(1, 2), (2, 3)]
    params = _stacked(jax.random.PRNGKey(22), N, U, d=d)
    plan = default_plan(U, N, cuts=(1, 2), intervals=(1, 1, 1),
                        entities=(N, 2, 1))
    members = class_tier_members(U, class_cuts, class_of)
    out = ragged_synchronize(params, plan, members, jnp.int32(0))

    w = np.asarray(params["units"]["w"], dtype=np.float64)
    mem = [np.asarray(m) for m in members]
    # oracle: tiers in order; per tier the plan's levels (entity then fed)
    for m in range(3):
        for groups, _ in plan.levels(m):
            per = N // groups
            for g in range(groups):
                idx = np.arange(g * per, (g + 1) * per)
                for u in range(U):
                    sel = idx[mem[m][idx, u] > 0]
                    if sel.size:
                        w[sel, u] = w[sel, u].mean(axis=0)
    np.testing.assert_allclose(
        np.asarray(out["units"]["w"]), w, rtol=1e-5, atol=1e-6
    )
    # frontend joins tier 0 (global fed at I=1): all clients equal
    fe = np.asarray(out["frontend"]["embed"])
    np.testing.assert_allclose(
        fe, np.broadcast_to(fe.mean(0), fe.shape), rtol=1e-6
    )


def test_ragged_sync_fully_masked_round_is_identity():
    """The zero-participant keep-last fallback survives the ragged path:
    an all-masked round with a lossy fed wire changes nothing."""
    N, U = 8, 6
    params = _stacked(jax.random.PRNGKey(23), N, U)
    plan = default_plan(U, N, cuts=(2, 4), intervals=(1, 1, 1),
                        entities=(N, 4, 1))
    members = class_tier_members(
        U, [(2, 4), (1, 5)], [i % 2 for i in range(N)]
    )
    out = ragged_synchronize(
        params, plan, members, jnp.int32(0),
        compress_fn=lambda x: jnp.round(x * 4.0) / 4.0,
        mask=jnp.zeros((N,), jnp.float32),
    )
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ragged_sync_guards():
    N, U = 4, 4
    params = _stacked(jax.random.PRNGKey(24), N, U)
    plan = default_plan(U, N, cuts=(1, 2), intervals=(1, 1, 1),
                        entities=(N, 2, 1))
    members = class_tier_members(U, [(1, 2)], [0] * N)
    with pytest.raises(ValueError, match="member matrix per tier"):
        ragged_synchronize(params, plan, members[:2], jnp.int32(0))
