"""Closed-loop replay: wall-clock time-to-ε over a fleet trace.

Replays a trace round by round under either a fixed schedule or a live
``Controller``, accruing two ledgers per round:

* **wall clock** — the realized split latency of the round (masked max
  over the round's participants, ``sim.fleet.round_latency``) plus every
  tier sync that fires under the current intervals, plus — for the
  adaptive arm — the measured wall time of every control re-solve (the
  controller pays for its own thinking);
* **ε-progress** — the round's bound headroom D_t =
  c(q₁) − κ·Σ I² d_m/q_m (``control.bound.progress_per_round``) under
  the round's *realized* per-tier participation rates; ε is reached when
  Σ_t D_t ≥ 2ϑ/γ, which for a static schedule under constant q is
  exactly Corollary 1's round count.

Both arms use identical ledgers, so the comparison isolates exactly what
the controller changes: the schedule each round runs under.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..core.convergence import HyperSpec
from ..sim.events import fires
from ..sim.fleet import round_latency
from ..sim.participation import _tier_entity_rates
from ..sim.scenarios import SystemTrace
from .bound import progress_per_round, progress_target
from .controller import ControlDecision, Controller
from .telemetry import observe_round


@dataclass
class ReplayResult:
    reached: bool
    time_to_eps: float                 # seconds (inf when ε not reached)
    rounds_to_eps: Optional[int]
    wall: np.ndarray                   # [rounds_run] per-round seconds
    progress: np.ndarray               # [rounds_run] per-round D_t
    solve_overhead: float              # seconds of control re-solves paid
    decisions: List[ControlDecision] = field(default_factory=list)
    schedule_log: List[Tuple[int, Tuple[int, ...], Tuple[int, ...]]] = field(
        default_factory=list
    )                                  # (start_round, cuts, intervals)

    @property
    def n_switches(self) -> int:
        return sum(1 for d in self.decisions if d.switched)


def replay(
    trace: SystemTrace,
    hp: HyperSpec,
    eps: float,
    cuts: Sequence[int],
    intervals: Sequence[int],
    controller: Optional[Controller] = None,
    omega: float = 0.0,
    rounds: Optional[int] = None,
    min_q: float = 1e-6,
) -> ReplayResult:
    """Run up to ``rounds`` rounds (trace replays cyclically beyond its
    length) and report wall-clock time-to-ε.  ``controller=None`` is the
    static arm; with a controller, its decisions change the schedule the
    following round and its re-solve seconds accrue to the wall clock."""
    system = trace.system
    M = system.M
    cuts = tuple(int(c) for c in cuts)
    intervals = tuple(int(i) for i in intervals)
    R = trace.rounds if rounds is None else int(rounds)
    target = progress_target(hp)

    wall: List[float] = []
    progress: List[float] = []
    schedule_log = [(0, cuts, intervals)]
    cum = 0.0
    wall_cum = 0.0
    solve_overhead = 0.0
    reached = False
    rounds_to_eps: Optional[int] = None
    time_to_eps = float("inf")
    for r in range(R):
        rr = r % trace.rounds
        fr = round_latency(trace, rr, cuts, backend="numpy")
        state = trace.round_state(rr)
        q_t = np.clip(
            _tier_entity_rates(state.available, system.entities), min_q, 1.0
        )
        d_t = progress_per_round(
            hp, eps, intervals, cuts, omega, participation=q_t
        )
        w_t = fr.split
        for m in range(M - 1):
            if fires(r, intervals[m]):
                w_t = w_t + fr.agg[m]
        cum += d_t
        wall_cum += w_t
        wall.append(float(w_t))
        progress.append(float(d_t))
        if not reached and cum >= target:
            reached = True
            rounds_to_eps = r + 1
            time_to_eps = wall_cum
            break
        if controller is not None:
            obs = observe_round(trace, rr, cuts)
            controller.observe(obs)
            dec = controller.maybe_replan(r)
            if dec is not None:
                wall_cum += dec.solve_seconds
                solve_overhead += dec.solve_seconds
                if dec.switched:
                    cuts, intervals = dec.new_cuts, dec.new_intervals
                    schedule_log.append((r + 1, cuts, intervals))
    return ReplayResult(
        reached=reached,
        time_to_eps=float(time_to_eps),
        rounds_to_eps=rounds_to_eps,
        wall=np.asarray(wall),
        progress=np.asarray(progress),
        solve_overhead=float(solve_overhead),
        decisions=list(controller.decisions) if controller is not None else [],
        schedule_log=schedule_log,
    )
