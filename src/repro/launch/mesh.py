"""Production meshes (TPU v5e target).

Single pod:  (data=16, model=16)          = 256 chips
Multi-pod:   (pod=2, data=16, model=16)   = 512 chips

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS *before* any jax initialization).
The HSFL mapping (DESIGN.md §2): one index of the client axis — `data`,
or (`pod`, `data`) in multi-pod — hosts one client's parameter replicas;
`model` is Megatron-style tensor parallelism inside every tier; the `pod`
axis is an additional HSFL hierarchy level whose aggregation interval the
MA solver prices with DCN (not ICI) constants.
"""
from __future__ import annotations

import jax

POD_SHAPE = (16, 16)
MULTIPOD_SHAPE = (2, 16, 16)


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTIPOD_SHAPE if multi_pod else POD_SHAPE
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def client_axes(multi_pod: bool = False):
    """Mesh axes the client-stacked parameter axis is sharded over."""
    return ("pod", "data") if multi_pod else ("data",)


def num_clients(multi_pod: bool = False) -> int:
    """One HSFL client per (pod, data) index."""
    import math

    shape = MULTIPOD_SHAPE if multi_pod else POD_SHAPE
    return math.prod(shape) // shape[-1]


def make_debug_mesh(data: int = 2, model: int = 2, pods: int = 0):
    """Tiny host-device mesh for tests (requires the caller to have set
    --xla_force_host_platform_device_count accordingly)."""
    if pods:
        return jax.make_mesh((pods, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
