"""Figs. 2(b)/2(d)/8/9: MA-interval and cut-layer ablations.

Two layers of evidence:
  * analytic — bound tightness + communication overhead across (I1, I2)
    grids and cut sweeps (exact reproduction of the paper's trade-off);
  * empirical — REAL split training of a thin VGG on the synthetic CIFAR
    stand-in under different (I, μ), non-IID, showing the same ordering
    (I=1 best, PSL worst; shallow cuts beat deep cuts).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.api import build, paper_spec
from repro.configs.vgg16_cifar10 import SPEC as VGG
from repro.core.convergence import theorem1_bound
from repro.core.latency import aggregation_latency

from .common import emit


def analytic_rows(prob) -> list:
    rows = []
    # Fig. 2(b)/8: bound vs (I1, I2) + communication overhead per round
    for I1 in (1, 5, 20, 140):
        for I2 in (1, 5, 20):
            b = theorem1_bound(prob.hyper, 2000, [I1, I2, 1], (3, 8))
            comm = (
                aggregation_latency(prob.profile, prob.system, (3, 8), 0) / I1
                + aggregation_latency(prob.profile, prob.system, (3, 8), 1) / I2
            )
            rows.append(("fig8_ma", I1, I2, b, comm))
    # Fig. 2(d)/9: bound vs cuts at fixed intervals (I1=140, I2=20)
    for L1, L2 in [(1, 4), (3, 8), (5, 10), (8, 12), (12, 14)]:
        b = theorem1_bound(prob.hyper, 2000, [140, 20, 1], (L1, L2))
        rows.append(("fig9_ms", L1, L2, b, 0.0))
    return rows


def training_rows(rounds: int = 50, seed: int = 0) -> list:
    """Real non-IID training: *global held-out accuracy of the fed-server
    aggregate* under different schedules — the paper's Fig. 8/9 metric.
    (Local training loss would invert the ordering: PSL reaches lower local
    loss by overfitting each client's 2-class shard.)"""
    import jax
    import jax.numpy as jnp

    from repro.core import build_train_step_a, init_state_a
    from repro.core.tiers import default_plan
    from repro.data import image_loader, make_cifar10_like, partition_sort_and_shard
    from repro.models.vgg import VggModel
    from repro.optim import sgd

    spec = dataclasses.replace(
        VGG, conv_channels=(8, 8, 16, 16, 32, 32, 32), pool_after=(0, 1, 3, 5),
        fc_dims=(64, 32, 10), name="vgg-thin",
    )
    ds = make_cifar10_like(512, noise=0.4, seed=seed + 2)
    held = make_cifar10_like(256, noise=0.4, seed=seed + 99, template_seed=seed + 2)
    parts = partition_sort_and_shard(ds.labels, 8, 2, seed=seed + 2)
    model = VggModel(spec)
    eval_batch = {"images": jnp.asarray(held.images),
                  "labels": jnp.asarray(held.labels)}

    def global_acc(intervals, cuts):
        loader = image_loader(ds, parts, batch=8, seed=seed + 2)
        plan = default_plan(spec.n_units, 8, cuts=cuts, intervals=intervals,
                            entities=(8, 4, 1))
        opt = sgd(0.05)
        state = init_state_a(model, plan, opt, jax.random.PRNGKey(seed + 2))
        step = jax.jit(build_train_step_a(model, plan, opt))
        for _ in range(rounds):
            batch = {k: jnp.asarray(v) for k, v in loader.next_round().items()}
            state, _ = step(state, batch)
        gparams = jax.tree.map(lambda x: jnp.mean(x, axis=0), state.params)
        return float(model.accuracy(gparams, eval_batch))

    rows = []
    for name, I in [("sync", (1, 1, 1)), ("paper", (8, 4, 1)),
                    ("psl", (10_000, 10_000, 1))]:
        rows.append(("train_ma", name, 0, global_acc(I, (3, 6)), 0.0))
    for name, cuts in [("shallow", (2, 4)), ("mid", (3, 6)), ("deep", (5, 6))]:
        rows.append(("train_ms", name, 0, global_acc((8, 4, 1), cuts), 0.0))
    return rows


def main(quick: bool = False, seed: int = 0) -> list:
    prob = build(paper_spec(seed=seed)).problem
    rows = analytic_rows(prob)
    rows += training_rows(rounds=30 if quick else 50, seed=seed)
    emit(rows, ("ablation", "a", "b", "bound_or_acc", "comm_s_per_round"))
    # Insight-1 check: bound tightens monotonically as I shrinks
    grid = {(r[1], r[2]): r[3] for r in rows if r[0] == "fig8_ma"}
    assert grid[(1, 1)] <= grid[(5, 5)] if (5, 5) in grid else True
    assert grid[(1, 1)] <= grid[(140, 20)]
    # training ordering (paper Fig. 8 trend): frequent aggregation reaches
    # higher *global held-out accuracy* than PSL (never aggregates)
    tr = {r[1]: r[3] for r in rows if r[0] == "train_ma"}
    assert tr["sync"] >= tr["psl"], tr
    return rows


if __name__ == "__main__":
    main()
