"""Fig. 2(c): per-round end-to-end latency versus cut layer.

Sweeps L1 with L2 fixed (and vice versa) on the paper's client-edge-cloud
system, reproducing the non-monotone communication/computing trade-off the
paper uses to motivate MS optimization.
"""
from __future__ import annotations

from repro.api import build, evaluate_schedule, paper_spec
from repro.core.latency import aggregation_latency, split_latency

from .common import emit, record


def main(quick: bool = False, seed: int = 0) -> list:
    built = build(paper_spec(seed=seed))
    prob = built.problem
    rows = []
    swept = []  # (cuts, split_T) actually measured, for the artifact
    for L1 in range(1, 14):
        cuts = (L1, max(L1, 8))
        ts = split_latency(prob.profile, prob.system, cuts)
        ta = sum(
            aggregation_latency(prob.profile, prob.system, cuts, m) for m in range(2)
        )
        rows.append(("L1_sweep", L1, 8, ts, ta))
        swept.append((cuts, ts))
    for L2 in range(3, 15):
        cuts = (min(3, L2), L2)
        ts = split_latency(prob.profile, prob.system, cuts)
        ta = sum(
            aggregation_latency(prob.profile, prob.system, cuts, m) for m in range(2)
        )
        rows.append(("L2_sweep", cuts[0], L2, ts, ta))
        swept.append((cuts, ts))
    emit(rows, ("sweep", "L1", "L2", "split_latency_s", "agg_latency_s"))
    # artifact: the min-split-latency cut of the sweep, priced end to end
    best_cuts, _ = min(swept, key=lambda c_t: c_t[1])
    record(evaluate_schedule(built, best_cuts, (1, 1, 1)))
    # the motivating claim (Fig. 2c): latency is NON-MONOTONE in the cut
    # layer — deeper cuts trade device compute against activation size, so
    # the curve zigzags and the optimum is data-dependent.
    l1_vals = [r[3] for r in rows if r[0] == "L1_sweep"]
    rises = any(b > a for a, b in zip(l1_vals, l1_vals[1:]))
    falls = any(b < a for a, b in zip(l1_vals, l1_vals[1:]))
    assert rises and falls, ("expected non-monotone cut-layer latency", l1_vals)
    return rows


if __name__ == "__main__":
    main()
