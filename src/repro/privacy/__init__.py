# repro.privacy — DP-noised fed-server uplinks as a first-class cost
# (DESIGN.md §15).
#
# Two halves mirror the compression contract (§9): an *executable*
# DPMechanism (per-client clip + Gaussian noise applied to fed-server
# uploads inside Engine A, bit-exact noiseless collapse) and an *analytic*
# PrivacySpec + RDP Accountant (composition over rounds × the sampling
# rate q from the participation masks) that turns an (ε, δ) budget into a
# round cap R_max, i.e. a denominator floor D ≥ 2θ₀/(γ·R_max) for the
# MA/MS/BCD solvers.
from .accountant import (
    Accountant,
    epsilon_oracle,
    rdp_epsilon,
    rdp_vector,
    rounds_for_budget,
)
from .mechanism import DPMechanism, PrivacySpec

__all__ = [
    "Accountant",
    "DPMechanism",
    "PrivacySpec",
    "epsilon_oracle",
    "rdp_epsilon",
    "rdp_vector",
    "rounds_for_budget",
]
