import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
# The 512 placeholder host devices exist ONLY for this dry-run process;
# smoke tests and benchmarks see the real single CPU device.

"""Multi-pod dry-run CLI (deliverable e).

    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m \
        --shape train_4k --mesh pod [--tag baseline] [--seq-shard] \
        [--dtype bfloat16] [--out experiments/dryrun]

Lowers + compiles the requested (architecture × input-shape × mesh) case,
prints memory_analysis() / cost_analysis(), and writes the JSON record the
roofline benchmark consumes. ``--mesh multipod`` proves the `pod` axis
shards (2×16×16 = 512 chips); the roofline table itself is single-pod.
"""
import argparse
import json
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", choices=["pod", "multipod"], default="pod")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--opt", default="sgd")
    ap.add_argument("--dtype", default=None)
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--round", choices=["dynamic", "local", "sync"],
                    default="dynamic", dest="round_kind",
                    help="train-step round specialization (perf)")
    ap.add_argument("--cache-seq-shard", action="store_true",
                    help="decode: shard the KV-cache sequence dim over model")
    ap.add_argument("--donate-cache", action="store_true",
                    help="decode: donate cache buffers (in-place update)")
    ap.add_argument("--remat-policy", choices=["full", "dots", "outs"], default="full",
                    help="train: remat policy (dots saves matmul outputs)")
    ap.add_argument("--moe-shard", action="store_true",
                    help="moe: expert-parallel dispatch sharding constraint")
    ap.add_argument("--flash-train", action="store_true",
                    help="train: blockwise (flash-style) attention path")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--lower-only", action="store_true")
    args = ap.parse_args(argv)

    from repro.launch.dryrun_lib import DryrunCase, run_case, save_result

    case = DryrunCase(
        arch=args.arch,
        shape=args.shape,
        multi_pod=args.mesh == "multipod",
        opt_name=args.opt,
        remat=not args.no_remat,
        dtype=args.dtype,
        seq_shard=args.seq_shard,
        round_kind=args.round_kind,
        cache_seq_shard=args.cache_seq_shard,
        donate_cache=args.donate_cache,
        remat_policy=args.remat_policy,
        moe_shard=args.moe_shard,
        flash_train=args.flash_train,
        tag=args.tag,
    )
    meta = run_case(case, compile_=not args.lower_only)
    print(json.dumps(meta, indent=1, default=str))
    if not args.lower_only:
        path = save_result(meta, args.out)
        print(f"saved -> {path}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
