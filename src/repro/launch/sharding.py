"""PartitionSpec rules for every architecture family and execution path.

Layout contract (DESIGN.md §5):

* Training (Engine A): every parameter leaf is client-stacked on axis 0 —
  sharded over the client mesh axes (``data``, or ``pod+data`` multi-pod).
  Trailing *weight* dimensions get Megatron-style TP over ``model``:
  up-projections shard their output dim, down-projections their input dim,
  embedding/unembedding shard the vocab, MoE experts shard the expert axis
  (expert parallelism), Mamba projections shard the channel dim.
* Serving: one aggregated model copy — same TP rules, no client axis;
  decode batch shards over the client axes; the ``long_500k`` single-request
  shape shards the KV cache on the *sequence* dim over ``data`` (scores are
  combined by a GSPMD-inserted all-reduce) and SSM state on heads over
  ``model``.

Every rule is divisibility-guarded: a dim that does not divide its mesh
axis stays replicated (noted per-arch in the roofline table).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# name -> (axis position from the END of the leaf) to shard over `model`.
_TP_RULES: Dict[str, Optional[int]] = {
    # attention
    "wq": -1, "wk": -1, "wv": -1, "wo": -2,
    "bq": -1, "bk": -1, "bv": -1,
    "q_norm": None, "k_norm": None,
    # mlp
    "w1": -1, "w3": -1, "w2": -2,
    # embeddings
    "embed": -2, "unembed": -1, "proj": -1, "enc_pos": None,
    # moe (expert axis first; see _pspec_for_leaf)
    "router": None,
    # mamba
    "in_proj": -1, "out_proj": -2, "conv_w": -1, "gate_norm": -1,
    "A_log": None, "D": None, "dt_bias": None,
    # norms / vgg
    "norm": None, "w": None, "b": None,
}

_MOE_KEYS = {"w1", "w2", "w3"}


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for pp in path:
        if hasattr(pp, "key"):
            names.append(str(pp.key))
        elif hasattr(pp, "idx"):
            names.append(str(pp.idx))
    return tuple(names)


def _pspec_for_leaf(
    names: Tuple[str, ...],
    shape: Tuple[int, ...],
    tp: int,
    tp_axis: str,
    client_axes: Optional[Tuple[str, ...]],
) -> P:
    rank = len(shape)
    entries: list = [None] * rank
    if client_axes:
        entries[0] = client_axes if len(client_axes) > 1 else client_axes[0]
    leaf = names[-1] if names else ""
    in_moe = "moe" in names
    pos = _TP_RULES.get(leaf, None)
    if in_moe and leaf in _MOE_KEYS:
        # expert parallelism when E divides, else fall back to ff sharding
        e_pos = -3
        if shape[e_pos] % tp == 0:
            pos = e_pos
        else:
            pos = -1 if leaf in ("w1", "w3") else -2
    if pos is not None:
        idx = rank + pos
        clientish = 1 if client_axes else 0
        if idx >= clientish and shape[idx] % tp == 0 and shape[idx] >= tp:
            entries[idx] = tp_axis
    return P(*entries)


def param_pspecs(
    params: Any,
    *,
    tp: int = 16,
    tp_axis: str = "model",
    client_axes: Optional[Tuple[str, ...]] = None,
) -> Any:
    """Pytree of PartitionSpec matching ``params`` (shape tree or arrays)."""

    def f(path, leaf):
        shape = leaf.shape
        return _pspec_for_leaf(_path_names(path), shape, tp, tp_axis, client_axes)

    return jax.tree_util.tree_map_with_path(f, params)


def train_pspecs(
    tree: Any,
    client_axes: Tuple[str, ...],
    num_clients: Optional[int] = None,
) -> Any:
    """Client-axis-only pspecs for the sharded *training* step
    (``core.sharded``): shard axis 0 of every client-stacked leaf over
    the client mesh axes, replicate everything else (scalar bookkeeping
    like adam's step counter).

    Deliberately distinct from ``param_pspecs``: Megatron TP over
    ``model`` is a *serving* feature here — the training step keeps
    weights replicated across ``model`` and shards only the client
    axis.  (Calling ``param_pspecs(tp=1, ...)`` would NOT express that:
    every weight dim divides 1, so every ``_TP_RULES`` entry would
    spuriously shard over ``model``.)

    ``num_clients`` restricts the client-stacked test to leaves whose
    leading dim matches (safe over mixed trees like a ``TrainState``);
    ``None`` treats every non-scalar leaf as client-stacked.
    """
    ca = client_axes if len(client_axes) > 1 else client_axes[0]

    def f(leaf):
        shape = getattr(leaf, "shape", ())
        stacked = len(shape) > 0 and (
            num_clients is None or shape[0] == num_clients
        )
        if stacked:
            return P(ca, *([None] * (len(shape) - 1)))
        return P()

    return jax.tree.map(f, tree)


def batch_pspecs(batch: Any, client_axes: Tuple[str, ...]) -> Any:
    """Client-stacked batch leaves [N, b, ...]: shard the client axis."""
    ca = client_axes if len(client_axes) > 1 else client_axes[0]

    def f(leaf):
        return P(ca, *([None] * (len(leaf.shape) - 1)))

    return jax.tree.map(f, batch)


def opt_pspecs(opt_state: Any, pps: Any, opt_name: str) -> Any:
    """Optimizer-state pspecs follow the parameter pspecs leaf-for-leaf."""
    if opt_name == "sgd":
        return ()
    if opt_name == "momentum":
        return pps
    if opt_name == "adam":
        return {"m": pps, "v": pps, "t": P()}
    raise ValueError(opt_name)


def state_pspecs(spec_params: Any, opt_name: str, *, tp: int, client_axes):
    from ..core.engine import TrainState

    pps = param_pspecs(spec_params, tp=tp, client_axes=client_axes)
    return TrainState(
        params=pps, opt_state=opt_pspecs(None, pps, opt_name), step=P()
    )


# --------------------------------------------------------------------------- #
# serving
# --------------------------------------------------------------------------- #

# cache leaf name -> {mode: axis position from END to shard, mesh axes}
_CACHE_RULES = {
    # leaf: (batch_pos, long_pos, long_axis)
    "k": (-4, -3, "data"),
    "v": (-4, -3, "data"),
    "xk": (-4, -3, "data"),
    "xv": (-4, -3, "data"),
    "conv": (-3, -1, "model"),
    "state": (-4, -3, "model"),
    "positions": (None, None, None),
    "index": (None, None, None),
}


def cache_pspecs(
    caches: Any,
    *,
    batch: int,
    client_axes: Tuple[str, ...],
    tp: int = 16,
    long_context: bool = False,
    seq_shard: bool = False,
) -> Any:
    """Decode caches: shard batch when it divides; long_500k shards the
    sequence (attention) / heads (SSM) instead.

    ``seq_shard=True`` (perf, see EXPERIMENTS.md sect. Perf/qwen3-decode):
    additionally shard the attention-cache *sequence* dim over ``model``.
    The baseline keeps the cache replicated across model ranks, which (a)
    multiplies per-chip cache memory by tp and (b) makes GSPMD all-gather
    the full updated cache every token to satisfy the replicated output
    sharding. Seq-sharding stores 1/tp of the cache per chip and reduces
    the per-token collective to a scores-gather (~1000x smaller)."""
    import math

    n_client = math.prod(
        {"data": 16, "pod": 2}.get(a, 1) for a in client_axes
    )
    ca = client_axes if len(client_axes) > 1 else client_axes[0]

    def f(path, leaf):
        names = _path_names(path)
        leafname = names[-1] if names else ""
        rule = _CACHE_RULES.get(leafname)
        rank = len(leaf.shape)
        entries: list = [None] * rank
        if rule is None:
            return P(*entries)
        b_pos, l_pos, l_axis = rule
        if not long_context:
            if b_pos is not None and leaf.shape[rank + b_pos] % n_client == 0 \
               and leaf.shape[rank + b_pos] >= n_client:
                entries[rank + b_pos] = ca
            if seq_shard and l_pos is not None and leafname in ("k", "v") \
               and leaf.shape[rank + l_pos] % tp == 0 \
               and leaf.shape[rank + l_pos] >= tp:
                entries[rank + l_pos] = "model"
        else:
            if l_pos is not None:
                size = {"data": 16, "model": tp}[l_axis]
                if leaf.shape[rank + l_pos] % size == 0 and leaf.shape[rank + l_pos] >= size:
                    entries[rank + l_pos] = l_axis
        return P(*entries)

    return jax.tree_util.tree_map_with_path(f, caches)


def token_pspec(batch: int, client_axes: Tuple[str, ...]) -> P:
    import math

    n_client = math.prod({"data": 16, "pod": 2}.get(a, 1) for a in client_axes)
    ca = client_axes if len(client_axes) > 1 else client_axes[0]
    if batch % n_client == 0 and batch >= n_client:
        return P(ca, None)
    return P(None, None)


def to_shardings(mesh: Mesh, pspecs: Any) -> Any:
    return jax.tree.map(
        lambda ps: NamedSharding(mesh, ps),
        pspecs,
        is_leaf=lambda x: isinstance(x, P),
    )
