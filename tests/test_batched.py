"""Batched solver core (DESIGN.md §11): bit-exactness vs the scalar oracle.

Two layers of contract:

* property tests (hypothesis-style, seeded rng) over random profiles /
  systems / compression specs assert the batched Θ'/N/D/T_S/T_{m,A}/C5
  arrays equal the scalar per-cut walk bit-for-bit across the WHOLE
  lattice;
* solver-equivalence tests assert ``solve_ms``/``solve_ma``/``solve_bcd``
  on the batched backends return *identical* optima (same cuts, same
  intervals, same Θ', same Dinkelbach iterates) to ``backend="scalar"``
  on every registry system preset, including robust trace-quantile and
  compressed-wire problems, plus numpy-vs-jax table equality.
"""
import dataclasses

import numpy as np
import pytest

from repro.compress import CompressionSpec
from repro.configs.vgg16_cifar10 import SPEC as VGG
from repro.core import (
    BatchedEvaluator,
    HsflProblem,
    SystemSpec,
    build_profile,
    solve_bcd,
    solve_ma,
    solve_ms,
    synthetic_hyperspec,
)
from repro.core.batched import _HAS_JAX, resolve_backend
from repro.core.convergence import theorem1_bound
from repro.core.latency import LayerProfile


# --------------------------------------------------------------------------- #
# random problem generators (the hypothesis-style search space)
# --------------------------------------------------------------------------- #


def random_profile(rng, U):
    params = rng.uniform(1e3, 1e7, U)
    return LayerProfile(
        n_units=U,
        flops_fwd=rng.uniform(1e8, 1e12, U),
        flops_bwd=rng.uniform(1e8, 2e12, U),
        act_bytes=rng.uniform(1e2, 1e6, U),
        grad_act_bytes=rng.uniform(1e2, 1e6, U),
        param_bytes=params,
        opt_bytes=params * rng.uniform(0.0, 2.0),
        frontend_param_bytes=float(rng.uniform(0.0, 1e6)),
        head_param_bytes=float(rng.uniform(0.0, 1e6)),
        batch=int(rng.integers(1, 32)),
    )


def random_system(rng, M, N):
    J2 = int(rng.integers(1, N + 1))
    entities = (N, J2) if M == 2 else (N, J2, 1)
    # occasionally squeeze a tier's memory so C5 actually bites
    mem = tuple(
        np.full(
            N if m == 0 else (J2 if m == 1 else 1),
            float(rng.choice([1e9, 1e12, 1e15])),
        )
        for m in range(M)
    )
    return SystemSpec(
        M=M,
        num_clients=N,
        entities=entities,
        compute=tuple(rng.uniform(1e11, 1e13, N) for _ in range(M)),
        act_up=tuple(rng.uniform(1e7, 1e9, N) for _ in range(M - 1)),
        act_down=tuple(rng.uniform(1e7, 1e9, N) for _ in range(M - 1)),
        model_up=tuple(
            rng.uniform(1e7, 1e9, N if m == 0 else J2) for m in range(M - 1)
        ),
        model_down=tuple(
            rng.uniform(1e7, 1e9, N if m == 0 else J2) for m in range(M - 1)
        ),
        memory=mem,
    )


def random_problem(seed):
    rng = np.random.default_rng(seed)
    M = 2 + seed % 2
    U = int(rng.integers(6, 14))
    N = int(rng.integers(3, 9))
    prof = random_profile(rng, U)
    system = random_system(rng, M, N)
    hp = synthetic_hyperspec(
        U, N,
        beta=float(rng.uniform(1, 10)),
        g2_scale=float(rng.uniform(1, 30)),
        seed=seed,
    )
    even = tuple(max(1, (m + 1) * U // M) for m in range(M - 1))
    floor = theorem1_bound(hp, 10**9, [1] * M, even)
    comp = None
    if seed % 3 == 0:
        comp = CompressionSpec(
            act_ratio=tuple(rng.uniform(0.05, 1.0, M - 1)),
            model_ratio=tuple(rng.uniform(0.05, 1.0, M - 1)),
            omega=float(rng.uniform(0.0, 0.5)),
        )
    return HsflProblem(
        prof, system, hp,
        eps=float(rng.uniform(1.5, 10)) * floor,
        compression=comp,
    )


def assert_evaluator_matches_scalar(problem, ev, intervals_draws):
    th_b = {tuple(iv): ev.theta(iv) for iv in intervals_draws}
    num_b = {tuple(iv): ev.numerator(iv) for iv in intervals_draws}
    den_b = {tuple(iv): ev.denominator(iv) for iv in intervals_draws}
    for k, cuts in enumerate(problem.iter_cut_vectors()):
        assert ev.cuts_at(k) == cuts
        assert ev.split[k] == problem.split_T(cuts)
        np.testing.assert_array_equal(ev.agg[k], problem.agg_T(cuts))
        assert bool(ev.mem_ok[k]) == problem.memory_feasible(cuts)
        for iv in intervals_draws:
            key = tuple(iv)
            assert num_b[key][k] == problem.numerator(iv, cuts)
            assert den_b[key][k] == problem.denominator(iv, cuts)
            assert th_b[key][k] == problem.theta(iv, cuts)


# --------------------------------------------------------------------------- #
# property tests: whole-lattice bit-exactness
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("seed", range(8))
def test_batched_matches_scalar_on_random_problems(seed):
    problem = random_problem(seed)
    rng = np.random.default_rng(1000 + seed)
    M = problem.M
    draws = [
        [int(rng.integers(1, 12)) for _ in range(M - 1)] + [1]
        for _ in range(3)
    ]
    ev = problem.evaluator("numpy")
    assert ev.K == problem.cut_lattice().shape[0] > 0
    assert_evaluator_matches_scalar(problem, ev, draws)


def test_batched_matches_scalar_vgg_compressed():
    prof = build_profile(VGG, batch=16)
    system = SystemSpec.paper_three_tier(seed=0)
    hp = synthetic_hyperspec(VGG.n_units, 20, beta=3.0, seed=0)
    floor = theorem1_bound(hp, 10**9, [1, 1, 1], (3, 8))
    comp = CompressionSpec.uniform(3, model_ratio=0.25, act_ratio=0.5, omega=0.1)
    problem = HsflProblem(prof, system, hp, eps=5 * floor, compression=comp)
    ev = problem.evaluator("numpy")
    assert_evaluator_matches_scalar(problem, ev, [[2, 3, 1], [1, 1, 1]])


@pytest.mark.skipif(not _HAS_JAX, reason="jax not importable")
def test_jax_tables_bit_equal_numpy():
    for comp in (None, CompressionSpec.uniform(3, 0.25, act_ratio=0.5)):
        prof = build_profile(VGG, batch=16)
        system = SystemSpec.paper_three_tier(seed=1)
        hp = synthetic_hyperspec(VGG.n_units, 20, beta=3.0, seed=1)
        floor = theorem1_bound(hp, 10**9, [1, 1, 1], (3, 8))
        problem = HsflProblem(
            prof, system, hp, eps=5 * floor, compression=comp
        )
        ev_np = BatchedEvaluator(problem, backend="numpy")
        ev_jax = BatchedEvaluator(problem, backend="jax")
        np.testing.assert_array_equal(ev_np.split, ev_jax.split)
        np.testing.assert_array_equal(ev_np.agg, ev_jax.agg)


def test_trace_latency_batch_methods_match_scalar():
    from repro.sim import make_trace, robust_problem

    prof = build_profile(VGG, batch=8)
    system = SystemSpec.paper_three_tier(num_clients=6, num_edges=2, seed=0)
    hp = synthetic_hyperspec(VGG.n_units, 6, beta=3.0, seed=0)
    floor = theorem1_bound(hp, 10**9, [1, 1, 1], (3, 8))
    base = HsflProblem(prof, system, hp, eps=5 * floor)
    for name in ("straggler-tail", "flaky-wan", "diurnal-churn"):
        trace = make_trace(name, prof, system, rounds=6, seed=2)
        rp = robust_problem(base, trace, quantile=0.95)
        lm = rp.latency_model
        lat = rp.cut_lattice()
        split_b, agg_b = lm.split_T_batch(lat), lm.agg_T_batch(lat)
        for k, cuts in enumerate(rp.iter_cut_vectors()):
            assert split_b[k] == lm.split_T(cuts), (name, cuts)
            for m in range(rp.M - 1):
                assert agg_b[k, m] == lm.agg_T(cuts, m), (name, cuts, m)


# --------------------------------------------------------------------------- #
# solver equivalence: identical optima on every backend
# --------------------------------------------------------------------------- #


def _assert_same_bcd(problem):
    r_scalar = solve_bcd(problem, backend="scalar")
    r_numpy = solve_bcd(problem, backend="numpy")
    assert r_scalar == r_numpy, (r_scalar, r_numpy)
    return r_scalar


@pytest.mark.parametrize(
    "preset",
    ["paper-three-tier", "two-tier-client-edge", "two-tier-client-cloud",
     "tpu-pod", "four-tier-wan"],
)
def test_solvers_identical_on_registry_presets(preset):
    from repro.api import ExperimentSpec, HyperCfg, ModelCfg, SystemCfg, build

    spec = ExperimentSpec(
        model=ModelCfg(arch="vgg16-cifar10", batch=8),
        system=SystemCfg(
            preset=preset,
            num_clients=12,
            num_edges=1 if preset == "two-tier-client-cloud" else 4,
            seed=0,
        ),
        hyper=HyperCfg(beta=3.0, eps_scale=8.0),
    )
    problem = build(spec).problem
    res = _assert_same_bcd(problem)
    assert np.isfinite(res.theta)

    ms_s = solve_ms(problem, list(res.intervals), backend="scalar")
    ms_b = solve_ms(problem, list(res.intervals), backend="numpy")
    assert ms_s == ms_b
    # degenerate (empty-tier) cuts sit outside the lattice; solve_ma must
    # handle them on both paths
    M = problem.M
    for cuts in (res.cuts, tuple([2] * (M - 1))):
        ma_s = solve_ma(problem, cuts, backend="scalar")
        ma_b = solve_ma(problem, cuts, backend="numpy")
        assert ma_s == ma_b


def test_solvers_identical_under_participation():
    """Deadline-priced + 1/q-inflated problems solve to identical optima
    on the scalar oracle and the batched core (DESIGN.md §12)."""
    from repro.api import (
        ExperimentSpec, HyperCfg, ModelCfg, ParticipationCfg, ScenarioCfg,
        SystemCfg, build,
    )

    spec = ExperimentSpec(
        model=ModelCfg(arch="vgg16-cifar10", batch=8),
        system=SystemCfg(preset="paper-three-tier", num_clients=8,
                         num_edges=2, seed=1),
        hyper=HyperCfg(beta=3.0, eps_scale=8.0),
        scenario=ScenarioCfg(name="straggler-tail", rounds=8, seed=1),
        participation=ParticipationCfg(target_rate=0.75),
    )
    problem = build(spec).problem
    assert problem.latency_model is not None and problem.participation is not None
    _assert_same_bcd(problem)


@pytest.mark.slow
@pytest.mark.parametrize("seed", range(5))
def test_batched_property_seed_sweep_nightly(seed):
    """Nightly flakiness guard: the whole-lattice bit-exactness property
    and BCD backend equivalence re-rolled over 5 fixed seeds, with a
    random participation spec layered on top of the random problem."""
    from repro.core import ParticipationSpec

    problem = random_problem(100 + seed)
    rng = np.random.default_rng(2000 + seed)
    M = problem.M
    q = tuple(float(v) for v in rng.uniform(0.2, 1.0, M))
    deadline = float(rng.uniform(0.1, 10.0)) if seed % 2 else None
    problem = problem.with_participation(
        ParticipationSpec(q=q, deadline=deadline)
    )
    draws = [
        [int(rng.integers(1, 12)) for _ in range(M - 1)] + [1]
        for _ in range(3)
    ]
    assert_evaluator_matches_scalar(problem, problem.evaluator("numpy"), draws)
    err = {}
    res = {}
    for backend in ("scalar", "numpy"):
        try:
            res[backend] = solve_bcd(problem, backend=backend)
        except ValueError as e:  # infeasible random draw: both paths agree
            err[backend] = str(e)
    assert set(err) in (set(), {"scalar", "numpy"}), err
    if not err:
        assert res["scalar"] == res["numpy"]


def test_solvers_identical_robust_and_compressed():
    from repro.api import (
        CompressionCfg, ExperimentSpec, HyperCfg, ModelCfg, ScenarioCfg,
        SystemCfg, build,
    )

    spec = ExperimentSpec(
        model=ModelCfg(arch="vgg16-cifar10", batch=8),
        system=SystemCfg(preset="paper-three-tier", num_clients=8,
                         num_edges=2, seed=1),
        hyper=HyperCfg(beta=3.0, eps_scale=8.0),
        scenario=ScenarioCfg(name="straggler-tail", rounds=8, seed=1),
        compression=CompressionCfg(codec="int8", act_ratio=0.5),
    )
    problem = build(spec).problem
    assert problem.latency_model is not None and problem.compression is not None
    _assert_same_bcd(problem)


def test_run_spec_backend_knob():
    from repro.api import ExperimentSpec, ModelCfg, SolverCfg, SystemCfg, run

    base = ExperimentSpec(
        model=ModelCfg(arch="vgg16-cifar10", batch=8),
        system=SystemCfg(preset="paper-three-tier", num_clients=8, num_edges=2),
    )
    results = {}
    for backend in ("scalar", "numpy", "auto"):
        spec = base.replace(solver=SolverCfg(kind="bcd", backend=backend))
        # the knob survives the JSON round trip
        assert ExperimentSpec.from_dict(spec.to_dict()) == spec
        results[backend] = run(spec)
    assert (
        results["scalar"].cuts == results["numpy"].cuts == results["auto"].cuts
    )
    assert (
        results["scalar"].theta == results["numpy"].theta == results["auto"].theta
    )
    with pytest.raises(ValueError, match="backend"):
        SolverCfg(backend="cuda")


# --------------------------------------------------------------------------- #
# lattice memoization + backend resolution
# --------------------------------------------------------------------------- #


def test_cut_lattice_memoized_and_invalidated_by_with_compression():
    prof = build_profile(VGG, batch=8)
    system = SystemSpec.paper_three_tier(seed=0)
    hp = synthetic_hyperspec(VGG.n_units, 20, beta=3.0, seed=0)
    floor = theorem1_bound(hp, 10**9, [1, 1, 1], (3, 8))
    problem = HsflProblem(prof, system, hp, eps=5 * floor)

    lat = problem.cut_lattice()
    assert problem.cut_lattice() is lat  # one shared materialization
    assert [tuple(int(x) for x in r) for r in lat] == list(
        problem.iter_cut_vectors()
    )
    ev = problem.evaluator("numpy")
    assert problem.evaluator("numpy") is ev  # memoized per backend
    assert ev.lattice is lat

    comp = CompressionSpec.uniform(3, model_ratio=0.5)
    derived = problem.with_compression(comp)
    assert derived.cut_lattice() is not lat  # fresh caches on the new wire
    assert derived.evaluator("numpy") is not ev
    np.testing.assert_array_equal(derived.cut_lattice(), lat)  # same geometry


def test_resolve_backend():
    assert resolve_backend("numpy") == "numpy"
    with pytest.raises(ValueError, match="unknown batched backend"):
        resolve_backend("cuda")
    if _HAS_JAX:
        assert resolve_backend("auto", work_elems=10) == "numpy"
        assert resolve_backend("auto", work_elems=10**9) == "jax"


def test_solve_ma_rejects_unknown_backend():
    problem = random_problem(1)
    cuts = next(problem.iter_cut_vectors())
    with pytest.raises(ValueError, match="unknown batched backend"):
        solve_ma(problem, cuts, backend="scaler")  # typo'd "scalar"


def test_zero_participant_round_consistent_across_paths():
    """A round where every client is absent must price split=0 and skip the
    client-hosted tier's sync identically in the event oracle, the scalar
    fleet path, and the batched lattice path (it used to crash the scalar
    paths while the lattice path silently zeroed the sync)."""
    import dataclasses as _dc

    from repro.sim import TraceLatency, make_trace, simulate, simulate_rounds
    from repro.sim.fleet import simulate_lattice_rounds
    from repro.sim.scenarios import SystemTrace

    prof = build_profile(VGG, batch=4)
    system = SystemSpec.paper_three_tier(num_clients=6, num_edges=2, seed=0)
    base = make_trace("homogeneous-paper", prof, system, rounds=4, seed=0)
    empty = _dc.replace(
        base.round_state(0),
        available=np.zeros(system.num_clients, dtype=bool),
    )
    trace = SystemTrace(
        "with-dead-round", prof, system, base.rounds, 0,
        lambda r: empty if r == 1 else base.round_state(r),
    )
    cuts = (3, 8)
    ev = simulate(trace, cuts)
    fl = simulate_rounds(trace, cuts, backend="numpy")
    np.testing.assert_array_equal(ev.split, fl.split)
    np.testing.assert_array_equal(ev.agg, fl.agg)
    assert ev.split[1] == 0.0 and (ev.agg[0, 1] == 0.0)  # tier 0 is client-hosted

    lat = np.asarray([cuts], dtype=np.int64)
    split_b, agg_b = simulate_lattice_rounds(trace, lat, backend="numpy")
    np.testing.assert_array_equal(split_b[0], fl.split)
    np.testing.assert_array_equal(agg_b[0], fl.agg)

    lm = TraceLatency(trace, quantile=0.95)
    assert lm.split_T_batch(lat)[0] == lm.split_T(cuts)
    for m in range(2):
        assert lm.agg_T_batch(lat)[0, m] == lm.agg_T(cuts, m)
