"""async_scale: the sharded async HSFL engine and its pricing (DESIGN.md §17).

Four claims, all asserted:

1. **Staleness-0 collapse (bit-exact)** — the staleness-inflated Theorem-1
   bound at s ≡ 0 equals the synchronous bound bit-for-bit, and a REAL
   training run through the AsyncTrainer with all-zero staleness
   reproduces the synchronous fed_round dispatch's loss trajectory
   bit-for-bit (the trainer IS the production dispatch at s = 0).
2. **Async overlap beats the sync barrier at 10⁶ clients** — per-round
   wall clock on the paper-three-tier fleet scaled to a million clients:
   synchronous T_S + Σ T_m^A/I_m vs the bounded-staleness residual
   T_S + Σ max(0, T_m^A − s_m·T_S)/I_m, both from the Eq. 17/18 latency
   model and from fleet-simulator telemetry (observed per-round stage
   times on the straggler-tail scenario).
3. **Staleness-priced envelope** — a REAL async (s = 1) training run's
   measured average gradient norm sits below the staleness-inflated
   Theorem-1 bound with constants estimated from the same run, and that
   bound sits above the synchronous one (the (I+s)² − I² drift term).
4. **Sharded async round end-to-end** — a subprocess with
   XLA_FLAGS=--xla_force_host_platform_device_count=4 drives
   ``launch.train --shard-data 4 --staleness 1`` through the shard_map
   engine, the async queue drain, and the checkpoint save.
"""
from __future__ import annotations

import dataclasses
import os
import subprocess
import sys

import numpy as np

from .common import emit

CUTS = (3, 8)
INTERVALS = (2, 4, 1)
STALENESS = (1, 1, 0)


def _tiny_vgg():
    from repro.configs.vgg16_cifar10 import SPEC as VGG

    return dataclasses.replace(
        VGG, conv_channels=(8, 16, 16), pool_after=(0, 1), fc_dims=(32, 10),
        name="vgg-tiny",
    )


def _collapse_rows(quick: bool, seed: int) -> list:
    import jax
    import jax.numpy as jnp

    from repro.core import build_train_step_a, init_state_a
    from repro.core.async_agg import make_async_trainer
    from repro.core.convergence import synthetic_hyperspec, theorem1_bound
    from repro.core.tiers import default_plan
    from repro.data import image_loader, make_cifar10_like, partition_iid
    from repro.models.vgg import VggModel
    from repro.optim import sgd

    rows = []
    hp = synthetic_hyperspec(n_units=12, num_clients=20, seed=seed)
    base = theorem1_bound(hp, 500, INTERVALS, CUTS)
    zero = theorem1_bound(hp, 500, INTERVALS, CUTS, staleness=0)
    rows.append(("bound_s0_collapse", "thm1", base, zero, base == zero))

    spec = _tiny_vgg()
    N, rounds = 4, 6 if quick else 10
    plan = default_plan(spec.n_units, N, cuts=(2, 3), intervals=(4, 2, 1),
                        entities=(N, 2, 1))
    ds = make_cifar10_like(256, seed=seed + 3)
    model, opt = VggModel(spec), sgd(0.01)

    def batches():
        loader = image_loader(
            ds, partition_iid(len(ds), N, seed + 3), batch=8, seed=seed + 3
        )
        for _ in range(rounds):
            yield {k: jnp.asarray(v) for k, v in loader.next_round().items()}

    cache, sync_losses = {}, []
    state = init_state_a(model, plan, opt, jax.random.PRNGKey(seed))
    for r, batch in enumerate(batches()):
        fed = tuple((r + 1) % I == 0 if I > 1 else True
                    for I in plan.intervals)
        if fed not in cache:
            cache[fed] = jax.jit(
                build_train_step_a(model, plan, opt, fed_round=fed)
            )
        state, loss = cache[fed](state, batch)
        sync_losses.append(float(loss))

    tr = make_async_trainer(model, plan, opt, staleness=0)
    astate = init_state_a(model, plan, opt, jax.random.PRNGKey(seed))
    async_losses = []
    for r, batch in enumerate(batches()):
        astate, loss = tr.run_round(astate, batch, r)
        async_losses.append(float(loss))
    astate = tr.drain(astate)
    exact = async_losses == sync_losses and all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(astate.params),
                        jax.tree.leaves(state.params))
    )
    rows.append(("train_s0_collapse", "vgg-tiny", sync_losses[-1],
                 async_losses[-1], exact))
    assert all(r[-1] for r in rows), rows
    return rows


def _overlap_rows(quick: bool, seed: int) -> list:
    from repro.api import ModelCfg, SystemCfg, resolve_model, resolve_system
    from repro.core import build_profile
    from repro.core.async_agg import async_round_time
    from repro.core.latency import aggregation_latency, split_latency
    from repro.sim import make_trace, simulate_rounds

    prof = build_profile(resolve_model(ModelCfg(arch="vgg16-cifar10")), batch=16)
    rows = []
    # analytic Eq. 17/18 pricing — cheap even at a million clients
    for n in (1_000, 100_000, 1_000_000):
        system = resolve_system(SystemCfg(
            preset="paper-three-tier", num_clients=n,
            num_edges=max(1, n // 200), seed=seed,
        ))
        split_T = split_latency(prof, system, CUTS)
        agg_T = [aggregation_latency(prof, system, CUTS, m)
                 for m in range(system.M)]
        sync, asyn = async_round_time(split_T, agg_T, INTERVALS, STALENESS)
        rows.append(("overlap_analytic", n, sync, asyn, asyn < sync))
    # fleet-simulator telemetry drives the same pricing: observed stage
    # times on the straggler-tail scenario (the sim's arrival model)
    n = 100_000 if quick else 1_000_000
    system = resolve_system(SystemCfg(
        preset="paper-three-tier", num_clients=n,
        num_edges=max(1, n // 200), seed=seed,
    ))
    trace = make_trace("straggler-tail", prof, system, rounds=4, seed=seed)
    res = simulate_rounds(trace, CUTS, INTERVALS)
    split_T = float(np.mean(res.split))
    agg_T = [float(np.mean(res.agg[m])) for m in range(res.agg.shape[0])]
    agg_T += [0.0]  # top tier: the round barrier itself
    sync, asyn = async_round_time(split_T, agg_T, INTERVALS, STALENESS)
    rows.append(("overlap_fleet_sim", n, sync, asyn, asyn <= sync))
    assert all(r[-1] for r in rows), rows
    assert any(r[1] >= 1_000_000 for r in rows), "must price a 10^6 fleet"
    return rows


def _envelope_rows(quick: bool, seed: int) -> list:
    import jax
    import jax.numpy as jnp

    from repro.core.async_agg import make_async_trainer
    from repro.core.convergence import theorem1_bound
    from repro.core.estimator import HyperEstimator
    from repro.core.tiers import default_plan
    from repro.data import image_loader, make_cifar10_like, partition_iid
    from repro.models.vgg import VggModel
    from repro.optim import sgd

    spec = _tiny_vgg()
    N, gamma = 4, 0.01
    rounds = 15 if quick else 30
    staleness = (1, 0, 0)
    ds = make_cifar10_like(256, noise=0.4, seed=seed + 3)
    loader = image_loader(
        ds, partition_iid(len(ds), N, seed + 3), batch=8, seed=seed + 3
    )
    model = VggModel(spec)
    eval_batch = {"images": jnp.asarray(ds.images[:192]),
                  "labels": jnp.asarray(ds.labels[:192])}
    gbar_fn = jax.jit(lambda p, b: jax.grad(model.loss_fn)(p, b))
    grad_fn = jax.jit(
        lambda p, b: jax.vmap(jax.value_and_grad(model.loss_fn))(p, b)
    )

    plan = default_plan(spec.n_units, N, cuts=(2, 3), intervals=(4, 1, 1),
                        entities=(N, 2, 1))
    opt = sgd(gamma)
    tr = make_async_trainer(model, plan, opt, staleness=staleness)
    from repro.core import init_state_a

    state = init_state_a(model, plan, opt, jax.random.PRNGKey(seed + 3))
    est = HyperEstimator(plan.n_units, N, gamma)
    sq_norms = []
    for r in range(rounds):
        batch = {k: jnp.asarray(v) for k, v in loader.next_round().items()}
        losses, grads = grad_fn(state.params, batch)
        est.observe(state.params, grads, float(jnp.mean(losses)))
        wbar = jax.tree.map(lambda x: jnp.mean(x, axis=0), state.params)
        g = gbar_fn(wbar, eval_batch)
        sq_norms.append(float(
            sum(jnp.sum(x * x) for x in jax.tree.leaves(g))
        ))
        state, _ = tr.run_round(state, batch, r)
    state = tr.drain(state)
    hp = est.hyperspec()
    measured = float(np.mean(sq_norms))
    b_sync = theorem1_bound(hp, rounds, plan.intervals, plan.cuts)
    b_async = theorem1_bound(hp, rounds, plan.intervals, plan.cuts,
                             staleness=staleness)
    rows = [
        ("envelope_async_run", "s=1", measured, b_async, measured <= b_async),
        ("staleness_inflates", "s=1", b_sync, b_async, b_async > b_sync),
    ]
    assert all(r[-1] for r in rows), rows
    return rows


def _sharded_round_rows(quick: bool, seed: int) -> list:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = "src"
    cmd = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", "smollm-135m", "--rounds", "2", "--clients", "8",
        "--edges", "4", "--batch", "2", "--shard-data", "4",
        "--staleness", "1",
        "--log-every", "1", "--seed", str(seed),
    ]
    out = subprocess.run(cmd, env=env, capture_output=True, text=True,
                         timeout=540)
    ok = out.returncode == 0 and "sharded over" in out.stdout
    assert ok, (out.stdout[-1500:], out.stderr[-1500:])
    return [("sharded_round_subprocess", "smollm-135m x4dev", 2.0, 0.0, ok)]


def main(quick: bool = False, seed: int = 0) -> list:
    rows = []
    rows += _collapse_rows(quick, seed)
    rows += _overlap_rows(quick, seed)
    rows += _envelope_rows(quick, seed)
    rows += _sharded_round_rows(quick, seed)
    emit(rows, ("part", "case", "sync_or_measured", "async_or_bound", "holds"))
    assert all(r[-1] for r in rows), rows
    return rows


if __name__ == "__main__":
    main()
