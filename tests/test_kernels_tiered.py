"""tiered_aggregate Pallas kernels vs pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.tiered_aggregate import (
    tiered_aggregate, tiered_aggregate_q8, tiered_aggregate_ref,
)
from repro.kernels.tiered_aggregate.ops import aggregate_tree


@pytest.mark.parametrize("N,J", [(16, 4), (8, 2), (20, 5), (16, 16), (4, 1)])
@pytest.mark.parametrize("P", [257, 2048, 5000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_matches_ref(N, J, P, dtype):
    key = jax.random.PRNGKey(N * P)
    x = jax.random.normal(key, (N, P)).astype(dtype)
    w = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 1), (N,)))
    for de in (0, 1):
        for dg in (0, 1):
            out = tiered_aggregate(
                x, w, jnp.array(de), jnp.array(dg), J, use_pallas=True, interpret=True
            )
            ref = tiered_aggregate_ref(x, w, jnp.array(bool(de)), jnp.array(bool(dg)), J)
            tol = 1e-5 if dtype == jnp.float32 else 2e-2
            np.testing.assert_allclose(
                np.asarray(out, np.float32), np.asarray(ref, np.float32),
                rtol=tol, atol=tol,
            )


# --------------------------------------------------------------------------- #
# edge shapes: the padding branch at P % tile != 0, non-power-of-two N,
# degenerate entity counts (J = 1 and J = N), both dtypes, small tiles so a
# short P still spans several grid steps
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("N", [6, 20])          # not powers of two
@pytest.mark.parametrize("P", [100, 257, 999])  # none divisible by tile_p
@pytest.mark.parametrize("J", ["one", "n", "mid"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_edge_shapes_match_ref(N, P, J, dtype):
    tile_p = 128
    num_entities = {"one": 1, "n": N, "mid": 2}[J]
    key = jax.random.PRNGKey(N * 10_000 + P)
    x = jax.random.normal(key, (N, P)).astype(dtype)
    w = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 1), (N,)))
    for de in (0, 1):
        for dg in (0, 1):
            out = tiered_aggregate(
                x, w, jnp.array(de), jnp.array(dg), num_entities,
                tile_p=tile_p, use_pallas=True, interpret=True,
            )
            ref = tiered_aggregate_ref(
                x, w, jnp.array(bool(de)), jnp.array(bool(dg)), num_entities
            )
            tol = 1e-5 if dtype == jnp.float32 else 2e-2
            np.testing.assert_allclose(
                np.asarray(out, np.float32), np.asarray(ref, np.float32),
                rtol=tol, atol=tol,
            )


# --------------------------------------------------------------------------- #
# fused q8 path: bit-for-bit against the tile-mirroring oracle
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize(
    "N,J,P,tile", [(16, 4, 2048, 256), (6, 2, 257, 128), (20, 20, 1000, 128),
                   (4, 1, 100, 128), (12, 3, 333, 128)],
)
def test_q8_kernel_bit_exact_vs_oracle(N, J, P, tile):
    from repro.kernels.tiered_aggregate.check import assert_q8_matches_oracle

    assert_q8_matches_oracle(N, J, P, tile)


@pytest.mark.parametrize(
    "N,J,P,tile", [(16, 4, 300, 128), (6, 2, 257, 128), (20, 5, 999, 128)],
)
def test_ragged_q8_kernel_bit_exact_vs_oracle(N, J, P, tile):
    """Per-class membership (DESIGN.md §14): the ragged fused kernel vs its
    tile-mirroring oracle, the jit entry's branches, and the all-ones
    collapse onto the dense kernel (where the divisions align)."""
    from repro.kernels.tiered_aggregate.check import (
        assert_ragged_q8_matches_oracle,
    )

    assert_ragged_q8_matches_oracle(N, J, P, tile)


def test_q8_aggregation_close_to_lossless():
    """Quantize-then-aggregate deviates from the f32 aggregate by < 1 LSB."""
    key = jax.random.PRNGKey(9)
    x = jax.random.normal(key, (8, 700))
    w = jnp.full((8,), 1 / 8)
    lossless = tiered_aggregate(x, w, jnp.array(1), jnp.array(1), 4)
    q8 = tiered_aggregate_q8(x, w, jnp.array(1), jnp.array(1), 4, tile_p=128)
    lsb = float(jnp.max(jnp.abs(x))) / 127.0
    np.testing.assert_allclose(
        np.asarray(q8), np.asarray(lossless, np.float32), atol=lsb
    )


def test_aggregate_tree_quantized_roundtrip():
    key = jax.random.PRNGKey(11)
    tree = {
        "a": jax.random.normal(key, (8, 3, 5)),
        "b": {"c": jax.random.normal(jax.random.fold_in(key, 1), (8, 7))},
    }
    w = jnp.full((8,), 1 / 8)
    out = aggregate_tree(
        tree, w, jnp.array(1), jnp.array(0), 4, tile_p=128, quantized=True
    )
    ref = aggregate_tree(tree, w, jnp.array(1), jnp.array(0), 4)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
        assert a.shape == b.shape and a.dtype == b.dtype
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=0.05)


def test_flags_semantics():
    x = jnp.arange(8.0).reshape(4, 2)
    w = jnp.full((4,), 0.25)
    noop = tiered_aggregate(x, w, jnp.array(0), jnp.array(0), 2)
    np.testing.assert_allclose(noop, x)
    glob = tiered_aggregate(x, w, jnp.array(0), jnp.array(1), 2)
    np.testing.assert_allclose(glob, jnp.broadcast_to(x.mean(0), x.shape), rtol=1e-6)
    ent = tiered_aggregate(x, w, jnp.array(1), jnp.array(0), 2)
    np.testing.assert_allclose(ent[0], ent[1])
    np.testing.assert_allclose(ent[2], ent[3])
    assert not np.allclose(ent[0], ent[2])


def test_weighted_global_mean():
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (8, 100))
    w = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 1), (8,)))
    out = tiered_aggregate(x, w, jnp.array(0), jnp.array(1), 4)
    expect = jnp.sum(x * w[:, None], axis=0)
    np.testing.assert_allclose(out[3], expect, rtol=1e-5, atol=1e-6)


def test_aggregate_tree_matches_synchronize_level():
    """Kernel applied tree-wise == the engine's _group_mean at a full sync."""
    from repro.core.tiers import _group_mean

    key = jax.random.PRNGKey(5)
    tree = {
        "a": jax.random.normal(key, (8, 3, 5)),
        "b": {"c": jax.random.normal(jax.random.fold_in(key, 1), (8, 7))},
    }
    w = jnp.full((8,), 1 / 8)
    out = aggregate_tree(tree, w, jnp.array(1), jnp.array(0), 4)
    ref = _group_mean(tree, 4)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)
