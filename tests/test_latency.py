"""Latency model Eqs. (11)-(19) against hand-computed values."""
import numpy as np
import pytest

from repro.configs.vgg16_cifar10 import SPEC as VGG
from repro.core.latency import (
    SystemSpec, aggregation_latency, build_profile, memory_ok, split_latency,
    total_latency,
)


def uniform_system(M=3, N=4, J2=2, f=1e12, r=1e8, mem=1e12):
    return SystemSpec(
        M=M, num_clients=N, entities=(N, J2, 1),
        compute=tuple(np.full(N, f) for _ in range(M)),
        act_up=tuple(np.full(N, r) for _ in range(M - 1)),
        act_down=tuple(np.full(N, r) for _ in range(M - 1)),
        model_up=(np.full(N, r), np.full(J2, r)),
        model_down=(np.full(N, r), np.full(J2, r)),
        memory=tuple(np.full(N, mem) for _ in range(M)),
    )


def test_split_latency_hand_computed():
    prof = build_profile(VGG, batch=2)
    sysu = uniform_system()
    cuts = (3, 8)
    ts = split_latency(prof, sysu, cuts)
    expect_compute = (prof.flops_fwd.sum() + prof.flops_bwd.sum()) / 1e12
    a3 = prof.act_bytes[2] * 8.0 * 2 / 1e8
    a8 = prof.act_bytes[7] * 8.0 * 2 / 1e8
    np.testing.assert_allclose(ts, expect_compute + 2 * a3 + 2 * a8, rtol=1e-9)


def test_aggregation_latency_indicator():
    prof = build_profile(VGG, batch=2)
    sysu = uniform_system()
    # top tier has one entity -> no fed-server traffic (Eq. 15/16 indicator)
    assert aggregation_latency(prof, sysu, (3, 8), 2) == 0.0
    t0 = aggregation_latency(prof, sysu, (3, 8), 0)
    lam = prof.param_bytes[:3].sum() + prof.frontend_param_bytes
    np.testing.assert_allclose(t0, 2 * lam * 8.0 / 1e8, rtol=1e-9)


def test_total_latency_floor_division():
    prof = build_profile(VGG, batch=2)
    sysu = uniform_system()
    R = 10
    t = total_latency(prof, sysu, (3, 8), [3, 2, 1], R)
    ts = split_latency(prof, sysu, (3, 8))
    t1 = aggregation_latency(prof, sysu, (3, 8), 0)
    t2 = aggregation_latency(prof, sysu, (3, 8), 1)
    np.testing.assert_allclose(t, R * ts + 3 * t1 + 5 * t2, rtol=1e-9)


def test_memory_constraint_detects_overflow():
    prof = build_profile(VGG, batch=2)
    assert memory_ok(prof, uniform_system(mem=1e12), (3, 8))
    assert not memory_ok(prof, uniform_system(mem=1e3), (3, 8))


def test_deeper_cut_moves_compute_to_lower_tier():
    prof = build_profile(VGG, batch=16)
    slow_devices = SystemSpec.paper_three_tier(compute_scale=0.01)
    shallow = split_latency(prof, slow_devices, (1, 8))
    deep = split_latency(prof, slow_devices, (10, 12))
    assert deep > shallow  # slow clients hurt more with deeper tier-1 cuts
