"""Pallas TPU kernels: fused client→entity→global parameter aggregation.

The MA hot-spot of HSFL. The naive schedule reads the [N, P] client-stacked
shard from HBM twice (once for the Eq. 3 entity mean, once for the Eq. 4
fed-server mean); this kernel fuses both reduction levels into a single HBM
pass, tiling P into VMEM-resident [N, TILE_P] blocks (N ≤ 64 clients per
shard in practice, so a tile is ≤ 64·TILE_P·4 B — TILE_P=2048 ⇒ 512 KiB,
comfortably inside the ~16 MiB v5e VMEM with double buffering).

Grid: one program per P tile. The round flags (do_entity / do_global) and
the fed-server weights ride in SMEM via scalar prefetch so one compiled
kernel serves every round of the schedule.

``quantized_tiered_aggregate_pallas`` is the compressed-wire variant
(DESIGN.md §9): clients upload int8 payloads with one f32 scale per
``tile_p`` chunk (the ``compress.quantize`` wire format), and the kernel
fuses dequantize → entity mean → fed-server weighted mean in VMEM, so the
single HBM read is ~4× cheaper than the f32 path.  Each grid step's scale
column is a blocked VMEM input next to its int8 tile (the full scale array
is O(P) — too big for SMEM); ``ref.py`` carries the tile-mirroring oracle
the interpret-mode tests pin bit-for-bit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TILE_P = 2048


def _kernel(flags_ref, w_ref, x_ref, o_ref, *, num_entities: int):
    """flags_ref: SMEM [2] int32; w_ref: SMEM [N] f32; x/o: VMEM [N, TP]."""
    x = x_ref[...].astype(jnp.float32)  # [N, TP]
    N = x.shape[0]
    J = num_entities
    per = N // J
    do_entity = flags_ref[0] > 0
    do_global = flags_ref[1] > 0

    grouped = x.reshape(J, per, x.shape[1])
    emean = jnp.mean(grouped, axis=1, keepdims=True)
    emean = jnp.broadcast_to(emean, grouped.shape).reshape(x.shape)
    y1 = jnp.where(do_entity, emean, x)

    w = w_ref[...].astype(jnp.float32)[:, None]  # [N, 1]
    gmean = jnp.sum(y1 * w, axis=0, keepdims=True)
    y2 = jnp.where(do_global, jnp.broadcast_to(gmean, y1.shape), y1)
    o_ref[...] = y2.astype(o_ref.dtype)


def tiered_aggregate_pallas(
    x: jax.Array,        # [N, P]
    weights: jax.Array,  # [N] f32, sums to 1
    do_entity: jax.Array,  # scalar bool/int
    do_global: jax.Array,  # scalar bool/int
    num_entities: int,
    tile_p: int = TILE_P,
    interpret: bool = False,
) -> jax.Array:
    N, P = x.shape
    assert N % num_entities == 0, (N, num_entities)
    pad = (-P) % tile_p
    xp = jnp.pad(x, ((0, 0), (0, pad))) if pad else x
    Pp = xp.shape[1]
    flags = jnp.stack(
        [do_entity.astype(jnp.int32), do_global.astype(jnp.int32)]
    )

    grid = (Pp // tile_p,)
    out = pl.pallas_call(
        functools.partial(_kernel, num_entities=num_entities),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,  # flags, weights
            grid=grid,
            in_specs=[pl.BlockSpec((N, tile_p), lambda i, *_: (0, i))],
            out_specs=pl.BlockSpec((N, tile_p), lambda i, *_: (0, i)),
        ),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        interpret=interpret,
    )(flags, weights.astype(jnp.float32), xp)
    return out[:, :P] if pad else out


def _q8_kernel(flags_ref, w_ref, q_ref, s_ref, o_ref, *, num_entities: int):
    """flags/w in SMEM ([2] i32, [N] f32); q [N, TP] i8 and this tile's
    scale column s [N, 1] f32 in VMEM; o VMEM [N, TP] f32.

    One fused pass per tile: int8 → f32 dequant against the tile's scale
    column, then the same two-level (Eq. 3 + Eq. 4) reduction as
    ``_kernel``.  Scales are a *blocked* input, not scalar prefetch — the
    full [N, P/tile_p] scale array is O(P) and would blow SMEM on real
    leaves; only the O(N) flags/weights ride the prefetch path.  The op
    sequence is mirrored verbatim by ``ref.quantized_tiered_aggregate_ref``
    so interpret mode matches the oracle bit-for-bit.
    """
    s = s_ref[...].astype(jnp.float32)            # [N, 1]
    x = q_ref[...].astype(jnp.float32) * s        # dequantized [N, TP]
    N = x.shape[0]
    J = num_entities
    per = N // J
    do_entity = flags_ref[0] > 0
    do_global = flags_ref[1] > 0

    grouped = x.reshape(J, per, x.shape[1])
    emean = jnp.mean(grouped, axis=1, keepdims=True)
    emean = jnp.broadcast_to(emean, grouped.shape).reshape(x.shape)
    y1 = jnp.where(do_entity, emean, x)

    w = w_ref[...].astype(jnp.float32)[:, None]  # [N, 1]
    gmean = jnp.sum(y1 * w, axis=0, keepdims=True)
    y2 = jnp.where(do_global, jnp.broadcast_to(gmean, y1.shape), y1)
    o_ref[...] = y2


def _ragged_q8_kernel(
    flags_ref, w_ref, m_ref, q_ref, s_ref, o_ref, *, num_entities: int
):
    """Ragged (per-class cut) variant of ``_q8_kernel`` (DESIGN.md §14).

    ``m_ref`` (SMEM [N] f32, 0/1) marks the clients whose class holds this
    shard's units in the aggregating tier.  Non-members neither contribute
    to nor receive either reduction level — their replica of these units
    belongs to a different tier and is aggregated by that tier's schedule:

      entity:  em_g = Σ_{i∈g} member_i·x_i / max(Σ_{i∈g} member_i, 1)
               y1_i = (do_entity ∧ member_i ∧ Σ_g > 0) ? em_g : x_i
      global:  sw   = Σ_i w_i·member_i
               gm   = Σ_i y1_i·(w_i·member_i) / (sw > 0 ? sw : 1)
               y2_i = (do_global ∧ member_i ∧ sw > 0) ? gm : y1_i

    With member ≡ 1 and weights already normalized (Σ w = 1, exact for
    uniform 1/N at power-of-two N) every guard divide is by 1.0 or the
    exact group size, so the result is bit-identical to ``_q8_kernel`` —
    the collapse the interpret-mode tests pin.  Mirrored per tile by
    ``ref.ragged_quantized_tiered_aggregate_ref``.
    """
    s = s_ref[...].astype(jnp.float32)            # [N, 1]
    x = q_ref[...].astype(jnp.float32) * s        # dequantized [N, TP]
    N = x.shape[0]
    J = num_entities
    per = N // J
    do_entity = flags_ref[0] > 0
    do_global = flags_ref[1] > 0
    member = m_ref[...].astype(jnp.float32)[:, None]   # [N, 1]

    grouped = x.reshape(J, per, x.shape[1])
    mg = member.reshape(J, per, 1)
    sg = jnp.sum(mg, axis=1, keepdims=True)            # [J, 1, 1]
    emean = jnp.sum(grouped * mg, axis=1, keepdims=True) / jnp.maximum(
        sg, 1.0
    )
    emean = jnp.broadcast_to(emean, grouped.shape).reshape(x.shape)
    sg_rows = jnp.broadcast_to(sg, grouped.shape).reshape(x.shape)
    y1 = jnp.where(do_entity & (member > 0.0) & (sg_rows > 0.0), emean, x)

    wm = w_ref[...].astype(jnp.float32)[:, None] * member  # [N, 1]
    sw = jnp.sum(wm, axis=0, keepdims=True)                # [1, 1]
    gmean = jnp.sum(y1 * wm, axis=0, keepdims=True) / jnp.where(
        sw > 0.0, sw, 1.0
    )
    y2 = jnp.where(
        do_global & (member > 0.0) & (sw > 0.0),
        jnp.broadcast_to(gmean, y1.shape),
        y1,
    )
    o_ref[...] = y2


def quantized_tiered_aggregate_pallas(
    q: jax.Array,          # [N, Pp] int8, Pp % tile_p == 0 (wire payload)
    scales: jax.Array,     # [N, Pp // tile_p] f32 per-tile scales
    weights: jax.Array,    # [N] f32, sums to 1
    do_entity: jax.Array,  # scalar bool/int
    do_global: jax.Array,  # scalar bool/int
    num_entities: int,
    tile_p: int = TILE_P,
    interpret: bool = False,
) -> jax.Array:
    """Fused dequantize → two-level aggregate over the q8 wire format.

    Returns the aggregated model in f32 [N, Pp]; the padded tail (zeros on
    the wire) is the caller's to slice off.
    """
    N, Pp = q.shape
    assert N % num_entities == 0, (N, num_entities)
    assert Pp % tile_p == 0, (Pp, tile_p)
    assert scales.shape == (N, Pp // tile_p), (scales.shape, q.shape, tile_p)
    flags = jnp.stack(
        [do_entity.astype(jnp.int32), do_global.astype(jnp.int32)]
    )

    grid = (Pp // tile_p,)
    return pl.pallas_call(
        functools.partial(_q8_kernel, num_entities=num_entities),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,  # flags, weights (O(N) only)
            grid=grid,
            in_specs=[
                pl.BlockSpec((N, tile_p), lambda i, *_: (0, i)),
                pl.BlockSpec((N, 1), lambda i, *_: (0, i)),  # scale column
            ],
            out_specs=pl.BlockSpec((N, tile_p), lambda i, *_: (0, i)),
        ),
        out_shape=jax.ShapeDtypeStruct((N, Pp), jnp.float32),
        interpret=interpret,
    )(flags, weights.astype(jnp.float32), q, scales.astype(jnp.float32))


def ragged_quantized_tiered_aggregate_pallas(
    q: jax.Array,          # [N, Pp] int8, Pp % tile_p == 0 (wire payload)
    scales: jax.Array,     # [N, Pp // tile_p] f32 per-tile scales
    weights: jax.Array,    # [N] f32, sums to 1 over the member set
    member: jax.Array,     # [N] f32/bool, 1 = client's class holds these units
    do_entity: jax.Array,  # scalar bool/int
    do_global: jax.Array,  # scalar bool/int
    num_entities: int,
    tile_p: int = TILE_P,
    interpret: bool = False,
) -> jax.Array:
    """Fused dequantize → member-masked two-level aggregate (q8 wire).

    The per-class-cut sync path (``tiers.ragged_synchronize``) applied to
    one unit-range shard whose tier membership is uniform across columns
    but ragged across clients.  ``member`` rides SMEM scalar prefetch next
    to the flags and weights — it is O(N), like them.  An all-ones member
    is bit-identical to ``quantized_tiered_aggregate_pallas`` (see
    ``_ragged_q8_kernel``).
    """
    N, Pp = q.shape
    assert N % num_entities == 0, (N, num_entities)
    assert Pp % tile_p == 0, (Pp, tile_p)
    assert scales.shape == (N, Pp // tile_p), (scales.shape, q.shape, tile_p)
    flags = jnp.stack(
        [do_entity.astype(jnp.int32), do_global.astype(jnp.int32)]
    )

    grid = (Pp // tile_p,)
    return pl.pallas_call(
        functools.partial(_ragged_q8_kernel, num_entities=num_entities),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,  # flags, weights, member (all O(N))
            grid=grid,
            in_specs=[
                pl.BlockSpec((N, tile_p), lambda i, *_: (0, i)),
                pl.BlockSpec((N, 1), lambda i, *_: (0, i)),  # scale column
            ],
            out_specs=pl.BlockSpec((N, tile_p), lambda i, *_: (0, i)),
        ),
        out_shape=jax.ShapeDtypeStruct((N, Pp), jnp.float32),
        interpret=interpret,
    )(
        flags,
        weights.astype(jnp.float32),
        member.astype(jnp.float32),
        q,
        scales.astype(jnp.float32),
    )
