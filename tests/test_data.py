"""Data pipeline: synthetic sets, partitioners, federated loader."""
import numpy as np
import pytest

from repro.data import (
    FederatedLoader, image_loader, label_skew, lm_loader, make_cifar10_like,
    make_lm_stream, make_mnist_like, partition_iid, partition_sort_and_shard,
)


def test_dataset_shapes():
    c = make_cifar10_like(128)
    assert c.images.shape == (128, 32, 32, 3) and c.labels.shape == (128,)
    m = make_mnist_like(64)
    assert m.images.shape == (64, 28, 28, 1)
    lm = make_lm_stream(32, seq=16, vocab=100)
    assert lm.tokens.shape == (32, 17)
    assert lm.tokens.max() < 100


def test_lm_stream_learnable_structure():
    """Bigram chain: successor entropy << uniform (dataset is learnable)."""
    lm = make_lm_stream(512, seq=32, vocab=64, branching=4)
    succ = {}
    for row in lm.tokens:
        for a, b in zip(row[:-1], row[1:]):
            succ.setdefault(int(a), set()).add(int(b))
    avg_branch = np.mean([len(v) for v in succ.values()])
    assert avg_branch <= 4.5  # ~branching, far below vocab=64


@pytest.mark.parametrize("partitioner", ["iid", "shard"])
def test_partitions_disjoint_and_cover(partitioner):
    ds = make_cifar10_like(400)
    if partitioner == "iid":
        parts = partition_iid(len(ds), 20)
    else:
        parts = partition_sort_and_shard(ds.labels, 20, 2)
    allidx = np.concatenate(parts)
    assert len(allidx) == 400
    assert len(np.unique(allidx)) == 400


def test_non_iid_skew_exceeds_iid():
    ds = make_cifar10_like(2000)
    iid = partition_iid(len(ds), 20)
    nid = partition_sort_and_shard(ds.labels, 20, 2)
    assert label_skew(ds.labels, nid) > 3 * label_skew(ds.labels, iid)


def test_sort_and_shard_limits_classes_per_client():
    ds = make_cifar10_like(2000)
    parts = partition_sort_and_shard(ds.labels, 20, 2)
    n_classes = [len(np.unique(ds.labels[p])) for p in parts]
    assert max(n_classes) <= 4  # 2 shards -> at most ~2-3 classes


def test_loader_layout_and_determinism():
    ds = make_cifar10_like(200)
    parts = partition_iid(len(ds), 10)
    l1 = image_loader(ds, parts, batch=4, seed=7)
    l2 = image_loader(ds, parts, batch=4, seed=7)
    b1, b2 = l1.next_round(), l2.next_round()
    assert b1["images"].shape == (10, 4, 32, 32, 3)
    assert b1["labels"].shape == (10, 4)
    np.testing.assert_array_equal(b1["images"], b2["images"])


def test_loader_samples_within_partition():
    ds = make_cifar10_like(300)
    parts = partition_sort_and_shard(ds.labels, 10, 2)
    loader = image_loader(ds, parts, batch=8)
    batch = loader.next_round()
    for n in range(10):
        allowed = set(np.unique(ds.labels[parts[n]]))
        assert set(np.unique(batch["labels"][n])) <= allowed


def test_lm_loader_labels_are_shifted_tokens():
    lm = make_lm_stream(64, seq=16, vocab=50)
    loader = lm_loader(lm, partition_iid(64, 4), batch=4)
    b = loader.next_round()
    assert b["tokens"].shape == (4, 4, 16)
    assert b["labels"].shape == (4, 4, 16)
