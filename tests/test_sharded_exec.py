"""Real multi-device execution tests for the perf-variant shardings.

Runs in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
(conftest must NOT set it globally) and checks the seq-sharded KV-cache
decode (EXPERIMENTS.md sect. Perf / qwen3-decode) is bit-compatible with
the replicated-cache layout AND with unsharded single-device decode.
"""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_reduced
    from repro.launch import sharding as sh
    from repro.models.model import SplittableModel

    assert len(jax.devices()) == 8
    spec = get_reduced("qwen2-1.5b")
    model = SplittableModel(spec)
    key = jax.random.PRNGKey(0)
    params = model.init_params(key)
    B, C = 16, 64
    tok = jax.random.randint(jax.random.fold_in(key, 1), (B, 1), 0,
                             spec.vocab_size)

    # reference: plain single-logical-device decode
    caches0 = model.init_caches(B, C)
    ref_logits, ref_caches = jax.jit(model.decode_step)(
        params, tok, caches0, jnp.int32(0)
    )

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    pps = sh.param_pspecs(params, tp=4, client_axes=None)
    params_sh = jax.device_put(params, sh.to_shardings(mesh, pps))
    outs = {}
    for seq_shard in (False, True):
        cps = sh.cache_pspecs(
            jax.eval_shape(lambda: model.init_caches(B, C)),
            batch=B, client_axes=("data",), tp=4, seq_shard=seq_shard,
        )
        caches = jax.device_put(model.init_caches(B, C),
                                sh.to_shardings(mesh, cps))
        f = jax.jit(model.decode_step)
        logits, ncaches = f(params_sh, jax.device_put(tok), caches,
                            jnp.int32(0))
        outs[seq_shard] = np.asarray(logits)
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(ref_logits), rtol=2e-5, atol=2e-5,
            err_msg=f"seq_shard={seq_shard} diverges from reference",
        )
    np.testing.assert_allclose(outs[False], outs[True], rtol=2e-5, atol=2e-5)
    print("SHARDED-DECODE-OK")
""")


@pytest.mark.slow
def test_seq_sharded_cache_decode_equivalence():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SHARDED-DECODE-OK" in out.stdout


MOE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import numpy as np
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_reduced
    from repro.models import layers as L

    spec = get_reduced("granite-moe-1b-a400m")
    ms = dataclasses.replace(spec.moe, capacity_factor=8.0)  # no drops
    spec = dataclasses.replace(spec, moe=ms)
    p = L.init_moe(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, spec.d_model))
    ref, _ = L.moe(p, x, spec, groups=1)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    def constraint(b):
        g, e = b.shape[0], b.shape[1]
        pg = "data" if g % 2 == 0 else None
        pe = "model" if e % 4 == 0 else None
        return jax.lax.with_sharding_constraint(
            b, NamedSharding(mesh, P(pg, pe, None, None)))
    xs = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
    out, _ = jax.jit(
        lambda p_, x_: L.moe(p_, x_, spec, constraint=constraint, groups=2)
    )(p, xs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    print("SHARDED-MOE-OK")
""")


@pytest.mark.slow
def test_grouped_moe_sharded_equivalence():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", MOE_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SHARDED-MOE-OK" in out.stdout
