"""Assigned input shapes + ShapeDtypeStruct factories for the dry-run.

Shapes (assignment):
  train_4k       seq_len=  4,096  global_batch= 256  (training)
  prefill_32k    seq_len= 32,768  global_batch=  32  (inference-prefill)
  decode_32k     seq_len= 32,768  global_batch= 128  (inference-decode)
  long_500k      seq_len=524,288  global_batch=   1  (long-context-decode)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import jax
import jax.numpy as jnp

from ..models.spec import ModelSpec


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

# sliding window applied to quadratic-attention archs for long_500k
LONG_CONTEXT_WINDOW = 8192


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(spec: ModelSpec, shape: InputShape) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of a train/prefill
    step (no device allocation). Decode shapes use `decode_input_specs`."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if spec.family == "vlm":
        P = spec.prefix_len
        return {
            "patch_embeds": sds((B, P, spec.d_model), spec.cdtype),
            "tokens": sds((B, S - P), i32),
            "labels": sds((B, S - P), i32),
        }
    if spec.family == "audio":
        return {
            "frames": sds((B, spec.encoder_len, spec.d_model), spec.cdtype),
            "tokens": sds((B, S), i32),
            "labels": sds((B, S), i32),
        }
    return {"tokens": sds((B, S), i32), "labels": sds((B, S), i32)}


def concrete_inputs(spec: ModelSpec, batch: int, seq: int, key=None) -> Dict[str, jax.Array]:
    """Small concrete batch for smoke tests."""
    key = key if key is not None else jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    V = spec.vocab_size
    if spec.family == "vlm":
        P = spec.prefix_len
        return {
            "patch_embeds": jax.random.normal(k3, (batch, P, spec.d_model), spec.cdtype),
            "tokens": jax.random.randint(k1, (batch, seq - P), 0, V),
            "labels": jax.random.randint(k2, (batch, seq - P), 0, V),
        }
    if spec.family == "audio":
        return {
            "frames": jax.random.normal(k3, (batch, spec.encoder_len, spec.d_model), spec.cdtype),
            "tokens": jax.random.randint(k1, (batch, seq), 0, V),
            "labels": jax.random.randint(k2, (batch, seq), 0, V),
        }
    return {
        "tokens": jax.random.randint(k1, (batch, seq), 0, V),
        "labels": jax.random.randint(k2, (batch, seq), 0, V),
    }
