from .npz import load_checkpoint, save_checkpoint
