from .synthetic import (
    SyntheticImageDataset,
    SyntheticLmDataset,
    make_cifar10_like,
    make_lm_stream,
    make_mnist_like,
)
from .partition import label_skew, partition_iid, partition_sort_and_shard
from .loader import FederatedLoader, image_loader, lm_loader
