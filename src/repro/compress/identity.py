"""The no-op codec: full-precision wire, zero error.

Exists so every compression code path (engines, kernel wrapper, sweeps)
can be exercised with a ``Compressor`` whose output is bit-identical to
the uncompressed path — the differential anchor of
``tests/test_engines_equal.py``.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Identity:
    name: str = "identity"
    ratio: float = 1.0
    omega: float = 0.0

    def transform(self, x, key=None):
        return x
