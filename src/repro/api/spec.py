"""The declarative experiment specification (DESIGN.md §10).

One serializable dataclass tree — ``ExperimentSpec`` — describes everything
this repo can do with the paper's pipeline: which model profile to price
(Eqs. 11–16), which multi-tier system to price it on, which fleet-sim
regime to robustify against, which wire codec to compress with, which
solver to run (Algorithm 2 BCD / Proposition-1 MA / Dinkelbach MS), and
what the run should produce (an optimized schedule, a simulated latency
profile, or a real Engine-A/B training run).

Every field is a plain JSON value (str / int / float / bool, tuples of
those, or a flat mapping), so a spec survives ``json.dumps(spec.to_dict())``
→ disk → ``ExperimentSpec.from_dict(json.loads(...))`` losslessly:
``from_dict(to_dict(s)) == s`` for every spec, which
``tests/test_api.py`` pins for every registry entry.

The spec is *data only*.  Name→object resolution lives in
``repro.api.registry``; the composition order (profile → compression →
trace → robust problem → solver) lives in ``repro.api.build`` — the one
place that knows compression must be attached to the base problem before
trace-quantile pricing, so the historical ``with_compression``-under-
``latency_model`` footgun cannot be expressed here at all.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple, Union


def _int_tuple(x: Optional[Sequence[int]]) -> Optional[Tuple[int, ...]]:
    """Normalize JSON lists (and any int sequence) to an int tuple."""
    if x is None:
        return None
    return tuple(int(v) for v in x)


def _ratio_tuple(
    x: Union[None, float, int, Sequence[float]]
) -> Union[None, float, Tuple[float, ...]]:
    """Ratios may be one scalar (uniform across links) or per-link values."""
    if x is None:
        return None
    if isinstance(x, (int, float)):
        return float(x)
    return tuple(float(v) for v in x)


@dataclass(frozen=True)
class ModelCfg:
    """Which ``repro.configs`` architecture to profile, and at what shape.

    ``arch`` is a registry id (``repro.api.registry.MODEL_IDS``);
    ``variant`` picks the full SPEC or the CPU-runnable REDUCED config;
    ``num_layers`` optionally overrides the unit count (e.g. the quickstart
    bumps reduced smollm to 4 layers so all three tiers hold a unit).
    """

    arch: str = "vgg16-cifar10"
    variant: str = "full"          # "full" | "reduced"
    batch: int = 16
    seq: int = 1
    num_layers: Optional[int] = None
    optimizer: str = "sgd"         # prices optimizer-state bytes (C5)

    def __post_init__(self):
        if self.variant not in ("full", "reduced"):
            raise ValueError(f"variant must be full|reduced: {self.variant!r}")

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ModelCfg":
        return cls(**d)


@dataclass(frozen=True)
class SystemCfg:
    """Which multi-tier resource topology to price against.

    ``preset`` names a builder in ``repro.api.registry.SYSTEMS``
    (paper-three-tier | tpu-pod | two-tier-client-edge |
    two-tier-client-cloud | anything registered via ``register_system``).
    ``extras`` passes preset-specific keyword arguments straight through
    (e.g. ``memory_bytes`` for the paper system, ``chip_flops`` for the
    TPU pod).
    """

    preset: str = "paper-three-tier"
    num_clients: int = 20
    num_edges: int = 5
    seed: int = 0
    compute_scale: float = 1.0
    comm_scale: float = 1.0
    extras: Dict[str, Any] = field(default_factory=dict)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "SystemCfg":
        d = dict(d)
        d["extras"] = dict(d.get("extras", {}))
        return cls(**d)


@dataclass(frozen=True)
class HyperCfg:
    """Theorem-1 constants (``synthetic_hyperspec`` knobs) + the target ε.

    ``eps`` pins the target directly; otherwise ``eps = eps_scale × floor``
    where floor is the I=1 bound at R→∞ (cut-independent, since only
    I_m > 1 tiers contribute drift).
    """

    gamma: float = 5e-4
    beta: float = 50.0
    theta0: float = 5.0
    g2_scale: float = 20.0
    sigma2_scale: float = 4.0
    decay: float = 0.9
    seed: int = 0
    eps: Optional[float] = None
    eps_scale: float = 6.0

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "HyperCfg":
        return cls(**d)


@dataclass(frozen=True)
class ScenarioCfg:
    """Which fleet-sim regime prices the latency terms, and at what quantile.

    ``name`` is a key of ``repro.sim.SCENARIOS``; ``params`` are the
    scenario constructor's extra knobs (e.g. ``compute_sigma`` for
    lognormal-heterogeneous).  ``quantile`` is the robust-pricing level the
    solvers consume (p50 typical, p95 straggler-robust); ``sim_rounds``
    optionally caps how many trace rounds the quantile uses.
    """

    name: str = "homogeneous-paper"
    rounds: int = 64
    seed: int = 0
    quantile: float = 0.95
    backend: str = "numpy"
    sim_rounds: Optional[int] = None
    params: Dict[str, Any] = field(default_factory=dict)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ScenarioCfg":
        d = dict(d)
        d["params"] = dict(d.get("params", {}))
        return cls(**d)


@dataclass(frozen=True)
class ParticipationCfg:
    """Straggler-aware partial participation policy (DESIGN.md §12).

    Exactly one of ``deadline`` (the round barrier in seconds) or
    ``target_rate`` (the pooled per-client finish-time quantile the
    barrier should sit at, e.g. 0.5 = drop the slower half of
    client-rounds) must be set.  Requires a ``scenario`` section — the
    policy is priced against that fleet trace: latency terms become
    deadline-capped trace expectations and the Theorem-1 terms inflate by
    the estimated 1/q_m.  ``cuts`` optionally pins the reference cut
    vector the q_m estimation replays (default: evenly spread, the BCD
    starting anchor).
    """

    deadline: Optional[float] = None
    target_rate: Optional[float] = None
    cuts: Optional[Tuple[int, ...]] = None

    def __post_init__(self):
        if (self.deadline is None) == (self.target_rate is None):
            raise ValueError(
                "participation needs exactly one of deadline= or "
                f"target_rate= (got deadline={self.deadline!r}, "
                f"target_rate={self.target_rate!r})"
            )
        if self.deadline is not None and self.deadline <= 0:
            raise ValueError(f"deadline must be positive: {self.deadline}")
        if self.target_rate is not None and not (0.0 < self.target_rate <= 1.0):
            raise ValueError(
                f"target_rate must lie in (0, 1]: {self.target_rate}"
            )
        object.__setattr__(self, "cuts", _int_tuple(self.cuts))

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ParticipationCfg":
        return cls(**d)


@dataclass(frozen=True)
class CompressionCfg:
    """Which wire codec to train with and how the analytic layer prices it.

    ``codec`` names an executable ``Compressor`` in
    ``repro.api.registry.CODECS`` (identity | int8 | top-k | registered);
    ``params`` are its constructor kwargs (``tile`` for int8, ``frac`` for
    top-k).  The analytic ``CompressionSpec`` is derived from the codec's
    declared (ratio, ω) unless overridden: ``model_ratio`` / ``act_ratio``
    accept one scalar (uniform across links) or one value per link, and
    ``omega`` overrides the bound inflation — so a pure pricing sweep uses
    ``codec="identity"`` with explicit ratios.
    """

    codec: str = "identity"
    params: Dict[str, Any] = field(default_factory=dict)
    model_ratio: Union[None, float, Tuple[float, ...]] = None
    act_ratio: Union[None, float, Tuple[float, ...]] = None
    omega: Optional[float] = None

    def __post_init__(self):
        object.__setattr__(self, "model_ratio", _ratio_tuple(self.model_ratio))
        object.__setattr__(self, "act_ratio", _ratio_tuple(self.act_ratio))

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "CompressionCfg":
        d = dict(d)
        d["params"] = dict(d.get("params", {}))
        return cls(**d)


@dataclass(frozen=True)
class ControlCfg:
    """Online adaptive control knobs (``run.mode="control"``, DESIGN.md §13).

    The controller watches a sliding window of observed round telemetry,
    re-prices the system online (``repro.control.WindowedLatency`` +
    windowed participation), and re-solves BCD warm-started when the
    window drifts ``rel_tol`` away from the prices the current schedule
    was solved for.  ``cooldown`` rounds must pass between re-solves;
    ``max_switches=0`` means unlimited.  Requires a ``scenario`` section —
    telemetry is observed from that fleet trace.
    """

    window: int = 8                # sliding telemetry window (rounds)
    check_every: int = 1           # drift-check cadence (rounds)
    rel_tol: float = 0.25          # relative drift that triggers a re-solve
    cooldown: int = 8              # rounds between re-solves
    min_window: int = 4            # observations before the first check
    quantile: float = 0.5          # windowed robust-pricing level
    warm_start: bool = True        # seed BCD/Dinkelbach at the current optimum
    backend: str = "auto"          # re-solve lattice backend
    max_switches: int = 0          # hard cap on schedule changes (0 = none)
    fault_tol: float = 1.0         # windowed fault-rate drift trigger
    #                                (DESIGN.md §16); 1.0 = never trips

    def __post_init__(self):
        if self.window < 2:
            raise ValueError(f"control window must be >= 2: {self.window}")
        if not 0.0 < self.fault_tol <= 1.0:
            raise ValueError(
                f"control fault_tol must lie in (0, 1]: {self.fault_tol}"
            )
        if self.min_window < 2:
            raise ValueError(
                f"control min_window must be >= 2: {self.min_window}"
            )
        if not 0.0 < self.quantile <= 1.0:
            raise ValueError(
                f"control quantile must lie in (0, 1]: {self.quantile}"
            )
        if self.rel_tol <= 0.0:
            raise ValueError(f"control rel_tol must be positive: {self.rel_tol}")
        if self.cooldown < 0 or self.check_every < 1 or self.max_switches < 0:
            raise ValueError(
                "control needs cooldown >= 0, check_every >= 1, "
                f"max_switches >= 0 (got {self.cooldown}, "
                f"{self.check_every}, {self.max_switches})"
            )
        if self.backend not in ("auto", "scalar", "numpy", "jax"):
            raise ValueError(
                f"control backend must be auto|scalar|numpy|jax: {self.backend!r}"
            )

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ControlCfg":
        return cls(**d)


@dataclass(frozen=True)
class ClassesCfg:
    """Heterogeneity-aware per-class cut assignment (DESIGN.md §14).

    Clients are banded into ``num_classes`` classes that each hold their
    own split vector; the per-class BCD (``core.classes``) optimizes the
    product of cut lattices.  ``by`` picks the banding signal:
    "compute" (tier-0 device rates), "uplink" (tier-0 fed-server model
    uplink rates — the channel whose stragglers per-class cuts relieve),
    or "explicit" with ``assign`` giving the class id per client.
    ``product_budget`` caps the exhaustively enumerated assignment rows
    (``K^C``); larger products fall back to coordinate descent seeded at
    the single-cut optimum.  Requires nominal pricing — a ``scenario`` or
    ``participation`` section (trace latency models) conflicts.
    """

    num_classes: int = 2
    by: str = "compute"            # "compute" | "uplink" | "explicit"
    assign: Optional[Tuple[int, ...]] = None
    product_budget: int = 200_000

    def __post_init__(self):
        if self.num_classes < 1:
            raise ValueError(
                f"classes.num_classes must be >= 1: {self.num_classes}"
            )
        if self.by not in ("compute", "uplink", "explicit"):
            raise ValueError(
                f"classes.by must be compute|uplink|explicit: {self.by!r}"
            )
        if (self.by == "explicit") != (self.assign is not None):
            raise ValueError(
                "classes.assign must be given exactly when by='explicit' "
                f"(got by={self.by!r}, assign={self.assign!r})"
            )
        if self.product_budget < 1:
            raise ValueError(
                f"classes.product_budget must be >= 1: {self.product_budget}"
            )
        object.__setattr__(self, "assign", _int_tuple(self.assign))

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ClassesCfg":
        return cls(**d)


@dataclass(frozen=True)
class PrivacyCfg:
    """Client-level DP on the fed-server uplink (DESIGN.md §15).

    ``noise_multiplier`` (z) and ``clip`` (C) parameterize the Gaussian
    mechanism the Engine-A wire applies per client replica; z = 0 keeps
    the wire noiseless — ``build`` then constructs no mechanism at all, so
    the training graph is bit-identical to a spec without this section.
    ``epsilon_budget`` (with ``delta``) caps the RDP-accounted privacy
    spend: the solvers turn it into a round cap R ≤ R_max(ε, δ) — i.e. a
    denominator floor D ≥ 2θ₀/(γ R_max) — and retreat to schedules whose
    bound reaches the target within the budget.  The mechanism dimension
    (Theorem-1 σ²-inflation) is resolved by ``build`` from the model
    profile; it is not a spec knob.
    """

    noise_multiplier: float = 0.0
    clip: float = 1.0
    delta: float = 1e-5
    epsilon_budget: Optional[float] = None

    def __post_init__(self):
        if self.noise_multiplier < 0:
            raise ValueError(
                f"privacy.noise_multiplier must be >= 0: {self.noise_multiplier}"
            )
        if self.clip <= 0:
            raise ValueError(f"privacy.clip must be positive: {self.clip}")
        if not 0.0 < self.delta < 1.0:
            raise ValueError(f"privacy.delta must lie in (0, 1): {self.delta}")
        if self.epsilon_budget is not None and self.epsilon_budget <= 0:
            raise ValueError(
                f"privacy.epsilon_budget must be positive: {self.epsilon_budget}"
            )

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "PrivacyCfg":
        return cls(**d)


@dataclass(frozen=True)
class EnergyCfg:
    """Per-tier energy pricing of the round (DESIGN.md §15).

    Prices accept one scalar (uniform across tiers/links, the common case)
    or one value per tier (``compute_j_per_flop``, len M) / per link
    (``act_j_per_byte`` / ``model_j_per_byte``, len M−1).
    ``budget_j_per_round`` caps the amortized fleet round energy
    E(I, μ) = E_S + Σ E_{m,A}/I_m as a solver feasibility constraint;
    without it the section is reporting-only.  All-zero prices with no
    budget are an exact no-op on every optimum.
    """

    compute_j_per_flop: Union[float, Tuple[float, ...]] = 1e-11
    act_j_per_byte: Union[float, Tuple[float, ...]] = 2e-7
    model_j_per_byte: Union[float, Tuple[float, ...]] = 2e-7
    budget_j_per_round: Optional[float] = None

    def __post_init__(self):
        for name in ("compute_j_per_flop", "act_j_per_byte", "model_j_per_byte"):
            object.__setattr__(self, name, _ratio_tuple(getattr(self, name)))
            v = getattr(self, name)
            vals = (v,) if isinstance(v, float) else v
            if any(x < 0 for x in vals):
                raise ValueError(f"energy.{name} has a negative price")
        if self.budget_j_per_round is not None and self.budget_j_per_round <= 0:
            raise ValueError(
                f"energy.budget_j_per_round must be positive: "
                f"{self.budget_j_per_round}"
            )

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "EnergyCfg":
        return cls(**d)


@dataclass(frozen=True)
class FaultsCfg:
    """Fault injection + fault-tolerant training (DESIGN.md §16).

    The fault fields mirror ``repro.faults.FaultSpec`` one-to-one:
    per-round crash / corrupt-update / link-retry / cell-outage draws from
    the spec's own seeded streams, layered on whatever scenario the run
    prices (a spec with all rates zero and no outage composes to a
    bit-exact no-op).  ``build`` threads the spec everywhere at once —
    retry-priced latency tables, fault-adjusted trace, deflated q_m for
    the Theorem-1 bound — and ``run`` modes "train"/"control" inject the
    data-plane faults into the engine loop behind the guarded sync.

    ``guard_norm_factor`` sets the quarantine threshold of the non-finite
    / norm-blow-up guard (``core.tiers.GuardSpec``).  ``checkpoint_every``
    > 0 saves an atomic engine checkpoint that cadence (to
    ``checkpoint_dir`` or a run-temp dir); ``engine_crash_round`` r kills
    the engine after round r's step and resumes from the last checkpoint
    (``control.resume_with_migration``) — the recovery drill the
    fault-tolerance benchmark times.
    """

    seed: int = 0
    crash_rate: float = 0.0
    crash_stage: str = "uplink"
    corrupt_rate: float = 0.0
    corrupt_mode: str = "nan"      # nan | inf | scale | bitflip
    corrupt_scale: float = 1e6
    link_fail_rate: float = 0.0
    link_retries: int = 2
    outage_cells: Tuple[int, ...] = ()
    outage_tier: int = 1
    outage_start: int = 0
    outage_len: int = 0
    guard_norm_factor: float = 1e4
    checkpoint_every: int = 0      # 0 = no checkpoints
    checkpoint_dir: Optional[str] = None
    engine_crash_round: Optional[int] = None

    def __post_init__(self):
        object.__setattr__(
            self, "outage_cells", _int_tuple(self.outage_cells) or ()
        )
        self.to_fault_spec()       # delegate fault-field validation
        self.to_guard_spec()       # ... and the guard threshold's
        if self.checkpoint_every < 0:
            raise ValueError(
                f"faults.checkpoint_every must be >= 0: {self.checkpoint_every}"
            )
        if self.engine_crash_round is not None:
            if self.engine_crash_round < 0:
                raise ValueError(
                    "faults.engine_crash_round must be >= 0: "
                    f"{self.engine_crash_round}"
                )
            if self.checkpoint_every < 1:
                raise ValueError(
                    "faults.engine_crash_round needs checkpoint_every >= 1 "
                    "— recovery resumes from the last saved checkpoint"
                )

    def to_fault_spec(self):
        """The analytic/injection ``repro.faults.FaultSpec`` this declares."""
        from ..faults import FaultSpec

        return FaultSpec(
            seed=self.seed,
            crash_rate=self.crash_rate,
            crash_stage=self.crash_stage,
            corrupt_rate=self.corrupt_rate,
            corrupt_mode=self.corrupt_mode,
            corrupt_scale=self.corrupt_scale,
            link_fail_rate=self.link_fail_rate,
            link_retries=self.link_retries,
            outage_cells=self.outage_cells,
            outage_tier=self.outage_tier,
            outage_start=self.outage_start,
            outage_len=self.outage_len,
        )

    def to_guard_spec(self):
        """The ``core.tiers.GuardSpec`` the engine's guarded syncs use."""
        from ..core.tiers import GuardSpec

        return GuardSpec(norm_factor=self.guard_norm_factor)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "FaultsCfg":
        d = dict(d)
        d["outage_cells"] = tuple(d.get("outage_cells", ()))
        return cls(**d)


@dataclass(frozen=True)
class SolverCfg:
    """Which optimizer of problem (20) runs, with its budgets.

    ``kind``: "bcd" (Algorithm 2), "ma" (Proposition 1, needs ``cuts``),
    "ms" (Dinkelbach, needs ``intervals``), or "fixed" (evaluate the given
    schedule without optimizing).  For "bcd", ``cuts``/``intervals`` seed
    the iteration.

    ``backend`` picks the lattice-evaluation path (DESIGN.md §11):
    "scalar" walks one cut vector at a time (the historical oracle path),
    "numpy"/"jax" run the batched whole-lattice core, and "auto"
    (default) picks numpy or — for lattices big enough to amortize the
    jit — jax.  All four return bit-identical optima.
    """

    kind: str = "bcd"
    cuts: Optional[Tuple[int, ...]] = None
    intervals: Optional[Tuple[int, ...]] = None
    tol: float = 1e-6
    max_iters: int = 50
    backend: str = "auto"

    def __post_init__(self):
        if self.kind not in ("bcd", "ma", "ms", "fixed"):
            raise ValueError(f"solver kind must be bcd|ma|ms|fixed: {self.kind!r}")
        if self.backend not in ("auto", "scalar", "numpy", "jax"):
            raise ValueError(
                f"solver backend must be auto|scalar|numpy|jax: {self.backend!r}"
            )
        object.__setattr__(self, "cuts", _int_tuple(self.cuts))
        object.__setattr__(self, "intervals", _int_tuple(self.intervals))

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "SolverCfg":
        return cls(**d)


@dataclass(frozen=True)
class ShardingCfg:
    """Mesh geometry for the sharded Engine-A step (DESIGN.md §17).

    The client-stacked parameter axis shards over ``data`` (or
    ``pod × data`` when ``pods`` > 0) and trailing weight dims get
    Megatron TP over ``model`` — exactly ``launch.sharding``'s layout
    contract.  The mesh needs data·model·max(pods, 1) devices; on a CPU
    host that means ``--xla_force_host_platform_device_count`` set
    before jax initializes (``launch.mesh.make_debug_mesh`` checks and
    says so).  ``data=1, model=1, pods=0`` is a valid degenerate mesh
    (useful for exercising the sharded code path on one device).
    """

    data: int = 2
    model: int = 1
    pods: int = 0                  # 0 = single-pod (data, model) mesh

    def __post_init__(self):
        if self.data < 1 or self.model < 1 or self.pods < 0:
            raise ValueError(
                f"sharding needs data >= 1, model >= 1, pods >= 0: "
                f"data={self.data}, model={self.model}, pods={self.pods}"
            )

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ShardingCfg":
        return cls(**d)


@dataclass(frozen=True)
class RunCfg:
    """What ``run(spec)`` produces.

    ``mode``: "solve" (optimized schedule + analytic latency breakdown),
    "simulate" (schedule + per-round trace latency profile; needs a
    ``scenario``), "train" (real Engine-A/B split training with the
    schedule), or "control" (training under the online adaptive
    controller — needs a ``scenario``; knobs come from the spec's
    ``control`` section).  Training knobs are ignored by solve/simulate.

    ``sharding`` (a ``ShardingCfg``) runs the Engine-A step sharded over
    a device mesh (DESIGN.md §17); Engine A only.  ``staleness`` — one
    bound or per-tier bounds s_m ≥ 0 — switches training to the async
    bounded-staleness aggregation mode: tier m's fed-server sync
    computed at round r applies at round r + s_m, overlapping client
    compute, and the reported Theorem-1 bound carries the (I_m + s_m)²
    drift inflation.  All-zero staleness is the synchronous engine
    bit-exactly.
    """

    mode: str = "solve"
    seed: int = 0
    rounds: int = 30               # training rounds (mode="train")
    lr: float = 0.1
    engine: str = "a"              # "a" (sync groups) | "b" (per-entity)
    non_iid: bool = False
    dataset_size: int = 512
    log_every: int = 0             # 0 = silent
    sharding: Optional[ShardingCfg] = None
    staleness: Union[int, Tuple[int, ...]] = 0

    def __post_init__(self):
        if self.mode not in ("solve", "simulate", "train", "control"):
            raise ValueError(
                f"run mode must be solve|simulate|train|control: {self.mode!r}"
            )
        if self.engine not in ("a", "b"):
            raise ValueError(f"engine must be a|b: {self.engine!r}")
        s = self.staleness
        if not isinstance(s, int):
            object.__setattr__(
                self, "staleness", tuple(int(v) for v in s)
            )
            s = self.staleness
        vals = (s,) if isinstance(s, int) else s
        if any(v < 0 for v in vals):
            raise ValueError(f"run.staleness bounds must be >= 0: {s!r}")

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "RunCfg":
        d = dict(d)
        sh = d.get("sharding")
        if sh is not None and not isinstance(sh, ShardingCfg):
            d["sharding"] = ShardingCfg.from_dict(sh)
        st = d.get("staleness")
        if st is not None and not isinstance(st, int):
            d["staleness"] = tuple(int(v) for v in st)
        return cls(**d)


@dataclass(frozen=True)
class ExperimentSpec:
    """The whole experiment as one declarative, serializable value."""

    model: ModelCfg = field(default_factory=ModelCfg)
    system: SystemCfg = field(default_factory=SystemCfg)
    hyper: HyperCfg = field(default_factory=HyperCfg)
    solver: SolverCfg = field(default_factory=SolverCfg)
    run: RunCfg = field(default_factory=RunCfg)
    scenario: Optional[ScenarioCfg] = None
    compression: Optional[CompressionCfg] = None
    participation: Optional[ParticipationCfg] = None
    control: Optional[ControlCfg] = None
    classes: Optional[ClassesCfg] = None
    privacy: Optional[PrivacyCfg] = None
    energy: Optional[EnergyCfg] = None
    faults: Optional[FaultsCfg] = None
    name: str = ""

    def to_dict(self) -> Dict[str, Any]:
        """Plain-JSON projection (tuples become lists; None sections stay None)."""
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ExperimentSpec":
        scenario = d.get("scenario")
        compression = d.get("compression")
        participation = d.get("participation")
        control = d.get("control")
        classes = d.get("classes")
        privacy = d.get("privacy")
        energy = d.get("energy")
        faults = d.get("faults")
        return cls(
            model=ModelCfg.from_dict(d.get("model", {})),
            system=SystemCfg.from_dict(d.get("system", {})),
            hyper=HyperCfg.from_dict(d.get("hyper", {})),
            solver=SolverCfg.from_dict(d.get("solver", {})),
            run=RunCfg.from_dict(d.get("run", {})),
            scenario=None if scenario is None else ScenarioCfg.from_dict(scenario),
            compression=(
                None if compression is None
                else CompressionCfg.from_dict(compression)
            ),
            participation=(
                None if participation is None
                else ParticipationCfg.from_dict(participation)
            ),
            control=None if control is None else ControlCfg.from_dict(control),
            classes=None if classes is None else ClassesCfg.from_dict(classes),
            privacy=None if privacy is None else PrivacyCfg.from_dict(privacy),
            energy=None if energy is None else EnergyCfg.from_dict(energy),
            faults=None if faults is None else FaultsCfg.from_dict(faults),
            name=d.get("name", ""),
        )

    def replace(self, **kwargs) -> "ExperimentSpec":
        """Convenience ``dataclasses.replace`` that reads like the spec."""
        return dataclasses.replace(self, **kwargs)
