"""``run(spec)`` — one dispatcher over the three things the repo can do.

* ``mode="solve"``    — optimize (I, μ) with the configured solver and
  report the schedule, Θ′, R-to-ε, and the Eq. 17/18 latency breakdown.
* ``mode="simulate"`` — same solve (typically against trace quantiles),
  then replay the schedule through the fleet simulator and report the
  per-round latency profile (p50/p95/worst, participants).
* ``mode="train"``    — real Engine-A/B split training with the schedule
  (solved or fixed), the spec's codec on the fed-server wire, and the
  Theorem-1 bound for the schedule actually trained.
* ``mode="control"``  — the train loop under the online adaptive
  controller (``repro.control``): round telemetry feeds a sliding-window
  system estimate, drift triggers warm-started re-solves, engine state
  migrates across switches, and the Theorem-1 bound is composed
  piecewise over the schedule segments.

Every mode returns the same ``ExperimentResult``; ``provenance`` is the
resolved spec, so the artifact alone reproduces the run.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import numpy as np

from ..core.bcd import solve_bcd
from ..core.ma_solver import solve_ma
from ..core.ms_solver import solve_ms
from .build import BuiltExperiment, build
from .result import ExperimentResult, jsonify
from .spec import ExperimentSpec


def _schedule(built: BuiltExperiment) -> Tuple[Tuple[int, ...], Tuple[int, ...]]:
    """Resolve the (cuts, intervals) the run uses, per the solver config."""
    s = built.spec.solver
    p = built.problem
    if s.kind == "bcd":
        res = solve_bcd(
            p,
            init_cuts=s.cuts,
            init_intervals=s.intervals,
            tol=s.tol,
            max_iters=s.max_iters,
            backend=s.backend,
        )
        return res.cuts, tuple(res.intervals)
    if s.kind == "ma":
        if s.cuts is None:
            raise ValueError('solver kind="ma" needs solver.cuts (fixed μ)')
        ma = solve_ma(p, s.cuts, backend=s.backend)
        return tuple(s.cuts), tuple(ma.intervals)
    if s.kind == "ms":
        if s.intervals is None:
            raise ValueError('solver kind="ms" needs solver.intervals (fixed I)')
        ms = solve_ms(p, s.intervals, backend=s.backend)
        return tuple(ms.cuts), tuple(s.intervals)
    # "fixed": evaluate the given schedule as-is
    if s.cuts is None or s.intervals is None:
        raise ValueError('solver kind="fixed" needs both solver.cuts and '
                         "solver.intervals")
    return tuple(s.cuts), tuple(s.intervals)


def _run_classes(built: BuiltExperiment) -> ExperimentResult:
    """Per-class cut assignment solve (DESIGN.md §14).

    ``result.cuts`` reports class 0's vector (with one class this IS the
    single-cut schedule and the whole result collapses bit-exactly to the
    classless run); the full assignment lives in ``result.classes``.
    """
    from ..core.classes import solve_bcd_classes

    s = built.spec.solver
    if s.kind != "bcd":
        raise ValueError(
            'a classes section needs solver kind="bcd": the per-class '
            f"optimizer is the BCD loop (got kind={s.kind!r})"
        )
    if built.spec.run.mode != "solve":
        raise ValueError(
            'a classes section supports run mode="solve"; mixed-cut '
            "training runs through core.engine.build_train_step_a("
            f"class_members=...) directly (got mode={built.spec.run.mode!r})"
        )
    res = solve_bcd_classes(
        built.problem,
        built.class_spec,
        init_intervals=s.intervals,
        tol=s.tol,
        max_iters=s.max_iters,
        backend=s.backend,
        product_budget=built.spec.classes.product_budget,
    )
    p = built.problem
    cs = res.spec
    latency = {
        "split_T": float(p.class_split_T(cs)),
        "agg_T": [float(t) for t in p.class_agg_T(cs)],
        "pricing": "nominal",
    }
    payload = {
        "num_classes": cs.num_classes,
        "by": built.spec.classes.by,
        "class_of": [int(c) for c in cs.class_of],
        "class_cuts": [list(c) for c in cs.cuts],
        "class_sizes": [int(n) for n in cs.class_sizes()],
        "product_budget": built.spec.classes.product_budget,
    }
    return ExperimentResult(
        mode="solve",
        cuts=tuple(cs.cuts[0]),
        intervals=tuple(res.intervals),
        theta=float(res.theta),
        rounds_to_eps=float(res.rounds) if res.rounds is not None else None,
        total_latency=(
            float(res.total_latency) if res.total_latency is not None else None
        ),
        latency=latency,
        classes=payload,
        provenance=jsonify(built.spec.to_dict()),
    )


def _latency_breakdown(built: BuiltExperiment, cuts, intervals) -> Dict[str, Any]:
    p = built.problem
    if built.spec.scenario is None:
        pricing = "nominal"
    elif built.participation is not None and built.participation.deadline is not None:
        pricing = (
            f"{built.spec.scenario.name}"
            f"@deadline{built.participation.deadline:.4g}s"
        )
    else:
        # covers fault-deflated participation with no deadline policy:
        # latency stays quantile-priced, only the q_m side deflates
        pricing = f"{built.spec.scenario.name}@q{built.spec.scenario.quantile}"
    out = {
        "split_T": float(p.split_T(cuts)),
        "agg_T": [float(t) for t in p.agg_T(cuts)],
        "pricing": pricing,
    }
    if built.participation is not None:
        out["participation"] = {
            "deadline": built.participation.deadline,
            "q_tier": [float(v) for v in built.participation.q],
        }
    return out


def _simulate(built: BuiltExperiment, cuts, intervals) -> Dict[str, Any]:
    from ..sim import simulate_rounds

    sc = built.spec.scenario
    res = simulate_rounds(
        built.trace, cuts, intervals=intervals, backend=sc.backend
    )
    p50, p95, worst = np.quantile(res.total, [0.5, 0.95, 1.0])
    out = {
        "scenario": sc.name,
        "rounds": int(res.total.shape[0]),
        "split_p50": float(np.quantile(res.split, 0.5)),
        "split_p95": float(np.quantile(res.split, 0.95)),
        "total_p50": float(p50),
        "total_p95": float(p95),
        "total_worst": float(worst),
        "mean_participants": float(np.mean(res.participants)),
    }
    if built.participation is not None:
        from ..sim import participation_masks

        pr = participation_masks(
            built.trace, cuts, built.participation.deadline
        )
        out["participation"] = {
            "deadline": built.participation.deadline,
            "mean_rate": float(np.mean(pr.rates)),
            "q_tier": [float(v) for v in pr.q_tier],
            "expected_round_time": float(np.mean(pr.round_time)),
            "full_round_time": float(np.mean(res.split)),
        }
    return out


def _training_setup(built: BuiltExperiment):
    """Shared data / model / optimizer assembly for train and control modes.

    Returns ``(model, loader, opt, N)``; the plan / step / mask wiring
    stays with the caller because the control loop rebuilds those on
    every schedule switch.
    """
    from ..data import (
        image_loader,
        lm_loader,
        make_cifar10_like,
        make_lm_stream,
        partition_iid,
        partition_sort_and_shard,
    )
    from ..models.vgg import VggSpec, build_model
    from ..optim import adam, momentum, sgd

    spec = built.spec
    rc = spec.run
    model_spec = built.model_spec
    N = built.system.num_clients

    if isinstance(model_spec, VggSpec):
        ds = make_cifar10_like(rc.dataset_size, seed=rc.seed)
        labels = ds.labels
        mk_loader = lambda parts: image_loader(ds, parts, spec.model.batch, rc.seed)
    else:
        # train at the spec's literal seq so pricing, Theorem-1 bound, and
        # provenance all describe the run that actually happened
        if spec.model.seq < 2:
            raise ValueError(
                f'run mode="{rc.mode}" on LM arch {spec.model.arch!r} needs '
                f"model.seq >= 2 (next-token loss); got {spec.model.seq}"
            )
        ds = make_lm_stream(
            rc.dataset_size, spec.model.seq, model_spec.vocab_size, seed=rc.seed
        )
        labels = ds.tokens[:, 0] % 10
        mk_loader = lambda parts: lm_loader(ds, parts, spec.model.batch, rc.seed)

    parts = (
        partition_sort_and_shard(labels, N, 2, rc.seed)
        if rc.non_iid
        else partition_iid(len(labels), N, rc.seed)
    )
    loader = mk_loader(parts)
    model = build_model(model_spec)
    opt = {"sgd": sgd, "momentum": momentum, "adam": adam}[spec.model.optimizer](rc.lr)
    return model, loader, opt, N


def _participation_masks(built: BuiltExperiment, cuts) -> Optional[np.ndarray]:
    """Deadline-driven per-round client masks sampled from the fleet trace
    at the schedule actually trained (DESIGN.md §12); the trace replays
    cyclically past its horizon.  ``None`` without a participation policy
    (a fault-deflated spec with no deadline carries q_m only — the fault
    loop masks crashed clients itself; there is no barrier to miss)."""
    if built.participation is None or built.participation.deadline is None:
        return None
    from ..sim import participation_masks

    return participation_masks(
        built.trace, cuts, built.participation.deadline
    ).masks


def _make_step(built: BuiltExperiment, model, plan, opt, with_mask: bool):
    """Jitted engine step for one tier plan (re-built on control switches)."""
    import jax

    from ..core.engine import build_train_step_a, build_train_step_b

    kwargs = dict(
        compressor=built.compressor, with_mask=with_mask,
        privacy=built.dp_mechanism,
    )
    if built.spec.run.engine == "a":
        builder = build_train_step_a
        if (
            built.guard is not None
            and built.faults is not None
            and not built.faults.is_null
        ):
            # live faults: every sync runs behind the non-finite/norm
            # guard.  A null spec builds the exact clean graph instead —
            # jit fusion may legally re-order reductions between two
            # different graphs, so bit-for-bit zero-fault collapse means
            # emitting the same graph, not an equivalent one.
            kwargs["guard"] = built.guard
    else:
        builder = build_train_step_b
    return jax.jit(builder(model, plan, opt, **kwargs))


def _train(built: BuiltExperiment, cuts, intervals) -> Dict[str, Any]:
    """Real split training of the spec's model under the schedule.

    With a faults section the loop becomes the fault-tolerant variant
    (DESIGN.md §16): each round's seeded fault draws corrupt the marked
    clients' replicas *before* the jitted step (the guard quarantines
    them inside it), crashed clients drop out of the round mask, a cell
    outage re-routes its clients' tier sync to sibling cells after the
    step, and the atomic checkpoint cadence + simulated engine crash
    exercise ``resume_with_migration`` recovery mid-run.
    """
    import os
    import tempfile

    import jax
    import jax.numpy as jnp

    from ..core.convergence import theorem1_bound
    from ..core.engine import TrainState, init_state_a, init_state_b
    from ..core.tiers import TierPlan

    spec = built.spec
    rc = spec.run
    fc = spec.faults
    fs = built.faults
    inject = fs is not None and not fs.is_null
    model, loader, opt, N = _training_setup(built)
    plan = TierPlan(
        n_units=built.model_spec.n_units,
        num_clients=N,
        cuts=tuple(cuts),
        intervals=tuple(intervals),
        entities=built.system.entities,
    )
    key = jax.random.PRNGKey(rc.seed)

    masks = _participation_masks(built, cuts)
    with_mask = masks is not None or inject
    init = init_state_a if rc.engine == "a" else init_state_b

    # sharded / async execution (DESIGN.md §17) — capability-checked at
    # build time (engine A, no privacy/classes/faults/control)
    from ..core.async_agg import normalize_staleness

    s_eff = normalize_staleness(rc.staleness, plan)
    use_async = any(s_eff)
    mesh, client_axes = None, ("data",)
    if rc.sharding is not None:
        from ..core.sharded import init_sharded_state_a
        from ..launch.mesh import make_debug_mesh

        sh = rc.sharding
        mesh = make_debug_mesh(data=sh.data, model=sh.model, pods=sh.pods)
        client_axes = ("pod", "data") if sh.pods else ("data",)
        state = init_sharded_state_a(
            model, plan, opt, key, mesh, client_axes=client_axes
        )
    else:
        state = init(model, plan, opt, key)

    trainer, step = None, None
    if use_async:
        from ..core.async_agg import make_async_trainer

        guard_kw = (
            built.guard
            if built.guard is not None and inject
            else None
        )
        trainer = make_async_trainer(
            model, plan, opt, staleness=rc.staleness,
            compressor=built.compressor, with_mask=with_mask,
            guard=guard_kw, mesh=mesh, client_axes=client_axes,
        )
    elif mesh is not None:
        from ..core.sharded import build_sharded_train_step_a

        step = build_sharded_train_step_a(
            model, plan, opt, mesh, client_axes=client_axes,
            compressor=built.compressor, with_mask=with_mask,
        )
    else:
        step = _make_step(built, model, plan, opt, with_mask)

    members = None
    if inject:
        from ..faults import (
            apply_corruption,
            assignment_members,
            expand_faults,
            outage_assignment,
            reroute_entity_sync,
        )

        if fs.has_outage:
            J = built.system.entities[fs.outage_tier]
            members = assignment_members(
                outage_assignment(N, J, fs.outage_cells), J
            )

    ckpt_path = None
    n_ckpts = 0
    recovered_round = None
    if fc is not None and fc.checkpoint_every > 0:
        from ..checkpoint import save_checkpoint

        d = fc.checkpoint_dir or tempfile.mkdtemp(prefix="repro-ckpt-")
        ckpt_path = os.path.join(d, "engine.npz")

    n_faulty_total = 0
    faulty_rounds = 0
    losses = []
    for r in range(rc.rounds):
        batch = {k: jnp.asarray(v) for k, v in loader.next_round().items()}
        mrow = None
        if masks is not None:
            mrow = np.asarray(masks[r % masks.shape[0]], dtype=bool)
        if inject:
            rf = expand_faults(fs, r, N)
            if rf.corrupt.any():
                state = TrainState(
                    apply_corruption(state.params, rf.corrupt, fs),
                    state.opt_state,
                    state.step,
                )
            base_m = np.ones(N, dtype=bool) if mrow is None else mrow
            mrow = base_m & ~rf.crashed
            if not mrow.any():
                raise ValueError(
                    f"round {r}: every client crashed or missed the "
                    "deadline — an all-faulty round has no aggregate; "
                    "lower crash_rate or loosen the deadline"
                )
            if rf.faulty.any():
                faulty_rounds += 1
                n_faulty_total += rf.n_faulty
        if with_mask:
            m_arr = jnp.asarray(mrow, dtype=jnp.float32)
            if trainer is not None:
                state, loss = trainer.run_round(state, batch, r, m_arr)
            else:
                state, loss = step(state, batch, m_arr)
        elif trainer is not None:
            state, loss = trainer.run_round(state, batch, r)
        else:
            state, loss = step(state, batch)
        if inject and rf.cell_out and members is not None:
            # dead cells' clients adopt their sibling cell's tier mean
            state = TrainState(
                reroute_entity_sync(
                    state.params, plan, fs.outage_tier, members
                ),
                state.opt_state,
                state.step,
            )
        losses.append(float(loss))
        if ckpt_path is not None and (r + 1) % fc.checkpoint_every == 0:
            save_checkpoint(
                ckpt_path, state, step=r + 1,
                meta={"cuts": list(cuts), "intervals": list(intervals)},
            )
            n_ckpts += 1
        if fc is not None and fc.engine_crash_round == r:
            from ..control import resume_with_migration

            if n_ckpts == 0:
                raise ValueError(
                    f"engine crashed at round {r} before the first "
                    f"checkpoint (checkpoint_every={fc.checkpoint_every}) "
                    "— nothing to resume from"
                )
            template = init(model, plan, opt, key)
            state, _, _ = resume_with_migration(ckpt_path, template, plan)
            recovered_round = r
        if rc.log_every and ((r + 1) % rc.log_every == 0 or r == 0):
            print(f"round {r+1:5d}  loss {losses[-1]:.4f}")

    if trainer is not None:
        # fold any still in-flight aggregations in before reporting
        state = trainer.drain(state)

    omega = 0.0 if built.compression is None else built.compression.omega
    bound = theorem1_bound(
        built.hyper, max(1, rc.rounds), intervals, cuts, omega=omega,
        participation=built.participation,
        dp_sigma2=built.problem.dp_sigma2,
        staleness=s_eff,
    )
    out = {
        "engine": rc.engine,
        "rounds": rc.rounds,
        "first_loss": losses[0] if losses else None,
        "final_loss": losses[-1] if losses else None,
        "losses": losses,
        "thm1_bound": float(bound),
        "async": bool(use_async),
        "staleness": [int(v) for v in s_eff],
    }
    if mesh is not None:
        out["sharding"] = {
            "data": rc.sharding.data,
            "model": rc.sharding.model,
            "pods": rc.sharding.pods,
            "client_shards": int(
                np.prod([mesh.shape[a] for a in client_axes])
            ),
        }
    if fc is not None:
        out["faults"] = {
            "n_faulty_total": int(n_faulty_total),
            "faulty_rounds": int(faulty_rounds),
            "fault_rate": float(n_faulty_total) / float(N * max(1, rc.rounds)),
            "checkpoints": int(n_ckpts),
            "recovered_round": recovered_round,
            "deflated_q": (
                None if built.participation is None
                else [float(v) for v in built.participation.q]
            ),
            "retry_mult": fs.retry_mult if fs is not None else None,
        }
    if built.privacy is not None:
        q1 = float(built.problem.q[0])
        out["privacy"] = {
            "noise_multiplier": built.privacy.noise_multiplier,
            "clip": built.privacy.clip,
            "dp_sigma2": built.problem.dp_sigma2,
            "epsilon_spent": built.privacy.accountant(q1).epsilon(rc.rounds),
            "delta": built.privacy.delta,
        }
    if masks is not None:
        out["mean_participation"] = float(
            np.mean(masks[np.arange(rc.rounds) % masks.shape[0]])
        )
        out["deadline"] = built.participation.deadline
    return out


def _control(built: BuiltExperiment, cuts, intervals) -> Dict[str, Any]:
    """Engine training under the online adaptive controller (DESIGN.md §13).

    Each round the engine trains under the current schedule, the round's
    telemetry is observed from the fleet trace and folded into the
    controller's window, and a drift-triggered warm re-solve may switch
    the schedule — at which point the tier plan is rebuilt, the engine
    state (params + optimizer moments) is migrated without loss, the step
    re-jitted, and participation masks re-sampled at the new cuts.  The
    Theorem-1 bound is kept piecewise across the segments and collapses
    bit-exactly to the static bound when no switch fires.
    """
    import jax
    import jax.numpy as jnp

    from ..control import (
        BoundSegment,
        Controller,
        migrate_state,
        observe_round,
        piecewise_bound,
    )
    from ..core.convergence import theorem1_bound
    from ..core.engine import init_state_a, init_state_b
    from ..core.tiers import TierPlan
    from .spec import ControlCfg

    spec = built.spec
    rc = spec.run
    cc = spec.control if spec.control is not None else ControlCfg()
    trace = built.trace
    model, loader, opt, N = _training_setup(built)
    cuts = tuple(int(c) for c in cuts)
    intervals = tuple(int(i) for i in intervals)
    init_cuts, init_intervals = cuts, intervals

    def make_plan(c, i):
        return TierPlan(
            n_units=built.model_spec.n_units,
            num_clients=N,
            cuts=tuple(c),
            intervals=tuple(i),
            entities=built.system.entities,
        )

    plan = make_plan(cuts, intervals)
    key = jax.random.PRNGKey(rc.seed)
    masks = _participation_masks(built, cuts)
    fs = built.faults
    inject = fs is not None and not fs.is_null
    members = None
    if inject:
        from ..core.engine import TrainState
        from ..faults import (
            apply_corruption,
            assignment_members,
            expand_faults,
            outage_assignment,
            reroute_entity_sync,
        )

        if fs.has_outage:
            members = assignment_members(
                outage_assignment(
                    N, built.system.entities[fs.outage_tier], fs.outage_cells
                ),
                built.system.entities[fs.outage_tier],
            )
    with_mask = masks is not None or inject
    init = init_state_a if rc.engine == "a" else init_state_b
    state = init(model, plan, opt, key)
    step = _make_step(built, model, plan, opt, with_mask)

    controller = Controller(
        built.problem,
        cuts,
        intervals,
        window=cc.window,
        check_every=cc.check_every,
        rel_tol=cc.rel_tol,
        cooldown=cc.cooldown,
        min_window=cc.min_window,
        quantile=cc.quantile,
        warm_start=cc.warm_start,
        backend=cc.backend,
        max_switches=cc.max_switches,
        fault_tol=cc.fault_tol,
    )

    omega = 0.0 if built.compression is None else built.compression.omega
    segments = []
    seg_rounds = 0
    losses = []
    n_faulty_total = 0
    for r in range(rc.rounds):
        rr = r % trace.rounds
        batch = {k: jnp.asarray(v) for k, v in loader.next_round().items()}
        mrow = None
        if masks is not None:
            mrow = np.asarray(masks[r % masks.shape[0]], dtype=bool)
        n_faulty = 0
        if inject:
            rf = expand_faults(fs, rr, N)
            if rf.corrupt.any():
                state = TrainState(
                    apply_corruption(state.params, rf.corrupt, fs),
                    state.opt_state,
                    state.step,
                )
            base_m = np.ones(N, dtype=bool) if mrow is None else mrow
            mrow = base_m & ~rf.crashed
            if not mrow.any():
                raise ValueError(
                    f"round {r}: every client crashed or missed the "
                    "deadline — an all-faulty round has no aggregate"
                )
            n_faulty = rf.n_faulty
            n_faulty_total += n_faulty
        if with_mask:
            state, loss = step(state, batch, jnp.asarray(mrow, dtype=jnp.float32))
        else:
            state, loss = step(state, batch)
        if inject and rf.cell_out and members is not None:
            state = TrainState(
                reroute_entity_sync(
                    state.params, plan, fs.outage_tier, members
                ),
                state.opt_state,
                state.step,
            )
        losses.append(float(loss))
        seg_rounds += 1
        if rc.log_every and ((r + 1) % rc.log_every == 0 or r == 0):
            print(f"round {r+1:5d}  loss {losses[-1]:.4f}  "
                  f"cuts {cuts} I{intervals}")

        obs = observe_round(
            trace, rr, cuts,
            mask=None if mrow is None else np.asarray(mrow, dtype=bool),
            loss=losses[-1],
            n_faulty=n_faulty,
        )
        controller.observe(obs)
        dec = controller.maybe_replan(r)
        if dec is not None and dec.switched:
            segments.append(
                BoundSegment(
                    seg_rounds, intervals, cuts,
                    omega=omega, participation=built.participation,
                    dp_sigma2=built.problem.dp_sigma2,
                )
            )
            seg_rounds = 0
            old_plan = plan
            cuts, intervals = dec.new_cuts, dec.new_intervals
            plan = make_plan(cuts, intervals)
            state = migrate_state(
                state, plan, opt, engine=rc.engine, model=model,
                old_plan=old_plan,
            )
            step = _make_step(built, model, plan, opt, with_mask)
            if with_mask:
                masks = _participation_masks(built, cuts)
            if rc.log_every:
                print("  " + dec.describe())
    if seg_rounds:
        segments.append(
            BoundSegment(
                seg_rounds, intervals, cuts,
                omega=omega, participation=built.participation,
                dp_sigma2=built.problem.dp_sigma2,
            )
        )

    bound = piecewise_bound(built.hyper, segments) if segments else None
    static_bound = theorem1_bound(
        built.hyper, max(1, rc.rounds), init_intervals, init_cuts,
        omega=omega, participation=built.participation,
        dp_sigma2=built.problem.dp_sigma2,
    )
    p50, p95 = controller.resolve_quantiles((0.5, 0.95))
    return {
        "engine": rc.engine,
        "rounds": rc.rounds,
        "first_loss": losses[0] if losses else None,
        "final_loss": losses[-1] if losses else None,
        "losses": losses,
        "initial_cuts": list(init_cuts),
        "initial_intervals": list(init_intervals),
        "final_cuts": list(cuts),
        "final_intervals": list(intervals),
        "n_switches": controller.n_switches,
        "n_resolves": len(controller.resolve_seconds),
        "switches": [
            {
                "round": d.round_index,
                "trigger": d.trigger,
                "old_cuts": list(d.old_cuts),
                "old_intervals": list(d.old_intervals),
                "new_cuts": list(d.new_cuts),
                "new_intervals": list(d.new_intervals),
                "solve_ms": 1e3 * d.solve_seconds,
            }
            for d in controller.decisions
            if d.switched
        ],
        "switch_log": [
            d.describe() for d in controller.decisions if d.switched
        ],
        "segments": [
            {"rounds": s.rounds, "cuts": list(s.cuts),
             "intervals": list(s.intervals)}
            for s in segments
        ],
        "piecewise_bound": None if bound is None else float(bound),
        "static_bound": float(static_bound),
        "resolve_p50_s": p50,
        "resolve_p95_s": p95,
        "n_faulty_total": int(n_faulty_total),
        "windowed_fault_rate": float(controller.fault_rate()),
    }


def evaluate_schedule(
    built: BuiltExperiment,
    cuts,
    intervals,
    mode: str = "solve",
) -> ExperimentResult:
    """Price one (I, μ) schedule under the built problem as a result.

    This is the solve-mode result body; benchmarks that already hold a
    solved schedule use it to emit artifacts without re-solving.
    """
    p = built.problem
    theta = float(p.theta(intervals, cuts))
    R = p.rounds(intervals, cuts)
    total = float(p.total_T(intervals, cuts, R)) if R is not None else None

    privacy = None
    if built.privacy is not None:
        q1 = float(p.q[0])
        acc = built.privacy.accountant(q1)
        r_max = built.privacy.max_rounds(q1)
        privacy = {
            "noise_multiplier": built.privacy.noise_multiplier,
            "clip": built.privacy.clip,
            "delta": built.privacy.delta,
            "dp_sigma2": p.dp_sigma2,
            "epsilon_budget": built.privacy.epsilon_budget,
            "max_rounds": r_max,
            # ε actually spent by the schedule's R-to-target rounds
            "epsilon_at_schedule": (
                None if R is None or not np.isfinite(R)
                else acc.epsilon(int(np.ceil(R)))
            ),
        }
    energy = None
    if built.energy is not None:
        e = p.round_energy(intervals, cuts)
        energy = {
            "round_energy_j": e,
            "budget_j_per_round": built.energy.budget_j_per_round,
            "feasible": p.energy_feasible(intervals, cuts),
            # total campaign energy to the ε target, when R is finite
            "total_energy_j": (
                None if R is None or not np.isfinite(R) else float(e * R)
            ),
        }

    return ExperimentResult(
        mode=mode,
        cuts=tuple(int(c) for c in cuts),
        intervals=tuple(int(i) for i in intervals),
        theta=theta,
        rounds_to_eps=float(R) if R is not None else None,
        total_latency=total,
        latency=_latency_breakdown(built, cuts, intervals),
        privacy=privacy,
        energy=energy,
        provenance=jsonify(built.spec.to_dict()),
    )


def run(
    spec: ExperimentSpec, built: Optional[BuiltExperiment] = None
) -> ExperimentResult:
    """Build the spec, resolve its schedule, and produce the mode's result.

    Callers that already hold the ``build(spec)`` output pass it as
    ``built`` to avoid re-resolving registries / re-drawing the system.
    """
    import dataclasses

    if built is None:
        built = build(spec)
    elif built.spec != spec:
        raise ValueError("built was constructed from a different spec")
    if spec.run.mode == "simulate" and built.trace is None:
        # fail before the (expensive) solve, not after
        raise ValueError('run mode="simulate" needs a scenario section')
    if built.class_spec is not None:
        return _run_classes(built)
    cuts, intervals = _schedule(built)
    result = evaluate_schedule(built, cuts, intervals, mode=spec.run.mode)

    if spec.run.mode == "simulate":
        result = dataclasses.replace(
            result, sim=_simulate(built, cuts, intervals)
        )
    elif spec.run.mode == "train":
        result = dataclasses.replace(
            result, train=_train(built, cuts, intervals)
        )
    elif spec.run.mode == "control":
        result = dataclasses.replace(
            result, control=_control(built, cuts, intervals)
        )
    return result
