"""Bound-constant estimator (beta, sigma^2, G^2, theta0) on a probe run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.configs.shapes import concrete_inputs
from repro.core import build_train_step_a, init_state_a
from repro.core.estimator import HyperEstimator, _unit_sq_norms
from repro.core.tiers import default_plan
from repro.models.model import SplittableModel
from repro.optim import sgd


def test_unit_sq_norms_partition():
    """Per-unit squared norms sum to the global squared norm."""
    key = jax.random.PRNGKey(0)
    N, U = 4, 6
    tree = {
        "frontend": {"e": jax.random.normal(key, (N, 5))},
        "units": {"w": jax.random.normal(jax.random.fold_in(key, 1), (N, U, 3, 3))},
        "head": {"h": jax.random.normal(jax.random.fold_in(key, 2), (N, 2))},
    }
    sq = _unit_sq_norms(tree, U)
    assert sq.shape == (N, U)
    total = sum(float(jnp.sum(x**2)) for x in jax.tree.leaves(tree))
    np.testing.assert_allclose(float(jnp.sum(sq)), total, rtol=1e-5)


def test_estimator_on_probe_run():
    spec = get_reduced("smollm-135m")
    model = SplittableModel(spec)
    N = 4
    plan = default_plan(spec.n_units, N, entities=(N, 2, 1))
    opt = sgd(1e-2)
    state = init_state_a(model, plan, opt, jax.random.PRNGKey(0))
    step = jax.jit(build_train_step_a(model, plan, opt))
    grad_fn = jax.jit(lambda p, b: jax.vmap(jax.value_and_grad(model.loss_fn))(p, b))
    est = HyperEstimator(plan.n_units, N, gamma=1e-2)
    for t in range(4):
        batch = concrete_inputs(spec, N * 2, 16, jax.random.PRNGKey(t))
        batch = {k: v.reshape(N, 2, *v.shape[1:]) for k, v in batch.items()}
        losses, grads = grad_fn(state.params, batch)
        est.observe(state.params, grads, float(jnp.mean(losses)))
        state, _ = step(state, batch)
    hp = est.hyperspec()
    assert hp.G2.shape == (plan.n_units,)
    assert np.all(hp.G2 > 0)
    assert np.all(hp.sigma2 >= 0)
    # non-IID client batches: variance is strictly positive somewhere
    assert hp.sigma2.sum() > 0
    assert hp.beta > 0 and np.isfinite(hp.beta)
    assert hp.theta0 > 0
    # variance can never exceed the second moment (Assumption 2 structure)
    assert np.all(hp.sigma2 <= hp.G2 + 1e-9)


def test_estimator_requires_observations():
    est = HyperEstimator(4, 2, 1e-3)
    with pytest.raises(ValueError):
        est.hyperspec()
