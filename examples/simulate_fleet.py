"""Fleet simulation demo: robust (I, mu) against heterogeneous regimes.

Builds the paper's client(20)-edge(5)-cloud(1) system with VGG-16, replays
every scenario in the fleet-simulator library against it, and then re-solves
the joint MA+MS problem with the per-round p95 trace latencies in place of
the paper's static point estimates:

  1. nominal BCD solution on the static system (the paper's Sec. VII run);
  2. per-scenario round-latency profile of that nominal schedule
     (p50 / p95 / worst over 64 simulated rounds);
  3. robust BCD per scenario (p95 pricing) -- on the homogeneous-paper
     scenario this provably recovers the nominal solution, while the
     straggler-tail regime moves the cut shallower: a heavy on-device
     compute tail makes client-side units expensive at p95, which the
     static model cannot see.

    PYTHONPATH=src python examples/simulate_fleet.py
"""
import numpy as np

from repro.configs.vgg16_cifar10 import SPEC as VGG
from repro.core import (
    HsflProblem, SystemSpec, build_profile, solve_bcd, synthetic_hyperspec,
)
from repro.core.convergence import theorem1_bound
from repro.sim import SCENARIOS, make_trace, robust_problem, simulate_rounds

ROUNDS = 64


def build_problem(seed=0):
    prof = build_profile(VGG, batch=16)
    system = SystemSpec.paper_three_tier(num_clients=20, num_edges=5, seed=seed)
    hp = synthetic_hyperspec(VGG.n_units, 20, beta=3.0, seed=seed)
    floor = theorem1_bound(hp, 10**9, [1, 1, 1], (3, 8))
    return HsflProblem(prof, system, hp, eps=6.0 * floor)


def main(seed=0):
    prob = build_problem(seed)
    nominal = solve_bcd(prob)
    print(f"nominal (static Eq. 17/18): cuts={nominal.cuts} "
          f"I={tuple(nominal.intervals)} Theta'={nominal.theta:.4g}")

    # --- what the nominal schedule actually costs per scenario ------------
    print(f"\nper-round latency of the nominal schedule over {ROUNDS} rounds:")
    print(f"{'scenario':>26s}  {'p50':>9s}  {'p95':>9s}  {'worst':>9s}  "
          f"{'vs static':>9s}")
    traces = {}
    static = prob.split_T(nominal.cuts)
    for name in sorted(SCENARIOS):
        traces[name] = make_trace(
            name, prob.profile, prob.system, rounds=ROUNDS, seed=seed
        )
        res = simulate_rounds(traces[name], nominal.cuts)
        p50, p95 = np.quantile(res.split, [0.5, 0.95])
        print(f"{name:>26s}  {p50:9.3f}  {p95:9.3f}  {res.split.max():9.3f}  "
              f"{p95 / static:8.2f}x")

    # --- robust BCD: optimize against the p95 trace latencies -------------
    print("\nrobust BCD (p95 trace pricing) per scenario:")
    solutions = {}
    for name in sorted(SCENARIOS):
        res = solve_bcd(robust_problem(prob, traces[name], quantile=0.95))
        solutions[name] = res
        moved = "" if (res.cuts == nominal.cuts
                       and tuple(res.intervals) == tuple(nominal.intervals)) \
            else "   <- schedule moved"
        print(f"{name:>26s}: cuts={res.cuts} I={tuple(res.intervals)} "
              f"Theta'={res.theta:.4g}{moved}")

    # the two claims the sim subsystem is built around
    hom = solutions["homogeneous-paper"]
    assert hom.cuts == nominal.cuts and tuple(hom.intervals) == tuple(
        nominal.intervals
    ), "homogeneous trace must recover the static optimum"
    tail = solutions["straggler-tail"]
    assert tail.cuts != nominal.cuts, (
        "straggler-tail p95 should move the cut away from the static optimum"
    )
    print("\nhomogeneous trace recovers the static optimum; straggler tail "
          f"moves the cut {nominal.cuts} -> {tail.cuts} (fewer client-side "
          "units: on-device compute is what the tail inflates)")
    return solutions


if __name__ == "__main__":
    main()
