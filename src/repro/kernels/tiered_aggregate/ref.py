"""Pure-jnp oracle for the fused two-level HSFL aggregation (Eqs. 3–4).

Semantics (one tier's parameter shard, client-stacked):

    x        [N, P]   per-client parameter values
    weights  [N]      fed-server aggregation weights (N_m^j/N expanded to
                      clients; uniform = 1/N), must sum to 1
    do_entity scalar  bool — apply Eq. (3) entity-local mean (every round)
    do_global scalar  bool — apply Eq. (4) fed-server weighted mean (at I_m)

    y1 = do_entity ? mean within each of the J contiguous client groups : x
    y2 = do_global ? Σ_n w_n · y1_n  (broadcast back)                  : y1
"""
from __future__ import annotations

import jax.numpy as jnp


def tiered_aggregate_ref(x, weights, do_entity, do_global, num_entities: int):
    N, P = x.shape
    J = num_entities
    per = N // J
    xf = x.astype(jnp.float32)
    grouped = xf.reshape(J, per, P)
    emean = jnp.broadcast_to(
        jnp.mean(grouped, axis=1, keepdims=True), grouped.shape
    ).reshape(N, P)
    y1 = jnp.where(do_entity, emean, xf)
    w = weights.astype(jnp.float32)[:, None]
    gmean = jnp.sum(y1 * w, axis=0, keepdims=True)
    y2 = jnp.where(do_global, jnp.broadcast_to(gmean, y1.shape), y1)
    return y2.astype(x.dtype)
