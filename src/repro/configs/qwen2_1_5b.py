"""qwen2-1.5b [dense] — GQA, QKV bias [arXiv:2407.10671]."""
import dataclasses
from ..models.spec import ModelSpec

SPEC = ModelSpec(
    name="qwen2-1.5b", family="dense", num_layers=28, d_model=1536,
    num_heads=12, num_kv_heads=2, d_ff=8960, vocab_size=151936,
    qkv_bias=True, tie_embeddings=True, rope_theta=1e6,
    source="arXiv:2407.10671",
)

REDUCED = dataclasses.replace(
    SPEC, num_layers=2, d_model=192, num_heads=6, num_kv_heads=2,
    d_ff=384, vocab_size=512, head_dim=32,
)
