"""Property-style invariant sweeps (seed-parametrized; hypothesis is not
installable offline — same invariants, explicit random instances)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.tiers import TierPlan, default_plan, synchronize
from repro.core.problem import HsflProblem
from repro.core import SystemSpec, build_profile, synthetic_hyperspec
from repro.configs.vgg16_cifar10 import SPEC as VGG


def _rand_plan(rng, N=8, U=10):
    c1 = int(rng.integers(1, U - 1))
    c2 = int(rng.integers(c1, U))
    J2 = int(rng.choice([1, 2, 4, 8]))
    return default_plan(
        U, N, cuts=(c1, c2),
        intervals=(int(rng.integers(1, 9)), int(rng.integers(1, 9)), 1),
        entities=(N, J2, 1),
    )


@pytest.mark.parametrize("seed", range(12))
def test_synchronize_preserves_client_mean(seed):
    """Invariant: aggregation never changes the client-mean of any leaf
    (uniform weights) — HSFL only redistributes, it does not drift."""
    rng = np.random.default_rng(seed)
    plan = _rand_plan(rng)
    key = jax.random.PRNGKey(seed)
    params = {
        "frontend": {"e": jax.random.normal(key, (8, 5, 3))},
        "units": {"w": jax.random.normal(jax.random.fold_in(key, 1), (8, 10, 4))},
        "head": {"h": jax.random.normal(jax.random.fold_in(key, 2), (8, 6))},
    }
    step = int(rng.integers(0, 20))
    out = synchronize(params, plan, jnp.int32(step))
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(params)):
        np.testing.assert_allclose(
            np.asarray(a).mean(0), np.asarray(b).mean(0), atol=1e-5
        )


@pytest.mark.parametrize("seed", range(12))
def test_synchronize_idempotent(seed):
    """Applying the same round's schedule twice == once (projection)."""
    rng = np.random.default_rng(100 + seed)
    plan = _rand_plan(rng)
    key = jax.random.PRNGKey(seed)
    params = {
        "frontend": {"e": jax.random.normal(key, (8, 2))},
        "units": {"w": jax.random.normal(jax.random.fold_in(key, 1), (8, 10, 3))},
        "head": {"h": jax.random.normal(jax.random.fold_in(key, 2), (8, 2))},
    }
    step = int(rng.integers(0, 20))
    once = synchronize(params, plan, jnp.int32(step))
    twice = synchronize(once, plan, jnp.int32(step))
    for a, b in zip(jax.tree.leaves(once), jax.tree.leaves(twice)):
        np.testing.assert_allclose(a, b, atol=1e-5)


@pytest.mark.parametrize("seed", range(10))
def test_theta_consistency_numerator_denominator(seed):
    """Θ' == (2ϑ/γ)·N/D for random feasible points (objective assembly)."""
    rng = np.random.default_rng(seed)
    prob = HsflProblem(
        build_profile(VGG, batch=16),
        SystemSpec.paper_three_tier(seed=seed),
        synthetic_hyperspec(VGG.n_units, 20, beta=2.0, seed=seed),
        eps=10.0,
    )
    cuts = tuple(sorted(int(c) for c in rng.integers(1, 15, 2)))
    I = [int(rng.integers(1, 10)), int(rng.integers(1, 10)), 1]
    th = prob.theta(I, cuts)
    D = prob.denominator(I, cuts)
    if D > 0 and prob.memory_feasible(cuts):
        expect = 2 * prob.hyper.theta0 / prob.hyper.gamma * prob.numerator(I, cuts) / D
        np.testing.assert_allclose(th, expect, rtol=1e-12)
    else:
        assert th == float("inf")


@pytest.mark.parametrize("seed", range(10))
def test_rounds_decrease_with_smaller_intervals(seed):
    """Corollary 1 monotonicity on random problems."""
    prob = HsflProblem(
        build_profile(VGG, batch=16),
        SystemSpec.paper_three_tier(seed=seed),
        synthetic_hyperspec(VGG.n_units, 20, beta=2.0, seed=seed),
        eps=8.0,
    )
    rng = np.random.default_rng(seed)
    cuts = tuple(sorted(int(c) for c in rng.integers(1, 15, 2)))
    rounds = [prob.rounds([i, 2, 1], cuts) for i in (1, 3, 6)]
    rounds = [r for r in rounds if r is not None]
    assert rounds == sorted(rounds)


@pytest.mark.parametrize("seed", range(6))
def test_cut_vectors_all_valid(seed):
    prob = HsflProblem(
        build_profile(VGG, batch=16),
        SystemSpec.paper_three_tier(seed=seed),
        synthetic_hyperspec(VGG.n_units, 20, seed=seed),
        eps=10.0,
    )
    cuts_list = list(prob.iter_cut_vectors())
    assert len(cuts_list) > 50
    for cuts in cuts_list:
        assert prob.valid_cuts(cuts)
        assert all(c >= 1 for c in cuts)


@pytest.mark.parametrize("seed", range(10))
def test_bound_monotone_in_omega_and_dp_sigma2(seed):
    """Theorem 1 is non-decreasing in the compression second moment ω and
    in the DP noise mass dp_sigma2 on random (I, μ, R) — inflating either
    wire-error term can never tighten the bound (DESIGN.md §9/§15)."""
    from repro.core import theorem1_bound

    rng = np.random.default_rng(300 + seed)
    hp = synthetic_hyperspec(
        VGG.n_units, 20, beta=float(rng.uniform(1, 8)), seed=seed
    )
    cuts = tuple(sorted(int(c) for c in rng.integers(1, 15, 2)))
    I = [int(rng.integers(1, 10)), int(rng.integers(1, 10)), 1]
    R = int(rng.integers(5, 5000))
    for omegas, sig2s in (
        ((0.0, 0.05, 0.3, 1.0, 4.0), (0.7,)),
        ((0.25,), (0.0, 0.1, 1.0, 10.0, 1e4)),
    ):
        vals = [
            theorem1_bound(hp, R, I, cuts, omega=w, dp_sigma2=s)
            for w in omegas
            for s in sig2s
        ]
        assert all(a <= b for a, b in zip(vals, vals[1:]))
    # the zero point is the exact pre-DP/pre-compression bound, not a limit
    assert theorem1_bound(hp, R, I, cuts, omega=0.0, dp_sigma2=0.0) == \
        theorem1_bound(hp, R, I, cuts)
