"""Synthetic datasets standing in for CIFAR-10 / MNIST (offline container).

``SyntheticImageDataset`` draws class-conditional *structured* images: each
class owns a fixed random template filtered through a shared random conv
bank, plus per-sample noise — learnable by a CNN but not trivially (noise
floor keeps single-step accuracy < 100%), with the same dimensions as the
originals (32×32×3 CIFAR-like, 28×28×1 MNIST-like).

``SyntheticLmDataset`` emits token streams from a sparse random bigram
chain so that LM losses are reducible below the uniform floor — used for
the 10 assigned transformer architectures' smoke training runs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import numpy as np


@dataclass
class SyntheticImageDataset:
    images: np.ndarray  # [K, H, W, C] float32
    labels: np.ndarray  # [K] int32
    num_classes: int

    def __len__(self) -> int:
        return len(self.labels)


def _conv2d_same(x: np.ndarray, k: np.ndarray) -> np.ndarray:
    """Tiny valid 3x3 conv with zero padding (numpy, dataset-gen only)."""
    H, W, Cin = x.shape
    Cout = k.shape[-1]
    xp = np.pad(x, ((1, 1), (1, 1), (0, 0)))
    out = np.zeros((H, W, Cout), np.float32)
    for dy in range(3):
        for dx in range(3):
            out += np.einsum(
                "hwc,co->hwo", xp[dy : dy + H, dx : dx + W], k[dy, dx]
            )
    return out


def _make_images(
    n: int,
    num_classes: int,
    hw: int,
    channels: int,
    noise: float,
    seed: int,
    template_seed: int | None = None,
) -> SyntheticImageDataset:
    # Class templates define the *distribution*; `seed` only drives sampling.
    # Held-out sets must share template_seed with the train set or they come
    # from a different task entirely.
    trng = np.random.default_rng(seed if template_seed is None else template_seed)
    rng = np.random.default_rng(seed)
    templates = trng.normal(0, 1, (num_classes, hw, hw, channels)).astype(np.float32)
    conv = trng.normal(0, 0.3, (3, 3, channels, channels)).astype(np.float32)
    templates = np.stack([_conv2d_same(t, conv) for t in templates])
    templates /= np.abs(templates).max() + 1e-6
    labels = rng.integers(0, num_classes, n).astype(np.int32)
    imgs = templates[labels] + noise * rng.normal(0, 1, (n, hw, hw, channels)).astype(
        np.float32
    )
    return SyntheticImageDataset(imgs.astype(np.float32), labels, num_classes)


def make_cifar10_like(
    n: int = 4096, noise: float = 0.6, seed: int = 0,
    template_seed: int | None = None,
) -> SyntheticImageDataset:
    """32×32×3, 10 classes (matched to the paper's CIFAR-10 setting)."""
    return _make_images(n, 10, 32, 3, noise, seed, template_seed)


def make_mnist_like(
    n: int = 4096, noise: float = 0.5, seed: int = 1,
    template_seed: int | None = None,
) -> SyntheticImageDataset:
    """28×28×1, 10 classes (matched to the paper's MNIST setting)."""
    return _make_images(n, 10, 28, 1, noise, seed, template_seed)


@dataclass
class SyntheticLmDataset:
    tokens: np.ndarray  # [K, S+1] int32 (inputs=x[:, :-1], labels=x[:, 1:])
    vocab_size: int

    def __len__(self) -> int:
        return len(self.tokens)

    def batch(self, idx: np.ndarray) -> Dict[str, np.ndarray]:
        t = self.tokens[idx]
        return {"tokens": t[:, :-1], "labels": t[:, 1:]}


def make_lm_stream(
    n: int = 2048,
    seq: int = 64,
    vocab: int = 512,
    branching: int = 4,
    seed: int = 0,
) -> SyntheticLmDataset:
    """Sparse random bigram chain: every token has `branching` successors."""
    rng = np.random.default_rng(seed)
    succ = rng.integers(0, vocab, (vocab, branching)).astype(np.int32)
    toks = np.zeros((n, seq + 1), np.int32)
    toks[:, 0] = rng.integers(0, vocab, n)
    choices = rng.integers(0, branching, (n, seq))
    for s in range(seq):
        toks[:, s + 1] = succ[toks[:, s], choices[:, s]]
    return SyntheticLmDataset(toks, vocab)
