"""whisper-large-v3 [audio] — enc-dec, conv/mel frontend stubbed [arXiv:2212.04356]."""
import dataclasses
from ..models.spec import ModelSpec

SPEC = ModelSpec(
    name="whisper-large-v3", family="audio", num_layers=32, d_model=1280,
    num_heads=20, num_kv_heads=20, d_ff=5120, vocab_size=51866,
    encoder_layers=32, encoder_len=1500,
    source="arXiv:2212.04356",
)

REDUCED = dataclasses.replace(
    SPEC, num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
    d_ff=256, vocab_size=512, encoder_layers=2, encoder_len=16,
)
