"""Per-architecture smoke tests (deliverable f): every assigned arch's
REDUCED variant runs one forward + one train step + decode on CPU with
finite outputs and the right shapes."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_reduced, get_spec
from repro.configs.shapes import concrete_inputs
from repro.core import build_train_step_a, init_state_a
from repro.core.tiers import default_plan
from repro.models.model import SplittableModel
from repro.optim import sgd


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_constraints(arch):
    r = get_reduced(arch)
    assert r.num_layers <= 2 or r.family == "hybrid" and r.n_units <= 2
    assert r.d_model <= 512
    if r.moe:
        assert r.moe.num_experts <= 4


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_spec_matches_assignment(arch):
    s = get_spec(arch)
    expect = {
        "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "qwen3-32b": (64, 5120, 64, 8, 25600, 151936),
        "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151936),
        "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
        "smollm-135m": (30, 576, 9, 3, 1536, 49152),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "mamba2-1.3b": (48, 2048, 0, 0, 0, 50280),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
    }[arch]
    got = (s.num_layers, s.d_model, s.num_heads, s.num_kv_heads, s.d_ff, s.vocab_size)
    assert got == expect, (arch, got, expect)
    assert s.source, f"{arch} missing its public-pool citation"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    spec = get_reduced(arch)
    model = SplittableModel(spec)
    params = model.init_params(jax.random.PRNGKey(0))
    B, S = 2, 32
    batch = concrete_inputs(spec, B, S)
    logits, aux = model.forward(params, batch)
    S_text = S - (spec.prefix_len if spec.family == "vlm" else 0)
    expect_len = S_text + (spec.prefix_len if spec.family == "vlm" else 0)
    assert logits.shape == (B, expect_len if spec.family == "vlm" else S_text if spec.family == "vlm" else S, spec.padded_vocab) or logits.shape[0] == B
    assert logits.shape[-1] == spec.padded_vocab
    assert np.all(np.isfinite(np.asarray(logits[..., : spec.vocab_size], np.float32)))


@pytest.mark.parametrize(
    "arch",
    [  # jamba's 398B reduced variant still jits ~30s of hybrid blocks on CPU
        pytest.param(a, marks=pytest.mark.slow) if a == "jamba-1.5-large-398b"
        else a
        for a in ARCH_IDS
    ],
)
def test_one_train_step(arch):
    spec = get_reduced(arch)
    model = SplittableModel(spec)
    N = 4
    plan = default_plan(spec.n_units, N, entities=(N, 2, 1))
    opt = sgd(1e-2)
    state = init_state_a(model, plan, opt, jax.random.PRNGKey(0))
    step = jax.jit(build_train_step_a(model, plan, opt))
    batch = concrete_inputs(spec, N * 2, 32)
    batch = {k: v.reshape(N, 2, *v.shape[1:]) for k, v in batch.items()}
    state2, loss = step(state, batch)
    assert np.isfinite(float(loss)) and float(loss) > 0
    # params actually moved
    moved = any(
        not np.allclose(a, b)
        for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(state2.params))
    )
    assert moved
    assert int(state2.step) == 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    spec = get_reduced(arch)
    model = SplittableModel(spec)
    params = model.init_params(jax.random.PRNGKey(0))
    B, C = 2, 16
    caches = model.init_caches(B, C)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, caches2 = jax.jit(model.decode_step)(params, tok, caches, jnp.int32(0))
    assert logits.shape == (B, spec.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits[:, : spec.vocab_size], np.float32)))
    changed = any(
        not np.allclose(a, b)
        for a, b in zip(jax.tree.leaves(caches), jax.tree.leaves(caches2))
    )
    assert changed


@pytest.mark.parametrize("arch", ["smollm-135m", "qwen2-1.5b", "mamba2-1.3b", "qwen3-32b"])
def test_decode_matches_forward(arch):
    """Teacher-forced decode reproduces the training-path logits."""
    spec = get_reduced(arch)
    model = SplittableModel(spec)
    params = model.init_params(jax.random.PRNGKey(1))
    B, S = 1, 8
    batch = concrete_inputs(spec, B, S)
    full_logits, _ = model.forward(params, batch)
    caches = model.init_caches(B, S)
    decode = jax.jit(model.decode_step)
    for i in range(S):
        step_logits, caches = decode(
            params, batch["tokens"][:, i : i + 1], caches, jnp.int32(i)
        )
    np.testing.assert_allclose(
        np.asarray(step_logits[:, : spec.vocab_size]),
        np.asarray(full_logits[:, -1, : spec.vocab_size]),
        rtol=2e-3, atol=2e-3,
    )


def test_long_context_window_variant():
    """Dense archs get a ring-buffer cache under a sliding window."""
    spec = get_reduced("qwen2.5-14b").with_window(8)
    model = SplittableModel(spec)
    params = model.init_params(jax.random.PRNGKey(0))
    caches = model.init_caches(1, 64)
    # ring buffer bounded by window, not cache_len: k/v leaves have a
    # window-sized cache axis (leaf order puts scalar "index" first, so
    # look at the 4+-dim leaves explicitly).
    kv_shapes = [x.shape for x in jax.tree.leaves(caches) if x.ndim >= 4]
    assert kv_shapes and all(s[-3] == 8 for s in kv_shapes)
    decode = jax.jit(model.decode_step)
    tok = jnp.zeros((1, 1), jnp.int32)
    for i in range(12):  # wrap past the window
        logits, caches = decode(params, tok, caches, jnp.int32(i))
    assert np.all(np.isfinite(np.asarray(logits[:, : spec.vocab_size])))


def test_total_param_count_close_to_nominal():
    """Analytic param accounting lands near each card's nominal size."""
    nominal = {
        "qwen2.5-14b": 14e9, "qwen3-32b": 32e9, "qwen2-1.5b": 1.5e9,
        "smollm-135m": 135e6, "mamba2-1.3b": 1.3e9,
        "granite-moe-1b-a400m": 1.3e9, "phi3.5-moe-42b-a6.6b": 42e9,
        "jamba-1.5-large-398b": 398e9, "paligemma-3b": 2.6e9,  # LM backbone
        "whisper-large-v3": 1.5e9,
    }
    for arch, nom in nominal.items():
        got = get_spec(arch).total_param_count()
        assert 0.5 * nom < got < 1.7 * nom, (arch, got / 1e9)


@pytest.mark.slow
def test_moe_grouped_gradients():
    """Grouped dispatch + scatter-add combine is differentiable and its
    gradients match the ungrouped path (no-drop capacity)."""
    import dataclasses

    import numpy as np

    from repro.configs import get_reduced
    from repro.models import layers as L

    spec = get_reduced("granite-moe-1b-a400m")
    ms = dataclasses.replace(spec.moe, capacity_factor=8.0)
    spec = dataclasses.replace(spec, moe=ms)
    p = L.init_moe(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, spec.d_model))

    def loss(params, g):
        out, aux = L.moe(params, x, spec, groups=g)
        return jnp.sum(out**2) + aux

    g1 = jax.grad(lambda p_: loss(p_, 1))(p)
    g4 = jax.grad(lambda p_: loss(p_, 4))(p)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-5)
    assert all(np.isfinite(np.asarray(l)).all() for l in jax.tree.leaves(g4))
