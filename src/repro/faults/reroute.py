"""Cell-outage rerouting: dead cells' clients join sibling cells' syncs.

The dense sync path (``tiers._group_mean``) realizes entity grouping as a
contiguous ``reshape(J, N//J)`` — it cannot express a client served by a
cell other than its own.  This module generalizes the grouping the same
way DESIGN.md §14's ragged machinery generalized the unit axis: an
explicit 0/1 *membership matrix*, here ``[N, J]`` over cells instead of
``[N, U]`` over units.  ``outage_assignment`` remaps every dead cell's
clients round-robin onto the surviving cells; ``reroute_entity_sync``
then runs the tier's entity-level mean (Eq. 3) under that membership:

    mean_j = Σ_i members[i,j]·w_i·x_i / Σ_i members[i,j]·w_i
    out_i  = Σ_j members[i,j]·mean_j      (broadcast back to every member)

Because a completed level leaves every member carrying its cell's
weighted mean, the rerouted mean over (sibling cell ∪ adopted clients)
is exactly the joint participant-weighted mean — the same hierarchical-
weighting argument as ``_group_mean_masked``'s docstring.  With the
identity assignment the matrix is the plan's contiguous grouping, but
the rerouted path is only ever entered on outage rounds: clean rounds
never leave today's reshape-based code (the bit-exactness gate).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np


def outage_assignment(
    num_clients: int, num_cells: int, out_cells: Sequence[int]
) -> np.ndarray:
    """[N] int — each client's serving cell with dead cells remapped.

    Healthy cells keep their contiguous client block; a dead cell's
    clients are dealt round-robin across the surviving cells (balanced,
    deterministic in client order).  Raises when nothing survives.
    """
    J, N = num_cells, num_clients
    if N % J != 0:
        raise ValueError(f"num_clients={N} not divisible by num_cells={J}")
    dead = sorted({int(c) for c in out_cells})
    bad = [c for c in dead if not 0 <= c < J]
    if bad:
        raise ValueError(f"out_cells {bad} outside [0, {J})")
    alive = [j for j in range(J) if j not in dead]
    if not alive:
        raise ValueError(
            f"all {J} cells are out — no sibling cell left to reroute to"
        )
    per = N // J
    assign = np.repeat(np.arange(J), per)
    orphans = np.flatnonzero(np.isin(assign, dead))
    assign[orphans] = np.asarray(alive, dtype=assign.dtype)[
        np.arange(len(orphans)) % len(alive)
    ]
    return assign


def assignment_members(assign: np.ndarray, num_cells: int) -> np.ndarray:
    """[N, J] float32 one-hot membership matrix for an assignment vector."""
    N = len(assign)
    members = np.zeros((N, num_cells), dtype=np.float32)
    members[np.arange(N), assign] = 1.0
    return members


def membership_mean(tree, members, w=None, keep=None):
    """Membership-weighted cell mean, broadcast back to members (jittable).

    ``members`` [N, J] gates which cell averages a client's replica and
    which mean the client receives; ``w`` [N] is the usual participation
    weight (``tiers._group_mean_masked`` semantics: a zero-weight cell
    keeps its members' ``keep`` values).  Leaves without a leading client
    axis pass through untouched.
    """
    import jax
    import jax.numpy as jnp

    mem = jnp.asarray(members, dtype=jnp.float32)
    N = mem.shape[0]
    cw = mem if w is None else mem * w.astype(jnp.float32)[:, None]
    s = jnp.sum(cw, axis=0)  # [J] per-cell participant weight
    if keep is None:
        keep = tree

    def f(x, k):
        if x.ndim == 0 or x.shape[0] != N:
            return x
        flat = x.reshape(N, -1)
        tot = jnp.einsum(
            "nj,nd->jd", cw.astype(jnp.float32), flat.astype(jnp.float32)
        )
        mean = tot / jnp.maximum(s, 1.0)[:, None]
        back = jnp.einsum("nj,jd->nd", mem, mean).astype(x.dtype)
        ok = (jnp.einsum("nj,j->n", mem, s) > 0.0)[:, None]
        out = jnp.where(ok, back, k.reshape(N, -1))
        return out.reshape(x.shape)

    return jax.tree.map(f, tree, keep)


def reroute_entity_sync(params, plan, m: int, members, mask=None):
    """Tier m's entity-level sync (Eq. 3) under a rerouted membership.

    Slices tier m's subtree, applies the membership mean, and recombines.
    On an outage round the fault-aware loop zeroes the dead cells'
    clients out of the step's mask (their serving fed cell is
    unreachable, so their round contribution is lost — the same loss the
    q-deflation accounting charges), then calls this with the rerouted
    membership and that same mask: the adopted clients contribute no
    weight to the sibling's mean but *receive* its broadcast, so they
    rejoin healed instead of drifting for the whole outage span.
    """
    from ..core.tiers import combine_tiers, tier_subtrees

    parts = tier_subtrees(params, plan)
    parts[m] = membership_mean(parts[m], members, w=mask)
    return combine_tiers(parts, params)
