"""npz checkpoint roundtrip + failure modes."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint


def tree(key=0):
    k = jax.random.PRNGKey(key)
    return {
        "frontend": {"embed": jax.random.normal(k, (4, 8))},
        "units": {"w": jnp.arange(24.0).reshape(2, 3, 4)},
        "list": [jnp.ones((2,)), jnp.zeros((3,), jnp.int32)],
    }


def test_roundtrip(tmp_path):
    t = tree()
    p = str(tmp_path / "ck.npz")
    save_checkpoint(p, t, step=42, meta={"cuts": [3, 8], "intervals": [140, 20, 1]})
    t2, step, meta = load_checkpoint(p, tree(key=1))
    assert step == 42
    assert meta == {"cuts": [3, 8], "intervals": [140, 20, 1]}
    for a, b in zip(jax.tree.leaves(t2), jax.tree.leaves(t)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_missing_leaf_fails(tmp_path):
    p = str(tmp_path / "ck.npz")
    save_checkpoint(p, {"a": jnp.ones(3)}, step=1)
    with pytest.raises(KeyError):
        load_checkpoint(p, {"a": jnp.ones(3), "b": jnp.ones(2)})


def test_shape_mismatch_fails(tmp_path):
    p = str(tmp_path / "ck.npz")
    save_checkpoint(p, {"a": jnp.ones(3)}, step=1)
    with pytest.raises(ValueError):
        load_checkpoint(p, {"a": jnp.ones(4)})


def test_atomic_overwrite(tmp_path):
    p = str(tmp_path / "ck.npz")
    save_checkpoint(p, {"a": jnp.zeros(2)}, step=1)
    save_checkpoint(p, {"a": jnp.ones(2)}, step=2)
    t, step, _ = load_checkpoint(p, {"a": jnp.zeros(2)})
    assert step == 2 and np.all(np.asarray(t["a"]) == 1)
    assert not [f for f in os.listdir(tmp_path) if f.endswith(".tmp.npz")]
