"""jit'd public wrapper for the SWA flash-attention kernels.

``swa_attention(q, k, v, window)`` takes [B, S, H, hd] / [B, S, K, hd]
(GQA), handles layout (head-major for the kernel grid), sequence padding to
the 128 tile, head-dim padding to the 128 lane, the 1/√hd scale fold, and
wires the forward/backward kernels through ``jax.custom_vjp``. Set
``use_pallas=False`` to run the pure-jnp oracle; ``interpret=True`` (the
default here) executes the kernel body in Python on CPU — on real TPU pass
``interpret=False``.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from .ref import swa_attention_ref
from .swa_attention import _bwd, _fwd

T = 128  # MXU-aligned tile


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _swa(q, k, v, window: int, interpret: bool):
    o, _ = _swa_fwd_res(q, k, v, window, interpret)[0], None
    return o


def _prep(q, k, v, window):
    B, S, H, hd = q.shape
    scale = 1.0 / math.sqrt(hd)
    qt = _pad_to(_pad_to((q * scale).transpose(0, 2, 1, 3), T, 2), 128, 3)
    kt = _pad_to(_pad_to(k.transpose(0, 2, 1, 3), T, 2), 128, 3)
    vt = _pad_to(_pad_to(v.transpose(0, 2, 1, 3), T, 2), 128, 3)
    w_eff = 0 if (window == 0 or window >= S) else window
    return qt, kt, vt, w_eff, S, hd, scale


def _swa_fwd_res(q, k, v, window, interpret):
    qt, kt, vt, w_eff, S, hd, scale = _prep(q, k, v, window)
    o, lse = _fwd(qt, kt, vt, window=w_eff, T=T, S_true=S, interpret=interpret)
    out = o[:, :, :S, :hd].transpose(0, 2, 1, 3)
    return out, (qt, kt, vt, o, lse, w_eff, S, hd, scale)


def _swa_fwd(q, k, v, window, interpret):
    out, res = _swa_fwd_res(q, k, v, window, interpret)
    return out, res


def _swa_bwd(window, interpret, res, dout):
    qt, kt, vt, o, lse, w_eff, S, hd, scale = res
    dot = _pad_to(_pad_to(dout.transpose(0, 2, 1, 3), T, 2), 128, 3)
    dq, dk, dv = _bwd(
        qt, kt, vt, o, lse, dot, window=w_eff, T=T, S_true=S, interpret=interpret
    )
    dq = dq[:, :, :S, :hd].transpose(0, 2, 1, 3) * scale
    dk = dk[:, :, :S, :hd].transpose(0, 2, 1, 3)
    dv = dv[:, :, :S, :hd].transpose(0, 2, 1, 3)
    return dq.astype(qt.dtype), dk.astype(kt.dtype), dv.astype(vt.dtype)


_swa.defvjp(_swa_fwd, _swa_bwd)


@partial(
    jax.jit, static_argnames=("window", "use_pallas", "interpret")
)
def swa_attention(
    q: jax.Array,  # [B, S, H, hd]
    k: jax.Array,  # [B, S, K, hd]
    v: jax.Array,  # [B, S, K, hd]
    window: int = 0,
    use_pallas: bool = True,
    interpret: bool = True,
) -> jax.Array:
    if window > 0:
        assert window % T == 0, f"window must be a multiple of {T}"
    if not use_pallas:
        return swa_attention_ref(q, k, v, window)
    return _swa(q, k, v, window, interpret)
