"""String-keyed registries the spec layer resolves against (DESIGN.md §10).

Four maps, one per spec axis:

* ``MODEL_IDS``  — architecture ids (delegates to ``repro.configs``);
* ``SYSTEMS``    — system presets: name → ``SystemCfg`` → ``SystemSpec``
  (paper-three-tier, tpu-pod, the two two-tier SFL baselines of Fig. 7,
  the M=4 four-tier-wan hierarchy, plus anything added via
  ``register_system``);
* ``SCENARIOS``  — fleet-sim regimes (delegates to ``repro.sim``);
* ``CODECS``     — wire codecs: name → ``Compressor`` constructor
  (delegates to ``repro.compress.SCHEMES``; extend via ``register_codec``).

Registries keep specs *data*: a new scenario/system/codec becomes reachable
from serialized specs by registering a builder, with no new wiring code at
any call site.
"""
from __future__ import annotations

from typing import Callable, Dict, List

import numpy as np

from ..compress import SCHEMES
from ..configs import ARCH_IDS, get_reduced, get_spec
from ..core.latency import SystemSpec
from .spec import ModelCfg, SystemCfg

# --------------------------------------------------------------------------- #
# models
# --------------------------------------------------------------------------- #

MODEL_IDS: List[str] = sorted([*ARCH_IDS, "vgg16-cifar10"])


def resolve_model(cfg: ModelCfg):
    """``ModelCfg`` → the concrete ModelSpec / VggSpec it names."""
    spec = get_reduced(cfg.arch) if cfg.variant == "reduced" else get_spec(cfg.arch)
    if cfg.num_layers is not None:
        import dataclasses

        spec = dataclasses.replace(spec, num_layers=cfg.num_layers)
    return spec


# --------------------------------------------------------------------------- #
# systems
# --------------------------------------------------------------------------- #

SystemBuilder = Callable[[SystemCfg], SystemSpec]
SYSTEMS: Dict[str, SystemBuilder] = {}


def register_system(name: str) -> Callable[[SystemBuilder], SystemBuilder]:
    """Register a system preset under ``name`` (decorator)."""

    def deco(fn: SystemBuilder) -> SystemBuilder:
        SYSTEMS[name] = fn
        return fn

    return deco


def resolve_system(cfg: SystemCfg) -> SystemSpec:
    try:
        builder = SYSTEMS[cfg.preset]
    except KeyError:
        raise KeyError(
            f"unknown system preset {cfg.preset!r}; available: {sorted(SYSTEMS)}"
        ) from None
    return builder(cfg)


@register_system("paper-three-tier")
def _paper_three_tier(cfg: SystemCfg) -> SystemSpec:
    """Sec. VII client–edge–cloud WAN system."""
    return SystemSpec.paper_three_tier(
        num_clients=cfg.num_clients,
        num_edges=cfg.num_edges,
        seed=cfg.seed,
        compute_scale=cfg.compute_scale,
        comm_scale=cfg.comm_scale,
        **cfg.extras,
    )


@register_system("tpu-pod")
def _tpu_pod(cfg: SystemCfg) -> SystemSpec:
    """HSFL hierarchy priced with TPU v5e ICI/DCN constants (DESIGN.md §2).

    Deterministic preset (``seed`` unused); ``compute_scale`` scales chip
    FLOPS and ``comm_scale`` scales the ICI/DCN links so Fig.-6-style
    resource sweeps work here too.
    """
    extras = dict(cfg.extras)
    chip_flops = extras.pop("chip_flops", 197e12) * cfg.compute_scale
    ici_bps = extras.pop("ici_bps", 50e9 * 8) * cfg.comm_scale
    dcn_bps = extras.pop("dcn_bps", 25e9 * 8) * cfg.comm_scale
    return SystemSpec.tpu_pod_mapping(
        num_clients=cfg.num_clients,
        num_edges=cfg.num_edges,
        chip_flops=chip_flops,
        ici_bps=ici_bps,
        dcn_bps=dcn_bps,
        **extras,
    )


def _two_tier(cfg: SystemCfg, kind: str) -> SystemSpec:
    """Client-edge (J2 near servers) or client-cloud (one far server) SFL —
    the Fig. 7 baselines (formerly hand-wired in benchmarks/fig67)."""
    # fail loudly rather than run a system the provenance doesn't describe
    if cfg.extras:
        raise ValueError(
            f"two-tier-{kind} takes no extras; got {sorted(cfg.extras)}"
        )
    if kind == "client-cloud" and cfg.num_edges != 1:
        raise ValueError(
            "two-tier-client-cloud has exactly one server; set num_edges=1 "
            f"(got {cfg.num_edges})"
        )
    if not 1 <= cfg.num_edges <= cfg.num_clients:
        raise ValueError(
            f"two-tier-{kind} needs 1 <= num_edges <= num_clients; got "
            f"num_edges={cfg.num_edges}, num_clients={cfg.num_clients}"
        )
    rng = np.random.default_rng(cfg.seed)
    N = cfg.num_clients
    dev = rng.uniform(0.4e12, 0.6e12, N) * cfg.compute_scale
    if kind == "client-edge":
        J2, f2 = cfg.num_edges, 5e12
        up = rng.uniform(75e6, 80e6, N) * cfg.comm_scale
        down = np.full(N, 370e6) * cfg.comm_scale
    else:  # client-cloud: more compute, slower WAN link (15 Mbps, Fig. 2)
        J2, f2 = 1, 50e12
        up = np.full(N, 15e6) * cfg.comm_scale
        down = np.full(N, 15e6) * cfg.comm_scale
    per = N // J2
    return SystemSpec(
        M=2,
        num_clients=N,
        entities=(N, J2),
        compute=(dev, np.full(N, f2 / per) * cfg.compute_scale),
        act_up=(up,),
        act_down=(down,),
        model_up=(rng.uniform(75e6, 80e6, N) * cfg.comm_scale,),
        model_down=(np.full(N, 370e6) * cfg.comm_scale,),
        memory=(np.full(N, 8e9), np.full(J2, 64e9)),
    )


@register_system("lognormal-fleet")
def _lognormal_fleet(cfg: SystemCfg) -> SystemSpec:
    """Sec. VII system with a *statically* heterogeneous device tier.

    The paper-three-tier arrays with per-device lognormal multipliers
    drawn once — ``exp(N(0, compute_sigma))`` on tier-0 compute and
    ``exp(N(0, link_sigma))`` on the tier-0 links.  Each device's fed
    uplink/downlink shares its access-link draw (one radio), so slow-link
    devices are slow on both the activation and the model wire — the
    regime where per-class cut assignment pays (DESIGN.md §14).  Unlike
    the ``lognormal-heterogeneous`` *scenario* (fresh draws per round),
    this is a fixed system, so nominal pricing — and hence the per-class
    solver — applies.  ``extras``: ``compute_sigma`` (0.5), ``link_sigma``
    (0.6).
    """
    import dataclasses

    extras = dict(cfg.extras)
    compute_sigma = float(extras.pop("compute_sigma", 0.5))
    link_sigma = float(extras.pop("link_sigma", 0.6))
    base = SystemSpec.paper_three_tier(
        num_clients=cfg.num_clients,
        num_edges=cfg.num_edges,
        seed=cfg.seed,
        compute_scale=cfg.compute_scale,
        comm_scale=cfg.comm_scale,
        **extras,
    )
    rng = np.random.default_rng(cfg.seed + 777)
    N = cfg.num_clients
    dev = np.exp(rng.normal(0.0, compute_sigma, N))
    up = np.exp(rng.normal(0.0, link_sigma, N))
    down = np.exp(rng.normal(0.0, link_sigma, N))
    return dataclasses.replace(
        base,
        compute=(base.compute[0] * dev,) + base.compute[1:],
        act_up=(base.act_up[0] * up,) + base.act_up[1:],
        act_down=(base.act_down[0] * down,) + base.act_down[1:],
        model_up=(base.model_up[0] * up,) + base.model_up[1:],
        model_down=(base.model_down[0] * down,) + base.model_down[1:],
    )


@register_system("four-tier-wan")
def _four_tier_wan(cfg: SystemCfg) -> SystemSpec:
    """Client–edge–regional–cloud WAN hierarchy (M=4): the Sec. VII
    numbers extended one tier up (a regional aggregation layer between
    edge and cloud), for M-sweeps of the solver core and deeper-hierarchy
    scenarios.  ``extras['num_regional']`` sets J₃ (default J₂//2)."""
    rng = np.random.default_rng(cfg.seed)
    N, J2 = cfg.num_clients, cfg.num_edges
    extras = dict(cfg.extras)
    J3 = int(extras.pop("num_regional", max(1, J2 // 2)))
    if extras:
        raise ValueError(f"four-tier-wan unknown extras: {sorted(extras)}")
    if not 1 <= J3 <= J2 <= N:
        raise ValueError(
            f"four-tier-wan needs 1 <= num_regional <= num_edges <= "
            f"num_clients; got {J3}/{J2}/{N}"
        )
    dev = rng.uniform(0.4e12, 0.6e12, N) * cfg.compute_scale
    edge = np.full(N, 5e12 / max(1, N // J2)) * cfg.compute_scale
    regional = np.full(N, 20e12 / max(1, N // J3)) * cfg.compute_scale
    cloud = np.full(N, 50e12 / N) * cfg.compute_scale
    up_dev = rng.uniform(75e6, 80e6, N) * cfg.comm_scale
    down_dev = np.full(N, 370e6) * cfg.comm_scale
    edge_reg = rng.uniform(370e6, 400e6, N) * cfg.comm_scale
    reg_cloud = rng.uniform(800e6, 1000e6, N) * cfg.comm_scale
    return SystemSpec(
        M=4,
        num_clients=N,
        entities=(N, J2, J3, 1),
        compute=(dev, edge, regional, cloud),
        act_up=(up_dev, edge_reg, reg_cloud),
        act_down=(down_dev, edge_reg, reg_cloud),
        model_up=(
            rng.uniform(75e6, 80e6, N) * cfg.comm_scale,
            rng.uniform(370e6, 400e6, J2) * cfg.comm_scale,
            rng.uniform(800e6, 1000e6, J3) * cfg.comm_scale,
        ),
        model_down=(
            np.full(N, 370e6) * cfg.comm_scale,
            np.full(J2, 370e6) * cfg.comm_scale,
            np.full(J3, 1000e6) * cfg.comm_scale,
        ),
        memory=(
            np.full(N, 8e9),
            np.full(J2, 16e9),
            np.full(J3, 64e9),
            np.array([256e9]),
        ),
    )


@register_system("two-tier-client-edge")
def _two_tier_client_edge(cfg: SystemCfg) -> SystemSpec:
    return _two_tier(cfg, "client-edge")


@register_system("two-tier-client-cloud")
def _two_tier_client_cloud(cfg: SystemCfg) -> SystemSpec:
    return _two_tier(cfg, "client-cloud")


# --------------------------------------------------------------------------- #
# scenarios (delegated) and codecs
# --------------------------------------------------------------------------- #


def scenario_names() -> List[str]:
    from ..sim.scenarios import SCENARIOS

    return sorted(SCENARIOS)


CODECS: Dict[str, Callable] = dict(SCHEMES)


def register_codec(name: str, ctor: Callable) -> None:
    """Register a ``Compressor`` constructor under ``name``."""
    CODECS[name] = ctor


def resolve_codec(name: str, params: Dict) -> object:
    try:
        ctor = CODECS[name]
    except KeyError:
        raise KeyError(
            f"unknown codec {name!r}; available: {sorted(CODECS)}"
        ) from None
    return ctor(**params)
