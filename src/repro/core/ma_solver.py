r"""P1 — the model-aggregation sub-problem (Proposition 1).

For fixed cuts μ, minimize over I ∈ (ℕ⁺)^{M-1}

    Θ'(I) ∝ (a + Σ_m b_m / I_m) / (c − κ Σ_m 1{I_m>1} d_m I_m²).

Proposition 1 structure:
  * enumerate all 2^{M-1} subsets M' of tiers pinned to I_m = 1;
  * for the free tiers M'', the stationary condition ∂Θ'/∂I_{m'} = 0 is the
    cubic  Ξ_{m'}(I) = 2κ d a' I³ + 3κ d b I² − b c' = 0  with
        a' = a + Σ_{m∈M''\{m'}} b_m/I_m + Σ_{m∈M'} b_m,
        c' = c − κ Σ_{m∈M''\{m'}} d_m I_m²,
    which has exactly one positive root (Ξ is increasing, Ξ(0) < 0);
  * solve the coupled system by Newton–Jacobi sweeps, then pick the best of
    the 2^{|M''|} floor/ceil roundings under the *exact* objective (with the
    I=1 indicator discontinuity honoured).

The candidate set (pinned bases + rounding neighbourhoods) is generated
once by ``_candidate_intervals``; the final exact-objective pick runs
either as the historical per-candidate ``problem.theta`` walk
(``backend="scalar"``, each call re-prices T_S/T_{m,A} from scratch) or
as one vectorized Θ' evaluation over a ``[C, M-1]`` interval array with
the latency terms a/b priced exactly once (any other backend) — same
candidate order, same accumulation order, bit-identical winner
(DESIGN.md §11).

The solver is exact up to the integer rounding neighbourhood, which matches
Eq. (26)/(38); ``tests/test_solvers.py`` verifies optimality against brute
force over the full integer grid.
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .problem import INFEASIBLE, HsflProblem

# Newton stop threshold for _cubic_positive_root (hoisted: the controller's
# warm re-solve path prices thousands of cubics per second and
# ``np.finfo(...).eps`` is a surprisingly expensive constructor).
_EPS4 = 4.0 * float(np.finfo(float).eps)


@dataclass(frozen=True)
class MaSolution:
    intervals: Tuple[int, ...]  # length M (top tier forced to 1)
    theta: float


def _cubic_positive_root(
    ka: float, kb: float, kc: float, max_doublings: int = 200
) -> float:
    """Unique positive root of  ka·I³ + kb·I² − kc = 0  (ka, kb, kc > 0).

    For positive coefficients f(I) = ka·I³ + kb·I² − kc is strictly
    increasing and convex on I > 0 with f(0) = −kc < 0, so Newton from any
    point above the root descends monotonically and converges
    quadratically — orders of magnitude cheaper than the companion-matrix
    eigensolve ``np.roots`` runs, which matters because the adaptive
    controller (``repro.control``) prices this root on every warm re-solve.
    The historical bisection fallback still guards degenerate coefficients.
    """
    ka, kb, kc = float(ka), float(kb), float(kc)
    if ka > 0 and kb > 0 and kc > 0:
        # each term alone overshoots kc at these points, so both are upper
        # bounds; start at the tighter one
        x = min((kc / ka) ** (1.0 / 3.0), (kc / kb) ** 0.5)
        for _ in range(100):
            f = (ka * x + kb) * x * x - kc
            df = (3.0 * ka * x + 2.0 * kb) * x
            if df <= 0:
                break
            step = f / df
            x_new = x - step
            if x_new <= 0 or x_new >= x:
                break
            x = x_new
            if abs(step) <= _EPS4 * x:
                break
        else:
            x = None
        if x is not None and x > 0:
            return float(x)
    if kb > 0 and kc > 0:
        # Degenerate-leading-coefficient deflation: when ka ≈ 0 the cubic
        # collapses to  kb·I² − kc = 0.  ``np.roots`` cannot handle this
        # regime — its companion matrix divides by the leading coefficient,
        # so a subnormal ka yields inf/garbage eigenvalues and an empty (or
        # spurious) positive-root set.  Deflate explicitly whenever the
        # cubic term is negligible at the quadratic root: at I = r₂ the
        # cubic contributes ka·r₂³ against kb·r₂², i.e. the test ka·r₂ ≪ kb.
        r2 = math.sqrt(kc / kb)
        if ka <= 0.0 or ka * r2 <= _EPS4 * kb:
            return float(r2)
    try:
        roots = np.roots([ka, kb, 0.0, -kc])
    except np.linalg.LinAlgError:
        roots = np.empty(0, dtype=complex)
    roots = roots[np.isfinite(roots)]
    real = roots[np.abs(roots.imag) < 1e-9].real
    pos = real[real > 0]
    if len(pos) == 0:  # numerical fallback: bisection
        lo, hi = 1e-9, 1.0
        f = lambda x: ka * x**3 + kb * x**2 - kc
        for _ in range(max_doublings):
            if f(hi) >= 0:
                break
            hi *= 2.0
        else:
            # a degenerate coefficient set (e.g. ka = kb = 0, kc > 0) has no
            # positive root at all; without this cap the bracket expansion
            # would double `hi` forever.
            raise ValueError(
                "MA bracket expansion failed: "
                f"Ξ(I) = {ka!r}·I³ + {kb!r}·I² − {kc!r} has no positive root "
                f"within I ≤ {hi:.3g} after {max_doublings} doublings "
                "(Proposition 1 requires ka, kb, kc > 0)"
            )
        for _ in range(200):
            mid = 0.5 * (lo + hi)
            if f(mid) < 0:
                lo = mid
            else:
                hi = mid
        return 0.5 * (lo + hi)
    return float(pos[0])


def _newton_jacobi(
    a: float,
    b: np.ndarray,
    c: float,
    kappa: float,
    d: np.ndarray,
    free: List[int],
    pinned_b_sum: float,
    iters: int = 200,
    tol: float = 1e-10,
) -> Optional[List[float]]:
    """Solve the stationary system for the free tiers; None if c' ≤ 0 always
    (the bound cannot reach ε with any finite interval).

    Pure-scalar sweeps: the free set is at most M−1 ≈ 2 tiers, where numpy
    array dispatch costs more than the arithmetic itself — and this loop
    sits on the adaptive controller's warm re-solve path.
    """
    bs = [float(b[m]) for m in free]
    ds = [float(d[m]) for m in free]
    n = len(free)
    I = [2.0] * n
    for _ in range(iters):
        new = list(I)
        for i in range(n):
            a_eff = a + pinned_b_sum + sum(
                bs[j] / I[j] for j in range(n) if j != i
            )
            c_eff = c - kappa * sum(
                ds[j] * I[j] ** 2 for j in range(n) if j != i
            )
            if c_eff <= 0:
                return None
            if ds[i] <= 0:
                # tier has no G² mass: Θ' strictly decreases in I_m → unbounded;
                # cap at a large interval (aggregation is pure overhead here).
                new[i] = 1e6
                continue
            ka = 2.0 * kappa * ds[i] * a_eff
            kb = 3.0 * kappa * ds[i] * bs[i]
            kc = bs[i] * c_eff
            if kc <= 0:
                return None
            new[i] = _cubic_positive_root(ka, kb, kc)
        if max(abs(new[i] - I[i]) for i in range(n)) < tol * (
            1.0 + max(abs(x) for x in I)
        ):
            return new
        I = new
    return I


def _candidate_intervals(
    M: int,
    a: float,
    b: np.ndarray,
    c: float,
    kappa: float,
    d: np.ndarray,
    i_max: int,
) -> List[Tuple[int, ...]]:
    """Proposition-1 candidate set, in the exact enumeration order the
    scalar path historically evaluated (pinned subsets outer, rounding
    combos inner) — both backends pick argmins over this one list."""
    tiers = list(range(M - 1))
    out: List[Tuple[int, ...]] = []
    for pinned in itertools.chain.from_iterable(
        itertools.combinations(tiers, k) for k in range(M)
    ):
        free = [m for m in tiers if m not in pinned]
        base = {m: 1 for m in pinned}
        if not free:
            out.append(tuple(base[m] for m in tiers))
            continue
        pinned_b = float(sum(b[m] for m in pinned))
        root = _newton_jacobi(a, b, c, kappa, d, free, pinned_b)
        if root is None:
            continue
        # floor/ceil neighbourhood of the continuous stationary point
        cands_per = [
            sorted(
                {
                    min(max(int(math.floor(r)), 1), i_max),
                    min(max(int(math.ceil(r)), 1), i_max),
                }
            )
            for r in root
        ]
        for combo in itertools.product(*cands_per):
            iv = dict(base)
            iv.update({m: v for m, v in zip(free, combo)})
            out.append(tuple(iv[m] for m in tiers))
    return out


def _theta_candidates(
    problem: HsflProblem,
    mem_ok: bool,
    a: float,
    b: np.ndarray,
    c: float,
    kappa: float,
    d: np.ndarray,
    cand: np.ndarray,
    e_split: Optional[float] = None,
    e_agg: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Exact Θ'(I, μ) for ``[C, M-1]`` interval rows at one fixed cut —
    latency terms a/b priced once, accumulation order matching
    ``problem.numerator``/``denominator``/``theta`` bit-for-bit.

    ``e_split``/``e_agg`` (the fixed cut's split/agg round energies, from
    ``repro.energy``) mask candidates whose amortized E(I, μ) overruns
    the problem's energy budget; None skips the pricing entirely, and the
    D-floor ``problem.d_min()`` is 0.0 without a privacy budget — both
    checks are bit-identical no-ops when unconstrained (DESIGN.md §15).
    """
    C = cand.shape[0]
    if not mem_ok:
        return np.full(C, INFEASIBLE)
    M = problem.M
    acc = b[0] / cand[:, 0]
    for m in range(1, M - 1):
        acc = acc + b[m] / cand[:, m]
    num = a + acc
    s = np.zeros(C)
    for m in range(M - 1):
        I = cand[:, m]
        s = s + np.where(I > 1, (I * I) * d[m], 0.0)
    D = c - kappa * s
    th = np.full(C, INFEASIBLE)
    ok = D > problem.d_min()
    if e_split is not None:
        e_acc = e_agg[0] / cand[:, 0]
        for m in range(1, M - 1):
            e_acc = e_acc + e_agg[m] / cand[:, m]
        ok = ok & (e_split + e_acc <= problem.energy.budget_j_per_round)
    scale = 2.0 * problem.hyper.theta0 / problem.hyper.gamma
    th[ok] = scale * num[ok] / D[ok]
    return th


def _budget_grid(
    M: int,
    c: float,
    kappa: float,
    d: np.ndarray,
    d_min: float,
    i_max: int,
) -> List[Tuple[int, ...]]:
    """Interval grid over the D-feasible box, for budget-constrained MA.

    Proposition 1's candidate set is the *unconstrained* stationary
    neighbourhood; a binding energy budget pushes the optimum to the
    E(I) = budget boundary (larger I amortizes sync energy), which that
    set never contains.  But C1 bounds the search: D > d_min forces
    I_m < sqrt((c − d_min)/(κ d_m)), so the feasible region is a finite
    box — enumerate it densely (geometric tail past 128, or past 16 when
    M−1 ≥ 3, to keep the product bounded).  Only priced when a budget
    binds, so the unconstrained path never sees these rows.
    """
    dense = 128 if M <= 3 else 16
    per: List[List[int]] = []
    for m in range(M - 1):
        if kappa > 0 and d[m] > 0:
            cap = int(math.floor(math.sqrt(max(c - d_min, 0.0) / (kappa * float(d[m])))))
        else:
            cap = i_max
        cap = max(1, min(cap, i_max))
        vals = list(range(1, min(cap, dense) + 1))
        v = dense
        while v < cap:
            v = min(cap, int(v * 1.25) + 1)
            vals.append(v)
        per.append(vals)
    return [tuple(combo) for combo in itertools.product(*per)]


def _energy_terms(problem: HsflProblem, cuts: Sequence[int]):
    """(E_S, [E_{m,A}]) of the fixed cut when an energy *budget* binds;
    (None, None) otherwise — the vectorized pass then skips pricing."""
    en = problem.energy
    if en is None or en.budget_j_per_round is None:
        return None, None
    from ..energy import agg_energy, split_energy

    e_split = split_energy(
        problem.profile, problem.system, en, cuts, problem.compression
    )
    e_agg = np.array(
        [
            agg_energy(
                problem.profile, problem.system, en, cuts, m,
                problem.compression,
            )
            for m in range(problem.M - 1)
        ]
    )
    return e_split, e_agg


def solve_ma(
    problem: HsflProblem,
    cuts: Sequence[int],
    i_max: int = 10_000,
    backend: str = "auto",
) -> MaSolution:
    """Optimal MA intervals for fixed cuts (Proposition 1 + enumeration).

    ``backend="scalar"`` evaluates each candidate through
    ``problem.theta`` (re-pricing the latency terms per candidate — the
    oracle path); anything else evaluates all candidates in one
    vectorized pass.  Identical winner either way.
    """
    if backend != "scalar":
        from .batched import resolve_backend

        resolve_backend(backend)  # validate; MA's candidate set is small
        # enough that the vectorized pass below is numpy on every backend
    M = problem.M
    a = problem.split_T(cuts)
    b = problem.agg_T(cuts)  # [M-1]
    c, kappa = problem.constants()
    d = problem.tier_d(cuts)[: M - 1]
    cands = _candidate_intervals(M, a, b, c, kappa, d, i_max)
    e_split, e_agg = _energy_terms(problem, cuts)
    if e_split is not None:
        # budget-constrained optimum sits on the E(I) = budget boundary:
        # append the D-feasible integer box (both backends share the list)
        cands = cands + _budget_grid(M, c, kappa, d, problem.d_min(), i_max)

    best: Optional[MaSolution] = None
    if backend == "scalar":
        for intervals in cands:
            th = problem.theta(list(intervals) + [1], cuts)
            if th < (best.theta if best else INFEASIBLE):
                best = MaSolution(tuple(intervals) + (1,), th)
    elif cands:
        arr = np.asarray(cands, dtype=np.int64)
        th = _theta_candidates(
            problem, problem.memory_feasible(cuts), a, b, c, kappa, d, arr,
            e_split, e_agg,
        )
        i = int(np.argmin(th))  # first-tie, like the scalar strict-< scan
        if th[i] < INFEASIBLE:
            best = MaSolution(
                tuple(int(x) for x in arr[i]) + (1,), float(th[i])
            )

    if best is None:
        # No finite-interval schedule reaches ε: fall back to all-ones
        # (most frequent aggregation = tightest bound).
        ones = tuple([1] * (M - 1)) + (1,)
        return MaSolution(ones, problem.theta(list(ones), cuts))
    return best


def solve_ma_bruteforce(
    problem: HsflProblem, cuts: Sequence[int], i_max: int = 60
) -> MaSolution:
    """Exhaustive grid search (test oracle; exponential in M)."""
    M = problem.M
    best_iv, best_th = None, INFEASIBLE
    for combo in itertools.product(range(1, i_max + 1), repeat=M - 1):
        th = problem.theta(list(combo) + [1], cuts)
        if th < best_th:
            best_iv, best_th = tuple(combo) + (1,), th
    if best_iv is None:
        best_iv = tuple([1] * M)
        best_th = problem.theta(list(best_iv), cuts)
    return MaSolution(best_iv, best_th)
