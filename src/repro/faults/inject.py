"""Fault injection: compose a ``FaultSpec`` with a ``SystemTrace``.

``faulty_trace`` wraps a scenario trace so every ``round_state(r)`` carries
the round's realized faults *as ordinary RoundState fields* — crashed
clients drop out of ``available``, realized link retries scale the link
multipliers, a cell outage zeroes its fed-exchange contribution.  Because
both the discrete-event oracle (``sim.events``) and the vectorized fleet
path (``sim.fleet``) consume only ``round_state``, their fault-adjusted
round times stay bit-identical — the same contract the scenario library
already maintains, inherited for free.

``apply_corruption`` is the data-plane half: it transforms the corrupt
clients' rows of a client-stacked parameter pytree (the uploads the guard
in ``tiers.synchronize`` must catch).  Corruption never changes timing —
the bytes arrive on schedule, they are just wrong.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from ..sim.scenarios import RoundState, SystemTrace
from .spec import FaultSpec, RoundFaults, expand_faults


def faulty_round_state(
    spec: FaultSpec, state: RoundState, rf: RoundFaults
) -> RoundState:
    """One round's fault-adjusted fleet state.

    * crash: the client's upload never lands, so the server's round
      barrier excludes it — exactly ``available=False`` (the crash stage
      only determines how much work was wasted; nobody waits on it).
    * link retries: a traversal needing a attempts takes a× the time —
      every per-client link multiplier divides by the realized attempt
      count (the trace analogue of the expected-attempts pricing in
      ``core.latency``).
    * outage: a dead cell's fed exchange contributes nothing to the
      tier's aggregation barrier — its rate multiplier becomes +inf, so
      its λ/rate term is exactly 0.0 under IEEE division.
    """
    available = state.available
    if spec.crash_rate > 0.0:
        available = available & ~rf.crashed
        if not available.any():
            raise ValueError(
                "every client crashed this round — an all-faulty round has "
                "no defined latency or aggregate; lower crash_rate (or the "
                "scenario's churn) so at least one upload can land"
            )
    link_up = state.link_up_mult
    link_down = state.link_down_mult
    fed_up = state.fed_up_mult
    fed_down = state.fed_down_mult
    if spec.link_fail_rate > 0.0:
        inv = 1.0 / rf.attempts
        link_up = tuple(m * inv for m in link_up)
        link_down = tuple(m * inv for m in link_down)
        fed_up = tuple(
            m * inv if len(m) == len(inv) else m for m in fed_up
        )
        fed_down = tuple(
            m * inv if len(m) == len(inv) else m for m in fed_down
        )
    if rf.cell_out:
        mt = spec.outage_tier
        dead = np.asarray(spec.outage_cells, dtype=np.int64)
        up = fed_up[mt].copy()
        down = fed_down[mt].copy()
        up[dead] = np.inf
        down[dead] = np.inf
        fed_up = fed_up[:mt] + (up,) + fed_up[mt + 1 :]
        fed_down = fed_down[:mt] + (down,) + fed_down[mt + 1 :]
    return RoundState(
        available=available,
        compute_mult=state.compute_mult,
        link_up_mult=link_up,
        link_down_mult=link_down,
        fed_up_mult=fed_up,
        fed_down_mult=fed_down,
    )


def faulty_trace(trace: SystemTrace, spec: Optional[FaultSpec]) -> SystemTrace:
    """The trace with the spec's faults layered on every round.

    A null spec (zero rates, no outage) returns the *input trace object*
    unchanged — the zero-fault bit-exactness contract.  The wrapped trace
    keeps the base trace's name (suffixed), profile/system/compression and
    seed; fault draws come from the spec's own seeded streams, so the
    underlying scenario's randomness is untouched.
    """
    if spec is None or spec.is_null:
        return trace
    spec.validate_for(trace.system.M, trace.system.entities)
    N = trace.system.num_clients

    def gen(r: int) -> RoundState:
        return faulty_round_state(
            spec, trace.round_state(r), expand_faults(spec, r, N)
        )

    return SystemTrace(
        f"{trace.name}+faults",
        trace.profile,
        trace.system,
        trace.rounds,
        trace.seed,
        gen,
        trace.compression,
    )


# --------------------------------------------------------------------------- #
# data-plane corruption (what the guard must catch)
# --------------------------------------------------------------------------- #


def apply_corruption(params, corrupt: np.ndarray, spec: FaultSpec):
    """Corrupt the marked clients' rows of a client-stacked pytree.

    Only leaves with a leading client axis (shape[0] == len(corrupt)) are
    touched; scalar bookkeeping leaves pass through.  Returns a new pytree
    (applied between the local update and the guarded sync by the fault-
    aware training loop; never inside the jitted step).
    """
    import jax
    import jax.numpy as jnp

    if not corrupt.any():
        return params
    n = len(corrupt)
    mask = jnp.asarray(corrupt)

    def hit(x):
        if x.ndim == 0 or x.shape[0] != n:
            return x
        m = mask.reshape((n,) + (1,) * (x.ndim - 1))
        if spec.corrupt_mode == "nan":
            return jnp.where(m, jnp.nan, x)
        if spec.corrupt_mode == "inf":
            return jnp.where(m, jnp.inf, x)
        if spec.corrupt_mode == "scale":
            return jnp.where(m, x * spec.corrupt_scale, x)
        # bitflip: XOR an exponent bit of the float32 representation —
        # values blow up (or collapse) by ~2^64, the classic DRAM flip
        if x.dtype != jnp.float32:
            return x
        bits = jax.lax.bitcast_convert_type(x, jnp.int32)
        flipped = jax.lax.bitcast_convert_type(
            bits ^ jnp.int32(0x40000000), jnp.float32
        )
        return jnp.where(m, flipped, x)

    return jax.tree.map(hit, params)
