"""Neural-net primitives shared by every architecture in the zoo.

Pure functions over parameter dicts (no module framework — the HSFL engine
needs to slice/stack/aggregate raw pytrees). All initializers take an explicit
PRNG key. Shapes follow [batch, seq, ...] row-major conventions.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .spec import ModelSpec

Params = Dict[str, Any]

# --------------------------------------------------------------------------- #
# basics
# --------------------------------------------------------------------------- #


def _dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    s = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape) * s).astype(dtype)


def rms_norm(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * lax.rsqrt(var + eps)
    return (out * (1.0 + w.astype(jnp.float32))).astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: [..., S, H, hd]; positions: [..., S]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(ang)[..., :, None, :]  # [..., S, 1, half]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def cross_entropy(logits: jax.Array, labels: jax.Array, mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token cross-entropy. logits [..., V], labels [...] int."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - ll
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


# --------------------------------------------------------------------------- #
# attention (GQA + rope + optional qk-norm / bias / sliding window / prefix)
# --------------------------------------------------------------------------- #


def init_attention(key, spec: ModelSpec, cross: bool = False) -> Params:
    d, hd = spec.d_model, spec.hd
    h, k = spec.num_heads, spec.num_kv_heads
    ks = jax.random.split(key, 8)
    p: Params = {
        "wq": _dense_init(ks[0], (d, h * hd), spec.pdtype),
        "wk": _dense_init(ks[1], (d, k * hd), spec.pdtype),
        "wv": _dense_init(ks[2], (d, k * hd), spec.pdtype),
        "wo": _dense_init(ks[3], (h * hd, d), spec.pdtype),
        "norm": jnp.zeros((d,), spec.pdtype),
    }
    if spec.qkv_bias and not cross:
        p["bq"] = jnp.zeros((h * hd,), spec.pdtype)
        p["bk"] = jnp.zeros((k * hd,), spec.pdtype)
        p["bv"] = jnp.zeros((k * hd,), spec.pdtype)
    if spec.qk_norm and not cross:
        p["q_norm"] = jnp.zeros((hd,), spec.pdtype)
        p["k_norm"] = jnp.zeros((hd,), spec.pdtype)
    return p


def _mask_bias(
    q_pos: jax.Array,  # [Sq]
    k_pos: jax.Array,  # [Sk]
    causal: bool,
    window: int,
    prefix_len: int,
    k_valid: Optional[jax.Array] = None,  # [B, Sk] bool (cache validity)
) -> jax.Array:
    """Additive mask [.., Sq, Sk] (broadcastable), 0 allowed / -inf blocked."""
    qp = q_pos[:, None]
    kp = k_pos[None, :]
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        ok = kp <= qp
        if prefix_len > 0:
            # prefix-LM: everything attends to the full (bidirectional) prefix
            ok = ok | (kp < prefix_len)
    if window > 0:
        ok = ok & (kp > qp - window)
    # negative positions mark padding / unfilled cache slots
    ok = ok & (kp >= 0)
    bias = jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)
    if k_valid is not None:
        bias = bias[None] + jnp.where(k_valid, 0.0, -jnp.inf)[:, None, :]
    return bias


def _sdpa(q, k, v, bias):
    """q [B,Sq,H,hd]; k,v [B,Sk,K,hd]; bias broadcastable to [B,H,Sq,Sk]."""
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    q = q.reshape(B, Sq, K, G, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q, k).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    if bias.ndim == 2:  # [Sq, Sk]
        b = bias[None, None, None]
    else:  # [B, Sq, Sk]
        b = bias[:, None, None]
    scores = scores + b
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v)
    return out.reshape(B, Sq, H, hd)


def _blockwise_sdpa(q, k, v, q_pos, k_pos, causal, window, prefix_len,
                    block_q: int = 512, block_k: int = 1024):
    """O(S) memory attention: lax.scan over q blocks, inner scan over kv
    blocks with online softmax. Used when Sq*Sk would be too large.
    For windowed attention, each q block gathers only its kv window slice."""
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    Sk = k.shape[1]
    orig_Sq = Sq
    pad_q = (-Sq) % block_q
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, pad_q), constant_values=-1)
        Sq = q.shape[1]
    nq = Sq // block_q
    qb = q.reshape(B, nq, block_q, H, hd)
    qpb = q_pos.reshape(nq, block_q)

    scale = 1.0 / math.sqrt(hd)

    if window > 0 and prefix_len == 0:
        # windowed path: gather [block_q + window] kv slice per q block
        span = block_q + window
        pad_k = window
        kp = jnp.pad(k, ((0, 0), (pad_k, 0), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (pad_k, 0), (0, 0), (0, 0)))
        kpp = jnp.pad(k_pos, (pad_k, 0), constant_values=-(10**9))

        def per_qblock(i, qi, qpi):
            start = i * block_q  # offset in padded k == qstart - window + pad_k
            ks = lax.dynamic_slice_in_dim(kp, start, span, axis=1)
            vs = lax.dynamic_slice_in_dim(vp, start, span, axis=1)
            kps = lax.dynamic_slice_in_dim(kpp, start, span, axis=0)
            bias = _mask_bias(qpi, kps, causal, window, 0)
            return _sdpa(qi, ks, vs, bias)

        outs = []
        for i in range(nq):
            outs.append(per_qblock(i, qb[:, i], qpb[i]))
        out = jnp.stack(outs, axis=1).reshape(B, Sq, H, hd)
        return out[:, :orig_Sq]

    pad_k2 = (-Sk) % block_k
    if pad_k2:
        k = jnp.pad(k, ((0, 0), (0, pad_k2), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k2), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad_k2), constant_values=-(10**9))
    nk = k.shape[1] // block_k
    kb = k.reshape(B, nk, block_k, K, hd)
    vb = v.reshape(B, nk, block_k, K, hd)
    kpb = k_pos.reshape(nk, block_k)

    def q_step(_, qi_qpi):
        qi, qpi = qi_qpi  # [B, bq, H, hd], [bq]
        qg = qi.reshape(B, block_q, K, G, hd)

        def kv_step(carry, kv):
            m, l, acc = carry
            ki, vi, kpi = kv
            s = jnp.einsum("bqkgh,bskh->bkgqs", qg, ki).astype(jnp.float32) * scale
            bias = _mask_bias(qpi, kpi, causal, window, prefix_len)
            s = s + bias[None, None, None]
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", p.astype(vi.dtype), vi
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, K, G, block_q), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, K, G, block_q), jnp.float32)
        a0 = jnp.zeros((B, K, G, block_q, hd), jnp.float32)
        (m, l, acc), _ = lax.scan(
            kv_step, (m0, l0, a0),
            (kb.transpose(1, 0, 2, 3, 4), vb.transpose(1, 0, 2, 3, 4), kpb),
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        out = out.transpose(0, 3, 1, 2, 4).reshape(B, block_q, K * G, hd)
        return None, out.astype(qi.dtype)

    _, outs = lax.scan(
        q_step, None, (qb.transpose(1, 0, 2, 3, 4), qpb)
    )  # [nq, B, bq, H, hd]
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, hd)
    return out[:, :orig_Sq]


BLOCKWISE_THRESHOLD = 4096  # Sq*Sk above (threshold)^2 -> O(S)-memory path


def attention(
    params: Params,
    x: jax.Array,  # [B, S, d]
    spec: ModelSpec,
    *,
    positions: Optional[jax.Array] = None,  # [S]
    causal: bool = True,
    prefix_len: int = 0,
    cache: Optional[Params] = None,  # {"k","v": [B,C,K,hd], "index": scalar}
    kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,  # cross-attn
    use_rope: bool = True,
) -> Tuple[jax.Array, Optional[Params]]:
    """Full GQA attention sub-layer (pre-norm + residual handled by caller)."""
    B, S, d = x.shape
    h, k_heads, hd = spec.num_heads, spec.num_kv_heads, spec.hd
    if positions is None:
        positions = jnp.arange(S)

    q = x @ params["wq"]
    if "bq" in params:
        q = q + params["bq"]
    q = q.reshape(B, S, h, hd)

    if kv_override is not None:
        k, v = kv_override  # precomputed (cross attention / enc out)
        new_cache = cache
        k_pos = jnp.arange(k.shape[1])
        bias = jnp.zeros((S, k.shape[1]), jnp.float32)
        if spec.qk_norm and "q_norm" in params:
            q = rms_norm(q, params["q_norm"], spec.norm_eps)
        out = _sdpa(q, k, v, bias)
        return out.reshape(B, S, h * hd) @ params["wo"], new_cache

    kx = x @ params["wk"]
    vx = x @ params["wv"]
    if "bk" in params:
        kx = kx + params["bk"]
        vx = vx + params["bv"]
    kx = kx.reshape(B, S, k_heads, hd)
    vx = vx.reshape(B, S, k_heads, hd)

    if spec.qk_norm and "q_norm" in params:
        q = rms_norm(q, params["q_norm"], spec.norm_eps)
        kx = rms_norm(kx, params["k_norm"], spec.norm_eps)
    if use_rope:
        q = rope(q, positions, spec.rope_theta)
        kx = rope(kx, positions, spec.rope_theta)

    new_cache = None
    if cache is not None:
        C = cache["k"].shape[1]
        idx = cache["index"]  # scalar int32: absolute position of this token
        if spec.window and spec.window < C:
            slot = idx % spec.window
        else:
            slot = idx
        ck = cache["k"].at[:, slot].set(kx[:, 0])
        cv = cache["v"].at[:, slot].set(vx[:, 0])
        new_cache = {"k": ck, "v": cv, "index": idx + 1}
        cache_pos = cache["positions"].at[slot].set(idx)
        new_cache["positions"] = cache_pos
        k_valid = (cache_pos >= 0)[None, :]
        k_valid = jnp.broadcast_to(k_valid, (B, C))
        bias = _mask_bias(
            positions, cache_pos, causal, spec.window, prefix_len, k_valid
        )
        out = _sdpa(q, ck, cv, bias)
        return out.reshape(B, S, h * hd) @ params["wo"], new_cache

    if S > BLOCKWISE_THRESHOLD:
        out = _blockwise_sdpa(
            q, kx, vx, positions, positions, causal, spec.window, prefix_len
        )
    else:
        bias = _mask_bias(positions, positions, causal, spec.window, prefix_len)
        out = _sdpa(q, kx, vx, bias)
    return out.reshape(B, S, h * hd) @ params["wo"], new_cache


def init_attn_cache(spec: ModelSpec, batch: int, cache_len: int) -> Params:
    C = min(cache_len, spec.window) if spec.window else cache_len
    return {
        "k": jnp.zeros((batch, C, spec.num_kv_heads, spec.hd), spec.cdtype),
        "v": jnp.zeros((batch, C, spec.num_kv_heads, spec.hd), spec.cdtype),
        "positions": jnp.full((C,), -1, jnp.int32),
        "index": jnp.zeros((), jnp.int32),
    }


# --------------------------------------------------------------------------- #
# MLP (SwiGLU) and MoE
# --------------------------------------------------------------------------- #


def init_mlp(key, spec: ModelSpec, d_ff: Optional[int] = None, gelu: bool = False) -> Params:
    d = spec.d_model
    ff = d_ff or spec.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "w1": _dense_init(ks[0], (d, ff), spec.pdtype),
        "w2": _dense_init(ks[1], (ff, d), spec.pdtype),
        "norm": jnp.zeros((d,), spec.pdtype),
    }
    if not gelu:
        p["w3"] = _dense_init(ks[2], (d, ff), spec.pdtype)
    return p


def mlp(params: Params, x: jax.Array) -> jax.Array:
    if "w3" in params:
        return (jax.nn.silu(x @ params["w1"]) * (x @ params["w3"])) @ params["w2"]
    return jax.nn.gelu(x @ params["w1"]) @ params["w2"]


def init_moe(key, spec: ModelSpec) -> Params:
    d, ff = spec.d_model, spec.d_ff
    E = spec.moe.num_experts
    ks = jax.random.split(key, 4)
    return {
        "router": _dense_init(ks[0], (d, E), spec.pdtype, scale=0.02),
        "w1": _dense_init(ks[1], (E, d, ff), spec.pdtype),
        "w3": _dense_init(ks[2], (E, d, ff), spec.pdtype),
        "w2": _dense_init(ks[3], (E, ff, d), spec.pdtype),
        "norm": jnp.zeros((d,), spec.pdtype),
    }


def moe(params: Params, x: jax.Array, spec: ModelSpec,
        constraint=None, groups: int = 1) -> Tuple[jax.Array, jax.Array]:
    """Scatter-based top-k MoE with capacity (scales to long sequences).

    ``groups`` (perf, EXPERIMENTS.md sect. Perf / granite-prefill): with
    groups=1 the dispatch scatter spans the *global* token range, so GSPMD
    cannot prove it local — it all-gathers every token to every device and
    replicates the dispatch + expert compute. With groups=G the tokens are
    reshaped [G, T/G] with per-group capacity (GShard-style grouping) and
    the scatter becomes a batched scatter whose group dim shards over
    `data`; expert compute then shards over (data, model) with no token
    all-gather. Capacity semantics change from global to per-group — the
    standard GShard trade (slightly more drops under skew).

    ``constraint``: optional hook applied to the dispatch buffer and expert
    outputs to pin the sharding GSPMD should use.

    Returns (output, aux_load_balance_loss)."""
    ms = spec.moe
    B, S, d = x.shape
    T = B * S
    E, K = ms.num_experts, ms.top_k
    G = groups if T % groups == 0 else 1
    Tg = T // G
    xg = x.reshape(G, Tg, d)
    logits = (xg @ params["router"]).astype(jnp.float32)  # [G, Tg, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = lax.top_k(probs, K)  # [G, Tg, K]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    cap = int(max(1, math.ceil(Tg * K / E * ms.capacity_factor)))
    # position of each (token, k) within its expert, via cumsum of one-hots
    oh = jax.nn.one_hot(expert_ids, E, dtype=jnp.int32)  # [G, Tg, K, E]
    oh_flat = oh.reshape(G, Tg * K, E)
    pos = jnp.cumsum(oh_flat, axis=1) - oh_flat  # rank within expert (per group)
    pos = jnp.sum(pos * oh_flat, axis=-1).reshape(G, Tg, K)
    keep = pos < cap
    slot = jnp.where(keep, pos, cap)  # overflow -> spill slot `cap`

    # dispatch: per-group buffer [G, E, cap+1, d]; the scatter batches over
    # the group dim, so it shards over `data` instead of forcing a global
    # token all-gather (see docstring).
    buf = jnp.zeros((G, E, cap + 1, d), x.dtype)
    eid = expert_ids.reshape(G, -1)
    sid = slot.reshape(G, -1)
    xrep = jnp.repeat(xg, K, axis=1)  # [G, Tg*K, d]
    buf = jax.vmap(lambda b, e, s, u: b.at[e, s].set(u, mode="drop"))(
        buf, eid, sid, xrep
    )
    ein = buf[:, :, :cap]  # [G, E, cap, d]
    if constraint is not None:
        ein = constraint(ein)

    h = jnp.einsum("gecd,edf->gecf", ein, params["w1"])
    g = jnp.einsum("gecd,edf->gecf", ein, params["w3"])
    h = jax.nn.silu(h) * g
    eout = jnp.einsum("gecf,efd->gecd", h, params["w2"])  # [G, E, cap, d]
    if constraint is not None:
        eout = constraint(eout)

    if G > 1:
        # combine via scatter-add in *expert space*: each model rank adds its
        # local experts' gate-weighted rows into a per-group [Tg, d] partial,
        # so the cross-rank reduction is over [Tg, d] instead of the 8x
        # larger pre-combine [Tg, K, d] gather output (EXPERIMENTS.md
        # sect. Perf / granite-prefill iteration 2).
        gbuf = jnp.zeros((G, E, cap + 1), jnp.float32)
        gbuf = jax.vmap(lambda b, e, s, u: b.at[e, s].set(u, mode="drop"))(
            gbuf, eid, sid, gate_vals.reshape(G, -1)
        )
        tbuf = jnp.full((G, E, cap + 1), Tg, jnp.int32)  # spill -> drop row
        tok_ids = jnp.broadcast_to(
            jnp.arange(Tg)[:, None], (Tg, K)
        ).reshape(1, -1)
        tbuf = jax.vmap(lambda b, e, s, u: b.at[e, s].set(u, mode="drop"))(
            tbuf, eid, sid, jnp.broadcast_to(tok_ids, (G, Tg * K))
        )
        weighted = eout * gbuf[:, :, :cap, None].astype(eout.dtype)
        out = jnp.zeros((G, Tg + 1, d), x.dtype)
        out = jax.vmap(lambda o, t, w: o.at[t].add(w, mode="drop"))(
            out, tbuf[:, :, :cap].reshape(G, -1),
            weighted.reshape(G, E * cap, d),
        )
        out = out[:, :Tg]
    else:
        # combine: gather each (token, k) slot back, per group
        eout_p = jnp.pad(eout, ((0, 0), (0, 0), (0, 1), (0, 0)))  # spill -> 0
        got = jax.vmap(lambda eo, e, s: eo[e, s])(eout_p, eid, sid)
        got = got.reshape(G, Tg, K, d)
        out = jnp.sum(got * gate_vals[..., None].astype(got.dtype), axis=2)

    # aux loss (Switch-style load balancing), per dispatch group then
    # averaged — with groups == co-located clients this makes the pooled
    # (split-placement) execution equal the per-client one by construction.
    me = jnp.mean(probs, axis=1)  # [G, E]
    ce = jnp.mean(
        jax.nn.one_hot(expert_ids[..., 0], E, dtype=jnp.float32), axis=1
    )
    aux = E * jnp.mean(jnp.sum(me * ce, axis=-1))
    return out.reshape(B, S, d), aux


# --------------------------------------------------------------------------- #
# Mamba2 (SSD — state space duality, arXiv:2405.21060)
# --------------------------------------------------------------------------- #


def init_mamba(key, spec: ModelSpec) -> Params:
    ss = spec.ssm
    d = spec.d_model
    di = ss.expand * d
    nh = di // ss.head_dim
    n = ss.state_dim
    ks = jax.random.split(key, 5)
    in_dim = 2 * di + 2 * n + nh  # z, x, B, C, dt
    return {
        "in_proj": _dense_init(ks[0], (d, in_dim), spec.pdtype),
        "conv_w": _dense_init(ks[1], (ss.conv_width, di), spec.pdtype, scale=0.5),
        "A_log": jnp.log(
            jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)
        ).astype(spec.pdtype),
        "D": jnp.ones((nh,), spec.pdtype),
        "dt_bias": jnp.zeros((nh,), spec.pdtype),
        "gate_norm": jnp.zeros((di,), spec.pdtype),
        "out_proj": _dense_init(ks[2], (di, d), spec.pdtype),
        "norm": jnp.zeros((d,), spec.pdtype),
    }


def _segsum(x: jax.Array) -> jax.Array:
    """x [..., T] -> lower-triangular cumulative segment sums [..., T, T]."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    tril = jnp.tril(jnp.ones((T, T), bool), 0)
    return jnp.where(tril, diff, -jnp.inf)


def ssd_scan(
    x: jax.Array,  # [B, S, H, P] (already dt-discretized input)
    A: jax.Array,  # [B, S, H]    (dt * A, negative)
    Bm: jax.Array,  # [B, S, N]
    Cm: jax.Array,  # [B, S, N]
    chunk: int,
    init_state: Optional[jax.Array] = None,  # [B, H, P, N]
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD (dual form). Returns (y [B,S,H,P], final_state [B,H,P,N])."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        A = jnp.pad(A, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = x.shape[1]
    nc = Sp // chunk
    xc = x.reshape(B, nc, chunk, H, P)
    Ac = A.reshape(B, nc, chunk, H).transpose(0, 3, 1, 2)  # [B,H,nc,l]
    Bc = Bm.reshape(B, nc, chunk, N)
    Cc = Cm.reshape(B, nc, chunk, N)

    A_cumsum = jnp.cumsum(Ac, axis=-1)  # [B,H,nc,l]
    L = jnp.exp(_segsum(Ac))  # [B,H,nc,l,l]
    # 1. intra-chunk (diagonal block) outputs
    Y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp", Cc, Bc, L, xc)
    # 2. chunk-final states
    decay_states = jnp.exp(A_cumsum[..., -1:] - A_cumsum)  # [B,H,nc,l]
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn", Bc, decay_states, xc)
    # 3. inter-chunk recurrence
    if init_state is None:
        init_state = jnp.zeros((B, H, P, N), states.dtype)
    states = jnp.concatenate([init_state[:, None], states], axis=1)
    chunk_decay = A_cumsum[..., -1]  # [B,H,nc]
    dec_pad = jnp.pad(chunk_decay, ((0, 0), (0, 0), (1, 0)))
    decay_chunk = jnp.exp(_segsum(dec_pad))  # [B,H,nc+1,nc+1]
    new_states = jnp.einsum("bhzc,bchpn->bzhpn", decay_chunk, states)
    states_in = new_states[:, :-1]  # state entering each chunk
    final_state = new_states[:, -1]
    # 4. state -> output contribution
    state_decay_out = jnp.exp(A_cumsum)  # [B,H,nc,l]
    Y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", Cc, states_in, state_decay_out)
    Y = (Y_diag + Y_off).reshape(B, Sp, H, P)
    return Y[:, :S], final_state


def mamba_block(
    params: Params,
    x: jax.Array,  # [B, S, d]
    spec: ModelSpec,
    cache: Optional[Params] = None,  # {"conv": [B,W-1,di], "state": [B,H,P,N]}
) -> Tuple[jax.Array, Optional[Params]]:
    ss = spec.ssm
    d = spec.d_model
    di = ss.expand * d
    nh = di // ss.head_dim
    n = ss.state_dim
    B, S, _ = x.shape

    zxbcdt = x @ params["in_proj"]
    z, xs, Bm, Cm, dt = jnp.split(zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))  # [B,S,H]
    A = -jnp.exp(params["A_log"].astype(jnp.float32))  # [H]

    new_cache = None
    if cache is None:
        # causal depthwise conv over xs
        W = ss.conv_width
        xpad = jnp.pad(xs, ((0, 0), (W - 1, 0), (0, 0)))
        xconv = sum(
            xpad[:, i : i + S] * params["conv_w"][i] for i in range(W)
        )
        xconv = jax.nn.silu(xconv)
        xh = xconv.reshape(B, S, nh, ss.head_dim)
        x_dt = xh * dt[..., None].astype(xh.dtype)
        Adt = dt * A  # [B,S,H]
        y, _ = ssd_scan(x_dt, Adt, Bm, Cm, ss.chunk)
        y = y + xh * params["D"].astype(xh.dtype)[None, None, :, None]
    else:
        W = ss.conv_width
        conv_st = cache["conv"]  # [B, W-1, di]
        xcat = jnp.concatenate([conv_st, xs], axis=1)  # [B, W, di] (S==1)
        xconv = sum(xcat[:, i : i + 1] * params["conv_w"][i] for i in range(W))
        xconv = jax.nn.silu(xconv)
        xh = xconv.reshape(B, 1, nh, ss.head_dim)
        dA = jnp.exp(dt[:, 0] * A)  # [B,H]
        st = cache["state"]  # [B,H,P,N]
        inp = (xh[:, 0] * dt[:, 0, :, None]).astype(jnp.float32)  # [B,H,P]
        st = st * dA[..., None, None] + inp[..., None] * Bm[:, 0, None, None, :].astype(jnp.float32)
        y0 = jnp.einsum("bhpn,bn->bhp", st, Cm[:, 0].astype(jnp.float32))
        y = (y0[:, None] + xh * params["D"][None, None, :, None]).astype(xs.dtype)
        new_cache = {"conv": xcat[:, 1:], "state": st}

    y = y.reshape(B, S, di)
    y = rms_norm(y * jax.nn.silu(z), params["gate_norm"], spec.norm_eps)
    return y @ params["out_proj"], new_cache


def init_mamba_cache(spec: ModelSpec, batch: int) -> Params:
    ss = spec.ssm
    di = ss.expand * spec.d_model
    nh = di // ss.head_dim
    return {
        "conv": jnp.zeros((batch, ss.conv_width - 1, di), spec.cdtype),
        "state": jnp.zeros((batch, nh, ss.head_dim, ss.state_dim), jnp.float32),
    }
