from .npz import check_schedule_meta, load_checkpoint, save_checkpoint
