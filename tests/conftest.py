import os
import sys

# allow `pytest tests/` from the repo root without installing the package
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def pytest_addoption(parser):
    parser.addoption(
        "--durations-budget",
        type=float,
        default=None,
        metavar="SECONDS",
        help="fail the session if any test not marked 'slow' spends more "
        "than SECONDS in its call phase (CI keeps the fast suite fast: "
        "long-running tests must be marked slow and ride the nightly job)",
    )


# (nodeid, seconds) for every non-slow call phase; compared against the
# budget at session end so one report lists every offender, not just the
# first.
_CALL_DURATIONS = []


def pytest_runtest_logreport(report):
    if report.when == "call" and "slow" not in report.keywords:
        _CALL_DURATIONS.append((report.nodeid, report.duration))


def pytest_sessionfinish(session, exitstatus):
    budget = session.config.getoption("--durations-budget")
    if budget is None:
        return
    offenders = sorted(
        ((nid, sec) for nid, sec in _CALL_DURATIONS if sec > budget),
        key=lambda kv: -kv[1],
    )
    if not offenders:
        return
    tr = session.config.pluginmanager.get_plugin("terminalreporter")
    lines = [
        f"duration budget exceeded ({budget:.1f}s per non-slow test):"
    ] + [f"  {sec:8.2f}s  {nid}" for nid, sec in offenders] + [
        "mark these @pytest.mark.slow (nightly job) or speed them up"
    ]
    msg = "\n".join(lines)
    if tr is not None:
        tr.write_line(msg, red=True)
    else:
        print(msg, file=sys.stderr)
    session.exitstatus = max(int(exitstatus), 1)
