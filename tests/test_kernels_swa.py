"""swa_attention Pallas kernel vs pure-jnp oracle: fwd + custom-vjp bwd,
swept over shapes, windows, GQA ratios, head-dim padding and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.swa_attention import swa_attention, swa_attention_ref


def rand_qkv(key, B, S, H, K, hd, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    return (
        jax.random.normal(ks[0], (B, S, H, hd), dtype),
        jax.random.normal(ks[1], (B, S, K, hd), dtype),
        jax.random.normal(ks[2], (B, S, K, hd), dtype),
    )


CASES = [
    # B, S, H, K, hd, window
    (1, 256, 4, 2, 64, 128),      # GQA + window
    (2, 384, 4, 4, 128, 256),     # MHA + window, aligned hd
    (1, 512, 8, 2, 80, 0),        # full causal, hd padding (80 -> 128)
    (1, 300, 4, 1, 64, 128),      # MQA + seq padding (300 -> 384)
    (1, 256, 6, 3, 96, 128),      # 2:1 GQA, hd pad
    (1, 640, 4, 2, 64, 512),      # window > half of seq
]


@pytest.mark.parametrize("case", CASES, ids=[str(c) for c in CASES])
def test_forward_matches_ref(case):
    B, S, H, K, hd, W = case
    q, k, v = rand_qkv(jax.random.PRNGKey(sum(case)), B, S, H, K, hd)
    out = swa_attention(q, k, v, window=W)
    ref = swa_attention_ref(q, k, v, W)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("case", CASES[:4], ids=[str(c) for c in CASES[:4]])
def test_backward_matches_ref(case):
    B, S, H, K, hd, W = case
    key = jax.random.PRNGKey(sum(case) + 1)
    q, k, v = rand_qkv(key, B, S, H, K, hd)
    dd = jax.random.normal(jax.random.fold_in(key, 9), q.shape)
    g1 = jax.grad(lambda *a: jnp.sum(swa_attention(*a, window=W) * dd), (0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda *a: jnp.sum(swa_attention_ref(*a, W) * dd), (0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        scale = np.max(np.abs(np.asarray(b))) + 1e-9
        np.testing.assert_allclose(
            np.asarray(a) / scale, np.asarray(b) / scale, atol=2e-5
        )


def test_bfloat16_forward():
    B, S, H, K, hd, W = 1, 256, 4, 2, 64, 128
    q, k, v = rand_qkv(jax.random.PRNGKey(7), B, S, H, K, hd, jnp.bfloat16)
    out = swa_attention(q, k, v, window=W)
    ref = swa_attention_ref(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32), W
    )
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=3e-2
    )


def test_window_equals_full_when_large():
    B, S, H, K, hd = 1, 256, 4, 2, 64
    q, k, v = rand_qkv(jax.random.PRNGKey(8), B, S, H, K, hd)
    np.testing.assert_allclose(
        swa_attention(q, k, v, window=512),  # window >= S -> full causal
        swa_attention(q, k, v, window=0),
        rtol=1e-6,
    )


def test_matches_model_layer_semantics():
    """Kernel == the model zoo's windowed attention path (mask conventions)."""
    import math

    from repro.models import layers as L
    from repro.configs import get_reduced

    spec = get_reduced("qwen2-1.5b").with_window(128)
    B, S = 1, 256
    hd, H, K = spec.hd, spec.num_heads, spec.num_kv_heads
    key = jax.random.PRNGKey(9)
    q, k, v = rand_qkv(key, B, S, H, K, hd)
    bias = L._mask_bias(jnp.arange(S), jnp.arange(S), True, 128, 0)
    ref = L._sdpa(q, k, v, bias)
    out = swa_attention(q, k, v, window=128)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)
