"""Straggler-aware partial participation: deadline masks from fleet traces.

The paper's Algorithm 1 waits for every client every round, but any real
multi-tier fleet (DESIGN.md §8) spreads per-round client latencies over
orders of magnitude — a production deployment closes the round at a
*deadline* and drops the stragglers.  This module is the bridge from the
fleet simulator's sampled per-round latencies into everything downstream
(DESIGN.md §12):

* :func:`participation_masks` — replay a ``SystemTrace`` at a cut vector
  and a deadline into per-round boolean client masks (the masks the
  engines consume via ``build_train_step_a/b(with_mask=True)``), per-round
  capped round times, and per-tier participation rates q_m;
* :func:`deadline_for_rate` — invert the policy: the deadline whose pooled
  per-client finish-time quantile hits a target participation rate;
* :func:`estimate_participation` — package the rates as the analytic
  ``ParticipationSpec`` the Theorem-1 bound inflates by 1/q;
* :class:`DeadlineLatency` — a ``LatencyModel`` pricing T_S as the trace
  *expectation* of the deadline-capped round time (a deadline converts the
  straggler max into E[min(deadline, max)]), with whole-lattice batch
  methods for the batched solver core;
* :func:`participation_problem` — compose both sides onto an
  ``HsflProblem`` so BCD/MA/MS trade a tighter deadline (cheaper expected
  rounds) against the 1/q-inflated bound (more rounds to ε).

Conventions (pinned by ``tests/test_participation.py``):

* a zero-**available** round prices split = 0 (nothing runs — the
  events/fleet/lattice convention);
* the server cannot close a round with zero uploads: when every available
  client would miss the barrier, the effective deadline extends to the
  fastest available client's finish — ``d_eff = max(deadline, min finish)``
  — so each round with available clients keeps ≥ 1 participant (the mask
  analogue of the scenario library's ``_ensure_someone``, and what stops a
  solver from "optimizing" into a cut whose rounds are cheap only because
  nobody survives them);
* masks are per-(round, cut): finish times depend on the cut vector, so a
  client can make the deadline under one split and miss it under another;
* a zero-participant *group* (entity) during aggregation keeps its last
  synced params (``tiers.synchronize`` mask semantics).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from ..core.convergence import ParticipationSpec
from ..core.latency import aggregation_phases
from ..core.problem import HsflProblem
from .events import round_stage_durations
from .fleet import simulate_lattice_rounds
from .scenarios import SystemTrace


@dataclass(frozen=True)
class ParticipationResult:
    """One trace replay under a deadline, at one cut vector."""

    masks: np.ndarray        # [R, N] bool — available AND finished by deadline
    round_time: np.ndarray   # [R] min(deadline, max over available finish)
    rates: np.ndarray        # [R] participating fraction of the fleet
    q_tier: np.ndarray       # [M] mean per-tier entity participation rates
    deadline: float
    cuts: Tuple[int, ...]

    @property
    def q(self) -> float:
        """Mean client participation rate (== q_tier[0])."""
        return float(self.q_tier[0])

    def spec(self) -> ParticipationSpec:
        """The analytic view the Theorem-1 bound consumes."""
        return ParticipationSpec(
            q=tuple(float(v) for v in self.q_tier), deadline=self.deadline
        )


def _tier_entity_rates(mask: np.ndarray, entities: Sequence[int]) -> np.ndarray:
    """[M] fraction of tier-m entities with ≥1 participating client.

    Entity groups are the contiguous client blocks of ``TierPlan``/
    ``tiers.synchronize``; tier 1's entities are the clients themselves,
    so the first entry is the plain client participation rate.
    """
    N = mask.shape[0]
    return np.array(
        [mask.reshape(J, N // J).any(axis=1).mean() for J in entities]
    )


def per_client_finish_times(
    trace: SystemTrace, r: int, cuts: Sequence[int]
) -> np.ndarray:
    """[N] round-r chain finish times, accumulated in canonical stage order
    (the ``events.round_stage_durations`` arrays — identical bits to both
    sim paths; absent clients still get a hypothetical time, the caller
    masks with ``round_state(r).available``)."""
    _, durs = round_stage_durations(trace, r, cuts)
    t = np.zeros(trace.system.num_clients)
    for d in durs:
        t = t + d
    return t


def participation_masks(
    trace: SystemTrace,
    cuts: Sequence[int],
    deadline: float,
    rounds: Optional[int] = None,
) -> ParticipationResult:
    """Replay the trace at ``cuts`` under ``deadline`` into per-round masks.

    A client participates in round r iff it is available and its canonical
    stage chain finishes by the round's effective deadline
    ``d_eff = max(deadline, fastest available finish)`` — the barrier
    extends until at least one upload lands (module conventions).  Round
    time is the d_eff-capped straggler max over *available* clients (0 for
    a zero-available round).
    """
    if deadline <= 0:
        raise ValueError(f"deadline must be positive: {deadline}")
    R = trace.rounds if rounds is None else min(rounds, trace.rounds)
    system = trace.system
    N, M = system.num_clients, system.M
    cuts = tuple(int(c) for c in cuts)

    masks = np.zeros((R, N), dtype=bool)
    round_time = np.zeros(R)
    q_rounds = np.zeros((R, M))
    for r in range(R):
        avail = trace.round_state(r).available
        t = per_client_finish_times(trace, r, cuts)
        if avail.any():
            d_eff = max(deadline, float(t[avail].min()))
            masks[r] = avail & (t <= d_eff)
            round_time[r] = min(d_eff, float(t[avail].max()))
        q_rounds[r] = _tier_entity_rates(masks[r], system.entities)
    return ParticipationResult(
        masks=masks,
        round_time=round_time,
        rates=masks.mean(axis=1),
        q_tier=q_rounds.mean(axis=0),
        deadline=float(deadline),
        cuts=cuts,
    )


def deadline_for_rate(
    trace: SystemTrace,
    cuts: Sequence[int],
    target_rate: float,
    rounds: Optional[int] = None,
) -> float:
    """The deadline whose pooled per-client finish-time quantile hits
    ``target_rate`` — e.g. 0.5 drops the slower half of client-rounds,
    1.0 waits for everyone (the full-participation barrier)."""
    if not (0.0 < target_rate <= 1.0):
        raise ValueError(f"target_rate must lie in (0, 1]: {target_rate}")
    R = trace.rounds if rounds is None else min(rounds, trace.rounds)
    pooled = []
    for r in range(R):
        avail = trace.round_state(r).available
        if avail.any():
            pooled.append(per_client_finish_times(trace, r, cuts)[avail])
    if not pooled:
        raise ValueError("trace has no available client in any round")
    return float(np.quantile(np.concatenate(pooled), target_rate))


def estimate_participation(
    trace: SystemTrace,
    cuts: Sequence[int],
    deadline: Optional[float] = None,
    target_rate: Optional[float] = None,
    rounds: Optional[int] = None,
) -> ParticipationSpec:
    """Estimate the analytic ``ParticipationSpec`` (q_m per tier + the
    resolved deadline) for one policy — exactly one of ``deadline`` /
    ``target_rate`` must be given."""
    if (deadline is None) == (target_rate is None):
        raise ValueError(
            "give exactly one of deadline= or target_rate= "
            f"(got deadline={deadline!r}, target_rate={target_rate!r})"
        )
    if deadline is None:
        deadline = deadline_for_rate(trace, cuts, target_rate, rounds=rounds)
    res = participation_masks(trace, cuts, deadline, rounds=rounds)
    return res.spec().validate_for(trace.system.M)


class DeadlineLatency:
    """Expected-round-time pricing of the latency terms under a deadline.

    Where ``TraceLatency`` prices T_S at a straggler quantile of the
    *full-participation* round (every round waits for its slowest client),
    a deadline policy never waits past the barrier: T_S(μ) becomes the
    trace expectation E[min(d_eff, max over available finish)] with
    ``d_eff = max(deadline, fastest available finish)`` (module
    conventions), and T_{m,A}(μ) the expectation of the sync priced over
    that round's *participants* (a client that missed the barrier uploads
    nothing).

    Implements the ``LatencyModel`` protocol plus the whole-lattice batch
    methods of the batched solver core — both read the same stage-chain
    arithmetic, so scalar and batched pricing agree bit-for-bit
    (``tests/test_participation.py``).
    """

    def __init__(
        self,
        trace: SystemTrace,
        deadline: float,
        rounds: Optional[int] = None,
        backend: str = "numpy",
    ):
        if deadline <= 0:
            raise ValueError(f"deadline must be positive: {deadline}")
        self.trace = trace
        self.deadline = float(deadline)
        self.rounds = trace.rounds if rounds is None else min(rounds, trace.rounds)
        self.backend = backend
        self._cache: Dict[Tuple[int, ...], Tuple[np.ndarray, np.ndarray]] = {}
        self._lattice_cache: Optional[
            Tuple[bytes, Tuple[np.ndarray, np.ndarray]]
        ] = None

    def per_round(self, cuts: Sequence[int]) -> Tuple[np.ndarray, np.ndarray]:
        """(capped split [R], participant-masked agg [M-1, R]), cached.

        Round times and participant sets come from ``participation_masks``
        — the one source of truth for the d_eff convention, so the masks
        ``run(mode="train")`` samples and the expectations priced here can
        never describe different policies.
        """
        key = tuple(int(c) for c in cuts)
        hit = self._cache.get(key)
        if hit is not None:
            return hit
        trace, system = self.trace, self.trace.system
        N, M = system.num_clients, system.M
        pr = participation_masks(trace, key, self.deadline, rounds=self.rounds)
        split = pr.round_time
        agg = np.zeros((M - 1, self.rounds))
        for r in range(self.rounds):
            state = trace.round_state(r)
            part = pr.masks[r]
            for m in range(M - 1):
                if system.entities[m] <= 1:
                    continue
                up, down = aggregation_phases(
                    trace.profile, system, key, m,
                    up_rate=system.model_up[m] * state.fed_up_mult[m],
                    down_rate=system.model_down[m] * state.fed_down_mult[m],
                    compression=trace.compression,
                )
                if len(up) == N:  # clients host tier m: only participants sync
                    up, down = up[part], down[part]
                    if len(up) == 0:
                        continue  # zero-participant round: sync prices 0
                agg[m, r] = float(up.max()) + float(down.max())
        hit = self._cache[key] = (split, agg)
        return hit

    def per_round_lattice(
        self, lattice: np.ndarray
    ) -> Tuple[np.ndarray, np.ndarray]:
        """(split [K, R], agg [K, M-1, R]) for a whole cut lattice, cached."""
        key = lattice.tobytes()
        if self._lattice_cache is not None and self._lattice_cache[0] == key:
            return self._lattice_cache[1]
        res = simulate_lattice_rounds(
            self.trace, lattice, rounds=self.rounds, backend=self.backend,
            deadline=self.deadline,
        )
        self._lattice_cache = (key, res)
        return res

    # ------------------------------------------------------------------ #
    # LatencyModel protocol (expectation pricing)
    # ------------------------------------------------------------------ #
    def split_T(self, cuts: Sequence[int]) -> float:
        split, _ = self.per_round(cuts)
        return float(np.mean(split))

    def agg_T(self, cuts: Sequence[int], m: int) -> float:
        _, agg = self.per_round(cuts)
        return float(np.mean(agg[m]))

    # ------------------------------------------------------------------ #
    # batched lattice protocol (consumed by core.batched.BatchedEvaluator)
    # ------------------------------------------------------------------ #
    def split_T_batch(self, lattice: np.ndarray) -> np.ndarray:
        split, _ = self.per_round_lattice(lattice)
        return np.mean(split, axis=1)

    def agg_T_batch(self, lattice: np.ndarray) -> np.ndarray:
        _, agg = self.per_round_lattice(lattice)
        return np.mean(agg, axis=2)


def participation_problem(
    problem: HsflProblem,
    trace: SystemTrace,
    deadline: Optional[float] = None,
    target_rate: Optional[float] = None,
    cuts: Optional[Sequence[int]] = None,
    rounds: Optional[int] = None,
    backend: str = "numpy",
) -> HsflProblem:
    """The same MA+MS problem under a straggler deadline: latency terms
    become trace expectations of the deadline-capped round
    (``DeadlineLatency``) and the bound inflates by the estimated 1/q_m
    (``ParticipationSpec``) — the solvers then trade deadline-cheapened
    rounds against the extra rounds the inflated bound demands, unchanged.

    Mirrors ``robust_problem``'s compression handling: a compressed
    problem re-prices the (uncompressed) trace over the same wire; a trace
    already on a *different* wire is a configuration error.
    """
    if problem.compression is not None and trace.compression is None:
        trace = trace.with_compression(problem.compression)
    elif trace.compression != problem.compression:
        raise ValueError(
            "trace and problem carry different CompressionSpecs "
            f"({trace.compression} vs {problem.compression}); price both "
            "over one wire (build the trace uncompressed, or attach the "
            "same spec to both)"
        )
    if cuts is None:
        # the shared evenly-spread anchor (solve_bcd's starting point):
        # q_m is estimated here once and held fixed while the solvers move
        # the cut — DESIGN.md §12 discusses the approximation
        from ..core.bcd import default_init_cuts

        cuts = default_init_cuts(problem.n_units, problem.M)
    spec = estimate_participation(
        trace, cuts, deadline=deadline, target_rate=target_rate, rounds=rounds
    )
    model = DeadlineLatency(trace, spec.deadline, rounds=rounds, backend=backend)
    return dataclasses.replace(
        problem, latency_model=model, participation=spec
    )
