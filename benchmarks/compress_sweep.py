"""Compression sweep: the (ratio, ω) knob priced end-to-end (DESIGN.md §9).

Four asserted claims, not just tables:

1. **Ratio sweep** — as the fed-server model-byte ratio drops, the
   BCD-optimal cut moves deeper (tier-1 hosts more units: the per-round
   model upload that punished deep client cuts got cheap), the optimal
   aggregation intervals weakly shrink (cheap syncs → sync more often),
   the optimal per-round latency weakly drops, and so does total
   converged time.
2. **Scheme table** — identity / int8 / top-k priced with their real
   (ratio, ω); the ω-inflated problem stays feasible and its optimum is
   reported next to the full-precision one.
3. **Bound check under compression** — a REAL (tiny-VGG) HSFL run with
   int8-compressed fed-server aggregation: the measured average gradient
   norm must sit below Theorem 1 evaluated with the codec's ω.  (The
   engine path rounds deterministically — error second moment ≤ ω but not
   unbiased — so this is an empirical sanity check of the ω-inflated
   bound, not a proof of the unbiased-noise model it derives from.)
4. **Kernel oracle** — the fused quantize→aggregate→dequantize Pallas
   path equals its ``ref.py`` oracle bit-for-bit (interpret mode) at every
   tested (N, J, P, tile) shape, including pad-branch shapes.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .common import emit, record


# --------------------------------------------------------------------------- #
# 1. ratio sweep through the BCD solver
# --------------------------------------------------------------------------- #


def ratio_sweep(quick: bool, seed: int) -> list:
    from repro.api import CompressionCfg, paper_spec, run

    base = paper_spec(seed=seed)
    ratios = (1.0, 0.25, 0.05) if quick else (1.0, 0.5, 0.25, 0.1, 0.05)
    results = []
    for r in ratios:
        spec = base.replace(
            name=f"ratio-{r}",
            compression=CompressionCfg(codec="identity", model_ratio=r),
        )
        res = record(run(spec))
        num = res.latency["split_T"] + sum(
            b / I for b, I in zip(res.latency["agg_T"], res.intervals)
        )
        results.append((r, res, num))
    rows = [(r, res.cuts[0], str(res.cuts), str(tuple(res.intervals)),
             num, res.total_latency) for r, res, num in results]
    emit(rows, ("model_ratio", "tier1_depth", "cuts", "intervals",
                "round_latency", "converged_T"))

    depth = [res.cuts[0] for _, res, _ in results]
    rlat = [num for _, _, num in results]
    tot = [res.total_latency for _, res, _ in results]
    imax = [max(res.intervals) for _, res, _ in results]
    # cheaper model bytes -> the optimal cut moves (weakly) deeper, and
    # strictly deeper across the full sweep
    assert all(a <= b for a, b in zip(depth, depth[1:])), depth
    assert depth[-1] > depth[0], depth
    # cheaper bytes -> weakly lower optimal round latency / converged time
    assert all(a >= b - 1e-12 for a, b in zip(rlat, rlat[1:])), rlat
    assert all(a >= b - 1e-9 for a, b in zip(tot, tot[1:])), tot
    # cheaper syncs -> aggregate (weakly) more often
    assert all(a >= b for a, b in zip(imax, imax[1:])), imax
    return rows


# --------------------------------------------------------------------------- #
# 2. the three schemes at their real (ratio, omega)
# --------------------------------------------------------------------------- #


def scheme_table(quick: bool, seed: int) -> list:
    from repro.api import CompressionCfg, build, paper_spec, run

    base_spec = paper_spec(seed=seed)
    rows = []
    schemes = (
        ("identity", {}),
        ("int8", {"tile": 256}),
        ("top-k", {"frac": 0.25}),
    )
    for codec, params in schemes:
        spec = base_spec.replace(
            name=f"scheme-{codec}",
            compression=CompressionCfg(codec=codec, params=params),
        )
        built = build(spec)
        res = record(run(spec, built=built))
        assert np.isfinite(res.theta), (codec, res)
        rows.append((codec, built.compressor.ratio, built.compressor.omega,
                     str(res.cuts), str(tuple(res.intervals)), res.theta))
    emit(rows, ("scheme", "ratio", "omega", "cuts", "intervals", "theta"))
    # identity == the uncompressed optimum, exactly
    base = run(base_spec)
    assert rows[0][3] == str(base.cuts) and rows[0][5] == base.theta, rows[0]
    return rows


# --------------------------------------------------------------------------- #
# 3. Theorem 1 with omega vs a real compressed training run
# --------------------------------------------------------------------------- #


def bound_check_compressed(quick: bool, seed: int) -> list:
    import jax
    import jax.numpy as jnp

    from repro.compress import Int8Stochastic
    from repro.configs.vgg16_cifar10 import SPEC as VGG
    from repro.core import build_train_step_a, init_state_a
    from repro.core.convergence import theorem1_bound
    from repro.core.estimator import HyperEstimator
    from repro.core.tiers import default_plan
    from repro.data import image_loader, make_cifar10_like, partition_iid
    from repro.models.vgg import VggModel
    from repro.optim import sgd

    spec = dataclasses.replace(
        VGG, conv_channels=(8, 16, 16), pool_after=(0, 1), fc_dims=(32, 10),
        name="vgg-tiny",
    )
    N, gamma = 4, 0.01
    rounds = 10 if quick else 25
    codec = Int8Stochastic(tile=256)
    ds = make_cifar10_like(256, noise=0.4, seed=seed + 11)
    loader = image_loader(
        ds, partition_iid(len(ds), N, seed + 11), batch=8, seed=seed + 11
    )
    model = VggModel(spec)
    eval_batch = {"images": jnp.asarray(ds.images[:192]),
                  "labels": jnp.asarray(ds.labels[:192])}
    gbar_fn = jax.jit(lambda p, b: jax.grad(model.loss_fn)(p, b))

    plan = default_plan(spec.n_units, N, cuts=(2, 3), intervals=(2, 1, 1),
                        entities=(N, 2, 1))
    opt = sgd(gamma)
    state = init_state_a(model, plan, opt, jax.random.PRNGKey(seed + 11))
    step = jax.jit(build_train_step_a(model, plan, opt, compressor=codec))
    grad_fn = jax.jit(
        lambda p, b: jax.vmap(jax.value_and_grad(model.loss_fn))(p, b)
    )
    est = HyperEstimator(plan.n_units, N, gamma)
    sq_norms = []
    for _ in range(rounds):
        batch = {k: jnp.asarray(v) for k, v in loader.next_round().items()}
        losses, grads = grad_fn(state.params, batch)
        est.observe(state.params, grads, float(jnp.mean(losses)))
        wbar = jax.tree.map(lambda x: jnp.mean(x, axis=0), state.params)
        g = gbar_fn(wbar, eval_batch)
        sq_norms.append(float(
            sum(jnp.sum(x * x) for x in jax.tree.leaves(g))
        ))
        state, _ = step(state, batch)
    hp = est.hyperspec()
    measured = float(np.mean(sq_norms))
    bound = theorem1_bound(hp, rounds, plan.intervals, plan.cuts,
                           omega=codec.omega)
    rows = [(f"int8 I1={plan.intervals[0]}", codec.omega, measured, bound,
             measured <= bound)]
    emit(rows, ("run", "omega", "measured_avg_grad_sq", "thm1_bound_omega",
                "holds"))
    assert all(r[4] for r in rows), rows
    return rows


# --------------------------------------------------------------------------- #
# 4. fused q8 kernel vs its oracle, bit for bit
# --------------------------------------------------------------------------- #


def kernel_oracle(quick: bool, seed: int) -> list:
    from repro.kernels.tiered_aggregate.check import assert_q8_matches_oracle

    shapes = [(16, 4, 2048, 256), (6, 2, 257, 128), (4, 1, 100, 128)]
    if not quick:
        shapes += [(20, 20, 1000, 128), (8, 2, 5000, 2048), (12, 3, 333, 128)]
    rows = []
    for (N, J, P, tile) in shapes:
        assert_q8_matches_oracle(N, J, P, tile, seed=seed)
        rows.append((f"N={N} J={J} P={P} tile={tile}", True))
    emit(rows, ("shape", "bit_exact"))
    return rows


def main(quick: bool = False, seed: int = 0) -> list:
    out = []
    out += ratio_sweep(quick, seed)
    out += scheme_table(quick, seed)
    out += kernel_oracle(quick, seed)
    out += bound_check_compressed(quick, seed)
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    main(args.quick, seed=args.seed)
