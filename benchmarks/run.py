"""Benchmark runner: one harness per paper table/figure, the roofline
extraction over the dry-run artifacts, and the fleet-simulator scale sweep.

    PYTHONPATH=src python -m benchmarks.run [names...] [--quick] [--seed S]
                                            [--skip-training] [--list]
                                            [--json PATH]

Every harness is registered in ``HARNESSES`` with a group tag; ``--list``
prints the registry, positional names (or ``--only``) select a subset, and
``--seed`` is threaded through every harness that derives randomness
(system draws, policy draws, synthetic data, model init).

``--json PATH`` writes one machine-readable artifact for the whole run:
per-harness row tables plus every ``repro.api.ExperimentResult`` the
harnesses recorded (serialized via ``to_dict()``, provenance = the resolved
spec) — the BENCH_*.json perf-trajectory seed.

Harness -> paper artifact map (details in DESIGN.md §7):
    fig2_latency_vs_cut   Fig. 2(c)  per-round latency vs cut layer
    fig45_benchmarks      Figs. 4-5  HSFL vs the 5 baseline policies
    fig67_resources       Figs. 6-7  resource scaling + tier count
    sim_scale             (ours)     fleet simulator: oracle check + 10^6-client sweep
    solver_scale          (ours)     batched MS/MA/BCD lattice core vs the scalar
                                     oracle walk (bit-exact optima, >=20x headline)
    control_drift         (ours)     online adaptive control: time-to-eps vs every
                                     static schedule on drifting fleets + warm
                                     re-solve latency (>=10x over cold)
    heterogeneous_cuts    (ours)     per-class cut assignment: strict theta
                                     improvement on the lognormal fleet, bit-exact
                                     collapse when homogeneous, ragged q8 oracle
    compress_sweep        (ours)     compression ratio/omega priced through BCD,
                                     Thm 1 + the fused q8 kernel oracle
    participation_sweep   (ours)     straggler deadline: round-time vs
                                     rounds-to-eps crossover + masked training
    privacy_energy        (ours)     DP-noised uplinks + per-tier energy pricing:
                                     bit-exact noiseless/free collapse, solver
                                     retreat under (eps, delta) / joule budgets,
                                     sigma^2-inflated Thm 1 vs a real noised run
    ablations             Figs. 8-9  MA / MS ablations (+ real training)
    bound_check           Thm 1      empirical gradient norms vs the bound
    async_scale           (ours)     sharded async engine (DESIGN.md §17):
                                     staleness-0 bit-exact collapse, 10^6-client
                                     async-vs-sync round pricing, staleness-
                                     inflated Thm 1 envelope, sharded subprocess
    roofline              §g         three-term roofline per (arch x shape)
"""
from __future__ import annotations

import argparse
import contextlib
import ctypes
import signal
import sys
import threading
import time


class HarnessTimeout(Exception):
    """A harness overran ``--timeout`` and was interrupted."""


@contextlib.contextmanager
def _alarm(seconds: int):
    """Wall-clock limit for one harness.  0 disables the limit.

    On the main thread of a platform with SIGALRM, a signal-based alarm
    interrupts the straggler directly.  Everywhere else — a worker
    thread driving ``main()`` programmatically, or a platform without
    SIGALRM — a watchdog thread injects ``HarnessTimeout`` into the
    *calling* thread via ``PyThreadState_SetAsyncExc``; the exception
    lands at the next bytecode boundary, so a harness stuck inside one
    long C call is interrupted when that call returns.  Previously these
    callers silently ran with no limit at all.
    """
    if seconds <= 0:
        yield
        return
    if (
        hasattr(signal, "SIGALRM")
        and threading.current_thread() is threading.main_thread()
    ):
        prev = signal.signal(
            signal.SIGALRM,
            lambda *_: (_ for _ in ()).throw(
                HarnessTimeout(f"exceeded --timeout {seconds}s")
            ),
        )
        signal.alarm(seconds)
        try:
            yield
        finally:
            signal.alarm(0)
            signal.signal(signal.SIGALRM, prev)
        return
    # watchdog-thread fallback: no signals involved, works from any thread
    target = threading.get_ident()
    done = threading.Event()

    def watch():
        if not done.wait(seconds) and not done.is_set():
            ctypes.pythonapi.PyThreadState_SetAsyncExc(
                ctypes.c_ulong(target), ctypes.py_object(HarnessTimeout)
            )

    watchdog = threading.Thread(target=watch, daemon=True, name="bench-watchdog")
    watchdog.start()
    try:
        yield
    except HarnessTimeout:
        # async-injected exceptions carry no message; re-raise with one
        raise HarnessTimeout(f"exceeded --timeout {seconds}s") from None
    finally:
        done.set()
        watchdog.join()


def _registry(args):
    from . import (
        ablations, async_scale, bound_check, compress_sweep, control_drift,
        fault_tolerance, fig2_latency_vs_cut, fig45_benchmarks,
        fig67_resources, heterogeneous_cuts, participation_sweep,
        privacy_energy, roofline, sim_scale, solver_scale,
    )

    return [
        # (name, group, thunk)
        ("fig2_latency_vs_cut", "analytic",
         lambda: fig2_latency_vs_cut.main(args.quick, seed=args.seed)),
        ("fig45_benchmarks", "analytic",
         lambda: fig45_benchmarks.main(args.quick, seed=args.seed)),
        ("fig67_resources", "analytic",
         lambda: fig67_resources.main(args.quick, seed=args.seed)),
        ("sim_scale", "analytic",
         lambda: sim_scale.main(args.quick, seed=args.seed)),
        ("solver_scale", "analytic",
         lambda: solver_scale.main(args.quick, seed=args.seed)),
        ("control_drift", "analytic",
         lambda: control_drift.main(args.quick, seed=args.seed)),
        ("heterogeneous_cuts", "analytic",
         lambda: heterogeneous_cuts.main(args.quick, seed=args.seed)),
        ("ablations", "training",
         lambda: ablations.main(args.quick, seed=args.seed)),
        ("bound_check", "training",
         lambda: bound_check.main(args.quick, seed=args.seed)),
        # runs a (tiny) real compressed training round for the omega bound
        ("compress_sweep", "training",
         lambda: compress_sweep.main(args.quick, seed=args.seed)),
        # runs a (tiny) real masked training run off the sampled fleet masks
        ("participation_sweep", "training",
         lambda: participation_sweep.main(args.quick, seed=args.seed)),
        # runs a (tiny) real DP-noised masked run for the sigma^2 envelope
        ("privacy_energy", "training",
         lambda: privacy_energy.main(args.quick, seed=args.seed)),
        # runs the fault-storm drill: guarded training + crash recovery
        ("fault_tolerance", "training",
         lambda: fault_tolerance.main(args.quick, seed=args.seed)),
        # prices + runs the sharded async engine (real s=0/s=1 training,
        # a 10^6-client overlap sweep, and a sharded subprocess round)
        ("async_scale", "training",
         lambda: async_scale.main(args.quick, seed=args.seed)),
        ("roofline", "extracted", lambda: _roofline(roofline)),
    ]


def _roofline(roofline):
    import os

    if not os.path.isdir("experiments/dryrun"):
        print("roofline skipped: no dry-run artifacts under experiments/ "
              "(produce them with `python -m repro.launch.dryrun` first)")
        return []
    return roofline.main(["--csv", "experiments/roofline_16x16.csv"])


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("names", nargs="*",
                    help="harness names to run (default: all)")
    ap.add_argument("--quick", action="store_true",
                    help="smaller grids / fewer training rounds")
    ap.add_argument("--seed", type=int, default=0,
                    help="base PRNG seed threaded through every harness")
    ap.add_argument("--skip-training", action="store_true",
                    help="skip the real-training ablation/bound harnesses")
    ap.add_argument("--only", default=None,
                    help="run a single harness (same as one positional name)")
    ap.add_argument("--list", action="store_true", dest="list_harnesses",
                    help="print the registered harnesses and exit")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write a machine-readable result artifact (rows per "
                         "harness + recorded ExperimentResults) to PATH")
    ap.add_argument("--timeout", type=int, default=0, metavar="SECONDS",
                    help="per-harness wall-clock limit; an overrunning "
                         "harness is interrupted and reported as failed "
                         "while the rest of the run continues (0 = no limit)")
    args = ap.parse_args(argv)

    registry = _registry(args)
    if args.list_harnesses:
        for name, group, _ in registry:
            print(f"{name:22s} [{group}]")
        return 0

    selected = list(args.names) + ([args.only] if args.only else [])
    if selected:
        known = {n for n, _, _ in registry}
        unknown = [n for n in selected if n not in known]
        if unknown:
            print(f"unknown harness(es) {unknown!r}; --list shows the "
                  "registry", file=sys.stderr)
            return 2
        # an explicitly named harness always runs, even under --skip-training
        jobs = [(n, f) for n, _, f in registry if n in selected]
    else:
        jobs = [(n, f) for n, group, f in registry
                if not (args.skip_training and group == "training")]

    failures = []
    report = {}
    for name, fn in jobs:
        print(f"\n{'='*70}\n== {name}\n{'='*70}")
        t0 = time.time()
        try:
            with _alarm(args.timeout):
                rows = fn()
            dt = time.time() - t0
            report[name] = {"ok": True, "seconds": dt, "rows": rows}
            print(f"-- {name} ok ({dt:.1f}s)")
        except Exception as e:  # keep going; report at the end
            failures.append((name, repr(e)))
            report[name] = {"ok": False, "seconds": time.time() - t0,
                            "error": repr(e)}
            print(f"-- {name} FAILED: {e!r}", file=sys.stderr)
    if args.json:
        _write_json(args.json, args, report)
    _summary(report)
    if failures:
        print(f"\n{len(failures)} harness(es) failed: {failures}", file=sys.stderr)
        return 1
    print(f"\nall {len(jobs)} harnesses passed")
    return 0


def _summary(report: dict) -> None:
    """Pass/fail table over everything that ran, failures last."""
    if not report:
        return
    print(f"\n{'='*70}\n== summary\n{'='*70}")
    print(f"{'harness':<22s} {'status':<8s} {'seconds':>8s}")
    for name, r in sorted(report.items(), key=lambda kv: kv[1]["ok"],
                          reverse=True):
        status = "ok" if r["ok"] else "FAILED"
        print(f"{name:<22s} {status:<8s} {r['seconds']:>8.1f}"
              + ("" if r["ok"] else f"  {r['error']}"))


def _write_json(path: str, args, report: dict) -> None:
    """One artifact per run: harness row tables + recorded ExperimentResults."""
    import json

    from repro.api import jsonify

    from . import common

    doc = {
        "meta": {
            "seed": args.seed,
            "quick": bool(args.quick),
            "skip_training": bool(args.skip_training),
            "harnesses": sorted(report),
            "failed": sorted(n for n, r in report.items() if not r["ok"]),
        },
        "harnesses": jsonify(report),
        "experiments": [r.to_dict() for r in common.RESULTS],
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True, default=str)
    print(f"\nwrote JSON artifact -> {path} "
          f"({len(common.RESULTS)} experiment result(s))")


if __name__ == "__main__":
    raise SystemExit(main())
