"""Engine A (sync-groups, production) == Engine B (split-placement, literal
SFL dataflow): identical losses and parameters after every step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.configs.shapes import concrete_inputs
from repro.core import build_train_step_a, build_train_step_b, init_state_a, init_state_b
from repro.core.engine import engine_b_to_full
from repro.core.tiers import default_plan

# multi-arch jit compiles dominate (~2 min total): out of the CI fast subset
pytestmark = pytest.mark.slow
from repro.models.model import SplittableModel
from repro.optim import sgd


@pytest.mark.parametrize(
    "arch,cuts,intervals",
    [
        ("smollm-135m", (1, 2), (3, 2, 1)),
        ("qwen2-1.5b", (1, 1), (2, 4, 1)),
        ("mamba2-1.3b", (1, 2), (2, 2, 1)),
        ("granite-moe-1b-a400m", (1, 2), (2, 3, 1)),  # MoE: dispatch+aux path
        ("jamba-1.5-large-398b", (1, 1), (4, 2, 1)),  # hybrid super-blocks
    ],
)
def test_engines_match(arch, cuts, intervals):
    spec = get_reduced(arch)
    model = SplittableModel(spec)
    N = 8
    plan = default_plan(
        spec.n_units, N, cuts=cuts, intervals=intervals, entities=(N, 4, 1)
    )
    opt = sgd(1e-2)
    key = jax.random.PRNGKey(0)
    sa = init_state_a(model, plan, opt, key)
    sb = init_state_b(model, plan, opt, key)
    step_a = jax.jit(build_train_step_a(model, plan, opt))
    step_b = jax.jit(build_train_step_b(model, plan, opt))
    for t in range(4):
        batch = concrete_inputs(spec, N * 2, 16, jax.random.PRNGKey(t))
        batch = {k: v.reshape(N, 2, *v.shape[1:]) for k, v in batch.items()}
        sa, la = step_a(sa, batch)
        sb, lb = step_b(sb, batch)
        assert np.allclose(float(la), float(lb), rtol=1e-5)
        full_b = engine_b_to_full(model, plan, sb.params)
        for a, b in zip(jax.tree.leaves(sa.params), jax.tree.leaves(full_b)):
            np.testing.assert_allclose(a, b, atol=5e-6, rtol=1e-4)
