"""Fault injection & fault-tolerant training (DESIGN.md §16).

Four claims, all asserted:

1. **Zero-fault collapse** — a spec carrying an all-zero ``FaultsCfg``
   solves to the *bit-identical* schedule, Θ', and latency breakdown as
   the clean spec, and the quickstart training run reproduces its loss
   trajectory bit-for-bit: a null fault spec composes to structurally
   nothing.
2. **Retry pricing, scalar == batched** — the expected-attempts factor on
   every link payload prices identically through the scalar Eq. 17/18
   walk and the batched whole-lattice tables, and the discrete-event
   oracle agrees with the vectorized fleet path round-by-round on a
   fault-adjusted trace.
3. **Storm survival + recovery** — the ``fault-storm`` preset (crash +
   corrupt + retried links + cell outage, plus a mid-run engine crash)
   completes every round with finite losses, detects faults, checkpoints
   atomically, and resumes from the last checkpoint.
4. **Deflated-q envelope** — a REAL guarded training run under crash +
   corruption keeps its measured average gradient norm below the
   Theorem-1 bound evaluated with the fault-deflated q_m (constants
   estimated from the same run).
"""
from __future__ import annotations

import numpy as np

from .common import emit, record


def _collapse_rows(quick: bool, seed: int) -> list:
    from repro.api import FaultsCfg, paper_spec, quickstart_spec, run

    rows = []
    base = run(paper_spec(seed=seed))
    nulled = run(paper_spec(seed=seed).replace(faults=FaultsCfg(seed=seed)))
    record(nulled)
    rows.append(
        ("null-spec solve == clean (bit-exact)",
         f"{base.cuts}/{base.intervals}",
         f"{nulled.cuts}/{nulled.intervals}",
         base.cuts == nulled.cuts
         and base.intervals == nulled.intervals
         and base.theta == nulled.theta
         and base.latency == nulled.latency)
    )

    rounds = 4 if quick else 8
    clean = run(quickstart_spec(seed=seed, rounds=rounds))
    faulty = run(
        quickstart_spec(seed=seed, rounds=rounds).replace(
            faults=FaultsCfg(seed=seed)
        )
    )
    a = np.asarray(clean.train["losses"])
    b = np.asarray(faulty.train["losses"])
    rows.append(
        ("null-spec train losses == clean (bit-exact)",
         float(a[-1]), float(b[-1]), bool((a == b).all()))
    )
    return rows


def _pricing_rows(quick: bool, seed: int) -> list:
    from repro.api import build, paper_spec
    from repro.core.batched import BatchedEvaluator
    from repro.faults import FaultSpec, faulty_trace
    from repro.sim import make_trace
    from repro.sim.events import simulate as simulate_events
    from repro.sim.fleet import simulate_rounds

    rows = []
    problem = build(paper_spec(seed=seed)).problem
    spec = FaultSpec(seed=seed, link_fail_rate=0.2, link_retries=3)
    fp = problem.with_faults(spec)

    lattice = fp.cut_lattice()
    ev = BatchedEvaluator(fp, backend="numpy")
    stride = max(1, len(lattice) // 24)
    idxs = range(0, len(lattice), stride)
    ok = True
    for i in idxs:
        key = tuple(int(c) for c in lattice[i])
        if float(fp.split_T(key)) != float(ev.split[i]):
            ok = False
        if list(map(float, fp.agg_T(key))) != list(map(float, ev.agg[i])):
            ok = False
    rows.append(
        ("retry pricing scalar == batched tables (bit-exact)",
         spec.retry_mult, len(list(idxs)), ok)
    )

    # oracle check: events == fleet on a fault-adjusted trace
    storm = FaultSpec(
        seed=seed, crash_rate=0.1, corrupt_rate=0.1,
        link_fail_rate=0.2, link_retries=2,
        outage_cells=(0,), outage_tier=1, outage_start=2, outage_len=4,
    )
    built = build(paper_spec(seed=seed))
    trace = faulty_trace(
        make_trace(
            "lognormal-heterogeneous", built.profile, built.system,
            rounds=6 if quick else 16, seed=seed,
        ),
        storm,
    )
    cuts = (2, 4)
    res_e = simulate_events(trace, cuts)
    res_f = simulate_rounds(trace, cuts, backend="numpy")
    exact = (
        bool((res_e.split == res_f.split).all())
        and bool((res_e.agg == res_f.agg).all())
        and bool((res_e.total == res_f.total).all())
    )
    rows.append(
        ("fault-storm trace: events == fleet (bit-exact)",
         trace.rounds, f"cuts {cuts}", exact)
    )
    return rows


def _storm_rows(quick: bool, seed: int) -> list:
    from repro.api import fault_storm_spec, run

    rounds = 12 if quick else 40
    spec = fault_storm_spec(
        seed=seed, rounds=rounds, checkpoint_every=max(2, rounds // 4),
        engine_crash_round=rounds // 2,
    )
    res = record(run(spec))
    tr = res.train
    f = tr["faults"]
    losses = np.asarray(tr["losses"])
    rows = [
        ("storm completes all rounds, losses finite",
         rounds, len(losses), bool(np.isfinite(losses).all())
         and len(losses) == rounds),
        ("faults detected + q deflated",
         f["n_faulty_total"],
         "/".join(f"{q:.3f}" for q in f["deflated_q"]),
         f["n_faulty_total"] > 0 and min(f["deflated_q"]) < 1.0),
        ("engine crash recovered from checkpoint",
         f["checkpoints"], f["recovered_round"],
         f["checkpoints"] >= 1 and f["recovered_round"] == rounds // 2),
    ]
    return rows


def _envelope_rows(quick: bool, seed: int) -> list:
    """Claim 4: deflated-q Theorem 1 envelopes a real guarded faulty run."""
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.configs.vgg16_cifar10 import SPEC as VGG
    from repro.core import build_train_step_a, init_state_a
    from repro.core.convergence import theorem1_bound
    from repro.core.engine import TrainState
    from repro.core.estimator import HyperEstimator
    from repro.core.tiers import GuardSpec, default_plan
    from repro.data import image_loader, make_cifar10_like, partition_iid
    from repro.faults import FaultSpec, apply_corruption, deflate_participation, expand_faults
    from repro.models.vgg import VggModel
    from repro.optim import sgd

    spec = dataclasses.replace(
        VGG, conv_channels=(8, 16, 16), pool_after=(0, 1), fc_dims=(32, 10),
        name="vgg-tiny",
    )
    N, gamma = 4, 0.01
    rounds = 10 if quick else 25
    entities = (N, 2, 1)
    ds = make_cifar10_like(256, noise=0.4, seed=seed + 3)
    loader = image_loader(
        ds, partition_iid(len(ds), N, seed + 3), batch=8, seed=seed + 3
    )
    model = VggModel(spec)
    eval_batch = {"images": jnp.asarray(ds.images[:192]),
                  "labels": jnp.asarray(ds.labels[:192])}
    gbar_fn = jax.jit(lambda p, b: jax.grad(model.loss_fn)(p, b))
    plan = default_plan(spec.n_units, N, cuts=(2, 3), intervals=(2, 1, 1),
                        entities=entities)
    opt = sgd(gamma)

    fs = FaultSpec(seed=seed, crash_rate=0.15, corrupt_rate=0.15,
                   corrupt_mode="nan")
    part = deflate_participation(None, fs, N, entities, rounds)

    step = jax.jit(build_train_step_a(
        model, plan, opt, with_mask=True, guard=GuardSpec()
    ))
    grad_fn = jax.jit(
        lambda p, b: jax.vmap(jax.value_and_grad(model.loss_fn))(p, b)
    )
    state = init_state_a(model, plan, opt, jax.random.PRNGKey(seed + 3))
    est = HyperEstimator(plan.n_units, N, gamma)
    sq_norms = []
    for r in range(rounds):
        batch = {k: jnp.asarray(v) for k, v in loader.next_round().items()}
        losses, grads = grad_fn(state.params, batch)
        est.observe(state.params, grads, float(jnp.mean(losses)))
        wbar = jax.tree.map(lambda x: jnp.mean(x, axis=0), state.params)
        g = gbar_fn(wbar, eval_batch)
        sq_norms.append(float(
            sum(jnp.sum(x * x) for x in jax.tree.leaves(g))
        ))
        rf = expand_faults(fs, r, N)
        if rf.corrupt.any():
            state = TrainState(
                apply_corruption(state.params, rf.corrupt, fs),
                state.opt_state, state.step,
            )
        mask = (~rf.crashed).astype(np.float32)
        if mask.sum() == 0:
            mask[0] = 1.0
        state, loss = step(state, batch, jnp.asarray(mask))
        assert np.isfinite(float(loss)), f"guard leaked a NaN at round {r}"
    hp = est.hyperspec()
    measured = float(np.mean(sq_norms))
    bound = theorem1_bound(
        hp, rounds, plan.intervals, plan.cuts, participation=part,
    )
    rows = [
        (f"crash={fs.crash_rate} corrupt={fs.corrupt_rate} "
         f"(q_eff={'/'.join(f'{q:.3f}' for q in part.q)})",
         measured, bound, measured <= bound),
    ]
    emit(rows, ("faulty run", "measured_avg_grad_sq", "deflated_q_thm1_bound",
                "holds"))
    assert all(r[3] for r in rows), rows
    return rows


def main(quick: bool = False, seed: int = 0) -> list:
    rows = _collapse_rows(quick, seed)
    rows += _pricing_rows(quick, seed)
    rows += _storm_rows(quick, seed)
    emit(rows, ("case", "reference", "observed", "ok"))
    assert all(r[3] for r in rows), [r for r in rows if not r[3]]
    rows += _envelope_rows(quick, seed)
    return rows


if __name__ == "__main__":
    main()
