"""Production meshes (TPU v5e target).

Single pod:  (data=16, model=16)          = 256 chips
Multi-pod:   (pod=2, data=16, model=16)   = 512 chips

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS *before* any jax initialization).
The HSFL mapping (DESIGN.md §2): one index of the client axis — `data`,
or (`pod`, `data`) in multi-pod — hosts one client's parameter replicas;
`model` is Megatron-style tensor parallelism inside every tier; the `pod`
axis is an additional HSFL hierarchy level whose aggregation interval the
MA solver prices with DCN (not ICI) constants.
"""
from __future__ import annotations

import jax

POD_SHAPE = (16, 16)
MULTIPOD_SHAPE = (2, 16, 16)


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTIPOD_SHAPE if multi_pod else POD_SHAPE
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def client_axes(multi_pod: bool = False):
    """Mesh axes the client-stacked parameter axis is sharded over."""
    return ("pod", "data") if multi_pod else ("data",)


def num_clients(multi_pod: bool = False) -> int:
    """One HSFL client per (pod, data) index."""
    import math

    shape = MULTIPOD_SHAPE if multi_pod else POD_SHAPE
    return math.prod(shape) // shape[-1]


def make_debug_mesh(data: int = 2, model: int = 2, pods: int = 0):
    """Tiny host-device mesh for tests.

    Requires ``--xla_force_host_platform_device_count`` (in XLA_FLAGS)
    to have been set to at least data·model·max(pods, 1) *before* jax
    initialized its backend — the flag is read exactly once, at backend
    init, so setting it afterwards is silently ignored.  Rather than let
    ``jax.make_mesh`` fail with an opaque shape assertion (or silently
    build a 1×1 mesh), detect the already-initialized-with-too-few-
    devices state here and say what to do about it.
    """
    need = data * model * max(pods, 1)
    have = jax.device_count()
    if have < need:
        raise RuntimeError(
            f"make_debug_mesh needs {need} devices "
            f"({pods or 1}x{data}x{model}) but the jax backend initialized "
            f"with only {have}.  The host-platform device count is fixed at "
            f"backend init: set XLA_FLAGS="
            f"'--xla_force_host_platform_device_count={need}' in the "
            f"environment (or jax.config) BEFORE the first jax call — e.g. "
            f"run the sharded test/benchmark in a fresh subprocess with the "
            f"flag exported, as tests/test_sharded_exec.py does."
        )
    if pods:
        return jax.make_mesh((pods, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
