"""VGG-16 (the paper's experimental model, Sec. VII) as a SplittableModel.

Units are the 13 conv layers + 3 FC layers = 16 cut-indexable units, matching
the paper's cut-layer sweep (Fig. 2c uses cuts 1..14, L1=3 / L2=8 defaults).
Unlike the LLM zoo the units are heterogeneous, so they are kept as a python
list (the HSFL engine supports both stacked and listed unit containers).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .layers import cross_entropy

Params = Dict[str, Any]


@dataclass(frozen=True)
class VggSpec:
    name: str
    conv_channels: Tuple[int, ...]
    pool_after: Tuple[int, ...]  # conv indices followed by a 2x2 max-pool
    fc_dims: Tuple[int, ...]
    image_size: int
    in_channels: int
    num_classes: int
    family: str = "vgg"
    param_dtype: str = "float32"

    @property
    def n_units(self) -> int:
        return len(self.conv_channels) + len(self.fc_dims)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def _feature_hw(self) -> int:
        hw = self.image_size
        for _ in self.pool_after:
            hw //= 2
        return hw

    def unit_io(self, unit: int) -> Tuple[int, int, int]:
        """(in_dim, out_dim, spatial_hw_after) for analytic cost accounting."""
        ncv = len(self.conv_channels)
        hw = self.image_size
        if unit < ncv:
            cin = self.in_channels if unit == 0 else self.conv_channels[unit - 1]
            for i in range(unit + 1):
                if i in self.pool_after and i < unit:
                    pass
            # spatial size after this unit
            pools = sum(1 for p in self.pool_after if p <= unit)
            hw_out = self.image_size // (2**pools)
            return cin, self.conv_channels[unit], hw_out
        fi = unit - ncv
        fhw = self._feature_hw()
        in_dim = (
            self.conv_channels[-1] * fhw * fhw if fi == 0 else self.fc_dims[fi - 1]
        )
        return in_dim, self.fc_dims[fi], 1

    # analytic per-unit accounting for the HSFL latency model ------------- #
    def unit_param_count(self, unit: int) -> int:
        ncv = len(self.conv_channels)
        cin, cout, _ = self.unit_io(unit)
        if unit < ncv:
            return 9 * cin * cout + cout
        return cin * cout + cout

    def unit_flops_fwd(self, unit: int, batch: int, seq: int = 1) -> float:
        ncv = len(self.conv_channels)
        cin, cout, hw = self.unit_io(unit)
        if unit < ncv:
            pools_before = sum(1 for p in self.pool_after if p < unit)
            hw_in = self.image_size // (2**pools_before)
            return 2.0 * batch * hw_in * hw_in * 9 * cin * cout
        return 2.0 * batch * cin * cout

    def unit_act_bytes(self, batch: int, seq: int = 1, bytes_per: int = 4) -> int:
        # conservative: activation at unit boundaries varies; use max conv map
        return batch * self.image_size * self.image_size * self.conv_channels[0] * bytes_per

    def unit_act_bytes_at(self, unit: int, batch: int, bytes_per: int = 4) -> int:
        ncv = len(self.conv_channels)
        if unit < ncv:
            _, cout, hw = self.unit_io(unit)
            return batch * hw * hw * cout * bytes_per
        _, dout, _ = self.unit_io(unit)
        return batch * dout * bytes_per

    def frontend_param_count(self) -> int:
        return 0

    def head_param_count(self) -> int:
        return 0

    def total_param_count(self) -> int:
        return sum(self.unit_param_count(u) for u in range(self.n_units))

    def active_param_count(self) -> int:
        return self.total_param_count()


class VggModel:
    def __init__(self, spec: VggSpec):
        self.spec = spec

    def init_params(self, key) -> Params:
        spec = self.spec
        units: List[Params] = []
        ncv = len(spec.conv_channels)
        keys = jax.random.split(key, spec.n_units)
        for u in range(spec.n_units):
            cin, cout, _ = spec.unit_io(u)
            if u < ncv:
                w = jax.random.normal(keys[u], (3, 3, cin, cout)) * math.sqrt(
                    2.0 / (9 * cin)
                )
            else:
                w = jax.random.normal(keys[u], (cin, cout)) * math.sqrt(2.0 / cin)
            units.append(
                {"w": w.astype(spec.pdtype), "b": jnp.zeros((cout,), spec.pdtype)}
            )
        return {"frontend": {}, "units": units, "head": {}}

    def apply_units(self, units, carry: Params, lo: int, hi: int, **_) -> Params:
        spec = self.spec
        h = carry["h"]
        ncv = len(spec.conv_channels)
        for u in range(lo, hi):
            p = units[u]
            if u < ncv:
                h = lax.conv_general_dilated(
                    h, p["w"], (1, 1), "SAME",
                    dimension_numbers=("NHWC", "HWIO", "NHWC"),
                ) + p["b"]
                h = jax.nn.relu(h)
                if u in spec.pool_after:
                    h = lax.reduce_window(
                        h, -jnp.inf, lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
                    )
            else:
                if u == ncv:
                    h = h.reshape(h.shape[0], -1)
                h = h @ p["w"] + p["b"]
                if u < spec.n_units - 1:
                    h = jax.nn.relu(h)
        out = dict(carry)
        out["h"] = h
        return out

    def frontend_apply(self, frontend, batch) -> Params:
        return {"h": batch["images"], "aux": jnp.zeros((), jnp.float32)}

    def head_apply(self, params, carry) -> jax.Array:
        return carry["h"]

    def forward(self, params, batch):
        carry = self.frontend_apply(params["frontend"], batch)
        carry = self.apply_units(params["units"], carry, 0, self.spec.n_units)
        return self.head_apply(params, carry), carry["aux"]

    def loss_fn(self, params, batch) -> jax.Array:
        logits, _ = self.forward(params, batch)
        return cross_entropy(logits, batch["labels"])

    def accuracy(self, params, batch) -> jax.Array:
        logits, _ = self.forward(params, batch)
        return jnp.mean((jnp.argmax(logits, -1) == batch["labels"]).astype(jnp.float32))


def build_model(spec):
    """Factory accepting either ModelSpec or VggSpec."""
    if isinstance(spec, VggSpec):
        return VggModel(spec)
    from .model import SplittableModel

    return SplittableModel(spec)
