"""HSFL training-latency model — Eqs. (11)–(19) of the paper.

Two parameterizations of the same code:
  * the paper's WAN numbers (Sec. VII) for reproducing Figs. 2, 4–9;
  * TPU ICI/DCN constants for the pod mapping (see DESIGN.md §2).

``LayerProfile`` carries per-unit compute/communication quantities derived
from a ModelSpec/VggSpec; ``SystemSpec`` carries the multi-tier resource
topology. Everything downstream (solvers, benchmarks) consumes only these.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..compress.base import CompressionSpec, act_ratio, model_ratio
from ..models.spec import ModelSpec
from ..models.vgg import VggSpec

BITS = 8.0


def prefix_table(arr: np.ndarray) -> np.ndarray:
    """Leading-zero float64 prefix sums: ``table[hi] - table[lo]`` is the
    canonical tier sum of ``arr[lo:hi]``.

    Every tier quantity in this repo — scalar chain, batched lattice core
    (``core.batched``), memory constraint — reads the SAME tables with the
    same subtraction, which is what makes the batched evaluation bit-exact
    against the scalar walk (``np.sum`` over a slice pairwise-accumulates
    and would differ in the last bit).
    """
    return np.concatenate(([0.0], np.cumsum(np.asarray(arr, dtype=np.float64))))


@dataclass(frozen=True)
class ProfilePrefix:
    """Prefix-sum tables ([U+1] each) of every per-unit profile column."""
    flops_fwd: np.ndarray
    flops_bwd: np.ndarray
    act_bytes: np.ndarray
    grad_act_bytes: np.ndarray
    param_bytes: np.ndarray
    opt_bytes: np.ndarray


@dataclass(frozen=True)
class LayerProfile:
    """Per-unit workload profile (unit = HSFL cut granularity)."""
    n_units: int
    flops_fwd: np.ndarray        # [U] forward FLOPs per mini-batch b
    flops_bwd: np.ndarray        # [U] backward FLOPs per mini-batch b
    act_bytes: np.ndarray        # [U] activation bytes *per sample* at the
                                 #     boundary after unit u (ψ_l)
    grad_act_bytes: np.ndarray   # [U] activation-gradient bytes per sample (χ_l)
    param_bytes: np.ndarray      # [U] parameter bytes of unit u (δ contribution)
    opt_bytes: np.ndarray        # [U] optimizer-state bytes of unit u (ϑ̃_l)
    frontend_param_bytes: float
    head_param_bytes: float
    batch: int

    def __post_init__(self):
        # Degenerate-input guard (DESIGN.md §16): a zero-work or
        # non-finite profile silently turns latencies and Θ' into 0/inf/
        # NaN deep inside the solvers; fail loudly at construction.
        if self.n_units <= 0:
            raise ValueError(f"n_units must be > 0: {self.n_units}")
        if self.batch <= 0:
            raise ValueError(f"batch must be > 0: {self.batch}")
        per_unit = (
            "flops_fwd", "flops_bwd", "act_bytes", "grad_act_bytes",
            "param_bytes", "opt_bytes",
        )
        for name in per_unit:
            a = np.asarray(getattr(self, name), dtype=float)
            if a.shape != (self.n_units,):
                raise ValueError(
                    f"LayerProfile.{name} must have shape ({self.n_units},): "
                    f"{a.shape}"
                )
            if not np.all(np.isfinite(a)) or np.any(a < 0.0):
                raise ValueError(
                    f"LayerProfile.{name} must be finite and non-negative"
                )
        for name in ("flops_fwd", "flops_bwd", "param_bytes"):
            if float(np.asarray(getattr(self, name), dtype=float).sum()) <= 0.0:
                raise ValueError(
                    f"LayerProfile.{name} sums to zero — a model with no "
                    "work/parameters has no defined split latency"
                )

    @property
    def prefix(self) -> ProfilePrefix:
        """Memoized prefix-sum tables (computed once per profile; the
        instance ``__dict__`` write bypasses the frozen-dataclass guard)."""
        tables = self.__dict__.get("_prefix")
        if tables is None:
            tables = ProfilePrefix(
                flops_fwd=prefix_table(self.flops_fwd),
                flops_bwd=prefix_table(self.flops_bwd),
                act_bytes=prefix_table(self.act_bytes),
                grad_act_bytes=prefix_table(self.grad_act_bytes),
                param_bytes=prefix_table(self.param_bytes),
                opt_bytes=prefix_table(self.opt_bytes),
            )
            self.__dict__["_prefix"] = tables
        return tables

    def tier_flops(self, cuts: Sequence[int], m: int, bwd: bool = False) -> float:
        lo, hi = self._bounds(cuts, m)
        cs = self.prefix.flops_bwd if bwd else self.prefix.flops_fwd
        return float(cs[hi] - cs[lo])

    def tier_param_bytes(self, cuts: Sequence[int], m: int) -> float:
        lo, hi = self._bounds(cuts, m)
        M = len(cuts) + 1
        extra = 0.0
        if m == 0:
            extra += self.frontend_param_bytes
        if m == M - 1:
            extra += self.head_param_bytes
        cs = self.prefix.param_bytes
        return float(cs[hi] - cs[lo]) + extra

    def _bounds(self, cuts: Sequence[int], m: int) -> Tuple[int, int]:
        b = [0, *cuts, self.n_units]
        return b[m], b[m + 1]


def build_profile(
    spec,
    batch: int,
    seq: int = 1,
    bytes_per_param: float = 4.0,
    bytes_per_act: float = 4.0,
    optimizer: str = "sgd",
    bwd_fwd_ratio: float = 2.0,
) -> LayerProfile:
    """Derive a LayerProfile from a ModelSpec or VggSpec."""
    from ..optim import opt_state_bytes_per_param

    U = spec.n_units
    flops = np.array([spec.unit_flops_fwd(u, batch, seq) for u in range(U)])
    params = np.array([spec.unit_param_count(u) for u in range(U)], dtype=float)
    if isinstance(spec, VggSpec):
        act = np.array(
            [spec.unit_act_bytes_at(u, 1, int(bytes_per_act)) for u in range(U)],
            dtype=float,
        )
    else:
        act = np.full(U, float(spec.unit_act_bytes(1, seq, int(bytes_per_act))))
    opt_per = opt_state_bytes_per_param(optimizer)
    return LayerProfile(
        n_units=U,
        flops_fwd=flops,
        flops_bwd=bwd_fwd_ratio * flops,
        act_bytes=act,
        grad_act_bytes=act.copy(),
        param_bytes=params * bytes_per_param,
        opt_bytes=params * opt_per,
        frontend_param_bytes=spec.frontend_param_count() * bytes_per_param,
        head_param_bytes=spec.head_param_count() * bytes_per_param,
        batch=batch,
    )


@dataclass(frozen=True)
class SystemSpec:
    """Multi-tier resource topology (client→…→cloud) + fed-server links."""
    M: int
    num_clients: int
    entities: Tuple[int, ...]            # J_m
    compute: Tuple[np.ndarray, ...]      # per tier: FLOPS per hosted sub-model [N]
    act_up: Tuple[np.ndarray, ...]       # [M-1][N] bit/s client-sub-model uplink
    act_down: Tuple[np.ndarray, ...]     # [M-1][N] bit/s
    model_up: Tuple[np.ndarray, ...]     # [M-1][J_m] bit/s to fed server
    model_down: Tuple[np.ndarray, ...]   # [M-1][J_m] bit/s from fed server
    memory: Tuple[np.ndarray, ...]       # [M][J_m] bytes (C5)

    def __post_init__(self):
        # Degenerate-input guard (DESIGN.md §16): a zero/negative service
        # rate would silently turn every latency downstream into inf/NaN;
        # fail loudly at construction instead.
        for name in ("compute", "act_up", "act_down", "model_up", "model_down"):
            for i, arr in enumerate(getattr(self, name)):
                a = np.asarray(arr, dtype=float)
                if a.size == 0 or not np.all(np.isfinite(a)) or np.any(a <= 0.0):
                    raise ValueError(
                        f"SystemSpec.{name}[{i}] must be non-empty, finite "
                        f"and strictly positive (got min="
                        f"{a.min() if a.size else 'empty'})"
                    )

    @classmethod
    def paper_three_tier(
        cls,
        num_clients: int = 20,
        num_edges: int = 5,
        seed: int = 0,
        compute_scale: float = 1.0,
        comm_scale: float = 1.0,
        memory_bytes: float = 16e9,
    ) -> "SystemSpec":
        """Sec. VII experimental setup (client–edge–cloud)."""
        rng = np.random.default_rng(seed)
        N, J2 = num_clients, num_edges
        per_edge = N // J2
        dev = rng.uniform(0.4e12, 0.6e12, N) * compute_scale
        edge = np.full(N, 5e12 / per_edge) * compute_scale  # evenly split
        cloud = np.full(N, 50e12 / N) * compute_scale
        up_dev = rng.uniform(75e6, 80e6, N) * comm_scale
        down_dev = np.full(N, 370e6) * comm_scale
        edge_cloud = rng.uniform(370e6, 400e6, N) * comm_scale
        edge_fed = rng.uniform(370e6, 400e6, J2) * comm_scale
        dev_fed = rng.uniform(75e6, 80e6, N) * comm_scale
        return cls(
            M=3,
            num_clients=N,
            entities=(N, J2, 1),
            compute=(dev, edge, cloud),
            act_up=(up_dev, edge_cloud),
            act_down=(down_dev, edge_cloud),
            model_up=(dev_fed, edge_fed),
            model_down=(np.full(N, 370e6) * comm_scale, edge_fed),
            memory=(
                np.full(N, 8e9),
                np.full(J2, memory_bytes),
                np.array([64e9]),
            ),
        )

    @classmethod
    def tpu_pod_mapping(
        cls,
        num_clients: int = 16,
        num_edges: int = 4,
        chip_flops: float = 197e12,
        ici_bps: float = 50e9 * 8,
        dcn_bps: float = 25e9 * 8,
        hbm_bytes: float = 16e9,
    ) -> "SystemSpec":
        """HSFL hierarchy priced with TPU v5e constants (DESIGN.md §2):
        tier links = ICI, fed-server (cross-pod) links = DCN."""
        N, J2 = num_clients, num_edges
        return cls(
            M=3,
            num_clients=N,
            entities=(N, J2, 1),
            compute=(
                np.full(N, chip_flops),
                np.full(N, chip_flops),
                np.full(N, chip_flops),
            ),
            act_up=(np.full(N, ici_bps), np.full(N, ici_bps)),
            act_down=(np.full(N, ici_bps), np.full(N, ici_bps)),
            model_up=(np.full(N, dcn_bps), np.full(J2, dcn_bps)),
            model_down=(np.full(N, dcn_bps), np.full(J2, dcn_bps)),
            memory=(
                np.full(N, hbm_bytes),
                np.full(J2, hbm_bytes),
                np.array([hbm_bytes * 16]),
            ),
        )


# --------------------------------------------------------------------------- #
# Eq. (11)–(19)
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class Stage:
    """One sequential leg of a client's per-round pipeline.

    ``kind``  ∈ {compute_fwd, uplink, compute_bwd, downlink};
    ``index`` is the tier for compute stages, the link (cut boundary) for
    communication stages; ``work`` is FLOPs for compute, bits for links.

    The tuple returned by :func:`split_stages` is the *canonical chain
    order* — fwd up the hierarchy, bwd back down.  Every consumer
    (``split_latency``, the fleet simulator's vectorized path, and the
    discrete-event oracle) accumulates latency in exactly this order so
    their floating-point results agree bit-for-bit.
    """
    kind: str
    index: int
    work: float


def split_stages(
    profile: LayerProfile,
    cuts: Sequence[int],
    compression: Optional[CompressionSpec] = None,
    retry_mult: Optional[float] = None,
) -> Tuple[Stage, ...]:
    """Canonical per-client stage chain for cut vector μ (Eqs. 11–14).

    ``compression`` scales boundary-m's activation/gradient bits by
    ``act_ratio[m]`` (DESIGN.md §9); None prices the full-precision wire.
    ``retry_mult`` prices transient link failures (DESIGN.md §16): every
    link payload carries the expected attempt count
    ``faults.retry_attempts(p, k)`` as extra traversals.  None (the
    zero-fault gate) leaves every bit count untouched.
    """
    M = len(cuts) + 1
    b = profile.batch
    bnds = [0, *cuts, profile.n_units]

    def boundary_bits(m: int) -> float:
        cut = bnds[m + 1]
        act = 0.0 if cut == 0 else float(profile.act_bytes[cut - 1])
        bits = b * act * BITS * act_ratio(compression, m)
        return bits if retry_mult is None else bits * retry_mult

    stages: List[Stage] = []
    for m in range(M):  # forward sweep: Eq. (11) interleaved with Eq. (12)
        stages.append(Stage("compute_fwd", m, profile.tier_flops(cuts, m, bwd=False)))
        if m < M - 1:
            stages.append(Stage("uplink", m, boundary_bits(m)))
    for m in range(M - 1, -1, -1):  # backward sweep: Eq. (13) + Eq. (14)
        stages.append(Stage("compute_bwd", m, profile.tier_flops(cuts, m, bwd=True)))
        if m > 0:
            stages.append(Stage("downlink", m - 1, boundary_bits(m - 1)))
    return tuple(stages)


def stage_rate(system: SystemSpec, stage: Stage) -> np.ndarray:
    """Nominal per-client service rate [N] for one stage (FLOPS or bit/s)."""
    if stage.kind in ("compute_fwd", "compute_bwd"):
        return system.compute[stage.index]
    if stage.kind == "uplink":
        return system.act_up[stage.index]
    return system.act_down[stage.index]


def per_client_split_latency(
    profile: LayerProfile,
    system: SystemSpec,
    cuts: Sequence[int],
    compression: Optional[CompressionSpec] = None,
    retry_mult: Optional[float] = None,
) -> np.ndarray:
    """Per-client round latency [N], accumulated in canonical chain order.

    The fleet simulator (``repro.sim``) prices the same ``work / rate``
    stages with trace-perturbed rates and MUST keep this accumulation
    order — the homogeneous golden test in ``tests/test_sim.py`` pins the
    two paths to exact floating-point equality.
    """
    stages = split_stages(profile, cuts, compression, retry_mult)
    t = np.zeros(system.num_clients)
    for s in stages:
        t = t + s.work / stage_rate(system, s)
    return t


def split_latency(
    profile: LayerProfile,
    system: SystemSpec,
    cuts: Sequence[int],
    compression: Optional[CompressionSpec] = None,
    retry_mult: Optional[float] = None,
) -> float:
    """T_S(μ): per-round split-training latency, Eq. (17)."""
    return float(
        np.max(
            per_client_split_latency(
                profile, system, cuts, compression, retry_mult
            )
        )
    )


def aggregation_phases(
    profile: LayerProfile,
    system: SystemSpec,
    cuts: Sequence[int],
    m: int,
    up_rate: Optional[np.ndarray] = None,
    down_rate: Optional[np.ndarray] = None,
    compression: Optional[CompressionSpec] = None,
    retry_mult: Optional[float] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Per-entity (upload, download) times [J_m] of a tier-m sync, Eq. (18).

    ``compression`` scales the model bits λ_m by ``model_ratio[m]`` — the
    wire the quantized aggregation kernel actually carries (DESIGN.md §9).
    ``retry_mult`` scales the same bits by the expected link attempt count
    (DESIGN.md §16); None leaves them untouched.
    """
    lam = profile.tier_param_bytes(cuts, m) * BITS * model_ratio(compression, m)
    if retry_mult is not None:
        lam = lam * retry_mult
    up = lam / (system.model_up[m] if up_rate is None else up_rate)
    down = lam / (system.model_down[m] if down_rate is None else down_rate)
    return up, down


def aggregation_latency(
    profile: LayerProfile,
    system: SystemSpec,
    cuts: Sequence[int],
    m: int,
    compression: Optional[CompressionSpec] = None,
    retry_mult: Optional[float] = None,
) -> float:
    """T_{m,A}(μ): fed-server aggregation latency of tier m, Eq. (18)."""
    if system.entities[m] <= 1:
        return 0.0  # Eq. (15)/(16) indicator
    up, down = aggregation_phases(
        profile, system, cuts, m, compression=compression,
        retry_mult=retry_mult,
    )
    return float(np.max(up)) + float(np.max(down))


def total_latency(
    profile: LayerProfile,
    system: SystemSpec,
    cuts: Sequence[int],
    intervals: Sequence[int],
    R: float,
    compression: Optional[CompressionSpec] = None,
    retry_mult: Optional[float] = None,
) -> float:
    """T(I, μ), Eq. (19)."""
    ts = split_latency(profile, system, cuts, compression, retry_mult)
    tot = R * ts
    for m in range(system.M - 1):
        tot += np.floor(R / intervals[m]) * aggregation_latency(
            profile, system, cuts, m, compression, retry_mult
        )
    return float(tot)


def memory_ok(profile: LayerProfile, system: SystemSpec, cuts: Sequence[int]) -> bool:
    """Constraint C5: per-entity memory for hosted sub-models.

    Reads the profile's prefix tables with the same expression shape as
    the batched lattice check (``core.batched.memory_mask``) so the two
    agree on every knife-edge cut.
    """
    N = system.num_clients
    bnds = [0, *cuts, profile.n_units]
    px = profile.prefix
    for m in range(system.M):
        lo, hi = bnds[m], bnds[m + 1]
        hosted = N // system.entities[m]
        per_model = (
            (px.act_bytes[hi] - px.act_bytes[lo])
            + (px.grad_act_bytes[hi] - px.grad_act_bytes[lo])
        ) * profile.batch + (
            (px.param_bytes[hi] - px.param_bytes[lo])
            + (px.opt_bytes[hi] - px.opt_bytes[lo])
        )
        if m == 0:
            per_model = per_model + profile.frontend_param_bytes
        if m == system.M - 1:
            per_model = per_model + profile.head_param_bytes
        cap = float(np.min(system.memory[m]))
        if hosted * per_model >= cap:
            return False
    return True
