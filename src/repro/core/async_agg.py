"""Async hierarchical aggregation: bounded-staleness fed-server syncs.

The synchronous engine applies tier m's fed-server level (Eq. 4) inside
the training step of every I_m-th round — the fleet blocks on the
aggregation wire before the next round starts.  This module overlaps
that wire with client compute instead: at round r with (r+1) % I_m == 0
the due tier's client replicas are *snapshotted* (the upload leaves),
clients keep stepping, and the fed aggregate folds back in at round
r + s_m — the paper-style bounded-staleness schedule, priced in
Theorem 1 by ``convergence.bound_round_terms(staleness=...)`` as the
gated drift inflation (I_m + s_m)² − I_m².

Folding a stale aggregate back cannot simply overwrite the replicas:
clients made s_m rounds of local progress since the snapshot.  The
apply is *delta-retaining*:

    params_new = fed_mean(snapshot) + (params_now − snapshot)

i.e. the aggregate replaces the snapshot-time component and local
progress since the snapshot rides on top — at s_m = 0 the delta term
vanishes structurally (apply happens the same round, snapshot ==
params_now) and the apply is the plain in-step fed mean, which is why
staleness 0 collapses *bit-identically* onto the synchronous engine
(``tests/test_async.py``), mirroring the participation/dp_sigma2/omega
gating pattern everywhere else in this repo.

Tiers with s_m = 0 never enter the queue at all: their fed levels stay
inside the compiled step via the ``fed_round`` dispatch — the async
trainer with all-zero staleness IS the synchronous production dispatch.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax

from ..optim import Optimizer
from .engine import TrainState, build_train_step_a
from .tiers import (
    GuardSpec,
    TierPlan,
    _group_mean,
    _group_mean_masked,
    combine_tiers,
    tier_subtrees,
)

Params = Dict[str, Any]


def normalize_staleness(staleness, plan: TierPlan) -> Tuple[int, ...]:
    """Per-tier staleness bounds s_m, validated against the plan.

    A scalar applies to every *deferrable* tier (non-top, I_m > 1) and 0
    elsewhere.  s_m > 0 requires I_m > 1: a tier whose fed level runs
    every round (interval ≤ 1) is applied unconditionally inside
    ``tiers.synchronize`` — there is no round boundary to defer across —
    and the top tier's cloud sync is the round barrier itself.
    """
    M = plan.M
    if staleness is None:
        s = (0,) * M
    elif isinstance(staleness, int):
        s = tuple(
            staleness if (m < M - 1 and plan.intervals[m] > 1) else 0
            for m in range(M)
        )
    else:
        s = tuple(int(v) for v in staleness)
        if len(s) != M:
            raise ValueError(
                f"need {M} per-tier staleness bounds, got {len(s)}: {s!r}"
            )
    for m, sm in enumerate(s):
        if sm < 0:
            raise ValueError(f"staleness bounds must be >= 0: {s!r}")
        if sm > 0 and m == M - 1:
            raise ValueError(
                "the top tier's cloud sync is the round barrier itself "
                f"and cannot run stale: staleness={s!r}"
            )
        if sm > 0 and plan.intervals[m] <= 1:
            raise ValueError(
                f"tier {m} syncs every round (I_m={plan.intervals[m]}); "
                "its fed level is applied unconditionally in-step and "
                f"cannot be deferred: staleness={s!r} (raise I_m or set "
                "s_m=0)"
            )
    return s


def fed_level_apply(
    params: Params,
    plan: TierPlan,
    m: int,
    *,
    snapshot: Optional[Params] = None,
    compress_fn=None,
    mask=None,
) -> Params:
    """Apply ONLY tier m's fed-server level (Eq. 4) to a client-stacked tree.

    This deliberately does *not* call ``tiers.synchronize`` with a
    crafted ``fed_round``: synchronize would also re-apply every
    interval-1 entity level, and a group mean is not bit-idempotent
    ((x+x+x)/3 ≠ x in f32) — re-running an already-applied level moves
    the params.  Only the gated fed level of tier m runs here, with
    exactly synchronize's semantics for that level: fed-wire compression
    iff the tier has >1 entities, participation-weighted mean under
    ``mask`` with the pre-compression tree as the zero-participant
    fallback, broadcast to every member.

    ``snapshot`` switches on stale (delta-retaining) application: the
    mean is taken over the *snapshot's* tier-m replicas and local
    progress since the snapshot (params − snapshot on the tier slice)
    is added back on top.  ``snapshot=None`` is the fresh in-step apply.
    """
    if m >= plan.M - 1:
        raise ValueError(
            f"tier {m} is the top tier — its sync is never deferred"
        )
    parts = tier_subtrees(params, plan)
    src = (
        parts[m] if snapshot is None
        else tier_subtrees(snapshot, plan)[m]
    )
    groups, _interval = plan.levels(m)[-1]
    fed = compress_fn is not None and plan.entities[m] > 1
    original = src
    p = jax.tree.map(compress_fn, src) if fed else src
    if mask is not None:
        agg = _group_mean_masked(p, groups, mask, keep=original)
    else:
        agg = _group_mean(p, groups)
    if snapshot is not None:
        agg = jax.tree.map(
            lambda a, now, snap: a + (now - snap), agg, parts[m], src
        )
    parts[m] = agg
    return combine_tiers(parts, params)


@dataclass
class PendingSync:
    """One in-flight fed-server aggregation."""

    tier: int
    snapshot_round: int
    apply_round: int          # snapshot_round + s_m
    snapshot: Params          # full client-stacked params at snapshot time
    weights: Optional[jax.Array]  # the snapshot round's effective sync mask


class AsyncTrainer:
    """Drive Engine A on the bounded-staleness aggregation schedule.

    One instance owns the per-round ``fed_round`` step dispatch (the
    production specialization — at most 2^(#gated tiers) compiled
    variants), the pending-sync queue, and the jitted per-tier
    ``fed_level_apply`` programs.  Works over single-host state or the
    sharded state of ``core.sharded`` (pass ``step_builder`` /
    ``jit_apply`` accordingly — ``make_async_trainer`` wires both).

    Per round r::

        state, loss, w = step[fed(r)](state, batch[, mask])   # async tiers' fed OFF
        for m due ((r+1) % I_m == 0, s_m > 0):  queue snapshot(apply at r+s_m)
        for pending with apply_round <= r:      state.params = fed_level_apply(...)

    The snapshot captures the step's effective sync weights ``w``
    (participation × guard health × finite loss) so the deferred apply
    weights clients exactly as the in-step levels did; re-deriving guard
    health at apply time would quarantine a different set (health is a
    function of the pre-sync tree, which no longer exists).
    """

    def __init__(
        self,
        plan: TierPlan,
        step_builder: Callable[[Any], Callable],
        *,
        staleness,
        compressor=None,
        with_mask: bool = False,
        guard: Optional[GuardSpec] = None,
        jit_apply: bool = True,
    ):
        self.plan = plan
        self.s = normalize_staleness(staleness, plan)
        self.async_tiers = [
            m for m in range(plan.M - 1) if self.s[m] > 0
        ]
        self._builder = step_builder
        self._with_mask = with_mask
        self._use_weights = with_mask or guard is not None
        self._compress_fn = (
            None if compressor is None
            else (lambda x: jax.vmap(lambda v: compressor.transform(v))(x))
        )
        self._steps: Dict[Tuple[bool, ...], Callable] = {}
        self._appliers: Dict[Tuple[int, bool], Callable] = {}
        self._jit_apply = jit_apply
        self.pending: List[PendingSync] = []

    # -- step dispatch ------------------------------------------------------ #

    def _fed_tuple(self, r: int) -> Tuple[bool, ...]:
        return tuple(
            False if self.s[m] > 0
            else (True if I <= 1 else (r + 1) % I == 0)
            for m, I in enumerate(self.plan.intervals)
        )

    def _get_step(self, fed: Tuple[bool, ...]) -> Callable:
        fn = self._steps.get(fed)
        if fn is None:
            fn = self._steps[fed] = self._builder(fed)
        return fn

    # -- deferred fed applies ----------------------------------------------- #

    def _get_applier(self, m: int, masked: bool) -> Callable:
        key = (m, masked)
        fn = self._appliers.get(key)
        if fn is None:

            def apply(params, snapshot, w):
                return fed_level_apply(
                    params, self.plan, m,
                    snapshot=snapshot,
                    compress_fn=self._compress_fn,
                    mask=(w if masked else None),
                )

            fn = jax.jit(apply) if self._jit_apply else apply
            self._appliers[key] = fn
        return fn

    # -- one round ---------------------------------------------------------- #

    def run_round(self, state: TrainState, batch, r: int, mask=None):
        step = self._get_step(self._fed_tuple(r))
        if self._with_mask:
            state, loss, w = step(state, batch, mask)
        else:
            state, loss, w = step(state, batch)
        for m in self.async_tiers:
            if (r + 1) % self.plan.intervals[m] == 0:
                self.pending.append(PendingSync(
                    tier=m,
                    snapshot_round=r,
                    apply_round=r + self.s[m],
                    snapshot=state.params,
                    weights=(w if self._use_weights else None),
                ))
        due = [p for p in self.pending if p.apply_round <= r]
        if due:
            # deterministic fold-in order: apply time, then tier ascending
            # (the order synchronize visits tiers on a synchronous round)
            due.sort(key=lambda p: (p.apply_round, p.tier))
            self.pending = [p for p in self.pending if p.apply_round > r]
            params = state.params
            for p in due:
                params = self._get_applier(p.tier, p.weights is not None)(
                    params, p.snapshot, p.weights
                )
            state = TrainState(params, state.opt_state, state.step)
        return state, loss

    def drain(self, state: TrainState) -> TrainState:
        """Fold every still-pending aggregation in (end of training)."""
        params = state.params
        for p in sorted(self.pending, key=lambda q: (q.apply_round, q.tier)):
            params = self._get_applier(p.tier, p.weights is not None)(
                params, p.snapshot, p.weights
            )
        self.pending = []
        return TrainState(params, state.opt_state, state.step)


def make_async_trainer(
    model,
    plan: TierPlan,
    opt: Optimizer,
    *,
    staleness,
    compressor=None,
    with_mask: bool = False,
    guard: Optional[GuardSpec] = None,
    mesh=None,
    client_axes=("data",),
) -> AsyncTrainer:
    """AsyncTrainer over the single-host engine, or the sharded engine
    when ``mesh`` is given (``core.sharded``)."""
    if mesh is None:
        def builder(fed):
            return jax.jit(build_train_step_a(
                model, plan, opt, fed_round=fed, compressor=compressor,
                with_mask=with_mask, guard=guard, with_sync_weights=True,
            ))
    else:
        from .sharded import build_sharded_train_step_a

        def builder(fed):
            return build_sharded_train_step_a(
                model, plan, opt, mesh, client_axes=client_axes,
                fed_round=fed, compressor=compressor, with_mask=with_mask,
                guard=guard, with_sync_weights=True,
            )
    return AsyncTrainer(
        plan, builder, staleness=staleness, compressor=compressor,
        with_mask=with_mask, guard=guard,
    )


def async_round_time(
    split_T: float,
    agg_T: Sequence[float],
    intervals: Sequence[int],
    staleness: Sequence[int],
) -> Tuple[float, float]:
    """(sync, async) amortized wall-clock per round.

    Synchronous barrier (the latency model's round):
        T_sync = T_S + Σ_m T_m^A / I_m
    Bounded staleness hides tier m's aggregation inside the next s_m
    rounds of split compute; only the residual beyond s_m·T_S still
    blocks the fleet:
        T_async = T_S + Σ_m max(0, T_m^A − s_m·T_S) / I_m
    s ≡ 0 reproduces T_sync exactly (the same gating as the bound).
    """
    split_T = float(split_T)
    sync = split_T + sum(
        float(T) / max(1, int(I)) for T, I in zip(agg_T, intervals)
    )
    asyn = split_T + sum(
        (float(T) if s == 0 else max(0.0, float(T) - s * split_T))
        / max(1, int(I))
        for T, I, s in zip(agg_T, intervals, staleness)
    )
    return sync, asyn
