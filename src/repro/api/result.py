"""``ExperimentResult`` — the uniform return value of ``run(spec)``.

Whatever the run mode (solve / simulate / train), the result always carries
the schedule (I, μ), the exact objective Θ′, R-to-ε from Corollary 1, the
Eq. 19 total latency, a per-stage latency breakdown, and *provenance*: the
fully resolved spec as plain JSON — so a result artifact alone is enough to
re-run the experiment and reproduce the identical numbers
(``tests/test_api.py`` pins this, seeds included).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Mapping, Optional, Tuple

import numpy as np


def jsonify(x: Any) -> Any:
    """Recursively coerce numpy scalars/arrays (and tuples) to JSON types."""
    if isinstance(x, dict):
        return {str(k): jsonify(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [jsonify(v) for v in x]
    if isinstance(x, np.ndarray):
        return [jsonify(v) for v in x.tolist()]
    if isinstance(x, (np.floating, np.integer, np.bool_)):
        x = x.item()
    if isinstance(x, float) and (np.isnan(x) or np.isinf(x)):
        return None  # JSON has no inf/nan; absent beats invalid output
    return x


@dataclass(frozen=True)
class ExperimentResult:
    mode: str
    cuts: Tuple[int, ...]
    intervals: Tuple[int, ...]
    theta: float
    rounds_to_eps: Optional[float]         # R(I, μ), Corollary 1
    total_latency: Optional[float]         # T(I, μ), Eq. 19
    latency: Dict[str, Any] = field(default_factory=dict)
    sim: Optional[Dict[str, Any]] = None   # per-round trace profile
    train: Optional[Dict[str, Any]] = None # real-training metrics
    control: Optional[Dict[str, Any]] = None  # adaptive-control run log
    classes: Optional[Dict[str, Any]] = None  # per-class cut assignment
    privacy: Optional[Dict[str, Any]] = None  # (ε, δ) accountant report
    energy: Optional[Dict[str, Any]] = None   # per-round / total joules
    provenance: Dict[str, Any] = field(default_factory=dict)  # resolved spec

    @property
    def schedule(self) -> Tuple[Tuple[int, ...], Tuple[int, ...]]:
        return self.cuts, self.intervals

    def to_dict(self) -> Dict[str, Any]:
        return jsonify(
            {
                "mode": self.mode,
                "cuts": list(self.cuts),
                "intervals": list(self.intervals),
                "theta": self.theta,
                "rounds_to_eps": self.rounds_to_eps,
                "total_latency": self.total_latency,
                "latency": self.latency,
                "sim": self.sim,
                "train": self.train,
                "control": self.control,
                "classes": self.classes,
                "privacy": self.privacy,
                "energy": self.energy,
                "provenance": self.provenance,
            }
        )

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ExperimentResult":
        return cls(
            mode=d["mode"],
            cuts=tuple(int(c) for c in d["cuts"]),
            intervals=tuple(int(i) for i in d["intervals"]),
            theta=float(d["theta"]) if d["theta"] is not None else float("inf"),
            rounds_to_eps=d.get("rounds_to_eps"),
            total_latency=d.get("total_latency"),
            latency=dict(d.get("latency", {})),
            sim=d.get("sim"),
            train=d.get("train"),
            control=d.get("control"),
            classes=d.get("classes"),
            privacy=d.get("privacy"),
            energy=d.get("energy"),
            provenance=dict(d.get("provenance", {})),
        )
