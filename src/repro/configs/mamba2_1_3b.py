"""mamba2-1.3b [ssm] — SSD state-space duality, attention-free [arXiv:2405.21060]."""
import dataclasses
from ..models.spec import ModelSpec, SsmSpec

SPEC = ModelSpec(
    name="mamba2-1.3b", family="ssm", num_layers=48, d_model=2048,
    num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=50280,
    ssm=SsmSpec(state_dim=128, head_dim=64, expand=2, conv_width=4, chunk=256),
    tie_embeddings=True,
    source="arXiv:2405.21060",
)

REDUCED = dataclasses.replace(
    SPEC, num_layers=2, d_model=128, vocab_size=512,
    ssm=SsmSpec(state_dim=16, head_dim=32, expand=2, conv_width=4, chunk=32),
)
