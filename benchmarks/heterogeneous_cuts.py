"""Per-class cut assignment (DESIGN.md §14): heterogeneity pays, uniformity
collapses.

Three asserted claims:

1. **Collapse** — on a homogeneous system (tpu-pod: every client identical)
   the per-class BCD with C=2 classes must land on the single-cut BCD
   optimum *bit-exactly*: same theta, every class on the same cuts, same
   intervals.  Heterogeneity machinery must cost nothing when there is
   no heterogeneity.

2. **Strict improvement** — on the statically heterogeneous
   ``lognormal-fleet`` system (per-device lognormal compute and link
   multipliers; each device's fed link shares its access-link draw),
   banding clients by fed-uplink rate and giving each band its own split
   vector strictly lowers Θ′: the slow-link band pushes its cut earlier
   (smaller fed payload on the bottleneck uplink) while only paying the
   drift increase weighted by its class share.  Asserted: Θ′ is
   non-increasing in C, C=1 equals the single-cut optimum bit-exactly,
   and C=2 / C=4 are strictly below it.

3. **Ragged wire** — mixed-cut client groups make the tier-aggregation
   membership ragged (clients in one entity group disagree on which units
   are client-side).  The ragged q8 fused kernel must be bit-exact vs the
   tile-mirroring oracle for every (do_entity, do_global) flag combination,
   and collapse bit-exactly to the dense q8 kernel under all-ones
   membership.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from .common import emit, record


# --------------------------------------------------------------------------- #
# 1. homogeneous collapse: per-class == single-cut, bit-exact
# --------------------------------------------------------------------------- #


def collapse_case(quick: bool, seed: int) -> List[Tuple]:
    from repro.api import ClassesCfg, run, tpu_pod_spec

    base = tpu_pod_spec(seed=seed)
    single = record(run(base))
    N = 16  # tpu-pod preset client count
    assign = tuple(i % 2 for i in range(N))
    classy = record(run(base.replace(
        name="tpu-pod-hetcuts-c2",
        classes=ClassesCfg(num_classes=2, by="explicit", assign=assign),
    )))

    assert classy.theta == single.theta, (
        "homogeneous per-class optimum must equal the single-cut optimum "
        f"bit-exactly: {classy.theta} vs {single.theta}"
    )
    assert classy.intervals == single.intervals, (
        f"intervals must collapse: {classy.intervals} vs {single.intervals}"
    )
    for c, cuts in enumerate(classy.classes["class_cuts"]):
        assert tuple(cuts) == single.cuts, (
            f"class {c} must land on the single-cut optimum: "
            f"{cuts} vs {single.cuts}"
        )
    print(f"tpu-pod: C=2 collapses bit-exactly to single-cut "
          f"theta {single.theta:.6f} at cuts {single.cuts} ✓")
    return [
        ("tpu-pod", "single", f"{single.theta:.6f}", str(single.cuts), ""),
        ("tpu-pod", "C=2", f"{classy.theta:.6f}",
         str(classy.classes["class_cuts"]), "+0.00%"),
    ]


# --------------------------------------------------------------------------- #
# 2. lognormal-fleet: strict improvement from per-class cuts
# --------------------------------------------------------------------------- #


def improvement_case(quick: bool, seed: int) -> List[Tuple]:
    from repro.api import hetcuts_spec, run

    single = record(run(hetcuts_spec(num_classes=1, seed=seed)
                        .replace(classes=None, name="lognormal-single")))
    rows = [("lognormal-fleet", "single", f"{single.theta:.6f}",
             str(single.cuts), "")]

    prev = single.theta
    thetas = {}
    for C in (1, 2, 4):
        res = record(run(hetcuts_spec(num_classes=C, seed=seed)))
        thetas[C] = res.theta
        gain = 100.0 * (single.theta - res.theta) / single.theta
        rows.append((
            "lognormal-fleet", f"C={C}", f"{res.theta:.6f}",
            str(res.classes["class_cuts"]), f"{gain:+.2f}%",
        ))
        assert res.theta <= prev + 0.0, (
            f"theta must be non-increasing in C: C={C} gives {res.theta} "
            f"after {prev}"
        )
        prev = res.theta

    assert thetas[1] == single.theta, (
        "C=1 must collapse bit-exactly to the single-cut optimum: "
        f"{thetas[1]} vs {single.theta}"
    )
    for C in (2, 4):
        assert thetas[C] < single.theta, (
            f"per-class cuts must strictly beat the best single cut on the "
            f"lognormal fleet: C={C} gives {thetas[C]} vs single "
            f"{single.theta}"
        )
    g2 = 100.0 * (single.theta - thetas[2]) / single.theta
    g4 = 100.0 * (single.theta - thetas[4]) / single.theta
    print(f"lognormal-fleet: single {single.theta:.1f} -> "
          f"C=2 {thetas[2]:.1f} ({g2:+.2f}%), "
          f"C=4 {thetas[4]:.1f} ({g4:+.2f}%) ✓")
    return rows


# --------------------------------------------------------------------------- #
# 3. ragged q8 kernel: bit-exact vs oracle, dense collapse
# --------------------------------------------------------------------------- #


def ragged_kernel_case(quick: bool, seed: int) -> List[Tuple]:
    from repro.kernels.tiered_aggregate.check import (
        assert_ragged_q8_matches_oracle,
    )

    shapes = [(16, 4, 300, 128)]
    if not quick:
        shapes += [(8, 2, 1000, 128), (16, 16, 257, 128)]
    for N, J, P, tile in shapes:
        assert_ragged_q8_matches_oracle(N, J, P, tile, seed=seed)
    print(f"ragged q8 kernel: {len(shapes)} shape(s) x 4 flag combos "
          f"bit-exact vs oracle + dense collapse ✓")
    return [
        ("ragged-q8", f"N{N}xJ{J}xP{P}", "4", "flag-combos", "bit-exact")
        for N, J, P, tile in shapes
    ]


def main(quick: bool = False, seed: int = 0) -> list:
    rows = []
    rows += collapse_case(quick, seed)
    rows += improvement_case(quick, seed)
    rows += ragged_kernel_case(quick, seed)
    emit(rows, ("system", "arm", "theta", "cuts", "vs_single"))
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args()
    main(a.quick, seed=a.seed)
