"""Algorithm 2 — block-coordinate descent over the MA and MS sub-problems.

Alternates P1 (``solve_ma``) and P2 (``solve_ms``) from a feasible starting
point until |ΔΘ'| ≤ ε_bcd. Each block solve is optimal for its block, so Θ'
is non-increasing and the iteration terminates; the result is the paper's
efficient sub-optimal solution to problem (20).

Compression is a first-class knob here: pass ``compression=`` (or attach it
to the problem via ``HsflProblem.with_compression``) and both block solvers
re-optimize (I, μ) against the compressed wire — cheaper model bytes pull
the optimal cut deeper and the optimal intervals down, which
``benchmarks/compress_sweep.py`` sweeps and asserts.

So is partial participation (DESIGN.md §12): a problem composed through
``repro.sim.participation_problem`` prices T_S as the trace expectation of
the deadline-capped round and inflates the bound denominator by the
estimated 1/q_m — the BCD iteration then trades a tighter deadline
(cheaper expected rounds via ``problem.split_T``/``total_T``) against the
extra rounds-to-ε the inflated D(I, μ) demands, with no changes below;
``benchmarks/participation_sweep.py`` sweeps the crossover.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

from ..compress.base import CompressionSpec
from .ma_solver import solve_ma
from .ms_solver import solve_ms
from .problem import INFEASIBLE, HsflProblem


def default_init_cuts(n_units: int, M: int) -> Tuple[int, ...]:
    """Evenly spread cuts — the feasible starting anchor of ``solve_bcd``,
    shared with eps-floor pricing (``repro.api.build``) and participation
    q_m estimation (``repro.sim.participation``) so every consumer anchors
    at the same reference point."""
    return tuple(max(1, (m + 1) * n_units // M) for m in range(M - 1))


_SEED_INTERVALS = (1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64)


def _feasible_seed(
    problem: HsflProblem,
) -> Optional[Tuple[Tuple[int, ...], Tuple[int, ...]]]:
    """Best feasible (I, μ) over a geometric interval grid × the cut lattice.

    A privacy ε budget (denominator floor) or a per-round energy budget can
    leave the default evenly-spread anchor with *no* feasible interval
    vector — e.g. the intervals large enough to amortize sync energy under
    the budget push D(I, μ) below the budget's round cap.  BCD needs a
    feasible starting point, so when the anchor dead-ends we scan the
    batched evaluator for the lowest-Θ' feasible lattice point and restart
    there.  Unconstrained problems never take this path.
    """
    import itertools

    import numpy as np

    ev = problem.evaluator("numpy")
    best = None
    for combo in itertools.product(_SEED_INTERVALS, repeat=problem.M - 1):
        intervals = (*combo, 1)
        dens = ev.denominator(intervals)
        ok = ev.mem_ok & (dens > ev.d_min)
        if ev.energy_budget is not None:
            ok = ok & (ev.round_energy(intervals) <= ev.energy_budget)
        idx = np.flatnonzero(ok)
        if idx.size == 0:
            continue
        th = ev.numerator(intervals)[idx] / dens[idx]
        j = int(np.argmin(th))
        if best is None or float(th[j]) < best[0]:
            best = (float(th[j]), intervals, ev.cuts_at(int(idx[j])))
    return None if best is None else (best[1], best[2])


@dataclass(frozen=True)
class BcdResult:
    intervals: Tuple[int, ...]
    cuts: Tuple[int, ...]
    theta: float
    rounds: float                      # R(I*, μ*) via Corollary 1
    total_latency: float               # T(I*, μ*) via Eq. (19)
    history: Tuple[float, ...] = ()    # Θ' per BCD iteration


def solve_bcd(
    problem: HsflProblem,
    init_cuts: Optional[Sequence[int]] = None,
    init_intervals: Optional[Sequence[int]] = None,
    tol: float = 1e-6,
    max_iters: int = 50,
    compression: Optional[CompressionSpec] = None,
    backend: str = "auto",
    warm_start: bool = False,
) -> BcdResult:
    """``backend`` selects the block solvers' evaluation path (DESIGN.md
    §11): "scalar" is the historical per-cut walk (test oracle);
    "numpy"/"jax"/"auto" run the batched lattice core — the MS latency
    tables are built once per problem and shared across every Dinkelbach
    step of every BCD iteration.  Results are bit-identical either way.

    ``warm_start=True`` seeds every inner Dinkelbach at the current BCD
    iterate (``warm_cuts``): starting from a previous optimum — the
    adaptive controller's re-solve path — the whole BCD pass is then one
    MA solve, one single-step MS solve, and a converged theta check, all
    against the problem's memoized evaluator tables.  The fixpoint is
    unchanged."""
    if compression is not None:
        problem = problem.with_compression(compression)
    M, U = problem.M, problem.n_units
    if init_cuts is None:
        init_cuts = default_init_cuts(U, M)  # evenly spread starting point
    cuts = tuple(init_cuts)
    intervals = (
        tuple(init_intervals) if init_intervals else tuple([1] * M)
    )

    history: List[float] = []
    theta = problem.theta(intervals, cuts)
    constrained = problem.d_min() > 0.0 or (
        problem.energy is not None
        and problem.energy.budget_j_per_round is not None
    )
    if constrained:
        probe = solve_ma(problem, cuts, backend=backend)
        if not problem.theta(probe.intervals, cuts) < INFEASIBLE:
            # the anchor admits no feasible intervals under the budget(s):
            # restart from the best feasible lattice point instead
            seed = _feasible_seed(problem)
            if seed is not None:
                intervals, cuts = seed
                theta = problem.theta(intervals, cuts)
    for _ in range(max_iters):
        ma = solve_ma(problem, cuts, backend=backend)
        intervals = ma.intervals
        ms = solve_ms(
            problem, intervals, backend=backend,
            warm_cuts=cuts if warm_start else None,
        )
        cuts = ms.cuts
        new_theta = problem.theta(intervals, cuts)
        history.append(new_theta)
        if theta < INFEASIBLE and abs(theta - new_theta) <= tol * max(1.0, abs(theta)):
            theta = new_theta
            break
        theta = new_theta

    R = problem.rounds(intervals, cuts)
    # Eq. (19) under the problem's latency pricing (nominal point estimates,
    # or trace quantiles when a sim latency_model is attached).
    T = problem.total_T(intervals, cuts, R)
    return BcdResult(
        intervals=intervals,
        cuts=cuts,
        theta=theta,
        rounds=float(R),
        total_latency=float(T),
        history=tuple(history),
    )
