"""Piecewise Theorem-1 bookkeeping across control switches.

A controlled run is a sequence of segments, each holding one schedule
(I, μ) — and possibly its own ω / participation view — for R_s rounds.
Summing the paper's per-round descent inequality over each segment and
telescoping f across the switch points (state migration preserves the
client-mean iterate, so the f-terms chain) gives

    (1/R) Σ_t E‖∇f(w_t)‖²  ≤  2ϑ/(γR)  +  Σ_s (R_s/R) · P_s

with P_s the schedule's per-round penalty — exactly the term2+term3 of
``theorem1_bound`` for segment s (``core.convergence.bound_round_terms``).

Bit-exact collapse: with a single segment, R_s/R is exactly 1.0 and the
accumulation below reproduces ``theorem1_bound``'s ``(term1 + term2) +
term3`` association with multiply-by-1.0 no-ops — the composed bound is
bit-identical to the static bound when no switch fires (property-tested
in ``tests/test_control.py``).

``progress_per_round`` is the ε-accounting dual: round t under schedule
s contributes D_t = ε − P_s headroom (with the round's *realized*
participation rates), and ε is reached once Σ_t D_t ≥ 2ϑ/γ — for a
static schedule under constant q this is exactly Corollary 1.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Tuple, Union

import numpy as np

from ..core.convergence import (
    HyperSpec,
    ParticipationSpec,
    bound_constants,
    bound_round_terms,
    participation_rates,
    tier_G2_sums,
)


@dataclass(frozen=True)
class BoundSegment:
    """``rounds`` consecutive rounds run under one schedule."""

    rounds: float
    intervals: Tuple[int, ...]
    cuts: Tuple[int, ...]
    omega: float = 0.0
    participation: Union[None, float, Sequence[float], ParticipationSpec] = None
    dp_sigma2: float = 0.0         # DP noise power (privacy.PrivacySpec)

    def __post_init__(self):
        if self.rounds <= 0:
            raise ValueError(f"segment rounds must be positive: {self.rounds}")
        object.__setattr__(self, "intervals", tuple(int(i) for i in self.intervals))
        object.__setattr__(self, "cuts", tuple(int(c) for c in self.cuts))


def piecewise_bound(hp: HyperSpec, segments: Sequence[BoundSegment]) -> float:
    """RHS of the composed Eq. (8) over a switch sequence.

    One segment collapses bit-exactly to ``theorem1_bound(hp, R, I, μ)``.
    """
    if not segments:
        raise ValueError("piecewise bound needs at least one segment")
    R = segments[0].rounds
    for s in segments[1:]:
        R = R + s.rounds
    acc = 2.0 * hp.theta0 / (hp.gamma * R)
    for s in segments:
        w = s.rounds / R
        term2, term3 = bound_round_terms(
            hp, s.intervals, s.cuts, s.omega, s.participation,
            dp_sigma2=s.dp_sigma2,
        )
        acc = acc + w * term2
        acc = acc + w * term3
    return acc


def progress_per_round(
    hp: HyperSpec,
    eps: float,
    intervals: Sequence[int],
    cuts: Sequence[int],
    omega: float = 0.0,
    participation: Union[None, float, Sequence[float], ParticipationSpec] = None,
) -> float:
    """ε-headroom one round under (I, μ) contributes: D = c(q₁) − κ·Σ I²d_m/q_m.

    Summed over a run, ε is reached when Σ_t D_t ≥ 2ϑ/γ
    (``progress_target``); under a static schedule with constant q the
    crossing round is exactly Corollary 1's R.
    """
    M = len(intervals)
    q = participation_rates(participation, M)
    c, kappa = bound_constants(hp, eps, omega, q1=q[0])
    d = tier_G2_sums(hp.G2, cuts)
    drift = sum(
        (I**2) * (dm / qm)
        for I, dm, qm in zip(intervals[:-1], d[:-1], q[:-1])
        if I > 1
    )
    return c - kappa * drift


def progress_target(hp: HyperSpec) -> float:
    """Total ε-headroom a run must accumulate: 2ϑ/γ."""
    return 2.0 * hp.theta0 / hp.gamma
