"""smollm-135m [dense] — llama-arch small [hf:HuggingFaceTB/SmolLM-135M]."""
import dataclasses
from ..models.spec import ModelSpec

SPEC = ModelSpec(
    name="smollm-135m", family="dense", num_layers=30, d_model=576,
    num_heads=9, num_kv_heads=3, d_ff=1536, vocab_size=49152,
    tie_embeddings=True,
    source="hf:HuggingFaceTB/SmolLM-135M",
)

REDUCED = dataclasses.replace(
    SPEC, num_layers=2, d_model=192, num_heads=3, num_kv_heads=3,
    d_ff=384, vocab_size=512,
)
