"""Client partitioners: IID shuffle-split and sort-and-shard non-IID.

Sort-and-shard follows the paper's Sec. VII protocol exactly: sort samples
by label, slice into ``shards_per_client × num_clients`` contiguous shards,
deal ``shards_per_client`` shards to each client (2 shards per client for 20
clients in the paper ⇒ most clients see only 1–2 classes).
"""
from __future__ import annotations

from typing import List

import numpy as np


def partition_iid(
    num_samples: int, num_clients: int, seed: int = 0
) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    perm = rng.permutation(num_samples)
    return [np.sort(chunk) for chunk in np.array_split(perm, num_clients)]


def partition_sort_and_shard(
    labels: np.ndarray,
    num_clients: int,
    shards_per_client: int = 2,
    seed: int = 0,
) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    order = np.argsort(labels, kind="stable")
    shards = np.array_split(order, num_clients * shards_per_client)
    assign = rng.permutation(len(shards))
    out = []
    for c in range(num_clients):
        ids = np.concatenate(
            [shards[assign[c * shards_per_client + s]] for s in range(shards_per_client)]
        )
        out.append(np.sort(ids))
    return out


def label_skew(labels: np.ndarray, parts: List[np.ndarray]) -> float:
    """Mean TV-distance of per-client label histograms from the global one
    (0 = perfectly IID; →1 = maximal skew). Used by tests/benchmarks."""
    num_classes = int(labels.max()) + 1
    glob = np.bincount(labels, minlength=num_classes) / len(labels)
    tv = []
    for idx in parts:
        h = np.bincount(labels[idx], minlength=num_classes) / max(len(idx), 1)
        tv.append(0.5 * np.abs(h - glob).sum())
    return float(np.mean(tv))
