"""repro — the HSFL paper as a production-shaped JAX/Pallas system.

``repro.api`` is the front door: a declarative, serializable
``ExperimentSpec`` that builds the solvers, the fleet simulator, and the
training engines (DESIGN.md §10).  ``repro.core`` / ``repro.sim`` /
``repro.compress`` remain the stable low-level layers underneath.

Submodules are imported lazily so ``import repro`` stays cheap.
"""
from importlib import import_module

_SUBMODULES = (
    "api",
    "checkpoint",
    "compress",
    "configs",
    "control",
    "core",
    "data",
    "energy",
    "kernels",
    "launch",
    "models",
    "optim",
    "privacy",
    "sim",
)

__all__ = list(_SUBMODULES)


def __getattr__(name: str):
    if name in _SUBMODULES:
        mod = import_module(f".{name}", __name__)
        globals()[name] = mod
        return mod
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_SUBMODULES))
