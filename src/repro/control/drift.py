"""Drift detection: windowed estimate vs. the currently-priced model.

The controller snapshots the latency/participation values its current
schedule was solved against; each check compares the windowed estimate of
those same quantities *at the current schedule* and trips when any
relative deviation exceeds ``rel_tol``.  Checking at the current operating
point (rather than, say, table norms over the whole lattice) keeps the
trigger cheap, scale-free, and aligned with what actually invalidates the
schedule: the prices the solver believed when it chose it.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np


@dataclass(frozen=True)
class DriftReport:
    drifted: bool
    trigger: str       # "", "latency", "participation", "latency+participation"
    split_rel: float   # relative deviation of windowed T_S at current cuts
    agg_rel: float     # max relative deviation of windowed T_{m,A}
    q_rel: float       # relative deviation of windowed q_1


def _rel(observed: float, priced: float, floor: float = 1e-12) -> float:
    return abs(float(observed) - float(priced)) / max(abs(float(priced)), floor)


def detect_drift(
    split_obs: float,
    split_priced: float,
    agg_obs: np.ndarray,
    agg_priced: np.ndarray,
    q1_obs: float,
    q1_priced: float,
    rel_tol: float,
) -> DriftReport:
    """Compare windowed vs. priced system values at the current schedule."""
    split_rel = _rel(split_obs, split_priced)
    agg_rel = 0.0
    for o, p in zip(np.atleast_1d(agg_obs), np.atleast_1d(agg_priced)):
        if float(o) == 0.0 and float(p) == 0.0:
            continue  # single-entity tier: no fed traffic on either side
        agg_rel = max(agg_rel, _rel(o, p))
    q_rel = _rel(q1_obs, q1_priced)
    triggers = []
    if split_rel > rel_tol or agg_rel > rel_tol:
        triggers.append("latency")
    if q_rel > rel_tol:
        triggers.append("participation")
    return DriftReport(
        drifted=bool(triggers),
        trigger="+".join(triggers),
        split_rel=float(split_rel),
        agg_rel=float(agg_rel),
        q_rel=float(q_rel),
    )
