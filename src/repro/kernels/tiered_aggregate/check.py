"""Shared bit-exactness check for the fused q8 aggregation path.

One definition of "the Pallas path matches its oracle", consumed by both
``tests/test_kernels_tiered.py`` and ``benchmarks/compress_sweep.py`` so a
wire-format or tolerance change can never leave one of them stale.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ...compress.quantize import q8_quantize
from .ops import ragged_tiered_aggregate_q8, tiered_aggregate_q8
from .ref import (
    quantized_tiered_aggregate_ref,
    ragged_quantized_tiered_aggregate_ref,
)
from .tiered_aggregate import (
    quantized_tiered_aggregate_pallas,
    ragged_quantized_tiered_aggregate_pallas,
)


def assert_q8_matches_oracle(
    N: int, J: int, P: int, tile: int, seed: int = 0
) -> None:
    """Raise AssertionError unless, at this (N, J, P, tile) and every flag
    combination, (a) the interpret-mode Pallas kernel equals the
    tile-mirroring ref oracle bit-for-bit on one shared wire payload, and
    (b) the jit'd end-to-end entry's pallas and fallback branches agree
    bit-for-bit."""
    key = jax.random.PRNGKey(seed * 7919 + N * P)
    x = jax.random.normal(key, (N, P))
    w = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 1), (N,)))
    q, s = q8_quantize(x, tile)  # one shared wire payload for both paths
    for de in (0, 1):
        for dg in (0, 1):
            out = quantized_tiered_aggregate_pallas(
                q, s, w, jnp.array(de), jnp.array(dg), J,
                tile_p=tile, interpret=True,
            )
            ref = quantized_tiered_aggregate_ref(
                q, s, w, jnp.array(de), jnp.array(dg), J, tile
            )
            assert np.array_equal(np.asarray(out), np.asarray(ref)), (
                "pallas vs oracle", N, J, P, tile, de, dg,
            )
            a = tiered_aggregate_q8(
                x, w, jnp.array(de), jnp.array(dg), J, tile_p=tile,
                use_pallas=True, interpret=True,
            )
            b = tiered_aggregate_q8(
                x, w, jnp.array(de), jnp.array(dg), J, tile_p=tile,
                use_pallas=False,
            )
            assert np.array_equal(np.asarray(a), np.asarray(b)), (
                "entry branches", N, J, P, tile, de, dg,
            )


def assert_ragged_q8_matches_oracle(
    N: int, J: int, P: int, tile: int, seed: int = 0, density: float = 0.6
) -> None:
    """The ragged (per-class membership) analogue of
    ``assert_q8_matches_oracle``: at every flag combination, (a) the
    interpret-mode ragged Pallas kernel equals its tile-mirroring ref
    oracle bit-for-bit on one shared wire payload, (b) the jit'd ragged
    entry's pallas and fallback branches agree bit-for-bit, and (c) with
    all-ones membership the ragged kernel reproduces the dense kernel
    bit-for-bit on the same payload (uniform 1/N weights, so every
    division the two kernels take is over identical operands)."""
    key = jax.random.PRNGKey(seed * 7919 + N * P + 1)
    x = jax.random.normal(key, (N, P))
    w = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 1), (N,)))
    member = (
        jax.random.uniform(jax.random.fold_in(key, 2), (N,)) < density
    ).astype(jnp.float32)
    ones = jnp.ones((N,), jnp.float32)
    uw = jnp.full((N,), 1.0 / N, jnp.float32)
    # the dense kernel's global mean never divides (weights sum to 1) while
    # the ragged one divides by the summed member-weights, and its entity
    # mean (jnp.mean) may divide differently than the ragged sum/count —
    # the collapse is bit-exact only when the member-weight f32 sum is
    # exactly 1.0 AND the group size is a power of two (every division is
    # then exact); skip the leg otherwise.
    per = N // J
    check_collapse = (
        per & (per - 1) == 0 and float(jnp.sum(uw)) == 1.0
    )
    q, s = q8_quantize(x, tile)  # one shared wire payload for all paths
    for de in (0, 1):
        for dg in (0, 1):
            out = ragged_quantized_tiered_aggregate_pallas(
                q, s, w, member, jnp.array(de), jnp.array(dg), J,
                tile_p=tile, interpret=True,
            )
            ref = ragged_quantized_tiered_aggregate_ref(
                q, s, w, member, jnp.array(de), jnp.array(dg), J, tile
            )
            assert np.array_equal(np.asarray(out), np.asarray(ref)), (
                "ragged pallas vs oracle", N, J, P, tile, de, dg,
            )
            a = ragged_tiered_aggregate_q8(
                x, w, member, jnp.array(de), jnp.array(dg), J, tile_p=tile,
                use_pallas=True, interpret=True,
            )
            b = ragged_tiered_aggregate_q8(
                x, w, member, jnp.array(de), jnp.array(dg), J, tile_p=tile,
                use_pallas=False,
            )
            assert np.array_equal(np.asarray(a), np.asarray(b)), (
                "ragged entry branches", N, J, P, tile, de, dg,
            )
            if not check_collapse:
                continue
            ragged = ragged_quantized_tiered_aggregate_pallas(
                q, s, uw, ones, jnp.array(de), jnp.array(dg), J,
                tile_p=tile, interpret=True,
            )
            dense = quantized_tiered_aggregate_pallas(
                q, s, uw, jnp.array(de), jnp.array(dg), J,
                tile_p=tile, interpret=True,
            )
            assert np.array_equal(np.asarray(ragged), np.asarray(dense)), (
                "all-ones collapse to dense", N, J, P, tile, de, dg,
            )
