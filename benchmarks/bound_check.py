"""Theorem 1 validation: the measured average gradient norm of a REAL
HSFL training run must sit below the bound evaluated with constants
estimated from the same run (Sec. IV empirical sanity check).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .common import emit


def main(quick: bool = False, seed: int = 0) -> list:
    import jax
    import jax.numpy as jnp

    from repro.configs.vgg16_cifar10 import SPEC as VGG
    from repro.core import build_train_step_a, init_state_a
    from repro.core.convergence import theorem1_bound
    from repro.core.estimator import HyperEstimator, _unit_sq_norms
    from repro.core.tiers import default_plan
    from repro.data import image_loader, make_cifar10_like, partition_iid
    from repro.models.vgg import VggModel
    from repro.optim import sgd

    spec = dataclasses.replace(
        VGG, conv_channels=(8, 16, 16), pool_after=(0, 1), fc_dims=(32, 10),
        name="vgg-tiny",
    )
    N, gamma = 4, 0.01
    rounds = 15 if quick else 30
    ds = make_cifar10_like(256, noise=0.4, seed=seed + 3)
    loader = image_loader(
        ds, partition_iid(len(ds), N, seed + 3), batch=8, seed=seed + 3
    )
    model = VggModel(spec)
    # Theorem 1's LHS is E||grad f(w_bar)||^2: the FULL gradient of the global
    # loss at the *aggregated* params. Estimate it with a large fixed batch at
    # w_bar each round - per-client stochastic grads at the unaveraged w_n
    # would overestimate by the gradient-noise and client-drift terms that
    # the bound accounts for separately.
    eval_batch = {"images": jnp.asarray(ds.images[:192]),
                  "labels": jnp.asarray(ds.labels[:192])}
    gbar_fn = jax.jit(lambda p, b: jax.grad(model.loss_fn)(p, b))

    rows = []
    for I1 in (1, 4):
        plan = default_plan(spec.n_units, N, cuts=(2, 3), intervals=(I1, 1, 1),
                            entities=(N, 2, 1))
        opt = sgd(gamma)
        state = init_state_a(model, plan, opt, jax.random.PRNGKey(seed + 3))
        step = jax.jit(build_train_step_a(model, plan, opt))
        grad_fn = jax.jit(
            lambda p, b: jax.vmap(jax.value_and_grad(model.loss_fn))(p, b)
        )
        est = HyperEstimator(plan.n_units, N, gamma)
        sq_norms = []
        for _ in range(rounds):
            batch = {k: jnp.asarray(v) for k, v in loader.next_round().items()}
            losses, grads = grad_fn(state.params, batch)
            est.observe(state.params, grads, float(jnp.mean(losses)))
            wbar = jax.tree.map(lambda x: jnp.mean(x, axis=0), state.params)
            g = gbar_fn(wbar, eval_batch)
            sq_norms.append(float(
                sum(jnp.sum(x * x) for x in jax.tree.leaves(g))
            ))
            state, _ = step(state, batch)
        hp = est.hyperspec()
        measured = float(np.mean(sq_norms))
        bound = theorem1_bound(hp, rounds, plan.intervals, plan.cuts)
        rows.append((f"I1={I1}", measured, bound, measured <= bound))
    emit(rows, ("schedule", "measured_avg_grad_sq", "thm1_bound", "holds"))
    assert all(r[3] for r in rows), rows
    return rows


if __name__ == "__main__":
    main()
