"""Figs. 4–5: HSFL vs the five benchmark policies — converged time to the
target ε on the paper's three-tier system (analytic reproduction; the
training-curve version lives in ablations.py / examples/train_hsfl_e2e.py).
"""
from __future__ import annotations

import numpy as np

from repro.api import build, evaluate_schedule, paper_spec

from .common import (
    POLICIES, converged_time, emit, expected_converged_time, policy_hsfl,
    record,
)


def main(quick: bool = False, seed: int = 0) -> list:
    draws = 5 if quick else 20
    rows = []
    for setting, eps_scale in [("easy_eps", 10.0), ("tight_eps", 3.0)]:
        built = build(paper_spec(eps_scale=eps_scale, seed=seed))
        prob = built.problem
        # one BCD solve per setting: it is both the recorded artifact and
        # the deterministic HSFL row below
        I, cuts = policy_hsfl(prob, np.random.default_rng(seed))
        record(evaluate_schedule(built, cuts, I))
        base = None
        for name, pol in POLICIES.items():
            if name == "HSFL(ours)":
                t, sd = converged_time(prob, I, cuts), 0.0
                base = t
            else:
                t, sd = expected_converged_time(prob, pol, draws=draws, seed=seed)
            rows.append((setting, name, t, sd, t / base if base else 1.0))
    emit(rows, ("setting", "policy", "converged_time_s", "std_s", "vs_hsfl"))
    # the headline claim: HSFL is fastest in every setting
    for setting in ("easy_eps", "tight_eps"):
        sub = [r for r in rows if r[0] == setting]
        best = min(sub, key=lambda r: r[2])
        assert best[1] == "HSFL(ours)", sub
    return rows


if __name__ == "__main__":
    main()
