"""paligemma-3b [vlm] — SigLIP(stub) + gemma backbone [arXiv:2407.07726]."""
import dataclasses
from ..models.spec import ModelSpec

SPEC = ModelSpec(
    name="paligemma-3b", family="vlm", num_layers=18, d_model=2048,
    num_heads=8, num_kv_heads=1, d_ff=16384, vocab_size=257216,
    head_dim=256, tie_embeddings=True, prefix_len=256,
    source="arXiv:2407.07726",
)

REDUCED = dataclasses.replace(
    SPEC, num_layers=2, d_model=128, num_heads=4, num_kv_heads=1,
    d_ff=256, vocab_size=512, head_dim=32, prefix_len=4,
)
