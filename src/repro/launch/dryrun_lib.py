"""Dry-run engine: lower + compile every (arch × shape × mesh) case and
extract the roofline inputs from the compiled artifact.

No env side effects — ``dryrun.py`` (the CLI) sets
``--xla_force_host_platform_device_count=512`` before importing jax and
calls into here. Tests import this module directly under smaller debug
meshes.

Per case we record:
  * ``cost_analysis()``  : HLO FLOPs + bytes accessed   (compute/memory terms)
  * HLO collective ops   : kind, per-device result bytes, group size
                           (collective term — cost_analysis has no ICI info)
  * ``memory_analysis()``: per-device argument/output/temp bytes (fits-check)
"""
from __future__ import annotations

import json
import os
import re
import time
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import get_spec
from ..configs.shapes import LONG_CONTEXT_WINDOW, SHAPES, InputShape, input_specs, sds
from ..core.engine import TrainState, build_train_step_a, init_state_a
from ..core.tiers import default_plan
from ..models.model import SplittableModel
from ..optim import sgd
from . import sharding as sh
from .mesh import client_axes as mesh_client_axes
from .mesh import make_production_mesh, num_clients

# families whose full attention is quadratic -> long_500k runs the
# sliding-window variant (window = 8192); ssm/hybrid run natively.
QUADRATIC_FAMILIES = {"dense", "moe", "vlm", "audio"}

COLLECTIVE_OPS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "f64": 8, "s32": 4, "u32": 4,
    "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "s16": 2, "u16": 2,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(type_str: str) -> int:
    """Sum bytes of every typed buffer in an HLO result type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> List[Dict[str, Any]]:
    """Extract every collective op with its per-device result bytes."""
    out: List[Dict[str, Any]] = []
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"%?[\w.\-]+ = (.*?) (" + "|".join(COLLECTIVE_OPS) + r")[.\d]*\(", ls)
        if not m:
            # also catch "ROOT %x = ..."
            m = re.match(
                r"ROOT %?[\w.\-]+ = (.*?) (" + "|".join(COLLECTIVE_OPS) + r")[.\d]*\(",
                ls,
            )
        if not m:
            continue
        type_str, op = m.group(1), m.group(2)
        rb = _shape_bytes(type_str)
        g = None
        gm = _GROUPS_RE.search(ls)
        if gm:
            g = int(gm.group(2))  # [groups, participants]
        else:
            gl = _GROUPS_LIST_RE.search(ls)
            if gl:
                g = len(gl.group(1).split(","))
        out.append({"op": op, "result_bytes": rb, "group": g})
    return out


def collective_traffic_bytes(colls: List[Dict[str, Any]]) -> float:
    """Per-device ICI traffic model (ring algorithms):
    all-gather: receive ≈ result; all-reduce: 2×result (RS+AG phases);
    reduce-scatter: receive ≈ result×(g−1); all-to-all: result;
    collective-permute: result."""
    total = 0.0
    for c in colls:
        b, g = c["result_bytes"], c["group"] or 2
        if c["op"] == "all-reduce":
            total += 2.0 * b * (g - 1) / g
        elif c["op"] == "all-gather":
            total += b * (g - 1) / g
        elif c["op"] == "reduce-scatter":
            total += b * (g - 1)
        else:
            total += b
    return total


def blockwise_attn_corr_flops(spec, shape, num_devices: int) -> float:
    """Analytic per-device FLOPs executed inside the *blockwise-attention*
    inner scans (layers._blockwise_sdpa), which stay rolled even in unroll
    mode (fully unrolling nq x nk score blocks would explode compile time)
    and are therefore counted once by cost_analysis.

    Only shapes with Sq*Sk > BLOCKWISE_THRESHOLD^2 take that path — in our
    shape set exactly prefill_32k (train_4k sits at the threshold and uses
    the exact-counted full _sdpa; decode attends a cache with Sq=1). The
    inner scans contain NO collectives, so only the compute (and a minor
    memory) term needs this correction. Score flops: QK^T + PV = 4·B·Sq·
    Sk_eff·(H·hd), causal Sk_eff ≈ Sk/2. Per-device = total/num_devices
    (batch over `data`, heads/blocks over `model`)."""
    from ..models.layers import BLOCKWISE_THRESHOLD

    if shape.kind not in ("train", "prefill"):
        return 0.0
    B, S = shape.global_batch, shape.seq_len
    d_attn = spec.num_heads * spec.hd

    def one(Sq: int, Sk: int, n_layers: int, causal: bool = True) -> float:
        if Sq * Sk <= BLOCKWISE_THRESHOLD**2:
            return 0.0
        eff = Sk / 2.0 if causal else float(Sk)
        return 4.0 * B * Sq * eff * d_attn * n_layers

    if spec.family == "ssm":
        total = 0.0
    elif spec.family == "audio":
        # enc self-attn (1500^2) is below threshold; dec self + cross are not
        total = one(S, S, spec.num_layers, causal=True)
        total += one(S, spec.encoder_len, spec.num_layers, causal=False)
    elif spec.family == "hybrid":
        total = one(S, S, spec.n_units)  # one attn layer per super-block
    else:
        total = one(S, S, spec.num_layers)
    mult = 4.0 if shape.kind == "train" else 1.0  # remat: fwd + refwd + 2x bwd
    return mult * total / num_devices


# --------------------------------------------------------------------------- #
# case construction
# --------------------------------------------------------------------------- #


@dataclass
class DryrunCase:
    arch: str
    shape: str
    multi_pod: bool
    opt_name: str = "sgd"
    remat: bool = True
    dtype: Optional[str] = None       # e.g. "bfloat16" override
    seq_shard: bool = False           # sequence-parallel residual constraint
    tag: str = "baseline"
    # XLA's cost_analysis counts a while-loop body ONCE (verified: a scanned
    # 8-layer stack reports exactly 1/8 of the unrolled FLOPs), and HLO-text
    # collectives inside the body likewise appear once. Unrolling the unit
    # scans makes the roofline terms exact; the multi-pod pass keeps the
    # rolled scan (it only proves the `pod` axis shards, and compiles ~2x
    # faster). None = unroll iff single-pod.
    unroll: Optional[bool] = None
    # round specialization (train shapes): "dynamic" = single step with an
    # in-graph cond (baseline), "local" / "sync" = the specialized round
    # steps (perf optimization; see tiers.synchronize).
    round_kind: str = "dynamic"
    # decode shapes: shard the attention-cache sequence dim over `model`
    # (perf; see sharding.cache_pspecs).
    cache_seq_shard: bool = False
    # decode shapes: donate the cache buffers so the in-place .at[].set
    # update aliases instead of copying the full cache every token (perf).
    donate_cache: bool = False
    # train shapes: remat policy ("full" | "dots"); see ModelSpec.remat_policy.
    remat_policy: str = "full"
    # moe archs: install the expert-parallel sharding constraint (perf).
    moe_shard: bool = False
    # train/prefill: lower BLOCKWISE_THRESHOLD so training attention takes the
    # O(S)-memory blockwise path (the Pallas flash kernel is the TPU
    # deployment analogue). NOTE: the blockwise inner scans are counted once
    # by cost_analysis, so the memory term under this flag is a lower bound
    # (attn_corr_flops keeps the compute term exact).
    flash_train: bool = False

    @property
    def resolved_unroll(self) -> bool:
        return (not self.multi_pod) if self.unroll is None else self.unroll


def _spec_for(case: DryrunCase):
    spec = get_spec(case.arch)
    shape = SHAPES[case.shape]
    if shape.name == "long_500k" and spec.family in QUADRATIC_FAMILIES:
        spec = spec.with_window(LONG_CONTEXT_WINDOW)
    if case.dtype:
        spec = spec.with_dtypes(case.dtype, case.dtype)
    if case.remat and shape.kind == "train":
        import dataclasses

        spec = dataclasses.replace(spec, remat=True,
                                   remat_policy=case.remat_policy)
    return spec, shape


def _abstract(tree):
    return jax.tree.map(lambda x: sds(x.shape, x.dtype), tree)


def _carry_constraint(mesh):
    def f(h):
        # sequence-parallel residuals: shard S over `model` between units
        if h.ndim == 3 and h.shape[1] % mesh.shape["model"] == 0:
            return jax.lax.with_sharding_constraint(
                h, NamedSharding(mesh, P(None, "model", None))
            )
        return h

    return f


def lower_case(case: DryrunCase, mesh=None):
    """Build + lower one case. Returns (lowered, meta dict)."""
    if mesh is None:
        mesh = make_production_mesh(multi_pod=case.multi_pod)
    ca = tuple(a for a in mesh.axis_names if a != "model")
    tp = mesh.shape["model"]
    n_client = 1
    for a in ca:
        n_client *= mesh.shape[a]

    spec, shape = _spec_for(case)
    if case.flash_train:
        from ..models import layers as _L

        _L.BLOCKWISE_THRESHOLD = 2048
    model = SplittableModel(spec)
    model.scan_unroll = case.resolved_unroll
    if case.seq_shard:
        model.carry_constraint = _carry_constraint(mesh)
    if case.moe_shard:
        def _moe_constraint(b):
            # [G, E, cap, d]: groups over `data`, experts over `model`
            g, e = b.shape[0], b.shape[1]
            pg = "data" if g % mesh.shape["data"] == 0 else None
            pe = "model" if e % mesh.shape["model"] == 0 else None
            return jax.lax.with_sharding_constraint(
                b, NamedSharding(mesh, P(pg, pe, None, None))
            )
        model.moe_constraint = _moe_constraint
        model.moe_groups = mesh.shape["data"]

    meta: Dict[str, Any] = {
        "arch": case.arch, "shape": case.shape,
        "mesh": "x".join(str(mesh.shape[a]) for a in mesh.axis_names),
        "axes": list(mesh.axis_names), "kind": shape.kind, "tag": case.tag,
        "window": spec.window, "dtype": str(spec.param_dtype),
        "num_devices": mesh.size,
    }

    if shape.kind == "train":
        opt = sgd(5e-4)
        plan = default_plan(
            spec.n_units, n_client,
            num_pods=mesh.shape.get("pod", 1),
            pod_interval=16 if case.multi_pod else 0,
        )
        state_abs = jax.eval_shape(
            lambda k: init_state_a(model, plan, opt, k), jax.random.PRNGKey(0)
        )
        b_per = shape.global_batch // n_client
        per_client = input_specs(spec, InputShape(shape.name, shape.seq_len, b_per, "train"))
        batch_abs = jax.tree.map(
            lambda s: sds((n_client,) + s.shape, s.dtype), per_client
        )
        pps = sh.param_pspecs(state_abs.params, tp=tp, client_axes=ca)
        state_ps = TrainState(
            params=pps, opt_state=sh.opt_pspecs(None, pps, case.opt_name), step=P()
        )
        state_sh = sh.to_shardings(mesh, state_ps)
        batch_sh = sh.to_shardings(mesh, sh.batch_pspecs(batch_abs, ca))
        fed_round = {"dynamic": None, "local": False, "sync": True}[case.round_kind]
        step = build_train_step_a(model, plan, opt, fed_round=fed_round)
        meta["round_kind"] = case.round_kind
        jitted = jax.jit(
            step,
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, NamedSharding(mesh, P())),
        )
        lowered = jitted.lower(state_abs, batch_abs)
        meta["plan"] = {
            "cuts": plan.cuts, "intervals": plan.intervals,
            "entities": plan.entities, "num_clients": n_client,
        }
        meta["global_batch"] = shape.global_batch
        meta["seq_len"] = shape.seq_len
        return lowered, meta

    # serving paths: single aggregated model copy
    params_abs = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    pps = sh.param_pspecs(params_abs, tp=tp, client_axes=None)
    params_sh = sh.to_shardings(mesh, pps)
    meta["global_batch"] = shape.global_batch
    meta["seq_len"] = shape.seq_len

    if shape.kind == "prefill":
        batch_abs = input_specs(spec, shape)
        bsh = {}
        for k, v in batch_abs.items():
            b_ax = ca if shape.global_batch % n_client == 0 else ()
            entries = [None] * len(v.shape)
            if b_ax:
                entries[0] = b_ax if len(b_ax) > 1 else b_ax[0]
            bsh[k] = NamedSharding(mesh, P(*entries))
        fwd = lambda p, b: model.forward(p, b)[0]
        jitted = jax.jit(fwd, in_shardings=(params_sh, bsh))
        lowered = jitted.lower(params_abs, batch_abs)
        return lowered, meta

    # decode: one token against a seq_len cache
    B = shape.global_batch
    caches_abs = jax.eval_shape(lambda: model.init_caches(B, shape.seq_len))
    long_ctx = shape.name == "long_500k"
    cps = sh.cache_pspecs(
        caches_abs, batch=B, client_axes=ca, tp=tp, long_context=long_ctx,
        seq_shard=case.cache_seq_shard,
    )
    caches_sh = sh.to_shardings(mesh, cps)
    tok_abs = sds((B, 1), jnp.int32)
    tok_sh = NamedSharding(mesh, sh.token_pspec(B, ca))
    pos_abs = sds((), jnp.int32)

    def serve_step(p, tok, caches, pos):
        return model.decode_step(p, tok, caches, pos)

    logits_entries = [None, "model"]
    if B % n_client == 0 and B >= n_client:
        logits_entries[0] = ca if len(ca) > 1 else ca[0]
    jitted = jax.jit(
        serve_step,
        in_shardings=(params_sh, tok_sh, caches_sh, NamedSharding(mesh, P())),
        out_shardings=(NamedSharding(mesh, P(*logits_entries)), caches_sh),
        donate_argnums=(2,) if case.donate_cache else (),
    )
    lowered = jitted.lower(params_abs, tok_abs, caches_abs, pos_abs)
    return lowered, meta


def run_case(case: DryrunCase, mesh=None, compile_: bool = True) -> Dict[str, Any]:
    t0 = time.time()
    lowered, meta = lower_case(case, mesh)
    meta["lower_s"] = round(time.time() - t0, 2)
    if not compile_:
        return meta
    t1 = time.time()
    compiled = lowered.compile()
    meta["compile_s"] = round(time.time() - t1, 2)

    ca_ = compiled.cost_analysis() or {}
    if isinstance(ca_, (list, tuple)):  # jax 0.4.x returns [dict], newer a dict
        ca_ = ca_[0] if ca_ else {}
    meta["flops"] = float(ca_.get("flops", 0.0))
    meta["bytes_accessed"] = float(ca_.get("bytes accessed", 0.0))
    spec, shape = _spec_for(case)
    meta["unrolled"] = case.resolved_unroll
    meta["attn_corr_flops"] = blockwise_attn_corr_flops(
        spec, shape, meta["num_devices"]
    )

    mem = compiled.memory_analysis()
    if mem is not None:
        meta["arg_bytes"] = int(getattr(mem, "argument_size_in_bytes", 0))
        meta["out_bytes"] = int(getattr(mem, "output_size_in_bytes", 0))
        meta["temp_bytes"] = int(getattr(mem, "temp_size_in_bytes", 0))
        meta["alias_bytes"] = int(getattr(mem, "alias_size_in_bytes", 0))

    hlo = compiled.as_text()
    colls = parse_collectives(hlo)
    meta["collectives"] = _summarize_collectives(colls)
    meta["collective_bytes"] = collective_traffic_bytes(colls)
    meta["hlo_bytes"] = len(hlo)
    return meta


def _summarize_collectives(colls: List[Dict[str, Any]]) -> Dict[str, Any]:
    summary: Dict[str, Any] = {}
    for c in colls:
        s = summary.setdefault(c["op"], {"count": 0, "result_bytes": 0})
        s["count"] += 1
        s["result_bytes"] += c["result_bytes"]
    return summary


def save_result(meta: Dict[str, Any], out_dir: str) -> str:
    os.makedirs(out_dir, exist_ok=True)
    name = f"{meta['arch']}_{meta['shape']}_{meta['mesh']}_{meta['tag']}.json"
    name = name.replace("/", "-")
    path = os.path.join(out_dir, name)
    with open(path, "w") as f:
        json.dump(meta, f, indent=1, default=str)
    return path
