"""granite-moe-1b-a400m [moe] — 32 experts top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base]."""
import dataclasses
from ..models.spec import ModelSpec, MoeSpec

SPEC = ModelSpec(
    name="granite-moe-1b-a400m", family="moe", num_layers=24, d_model=1024,
    num_heads=16, num_kv_heads=8, d_ff=512, vocab_size=49155,
    moe=MoeSpec(num_experts=32, top_k=8),
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)

REDUCED = dataclasses.replace(
    SPEC, num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=512, moe=MoeSpec(num_experts=4, top_k=2),
)
