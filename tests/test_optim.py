"""Optimizer math + state-size accounting (C5 inputs)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adam, momentum, opt_state_bytes_per_param, sgd


def test_sgd_step():
    opt = sgd(0.1)
    p = {"w": jnp.array([1.0, 2.0])}
    g = {"w": jnp.array([0.5, -1.0])}
    s = opt.init(p)
    p2, _ = opt.update(p, g, s)
    np.testing.assert_allclose(p2["w"], [0.95, 2.1])


def test_momentum_accumulates():
    opt = momentum(0.1, beta=0.5)
    p = {"w": jnp.zeros(1)}
    g = {"w": jnp.ones(1)}
    s = opt.init(p)
    p, s = opt.update(p, g, s)
    np.testing.assert_allclose(p["w"], [-0.1])
    p, s = opt.update(p, g, s)
    np.testing.assert_allclose(p["w"], [-0.25])  # m = 1.5


def test_adam_bias_correction_first_step():
    opt = adam(1e-3)
    p = {"w": jnp.zeros(3)}
    g = {"w": jnp.array([1.0, -2.0, 0.5])}
    s = opt.init(p)
    p2, s2 = opt.update(p, g, s)
    # first step of adam moves every coordinate by ~lr * sign(g)
    np.testing.assert_allclose(p2["w"], -1e-3 * np.sign(g["w"]), rtol=1e-3)
    assert int(s2["t"]) == 1


def test_state_bytes():
    assert opt_state_bytes_per_param("sgd") == 0.0
    assert opt_state_bytes_per_param("momentum") == 4.0
    assert opt_state_bytes_per_param("adam") == 8.0
    for name, mk in [("sgd", sgd), ("momentum", momentum), ("adam", adam)]:
        opt = mk(1e-3)
        assert opt.name == name
        assert opt.state_bytes_per_param == opt_state_bytes_per_param(name)
