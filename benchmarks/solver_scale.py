"""solver_scale: batched MS/MA/BCD lattice core vs the scalar oracle walk.

Sweeps U (cut units) × M (tiers) over the same HSFL problem family and
solves each point end-to-end with Algorithm 2 (``solve_bcd``) on

* ``backend="scalar"`` — the historical one-cut-at-a-time walk,
* ``backend="numpy"``  — the batched whole-lattice core (cold = first
  solve including the latency-table build, warm = tables memoized on the
  problem),
* ``backend="jax"``    — the jitted chain (cold includes trace+compile),

asserting the three return *identical* optima (the bit-exactness
contract of DESIGN.md §11) and reporting wall-clock speedups.  The
headline point U=128/M=4 (~3.2·10⁵ lattice rows) must show ≥20×
end-to-end batched-vs-scalar; above ``SCALAR_MAX_K`` lattice rows the
scalar walk is no longer worth running and only batched timings are
reported (logged as ``scalar_skipped`` rows, never silently dropped).

A robust row re-runs a mid-size point against trace-quantile pricing
(straggler-tail scenario) to show the batched core carries the
``TraceLatency`` path too.  Results land in ``benchmarks/run.py --json``
artifacts (rows + one recorded ``ExperimentResult``), the
``BENCH_solvers.json`` perf-trajectory seed that CI uploads.
"""
from __future__ import annotations

import time
from typing import Optional, Tuple

from repro.api import (
    ExperimentSpec, HyperCfg, ModelCfg, ScenarioCfg, SolverCfg, SystemCfg,
    build, evaluate_schedule,
)
from repro.core import solve_bcd
from repro.core.batched import _HAS_JAX

from .common import emit, record

# above this many lattice rows the scalar walk takes tens of minutes and
# stops being a useful comparison point
SCALAR_MAX_K = 400_000

_PRESET = {2: "two-tier-client-edge", 3: "paper-three-tier", 4: "four-tier-wan"}


def _spec(U: int, M: int, seed: int, scenario: bool = False) -> ExperimentSpec:
    return ExperimentSpec(
        model=ModelCfg(
            arch="smollm-135m", variant="reduced", num_layers=U, batch=16, seq=32
        ),
        system=SystemCfg(
            preset=_PRESET[M], num_clients=20, num_edges=5, seed=seed
        ),
        hyper=HyperCfg(beta=3.0, eps_scale=8.0, seed=seed),
        solver=SolverCfg(kind="bcd"),
        scenario=(
            ScenarioCfg(name="straggler-tail", rounds=16, seed=seed)
            if scenario else None
        ),
        name=f"solver-scale-U{U}-M{M}" + ("-robust" if scenario else ""),
    )


def _timed_bcd(U: int, M: int, seed: int, backend: str, scenario: bool = False):
    """Fresh problem (no memoized evaluator) -> (seconds, result, problem)."""
    problem = build(_spec(U, M, seed, scenario)).problem
    t0 = time.perf_counter()
    res = solve_bcd(problem, backend=backend)
    return time.perf_counter() - t0, res, problem


def _sweep_point(
    rows: list, U: int, M: int, seed: int, quick: bool, scenario: bool = False
) -> Tuple[Optional[float], object]:
    """One (U, M) grid point: all backends, identical-optimum asserts."""
    part = "robust" if scenario else "sweep"

    t_np, r_np, p_np = _timed_bcd(U, M, seed, "numpy", scenario)
    K = p_np.cut_lattice().shape[0]
    t0 = time.perf_counter()
    r_warm = solve_bcd(p_np, backend="numpy")  # evaluator memoized
    t_warm = time.perf_counter() - t0
    assert r_warm == r_np

    speedup = None
    if K <= SCALAR_MAX_K:
        t_sc, r_sc, _ = _timed_bcd(U, M, seed, "scalar", scenario)
        # the contract: not just close — identical schedules, Θ', history
        assert r_sc == r_np, (
            f"batched optimum differs from scalar oracle at U={U} M={M}: "
            f"{r_sc} vs {r_np}"
        )
        speedup = t_sc / t_np
        rows.append((part, U, M, K, "scalar", t_sc, 1.0))
        print(f"-- U={U} M={M} K={K}: scalar {t_sc:.2f}s, "
              f"batched {t_np:.3f}s ({speedup:.1f}x), warm {t_warm:.4f}s")
    else:
        rows.append((part, U, M, K, "scalar_skipped", float("nan"), float("nan")))
        print(f"-- U={U} M={M} K={K}: scalar walk skipped (K > {SCALAR_MAX_K}); "
              f"batched {t_np:.2f}s, warm {t_warm:.4f}s")
    rows.append((part, U, M, K, "numpy", t_np,
                 speedup if speedup is not None else float("nan")))
    rows.append((part, U, M, K, "numpy_warm", t_warm, float("nan")))

    if _HAS_JAX and not quick:
        t_jax, r_jax, _ = _timed_bcd(U, M, seed, "jax", scenario)
        assert r_jax == r_np, f"jax optimum drifted at U={U} M={M}"
        rows.append((part, U, M, K, "jax", t_jax, float("nan")))
    return speedup, r_np


def main(quick: bool = False, seed: int = 0) -> list:
    rows: list = []
    grid = [(16, 2), (16, 3), (32, 3), (64, 3)]
    if not quick:
        grid += [(32, 4), (64, 4), (128, 3), (128, 4), (256, 3), (256, 4)]

    speedups = {}
    for U, M in grid:
        speedup, bcd = _sweep_point(rows, U, M, seed, quick)
        if speedup is not None:
            speedups[(U, M)] = speedup
        if (U, M) == ((64, 3) if quick else (128, 4)):
            built = build(_spec(U, M, seed))
            record(evaluate_schedule(built, bcd.cuts, tuple(bcd.intervals)))

    # trace-quantile pricing rides the same batched core
    _sweep_point(rows, 32 if quick else 64, 3, seed, quick, scenario=True)

    emit(rows, ("part", "units", "tiers", "lattice_K", "backend", "seconds",
                "speedup_vs_scalar"))

    if quick:
        assert speedups[(64, 3)] >= 3.0, speedups
    else:
        # the headline: one Dinkelbach step = one argmin over [K]
        assert speedups[(128, 4)] >= 20.0, speedups
    return rows


if __name__ == "__main__":
    main()
