"""``build(spec)`` — the one owner of the composition order.

The paper's pipeline composes in exactly one valid order:

    profile (Eqs. 11–16)
      → compression attached to the *base* problem (ratios + ω)
        → scenario trace priced over the same wire
          → robust problem (trace-quantile LatencyModel)
            → solver / simulator / engine

Historically every example and benchmark re-assembled this chain by hand,
and the one illegal order — ``with_compression`` *after* a trace-based
``latency_model`` is attached — was only caught by a runtime raise in
``repro.core.problem``.  ``build`` makes that ordering unrepresentable:
compression always lands on the base problem first, and ``robust_problem``
re-prices the trace over the same wire.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

from ..compress.base import CompressionSpec
from ..core.convergence import (
    HyperSpec,
    ParticipationSpec,
    synthetic_hyperspec,
    theorem1_bound,
)
from ..core.latency import LayerProfile, SystemSpec, build_profile
from ..core.problem import HsflProblem
from .registry import resolve_codec, resolve_model, resolve_system
from .spec import CompressionCfg, ExperimentSpec


@dataclass
class BuiltExperiment:
    """Everything ``build`` resolved, with the composed problem ready to use.

    ``problem`` carries compression and (when a scenario is configured) the
    trace-quantile latency model; ``base_problem`` is the same problem
    before trace pricing — the nominal Eq. 17/18 view.
    """

    spec: ExperimentSpec
    model_spec: object                      # ModelSpec | VggSpec
    profile: LayerProfile
    system: SystemSpec
    hyper: HyperSpec
    eps: float
    compression: Optional[CompressionSpec]
    compressor: Optional[object]            # executable Compressor (engines)
    trace: Optional[object]                 # sim.SystemTrace
    base_problem: HsflProblem
    problem: HsflProblem
    participation: Optional[ParticipationSpec] = None  # resolved q_m/deadline
    class_spec: Optional[object] = None     # core.classes.CutClassSpec
    privacy: Optional[object] = None        # privacy.PrivacySpec (analytic)
    dp_mechanism: Optional[object] = None   # privacy.DPMechanism (engines);
    #                                         None at z=0 — noiseless graph
    energy: Optional[object] = None         # energy.EnergySpec
    faults: Optional[object] = None         # faults.FaultSpec (None = no
    #                                         faults section; a null spec
    #                                         still resolves, as a no-op)
    guard: Optional[object] = None          # core.tiers.GuardSpec


def resolve_compression(
    cfg: Optional[CompressionCfg], M: int
) -> Tuple[Optional[object], Optional[CompressionSpec]]:
    """``CompressionCfg`` → (executable codec, analytic CompressionSpec).

    Ratios/ω default to the codec's declared values; scalar ratios broadcast
    uniformly across the M-1 links, sequences are taken per link.
    """
    if cfg is None:
        return None, None
    codec = resolve_codec(cfg.codec, cfg.params)

    def links(value, default: float) -> Tuple[float, ...]:
        if value is None:
            value = default
        if isinstance(value, tuple):
            return tuple(float(v) for v in value)
        return (float(value),) * (M - 1)

    spec = CompressionSpec(
        act_ratio=links(cfg.act_ratio, 1.0),
        model_ratio=links(cfg.model_ratio, codec.ratio),
        omega=float(codec.omega if cfg.omega is None else cfg.omega),
    ).validate_for(M)
    return codec, spec


def _unsupported(combo: str, need: str, why: str) -> ValueError:
    """The one message shape every capability failure uses."""
    return ValueError(
        f"unsupported spec combination: {combo} requires {need} — {why}"
    )


def check_capabilities(spec: ExperimentSpec) -> None:
    """Engine/feature capability matrix — every unsupported spec
    combination fails HERE, at build time, with one message shape.

    Historically Engine B's missing features raised three divergent
    ``NotImplementedError``s at step-build time (classes / privacy /
    masked-MoE, deep in ``core.engine``) while faults × Engine B had its
    own ad-hoc build-time ValueError; sharded/async execution (DESIGN.md
    §17) adds more combinations.  The engine-level raises remain as
    backstops for direct ``core.engine`` users, but the declarative API
    rejects every combination before any state is allocated.
    """
    training = spec.run.mode in ("train", "control")
    sharded = spec.run.sharding is not None
    st = spec.run.staleness
    async_mode = bool(
        st if isinstance(st, int) else any(v > 0 for v in st)
    )
    if training and spec.run.engine != "a":
        eng = f'engine={spec.run.engine!r}'
        if spec.classes is not None:
            raise _unsupported(
                f"classes × {eng}", 'engine="a"',
                "Engine B physically places each tier's units on its "
                "hosts, and a per-class cut assignment has no single "
                "placement; Engine A runs the ragged sync-groups path "
                "(DESIGN.md §14)",
            )
        if spec.privacy is not None and spec.privacy.noise_multiplier > 0:
            raise _unsupported(
                f"privacy × {eng}", 'engine="a"',
                "Engine B's fed wire carries one model per entity, so "
                "per-client clipping (the unit the (ε, δ) accountant "
                "meters) has no faithful placement (DESIGN.md §15)",
            )
        if spec.faults is not None:
            raise _unsupported(
                f"faults × {eng}", 'engine="a"',
                "the guarded sync + quarantine path (DESIGN.md §16) "
                "lives on the Engine-A client-stacked wire",
            )
        if sharded:
            raise _unsupported(
                f"sharding × {eng}", 'engine="a"',
                "the sharded step shards the client-stacked parameter "
                "axis over the mesh (DESIGN.md §17); Engine B has no "
                "client-stacked layout to shard",
            )
        if async_mode:
            raise _unsupported(
                f"staleness × {eng}", 'engine="a"',
                "the async bounded-staleness schedule overlaps the "
                "Engine-A fed-server syncs (DESIGN.md §17)",
            )
    if sharded or async_mode:
        feature = "sharding" if sharded else "staleness"
        if spec.privacy is not None and spec.privacy.noise_multiplier > 0:
            raise _unsupported(
                f"{feature} × privacy", "noise_multiplier=0",
                "DP noise keys fold (seed, leaf, step), so the draw "
                "cannot be reproduced bit-exactly across shard layouts "
                "or stale apply rounds — the single-host synchronous "
                "engine is the DP path (DESIGN.md §15/§17)",
            )
        if spec.classes is not None:
            raise _unsupported(
                f"{feature} × classes", "no classes section",
                "the ragged per-class sync has no sharded/async "
                "collective lowering yet (DESIGN.md §14/§17)",
            )
        if spec.faults is not None:
            raise _unsupported(
                f"{feature} × faults", "no faults section",
                "crash-recovery checkpoints cannot capture the in-flight "
                "async aggregation queue, and the fault drill's "
                "corruption/outage hooks assume the single-host "
                "synchronous loop (DESIGN.md §16/§17)",
            )
        if spec.run.mode == "control":
            raise _unsupported(
                f'{feature} × mode="control"', 'mode="train"',
                "the controller re-plans (cut, I) mid-run, which would "
                "have to re-shard state and re-time in-flight async "
                "syncs across the switch (DESIGN.md §13/§17)",
            )


def build(spec: ExperimentSpec) -> BuiltExperiment:
    """Resolve every registry name and compose the problem in the one
    valid order (see module docstring)."""
    check_capabilities(spec)
    if spec.run.mode == "control" and spec.scenario is None:
        raise ValueError(
            'run mode="control" needs a scenario section: the controller '
            "observes round telemetry from that fleet trace (add scenario=, "
            'e.g. ScenarioCfg(name="flaky-wan"))'
        )
    if spec.classes is not None and (
        spec.scenario is not None or spec.participation is not None
    ):
        raise ValueError(
            "a classes section needs nominal pricing: per-class cuts are "
            "priced on the system's rate arrays, not a trace latency model "
            "(drop scenario=/participation=, and bake heterogeneity into "
            'the system preset instead, e.g. SystemCfg(preset="lognormal-fleet"))'
        )
    model_spec = resolve_model(spec.model)
    profile = build_profile(
        model_spec,
        batch=spec.model.batch,
        seq=spec.model.seq,
        optimizer=spec.model.optimizer,
    )
    system = resolve_system(spec.system)

    h = spec.hyper
    hyper = synthetic_hyperspec(
        model_spec.n_units,
        system.num_clients,
        gamma=h.gamma,
        beta=h.beta,
        theta0=h.theta0,
        g2_scale=h.g2_scale,
        sigma2_scale=h.sigma2_scale,
        decay=h.decay,
        seed=h.seed,
    )
    if h.eps is not None:
        eps = float(h.eps)
    else:
        # the I=1 floor at R→∞ is cut-independent (no I_m>1 drift term),
        # so any valid cut vector prices it; use the shared evenly-spread
        # anchor (also BCD's starting point and the q_m reference cut).
        from ..core.bcd import default_init_cuts

        M = system.M
        cuts = default_init_cuts(model_spec.n_units, M)
        floor = theorem1_bound(hyper, 10**9, [1] * M, cuts)
        eps = h.eps_scale * floor

    compressor, compression = resolve_compression(spec.compression, system.M)

    # compression attaches to the BASE problem, before any trace pricing —
    # the ordering core.problem.with_compression would otherwise refuse.
    base = HsflProblem(profile, system, hyper, eps=eps)
    if compression is not None:
        base = base.with_compression(compression)

    # privacy and energy also land on the base problem, so trace pricing
    # (dataclasses.replace) carries them into the robust problem unchanged.
    privacy_spec = None
    dp_mechanism = None
    if spec.privacy is not None:
        from ..privacy import DPMechanism, PrivacySpec

        pv = spec.privacy
        # σ²-inflation dimension: total trainable parameter count — every
        # noised coordinate contributes, so this keeps Theorem 1 an
        # envelope of the noised run (DESIGN.md §15).
        dim = max(
            1,
            int(
                (
                    float(np.sum(profile.param_bytes))
                    + profile.frontend_param_bytes
                    + profile.head_param_bytes
                )
                // 4
            ),
        )
        privacy_spec = PrivacySpec(
            noise_multiplier=pv.noise_multiplier,
            clip=pv.clip,
            delta=pv.delta,
            epsilon_budget=pv.epsilon_budget,
            dim=dim,
        )
        base = base.with_privacy(privacy_spec)
        if pv.noise_multiplier > 0.0:
            # z = 0 constructs NO mechanism: the engine graph stays
            # bit-identical to the spec without a privacy section.
            dp_mechanism = DPMechanism(
                clip=pv.clip,
                noise_multiplier=pv.noise_multiplier,
                seed=spec.run.seed,
            )

    energy_spec = None
    if spec.energy is not None:
        from ..energy import EnergySpec

        ec = spec.energy
        M = system.M

        def tiers(value, n: int) -> Tuple[float, ...]:
            if isinstance(value, tuple):
                return value
            return (float(value),) * n

        energy_spec = EnergySpec(
            compute_j_per_flop=tiers(ec.compute_j_per_flop, M),
            act_j_per_byte=tiers(ec.act_j_per_byte, M - 1),
            model_j_per_byte=tiers(ec.model_j_per_byte, M - 1),
            budget_j_per_round=ec.budget_j_per_round,
        ).validate_for(M)
        base = base.with_energy(energy_spec)

    fault_spec = None
    guard_spec = None
    if spec.faults is not None:
        fault_spec = spec.faults.to_fault_spec()
        guard_spec = spec.faults.to_guard_spec()
        # retry pricing (the expected-attempts factor on every link
        # payload) lands on the base problem before any trace pricing,
        # mirroring compression; with_faults validates the outage block
        # against the concrete topology.
        base = base.with_faults(fault_spec)

    trace = None
    problem = base
    participation = None
    if spec.scenario is not None:
        from ..sim import make_trace, participation_problem, robust_problem

        sc = spec.scenario
        trace = make_trace(
            sc.name, profile, system, rounds=sc.rounds, seed=sc.seed, **sc.params
        )
        if fault_spec is not None:
            # layer the fault draws on the scenario's rounds BEFORE trace
            # pricing, so quantiles / deadline expectations describe the
            # faulty fleet; a null spec returns the trace object unchanged
            from ..faults import faulty_trace

            trace = faulty_trace(trace, fault_spec)
        if spec.participation is not None:
            # deadline policy: expectation pricing of the deadline-capped
            # round + 1/q_m bound inflation, composed in one step so the
            # latency and convergence sides describe the same barrier.
            pc = spec.participation
            problem = participation_problem(
                base,
                trace,
                deadline=pc.deadline,
                target_rate=pc.target_rate,
                cuts=pc.cuts,
                rounds=sc.sim_rounds,
                backend=sc.backend,
            )
            participation = problem.participation
        else:
            # robust_problem re-prices the (uncompressed) trace over the
            # problem's wire, keeping quantiles and ω on the same codec.
            problem = robust_problem(
                base,
                trace,
                quantile=sc.quantile,
                rounds=sc.sim_rounds,
                backend=sc.backend,
            )
        trace = problem.latency_model.trace  # the (possibly re-priced) wire
    elif spec.participation is not None:
        raise ValueError(
            "a participation section needs a scenario section: the deadline "
            "policy is priced against a fleet trace (add scenario=, e.g. "
            'ScenarioCfg(name="straggler-tail"))'
        )

    if fault_spec is not None and not fault_spec.is_null:
        # detected faults ARE partial participation: deflate the effective
        # q_m the Theorem-1 bound sees by the per-tier entity survival of
        # the spec's own realized fault masks (DESIGN.md §16).  Composes
        # multiplicatively with a deadline policy's q_m.
        from ..faults import deflate_participation

        horizon = (
            spec.scenario.rounds if spec.scenario is not None
            else max(1, spec.run.rounds)
        )
        participation = deflate_participation(
            problem.participation, fault_spec,
            system.num_clients, system.entities, horizon,
        )
        problem = dataclasses.replace(problem, participation=participation)

    class_spec = None
    if spec.classes is not None:
        from ..core.classes import CutClassSpec, banded_assignment

        cc = spec.classes
        if cc.by == "explicit":
            class_of = cc.assign
            if len(class_of) != system.num_clients:
                raise ValueError(
                    "classes.assign must give one class id per client: "
                    f"{len(class_of)} != {system.num_clients}"
                )
        elif cc.by == "uplink":
            class_of = banded_assignment(system.model_up[0], cc.num_classes)
        else:  # "compute"
            class_of = banded_assignment(system.compute[0], cc.num_classes)
        # every class starts on BCD's evenly-spread anchor; the per-class
        # MS step moves them apart where heterogeneity pays.
        from ..core.bcd import default_init_cuts

        anchor = default_init_cuts(model_spec.n_units, system.M)
        num_classes = int(max(class_of)) + 1
        class_spec = CutClassSpec(
            class_of=tuple(class_of), cuts=(tuple(anchor),) * num_classes
        )

    return BuiltExperiment(
        spec=spec,
        model_spec=model_spec,
        profile=profile,
        system=system,
        hyper=hyper,
        eps=eps,
        compression=compression,
        compressor=compressor,
        trace=trace,
        base_problem=base,
        problem=problem,
        participation=participation,
        class_spec=class_spec,
        privacy=privacy_spec,
        dp_mechanism=dp_mechanism,
        energy=energy_spec,
        faults=fault_spec,
        guard=guard_spec,
    )
