"""The executable DP mechanism and its analytic spec (DESIGN.md §15).

``DPMechanism`` is the compressor-shaped stage Engine A applies to the
client→fed-server model uploads: each uploaded replica (axis 0 of a
stacked leaf) is L2-clipped to ``clip`` per leaf and perturbed with
per-coordinate Gaussian noise of std ``noise_multiplier · clip`` — the
noisy wire HierSFL (arXiv:2401.08723) places at exactly this boundary.
Noise keys fold the round counter and a trace-time leaf counter into one
base key, so every (round, leaf) draw is independent and a fixed seed
reproduces the run.  A ``noise_multiplier`` of 0 never constructs a
mechanism at all (``build()`` gates it), so the noiseless path executes
the pre-DP computation graph bit-for-bit.

``PrivacySpec`` is the analytic half the solvers consume: the per-round
noise mass σ²_DP = (z·C)²·dim joins Theorem 1's variance term (gated,
``convergence.bound_round_terms``), and the (ε, δ) budget becomes a
round cap through the accountant — ``HsflProblem.d_min()`` turns
R ≤ R_max into the denominator floor D ≥ 2θ₀/(γ·R_max).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

from .accountant import DEFAULT_ORDERS, Accountant


@dataclass(frozen=True)
class PrivacySpec:
    """Analytic view of the DP uplink: noise calibration + (ε, δ) budget.

    ``dim`` is the coordinate count of the noised upload (the full model
    parameter count in ``build()`` — an upper bound on the client-side
    upload at any cut, keeping the σ²-inflated bound an envelope).
    ``epsilon_budget`` None/inf means unconstrained accounting-wise.
    """

    noise_multiplier: float          # z = noise std / clip norm
    clip: float                      # C: per-leaf L2 clip on each upload
    delta: float = 1e-5
    epsilon_budget: Optional[float] = None
    dim: int = 1

    def __post_init__(self):
        if self.noise_multiplier < 0:
            raise ValueError(f"noise_multiplier < 0: {self.noise_multiplier}")
        if self.clip <= 0:
            raise ValueError(f"clip must be positive: {self.clip}")
        if not (0.0 < self.delta < 1.0):
            raise ValueError(f"delta outside (0, 1): {self.delta}")
        if self.epsilon_budget is not None and self.epsilon_budget <= 0:
            raise ValueError(
                f"epsilon_budget must be positive: {self.epsilon_budget}"
            )
        if self.dim < 1:
            raise ValueError(f"dim must be >= 1: {self.dim}")

    @property
    def dp_sigma2(self) -> float:
        """Per-round DP noise mass entering the Theorem-1 variance term.

        Exactly 0.0 when z = 0, so the gated bound terms vanish and the
        noiseless constants are bit-identical to the pre-DP arithmetic.
        """
        if self.noise_multiplier == 0.0:
            return 0.0
        return (self.noise_multiplier * self.clip) ** 2 * self.dim

    def accountant(self, sampling_rate: float = 1.0) -> Accountant:
        return Accountant(
            noise_multiplier=self.noise_multiplier,
            sampling_rate=sampling_rate,
            delta=self.delta,
            orders=DEFAULT_ORDERS,
        )

    def max_rounds(self, sampling_rate: float = 1.0) -> Optional[float]:
        """Round cap from the ε budget; None = unlimited."""
        if self.epsilon_budget is None or math.isinf(self.epsilon_budget):
            return None
        return self.accountant(sampling_rate).max_rounds(self.epsilon_budget)


@dataclass(frozen=True)
class DPMechanism:
    """Per-upload clip + Gaussian noise, applied leaf-wise on axis 0.

    ``transform(x, step, salt)`` treats ``x`` as ``[E, ...]`` stacked
    uploads: row e is scaled by min(1, clip/‖x_e‖₂) and perturbed with
    N(0, (z·clip)²) per coordinate.  ``step`` (the round counter, traced)
    and ``salt`` (a per-leaf trace-time counter) are folded into the seed
    so draws are independent across rounds and leaves yet reproducible.
    """

    clip: float
    noise_multiplier: float
    seed: int = 0

    def __post_init__(self):
        if self.clip <= 0:
            raise ValueError(f"clip must be positive: {self.clip}")
        if self.noise_multiplier < 0:
            raise ValueError(f"noise_multiplier < 0: {self.noise_multiplier}")

    def transform(self, x, step, salt: int = 0):
        import jax
        import jax.numpy as jnp

        flat = x.reshape((x.shape[0], -1))
        f32 = flat.astype(jnp.float32)
        norms = jnp.sqrt(jnp.sum(f32 * f32, axis=1))
        scale = jnp.minimum(1.0, self.clip / jnp.maximum(norms, 1e-12))
        out = f32 * scale[:, None]
        if self.noise_multiplier > 0.0:
            key = jax.random.fold_in(
                jax.random.fold_in(jax.random.PRNGKey(self.seed), salt),
                step,
            )
            out = out + self.noise_multiplier * self.clip * jax.random.normal(
                key, out.shape, dtype=out.dtype
            )
        return out.astype(x.dtype).reshape(x.shape)
