"""Shared bit-exactness check for the fused q8 aggregation path.

One definition of "the Pallas path matches its oracle", consumed by both
``tests/test_kernels_tiered.py`` and ``benchmarks/compress_sweep.py`` so a
wire-format or tolerance change can never leave one of them stale.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ...compress.quantize import q8_quantize
from .ops import tiered_aggregate_q8
from .ref import quantized_tiered_aggregate_ref
from .tiered_aggregate import quantized_tiered_aggregate_pallas


def assert_q8_matches_oracle(
    N: int, J: int, P: int, tile: int, seed: int = 0
) -> None:
    """Raise AssertionError unless, at this (N, J, P, tile) and every flag
    combination, (a) the interpret-mode Pallas kernel equals the
    tile-mirroring ref oracle bit-for-bit on one shared wire payload, and
    (b) the jit'd end-to-end entry's pallas and fallback branches agree
    bit-for-bit."""
    key = jax.random.PRNGKey(seed * 7919 + N * P)
    x = jax.random.normal(key, (N, P))
    w = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 1), (N,)))
    q, s = q8_quantize(x, tile)  # one shared wire payload for both paths
    for de in (0, 1):
        for dg in (0, 1):
            out = quantized_tiered_aggregate_pallas(
                q, s, w, jnp.array(de), jnp.array(dg), J,
                tile_p=tile, interpret=True,
            )
            ref = quantized_tiered_aggregate_ref(
                q, s, w, jnp.array(de), jnp.array(dg), J, tile
            )
            assert np.array_equal(np.asarray(out), np.asarray(ref)), (
                "pallas vs oracle", N, J, P, tile, de, dg,
            )
            a = tiered_aggregate_q8(
                x, w, jnp.array(de), jnp.array(dg), J, tile_p=tile,
                use_pallas=True, interpret=True,
            )
            b = tiered_aggregate_q8(
                x, w, jnp.array(de), jnp.array(dg), J, tile_p=tile,
                use_pallas=False,
            )
            assert np.array_equal(np.asarray(a), np.asarray(b)), (
                "entry branches", N, J, P, tile, de, dg,
            )
