"""Roofline analysis (deliverable g): three roofline terms per
(arch x shape x mesh) from the compiled dry-run artifacts.

Reads every record in experiments/dryrun/, derives

    compute term    = HLO_FLOPs            / peak_FLOP/s      (per chip)
    memory term     = HLO_bytes_accessed   / HBM_bw           (per chip)
    collective term = collective_bytes     / ICI link_bw      (per chip)

plus MODEL_FLOPS = 6 N D (train) / 2 N D (inference) with N = active
params, and the usefulness ratio MODEL_FLOPS / HLO_FLOPs. cost_analysis()
reports *per-device* FLOPs/bytes (verified: smollm train_4k halves when
the mesh doubles to 512 chips), and collective_bytes is the per-device
ring-traffic model from dryrun_lib - so all three terms are per-chip
seconds directly comparable against each other.

    PYTHONPATH=src python -m benchmarks.roofline [--mesh 16x16] [--tag baseline]
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

# TPU v5e hardware constants (per chip) - per the assignment brief.
PEAK_FLOPS = 197e12     # bf16
HBM_BW = 819e9          # bytes/s
ICI_BW = 50e9           # bytes/s per link

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")
# Rolled-scan compiles (archived): XLA's memory_analysis respects while-loop
# buffer reuse there, so the fits-check temp bytes come from these records;
# the unrolled records (experiments/dryrun) provide exact FLOPs/bytes/
# collective counts but inflate temp (no cross-iteration buffer reuse in
# the analysis).
ROLLED_DIR = os.path.join(
    os.path.dirname(__file__), "..", "experiments", "dryrun_rolled"
)


def model_flops_per_device(meta: Dict) -> float:
    """6·N_active·D for train, 2·N_active·D for inference, per device."""
    from repro.configs import get_spec

    spec = get_spec(meta["arch"])
    n_active = spec.active_param_count()
    if meta["kind"] == "train":
        tokens = meta["global_batch"] * meta["seq_len"]
        total = 6.0 * n_active * tokens
    elif meta["kind"] == "prefill":
        tokens = meta["global_batch"] * meta["seq_len"]
        total = 2.0 * n_active * tokens
    else:  # decode: one new token per sequence
        total = 2.0 * n_active * meta["global_batch"]
    return total / meta["num_devices"]


ADVICE = {
    "compute": "compute-bound: raise MFU (larger per-chip tiles, fuse "
               "elementwise chains, bf16 everywhere)",
    "memory": "memory-bound: cut HBM traffic (remat policy, bf16 params/"
              "activations, fuse producer-consumer chains)",
    "collective": "collective-bound: reshard (fewer all-gathers), overlap "
                  "collectives with compute, or aggregate less often "
                  "(larger I_m - the paper's own lever)",
}


def _rolled_temp_bytes(meta: Dict) -> Optional[int]:
    name = f"{meta['arch']}_{meta['shape']}_{meta['mesh']}_{meta.get('tag','baseline')}.json"
    path = os.path.join(ROLLED_DIR, name.replace("/", "-"))
    if os.path.exists(path):
        return json.load(open(path)).get("temp_bytes")
    return None


def analyse(meta: Dict) -> Dict:
    # attn_corr_flops: analytic correction for the blockwise-attention inner
    # scans that stay rolled (counted once by cost_analysis) - see dryrun_lib.
    flops = meta["flops"] + meta.get("attn_corr_flops", 0.0)
    c = flops / PEAK_FLOPS
    m = meta["bytes_accessed"] / HBM_BW
    k = meta["collective_bytes"] / ICI_BW
    dom = max(("compute", c), ("memory", m), ("collective", k), key=lambda t: t[1])
    mf = model_flops_per_device(meta)
    temp_bytes = meta.get("temp_bytes", 0)
    if meta.get("unrolled"):
        rolled = _rolled_temp_bytes(meta)
        if rolled is not None:
            temp_bytes = rolled
    return {
        "arch": meta["arch"], "shape": meta["shape"], "mesh": meta["mesh"],
        "tag": meta.get("tag", "baseline"),
        "compute_s": c, "memory_s": m, "collective_s": k,
        "dominant": dom[0], "bound_s": dom[1],
        "model_flops": mf, "hlo_flops": flops,
        "useful_ratio": mf / flops if flops else 0.0,
        "temp_gb": temp_bytes / 1e9,
        "advice": ADVICE[dom[0]],
    }


def load_records(mesh: Optional[str] = None, tag: Optional[str] = None) -> List[Dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        meta = json.load(open(f))
        if "flops" not in meta:
            continue
        if mesh and meta["mesh"] != mesh:
            continue
        if tag and meta.get("tag", "baseline") != tag:
            continue
        recs.append(meta)
    return recs


def main(argv=None) -> List[Dict]:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16",
                    help="mesh filter ('16x16', '2x16x16', or 'all')")
    ap.add_argument("--tag", default=None, help="tag filter (None = all tags)")
    ap.add_argument("--csv", default=None, help="also write CSV here")
    args = ap.parse_args(argv)

    mesh = None if args.mesh == "all" else args.mesh
    rows = [analyse(m) for m in load_records(mesh, args.tag)]
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["tag"]))

    hdr = (f"{'arch':24s} {'shape':12s} {'tag':14s} {'compute_s':>10s} "
           f"{'memory_s':>10s} {'collect_s':>10s} {'dominant':>10s} "
           f"{'useful':>7s} {'temp_GB':>8s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(f"{r['arch']:24s} {r['shape']:12s} {r['tag']:14s} "
              f"{r['compute_s']:10.3e} {r['memory_s']:10.3e} "
              f"{r['collective_s']:10.3e} {r['dominant']:>10s} "
              f"{r['useful_ratio']:7.3f} {r['temp_gb']:8.2f}")

    if args.csv:
        import csv

        keys = ["arch", "shape", "mesh", "tag", "compute_s", "memory_s",
                "collective_s", "dominant", "model_flops", "hlo_flops",
                "useful_ratio", "temp_gb", "advice"]
        with open(args.csv, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=keys, extrasaction="ignore")
            w.writeheader()
            w.writerows(rows)
        print(f"csv -> {args.csv}")
    return rows


if __name__ == "__main__":
    main()
