"""Heterogeneity-aware per-class cut assignment (HASFL-style, DESIGN.md §14).

The paper optimizes ONE model-splitting vector μ for the whole fleet.  When
device capabilities span orders of magnitude (the lognormal spreads the
fleet simulator generates), a single cut leaves speed on the table: slow
clients want shallow client-side stacks, fast clients can host more.  This
module lets *client classes* hold different split points:

* :class:`CutClassSpec` — the assignment (clients → classes) plus one cut
  vector per class;
* scalar oracle functions (``class_split_T`` / ``class_agg_T`` /
  ``class_tier_d`` / ``class_theta``) that price a per-class schedule with
  the exact arithmetic of ``HsflProblem`` — a single class collapses
  bit-for-bit to the single-cut objective;
* :class:`ClassBatchedEvaluator` — the whole *product* of per-class cut
  lattices ``[K₁×…×K_C]`` evaluated as array arithmetic over assignment
  index matrices (numpy|jax chain backends, same tables as
  ``core.batched``);
* ``solve_ms_classes`` / ``solve_ma_classes`` / ``solve_bcd_classes`` —
  the per-class MS/MA/BCD solvers.  MS enumerates the full lattice product
  when it fits the row budget and otherwise coordinate-descends over
  classes from the single-cut optimum (so the per-class objective is never
  worse than the best single cut, by construction).

Objective semantics.  The round latency T_S is the max over *all* clients
of the canonical stage chain priced at each client's own class cuts.  A
tier-m fed-server sync moves, per entity, the *union* of its member
classes' tier-m unit ranges ``[min_c lo_c, max_c hi_c)`` — clients in one
entity group disagreeing on which units are client-side still synchronize
through one upload whose payload covers every member's tier-m slice (the
ragged aggregation of ``tiers.ragged_synchronize``).  The bound denominator
uses the class-weighted drift mass d̄_m = Σ_c (n_c/N)·d_m(μ_c)
(``convergence.class_weighted_G2_sums``).  Memory (C5) must hold for every
entity's union range.

Trace-based ``latency_model`` pricing of per-class cuts is not implemented
(the attached models price one cut vector per row); constructing a
per-class problem over a trace raises with a pointer here.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..compress.base import model_ratio
from .batched import (
    lattice_bounds,
    nominal_stage_rates,
    resolve_backend,
    split_work_tensor,
    tier_d_lattice,
)
from .convergence import class_weighted_G2_sums
from .latency import BITS, per_client_split_latency
from .ma_solver import MaSolution, _candidate_intervals, _theta_candidates
from .ms_solver import _INFEASIBLE_MSG, solve_ms
from .problem import INFEASIBLE, HsflProblem

try:
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    _HAS_JAX = True
except Exception:  # pragma: no cover - jax-less installs
    _HAS_JAX = False


_LATENCY_MODEL_MSG = (
    "per-class cuts are priced nominally: the attached latency_model's "
    "trace tables price one cut vector per lattice row, not a per-class "
    "assignment (price the scenario into the SystemSpec rates instead, "
    "e.g. the 'lognormal-fleet' preset)"
)


# --------------------------------------------------------------------------- #
# the assignment spec
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class CutClassSpec:
    """Clients → classes, plus one cut vector per class.

    ``class_of[i]`` is client i's class id (contiguous ``0..C-1``, every
    class non-empty); ``cuts[c]`` is class c's M-1 cut boundaries.  The
    class *membership* is the search-space structure (it fixes which
    lattice product is optimized and how entities aggregate ragged
    ranges); the per-class ``cuts`` are the decision variables the MS
    solver moves.
    """

    class_of: Tuple[int, ...]              # [N]
    cuts: Tuple[Tuple[int, ...], ...]      # [C][M-1]

    def __post_init__(self):
        object.__setattr__(
            self, "class_of", tuple(int(c) for c in self.class_of)
        )
        object.__setattr__(
            self, "cuts", tuple(tuple(int(x) for x in cc) for cc in self.cuts)
        )
        C = len(self.cuts)
        if C == 0:
            raise ValueError("CutClassSpec needs at least one class")
        ids = set(self.class_of)
        if ids != set(range(C)):
            raise ValueError(
                f"class_of must use contiguous ids 0..{C - 1} with every "
                f"class non-empty; got ids {sorted(ids)} for {C} cut vectors"
            )
        width = len(self.cuts[0])
        for c, cc in enumerate(self.cuts):
            if len(cc) != width:
                raise ValueError(
                    f"every class needs the same number of cuts: class {c} "
                    f"has {len(cc)}, class 0 has {width}"
                )
            if any(cc[i] > cc[i + 1] for i in range(len(cc) - 1)):
                raise ValueError(
                    f"class {c} cuts must be non-decreasing (C4): {cc!r}"
                )
            if any(x < 0 for x in cc):
                raise ValueError(f"class {c} cuts must be >= 0: {cc!r}")

    @property
    def num_classes(self) -> int:
        return len(self.cuts)

    @property
    def num_clients(self) -> int:
        return len(self.class_of)

    def class_sizes(self) -> Tuple[int, ...]:
        sizes = [0] * self.num_classes
        for c in self.class_of:
            sizes[c] += 1
        return tuple(sizes)

    def weights(self) -> np.ndarray:
        """Client-share weights w_c = n_c / N ``[C]``."""
        n = float(self.num_clients)
        return np.array([s / n for s in self.class_sizes()], dtype=np.float64)

    def members(self, c: int) -> np.ndarray:
        """Client indices of class c (sorted)."""
        return np.flatnonzero(np.asarray(self.class_of) == c)

    def client_cuts(self) -> np.ndarray:
        """``[N, M-1]`` each client's own cut vector."""
        table = np.asarray(self.cuts, dtype=np.int64)
        return table[np.asarray(self.class_of)]

    def with_cuts(
        self, cuts: Sequence[Sequence[int]]
    ) -> "CutClassSpec":
        return CutClassSpec(self.class_of, tuple(tuple(c) for c in cuts))

    def is_uniform(self) -> bool:
        """True when every class holds the same cut vector (the spec
        collapses to a single-cut schedule)."""
        return len(set(self.cuts)) == 1

    # ------------------------------------------------------------------ #
    # constructors
    # ------------------------------------------------------------------ #

    @classmethod
    def uniform(
        cls, num_clients: int, num_classes: int, cuts: Sequence[int]
    ) -> "CutClassSpec":
        """Contiguous equal blocks of clients, every class at ``cuts``."""
        assign = banded_assignment(np.arange(num_clients), num_classes)
        return cls(tuple(assign), tuple(tuple(cuts) for _ in range(num_classes)))

    @classmethod
    def from_rates(
        cls,
        rates: Sequence[float],
        num_classes: int,
        cuts: Sequence[int],
    ) -> "CutClassSpec":
        """Band clients into ``num_classes`` by sorted rate (slowest class
        first), every class initialized at ``cuts``."""
        assign = banded_assignment(np.asarray(rates, dtype=float), num_classes)
        return cls(tuple(assign), tuple(tuple(cuts) for _ in range(num_classes)))


def banded_assignment(rates: np.ndarray, num_classes: int) -> np.ndarray:
    """``[N]`` class ids: sort clients by rate, split into ``num_classes``
    contiguous bands of (near-)equal size — slowest band is class 0.

    Deterministic: ties broken by client index (stable argsort), remainder
    clients spread over the leading bands.
    """
    N = len(rates)
    if not 1 <= num_classes <= N:
        raise ValueError(
            f"num_classes must lie in [1, num_clients={N}]: {num_classes}"
        )
    order = np.argsort(np.asarray(rates), kind="stable")
    base, rem = divmod(N, num_classes)
    assign = np.empty(N, dtype=np.int64)
    start = 0
    for c in range(num_classes):
        size = base + (1 if c < rem else 0)
        assign[order[start : start + size]] = c
        start += size
    return assign


# --------------------------------------------------------------------------- #
# scalar oracle: exact per-class objective pieces (mirrors HsflProblem)
# --------------------------------------------------------------------------- #


def _check_nominal(problem: HsflProblem) -> None:
    if problem.latency_model is not None:
        raise ValueError(_LATENCY_MODEL_MSG)


def _entity_unions(
    spec: CutClassSpec, bounds: np.ndarray, m: int, J: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Per-entity tier-m union unit ranges ``([J], [J])``.

    ``bounds`` is the [C, M+1] per-class tier-boundary table.  Entity j of
    a J-entity tier hosts clients ``[j·per, (j+1)·per)``; its tier-m slice
    must cover every member class's ``[lo_c, hi_c)``.
    """
    N = spec.num_clients
    per = N // J
    cls = np.asarray(spec.class_of).reshape(J, per)
    lo = bounds[cls, m].min(axis=1)
    hi = bounds[cls, m + 1].max(axis=1)
    return lo, hi


def _class_bounds(spec: CutClassSpec, n_units: int) -> np.ndarray:
    """``[C, M+1]`` per-class tier boundaries 0 | cuts | U."""
    C = spec.num_classes
    table = np.zeros((C, len(spec.cuts[0]) + 2), dtype=np.int64)
    for c, cc in enumerate(spec.cuts):
        table[c] = [0, *cc, n_units]
    return table


def class_split_T(problem: HsflProblem, spec: CutClassSpec) -> float:
    """T_S under per-class cuts: max over clients of the canonical chain
    priced at each client's own class cuts (deadline-capped like
    ``HsflProblem.split_T``)."""
    _check_nominal(problem)
    t = -np.inf
    for c in range(spec.num_classes):
        per_client = per_client_split_latency(
            problem.profile, problem.system, spec.cuts[c],
            problem.compression, problem.retry_mult,
        )
        t = max(t, float(np.max(per_client[spec.members(c)])))
    pp = problem.participation
    if pp is not None and pp.deadline is not None:
        t = min(t, pp.deadline)
    return t


def class_agg_T(problem: HsflProblem, spec: CutClassSpec) -> np.ndarray:
    """``[M-1]`` T_{m,A} with per-entity union payloads.

    Entity j's tier-m upload carries the union of its member classes'
    tier-m slices; the per-entity payload bytes read the same param-bytes
    prefix table as the single-cut path (plus the m=0 frontend extra), so
    identical classes reproduce ``aggregation_latency`` bit-for-bit.
    """
    _check_nominal(problem)
    system, profile = problem.system, problem.profile
    M = problem.M
    bounds = _class_bounds(spec, profile.n_units)
    pb = profile.prefix.param_bytes
    out = np.zeros(M - 1)
    for m in range(M - 1):
        J = system.entities[m]
        if J <= 1:
            continue  # Eq. (15)/(16) indicator
        lo, hi = _entity_unions(spec, bounds, m, J)
        lam = pb[hi] - pb[lo]
        if m == 0:
            lam = lam + profile.frontend_param_bytes
        lam = lam * BITS * model_ratio(problem.compression, m)
        if problem.retry_mult is not None:
            lam = lam * problem.retry_mult
        up = lam / system.model_up[m]
        down = lam / system.model_down[m]
        out[m] = float(np.max(up)) + float(np.max(down))
    return out


def class_memory_ok(problem: HsflProblem, spec: CutClassSpec) -> bool:
    """C5 for per-class cuts: every entity must host its union slice."""
    _check_nominal(problem)
    system, profile = problem.system, problem.profile
    N = system.num_clients
    bounds = _class_bounds(spec, profile.n_units)
    px = profile.prefix
    for m in range(system.M):
        J = system.entities[m]
        hosted = N // J
        lo, hi = _entity_unions(spec, bounds, m, J)
        per_model = (
            (px.act_bytes[hi] - px.act_bytes[lo])
            + (px.grad_act_bytes[hi] - px.grad_act_bytes[lo])
        ) * profile.batch + (
            (px.param_bytes[hi] - px.param_bytes[lo])
            + (px.opt_bytes[hi] - px.opt_bytes[lo])
        )
        if m == 0:
            per_model = per_model + profile.frontend_param_bytes
        if m == system.M - 1:
            per_model = per_model + profile.head_param_bytes
        if np.any(hosted * per_model >= system.memory[m]):
            return False
    return True


def class_tier_d(problem: HsflProblem, spec: CutClassSpec) -> np.ndarray:
    """``[M]`` class-weighted drift mass d̄_m (1/q_m-inflated under partial
    participation, like ``HsflProblem.tier_d``)."""
    d = class_weighted_G2_sums(
        problem.hyper.G2, spec.cuts, spec.weights()
    )
    if problem.participation is not None:
        d = d / problem.q
    return d


def class_denominator(
    problem: HsflProblem, spec: CutClassSpec, intervals: Sequence[int]
) -> float:
    c, kappa = problem.constants()
    d = class_tier_d(problem, spec)
    s = sum(
        (I**2) * dm
        for I, dm in zip(intervals[: problem.M - 1], d[: problem.M - 1])
        if I > 1
    )
    return c - kappa * s


def class_numerator(
    problem: HsflProblem, spec: CutClassSpec, intervals: Sequence[int]
) -> float:
    b = class_agg_T(problem, spec)
    return class_split_T(problem, spec) + float(
        np.sum(b / np.asarray(intervals[: problem.M - 1], dtype=float))
    )


def class_split_energy(problem: HsflProblem, spec: CutClassSpec) -> float:
    """Fleet split energy under per-class cuts: the class-share-weighted
    mean Σ_c w_c·E_S(μ_c), accumulated in class order (the
    ``class_weighted_G2_sums`` shape, so the batched per-class tables
    reproduce it bit-for-bit)."""
    from ..energy import split_energy

    w = spec.weights()
    e = w[0] * split_energy(
        problem.profile, problem.system, problem.energy, spec.cuts[0],
        problem.compression,
    )
    for c in range(1, spec.num_classes):
        e = e + w[c] * split_energy(
            problem.profile, problem.system, problem.energy, spec.cuts[c],
            problem.compression,
        )
    return float(e)


def class_agg_energy(problem: HsflProblem, spec: CutClassSpec) -> np.ndarray:
    """``[M-1]`` fed-server sync energy with per-entity union payloads —
    the energy counterpart of ``class_agg_T`` (same λ bytes, priced
    2 × J/byte over every entity instead of max-latency)."""
    system, profile = problem.system, problem.profile
    en = problem.energy
    M = problem.M
    bounds = _class_bounds(spec, profile.n_units)
    pb = profile.prefix.param_bytes
    out = np.zeros(M - 1)
    for m in range(M - 1):
        J = system.entities[m]
        if J <= 1:
            continue  # Eq. (15)/(16) indicator
        lo, hi = _entity_unions(spec, bounds, m, J)
        lam = pb[hi] - pb[lo]
        if m == 0:
            lam = lam + profile.frontend_param_bytes
        lam = lam * BITS * model_ratio(problem.compression, m)
        price = 2.0 * en.model_j_per_byte[m] / BITS
        out[m] = float(np.sum(lam * price))
    return out


def class_round_energy(
    problem: HsflProblem, spec: CutClassSpec, intervals: Sequence[int]
) -> Optional[float]:
    """E(I, {μ_c}) — amortized like ``energy.round_energy`` (None without
    an attached EnergySpec)."""
    if problem.energy is None:
        return None
    e = class_split_energy(problem, spec)
    b = class_agg_energy(problem, spec)
    acc = b[0] / float(intervals[0])
    for m in range(1, problem.M - 1):
        acc = acc + b[m] / float(intervals[m])
    return float(e + acc)


def class_energy_feasible(
    problem: HsflProblem, spec: CutClassSpec, intervals: Sequence[int]
) -> bool:
    """E ≤ budget; vacuously True without a spec or budget."""
    if problem.energy is None or problem.energy.budget_j_per_round is None:
        return True
    return (
        class_round_energy(problem, spec, intervals)
        <= problem.energy.budget_j_per_round
    )


def class_theta(
    problem: HsflProblem, spec: CutClassSpec, intervals: Sequence[int]
) -> float:
    """Exact Θ'(I, {μ_c}); +inf when infeasible — the scalar oracle the
    batched product evaluation must match bit-for-bit (the arithmetic
    mirrors ``HsflProblem.theta`` term for term, including the privacy
    D-floor and the energy budget mask of DESIGN.md §15)."""
    if not class_memory_ok(problem, spec):
        return INFEASIBLE
    D = class_denominator(problem, spec, intervals)
    if D <= problem.d_min():
        return INFEASIBLE
    if not class_energy_feasible(problem, spec, intervals):
        return INFEASIBLE
    return (
        2.0
        * problem.hyper.theta0
        / problem.hyper.gamma
        * class_numerator(problem, spec, intervals)
        / D
    )


def class_rounds(
    problem: HsflProblem, spec: CutClassSpec, intervals: Sequence[int]
) -> Optional[float]:
    D = class_denominator(problem, spec, intervals)
    if D <= problem.d_min():
        return None
    return 2.0 * problem.hyper.theta0 / (problem.hyper.gamma * D)


def class_total_T(
    problem: HsflProblem,
    spec: CutClassSpec,
    intervals: Sequence[int],
    R: float,
) -> float:
    """T(I, {μ_c}) of Eq. (19) under per-class pricing."""
    tot = R * class_split_T(problem, spec)
    b = class_agg_T(problem, spec)
    for m in range(problem.M - 1):
        tot += np.floor(R / intervals[m]) * b[m]
    return float(tot)


# --------------------------------------------------------------------------- #
# batched product evaluation
# --------------------------------------------------------------------------- #


def chain_matrix(
    works: np.ndarray, rates: Sequence[np.ndarray], backend: str = "numpy"
) -> np.ndarray:
    """``[K, N]`` per-client chain sums Σ_s work/rate in stage order — the
    pre-max form of ``batched.accumulate_chain`` (per-class maxima need
    the per-client column structure)."""
    if backend == "jax" and _HAS_JAX:
        with enable_x64():
            return np.asarray(
                _chain_matrix_jit(
                    jnp.asarray(works), jnp.asarray(np.stack(rates, axis=0))
                )
            )
    t = np.zeros((works.shape[0], rates[0].shape[0]))
    for s, r in enumerate(rates):
        t = t + works[:, s][:, None] / r[None, :]
    return t


if _HAS_JAX:

    @jax.jit
    def _chain_matrix_jit(works, rates):  # works [K, S], rates [S, N]
        t = jnp.zeros((works.shape[0], rates.shape[1]), dtype=works.dtype)
        for s in range(rates.shape[0]):
            t = t + works[:, s][:, None] / rates[s][None, :]
        return t


def product_assignments(K: int, C: int) -> np.ndarray:
    """``[K^C, C]`` all class→lattice-row assignments, class 0 slowest
    (lexicographic row order — first-tie argmins are deterministic)."""
    grids = np.meshgrid(*([np.arange(K)] * C), indexing="ij")
    return np.stack([g.ravel() for g in grids], axis=1)


class ClassBatchedEvaluator:
    """Product-lattice Θ' evaluation for one (problem, class membership).

    Tables depend on the class *membership* only (never on the per-class
    cut values), so one evaluator serves every MS solve inside a BCD run:

    * ``split_class`` [C, K] — per-class member-max chain latency over the
      shared cut lattice (deadline-capped), from the same ``[K, S]`` work
      tensor and chain accumulation order as ``BatchedEvaluator``;
    * ``d_tab`` [K, M-1] — the tier-G² gather (class weighting happens per
      assignment row);
    * per-tier entity → member-class index lists for the union payloads.

    ``theta_rows(assign, intervals)`` prices ``[R, C]`` assignment index
    matrices; a single class (C=1) reproduces the single-cut
    ``BatchedEvaluator`` tables bit-for-bit, which is what makes
    ``solve_ms_classes`` collapse exactly to ``solve_ms``.
    """

    def __init__(
        self,
        problem: HsflProblem,
        spec: CutClassSpec,
        backend: str = "auto",
    ):
        _check_nominal(problem)
        if spec.num_clients != problem.system.num_clients:
            raise ValueError(
                f"spec assigns {spec.num_clients} clients but the system "
                f"has {problem.system.num_clients}"
            )
        self.problem = problem
        self.class_of = spec.class_of
        self.C = spec.num_classes
        lattice = problem.cut_lattice()
        self.lattice = lattice
        M = problem.M
        self.backend = resolve_backend(
            backend, work_elems=lattice.shape[0] * problem.system.num_clients
        )
        self.bnds = lattice_bounds(lattice, problem.n_units)  # [K, M+1]
        works = split_work_tensor(
            problem.profile, lattice, problem.compression, problem.retry_mult
        )
        rates = nominal_stage_rates(problem.system, M)
        t = chain_matrix(works, rates, self.backend)  # [K, N]
        members = [
            np.flatnonzero(np.asarray(spec.class_of) == c)
            for c in range(self.C)
        ]
        self.split_class = np.stack(
            [t[:, idx].max(axis=1) for idx in members]
        )  # [C, K]
        pp = problem.participation
        if pp is not None and pp.deadline is not None:
            self.split_class = np.minimum(self.split_class, pp.deadline)
        self.d_tab = tier_d_lattice(problem.hyper.G2, lattice)[:, : M - 1]
        self.w = spec.weights()
        self.q = problem.q
        self.c, self.kappa = problem.constants()
        self.scale = 2.0 * problem.hyper.theta0 / problem.hyper.gamma
        # privacy D-floor + energy pricing (DESIGN.md §15): 0.0 / None when
        # unconstrained, keeping theta_rows bit-identical to the pre-§15 path
        self.d_min = problem.d_min()
        en = problem.energy
        self.energy_budget = None if en is None else en.budget_j_per_round
        if en is not None:
            from ..energy import split_energy_lattice

            self.e_split_tab = split_energy_lattice(
                problem.profile, problem.system, en, lattice,
                problem.compression,
            )
        else:
            self.e_split_tab = None
        # entity j of a J-entity tier hosts classes self._entity_classes[J][j]
        self._entity_classes: Dict[int, List[np.ndarray]] = {}
        N = spec.num_clients
        for J in set(problem.system.entities):
            per = N // J
            cls = np.asarray(spec.class_of).reshape(J, per)
            self._entity_classes[J] = [np.unique(cls[j]) for j in range(J)]

    @property
    def K(self) -> int:
        return self.lattice.shape[0]

    def cuts_at(self, assign: Sequence[int]) -> Tuple[Tuple[int, ...], ...]:
        return tuple(
            tuple(int(x) for x in self.lattice[k]) for k in assign
        )

    def split_T(self, assign: np.ndarray) -> np.ndarray:
        """[R] T_S — max over classes of the member-max chain latency."""
        t = self.split_class[0][assign[:, 0]]
        for c in range(1, self.C):
            t = np.maximum(t, self.split_class[c][assign[:, c]])
        return t

    def _unions(
        self, assign: np.ndarray, m: int, J: int
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Per-entity union unit ranges ``([R, J], [R, J])`` for tier m."""
        blo = self.bnds[:, m]
        bhi = self.bnds[:, m + 1]
        Blo = blo[assign]  # [R, C]
        Bhi = bhi[assign]
        R = assign.shape[0]
        lo = np.empty((R, J), dtype=np.int64)
        hi = np.empty((R, J), dtype=np.int64)
        for j, cls in enumerate(self._entity_classes[J]):
            lo[:, j] = Blo[:, cls].min(axis=1)
            hi[:, j] = Bhi[:, cls].max(axis=1)
        return lo, hi

    def agg_T(self, assign: np.ndarray) -> np.ndarray:
        """[R, M-1] T_{m,A} with per-entity union payloads."""
        problem = self.problem
        system, profile = problem.system, problem.profile
        M = problem.M
        pb = profile.prefix.param_bytes
        out = np.zeros((assign.shape[0], M - 1))
        for m in range(M - 1):
            J = system.entities[m]
            if J <= 1:
                continue
            lo, hi = self._unions(assign, m, J)
            lam = pb[hi] - pb[lo]
            if m == 0:
                lam = lam + profile.frontend_param_bytes
            lam = lam * BITS * model_ratio(problem.compression, m)
            if problem.retry_mult is not None:
                lam = lam * problem.retry_mult
            out[:, m] = (lam / system.model_up[m][None, :]).max(axis=1) + (
                lam / system.model_down[m][None, :]
            ).max(axis=1)
        return out

    def mem_ok(self, assign: np.ndarray) -> np.ndarray:
        """[R] bool — C5 over every entity's union range."""
        problem = self.problem
        system, profile = problem.system, problem.profile
        N = system.num_clients
        px = profile.prefix
        ok = np.ones(assign.shape[0], dtype=bool)
        for m in range(system.M):
            J = system.entities[m]
            hosted = N // J
            lo, hi = self._unions(assign, m, J)
            per_model = (
                (px.act_bytes[hi] - px.act_bytes[lo])
                + (px.grad_act_bytes[hi] - px.grad_act_bytes[lo])
            ) * profile.batch + (
                (px.param_bytes[hi] - px.param_bytes[lo])
                + (px.opt_bytes[hi] - px.opt_bytes[lo])
            )
            if m == 0:
                per_model = per_model + profile.frontend_param_bytes
            if m == system.M - 1:
                per_model = per_model + profile.head_param_bytes
            ok &= np.all(
                hosted * per_model < system.memory[m][None, :], axis=1
            )
        return ok

    def tier_d(self, assign: np.ndarray) -> np.ndarray:
        """[R, M-1] class-weighted d̄ (1/q-inflated) — multiply-add in
        class order, matching ``class_weighted_G2_sums``."""
        d = self.w[0] * self.d_tab[assign[:, 0]]
        for c in range(1, self.C):
            d = d + self.w[c] * self.d_tab[assign[:, c]]
        if self.problem.participation is not None:
            d = d / self.q[: d.shape[1]][None, :]
        return d

    def numerator(self, assign: np.ndarray, intervals: Sequence[int]) -> np.ndarray:
        agg = self.agg_T(assign)
        acc = agg[:, 0] / float(intervals[0])
        for m in range(1, self.problem.M - 1):
            acc = acc + agg[:, m] / float(intervals[m])
        return self.split_T(assign) + acc

    def denominator(self, assign: np.ndarray, intervals: Sequence[int]) -> np.ndarray:
        d = self.tier_d(assign)
        s = np.zeros(assign.shape[0])
        for m in range(self.problem.M - 1):
            I = int(intervals[m])
            if I > 1:
                s = s + (I**2) * d[:, m]
        return self.c - self.kappa * s

    def agg_energy(self, assign: np.ndarray) -> np.ndarray:
        """[R, M-1] sync energy with per-entity union payloads — the
        batched counterpart of ``class_agg_energy`` (same λ·price order)."""
        problem = self.problem
        system, profile = problem.system, problem.profile
        en = problem.energy
        M = problem.M
        pb = profile.prefix.param_bytes
        out = np.zeros((assign.shape[0], M - 1))
        for m in range(M - 1):
            J = system.entities[m]
            if J <= 1:
                continue
            lo, hi = self._unions(assign, m, J)
            lam = pb[hi] - pb[lo]
            if m == 0:
                lam = lam + profile.frontend_param_bytes
            lam = lam * BITS * model_ratio(problem.compression, m)
            price = 2.0 * en.model_j_per_byte[m] / BITS
            out[:, m] = np.sum(lam * price, axis=1)
        return out

    def round_energy_rows(
        self, assign: np.ndarray, intervals: Sequence[int]
    ) -> Optional[np.ndarray]:
        """[R] E(I, {μ_c}) — class-order weighted split tables plus the
        amortized union sync energy, matching ``class_round_energy``."""
        if self.e_split_tab is None:
            return None
        e = self.w[0] * self.e_split_tab[assign[:, 0]]
        for c in range(1, self.C):
            e = e + self.w[c] * self.e_split_tab[assign[:, c]]
        agg = self.agg_energy(assign)
        acc = agg[:, 0] / float(intervals[0])
        for m in range(1, self.problem.M - 1):
            acc = acc + agg[:, m] / float(intervals[m])
        return e + acc

    def theta_rows(
        self, assign: np.ndarray, intervals: Sequence[int]
    ) -> np.ndarray:
        """[R] Θ' in the Dinkelbach q-order ``scale · (N/D)`` — the order
        ``solve_ms`` reports, so the C=1 collapse is bit-exact against the
        single-cut MS optimum; +inf where C5 fails, D ≤ d_min, or the
        round energy overruns the budget."""
        D = self.denominator(assign, intervals)
        N_ = self.numerator(assign, intervals)
        th = np.full(assign.shape[0], INFEASIBLE)
        ok = self.mem_ok(assign) & (D > self.d_min)
        if self.energy_budget is not None:
            ok = ok & (
                self.round_energy_rows(assign, intervals) <= self.energy_budget
            )
        th[ok] = self.scale * (N_[ok] / D[ok])
        return th


# --------------------------------------------------------------------------- #
# solvers
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class ClassMsSolution:
    cuts: Tuple[Tuple[int, ...], ...]   # [C][M-1]
    theta: float
    exhaustive: bool                    # full product vs coordinate descent
    rows_evaluated: int = 0


@dataclass(frozen=True)
class ClassBcdResult:
    intervals: Tuple[int, ...]
    spec: CutClassSpec                  # final per-class cuts
    theta: float
    rounds: float
    total_latency: float
    history: Tuple[float, ...] = ()

    @property
    def class_cuts(self) -> Tuple[Tuple[int, ...], ...]:
        return self.spec.cuts


def solve_ms_classes(
    problem: HsflProblem,
    spec: CutClassSpec,
    intervals: Sequence[int],
    backend: str = "auto",
    product_budget: int = 200_000,
    max_sweeps: int = 16,
    evaluator: Optional[ClassBatchedEvaluator] = None,
) -> ClassMsSolution:
    """Optimal per-class cuts for fixed intervals.

    When the full lattice product ``K^C`` fits ``product_budget`` rows the
    objective is evaluated for *every* assignment in one batched pass and
    the argmin is exact.  Otherwise: coordinate descent over classes,
    seeded at the single-cut Dinkelbach optimum (every class at μ*), each
    step re-optimizing one class's row over the full ``[K]`` lattice with
    the others fixed — Θ' is non-increasing from the single-cut optimum,
    so the result is never worse than the best single cut.
    """
    ev = evaluator or ClassBatchedEvaluator(problem, spec, backend)
    K, C = ev.K, ev.C
    if K == 0:
        raise ValueError(_INFEASIBLE_MSG)
    if float(K) ** C <= product_budget:
        A = product_assignments(K, C)
        th = ev.theta_rows(A, intervals)
        j = int(np.argmin(th))
        if not np.isfinite(th[j]):
            raise ValueError(_INFEASIBLE_MSG)
        return ClassMsSolution(
            cuts=ev.cuts_at(A[j]),
            theta=float(th[j]),
            exhaustive=True,
            rows_evaluated=A.shape[0],
        )
    # coordinate descent from the single-cut optimum diagonal
    ms = solve_ms(problem, intervals, backend=backend)
    k0 = np.flatnonzero(
        (ev.lattice == np.asarray(ms.cuts)).all(axis=1)
    )
    assign = np.full(C, int(k0[0]) if k0.size else 0, dtype=np.int64)
    best = float(ev.theta_rows(assign[None, :], intervals)[0])
    rows = 1
    for _ in range(max_sweeps):
        improved = False
        for c in range(C):
            cand = np.tile(assign, (K, 1))
            cand[:, c] = np.arange(K)
            th = ev.theta_rows(cand, intervals)
            rows += K
            j = int(np.argmin(th))
            if th[j] < best:
                best = float(th[j])
                assign[c] = j
                improved = True
        if not improved:
            break
    if not np.isfinite(best):
        raise ValueError(_INFEASIBLE_MSG)
    return ClassMsSolution(
        cuts=ev.cuts_at(assign),
        theta=best,
        exhaustive=False,
        rows_evaluated=rows,
    )


def solve_ma_classes(
    problem: HsflProblem,
    spec: CutClassSpec,
    i_max: int = 10_000,
    backend: str = "auto",
) -> MaSolution:
    """Optimal MA intervals for fixed per-class cuts — Proposition 1 with
    the class-priced scalars (a, b, d̄) in the shared candidate machinery
    of ``ma_solver`` (same enumeration order, same vectorized Θ' pass)."""
    if backend != "scalar":
        resolve_backend(backend)
    M = problem.M
    a = class_split_T(problem, spec)
    b = class_agg_T(problem, spec)
    c, kappa = problem.constants()
    d = class_tier_d(problem, spec)[: M - 1]
    cands = _candidate_intervals(M, a, b, c, kappa, d, i_max)
    if problem.energy is not None and problem.energy.budget_j_per_round is not None:
        e_split: Optional[float] = class_split_energy(problem, spec)
        e_agg: Optional[np.ndarray] = class_agg_energy(problem, spec)
    else:
        e_split, e_agg = None, None
    best: Optional[MaSolution] = None
    if cands:
        arr = np.asarray(cands, dtype=np.int64)
        th = _theta_candidates(
            problem, class_memory_ok(problem, spec), a, b, c, kappa, d, arr,
            e_split, e_agg,
        )
        i = int(np.argmin(th))
        if th[i] < INFEASIBLE:
            best = MaSolution(
                tuple(int(x) for x in arr[i]) + (1,), float(th[i])
            )
    if best is None:
        ones = tuple([1] * (M - 1)) + (1,)
        return MaSolution(ones, class_theta(problem, spec, list(ones)))
    return best


def solve_bcd_classes(
    problem: HsflProblem,
    spec: CutClassSpec,
    init_intervals: Optional[Sequence[int]] = None,
    tol: float = 1e-6,
    max_iters: int = 50,
    backend: str = "auto",
    product_budget: int = 200_000,
) -> ClassBcdResult:
    """Per-class BCD: alternate Proposition-1 intervals and product-lattice
    cuts until |ΔΘ'| ≤ tol, exactly the ``solve_bcd`` alternation with the
    class-priced sub-solvers.  The evaluator tables (class membership ×
    lattice) are built once and shared across every MS solve."""
    M = problem.M
    cur = spec
    intervals = (
        tuple(init_intervals) if init_intervals else tuple([1] * M)
    )
    ev = ClassBatchedEvaluator(problem, cur, backend)
    history: List[float] = []
    theta = class_theta(problem, cur, intervals)
    for _ in range(max_iters):
        ma = solve_ma_classes(problem, cur, backend=backend)
        intervals = ma.intervals
        ms = solve_ms_classes(
            problem, cur, intervals,
            backend=backend, product_budget=product_budget, evaluator=ev,
        )
        cur = cur.with_cuts(ms.cuts)
        new_theta = class_theta(problem, cur, intervals)
        history.append(new_theta)
        if theta < INFEASIBLE and abs(theta - new_theta) <= tol * max(
            1.0, abs(theta)
        ):
            theta = new_theta
            break
        theta = new_theta
    R = class_rounds(problem, cur, intervals)
    T = class_total_T(problem, cur, intervals, R)
    return ClassBcdResult(
        intervals=tuple(intervals),
        spec=cur,
        theta=theta,
        rounds=float(R),
        total_latency=float(T),
        history=tuple(history),
    )
