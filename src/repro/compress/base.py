"""Compression contract shared by every altitude (DESIGN.md §9).

Two views of the same knob:

* ``Compressor`` — the *executable* view: a lossy ``transform`` (the exact
  compress → wire → decompress round trip) plus the two scalars the
  analytic layer prices it with: ``ratio`` (wire bytes / raw f32 bytes,
  enters Eqs. 12–16) and ``omega`` (relative compression-error second
  moment ω = sup_x E‖C(x) − x‖² / ‖x‖², inflates the σ² term of
  Theorem 1).  Engines A/B apply ``transform`` on the fed-server tier
  boundaries; the quantized Pallas aggregation kernel consumes the same
  wire format.

* ``CompressionSpec`` — the *analytic* projection: per-boundary activation
  ratios, per-tier model-exchange ratios, and ω.  This is what
  ``core.latency`` / ``core.convergence`` / ``core.problem`` and the fleet
  simulator consume; ``Compressor.spec(M)`` bridges the two.

``base`` is deliberately jax-free so the analytic layer can import it
without pulling in the execution stack.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Protocol, Tuple, runtime_checkable

import numpy as np


@runtime_checkable
class Compressor(Protocol):
    """One lossy wire codec, priced by (ratio, omega)."""

    name: str
    ratio: float   # wire bytes / raw float32 bytes, in (0, 1]
    omega: float   # sup_x E‖transform(x) − x‖² / ‖x‖²  (0 for identity)

    def transform(self, x, key=None):
        """Compress → decompress round trip of one tensor.

        Deterministic when ``key`` is None (what the engine-equality tests
        pin); stochastic schemes accept a PRNG key for unbiased rounding.
        """
        ...


@dataclass(frozen=True)
class CompressionSpec:
    """Per-link byte ratios + bound inflation for an M-tier hierarchy.

    ``act_ratio[m]``    scales the boundary-m activation/gradient bits of
                        Eqs. (12)/(14)  (m < M-1),
    ``model_ratio[m]``  scales the tier-m fed-server model bits of
                        Eqs. (15)/(16)  (m < M-1),
    ``omega``           inflates the σ² term of Theorem 1: σ² → (1+ω)σ².
    """

    act_ratio: Tuple[float, ...]
    model_ratio: Tuple[float, ...]
    omega: float = 0.0

    def __post_init__(self):
        for r in (*self.act_ratio, *self.model_ratio):
            if not 0.0 < r <= 1.0:
                raise ValueError(f"compression ratios must be in (0, 1]: {r}")
        if self.omega < 0.0:
            raise ValueError(f"omega must be non-negative: {self.omega}")

    def validate_for(self, M: int) -> "CompressionSpec":
        """Fail fast when the spec's arity doesn't match an M-tier system
        (otherwise a short spec only IndexErrors deep inside a solve)."""
        if len(self.act_ratio) != M - 1 or len(self.model_ratio) != M - 1:
            raise ValueError(
                f"CompressionSpec arity mismatch: M={M} needs {M - 1} "
                f"act/model ratios, got {len(self.act_ratio)}/"
                f"{len(self.model_ratio)}"
            )
        return self

    def to_dict(self) -> dict:
        """Plain-JSON projection (the api layer's provenance format)."""
        return {
            "act_ratio": list(self.act_ratio),
            "model_ratio": list(self.model_ratio),
            "omega": self.omega,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "CompressionSpec":
        return cls(
            act_ratio=tuple(float(r) for r in d["act_ratio"]),
            model_ratio=tuple(float(r) for r in d["model_ratio"]),
            omega=float(d.get("omega", 0.0)),
        )

    @classmethod
    def identity(cls, M: int) -> "CompressionSpec":
        return cls((1.0,) * (M - 1), (1.0,) * (M - 1), 0.0)

    @classmethod
    def uniform(
        cls,
        M: int,
        model_ratio: float,
        act_ratio: Optional[float] = None,
        omega: float = 0.0,
    ) -> "CompressionSpec":
        """Same ratio on every link of its kind (the common sweep axis)."""
        ar = 1.0 if act_ratio is None else act_ratio
        return cls((ar,) * (M - 1), (model_ratio,) * (M - 1), omega)


def act_ratio(compression: Optional[CompressionSpec], m: int) -> float:
    """Boundary-m activation byte multiplier (1.0 when uncompressed)."""
    return 1.0 if compression is None else float(compression.act_ratio[m])


def model_ratio(compression: Optional[CompressionSpec], m: int) -> float:
    """Tier-m fed-server model byte multiplier (1.0 when uncompressed)."""
    return 1.0 if compression is None else float(compression.model_ratio[m])


def measure_omega(
    compressor: Compressor,
    shape: Tuple[int, ...] = (4096,),
    samples: int = 8,
    seed: int = 0,
) -> float:
    """Monte-Carlo estimate of E‖C(x) − x‖² / ‖x‖² on Gaussian tensors.

    A sanity probe for the scheme's declared ``omega`` (which is the
    worst-case bound the convergence side prices); tests assert
    measured ≤ declared.
    """
    import jax

    errs = []
    for s in range(samples):
        key = jax.random.PRNGKey(np.int64(seed * 1000 + s))
        kx, kt = jax.random.split(key)
        x = jax.random.normal(kx, shape)
        xh = compressor.transform(x, key=kt)
        num = float(np.sum(np.square(np.asarray(xh - x, np.float64))))
        den = float(np.sum(np.square(np.asarray(x, np.float64))))
        errs.append(num / den)
    return float(np.mean(errs))
