"""DP uplinks (DESIGN.md §15): the vectorized accountant vs the scalar
oracle, mechanism noiseless collapse, the ε budget as a denominator floor
through solve_bcd, and the Engine-B unsupported-path contract."""
import math

import numpy as np
import pytest

from repro.configs.vgg16_cifar10 import SPEC as VGG
from repro.core import (
    HsflProblem, SystemSpec, build_profile, solve_bcd, synthetic_hyperspec,
)
from repro.core.convergence import theorem1_bound
from repro.privacy import (
    Accountant,
    DPMechanism,
    PrivacySpec,
    epsilon_oracle,
    rounds_for_budget,
)


def make_problem(seed=0, eps_scale=5.0):
    prof = build_profile(VGG, batch=16)
    system = SystemSpec.paper_three_tier(seed=seed)
    hp = synthetic_hyperspec(VGG.n_units, 20, beta=3.0, seed=seed)
    floor = theorem1_bound(hp, 10**9, [1, 1, 1], (3, 8))
    return HsflProblem(prof, system, hp, eps=eps_scale * floor)


# --------------------------------------------------------------------- #
# accountant vs the scalar oracle
# --------------------------------------------------------------------- #

ORACLE_GRID = [
    (0.8, 1.00, 1),
    (1.2, 0.50, 10),
    (2.0, 0.25, 100),
    (4.0, 0.05, 1000),
    (8.0, 1.00, 37),
    (16.0, 0.75, 500),
]


@pytest.mark.parametrize("z,q,R", ORACLE_GRID)
def test_accountant_matches_scalar_oracle(z, q, R):
    """Vectorized numpy composition == literal per-round math loops, 1e-9."""
    acc = Accountant(noise_multiplier=z, sampling_rate=q, delta=1e-5)
    assert abs(acc.epsilon(R) - epsilon_oracle(z, q, R, 1e-5)) <= 1e-9


@pytest.mark.parametrize("z,q", [(1.0, 1.0), (2.0, 0.3), (6.0, 0.8)])
def test_epsilon_monotone_in_rounds(z, q):
    acc = Accountant(noise_multiplier=z, sampling_rate=q, delta=1e-5)
    eps = [acc.epsilon(r) for r in (1, 2, 5, 20, 100, 1000)]
    assert all(a < b for a, b in zip(eps, eps[1:]))


def test_epsilon_monotone_in_inverse_noise():
    """More noise (larger z) spends strictly less ε per round."""
    eps = [
        Accountant(noise_multiplier=z, sampling_rate=0.5).epsilon(50)
        for z in (0.7, 1.0, 2.0, 4.0, 8.0)
    ]
    assert all(a > b for a, b in zip(eps, eps[1:]))


def test_epsilon_monotone_in_sampling_rate():
    """Sampling more of the fleet per round spends weakly more ε."""
    eps = [
        Accountant(noise_multiplier=2.0, sampling_rate=q).epsilon(50)
        for q in (0.05, 0.2, 0.5, 1.0)
    ]
    assert all(a < b for a, b in zip(eps, eps[1:]))


@pytest.mark.parametrize("z,q,eps_b", [(2.0, 1.0, 5.0), (6.0, 0.4, 80.0)])
def test_max_rounds_inverts_epsilon(z, q, eps_b):
    """R_max is the exact boundary: ε(R_max) ≤ budget < ε(R_max + 1)."""
    acc = Accountant(noise_multiplier=z, sampling_rate=q, delta=1e-5)
    R = acc.max_rounds(eps_b)
    assert R == int(R) and R > 0
    assert acc.epsilon(int(R)) <= eps_b < acc.epsilon(int(R) + 1)


def test_noiseless_accounting_degenerates():
    """z = 0: every round spends infinite ε, so a finite budget allows 0
    rounds — and an absent/∞ budget is unconstrained (None)."""
    acc = Accountant(noise_multiplier=0.0, sampling_rate=1.0)
    assert math.isinf(acc.epsilon(1))
    assert acc.epsilon(0) == 0.0
    assert rounds_for_budget(0.0, 1.0, 1e-5, 10.0) == 0.0
    assert rounds_for_budget(2.0, 1.0, 1e-5, math.inf) is None
    spec = PrivacySpec(noise_multiplier=0.0, clip=1.0)
    assert spec.dp_sigma2 == 0.0
    assert spec.max_rounds() is None


def test_privacy_spec_sigma2_scaling():
    """dp_sigma2 = (z·C)²·dim exactly."""
    spec = PrivacySpec(noise_multiplier=3.0, clip=0.5, dim=1000)
    assert spec.dp_sigma2 == (3.0 * 0.5) ** 2 * 1000


# --------------------------------------------------------------------- #
# mechanism
# --------------------------------------------------------------------- #


def test_mechanism_noiseless_is_clip_only():
    """z = 0 transform == pure per-row L2 clipping; rows already inside
    the clip ball come back bit-identical."""
    import jax.numpy as jnp

    rng = np.random.default_rng(0)
    x = rng.normal(size=(6, 4, 3)).astype(np.float32)
    x[0] *= 1e-3  # inside the ball: scale = 1 exactly
    mech = DPMechanism(clip=0.5, noise_multiplier=0.0, seed=0)
    out = np.asarray(mech.transform(jnp.asarray(x), 3, salt=1))
    flat = x.reshape(6, -1)
    norms = np.sqrt((flat * flat).sum(axis=1))
    ref = (flat * np.minimum(1.0, 0.5 / norms)[:, None]).reshape(x.shape)
    np.testing.assert_allclose(out, ref, rtol=1e-6)
    np.testing.assert_array_equal(out[0], x[0])


def test_mechanism_noise_reproducible_and_salted():
    """Same (seed, step, salt) → identical draw; different step or salt →
    different draw (independent noise per round and leaf)."""
    import jax.numpy as jnp

    x = jnp.ones((4, 8), jnp.float32) * 1e-3
    mech = DPMechanism(clip=1.0, noise_multiplier=2.0, seed=7)
    a = np.asarray(mech.transform(x, 5, salt=0))
    b = np.asarray(mech.transform(x, 5, salt=0))
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, np.asarray(mech.transform(x, 6, salt=0)))
    assert not np.array_equal(a, np.asarray(mech.transform(x, 5, salt=1)))


# --------------------------------------------------------------------- #
# the ε budget through the solvers
# --------------------------------------------------------------------- #


def test_zero_noise_spec_collapses_bitexact():
    """Attaching a z = 0 PrivacySpec leaves the BCD optimum bit-identical:
    dp_sigma2 = 0 and d_min = 0 make every compare the pre-DP one."""
    base = make_problem(seed=3, eps_scale=5.0)
    res0 = solve_bcd(base)
    prob = base.with_privacy(PrivacySpec(noise_multiplier=0.0, clip=1.0,
                                         dim=10**6))
    res1 = solve_bcd(prob)
    assert (res1.cuts, res1.intervals) == (res0.cuts, res0.intervals)
    assert res1.theta == res0.theta
    assert prob.d_min() == 0.0


def test_tight_eps_budget_moves_bcd_optimum():
    """An ε budget inside (R(I=1), R*) caps the rounds the schedule may
    spend, so BCD retreats to shorter intervals with weakly worse Θ'."""
    base = make_problem(seed=0, eps_scale=8.0)
    res0 = solve_bcd(base)
    r_star = base.rounds(res0.intervals, res0.cuts)
    r_min = base.rounds((1,) * base.M, res0.cuts)
    assert r_min < r_star  # the band the budget must land in
    z, clip = 16.0, 0.1  # tiny dp_sigma2 (dim=1): feasibility preserved
    acc = Accountant(noise_multiplier=z, sampling_rate=1.0)
    eps_b = acc.epsilon(int(0.3 * r_min + 0.7 * r_star))
    prob = base.with_privacy(PrivacySpec(
        noise_multiplier=z, clip=clip, dim=1, epsilon_budget=eps_b,
    ))
    res1 = solve_bcd(prob)
    r1 = prob.rounds(res1.intervals, res1.cuts)
    assert res1.intervals != res0.intervals
    assert res1.theta >= res0.theta
    assert r1 <= acc.max_rounds(eps_b)
    assert prob.d_min() > 0.0


# --------------------------------------------------------------------- #
# Engine-B unsupported paths name the supported alternative
# --------------------------------------------------------------------- #


def _tiny_model_plan():
    from repro.configs import get_reduced
    from repro.core.tiers import default_plan
    from repro.models.model import SplittableModel

    spec = get_reduced("smollm-135m")
    model = SplittableModel(spec)
    plan = default_plan(spec.n_units, 8, cuts=(1, 2), intervals=(2, 2, 1),
                        entities=(8, 4, 1))
    return model, plan


def test_engine_b_privacy_error_names_engine_a():
    from repro.core import build_train_step_b
    from repro.optim import sgd

    model, plan = _tiny_model_plan()
    with pytest.raises(NotImplementedError, match="Engine A"):
        build_train_step_b(
            model, plan, sgd(1e-2),
            privacy=DPMechanism(clip=1.0, noise_multiplier=1.0),
        )


def test_engine_b_class_members_error_names_engine_a():
    from repro.core import build_train_step_b
    from repro.optim import sgd

    model, plan = _tiny_model_plan()
    with pytest.raises(NotImplementedError, match="Engine A"):
        build_train_step_b(
            model, plan, sgd(1e-2), class_members=((0, 1), (2, 3)),
        )
