"""Fleet simulator: oracle equivalence, golden pricing, robust solving."""
import numpy as np
import pytest

from repro.configs.vgg16_cifar10 import SPEC as VGG
from repro.core import (
    HsflProblem, SystemSpec, build_profile, solve_bcd, synthetic_hyperspec,
)
from repro.core.convergence import theorem1_bound
from repro.core.latency import (
    aggregation_latency, per_client_split_latency, split_latency, split_stages,
)
from repro.sim import (
    SCENARIOS, TraceLatency, make_trace, robust_problem, simulate,
    simulate_rounds,
)

CUTS = (3, 8)
INTERVALS = (2, 3, 1)


def small_setup(num_clients=20, num_edges=5, batch=2, seed=0):
    prof = build_profile(VGG, batch=batch)
    system = SystemSpec.paper_three_tier(
        num_clients=num_clients, num_edges=num_edges, seed=seed
    )
    return prof, system


# --------------------------------------------------------------------------- #
# stage chain
# --------------------------------------------------------------------------- #


def test_stage_chain_covers_all_work():
    prof, system = small_setup()
    stages = split_stages(prof, CUTS)
    fwd = sum(s.work for s in stages if s.kind == "compute_fwd")
    bwd = sum(s.work for s in stages if s.kind == "compute_bwd")
    assert fwd == pytest.approx(prof.flops_fwd.sum())
    assert bwd == pytest.approx(prof.flops_bwd.sum())
    # chain is fwd up then bwd down: one uplink + one downlink per boundary
    assert sum(1 for s in stages if s.kind == "uplink") == system.M - 1
    assert sum(1 for s in stages if s.kind == "downlink") == system.M - 1


def test_per_client_split_latency_max_is_split_latency():
    prof, system = small_setup()
    t = per_client_split_latency(prof, system, CUTS)
    assert float(np.max(t)) == split_latency(prof, system, CUTS)


# --------------------------------------------------------------------------- #
# event core vs vectorized fast path (bit-exact)
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_event_core_matches_fleet_bit_exact(scenario, backend):
    prof, system = small_setup()
    trace = make_trace(scenario, prof, system, rounds=8, seed=123)
    ev = simulate(trace, CUTS, INTERVALS)
    fl = simulate_rounds(trace, CUTS, INTERVALS, backend=backend)
    assert np.array_equal(ev.split, fl.split)
    assert np.array_equal(ev.agg, fl.agg)
    assert np.array_equal(ev.fired, fl.fired)
    assert np.array_equal(ev.total, fl.total)
    assert np.array_equal(ev.participants, fl.participants)


@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
def test_event_core_matches_fleet_n256(scenario):
    prof = build_profile(VGG, batch=2)
    system = SystemSpec.paper_three_tier(num_clients=256, num_edges=8, seed=1)
    trace = make_trace(scenario, prof, system, rounds=4, seed=7)
    ev = simulate(trace, CUTS, INTERVALS)
    fl = simulate_rounds(trace, CUTS, INTERVALS)
    assert np.array_equal(ev.split, fl.split)
    assert np.array_equal(ev.total, fl.total)


def test_trace_determinism():
    prof, system = small_setup()
    a = make_trace("flaky-wan", prof, system, rounds=6, seed=9)
    b = make_trace("flaky-wan", prof, system, rounds=6, seed=9)
    ra = simulate_rounds(a, CUTS)
    rb = simulate_rounds(b, CUTS)
    assert np.array_equal(ra.total, rb.total)
    c = make_trace("flaky-wan", prof, system, rounds=6, seed=10)
    assert not np.array_equal(ra.total, simulate_rounds(c, CUTS).total)


def test_every_round_has_a_participant():
    prof, system = small_setup(num_clients=4, num_edges=2)
    trace = make_trace(
        "diurnal-churn", prof, system, rounds=48, seed=3, p_min=0.01, p_max=0.2
    )
    res = simulate_rounds(trace, CUTS)
    assert (res.participants >= 1).all()


def test_dropout_and_join_events_emitted():
    from repro.sim.events import DROPOUT, JOIN, simulate_round

    prof, system = small_setup(num_clients=8)
    trace = make_trace("diurnal-churn", prof, system, rounds=32, seed=5)
    kinds = set()
    prev = None
    for r in range(trace.rounds):
        res = simulate_round(trace, r, CUTS, prev_available=prev)
        kinds |= {e.kind for e in res.events}
        prev = trace.round_state(r).available
    assert DROPOUT in kinds and JOIN in kinds


# --------------------------------------------------------------------------- #
# golden: homogeneous-paper == the analytic model, exactly
# --------------------------------------------------------------------------- #


def test_homogeneous_golden_reproduces_analytic_model():
    prof, system = small_setup()
    trace = make_trace("homogeneous-paper", prof, system, rounds=8, seed=0)
    res = simulate_rounds(trace, CUTS)
    ts = split_latency(prof, system, CUTS)
    assert all(s == ts for s in res.split)  # exact, not approx
    for m in range(system.M - 1):
        ta = aggregation_latency(prof, system, CUTS, m)
        assert all(a == ta for a in res.agg[m])
    # and through the quantile pricing layer too
    lat = TraceLatency(trace, quantile=0.95)
    assert lat.split_T(CUTS) == ts
    assert lat.agg_T(CUTS, 0) == aggregation_latency(prof, system, CUTS, 0)


# --------------------------------------------------------------------------- #
# robust solving
# --------------------------------------------------------------------------- #


def paper_problem():
    prof = build_profile(VGG, batch=16)
    system = SystemSpec.paper_three_tier(num_clients=20, num_edges=5, seed=0)
    hp = synthetic_hyperspec(VGG.n_units, 20, beta=3.0, seed=0)
    floor = theorem1_bound(hp, 10**9, [1, 1, 1], (3, 8))
    return HsflProblem(prof, system, hp, eps=6.0 * floor)


def test_robust_problem_homogeneous_equals_nominal():
    prob = paper_problem()
    trace = make_trace(
        "homogeneous-paper", prob.profile, prob.system, rounds=16, seed=0
    )
    rp = robust_problem(prob, trace, quantile=0.95)
    cuts, iv = (3, 8), (2, 3, 1)
    assert rp.split_T(cuts) == prob.split_T(cuts)
    assert np.array_equal(rp.agg_T(cuts), prob.agg_T(cuts))
    assert rp.theta(iv, cuts) == prob.theta(iv, cuts)


@pytest.mark.slow
def test_bcd_solves_straggler_tail_and_moves_the_cut():
    prob = paper_problem()
    nominal = solve_bcd(prob)
    trace = make_trace(
        "straggler-tail", prob.profile, prob.system, rounds=64, seed=0
    )
    res = solve_bcd(robust_problem(prob, trace, quantile=0.95))
    assert np.isfinite(res.theta)
    # heavy on-device tail -> robust optimum keeps fewer units client-side
    assert res.cuts != nominal.cuts
    assert res.cuts[0] <= nominal.cuts[0]
    # robust pricing can only see the nominal system or worse
    assert res.theta >= nominal.theta


def test_robust_problem_rejects_mismatched_compression():
    from repro.compress import CompressionSpec

    prob = paper_problem()
    int8 = CompressionSpec.uniform(3, 0.25, omega=0.004)
    topk = CompressionSpec.uniform(3, 0.5, omega=0.75)
    trace = make_trace(
        "homogeneous-paper", prob.profile, prob.system, rounds=4, seed=0,
        compression=topk,
    )
    with pytest.raises(ValueError):
        robust_problem(prob.with_compression(int8), trace)
    # same spec on both sides is fine; problem-only gets threaded through
    rp = robust_problem(prob.with_compression(topk), trace)
    assert rp.latency_model.trace.compression == topk
    rp2 = robust_problem(prob.with_compression(int8), trace.with_compression(None))
    assert rp2.latency_model.trace.compression == int8


def test_trace_latency_p95_dominates_p50():
    prob = paper_problem()
    trace = make_trace(
        "straggler-tail", prob.profile, prob.system, rounds=64, seed=0
    )
    p50 = TraceLatency(trace, quantile=0.5)
    p95 = TraceLatency(trace, quantile=0.95)
    assert p95.split_T(CUTS) >= p50.split_T(CUTS)
    assert p95.split_T(CUTS) > split_latency(prob.profile, prob.system, CUTS)
