# repro.energy — per-tier energy pricing as a first-class cost
# (DESIGN.md §15).
#
# J/FLOP compute and J/byte radio tables priced over the canonical stage
# chain (scalar oracle) and the whole cut lattice (batched tables, exact
# same elementwise multiply/accumulate order — bit-exact against the
# oracle, mirroring the latency contract of core/batched.py).  Energy
# enters the solvers ONLY as a feasibility mask E(I, μ) ≤ budget: it
# never touches the Θ' arithmetic, so zero prices / no budget collapse
# bit-exactly to the unconstrained problem.
from .pricing import (
    EnergySpec,
    agg_energy,
    agg_energy_lattice,
    default_energy_spec,
    round_energy,
    split_energy,
    split_energy_lattice,
    stage_energy_prices,
)

__all__ = [
    "EnergySpec",
    "agg_energy",
    "agg_energy_lattice",
    "default_energy_spec",
    "round_energy",
    "split_energy",
    "split_energy_lattice",
    "stage_energy_prices",
]
