"""HSFL execution engines.

Engine A ("sync-groups", production): every tier's parameters are stacked
per-client on axis 0 and sharded over the `data` (and `pod`) mesh axes. The
hierarchy is realized purely as the multi-timescale aggregation schedule of
``tiers.synchronize`` — memory-balanced and collective-efficient on TPU.

Engine B ("split-placement", reference): tier-1 params stacked per client,
tier-2 per entity, tier-3 single — the literal SFL dataflow where activations
physically move client → entity → cloud. Used to prove Engine A's math and to
ground the latency model's activation-transfer terms.

Both engines implement Algorithm 1 of the paper exactly (per-client SGD on
replicas + Eq. 3 entity sync + Eq. 4 fed-server aggregation at I_m).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..optim import Optimizer
from .tiers import (
    GuardSpec,
    TierPlan,
    combine_tiers,
    guard_health,
    ragged_synchronize,
    synchronize,
    tier_subtrees,
)

Params = Dict[str, Any]


@dataclass
class TrainState:
    params: Params
    opt_state: Any
    step: jax.Array

    def tree_flatten(self):
        return (self.params, self.opt_state, self.step), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    TrainState,
    lambda s: ((s.params, s.opt_state, s.step), None),
    lambda aux, ch: TrainState(*ch),
)


def replicate_for_clients(params: Params, num_clients: int) -> Params:
    """Broadcast a single-model pytree to the client-stacked layout."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (num_clients,) + x.shape), params
    )


def unreplicate(params: Params) -> Params:
    return jax.tree.map(lambda x: x[0], params)


# --------------------------------------------------------------------------- #
# Engine A — sync groups
# --------------------------------------------------------------------------- #


def init_state_a(model, plan: TierPlan, opt: Optimizer, key) -> TrainState:
    p0 = model.init_params(key)
    params = replicate_for_clients(p0, plan.num_clients)
    return TrainState(params=params, opt_state=opt.init(params), step=jnp.zeros((), jnp.int32))


def _masked_select(new, old, w: jax.Array):
    """Per-client select: participants take the updated leaf, absentees keep
    the old one.  Only client-stacked leaves (leading axis N) are masked;
    scalar bookkeeping leaves (e.g. adam's step counter) pass through."""

    def f(n, o):
        if n.ndim == 0 or n.shape[0] != w.shape[0]:
            return n
        return jnp.where(
            w.reshape((-1,) + (1,) * (n.ndim - 1)) > 0.0, n, o
        )

    return jax.tree.map(f, new, old)


def masked_mean_loss(losses: jax.Array, w: jax.Array) -> jax.Array:
    """Participation-weighted round loss Σ w_i·loss_i / Σ w_i (0.0 for a
    zero-participant round — the round is a no-op, DESIGN.md §12)."""
    total = jnp.sum(w)
    return jnp.where(
        total > 0.0, jnp.sum(losses * w) / jnp.maximum(total, 1.0), 0.0
    )


def build_train_step_a(
    model, plan: TierPlan, opt: Optimizer, *, sync_opt_state: bool = False,
    fed_round=None, compressor=None, with_mask: bool = False,
    class_members=None, privacy=None, guard: Optional[GuardSpec] = None,
    with_sync_weights: bool = False,
) -> Callable[..., Tuple[TrainState, jax.Array]]:
    """Engine-A step: vmapped per-client update + hierarchical aggregation.

    batch leaves have a leading client axis [N, b, ...].

    ``fed_round``: None compiles one step with an in-graph ``lax.cond`` on
    the round counter; False/True compile the specialized local/sync round
    steps (see ``tiers.synchronize``) — the production dispatch is
    ``sync_step if (t+1) % I == 0 else local_step``.

    ``compressor`` (a ``repro.compress.Compressor``) puts the fed-server
    model exchange on a lossy wire: each client's uploaded replica goes
    through ``compressor.transform`` before the Eq. 4 mean — the same
    transform Engine B applies per entity, so the two engines stay equal
    (``tests/test_engines_equal.py``).  Optimizer moments are synchronized
    full-precision; only the priced parameter wire is compressed.

    The engines run the codec *key-less*, i.e. deterministic nearest
    rounding: reproducible and what the equality tests pin, with error
    second moment still ≤ the codec's ω, but not unbiased — Theorem 1's
    (1+ω) variance reading is exact only for the keyed stochastic mode,
    so empirical bound checks over this path are conservative heuristics
    (see ``benchmarks/compress_sweep.py``).

    ``with_mask=True`` returns ``step(state, batch, mask)`` instead: the
    [N] participation mask (1 = the client made the round's deadline)
    restricts the local update to participants — absentees keep their
    params and optimizer moments untouched — and every aggregation level
    averages participants only (``tiers.synchronize`` mask semantics,
    DESIGN.md §12).  The reported loss is the participation-weighted mean.
    An all-ones mask is bit-identical to the unmasked step.

    ``class_members`` (the ``tiers.class_tier_members`` matrices for a
    per-class cut assignment, DESIGN.md §14) switches every aggregation —
    params and, under ``sync_opt_state``, the optimizer moments — to
    ``tiers.ragged_synchronize``: tier m's levels average each unit only
    over the clients whose class holds it there.  With identical classes
    the member matrices are the plan's tier slices and the step is
    bit-identical to the dense path.

    ``privacy`` (a ``repro.privacy.DPMechanism``) puts the *same* fed-server
    params wire under client-level DP: each uploaded replica is per-client
    L2-clipped and Gaussian-noised *before* the codec sees it (noise under
    compression would let the codec shave noise the accountant already
    charged for — the composition order is fixed here, not configurable)
    and before the Eq. 4 mean.  Keys fold (seed, leaf, step) so every leaf
    of every round draws independent noise.  Optimizer-moment syncs and
    local entity syncs stay untouched — only the wire the (ε, δ) accountant
    meters is noised.  ``build()`` constructs no mechanism at
    ``noise_multiplier=0``, so the noiseless graph is bit-identical.

    ``guard`` (a ``tiers.GuardSpec``) arms fault tolerance (DESIGN.md §16):
    each step quarantines clients whose update is non-finite or a norm
    blow-up — their local update rolls back and every aggregation runs the
    guarded masked path, which sanitizes corrupt replicas before any
    arithmetic and heals them with the group broadcast at zero weight.
    ``guard=None`` (default) is byte-identical to today's graph, and an
    armed guard over an all-healthy round collapses bit-for-bit to the
    unguarded step (``tests/test_faults.py``).

    ``with_sync_weights=True`` makes the step additionally return the
    effective per-client sync weights [N] (participation mask × guard
    health × finite-loss; all-ones when neither masking nor a guard is
    armed) — the exact weights every aggregation level used this round.
    The async bounded-staleness runner (``core.async_agg``) captures
    these at snapshot time so a deferred fed-server apply weights clients
    identically to the in-step levels; re-deriving health at apply time
    would quarantine a different set.
    """
    compress_fn = (
        None if compressor is None
        else lambda x: jax.vmap(lambda v: compressor.transform(v))(x)
    )

    def _fed_wire(step):
        # per-step fed-upload transform: DP (clip + noise) then codec.
        if privacy is None:
            return compress_fn
        salt = iter(range(1_000_000))  # trace-time leaf counter

        def fn(x):
            y = privacy.transform(x, step, salt=next(salt))
            return y if compress_fn is None else compress_fn(y)

        return fn

    def _sync(tree, step, *, compress=None, mask=None, guarded=False):
        g = guard if guarded else None
        if class_members is not None:
            return ragged_synchronize(
                tree, plan, class_members, step, fed_round=fed_round,
                compress_fn=compress, mask=mask, guard=g,
            )
        return synchronize(
            tree, plan, step, fed_round=fed_round, compress_fn=compress,
            mask=mask, guard=g,
        )

    def _step(state: TrainState, batch: Params, mask) -> Tuple[TrainState, jax.Array]:
        losses, grads = jax.vmap(jax.value_and_grad(model.loss_fn))(
            state.params, batch
        )
        new_params, new_opt = opt.update(state.params, grads, state.opt_state)
        if guard is not None:
            # Guarded step (DESIGN.md §16): quarantine clients whose update
            # went non-finite or blew up in norm.  Their local update is
            # rolled back (they keep pre-step params/moments, possibly still
            # corrupt — the guarded syncs below sanitize and heal them with
            # the group broadcast at zero weight), and the reported loss is
            # the health-weighted mean over finite losses only — every
            # arithmetic op here sees sanitized values, so a healthy round
            # runs clean under JAX_DEBUG_NANS.
            health, _ = guard_health(new_params, plan.num_clients, guard)
            lfin = jnp.isfinite(losses)
            health = health * lfin.astype(jnp.float32)
            w = (
                health if mask is None
                else mask.astype(jnp.float32) * health
            )
            new_params = _masked_select(new_params, state.params, w)
            new_opt = _masked_select(new_opt, state.opt_state, w)
            lsafe = jnp.where(lfin, losses, 0.0)
            loss = masked_mean_loss(lsafe, w)
            if mask is None:
                # all-healthy unmasked rounds must report the exact plain
                # mean (bit-for-bit zero-fault collapse); lsafe == losses
                # there, so this stays NaN-free under JAX_DEBUG_NANS
                loss = jnp.where(
                    jnp.all(w >= 1.0), jnp.mean(lsafe), loss
                )
            sync_mask = w
        elif mask is None:
            loss = jnp.mean(losses)
            sync_mask = None
        else:
            w = mask.astype(jnp.float32)
            new_params = _masked_select(new_params, state.params, w)
            new_opt = _masked_select(new_opt, state.opt_state, w)
            loss = masked_mean_loss(losses, w)
            sync_mask = mask
        new_params = _sync(
            new_params, state.step, compress=_fed_wire(state.step),
            mask=sync_mask, guarded=True,
        )
        if sync_opt_state and jax.tree.leaves(new_opt):
            new_opt = jax.tree.map(
                lambda x: x, new_opt
            )  # structure-preserving no-op; moments follow params below
            # momentum/adam moments are client-stacked like params: apply the
            # same schedule so replicas stay consistent after aggregation.
            if opt.name == "momentum":
                new_opt = _sync(
                    new_opt, state.step, mask=sync_mask, guarded=True
                )
            elif opt.name == "adam":
                new_opt = dict(new_opt)
                new_opt["m"] = _sync(
                    new_opt["m"], state.step, mask=sync_mask, guarded=True
                )
                new_opt["v"] = _sync(
                    new_opt["v"], state.step, mask=sync_mask, guarded=True
                )
        new_state = TrainState(new_params, new_opt, state.step + 1)
        if with_sync_weights:
            ww = (
                jnp.ones((plan.num_clients,), jnp.float32)
                if sync_mask is None else sync_mask.astype(jnp.float32)
            )
            return new_state, loss, ww
        return new_state, loss

    if with_mask:
        return _step
    return lambda state, batch: _step(state, batch, None)


# --------------------------------------------------------------------------- #
# Engine B — split placement (reference)
# --------------------------------------------------------------------------- #


def init_state_b(model, plan: TierPlan, opt: Optimizer, key) -> TrainState:
    """Params: list of per-tier pytrees; tier m stacked over J_m entities."""
    p0 = model.init_params(key)
    full = replicate_for_clients(p0, plan.num_clients)
    parts = tier_subtrees(full, plan)
    tier_params = []
    for m, part in enumerate(parts):
        J = plan.entities[m]
        per = plan.num_clients // J
        tier_params.append(jax.tree.map(lambda x: x[::per], part))  # [J_m, ...]
    return TrainState(
        params=tier_params,
        opt_state=opt.init(tier_params),
        step=jnp.zeros((), jnp.int32),
    )


def build_train_step_b(
    model, plan: TierPlan, opt: Optimizer, *, compressor=None,
    with_mask: bool = False, class_members=None, privacy=None,
) -> Callable[..., Tuple[TrainState, jax.Array]]:
    """Engine-B step: literal split execution.

    Forward: tier-1 vmapped over N clients; activations regrouped into J_2
    entity batches; ... up to the single tier-M model over the global batch.
    Backward: one value_and_grad through the composed function; per-tier
    gradients rescaled to implement per-client SGD + Eq. 3 exactly.

    ``compressor`` compresses each entity's model upload before the Eq. 4
    fed-server mean — the literal wire the latency model prices with
    ``model_ratio`` (DESIGN.md §9).

    ``with_mask=True`` returns ``step(state, batch, mask)``: the global
    objective becomes the participation-weighted mean Σ w_i·loss_i / Σ w_i
    (per-client losses, so clients weight exactly as in Engine A), each
    tier-m entity's gradient is rescaled by Σw / Σ_{i∈j} w_i — the mean
    over its *participating* clients' gradients, zero for a
    zero-participant entity, whose sub-model therefore keeps its last
    synced params — and the Eq. 4 fed-server mean weights entities by
    their participant counts.  This mirrors ``tiers.synchronize``'s mask
    semantics, so A == B extends to partial rounds
    (``tests/test_engines_equal.py``).  MoE specs are not supported here:
    the aux-loss regrouping means are unweighted, so a masked MoE round
    would diverge from Engine A.
    """
    N = plan.num_clients
    M = plan.M
    spec = model.spec
    if class_members is not None:
        raise NotImplementedError(
            "Engine B physically places each tier's units on its hosts — a "
            "per-class cut assignment has no single placement (clients "
            "disagree on which units are client-side).  Use Engine A with "
            "class_members (ragged sync-groups), the production path for "
            "DESIGN.md §14."
        )
    if privacy is not None:
        raise NotImplementedError(
            "Engine B does not support DP-noised uploads: its fed wire "
            "carries one model per *entity*, so per-client clipping (the "
            "unit the (ε, δ) accountant meters) has no faithful placement. "
            "Use Engine A with privacy (the production DP path), or run "
            "Engine B noiseless (privacy=None)."
        )
    if with_mask and getattr(spec, "moe", None) is not None:
        raise NotImplementedError(
            "masked Engine B does not support MoE specs: the aux-loss "
            "regroup means are participation-unweighted (use Engine A for "
            "masked MoE training)"
        )

    def global_loss(tier_params, batch, w=None):
        # ---- tier 1 on each client ----
        def t1(p, b):
            carry = model.frontend_apply(p["frontend"], b)
            lo, hi = plan.tier_bounds(0)
            prefix = spec.prefix_len if spec.family == "vlm" else 0
            return model.apply_units(p["units"], carry, 0, hi - lo, prefix_len=prefix)

        # MoE capacity semantics: a server hosting several clients' tokens
        # must dispatch with per-client groups, or pooled tokens compete for
        # expert slots and the split execution diverges from per-client SFL
        # (Eq. 2/3 operate per client). moe_groups = co-located clients.
        if hasattr(model, "moe_groups"):
            model.moe_groups = 1  # t1 is vmapped per client
        carry = jax.vmap(t1)(tier_params[0], batch)  # leaves [N, b, ...]

        # ---- middle tiers on entity-regrouped activations ----
        for m in range(1, M - 1):
            J = plan.entities[m]
            per = N // J

            def regroup(x):
                return x.reshape(J, per * x.shape[1], *x.shape[2:])

            def split_back(x):
                return x.reshape(N, x.shape[1] // per, *x.shape[2:])

            carry_e = jax.tree.map(
                lambda x: regroup(x) if x.ndim >= 2 else x.reshape(J, per).mean(1),
                carry,
            )
            lo, hi = plan.tier_bounds(m)

            def tm(p, c):
                # p["units"] is pre-sliced to this tier -> local indices
                prefix = spec.prefix_len if spec.family == "vlm" else 0
                return model.apply_units(p["units"], c, 0, hi - lo, prefix_len=prefix)

            if hasattr(model, "moe_groups"):
                model.moe_groups = per  # entity batch pools `per` clients
            carry_e = jax.vmap(tm)(tier_params[m], carry_e)
            # scalars (the moe aux) carry *means*: regroup averages over an
            # entity's clients, so split_back replicates the mean back to
            # each client unchanged (a /per here would shrink aux per tier).
            carry = jax.tree.map(
                lambda x: split_back(x) if x.ndim >= 2 else jnp.repeat(x, per),
                carry_e,
            )

        # ---- top tier on the concatenated global batch ----
        def flatten(x):
            return x.reshape(N * x.shape[1], *x.shape[2:])

        carry_g = jax.tree.map(
            lambda x: flatten(x) if x.ndim >= 2 else x.mean() * N, carry
        )
        lo, hi = plan.tier_bounds(M - 1)
        pM = jax.tree.map(lambda x: x[0], tier_params[M - 1])
        prefix = spec.prefix_len if spec.family == "vlm" else 0
        if hasattr(model, "moe_groups"):
            model.moe_groups = N  # cloud batch pools all N clients
        aux_pre = carry_g.get("aux", jnp.zeros((), jnp.float32))
        carry_g = model.apply_units(pM["units"], carry_g, 0, hi - lo, prefix_len=prefix)
        if hasattr(model, "moe_groups"):
            model.moe_groups = 1  # restore
        from ..models import layers as L

        if spec.tie_embeddings:
            # tied unembedding weights live on tier 1 (per client)
            h = L.rms_norm(carry_g["h"], pM["head"]["norm"], spec.norm_eps)
            b_sz = h.shape[0] // N
            hn = h.reshape(N, b_sz, *h.shape[1:])
            emb = tier_params[0]["frontend"]["embed"]  # [N, V, d]
            logits = jnp.einsum("nbsd,nvd->nbsv", hn, emb.astype(hn.dtype))
            logits = logits.reshape(h.shape[0], h.shape[1], -1)
        else:
            logits = model.head_apply(
                {"head": pM["head"], "frontend": None}, carry_g
            )
        labels = batch["labels"].reshape(-1, batch["labels"].shape[-1])
        if spec.family == "vlm":
            logits = logits[:, spec.prefix_len :]
        lmask = (labels >= 0).astype(jnp.float32)
        if w is None:
            loss = L.cross_entropy(logits, jnp.maximum(labels, 0), lmask)
        else:
            # per-client CE then participation-weighted mean: clients enter
            # the objective exactly as Engine A's vmapped loss_fn does.
            lg = logits.reshape(N, -1, *logits.shape[1:])
            lb = labels.reshape(N, -1, *labels.shape[1:])
            lm = lmask.reshape(N, -1, *lmask.shape[1:])
            per_client = jax.vmap(
                lambda lo, la, mk: L.cross_entropy(lo, jnp.maximum(la, 0), mk)
            )(lg, lb, lm)
            return masked_mean_loss(per_client, w)
        if spec.moe is not None:
            # aux bookkeeping: pre-flatten aux arrives scaled by N (the
            # scalar flatten is x.mean()*N), so divide it back; the top
            # tier's own aux (post - pre) is shared by every client in
            # Engine A and enters at full weight.
            aux_top = carry_g["aux"] - aux_pre
            loss = loss + 0.01 * (aux_pre / N + aux_top)
        return loss

    def _step(state: TrainState, batch: Params, mask) -> Tuple[TrainState, jax.Array]:
        w = None if mask is None else mask.astype(jnp.float32)
        loss, grads = jax.value_and_grad(global_loss)(state.params, batch, w)
        # per-client SGD semantics: tier m's shared entity model moves by the
        # *mean of its clients' gradients* = (N / N_m^j) * dL/dw_m  (see
        # DESIGN); under a mask the mean runs over the entity's participants
        # only — scale Σw / Σ_{i∈j} w_i, zero for a zero-participant entity.
        scaled = []
        for m, g in enumerate(grads):
            J = plan.entities[m]
            if w is None:
                scaled.append(jax.tree.map(lambda x, J=J: x * J, g))
            else:
                wj = w.reshape(J, N // J).sum(axis=1)  # [J] participant counts
                sc = jnp.where(wj > 0.0, jnp.sum(w) / jnp.maximum(wj, 1.0), 0.0)
                scaled.append(
                    jax.tree.map(
                        lambda x, sc=sc, J=J: x
                        * sc.reshape((J,) + (1,) * (x.ndim - 1)).astype(x.dtype),
                        g,
                    )
                )
        new_params, new_opt = opt.update(state.params, scaled, state.opt_state)
        # Eq. 4 fed-server aggregation across entities at I_m
        out = []
        for m, p in enumerate(new_params):
            interval = int(plan.intervals[m])
            if plan.entities[m] > 1 and interval >= 1:
                do = (state.step + 1) % interval == 0
                J = plan.entities[m]

                def agg(t, J=J):
                    original = t  # zero-participant fallback must be the
                    # entities' last synced params, never a compressed copy
                    if compressor is not None:
                        # lossy fed-server upload, per entity (axis 0)
                        t = jax.tree.map(
                            lambda x: jax.vmap(
                                lambda v: compressor.transform(v)
                            )(x),
                            t,
                        )
                    if w is None:
                        return jax.tree.map(
                            lambda x: jnp.broadcast_to(
                                jnp.mean(x, 0, keepdims=True), x.shape
                            ),
                            t,
                        )
                    # entities weighted by participant count — the same
                    # hierarchical weighting tiers.synchronize applies in
                    # Engine A; a zero-participant *round* leaves every
                    # entity at its last synced params.
                    wj = w.reshape(J, N // J).sum(axis=1)
                    s = jnp.sum(wj)

                    def wm(x, k):
                        ww = wj.reshape((J,) + (1,) * (x.ndim - 1))
                        tot = jnp.sum(
                            x * ww.astype(x.dtype), axis=0, keepdims=True,
                            dtype=jnp.float32,
                        )
                        mn = (tot / jnp.maximum(s, 1.0)).astype(x.dtype)
                        return jnp.where(
                            s > 0.0, jnp.broadcast_to(mn, x.shape), k
                        )

                    return jax.tree.map(wm, t, original)

                p = lax.cond(do, agg, lambda t: t, p)
            out.append(p)
        return TrainState(out, new_opt, state.step + 1), loss

    if with_mask:
        return _step
    return lambda state, batch: _step(state, batch, None)


def engine_b_to_full(model, plan: TierPlan, tier_params) -> Params:
    """Materialize Engine-B tier params back into a client-stacked pytree."""
    parts = []
    for m, p in enumerate(tier_params):
        J = plan.entities[m]
        per = plan.num_clients // J
        parts.append(jax.tree.map(lambda x: jnp.repeat(x, per, axis=0), p))
    template = {
        "units": parts[0]["units"],
        "frontend": parts[0]["frontend"],
        "head": parts[-1]["head"],
    }
    return combine_tiers(parts, template)
